"""LM token pipeline: block-I/O backed, double-buffered prefetch.

The paper's block-wise storage discipline applied to LM pretraining data:
the token corpus lives on storage as fixed-size blocks; an epoch visits a
shuffled sequence of *blocks* (not samples), each block-wise read feeding
``block_size/ (seq_len·4)`` samples — one storage I/O serves a whole
batch slice (the hyperbatch inversion again).  A background thread
prefetches the next block(s) while the device computes (paper §3.4(4)).
"""
from __future__ import annotations

import os
import queue
import threading

import numpy as np

from ..core.device_model import NVMeModel, IOStats


class TokenBlockStore:
    """Fixed-block token storage (synthetic corpus generator included)."""

    def __init__(self, path: str, vocab: int, block_tokens: int,
                 device: NVMeModel | None = None):
        self.path = path
        self.vocab = vocab
        self.block_tokens = block_tokens
        self.device = device or NVMeModel()
        self.stats = IOStats()
        self._mm = np.memmap(path, dtype=np.int32, mode="r")
        self.n_blocks = len(self._mm) // block_tokens

    @classmethod
    def synthesize(cls, path: str, *, vocab: int, n_tokens: int,
                   block_tokens: int = 1 << 20, seed: int = 0,
                   zipf: float = 1.2) -> "TokenBlockStore":
        """Zipf-distributed synthetic corpus (realistic token frequencies)."""
        if not os.path.exists(path):
            rng = np.random.default_rng(seed)
            n_blocks = max(n_tokens // block_tokens, 1)
            with open(path, "wb") as f:
                for _ in range(n_blocks):
                    u = rng.random(block_tokens)
                    ranks = (u ** (-1.0 / (zipf - 1.0))).astype(np.int64)
                    toks = np.clip(ranks, 1, vocab - 1).astype(np.int32)
                    toks.tofile(f)
        return cls(path, vocab, block_tokens)

    def read_block(self, i: int) -> np.ndarray:
        raw = np.asarray(self._mm[i * self.block_tokens:
                                  (i + 1) * self.block_tokens])
        nbytes = self.block_tokens * 4
        t = self.device.request_time(nbytes, sequential=False)
        self.stats.record_read(nbytes, t, sequential=False)
        return raw


class TokenPipeline:
    """Double-buffered block reader → (micro, batch, seq) batches."""

    def __init__(self, store: TokenBlockStore, *, batch: int, seq_len: int,
                 n_micro: int = 1, seed: int = 0, prefetch: int = 2):
        self.store = store
        self.batch = batch
        self.seq_len = seq_len
        self.n_micro = n_micro
        self.seed = seed
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = False
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self):
        rng = np.random.default_rng(self.seed)
        tokens_needed = self.batch * self.seq_len
        buf = np.zeros(0, dtype=np.int32)
        epoch = 0
        while not self._stop:
            order = rng.permutation(self.store.n_blocks)
            for b in order:
                if self._stop:
                    return
                buf = np.concatenate([buf, self.store.read_block(int(b))])
                while len(buf) >= tokens_needed:
                    batch = buf[:tokens_needed].reshape(
                        self.n_micro, self.batch // self.n_micro,
                        self.seq_len)
                    buf = buf[tokens_needed:]
                    self._q.put(batch.copy())
            epoch += 1

    def __iter__(self):
        return self

    def __next__(self) -> np.ndarray:
        return self._q.get()

    def close(self):
        self._stop = True
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
