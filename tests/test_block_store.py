"""Storage layer: block packing, object index table, round trips."""
import numpy as np
import pytest

from repro.core import (DEFAULT_BLOCK_SIZE, FeatureBlockStore,
                        GraphBlockStore, NVMeModel)
from repro.data.synth import powerlaw_graph, rmat_graph


def _roundtrip_all(store, indptr, indices):
    """Read every node's full adjacency back through block I/O."""
    n = len(indptr) - 1
    got = {v: [] for v in range(n)}
    for b in range(store.n_blocks):
        blk = store.read_block(b)
        for e in range(len(blk.node_ids)):
            got[int(blk.node_ids[e])].append(blk.adjacency(e))
    for v in range(n):
        ref = np.sort(indices[indptr[v]:indptr[v + 1]])
        mine = np.sort(np.concatenate(got[v]) if got[v] else
                       np.zeros(0, np.int64))
        assert np.array_equal(ref, mine), f"node {v}"


def test_graph_store_roundtrip(tmp_path):
    indptr, indices = rmat_graph(500, 4000, seed=1)
    store = GraphBlockStore.build(str(tmp_path / "g.blk"), indptr, indices,
                                  block_size=4096)
    _roundtrip_all(store, indptr, indices)


def test_graph_store_split_objects(tmp_path):
    """A hub node whose adjacency exceeds one block must split cleanly."""
    n = 64
    deg = np.full(n, 4)
    deg[0] = 3000  # >> one 4K block of int32 words
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(deg, out=indptr[1:])
    rng = np.random.default_rng(0)
    indices = rng.integers(0, n, indptr[-1])
    store = GraphBlockStore.build(str(tmp_path / "g.blk"), indptr, indices,
                                  block_size=4096)
    blocks = store.blocks_for_nodes(np.array([0]))
    assert len(blocks) >= 3, "hub must span multiple blocks"
    _roundtrip_all(store, indptr, indices)


def test_blocks_for_nodes_matches_scan(tmp_path):
    indptr, indices = powerlaw_graph(300, 10, seed=2)
    store = GraphBlockStore.build(str(tmp_path / "g.blk"), indptr, indices,
                                  block_size=2048)
    # ground truth membership by scanning all blocks
    member = {v: set() for v in range(300)}
    for b in range(store.n_blocks):
        blk = store.read_block(b)
        for v in blk.node_ids:
            member[int(v)].add(b)
    for v in [0, 1, 5, 99, 299]:
        got = set(store.blocks_for_nodes(np.array([v])).tolist())
        assert got == member[v], f"node {v}: {got} != {member[v]}"


def test_feature_store_roundtrip(tmp_path):
    feats = np.random.default_rng(0).normal(size=(100, 16)).astype(np.float32)
    store = FeatureBlockStore.build(str(tmp_path / "f.blk"), feats,
                                    block_size=1024)
    for b in range(store.n_blocks):
        rows = store.read_block(b)
        lo = b * store.rows_per_block
        hi = min(lo + store.rows_per_block, 100)
        assert np.allclose(rows[:hi - lo], feats[lo:hi])


def test_feature_node_granular_accounting(tmp_path):
    feats = np.zeros((50, 8), dtype=np.float32)
    store = FeatureBlockStore.build(str(tmp_path / "f.blk"), feats,
                                    block_size=1024)
    nodes = np.array([1, 7, 33])
    store.read_rows_node_granular(nodes)
    assert store.stats.n_reads == 3
    assert store.stats.bytes_read == 3 * 4096  # 4K min unit per row


def test_graph_decode_many_matches_decode(tmp_path):
    """Vectorized multi-block decode == per-block decode, incl. splits."""
    n = 64
    deg = np.full(n, 4)
    deg[0] = 3000  # split object spanning several 4K blocks
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(deg, out=indptr[1:])
    rng = np.random.default_rng(0)
    indices = rng.integers(0, n, indptr[-1])
    store = GraphBlockStore.build(str(tmp_path / "g.blk"), indptr, indices,
                                  block_size=4096)
    batch = store.read_run(0, store.n_blocks)
    assert len(batch) == store.n_blocks
    for b in range(store.n_blocks):
        ref = store.read_block(b)
        got = batch[b]
        assert got.block_id == b
        assert np.array_equal(ref.node_ids, got.node_ids)
        assert np.array_equal(ref.indptr, got.indptr)
        assert np.array_equal(ref.indices, got.indices)
        assert np.array_equal(ref.total_degree, got.total_degree)


def test_read_blocks_accounting(tmp_path):
    indptr, indices = rmat_graph(500, 4000, seed=1)
    store = GraphBlockStore.build(str(tmp_path / "g.blk"), indptr, indices,
                                  block_size=4096)
    ids = np.arange(store.n_blocks)
    out = store.read_blocks(ids, max_coalesce_bytes=4 * 4096)
    assert [b.block_id for b in out] == ids.tolist()
    # block-granular read count + coalesced request count
    assert store.stats.n_reads == store.n_blocks
    assert store.stats.n_requests == -(-store.n_blocks // 4)
    assert store.stats.bytes_read == store.n_blocks * 4096
    assert store.stats.n_sequential_reads == \
        store.n_blocks - store.stats.n_requests


def test_feature_read_blocks_matches_read_block(tmp_path):
    feats = np.random.default_rng(0).normal(size=(100, 16)).astype(np.float32)
    store = FeatureBlockStore.build(str(tmp_path / "f.blk"), feats,
                                    block_size=1024)
    batch = store.read_blocks(np.arange(store.n_blocks),
                              max_coalesce_bytes=8 * 1024)
    for b in range(store.n_blocks):
        assert np.array_equal(batch[b], store.read_block(b))


def test_feature_build_streams_with_tail_padding(tmp_path):
    """Streaming build: identical bytes to the old fully padded copy,
    including for non-contiguous feature input."""
    feats = np.random.default_rng(1).normal(size=(103, 12)).astype(np.float32)
    strided = np.asfortranarray(feats)  # non-C-contiguous input
    store = FeatureBlockStore.build(str(tmp_path / "f.blk"), strided,
                                    block_size=256)
    raw = np.fromfile(str(tmp_path / "f.blk"), dtype=np.float32)
    padded = np.zeros((store.n_blocks * store.rows_per_block, 12), np.float32)
    padded[:103] = feats
    assert np.array_equal(raw, padded.ravel())
    assert np.allclose(np.asarray(store._mm[:103]), feats)


def test_device_model_regimes():
    dev = NVMeModel()
    # many small random reads are IOPS-bound
    small = dev.batch_time(4096 * 10000, n_random=10000)
    # one big sequential read is bandwidth-bound
    big = dev.batch_time(4096 * 10000, n_random=1)
    assert small > big
    assert small >= 10000 * dev.latency / dev.queue_depth * 0.99
    # RAID0 scales bandwidth
    dev4 = NVMeModel(n_ssd=4)
    assert dev4.request_time(1 << 20) < dev.request_time(1 << 20)
