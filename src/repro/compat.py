"""jax version-compatibility shims.

The codebase targets the jax 0.5+/0.6 sharding surface
(``jax.sharding.get_abstract_mesh`` / ``set_mesh`` / ``AxisType``); the
pinned container toolchain ships jax 0.4.37 where none of those exist.
Every use of the newer API goes through this module so the rest of the
tree stays version-agnostic: on new jax the shims are thin pass-throughs,
on 0.4.x they fall back to the legacy mesh-context machinery
(``with mesh:`` sets ``thread_resources.env.physical_mesh``, which is
what ``with_sharding_constraint`` consults there).
"""
from __future__ import annotations

import contextlib

import jax


def get_abstract_mesh():
    """Mesh currently in scope, or None outside any mesh context.

    Returns an object with ``.axis_names`` and a mapping ``.shape`` —
    either jax's AbstractMesh (0.5+) or the legacy physical Mesh (0.4.x).
    """
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        return fn()
    mesh = jax._src.mesh.thread_resources.env.physical_mesh
    return None if mesh.empty else mesh


@contextlib.contextmanager
def set_mesh(mesh):
    """``jax.sharding.set_mesh`` when available, else the legacy
    ``with mesh:`` context (same effect for GSPMD constraint lookup)."""
    ctx = getattr(jax.sharding, "set_mesh", None)
    if ctx is not None:
        with ctx(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh


def make_mesh(shape, axis_names):
    """``jax.make_mesh`` with Auto axis types where the kwarg exists."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axis_names,
                             axis_types=(axis_type.Auto,) * len(axis_names))
    return jax.make_mesh(shape, axis_names)
