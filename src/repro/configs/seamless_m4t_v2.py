"""seamless-m4t-large-v2 [audio]: enc-dec, 24L each, d=1024, 16H,
d_ff=8192, vocab=256206 — multimodal; the speech frontend is a STUB
(``input_specs`` supplies precomputed frame embeddings to the encoder).
[arXiv:2308.11596; hf]
"""
from .base import ModelConfig, register


@register("seamless-m4t-large-v2")
def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2", family="audio",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=8192, vocab=256206, head_dim=64,
        n_enc_layers=24, enc_seq=4096, frontend="audio_stub",
        tie_embeddings=True,
        source="arXiv:2308.11596 (SeamlessM4T-large v2 text enc-dec dims)")
