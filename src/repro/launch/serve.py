"""LM serving driver: batched decode with paged KV admission.

Smoke-scale demo of the serving path: admits a queue of requests through
the AGNES-style paged KV manager, decodes them as one hyperbatch per
step, retires finished requests and back-fills from the queue
(continuous batching).

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, smoke_reduce
from ..models import build_model
from ..train.loop import make_serve_step
from ..train.paged_kv import PagedKVConfig, PagedKVManager


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--gen-tokens", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_reduce(cfg)
    if cfg.n_enc_layers:
        print("[serve] enc-dec serving demo uses zero encoder memory stub")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    serve_step = jax.jit(make_serve_step(model), donate_argnums=(1,))

    B = args.batch
    caches = model.init_cache(B, args.max_len)
    kv = PagedKVManager(PagedKVConfig(
        page_tokens=16, n_pages=B * args.max_len // 16 + 8,
        max_requests=B))

    rng = np.random.default_rng(0)
    pending = [(rid, int(rng.integers(4, 12)))
               for rid in range(args.requests)]
    done, generated = [], {}
    tokens = jnp.zeros((B,), jnp.int32)
    t0 = time.time()
    pos = 0
    slot_of = {}
    while pending or kv.tables:
        # continuous batching: back-fill free slots
        while pending and len(kv.tables) < B:
            rid, plen = pending.pop(0)
            if not kv.admit(rid, plen):
                pending.insert(0, (rid, plen))
                break
            slot_of[rid] = len(slot_of) % B
            generated[rid] = []
        tokens_next, logits, caches = serve_step(
            params, caches, tokens, jnp.asarray(pos, jnp.int32))
        pos += 1
        tokens = tokens_next
        batch = kv.decode_batch()
        for rid in list(kv.tables):
            kv.extend(rid, 1)
            generated[rid].append(int(tokens[slot_of[rid] % B]))
            if len(generated[rid]) >= args.gen_tokens or pos >= args.max_len:
                kv.release(rid)
                done.append(rid)
        if pos >= args.max_len:
            break
    dt = time.time() - t0
    n_tok = sum(len(g) for g in generated.values())
    print(f"[serve] {len(done)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/max(dt,1e-9):.1f} tok/s); "
          f"kv utilization peak={kv.utilization:.2f} "
          f"fragmentation={kv.fragmentation():.3f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
