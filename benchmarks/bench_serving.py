"""Serving tier under mixed tenancy: QoS latency, throughput, migration.

The serving tier (``core/serving.py``) exists so a latency-sensitive
inference prepare path can share one storage topology with bulk training
I/O without either destroying the other.  This benchmark drives real
concurrent tenants through one :class:`AdmissionController` and gates on
the subsystem's three claims:

* **inference latency** — p50/p99 of ego-net prepares (k-hop sample +
  gather) served *while bulk training runs*, vs the same requests on an
  idle system: the QoS path must hold duel p99 within 3x of idle p99
  (``MIN_P99_HEADROOM``, expressed as ``3 * idle_p99 / duel_p99 >= 1``).
  A ``fifo`` (uncoordinated) duel is reported alongside for contrast —
  there inference queues behind the full training backlog;
* **training throughput** — the bulk tenant must keep >= 0.8x of its
  solo modeled I/O rate (``MIN_TRAIN_THROUGHPUT``) with admission
  stalls charged, and **byte parity** must hold exactly for both
  tenants vs their solo runs (admission reorders issue order, never
  bytes);
* **mid-epoch migration** — the migration tenant runs only in queue
  slack (a drill asserts it refuses while any tenant has queued work),
  moves hot blocks mid-epoch through the same admission queues, and the
  oracle cache schedule is rebuilt from the *remaining* trace
  afterwards, with post-migration prepares byte-identical to an
  untouched twin.

Tracked in ``BENCH_serving.json`` and guarded by
``benchmarks.check_regression`` (p99 headroom + training throughput).
Timing is modeled (``device_model``) over real memmap reads, so the
latency numbers are deterministic rooflines, not wall-clock noise.
"""
from __future__ import annotations

import os
import threading

import numpy as np

from .common import WORKDIR, emit, quick_val

from repro.core import (AgnesConfig, AgnesEngine, FeatureBlockStore,
                        GraphBlockStore, NVMeModel, ServingTier,
                        StorageTopology, trace_from_plan)

MIN_P99_HEADROOM = 1.0       # 3 * idle_p99 / duel_p99 (>= 1 <=> duel <= 3x)
MIN_TRAIN_THROUGHPUT = 0.8   # duel training io rate vs solo, stalls charged

N_NODES = 4_096
RING_K = 8                   # ring neighbors per side (degree 16)
G_BLOCK = 2048
F_DIM = 512                  # 2 KiB rows -> one row per feature block
F_BLOCK = 2048
MB, N_MB = 64, 4             # training minibatch geometry
N_ARRAYS = 4


def _build_workload() -> tuple[str, str]:
    gpath = os.path.join(WORKDIR, "serving_ring.graph")
    fpath = os.path.join(WORKDIR, "serving_ring.feat")
    if not os.path.exists(gpath + ".meta.json"):
        offs = np.concatenate([np.arange(-RING_K, 0),
                               np.arange(1, RING_K + 1)])
        indices = ((np.arange(N_NODES)[:, None] + offs[None, :])
                   % N_NODES).astype(np.int64).ravel()
        indptr = (np.arange(N_NODES + 1, dtype=np.int64) * (2 * RING_K))
        GraphBlockStore.build(gpath, indptr, indices, block_size=G_BLOCK)
    if not os.path.exists(fpath + ".meta.json"):
        rng = np.random.default_rng(7)
        feats = rng.normal(0, 1, (N_NODES, F_DIM)).astype(np.float32)
        FeatureBlockStore.build(fpath, feats, block_size=F_BLOCK)
    return gpath, fpath


def _engine(gpath: str, fpath: str, **over) -> AgnesEngine:
    g = GraphBlockStore.open(gpath, NVMeModel())
    f = FeatureBlockStore.open(fpath, NVMeModel())
    kw = dict(block_size=G_BLOCK, minibatch_size=MB,
              hyperbatch_size=N_MB, fanouts=(RING_K,),
              graph_buffer_bytes=64 << 10, feature_buffer_bytes=128 << 10,
              feature_cache_rows=1, async_io=False, io_queue_depth=4,
              max_coalesce_bytes=64 << 10, placement="stripe")
    kw.update(over)
    return AgnesEngine(g, f, AgnesConfig(**kw),
                       topology=StorageTopology.uniform(N_ARRAYS))


def _tier(gpath, fpath, policy="priority", **over):
    eng = _engine(gpath, fpath, **over)
    tier = ServingTier(eng, policy=policy)
    tier.open_tenant("inference", fanouts=(RING_K,))
    return tier, eng


def _train_targets(hb: int) -> list[np.ndarray]:
    lo = (hb * N_MB * MB) % N_NODES
    return [(lo + np.arange(j * MB, (j + 1) * MB)) % N_NODES
            for j in range(N_MB)]


def _infer_nodes(i: int) -> np.ndarray:
    """One user's ego-net seed, marching around the ring."""
    return np.array([(i * 97) % N_NODES], dtype=np.int64)


def _tenant_bytes(tier: ServingTier, name: str) -> int:
    e = tier.engine_of(name)
    return (e.graph_store.stats.bytes_read
            + e.feature_store.stats.bytes_read)


def _tenant_io_s(tier: ServingTier, name: str) -> float:
    e = tier.engine_of(name)
    return (e.graph_store.stats.modeled_io_time
            + e.feature_store.stats.modeled_io_time)


def _drive(tier, n_hb, n_req, errs):
    """Run training + inference tenants concurrently through ``tier``."""

    def train():
        try:
            for hb in range(n_hb):
                tier.prepare("training", _train_targets(hb), epoch=0)
        except BaseException as e:
            errs.append(e)

    def infer():
        try:
            for i in range(n_req):
                tier.prepare("inference", [_infer_nodes(i)], epoch=1000 + i)
        except BaseException as e:
            errs.append(e)

    ts = [threading.Thread(target=train), threading.Thread(target=infer)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=300)


# ---------------------------------------------------------------- phases
def _phase_latency_duel(gpath, fpath) -> dict:
    n_hb = quick_val(16, 6)
    n_req = quick_val(96, 48)
    errs: list[BaseException] = []

    # idle system: the same inference request sequence, nothing else
    tier_idle, e_idle = _tier(gpath, fpath)
    for i in range(n_req):
        tier_idle.prepare("inference", [_infer_nodes(i)], epoch=1000 + i)
    idle = tier_idle.latency_summary("inference")
    solo_infer_bytes = _tenant_bytes(tier_idle, "inference")

    # solo training: the bulk job with the topology to itself
    tier_solo, e_solo = _tier(gpath, fpath)
    for hb in range(n_hb):
        tier_solo.prepare("training", _train_targets(hb), epoch=0)
    solo_train_bytes = _tenant_bytes(tier_solo, "training")
    solo_train_io = _tenant_io_s(tier_solo, "training")

    # the duel: both tenants concurrently, QoS admission on
    tier_duel, e_duel = _tier(gpath, fpath)
    _drive(tier_duel, n_hb, n_req, errs)
    assert not errs, errs
    duel = tier_duel.latency_summary("inference")
    duel_train_io = _tenant_io_s(tier_duel, "training")
    stall = tier_duel.controller.summary()["tenants"]["training"]["stall_s"]

    # byte parity: admission changed nothing about *what* was read
    assert _tenant_bytes(tier_duel, "training") == solo_train_bytes, \
        "training tenant byte parity broken under concurrency"
    assert _tenant_bytes(tier_duel, "inference") == solo_infer_bytes, \
        "inference tenant byte parity broken under concurrency"

    headroom = 3.0 * idle["p99_s"] / max(duel["p99_s"], 1e-12)
    assert headroom >= MIN_P99_HEADROOM, \
        (f"inference p99 regression: {duel['p99_s']*1e3:.3f}ms under load "
         f"vs {idle['p99_s']*1e3:.3f}ms idle (> 3x)")
    frac = solo_train_io / max(duel_train_io + stall, 1e-12)
    assert frac >= MIN_TRAIN_THROUGHPUT, \
        (f"training throughput regression: {frac:.3f} < "
         f"{MIN_TRAIN_THROUGHPUT} of solo with admission stalls charged")

    # contrast: an uncoordinated (fifo) duel — inference queues behind
    # the whole bulk backlog.  Reported, not floor-gated: the *measured*
    # backlog at each arrival depends on thread interleaving.
    tier_fifo, e_fifo = _tier(gpath, fpath, policy="fifo")
    _drive(tier_fifo, n_hb, n_req, errs)
    assert not errs, errs
    fifo = tier_fifo.latency_summary("inference")

    emit("serving/inference_p99_headroom", headroom,
         f"duel p99 {duel['p99_s']*1e6:.0f}us vs idle "
         f"{idle['p99_s']*1e6:.0f}us (fifo contrast "
         f"{fifo['p99_s']*1e6:.0f}us)")
    emit("serving/training_throughput_frac", frac,
         f"duel io {duel_train_io*1e3:.2f}ms + stall {stall*1e3:.2f}ms "
         f"vs solo {solo_train_io*1e3:.2f}ms")
    out = {
        "inference": {"idle": idle, "duel": duel, "fifo": fifo,
                      "p99_headroom": round(headroom, 4),
                      "bytes": solo_infer_bytes, "byte_parity": True},
        "training": {"solo_io_s": round(solo_train_io, 6),
                     "duel_io_s": round(duel_train_io, 6),
                     "stall_s": round(stall, 6),
                     "throughput_frac": round(frac, 4),
                     "bytes": solo_train_bytes, "byte_parity": True},
        "rooflines": tier_duel.summary(),
    }
    for tier, eng in ((tier_idle, e_idle), (tier_solo, e_solo),
                      (tier_duel, e_duel), (tier_fifo, e_fifo)):
        tier.close()
        eng.close()
    return out


def _phase_migration_drill(gpath, fpath) -> dict:
    """Mid-epoch migration: refuses without slack, runs in slack, moves
    hot blocks, and rebuilds the oracle schedule from the remaining
    trace — post-refresh prepares byte-identical to an untouched twin."""
    n_steps = quick_val(12, 8)
    consumed = n_steps // 2
    cfg = dict(fanouts=(), online_placement=True,
               migrate_budget_bytes=8 << 20, cache_policy="oracle",
               feature_cache_rows=64)
    eng = _engine(gpath, fpath, **cfg)
    tier = ServingTier(eng)
    # skewed plan: a hot tile hammered every step plus a cold walker —
    # measured hotness concentrates, so re-placement has real moves
    hot = np.arange(256)
    plan = [[hot, np.arange(1024 + i * MB, 1024 + (i + 1) * MB) % N_NODES]
            for i in range(n_steps)]
    eng.install_cache_oracle(trace_from_plan(plan))
    n_total = eng.feature_cache.oracle.n_steps

    # no slack -> the migration tenant must refuse to run
    tier.controller.note_submit("training", {0: (4, 8192)})
    blocked = tier.maybe_migrate()
    assert blocked is None and tier.migrations_blocked == 1, \
        "migration ran against a tenant's queued backlog"
    tier.controller.cancel_pending("training")

    for i in range(consumed):
        tier.prepare("training", plan[i], epoch=0)
    rep = tier.maybe_migrate()
    assert rep is not None and tier.migrations_run == 1, \
        "migration refused to run in queue slack"
    moved = sum(r["n_moved"] for k, r in rep.items()
                if isinstance(r, dict) and "n_moved" in r)
    assert moved > 0, "skewed traffic produced no mid-epoch moves"
    remaining = n_total - consumed
    fresh = eng.feature_cache.oracle
    assert fresh.n_steps == remaining, \
        "oracle schedule not rebuilt from the remaining trace"

    twin = _engine(gpath, fpath, fanouts=())   # untouched placement, no oracle
    for i in range(consumed, n_steps):
        a = tier.prepare("training", plan[i], epoch=0).prepared
        b = twin.prepare(plan[i], epoch=0)
        for x, y in zip(a, b):
            assert np.array_equal(x.features, y.features), \
                "mid-epoch migration changed served bytes"
    emit("serving/migration_drill", moved,
         f"{moved} blocks moved mid-epoch in queue slack, oracle "
         f"rebuilt for {remaining} remaining steps "
         f"(blocked {tier.migrations_blocked}x without slack)")
    out = {"moved_blocks": moved, "blocked_without_slack":
           tier.migrations_blocked, "oracle_steps_total": n_total,
           "oracle_steps_remaining": remaining, "post_parity": True,
           "reports": rep}
    twin.close()
    tier.close()
    eng.close()
    return out


def _phase_inference_server(gpath, fpath) -> dict:
    """The full embed path: ego-net prepare + jitted forward."""
    from repro.gnn import GNNTrainer

    eng = _engine(gpath, fpath)
    tier = ServingTier(eng)
    tr = GNNTrainer(arch="gcn", in_dim=F_DIM, hidden=16, n_classes=8,
                    n_layers=1, seed=0, backend="jnp")
    tr.labels = np.zeros(N_NODES, dtype=np.int32)
    from repro.core import InferenceServer
    srv = InferenceServer(tier, tr)
    n_req = quick_val(12, 6)
    for i in range(n_req):
        out = srv.embed(_infer_nodes(i), epoch=i)
        assert out.shape == (1, 8)
    again = srv.embed(_infer_nodes(0), epoch=0)
    first = srv.embed(_infer_nodes(0), epoch=0)
    assert np.allclose(again, first), "fixed-epoch embed not deterministic"
    lat = srv.latency_summary()
    emit("serving/embed_requests", lat["n"],
         f"p50 {lat['p50_s']*1e6:.0f}us p99 {lat['p99_s']*1e6:.0f}us "
         f"modeled prepare latency per embed")
    tier.close()
    eng.close()
    return {"requests": lat["n"], "latency": lat}


def run() -> dict:
    gpath, fpath = _build_workload()
    duel = _phase_latency_duel(gpath, fpath)
    migration = _phase_migration_drill(gpath, fpath)
    embed = _phase_inference_server(gpath, fpath)
    return {
        "workload": {"n_nodes": N_NODES, "graph_block": G_BLOCK,
                     "feature_block": F_BLOCK, "dim": F_DIM,
                     "n_arrays": N_ARRAYS},
        "duel": duel,
        "migration": migration,
        "embed": embed,
    }


if __name__ == "__main__":
    print(run())
