"""Storage layer: block packing, object index table, round trips."""
import numpy as np
import pytest

from repro.core import (DEFAULT_BLOCK_SIZE, FeatureBlockStore,
                        GraphBlockStore, NVMeModel)
from repro.data.synth import powerlaw_graph, rmat_graph


def _roundtrip_all(store, indptr, indices):
    """Read every node's full adjacency back through block I/O."""
    n = len(indptr) - 1
    got = {v: [] for v in range(n)}
    for b in range(store.n_blocks):
        blk = store.read_block(b)
        for e in range(len(blk.node_ids)):
            got[int(blk.node_ids[e])].append(blk.adjacency(e))
    for v in range(n):
        ref = np.sort(indices[indptr[v]:indptr[v + 1]])
        mine = np.sort(np.concatenate(got[v]) if got[v] else
                       np.zeros(0, np.int64))
        assert np.array_equal(ref, mine), f"node {v}"


def test_graph_store_roundtrip(tmp_path):
    indptr, indices = rmat_graph(500, 4000, seed=1)
    store = GraphBlockStore.build(str(tmp_path / "g.blk"), indptr, indices,
                                  block_size=4096)
    _roundtrip_all(store, indptr, indices)


def test_graph_store_split_objects(tmp_path):
    """A hub node whose adjacency exceeds one block must split cleanly."""
    n = 64
    deg = np.full(n, 4)
    deg[0] = 3000  # >> one 4K block of int32 words
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(deg, out=indptr[1:])
    rng = np.random.default_rng(0)
    indices = rng.integers(0, n, indptr[-1])
    store = GraphBlockStore.build(str(tmp_path / "g.blk"), indptr, indices,
                                  block_size=4096)
    blocks = store.blocks_for_nodes(np.array([0]))
    assert len(blocks) >= 3, "hub must span multiple blocks"
    _roundtrip_all(store, indptr, indices)


def test_blocks_for_nodes_matches_scan(tmp_path):
    indptr, indices = powerlaw_graph(300, 10, seed=2)
    store = GraphBlockStore.build(str(tmp_path / "g.blk"), indptr, indices,
                                  block_size=2048)
    # ground truth membership by scanning all blocks
    member = {v: set() for v in range(300)}
    for b in range(store.n_blocks):
        blk = store.read_block(b)
        for v in blk.node_ids:
            member[int(v)].add(b)
    for v in [0, 1, 5, 99, 299]:
        got = set(store.blocks_for_nodes(np.array([v])).tolist())
        assert got == member[v], f"node {v}: {got} != {member[v]}"


def test_feature_store_roundtrip(tmp_path):
    feats = np.random.default_rng(0).normal(size=(100, 16)).astype(np.float32)
    store = FeatureBlockStore.build(str(tmp_path / "f.blk"), feats,
                                    block_size=1024)
    for b in range(store.n_blocks):
        rows = store.read_block(b)
        lo = b * store.rows_per_block
        hi = min(lo + store.rows_per_block, 100)
        assert np.allclose(rows[:hi - lo], feats[lo:hi])


def test_feature_node_granular_accounting(tmp_path):
    feats = np.zeros((50, 8), dtype=np.float32)
    store = FeatureBlockStore.build(str(tmp_path / "f.blk"), feats,
                                    block_size=1024)
    nodes = np.array([1, 7, 33])
    store.read_rows_node_granular(nodes)
    assert store.stats.n_reads == 3
    assert store.stats.bytes_read == 3 * 4096  # 4K min unit per row


def test_device_model_regimes():
    dev = NVMeModel()
    # many small random reads are IOPS-bound
    small = dev.batch_time(4096 * 10000, n_random=10000)
    # one big sequential read is bandwidth-bound
    big = dev.batch_time(4096 * 10000, n_random=1)
    assert small > big
    assert small >= 10000 * dev.latency / dev.queue_depth * 0.99
    # RAID0 scales bandwidth
    dev4 = NVMeModel(n_ssd=4)
    assert dev4.request_time(1 << 20) < dev.request_time(1 << 20)
