"""GNN models learn on AGNES-prepared data; baselines produce same MFGs."""
import numpy as np
import pytest

from repro.core import (AgnesConfig, AgnesEngine, BaselineConfig, GinexLike,
                        GNNDriveLike, MariusLike, OutreLike)
from repro.gnn import GNNTrainer


@pytest.fixture(scope="module")
def engine(tiny_ds):
    g, f = tiny_ds.reopen_stores()
    cfg = AgnesConfig(block_size=16384, minibatch_size=64, hyperbatch_size=4,
                      fanouts=(4, 4), graph_buffer_bytes=1 << 20,
                      feature_buffer_bytes=1 << 20, async_io=False)
    return AgnesEngine(g, f, cfg)


@pytest.mark.parametrize("arch", ["gcn", "sage", "gat"])
def test_gnn_learns(engine, tiny_ds, arch):
    tr = GNNTrainer(arch=arch, in_dim=32, hidden=32, n_classes=16,
                    n_layers=2)
    tr.labels = tiny_ds.labels
    losses = []
    for ep in range(4):
        for prepared in engine.iter_epoch(np.arange(256), epoch=ep):
            for p in prepared:
                losses.append(tr.train_minibatch(p))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-4:]) < np.mean(losses[:4])


def test_baselines_same_mfgs_as_agnes(tiny_ds, rng):
    """Ginex/GNNDrive-like sample identically (shared deterministic hash)."""
    targets = [rng.choice(tiny_ds.n_nodes, 50, replace=False)
               for _ in range(3)]
    g, f = tiny_ds.reopen_stores()
    agnes = AgnesEngine(g, f, AgnesConfig(
        block_size=16384, fanouts=(4, 4), async_io=False,
        graph_buffer_bytes=1 << 20, feature_buffer_bytes=1 << 20))
    bcfg = BaselineConfig(fanouts=(4, 4), feature_cache_rows=500,
                          page_buffer_bytes=1 << 20)
    fm = np.memmap(tiny_ds.feature_store.path, dtype=np.float32,
                   mode="r").reshape(-1, tiny_ds.dim)
    pa = agnes.prepare(targets, epoch=0)
    for cls in (GinexLike, GNNDriveLike, OutreLike):
        _, fstore = tiny_ds.reopen_stores()
        eng = cls(tiny_ds.csr_storage(1 << 20), fstore, bcfg)
        pb = eng.prepare(targets, epoch=0)
        for a, b in zip(pa, pb):
            for x, y in zip(a.mfg.nodes, b.mfg.nodes):
                assert np.array_equal(x, y), cls.name
            assert np.allclose(a.features, b.features), cls.name
        assert eng.last_report is not None
        # node-granular engines do (far) more I/Os than block-wise AGNES
        assert eng.features.stats.n_reads >= \
            agnes.feature_store.stats.n_reads, cls.name


def test_marius_like_restricted_sampling(tiny_ds, rng):
    """Marius-like drops out-of-buffer neighbors (its documented bias)."""
    targets = [rng.choice(tiny_ds.n_nodes, 80, replace=False)]
    _, fstore = tiny_ds.reopen_stores()
    eng = MariusLike(tiny_ds.csr_storage(1 << 20), fstore,
                     BaselineConfig(fanouts=(4,), n_partitions=8,
                                    buffer_partitions=2))
    out = eng.prepare(targets, epoch=0)
    assert len(out) >= 1
    n = tiny_ds.n_nodes
    psize = -(-n // 8)
    for p in out:
        # all sampled nodes of each minibatch stay within 2 partitions
        parts = {int(v // psize) for v in p.mfg.all_sampled.tolist()}
        assert len(parts) <= 2 * 2  # buffered groups may differ per mb
