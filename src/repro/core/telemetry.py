"""Unified telemetry: trace spans, metrics registry, Fig.2 breakdown.

The prepare stack accumulated one ad-hoc summary dict per subsystem
(``IOStats.summary()``, ``io_stats()["hotness"/"migration"/"faults"]``,
serving-tier rooflines) and zero timeline visibility.  This module is
the one queryable, timestamped place the ROADMAP's model-based
controller will consume:

* :class:`TraceRecorder` — a lock-protected preallocated ring buffer of
  structured spans ("X") and instant events ("i").  Recording one event
  is a tuple build + one locked slot write; the buffer never grows, and
  the monotonic emit counter makes the dropped-event count *exact*
  (``n_dropped == n_emitted - capacity`` once wrapped).  Export with
  :meth:`TraceRecorder.export_chrome` and load the file in Perfetto /
  ``chrome://tracing``.
* :class:`MetricsRegistry` — named counters / gauges / histograms
  behind one namespace with atomic :meth:`~MetricsRegistry.snapshot`,
  counter-aware :meth:`~MetricsRegistry.delta`, and a Prometheus-style
  text exposition (:meth:`~MetricsRegistry.render_prometheus`).
  :meth:`~MetricsRegistry.set_gauges` folds the existing nested summary
  dicts into the same namespace.
* :class:`Telemetry` — the per-engine bundle: an always-on registry
  plus an optional recorder.  **Nullability contract**: ``trace`` is
  ``None`` when tracing is off, so every instrumented hot path costs
  exactly one ``is not None`` branch when disabled
  (``benchmarks/bench_obs.py`` floor-guards the enabled overhead too).
* :func:`fig2_breakdown` — reconstructs the paper's Fig. 2
  prepare/train/transfer decomposition from a recorded trace; the
  category scheme below makes its sums agree with
  :class:`~repro.gnn.pipeline.OverlapReport` wall times.

Category scheme (one cat per Fig.2 bar, sub-categories never double
count into a parent):

==================  ====================================================
category            emitted by
==================  ====================================================
``prepare``         ``AgnesEngine.prepare`` — one span per hyperbatch
``prepare.stage``   session stages (plan/consume/assemble), nested
``io.submit``       ``CoalescedReader.submit`` (coalesce + charge)
``io.run``          one span per coalesced run read, per-array track
``io.fault``        retry/hedge/stall/degraded/error instants
``train``           pipeline consumer — one span per hyperbatch
``train.step``      the jitted train step, nested inside ``train``
``transfer``        ``to_device`` + MFG padding, nested inside ``train``
``admission``       serving-tier admission waits + forced grants
``serving``         one span per served tenant prepare
``migration``       migration / evacuation windows
``cache``           admit / evict / writeback instants
``pipeline``        epoch-level summary span
==================  ====================================================
"""
from __future__ import annotations

import bisect
import json
import threading
import time
from contextlib import contextmanager, nullcontext

__all__ = [
    "TraceRecorder", "MetricsRegistry", "Telemetry", "fig2_breakdown",
    "validate_chrome_trace", "format_metrics", "maybe_span",
]


# --------------------------------------------------------------------- trace
class TraceRecorder:
    """Low-overhead ring buffer of trace events.

    Events are stored as tuples ``(ph, name, cat, track, ts_s, dur_s,
    args)`` with timestamps relative to the recorder's construction
    (``time.perf_counter`` clock).  ``track`` is a logical lane —
    ``"array:3"``, ``"prepare:training"``, ``"cache"`` — mapped to a
    Chrome thread id at export time so Perfetto renders one row per
    track.
    """

    def __init__(self, capacity: int = 65536):
        self.capacity = max(int(capacity), 1)
        self._buf: list = [None] * self.capacity
        self._n = 0                       # total emitted, never wraps
        self._lock = threading.Lock()
        self._t0 = time.perf_counter()

    # ------------------------------------------------------------ record
    def now(self) -> float:
        """The recorder's clock (absolute ``perf_counter`` seconds);
        pass the value to :meth:`complete` as ``t0``/``t1``."""
        return time.perf_counter()

    def _emit(self, ev: tuple) -> None:
        with self._lock:
            self._buf[self._n % self.capacity] = ev
            self._n += 1

    def complete(self, name: str, cat: str, track: str, t0: float,
                 t1: float | None = None, args: dict | None = None) -> None:
        """One "X" (complete) span from ``t0`` to ``t1`` (now if None),
        both absolute ``perf_counter`` readings — pass the *same*
        timestamps an existing wall-time accumulator measured and the
        trace agrees with it exactly."""
        if t1 is None:
            t1 = time.perf_counter()
        self._emit(("X", name, cat, track, t0 - self._t0,
                    max(t1 - t0, 0.0), args))

    def instant(self, name: str, cat: str, track: str,
                args: dict | None = None) -> None:
        """One "i" (instant) event at the current time."""
        self._emit(("i", name, cat, track,
                    time.perf_counter() - self._t0, 0.0, args))

    @contextmanager
    def span(self, name: str, cat: str, track: str,
             args: dict | None = None):
        """Context-managed :meth:`complete` around the block."""
        t0 = time.perf_counter()
        try:
            yield self
        finally:
            self.complete(name, cat, track, t0, args=args)

    # ------------------------------------------------------------ inspect
    @property
    def n_emitted(self) -> int:
        with self._lock:
            return self._n

    @property
    def n_dropped(self) -> int:
        """Exactly how many events the ring overwrote (oldest first)."""
        with self._lock:
            return max(self._n - self.capacity, 0)

    @property
    def n_retained(self) -> int:
        with self._lock:
            return min(self._n, self.capacity)

    def events(self) -> list:
        """Retained events, oldest first (a consistent locked copy)."""
        with self._lock:
            if self._n <= self.capacity:
                return self._buf[:self._n]
            cut = self._n % self.capacity
            return self._buf[cut:] + self._buf[:cut]

    def clear(self) -> None:
        with self._lock:
            self._buf = [None] * self.capacity
            self._n = 0
            self._t0 = time.perf_counter()

    # ------------------------------------------------------------ export
    def to_chrome(self) -> dict:
        """Chrome trace-event JSON object (the ``traceEvents`` format
        Perfetto and ``chrome://tracing`` load)."""
        tids: dict[str, int] = {}
        body = []
        for ph, name, cat, track, ts, dur, args in self.events():
            tid = tids.get(track)
            if tid is None:
                tid = tids[track] = len(tids) + 1
            ev = {"name": name, "cat": cat, "ph": ph, "pid": 1, "tid": tid,
                  "ts": round(ts * 1e6, 3)}
            if ph == "X":
                ev["dur"] = round(dur * 1e6, 3)
            elif ph == "i":
                ev["s"] = "t"      # thread-scoped instant
            if args:
                ev["args"] = args
            body.append(ev)
        meta = [{"name": "process_name", "ph": "M", "pid": 1, "tid": 0,
                 "args": {"name": "agnes"}}]
        for track, tid in tids.items():
            meta.append({"name": "thread_name", "ph": "M", "pid": 1,
                         "tid": tid, "args": {"name": track}})
            meta.append({"name": "thread_sort_index", "ph": "M", "pid": 1,
                         "tid": tid, "args": {"sort_index": tid}})
        return {"traceEvents": meta + body, "displayTimeUnit": "ms",
                "otherData": {"clock": "perf_counter",
                              "dropped_events": self.n_dropped}}

    def export_chrome(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path


def maybe_span(recorder: TraceRecorder | None, name: str, cat: str,
               track: str, args: dict | None = None):
    """``recorder.span(...)`` or a no-op context when tracing is off."""
    if recorder is None:
        return nullcontext()
    return recorder.span(name, cat, track, args=args)


def validate_chrome_trace(payload: dict) -> list[str]:
    """Schema-check an exported Chrome trace object (or loaded JSON).

    Returns a list of violation strings — empty means valid.  Checks
    the shape Perfetto's trace-event importer requires: a
    ``traceEvents`` list of dicts with ``name``/``ph``/``pid``/``tid``,
    numeric non-negative ``ts``, spans ("X") with a numeric
    non-negative ``dur``, instants ("i") with a valid scope ``s`` in
    ``t``/``p``/``g`` and *no* ``dur`` field, dict-typed ``args`` when
    present, and a ``thread_name`` metadata event for every tid that
    carries events.  ``displayTimeUnit``, when present, must be one of
    the two values the importer accepts ("ms"/"ns").
    """
    errs: list[str] = []
    evs = payload.get("traceEvents")
    if not isinstance(evs, list):
        return ["traceEvents missing or not a list"]
    unit = payload.get("displayTimeUnit")
    if unit is not None and unit not in ("ms", "ns"):
        errs.append(f"displayTimeUnit must be 'ms' or 'ns', got {unit!r}")
    named_tids = set()
    used_tids = set()
    for i, ev in enumerate(evs):
        if not isinstance(ev, dict):
            errs.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "i", "M", "B", "E"):
            errs.append(f"event {i}: bad ph {ph!r}")
            continue
        if not isinstance(ev.get("name"), str):
            errs.append(f"event {i}: name missing")
        if not isinstance(ev.get("pid"), int) \
                or not isinstance(ev.get("tid"), int):
            errs.append(f"event {i}: pid/tid must be ints")
            continue
        if ph == "M":
            if ev["name"] == "thread_name":
                named_tids.add(ev["tid"])
            continue
        used_tids.add(ev["tid"])
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errs.append(f"event {i}: bad ts {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errs.append(f"event {i}: bad dur {dur!r}")
        if ph == "i":
            if ev.get("s") not in ("t", "p", "g"):
                errs.append(f"event {i}: instant scope must be "
                            f"t/p/g, got {ev.get('s')!r}")
            if "dur" in ev:
                errs.append(f"event {i}: instant must not carry dur")
        if "args" in ev and not isinstance(ev["args"], dict):
            errs.append(f"event {i}: args must be an object")
    for tid in sorted(used_tids - named_tids):
        errs.append(f"tid {tid} has events but no thread_name metadata")
    return errs


# ------------------------------------------------------------------ metrics
_DEFAULT_BUCKETS = tuple(1e-6 * (4.0 ** i) for i in range(13))  # 1us..~67s


class _Metric:
    __slots__ = ("name", "help", "_lock")
    kind = "none"

    def __init__(self, name: str, help: str, lock: threading.Lock):
        self.name = name
        self.help = help
        self._lock = lock


class CounterMetric(_Metric):
    __slots__ = ("value",)
    kind = "counter"

    def __init__(self, name, help, lock):
        super().__init__(name, help, lock)
        self.value = 0

    def inc(self, v: float = 1) -> None:
        with self._lock:
            self.value += v


class GaugeMetric(_Metric):
    __slots__ = ("value",)
    kind = "gauge"

    def __init__(self, name, help, lock):
        super().__init__(name, help, lock)
        self.value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self.value = v


class HistogramMetric(_Metric):
    __slots__ = ("buckets", "counts", "sum", "count")
    kind = "histogram"

    def __init__(self, name, help, lock, buckets=None):
        super().__init__(name, help, lock)
        self.buckets = tuple(sorted(buckets or _DEFAULT_BUCKETS))
        self.counts = [0] * (len(self.buckets) + 1)  # last = overflow
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        i = bisect.bisect_left(self.buckets, v)
        with self._lock:
            self.counts[i] += 1
            self.sum += v
            self.count += 1


class MetricsRegistry:
    """Named counters / gauges / histograms under one namespace.

    All mutation and the snapshot share one lock, so
    :meth:`snapshot` is atomic: it can never observe a half-applied
    increment, and two snapshots bracket a window whose :meth:`delta`
    is exact.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, _Metric] = {}

    def _get(self, name: str, cls, help: str, **kw) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help, self._lock, **kw)
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} is a {m.kind}, "
                                f"not a {cls.kind}")
            return m

    def counter(self, name: str, help: str = "") -> CounterMetric:
        return self._get(name, CounterMetric, help)

    def gauge(self, name: str, help: str = "") -> GaugeMetric:
        return self._get(name, GaugeMetric, help)

    def histogram(self, name: str, help: str = "",
                  buckets=None) -> HistogramMetric:
        return self._get(name, HistogramMetric, help, buckets=buckets)

    def set_gauges(self, prefix: str, mapping) -> None:
        """Fold a nested summary dict into ``{prefix}.{path}`` gauges.

        Numeric leaves become gauges; dicts recurse; lists recurse with
        index keys; non-numeric leaves are skipped.  This is the bridge
        from the pre-telemetry summary dicts (``engine.io_stats()``,
        serving rooflines) into the unified namespace.
        """
        if isinstance(mapping, dict):
            items = mapping.items()
        elif isinstance(mapping, (list, tuple)):
            items = enumerate(mapping)
        else:
            return
        for k, v in items:
            name = f"{prefix}.{k}"
            if isinstance(v, bool):
                self.gauge(name).set(int(v))
            elif isinstance(v, (int, float)):
                self.gauge(name).set(v)
            elif isinstance(v, (dict, list, tuple)):
                self.set_gauges(name, v)

    # ------------------------------------------------------------ read
    def snapshot(self) -> dict:
        """Atomic point-in-time copy: ``{name: value}`` for counters
        and gauges, ``{name: {"count", "sum", "buckets"}}`` for
        histograms."""
        with self._lock:
            out = {}
            for name, m in self._metrics.items():
                if m.kind == "histogram":
                    out[name] = {"count": m.count, "sum": m.sum,
                                 "buckets": list(m.counts)}
                else:
                    out[name] = m.value
            return out

    def delta(self, prev: dict) -> dict:
        """Window between ``prev`` (an earlier :meth:`snapshot`) and
        now: counters and histograms are differenced, gauges pass
        through at their current value."""
        with self._lock:
            kinds = {n: m.kind for n, m in self._metrics.items()}
        cur = self.snapshot()
        out = {}
        for name, v in cur.items():
            kind = kinds.get(name, "gauge")
            p = prev.get(name)
            if kind == "counter" and p is not None:
                out[name] = v - p
            elif kind == "histogram" and isinstance(p, dict):
                out[name] = {
                    "count": v["count"] - p["count"],
                    "sum": v["sum"] - p["sum"],
                    "buckets": [a - b for a, b in zip(v["buckets"],
                                                      p["buckets"])]}
            else:
                out[name] = v
        return out

    def render_prometheus(self) -> str:
        """Prometheus text exposition (metric names sanitized to the
        ``[a-zA-Z0-9_]`` charset, histograms with cumulative
        ``_bucket{le=...}`` series).

        Every metric family gets a ``# HELP`` line (escaped per the
        exposition format, present even when the help string is empty
        so scrapers that key metadata off HELP never miss a family)
        followed by ``# TYPE``; histograms expose the full series:
        cumulative ``_bucket{le="..."}`` per bound, the mandatory
        ``le="+Inf"`` bucket, ``_sum`` and ``_count``.
        """
        lines: list[str] = []
        with self._lock:
            for name, m in sorted(self._metrics.items()):
                pname = _prom_name(name)
                help_ = m.help.replace("\\", "\\\\").replace("\n", "\\n")
                lines.append(f"# HELP {pname} {help_}".rstrip())
                lines.append(f"# TYPE {pname} {m.kind}")
                if m.kind == "histogram":
                    cum = 0
                    for ub, c in zip(m.buckets, m.counts):
                        cum += c
                        lines.append(f'{pname}_bucket{{le="{ub:g}"}} {cum}')
                    cum += m.counts[-1]
                    lines.append(f'{pname}_bucket{{le="+Inf"}} {cum}')
                    lines.append(f"{pname}_sum {m.sum:g}")
                    lines.append(f"{pname}_count {m.count}")
                else:
                    v = m.value
                    lines.append(f"{pname} {v:g}" if isinstance(v, float)
                                 else f"{pname} {v}")
        return "\n".join(lines) + "\n"


def _prom_name(name: str) -> str:
    out = "".join(c if c.isalnum() or c == "_" else "_" for c in name)
    return out if not out[:1].isdigit() else "_" + out


def format_metrics(snapshot: dict, include: tuple = (),
                   skip_zero: bool = True) -> str:
    """One-line compact render of a snapshot/delta: ``k=v`` pairs.

    ``include`` filters by name prefix; zero-valued entries are dropped
    by default so per-epoch deltas read as "what happened this epoch".
    """
    parts = []
    for name in sorted(snapshot):
        if include and not any(name.startswith(p) for p in include):
            continue
        v = snapshot[name]
        if isinstance(v, dict):                       # histogram
            n = v.get("count", 0)
            if skip_zero and not n:
                continue
            mean = v.get("sum", 0.0) / max(n, 1)
            parts.append(f"{name}[n={n} mean={mean:.3g}]")
        else:
            if skip_zero and not v:
                continue
            parts.append(f"{name}={v:.4g}" if isinstance(v, float)
                         else f"{name}={v}")
    return " ".join(parts)


# ------------------------------------------------------------------- bundle
class Telemetry:
    """One engine's observability bundle.

    ``metrics`` is always live (counter increments are cheap and the
    registry is the controller's substrate); ``trace`` is a
    :class:`TraceRecorder` only when tracing is enabled — instrumented
    hot paths hold the contract ``tr = tel.trace; if tr is not None:``
    so a disabled recorder costs exactly one branch.
    """

    __slots__ = ("metrics", "trace")

    def __init__(self, trace: bool = False, capacity: int = 65536,
                 metrics: MetricsRegistry | None = None):
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.trace = TraceRecorder(capacity) if trace else None


# ---------------------------------------------------------------- breakdown
def fig2_breakdown(trace_or_events) -> dict:
    """The paper's Fig. 2 decomposition, reconstructed from a trace.

    Sums span durations per category.  ``prepare`` is carried only by
    the top-level ``AgnesEngine.prepare`` spans and ``train`` only by
    the pipeline consumer's per-hyperbatch spans — nested
    sub-categories (``prepare.stage``, ``train.step``, ``transfer``)
    are reported separately and never double count into their parents —
    so ``prepare_s`` / ``train_s`` agree with
    :class:`~repro.gnn.pipeline.OverlapReport`'s
    ``prepare_wall_s`` / ``train_wall_s`` (the bench floor-guards the
    agreement).  ``transfer_s`` is the host→device landing inside the
    train spans, the paper's third bar.
    """
    if hasattr(trace_or_events, "events"):
        evs = trace_or_events.events()
    else:
        evs = list(trace_or_events)
    by_cat: dict[str, float] = {}
    n_cat: dict[str, int] = {}
    stages: dict[str, float] = {}
    for ev in evs:
        ph, name, cat, _track, _ts, dur, _args = ev
        if ph != "X":
            n_cat[cat] = n_cat.get(cat, 0)
            continue
        by_cat[cat] = by_cat.get(cat, 0.0) + dur
        n_cat[cat] = n_cat.get(cat, 0) + 1
        if cat == "prepare.stage":
            key = name.split(":", 1)[0]
            stages[key] = stages.get(key, 0.0) + dur
    prepare = by_cat.get("prepare", 0.0)
    train = by_cat.get("train", 0.0)
    transfer = by_cat.get("transfer", 0.0)
    denom = prepare + train
    out = {
        "prepare_s": prepare,
        "train_s": train,
        "transfer_s": transfer,           # nested inside train_s
        "train_step_s": by_cat.get("train.step", 0.0),
        "prepare_fraction": prepare / denom if denom else 0.0,
        "train_fraction": train / denom if denom else 0.0,
        "stages_s": stages,
        "by_category_s": {k: round(v, 6) for k, v in sorted(by_cat.items())},
        "spans_per_category": dict(sorted(n_cat.items())),
    }
    if hasattr(trace_or_events, "n_dropped"):
        out["dropped_events"] = trace_or_events.n_dropped
    return out
