"""Mamba (S6) selective state-space mixer: chunked parallel scan + decode.

Train/prefill runs a *time-chunked* scan: within a chunk the recurrence
h_t = a_t ⊙ h_{t-1} + b_t is solved with an associative scan (log-depth,
parallel on the VPU); across chunks a small (B, d_inner, d_state) carry
flows through ``lax.scan`` — the same memory-bounding pattern as the
attention KV chunks.  Channels (d_inner) are TP-shardable: every per-
channel recurrence is independent; only the in/out projections touch the
model axis.

Decode is the O(1) recurrent step on (conv window, ssm state) caches.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .common import dense_init


def mamba_init(key, cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A (negative, per channel x state)
    a = jnp.tile(jnp.arange(1, s.d_state + 1, dtype=jnp.float32)[None, :],
                 (di, 1))
    return {
        "w_in": dense_init(ks[0], (d, 2 * di), dtype=dt),     # x and gate z
        "conv_w": dense_init(ks[1], (s.d_conv, di), scale=0.5, dtype=dt),
        "conv_b": jnp.zeros((di,), dt),
        "w_bcdt": dense_init(ks[2], (di, 2 * s.d_state + 1), dtype=dt),
        "dt_bias": jnp.log(jnp.exp(
            jnp.exp(jax.random.uniform(ks[3], (di,), jnp.float32) * 3 - 4.6))
            - 1 + 1e-9),                                      # softplus^-1
        "log_a": jnp.log(a),
        "d_skip": jnp.ones((di,), jnp.float32),
        "w_out": dense_init(ks[4], (di, d), dtype=dt),
    }


def _ssm_scan_chunk(a, b):
    """Associative combine for h_t = a_t * h_{t-1} + b_t."""
    a1, b1 = a
    a2, b2 = b
    return a1 * a2, a2 * b1 + b2


def _chunk_step(u, dt_, B_, C_, log_a, h0):
    """One time chunk. u: (B, T, di); dt: (B, T, 1|di); B_/C_: (B, T, N).

    Returns (y: (B, T, di), h_T: (B, di, N)).  The (B, T, di, N) scan
    operands are the memory hot spot (the part a Pallas SSM kernel keeps
    in VMEM tiles); they run in bf16 with an f32 carry — log-depth scan
    keeps the accumulation error at the usual chunked-linear-attention
    level.
    """
    A = -jnp.exp(log_a)                                   # (di, N)
    decay = jnp.exp(dt_[..., None] * A)                   # (B, T, di, N)
    inp = (dt_ * u)[..., None] * B_[:, :, None, :]        # (B, T, di, N)
    # prepend carry as an extra step with a=1 ... fold via first element
    decay0 = jnp.concatenate(
        [jnp.ones_like(decay[:, :1]), decay[:, 1:]], axis=1)
    inp0 = jnp.concatenate(
        [decay[:, :1] * h0[:, None].astype(decay.dtype) + inp[:, :1],
         inp[:, 1:]], axis=1)
    a_cum, h = jax.lax.associative_scan(
        _ssm_scan_chunk,
        (decay0.astype(jnp.bfloat16), inp0.astype(jnp.bfloat16)), axis=1)
    y = jnp.einsum("btdn,btn->btd", h.astype(jnp.float32), C_)
    return y, h[:, -1].astype(jnp.float32)


def mamba_apply(p: dict, x: jnp.ndarray, cfg: ModelConfig, *,
                unroll: bool = False) -> jnp.ndarray:
    """x: (B, S, D) → (B, S, D)."""
    s = cfg.ssm
    B, S, D = x.shape
    di = s.expand * D
    xz = x @ p["w_in"]
    u, z = jnp.split(xz, 2, axis=-1)                      # (B, S, di)
    # depthwise causal conv1d
    u = _causal_conv(u, p["conv_w"], p["conv_b"])
    u = jax.nn.silu(u.astype(jnp.float32)).astype(x.dtype)
    bcd = u @ p["w_bcdt"]                                  # (B, S, 2N+1)
    B_, C_, dt_raw = jnp.split(
        bcd.astype(jnp.float32), [s.d_state, 2 * s.d_state], axis=-1)
    dt_ = jax.nn.softplus(dt_raw + p["dt_bias"][None, None, -1:])  # (B,S,1)
    # u stays bf16 across the sequence; per-chunk math upcasts locally —
    # full-seq f32 (B, S, d_inner) buffers are the prefill memory killer

    chunk = min(s.chunk, S)
    while S % chunk:
        chunk //= 2
    n_chunks = S // chunk

    def body(h, idx):
        sl = lambda t: jax.lax.dynamic_slice_in_dim(t, idx * chunk, chunk, 1)  # noqa: E731
        y, h = _chunk_step(sl(u).astype(jnp.float32), sl(dt_), sl(B_),
                           sl(C_), p["log_a"], h)
        return h, y.astype(x.dtype)

    h0 = jnp.zeros((B, di, s.d_state), jnp.float32)
    if unroll:
        ys = []
        h = h0
        for i in range(n_chunks):
            h, y = body(h, i)
            ys.append(y)
        y = jnp.concatenate(ys, axis=1)
    else:
        # remat per time chunk: keep only the (B, di, N) carries
        _, y = jax.lax.scan(jax.checkpoint(body), h0, jnp.arange(n_chunks))
        y = jnp.moveaxis(y, 0, 1).reshape(B, S, di)
    # fused elementwise epilogue (f32 math, bf16 storage)
    y = (y.astype(jnp.float32) + u.astype(jnp.float32) * p["d_skip"]) \
        * jax.nn.silu(z.astype(jnp.float32))
    return (y.astype(x.dtype)) @ p["w_out"]


def _causal_conv(u: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv. u: (B, S, di); w: (K, di)."""
    K = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(u, dtype=jnp.float32)
    for k in range(K):
        out = out + pad[:, k:k + u.shape[1]].astype(jnp.float32) \
            * w[k].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(u.dtype)


# ----------------------------------------------------------------- decode
@dataclasses.dataclass
class MambaCache:
    conv: jnp.ndarray   # (B, K-1, di) last inputs
    h: jnp.ndarray      # (B, di, N) ssm state


jax.tree_util.register_dataclass(MambaCache, data_fields=["conv", "h"],
                                 meta_fields=[])


def mamba_cache_init(cfg: ModelConfig, batch: int, dtype) -> MambaCache:
    s = cfg.ssm
    di = s.expand * cfg.d_model
    return MambaCache(conv=jnp.zeros((batch, s.d_conv - 1, di), dtype),
                      h=jnp.zeros((batch, di, s.d_state), jnp.float32))


def mamba_decode(p: dict, x: jnp.ndarray, cache: MambaCache,
                 cfg: ModelConfig) -> tuple[jnp.ndarray, MambaCache]:
    """One-token recurrent step. x: (B, D)."""
    s = cfg.ssm
    B, D = x.shape
    di = s.expand * D
    xz = x @ p["w_in"]
    u, z = jnp.split(xz, 2, axis=-1)                      # (B, di)
    window = jnp.concatenate([cache.conv, u[:, None]], axis=1)  # (B, K, di)
    conv = jnp.einsum("bkd,kd->bd", window.astype(jnp.float32),
                      p["conv_w"].astype(jnp.float32)) \
        + p["conv_b"].astype(jnp.float32)
    u = jax.nn.silu(conv).astype(x.dtype)
    bcd = u @ p["w_bcdt"]
    B_, C_, dt_raw = jnp.split(
        bcd.astype(jnp.float32), [s.d_state, 2 * s.d_state], axis=-1)
    dt_ = jax.nn.softplus(dt_raw + p["dt_bias"][None, -1:])
    dt_ = jnp.broadcast_to(dt_, (B, di))
    A = -jnp.exp(p["log_a"])
    decay = jnp.exp(dt_[..., None] * A)                   # (B, di, N)
    h = cache.h * decay + (dt_ * u.astype(jnp.float32))[..., None] \
        * B_[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, C_)
    y = y + u.astype(jnp.float32) * p["d_skip"]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = y.astype(x.dtype) @ p["w_out"]
    return out, MambaCache(conv=window[:, 1:], h=h)
