from .models import GNN_ARCHS, init_gnn, gnn_apply, pad_mfg, PaddedMFG
from .training import GNNTrainer, gnn_loss

__all__ = ["GNN_ARCHS", "init_gnn", "gnn_apply", "pad_mfg", "PaddedMFG",
           "GNNTrainer", "gnn_loss"]
