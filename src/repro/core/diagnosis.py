"""Storage doctor: roofline attribution, anomaly watchdog, diagnosis.

PR 9's telemetry can *record* (spans, counters, Fig.2 bars) but cannot
*explain*: when prepare time is exposed, nothing says whether the cause
is an iops-bound array, admission starvation, a hedge storm, a
cache-hit collapse, or a degraded array.  This module closes that gap —
it consumes exactly what the telemetry layer already produces
(:class:`~repro.core.telemetry.TraceRecorder` event tuples and flat
:class:`~repro.core.telemetry.MetricsRegistry` snapshots, including the
``agnes.*`` gauges ``AgnesEngine.metrics_snapshot`` folds in) and emits
a structured :class:`DoctorReport`:

* **per-array roofline attribution** — each array's achieved bytes /
  requests / busy time against its :class:`~repro.core.device_model.
  NVMeModel` ceiling, split into the model's two arms
  (``bw_term = bytes / array_bandwidth`` vs ``iops_term = n_random *
  latency / qd``) and classified as one of :data:`ARRAY_STATES`
  (bw-bound / iops-bound / queue-starved / admission-throttled /
  fault-degraded / idle);
* **exposed-prepare decomposition** — the pipeline's
  ``exposed_prepare_fraction`` split into sampling-CPU vs graph I/O vs
  cache-miss (feature) I/O vs admission-wait vs retry/hedge-stall
  components using the existing span categories (``prepare.stage``,
  ``io.run``, ``admission``, ``io.fault``) — an *attribution*, not a
  wall-clock partition: fault stalls carry modeled seconds and async
  reads overlap the prepare wall, so components are normalized before
  being scaled onto the exposed seconds;
* **findings** — ranked, each with a severity in [0, 1], the evidence
  numbers behind it, and a suggested knob from the controller's future
  action space (:data:`SUGGESTED_KNOBS`: queue depth, coalesce bytes,
  cache capacity, admission share);
* **anomaly watchdog** — :class:`AnomalyWatchdog`, rolling windowed
  detectors over :meth:`MetricsRegistry.delta` (stall spikes,
  starvation, hedge storms, cache-hit collapse, trace-event drops) that
  emit structured ``diag.alert`` instants back into the trace.

Ground truth: ``benchmarks/bench_doctor.py`` plants each bottleneck
(dropout schedules, throttled QoS shares, undersized caches, qd=1,
tiny/huge request mixes, latency spikes) and gates that
:func:`diagnose` names the planted primary in >= 7 of 8 scenarios with
a zero-alert clean run — the floors live in ``check_regression.py``.

Entry points: :meth:`AgnesEngine.diagnose`, :meth:`ServingTier.
diagnose`, and the offline CLI ``python -m repro.doctor trace.json
--metrics metrics.json``.
"""
from __future__ import annotations

import dataclasses
from collections import deque

__all__ = [
    "ARRAY_STATES", "SUGGESTED_KNOBS", "DoctorThresholds", "Finding",
    "ArrayDiagnosis", "DoctorReport", "AnomalyWatchdog", "diagnose",
    "decompose_prepare", "events_from_chrome",
]

# the six per-array states of the roofline attribution
ARRAY_STATES = ("idle", "bw-bound", "iops-bound", "queue-starved",
                "admission-throttled", "fault-degraded")

# finding kind -> the knob a controller (or a human) would turn.  This
# is the ROADMAP controller's action space, spelled out per cause.
SUGGESTED_KNOBS = {
    "fault-degraded": "bring the array back online / let end_epoch "
                      "evacuate (online_placement, migrate_budget_bytes)",
    "admission-throttled": "raise the tenant's QoS share / burst_bytes "
                           "(AdmissionController, QoSClass.share)",
    "queue-starved": "raise io_queue_depth "
                     "(AgnesEngine.set_io_queue_depth)",
    "iops-bound": "raise max_coalesce_bytes so small requests merge "
                  "(or grow block_size)",
    "bw-bound": "add arrays / widen striping (n_arrays, placement) — "
                "the device ceiling itself is the limit",
    "cache-miss-bound": "raise cache_capacity_rows (or install the "
                        "Belady oracle: install_cache_oracle)",
    "hedge-stall": "tighten hedge_deadline_frac toward p99 / raise "
                   "io_retries; investigate the latency spikes",
    "stall-spike": "raise io_retries / check the array for transient "
                   "faults",
    "hedge-storm": "tighten hedge_deadline_frac; check for a straggling "
                   "array",
    "starvation": "raise the tenant's QoS share or lower aging_wait_s",
    "cache-collapse": "raise cache_capacity_rows / refresh the oracle "
                      "schedule (refresh_cache_oracle)",
    "trace-drops": "raise trace_buffer_events",
    "healthy": "no action",
}

# io.fault instant kinds whose modeled seconds count as fault stall
_STALL_KINDS = ("retry", "hedge", "stall")


@dataclasses.dataclass(frozen=True)
class DoctorThresholds:
    """Detector thresholds; defaults calibrated by bench_doctor's
    labeled scenario matrix (every planted bottleneck must fire its
    detector, the clean run must fire none)."""

    idle_busy_s: float = 1e-6        # below: the array never worked
    # iops-dominant arrays with qd <= this fraction of the device's
    # native depth are starved by the *submitter*, not the device
    queue_starved_qd_frac: float = 0.125
    admission_wait_frac: float = 0.2   # wait / (wait + busy)
    fault_rate: float = 0.01           # (retries+hedges+stalls)/requests
    degraded_read_frac: float = 0.02   # degraded reads / reads
    cache_hit_floor: float = 0.5
    cache_feature_share: float = 0.35  # feature io / total io
    # --- watchdog windows ---
    w_min_events: int = 4
    w_stall_rate: float = 0.02         # faults per submitted run
    w_hedge_rate: float = 0.01
    w_wait_mean_s: float = 0.02        # mean admission wait per grant
    w_hit_drop: float = 0.25           # cache hit ratio drop vs baseline
    w_history: int = 8                 # rolling baseline length


@dataclasses.dataclass
class Finding:
    """One ranked diagnosis: what, how bad, why, and which knob."""

    kind: str
    severity: float                  # 0..1, ranks the findings
    summary: str
    knob: str
    evidence: dict = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ArrayDiagnosis:
    """One array's roofline attribution for the diagnosed window."""

    array: int
    state: str                       # one of ARRAY_STATES
    online: bool
    bytes: int
    n_requests: int
    busy_s: float
    bw_term_s: float                 # bytes / array_bandwidth
    iops_term_s: float               # n_random * latency / qd
    bw_utilization: float            # achieved bw / ceiling
    iops_utilization: float          # achieved iops / ceiling at qd
    queue_depth: int
    device_queue_depth: int
    avg_request_bytes: float

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class DoctorReport:
    """Structured output of :func:`diagnose`.

    ``primary`` is the top-ranked finding's kind ("healthy" when no
    detector fired); ``alerts`` is whatever the caller's
    :class:`AnomalyWatchdog` collected for the same window (empty when
    no watchdog ran).
    """

    primary: str
    findings: list
    arrays: list
    decomposition: dict
    alerts: list = dataclasses.field(default_factory=list)

    def to_dict(self) -> dict:
        return {
            "primary": self.primary,
            "findings": [f.to_dict() for f in self.findings],
            "arrays": [a.to_dict() for a in self.arrays],
            "decomposition": self.decomposition,
            "alerts": list(self.alerts),
        }

    def render(self) -> str:
        """Human-readable findings table (the ``repro.doctor`` CLI)."""
        out = [f"storage doctor — primary bottleneck: {self.primary}"]
        if self.findings:
            rows = [("finding", "sev", "suggested knob")]
            rows += [(f.kind, f"{f.severity:.2f}", f.knob)
                     for f in self.findings]
            w0 = max(len(r[0]) for r in rows)
            w1 = max(len(r[1]) for r in rows)
            for r in rows:
                out.append(f"  {r[0]:<{w0}}  {r[1]:>{w1}}  {r[2]}")
        else:
            out.append("  no findings — storage path is healthy")
        if self.arrays:
            out.append("per-array roofline:")
            rows = [("array", "state", "busy_s", "bw_util", "iops_util",
                     "qd", "KiB/req")]
            for a in self.arrays:
                rows.append((str(a.array), a.state, f"{a.busy_s:.4f}",
                             f"{a.bw_utilization:.2f}",
                             f"{a.iops_utilization:.2f}",
                             f"{a.queue_depth}/{a.device_queue_depth}",
                             f"{a.avg_request_bytes / 1024:.1f}"))
            widths = [max(len(r[i]) for r in rows) for i in range(7)]
            for r in rows:
                out.append("  " + "  ".join(
                    f"{c:<{w}}" for c, w in zip(r, widths)))
        d = self.decomposition
        if d.get("prepare_s"):
            comp = d.get("exposed_components_s", {})
            parts = " | ".join(
                f"{k} {d['component_fractions'].get(k, 0.0):.0%}"
                for k in comp)
            out.append(f"exposed prepare: {d['exposed_prepare_s']:.4f}s "
                       f"({d['exposed_prepare_fraction']:.0%} of "
                       f"{d['prepare_s']:.4f}s prepare) — {parts}")
        if self.alerts:
            out.append(f"alerts ({len(self.alerts)}):")
            for a in self.alerts:
                out.append(f"  [{a.get('window', '?')}] {a.get('kind')}: "
                           f"{a.get('detail', '')}")
        return "\n".join(out)


# ------------------------------------------------------------ trace import
def events_from_chrome(payload: dict) -> list:
    """Invert :meth:`TraceRecorder.to_chrome`: re-import an exported
    (or hand-built) Chrome trace object as recorder-style event tuples
    ``(ph, name, cat, track, ts_s, dur_s, args)``.

    ``thread_name`` metadata maps tids back to logical tracks; events
    on unnamed tids keep the tid as their track.  Only "X" and "i"
    events carry signal for the doctor; everything else is skipped.
    """
    evs = payload.get("traceEvents")
    if not isinstance(evs, list):
        return []
    names = {}
    for ev in evs:
        if isinstance(ev, dict) and ev.get("ph") == "M" \
                and ev.get("name") == "thread_name":
            names[ev.get("tid")] = ev.get("args", {}).get("name")
    out = []
    for ev in evs:
        if not isinstance(ev, dict):
            continue
        ph = ev.get("ph")
        if ph not in ("X", "i"):
            continue
        track = names.get(ev.get("tid")) or str(ev.get("tid"))
        try:
            ts = float(ev.get("ts", 0.0)) / 1e6
            dur = float(ev.get("dur", 0.0) or 0.0) / 1e6
        except (TypeError, ValueError):
            continue
        out.append((ph, ev.get("name", ""), ev.get("cat", ""), track,
                    ts, dur, ev.get("args") or None))
    return out


# ------------------------------------------------------- decomposition
def _merge_intervals(iv: list) -> list:
    if not iv:
        return []
    iv = sorted(iv)
    out = [list(iv[0])]
    for lo, hi in iv[1:]:
        if lo <= out[-1][1]:
            out[-1][1] = max(out[-1][1], hi)
        else:
            out.append([lo, hi])
    return out


def _overlap_s(a: list, b: list) -> float:
    """Total length of intersection(union(a), union(b))."""
    a, b = _merge_intervals(a), _merge_intervals(b)
    i = j = 0
    total = 0.0
    while i < len(a) and j < len(b):
        lo = max(a[i][0], b[j][0])
        hi = min(a[i][1], b[j][1])
        if hi > lo:
            total += hi - lo
        if a[i][1] < b[j][1]:
            i += 1
        else:
            j += 1
    return total


def decompose_prepare(events) -> dict:
    """Split the exposed-prepare fraction into causal components.

    Exposure is exact interval arithmetic over the trace: the prepare
    spans' wall time minus their timeline overlap with the train spans
    (matching ``OverlapReport.exposed_prepare_s = max(epoch_wall -
    train_wall, 0)`` when prepare and train tile the epoch).  The
    component split reuses the span category scheme:

    ============== ===================================================
    component      source
    ============== ===================================================
    sampling_cpu   ``prepare.stage`` spans named ``plan:*``/``assemble:*``
    io             ``io.run`` spans on the graph store
    cache_miss     ``io.run`` spans on the feature store (feature reads
                   reach storage only on buffer/cache misses)
    admission_wait ``admission`` spans
    fault_stall    ``io.fault`` retry/hedge/stall instants' modeled
                   seconds
    other          prepare wall not covered above (clamped >= 0)
    ============== ===================================================

    Components are attributions (fault stalls are modeled, async reads
    overlap the wall), so they are normalized into
    ``component_fractions`` and scaled onto the exposed seconds as
    ``exposed_components_s``.
    """
    prepare_iv: list = []
    train_iv: list = []
    comp = {"sampling_cpu": 0.0, "io": 0.0, "cache_miss": 0.0,
            "admission_wait": 0.0, "fault_stall": 0.0}
    for ev in events:
        ph, name, cat, _track, ts, dur, args = ev
        if ph == "X":
            if cat == "prepare":
                prepare_iv.append((ts, ts + dur))
            elif cat == "train":
                train_iv.append((ts, ts + dur))
            elif cat == "prepare.stage":
                stage = name.split(":", 1)[0]
                if stage in ("plan", "assemble"):
                    comp["sampling_cpu"] += dur
            elif cat == "io.run":
                if name.startswith("feature"):
                    comp["cache_miss"] += dur
                else:
                    comp["io"] += dur
            elif cat == "admission":
                comp["admission_wait"] += dur
        elif ph == "i" and cat == "io.fault" and args:
            kind = name.rsplit(".", 1)[-1]
            if kind in _STALL_KINDS:
                try:
                    comp["fault_stall"] += float(args.get("modeled_s", 0.0))
                except (TypeError, ValueError):
                    pass
    prepare_s = sum(hi - lo for lo, hi in prepare_iv)
    train_s = sum(hi - lo for lo, hi in train_iv)
    hidden_s = _overlap_s(prepare_iv, train_iv)
    exposed_s = max(prepare_s - hidden_s, 0.0)
    comp["other"] = max(prepare_s - sum(comp.values()), 0.0)
    total = sum(comp.values())
    fractions = {k: (v / total if total > 0 else 0.0)
                 for k, v in comp.items()}
    return {
        "prepare_s": prepare_s,
        "train_s": train_s,
        "hidden_prepare_s": hidden_s,
        "exposed_prepare_s": exposed_s,
        "exposed_prepare_fraction":
            exposed_s / prepare_s if prepare_s > 0 else 0.0,
        "components_s": {k: round(v, 6) for k, v in comp.items()},
        "component_fractions": {k: round(v, 4)
                                for k, v in fractions.items()},
        "exposed_components_s": {k: round(v * exposed_s, 6)
                                 for k, v in fractions.items()},
    }


# ----------------------------------------------------------- roofline
# NVMeModel defaults; used when the snapshot carries no per-array
# device gauges (single-array engines without a topology)
_DEF_BW = 6.7e9
_DEF_LATENCY = 80e-6
_DEF_DEVICE_QD = 32


def _array_rows(metrics: dict, default_device: dict | None) -> list:
    """Per-array facts from the flat snapshot.

    Multi-array engines fold ``topology.utilization_summary()`` into
    ``agnes.arrays.arrays.<i>.*`` gauges; without a topology the engine
    totals (``agnes.total.*``) become one pseudo-array using
    ``default_device`` (or NVMeModel defaults) as the ceiling.
    """
    pre = "agnes.arrays.arrays."
    grouped: dict[int, dict] = {}
    for k, v in metrics.items():
        if not k.startswith(pre):
            continue
        idx, _, field = k[len(pre):].partition(".")
        if not idx.isdigit() or not field:
            continue
        grouped.setdefault(int(idx), {})[field] = v
    dev = dict(bandwidth=_DEF_BW, latency=_DEF_LATENCY,
               queue_depth=_DEF_DEVICE_QD)
    if default_device:
        dev.update({k: v for k, v in default_device.items() if v})
    rows = []
    if grouped:
        for a in sorted(grouped):
            g = grouped[a]
            rows.append({
                "array": a,
                "online": bool(g.get("online", 1)),
                "bytes": int(g.get("bytes", 0)),
                "n_requests": int(g.get("n_requests", 0)),
                "sequential_fraction": float(
                    g.get("sequential_fraction", 0.0)),
                "busy_s": float(g.get("busy_s", 0.0)),
                "bandwidth": float(
                    g.get("bandwidth_GBps", dev["bandwidth"] / 1e9)) * 1e9,
                "latency": float(
                    g.get("latency_us", dev["latency"] * 1e6)) / 1e6,
                "device_queue_depth": int(
                    g.get("device_queue_depth", dev["queue_depth"])),
                "queue_depth": int(metrics.get(
                    f"agnes.io_queue_depth.{a}",
                    metrics.get("agnes.io_queue_depth", 0)) or 0),
            })
        return rows
    total_bytes = int(metrics.get("agnes.total.bytes_read", 0)
                      + metrics.get("agnes.total.bytes_written", 0))
    if not total_bytes and "agnes.total.n_requests" not in metrics:
        return []
    n_req = int(metrics.get("agnes.total.n_requests", 0))
    n_reads = int(metrics.get("agnes.total.n_reads", 0))
    n_seq = int(metrics.get("agnes.total.n_sequential_reads", 0))
    rows.append({
        "array": 0,
        "online": True,
        "bytes": total_bytes,
        "n_requests": n_req,
        "sequential_fraction": n_seq / n_reads if n_reads else 0.0,
        "busy_s": float(metrics.get("agnes.total.modeled_io_time_s", 0.0)),
        "bandwidth": dev["bandwidth"],
        "latency": dev["latency"],
        "device_queue_depth": dev["queue_depth"],
        "queue_depth": int(metrics.get("agnes.io_queue_depth", 0) or 0),
    })
    return rows


def _classify_array(row: dict, admission_frac: float,
                    degraded_frac: float, th: DoctorThresholds
                    ) -> ArrayDiagnosis:
    """One array against its NVMe ceiling (``NVMeModel.batch_time``'s
    two arms re-derived from the accounted aggregates)."""
    bw = max(row["bandwidth"], 1.0)
    lat = max(row["latency"], 1e-9)
    dqd = max(row["device_queue_depth"], 1)
    qd = row["queue_depth"] or dqd
    qd_eff = max(min(qd, dqd), 1)
    busy = row["busy_s"]
    nbytes = row["bytes"]
    n_req = row["n_requests"]
    # sequential_fraction is block-granular (n_sequential/n_reads); at
    # request granularity it slightly overestimates randomness, which
    # only biases toward the conservative (iops) arm
    n_random = n_req * max(1.0 - row["sequential_fraction"], 0.0)
    bw_term = nbytes / bw
    iops_term = n_random * lat / qd_eff
    bw_util = (nbytes / busy) / bw if busy > 0 else 0.0
    iops_ceiling = qd_eff / lat
    iops_util = (n_random / busy) / iops_ceiling if busy > 0 else 0.0
    if not row["online"] or degraded_frac > th.degraded_read_frac:
        state = "fault-degraded"
    elif busy <= th.idle_busy_s or nbytes == 0:
        state = "idle"
    elif admission_frac > th.admission_wait_frac:
        state = "admission-throttled"
    elif iops_term >= bw_term:
        starved_qd = max(1, int(dqd * th.queue_starved_qd_frac))
        state = "queue-starved" if qd_eff <= starved_qd else "iops-bound"
    else:
        state = "bw-bound"
    return ArrayDiagnosis(
        array=row["array"], state=state, online=row["online"],
        bytes=nbytes, n_requests=n_req, busy_s=busy,
        bw_term_s=round(bw_term, 6), iops_term_s=round(iops_term, 6),
        bw_utilization=round(min(bw_util, 1.0), 4),
        iops_utilization=round(min(iops_util, 1.0), 4),
        queue_depth=qd, device_queue_depth=dqd,
        avg_request_bytes=nbytes / n_req if n_req else 0.0)


# ----------------------------------------------------------- findings
def _mk(kind: str, severity: float, summary: str, evidence: dict
        ) -> Finding:
    return Finding(kind=kind, severity=round(min(max(severity, 0.0), 1.0), 4),
                   summary=summary, knob=SUGGESTED_KNOBS[kind],
                   evidence=evidence)


def diagnose(metrics: dict, events=None, *, tenant_rooflines: dict | None
             = None, thresholds: DoctorThresholds | None = None,
             default_device: dict | None = None,
             alerts: list | None = None) -> DoctorReport:
    """Produce a :class:`DoctorReport` for one observation window.

    ``metrics`` is a flat snapshot/delta from
    :meth:`MetricsRegistry.snapshot` (with the ``agnes.*`` gauges
    folded — :meth:`AgnesEngine.metrics_snapshot` does this);
    ``events`` are recorder tuples or ``None`` (metrics-only diagnosis
    still attributes the roofline; only the exposed-prepare
    decomposition degrades to zeros).  ``tenant_rooflines`` is
    :meth:`ServingTier.tenant_roofline` per tenant, for per-tenant
    admission attribution.  ``alerts`` attaches a watchdog's collected
    alerts to the report (they also factor into the zero-false-positive
    clean-run gate).
    """
    th = thresholds or DoctorThresholds()
    decomp = decompose_prepare(events) if events else decompose_prepare([])

    busy = float(metrics.get("agnes.total.modeled_io_time_s", 0.0))
    n_requests = int(metrics.get("agnes.total.n_requests", 0))
    n_reads = int(metrics.get("agnes.total.n_reads", 0))
    wait = float(metrics.get("agnes.total.admission_wait_s", 0.0))
    if tenant_rooflines:
        wait = max(wait, sum(
            t.get("io", {}).get("admission_wait_s", 0.0)
            for t in tenant_rooflines.values()))
    admission_frac = wait / (wait + busy) if (wait + busy) > 0 else 0.0
    degraded = int(metrics.get("agnes.total.io_degraded", 0))
    degraded_frac = degraded / n_reads if n_reads else 0.0
    offline = sorted(int(v) for k, v in metrics.items()
                     if k.startswith("agnes.faults.offline_arrays."))

    arrays = [_classify_array(r, admission_frac, degraded_frac, th)
              for r in _array_rows(metrics, default_device)]

    findings: list[Finding] = []

    # --- fault-degraded: structural — an array is gone or reads are
    # being served through the degraded path
    if offline or degraded_frac > th.degraded_read_frac:
        findings.append(_mk(
            "fault-degraded", 0.95,
            f"offline arrays {offline or '[]'}; "
            f"{degraded} degraded reads "
            f"({degraded_frac:.1%} of {n_reads})",
            {"offline_arrays": offline, "io_degraded": degraded,
             "degraded_read_frac": round(degraded_frac, 4)}))

    # --- admission-throttled: engine-wide, then per tenant
    if admission_frac > th.admission_wait_frac:
        findings.append(_mk(
            "admission-throttled", 0.5 + 0.5 * admission_frac,
            f"admission wait {wait:.4f}s vs {busy:.4f}s busy "
            f"({admission_frac:.0%} of storage time spent waiting)",
            {"admission_wait_s": round(wait, 6),
             "busy_s": round(busy, 6),
             "wait_fraction": round(admission_frac, 4)}))
    if tenant_rooflines:
        for name, tr_ in sorted(tenant_rooflines.items()):
            io = tr_.get("io", {})
            t_wait = float(io.get("admission_wait_s", 0.0))
            t_busy = float(io.get("modeled_io_time_s", 0.0))
            t_frac = t_wait / (t_wait + t_busy) \
                if (t_wait + t_busy) > 0 else 0.0
            if t_frac > th.admission_wait_frac and not any(
                    f.kind == "admission-throttled"
                    and f.evidence.get("tenant") == name
                    for f in findings):
                findings.append(_mk(
                    "admission-throttled", 0.5 + 0.5 * t_frac,
                    f"tenant {name!r}: {t_wait:.4f}s admission wait vs "
                    f"{t_busy:.4f}s of its own I/O ({t_frac:.0%})",
                    {"tenant": name,
                     "admission_wait_s": round(t_wait, 6),
                     "busy_s": round(t_busy, 6),
                     "wait_fraction": round(t_frac, 4),
                     "forced_grants": int(
                         tr_.get("admission", {}).get("forced_grants",
                                                      0))}))

    # --- hedge/stall: fault-path events per submitted request, plus
    # the trace's modeled stall attribution when available
    n_faults = int(metrics.get("agnes.total.io_retries", 0)
                   + metrics.get("agnes.total.io_hedges", 0))
    n_faults += sum(int(v) for k, v in metrics.items()
                    if k.endswith(".fault.stall")
                    and not isinstance(v, dict))
    fault_rate = n_faults / n_requests if n_requests else 0.0
    stall_frac = decomp["component_fractions"].get("fault_stall", 0.0)
    if fault_rate > th.fault_rate or stall_frac > 0.2:
        findings.append(_mk(
            "hedge-stall",
            0.45 + min(0.5, 5.0 * fault_rate + stall_frac),
            f"{n_faults} retry/hedge/stall events over {n_requests} "
            f"requests ({fault_rate:.1%}); fault stall is "
            f"{stall_frac:.0%} of attributed prepare",
            {"fault_events": n_faults, "n_requests": n_requests,
             "fault_rate": round(fault_rate, 4),
             "stall_fraction": round(stall_frac, 4)}))

    # --- cache-miss-bound: the feature cache stopped absorbing the
    # gather and feature I/O dominates storage time.  Eviction-gated:
    # a cache that never evicted is cold or streaming, not undersized —
    # cold first-touch misses are not a capacity problem
    hit = float(metrics.get("agnes.feature_cache_hit", 0.0))
    admitted = int(metrics.get("cache.rows_admitted", 0)
                   + metrics.get("agnes.total.cache_misses", 0))
    evictions = int(metrics.get("cache.rows_evicted", 0)
                    + metrics.get("agnes.total.cache_evictions", 0))
    feat_io = float(metrics.get("agnes.feature.modeled_io_time_s", 0.0))
    feat_share = feat_io / busy if busy > 0 else 0.0
    if (admitted and evictions and hit < th.cache_hit_floor
            and feat_share > th.cache_feature_share):
        findings.append(_mk(
            "cache-miss-bound",
            0.5 + 0.4 * (1.0 - hit) * feat_share,
            f"feature cache hit ratio {hit:.0%} with {evictions} "
            f"evictions over {admitted} admissions; feature I/O is "
            f"{feat_share:.0%} of storage time",
            {"cache_hit_ratio": round(hit, 4),
             "cache_rows_admitted": admitted,
             "cache_rows_evicted": evictions,
             "feature_io_share": round(feat_share, 4)}))

    # --- device shape of the busiest online array: always attributed,
    # ranked below any causal finding (severity capped at 0.4)
    active = [a for a in arrays if a.state not in ("idle",)]
    if active:
        top = max(active, key=lambda a: a.busy_s)
        if top.state in ("bw-bound", "iops-bound", "queue-starved"):
            dom = max(top.bw_term_s, top.iops_term_s)
            share = dom / top.busy_s if top.busy_s > 0 else 0.0
            findings.append(_mk(
                top.state, 0.25 + 0.15 * min(share, 1.0),
                f"array {top.array}: {top.state} "
                f"(bw arm {top.bw_term_s:.4f}s vs iops arm "
                f"{top.iops_term_s:.4f}s at qd "
                f"{min(top.queue_depth, top.device_queue_depth)}, "
                f"{top.avg_request_bytes / 1024:.1f} KiB/request)",
                {"array": top.array,
                 "bw_term_s": top.bw_term_s,
                 "iops_term_s": top.iops_term_s,
                 "queue_depth": top.queue_depth,
                 "avg_request_bytes": round(top.avg_request_bytes, 1)}))

    findings.sort(key=lambda f: f.severity, reverse=True)
    primary = findings[0].kind if findings else "healthy"
    return DoctorReport(primary=primary, findings=findings,
                        arrays=arrays, decomposition=decomp,
                        alerts=list(alerts or []))


# ----------------------------------------------------------- watchdog
class AnomalyWatchdog:
    """Rolling windowed anomaly detectors over the metrics registry.

    Drive :meth:`observe` at a fixed cadence (per hyperbatch or per
    epoch); each call closes one window via
    :meth:`MetricsRegistry.delta`, runs the detectors against rolling
    baselines, appends any alerts to :attr:`alerts`, and — when the
    bundle records a trace — emits each alert as a structured
    ``diag.alert`` instant on the ``doctor`` track, so anomalies land
    on the same timeline as the I/O that caused them.

    Detectors: stall/retry spikes, hedge storms, admission starvation
    (forced grants or waits past the per-grant mean bound), cache-hit
    collapse vs the rolling baseline, and trace-event drops.
    """

    def __init__(self, engine=None, *, telemetry=None,
                 thresholds: DoctorThresholds | None = None):
        if telemetry is None:
            telemetry = engine.telemetry
        self._engine = engine
        self.telemetry = telemetry
        self.th = thresholds or DoctorThresholds()
        self.alerts: list[dict] = []
        self._prev: dict | None = None
        self._window = 0
        self._hist: dict[str, deque] = {
            k: deque(maxlen=self.th.w_history)
            for k in ("stall", "hedge", "hit")}
        self._last_dropped = 0

    # ------------------------------------------------------------ snap
    def _snap(self) -> dict:
        if self._engine is not None:
            return self._engine.metrics_snapshot(refresh=True)
        return self.telemetry.metrics.snapshot()

    def begin(self) -> None:
        """Prime the first window (also implied by the first
        :meth:`observe`)."""
        self._prev = self._snap()
        tr = self.telemetry.trace
        self._last_dropped = tr.n_dropped if tr is not None else 0

    # -------------------------------------------------------- observe
    def observe(self, label: str = "") -> list:
        """Close the current window; returns this window's alerts."""
        if self._prev is None:
            self.begin()
            return []
        cur = self._snap()
        d = self.telemetry.metrics.delta(self._prev)
        self._prev = cur
        self._window += 1
        new = self._detect(d)
        for a in new:
            a["window"] = label or f"w{self._window}"
            self.alerts.append(a)
            self._emit(a)
        if new:
            # writing the alerts into a saturated ring bumps n_dropped;
            # re-baseline so the drops *we* caused don't retrigger the
            # trace-drops detector next window, forever
            tr = self.telemetry.trace
            if tr is not None:
                self._last_dropped = tr.n_dropped
        return new

    def _emit(self, alert: dict) -> None:
        tr = self.telemetry.trace
        if tr is not None:
            tr.instant(f"alert:{alert['kind']}", "diag.alert", "doctor",
                       args=dict(alert))

    # ------------------------------------------------------- detectors
    @staticmethod
    def _sum(d: dict, pred) -> float:
        return sum(v for k, v in d.items()
                   if not isinstance(v, dict) and pred(k))

    def _detect(self, d: dict) -> list[dict]:
        th = self.th
        out: list[dict] = []
        runs = self._sum(d, lambda k: k.startswith("io.")
                         and k.endswith(".runs"))

        # stall spike: transient-fault retries + exposed latency stalls
        n_stall = self._sum(d, lambda k: k.startswith("io.") and (
            k.endswith(".fault.stall") or k.endswith(".fault.retry")))
        rate = n_stall / max(runs, 1.0)
        base = self._baseline("stall")
        self._hist["stall"].append(rate)
        if n_stall >= th.w_min_events and rate > max(th.w_stall_rate,
                                                     3.0 * base):
            out.append({"kind": "stall-spike", "severity": min(1.0, rate * 10),
                        "detail": f"{int(n_stall)} stall/retry events over "
                                  f"{int(runs)} runs ({rate:.1%}, baseline "
                                  f"{base:.1%})",
                        "knob": SUGGESTED_KNOBS["stall-spike"]})

        # hedge storm: duplicate reads past the p99 deadline
        n_hedge = self._sum(d, lambda k: k.startswith("io.")
                            and k.endswith(".fault.hedge"))
        hrate = n_hedge / max(runs, 1.0)
        hbase = self._baseline("hedge")
        self._hist["hedge"].append(hrate)
        if n_hedge >= th.w_min_events and hrate > max(th.w_hedge_rate,
                                                      3.0 * hbase):
            out.append({"kind": "hedge-storm", "severity": min(1.0, hrate * 10),
                        "detail": f"{int(n_hedge)} hedged reads over "
                                  f"{int(runs)} runs ({hrate:.1%})",
                        "knob": SUGGESTED_KNOBS["hedge-storm"]})

        # starvation: aging overrode priority, or per-grant waits blew
        # past the bound ("admission.state.*" are pass-through gauges —
        # only the true counters/histograms carry window semantics)
        forced = self._sum(d, lambda k: k.startswith("admission.")
                           and not k.startswith("admission.state.")
                           and k.endswith(".forced_grants"))
        wait_n = wait_sum = 0.0
        for k, v in d.items():
            if k.startswith("admission.") and k.endswith(".wait_s") \
                    and isinstance(v, dict):
                wait_n += v.get("count", 0)
                wait_sum += v.get("sum", 0.0)
        mean_wait = wait_sum / wait_n if wait_n else 0.0
        if forced > 0 or (wait_n >= th.w_min_events
                          and mean_wait > th.w_wait_mean_s):
            out.append({"kind": "starvation",
                        "severity": min(1.0, 0.5 + forced / 10),
                        "detail": f"{int(forced)} forced grants, mean "
                                  f"admission wait {mean_wait * 1e3:.1f}ms "
                                  f"over {int(wait_n)} waits",
                        "knob": SUGGESTED_KNOBS["starvation"]})

        # cache-hit collapse: cumulative hit-ratio gauge falling off a
        # healthy rolling baseline
        hit = d.get("agnes.feature_cache_hit")
        if isinstance(hit, (int, float)):
            hbase = max(self._hist["hit"], default=0.0)
            self._hist["hit"].append(float(hit))
            if hbase >= self.th.cache_hit_floor \
                    and hbase - hit > th.w_hit_drop:
                out.append({"kind": "cache-collapse",
                            "severity": min(1.0, hbase - hit),
                            "detail": f"feature cache hit ratio fell "
                                      f"{hbase:.0%} -> {hit:.0%}",
                            "knob": SUGGESTED_KNOBS["cache-collapse"]})

        # trace drops: the ring started overwriting events this window
        tr = self.telemetry.trace
        if tr is not None:
            nd = tr.n_dropped
            if nd > self._last_dropped:
                out.append({"kind": "trace-drops", "severity": 0.3,
                            "detail": f"{nd - self._last_dropped} events "
                                      f"overwritten this window "
                                      f"({nd} total)",
                            "knob": SUGGESTED_KNOBS["trace-drops"]})
                self._last_dropped = nd
        return out

    def _baseline(self, key: str) -> float:
        h = self._hist[key]
        return sum(h) / len(h) if h else 0.0
