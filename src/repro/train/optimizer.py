"""Pure-JAX optimizers (no external deps): AdamW + schedules + clipping.

Written as init/update pairs over arbitrary pytrees so the same code
drives the GNN trainer and the sharded LM trainer.  For the LM path the
moments can be kept in a *different* sharding than the params (ZeRO-1:
optimizer states sharded over the data axis) — the update is elementwise,
so XLA inserts the reduce-scatter/all-gather pair automatically from the
in/out shardings requested by the caller.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any        # first moment, pytree like params
    nu: Any        # second moment, pytree like params


def adamw_init(params: Any, dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, dtype)  # noqa: E731
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def adamw_update(params: Any, grads: Any, state: AdamWState, *,
                 lr: float | jnp.ndarray = 1e-3, b1: float = 0.9,
                 b2: float = 0.999, eps: float = 1e-8,
                 weight_decay: float = 0.01) -> tuple[Any, AdamWState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        mdt = m.dtype  # f32 default; bf16 for 100B+ models (memory budget)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        mh = m32 / c1
        vh = v32 / c2
        new_p = p.astype(jnp.float32) - lr * (
            mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v)


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jnp.ndarray]:
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gn


def cosine_schedule(base_lr: float, warmup_steps: int,
                    total_steps: int, min_ratio: float = 0.1
                    ) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0)
        prog = jnp.clip((step - warmup_steps)
                        / jnp.maximum(total_steps - warmup_steps, 1), 0, 1)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * warm * cos
    return sched
