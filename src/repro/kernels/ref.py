"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function is the semantic specification its kernel is tested against
(`tests/test_kernels.py` sweeps shapes/dtypes and asserts allclose).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_rows_ref(table: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """out[i] = table[idx[i]] — block feature gather (paper G-1/G-2)."""
    return jnp.take(table, idx, axis=0)


def gather_resident_rows_ref(table: jnp.ndarray, slots: jnp.ndarray,
                             miss_pos: jnp.ndarray,
                             miss_rows: jnp.ndarray) -> jnp.ndarray:
    """Device-resident gather: cache hits from ``table``, misses scattered.

    out[i] = table[slots[i]]  where slots[i] >= 0, else 0; then
    out[miss_pos] = miss_rows.  ``table`` may be lane-padded wider than
    the true feature width — the output is ``miss_rows``'s width.
    """
    d = miss_rows.shape[1]
    valid = (slots >= 0)
    rows = jnp.take(table, jnp.clip(slots, 0), axis=0)[:, :d]
    out = rows * valid[:, None].astype(rows.dtype)
    if miss_pos.shape[0]:
        out = out.at[miss_pos].set(miss_rows.astype(out.dtype))
    return out


def gather_aggregate_ref(table: jnp.ndarray, nbr_idx: jnp.ndarray,
                         mean: bool = True) -> jnp.ndarray:
    """Fused neighbor gather + masked sum/mean (GNN aggregation).

    nbr_idx: (n_dst, fanout) int32, -1 padding.
    out[v]  = sum_f table[nbr_idx[v, f]]  (masked; mean divides by count).
    """
    mask = (nbr_idx >= 0)
    vals = jnp.take(table, jnp.clip(nbr_idx, 0), axis=0)
    m = mask[..., None].astype(vals.dtype)
    s = jnp.sum(vals * m, axis=1)
    if mean:
        c = jnp.maximum(jnp.sum(m, axis=1), 1.0)
        return s / c
    return s


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, window: int = 0,
                        scale: float | None = None) -> jnp.ndarray:
    """Reference attention. q: (B, Hq, S, D), k/v: (B, Hkv, S, D).

    GQA: Hq % Hkv == 0; query head h reads kv head h // (Hq // Hkv).
    ``window`` > 0 restricts to a causal sliding window of that size.
    """
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    g = Hq // Hkv
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    qf = q.astype(jnp.float32).reshape(B, Hkv, g, S, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bhgsd,bhtd->bhgst", qf, kf) * scale
    pos_q = jnp.arange(S)[:, None]
    pos_k = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= pos_k <= pos_q
    if window > 0:
        mask &= pos_k > pos_q - window
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgst,bhtd->bhgsd", p, vf)
    return out.reshape(B, Hq, S, D).astype(q.dtype)


def decode_attention_ref(q: jnp.ndarray, k_cache: jnp.ndarray,
                         v_cache: jnp.ndarray, lengths: jnp.ndarray,
                         scale: float | None = None) -> jnp.ndarray:
    """Single-token decode attention over a (ragged) KV cache.

    q: (B, Hq, D); k/v_cache: (B, Hkv, Smax, D); lengths: (B,) valid length.
    """
    B, Hq, D = q.shape
    Hkv, Smax = k_cache.shape[1], k_cache.shape[2]
    g = Hq // Hkv
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    qf = q.astype(jnp.float32).reshape(B, Hkv, g, D)
    logits = jnp.einsum("bhgd,bhtd->bhgt", qf, k_cache.astype(jnp.float32))
    logits = logits * scale
    mask = jnp.arange(Smax)[None, None, None, :] < lengths[:, None, None, None]
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgt,bhtd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, Hq, D).astype(q.dtype)
