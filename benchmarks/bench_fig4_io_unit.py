"""Fig 4: naively growing the I/O unit size in a node-granular engine
inflates total bytes while the cache hit ratio collapses."""
from __future__ import annotations

from .common import ALL_BASELINES, emit, get_dataset, make_baseline, \
    targets_for


def run():
    ds = get_dataset("ig-mini")
    targets = targets_for(ds, n_mb=4, mb_size=512)
    for unit_kb in (4, 16, 64, 256, 1024):
        eng = make_baseline(ALL_BASELINES["ginex"], ds,
                            setting_bytes=16 << 20)
        eng.cfg.io_unit = unit_kb * 1024
        eng.prepare(targets, epoch=0)
        st = eng.features.stats
        useful = st.n_reads * ds.dim * 4  # bytes actually consumed
        emit(f"fig4/unit_{unit_kb}KiB/bytes_read_MB",
             st.bytes_read / 1e6,
             f"useful_ratio={useful/max(st.bytes_read,1):.4f} "
             f"n_ios={st.n_reads}")


if __name__ == "__main__":
    run()
