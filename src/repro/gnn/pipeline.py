"""Pipelined prepare→train executor: hide data preparation behind compute.

The paper's Fig-2 point is that data preparation dominates storage-based
GNN training; the fix is overlap.  This module runs
:meth:`AgnesEngine.prepare` for hyperbatch *i+1* on a background thread
while the jitted train step consumes hyperbatch *i* — the same bounded
read-ahead pattern as :class:`repro.core.async_io.BlockPrefetcher`, one
level up the stack (hyperbatches instead of storage blocks).

Determinism: the producer walks :meth:`AgnesEngine.plan_epoch` in order
on a single thread, so every buffer/cache mutation happens in the same
sequence as the serial loop, and the counter-hash sampler is
order-independent anyway — pipelined losses are bit-identical to the
serial loop at a fixed seed (``tests/test_pipeline.py`` asserts this).

Accounting follows :class:`PrepareReport`'s ``max(cpu, io)`` overlap
model: with perfect overlap the epoch wall is ``max(prepare, train)``
instead of ``prepare + train``.  :class:`OverlapReport.hidden_fraction`
reports the measured fraction of prepare wall time hidden behind the
train steps (train releases the GIL inside XLA, prepare is numpy + I/O,
so overlap is real even in-process).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time

import numpy as np

from ..core.agnes import PrepareReport


def _chain_errors(errors: list[BaseException]) -> BaseException | None:
    """Fold multiple drained producer errors into one raisable exception.

    Python 3.10 has no ``ExceptionGroup``, so the first error becomes
    the head and every later distinct error is linked behind it through
    ``__context__`` — the traceback then prints the whole cascade
    ("During handling of ... another exception occurred").  Identity
    duplicates (the same object drained twice via sentinel + stash) are
    dropped; existing context chains are preserved by appending at each
    chain's tail, with a seen-set guarding against cycles.
    """
    unique: list[BaseException] = []
    for exc in errors:
        if not any(exc is u for u in unique):
            unique.append(exc)
    if not unique:
        return None
    head = unique[0]
    for nxt in unique[1:]:
        node, seen = head, {id(head)}
        while node.__context__ is not None and id(node.__context__) not in seen:
            node = node.__context__
            seen.add(id(node))
        if id(nxt) not in seen:
            node.__context__ = nxt
    return head


@dataclasses.dataclass
class OverlapReport:
    """Measured overlap for one pipelined epoch."""

    epoch_wall_s: float
    prepare_wall_s: float        # producer time inside engine.prepare
    train_wall_s: float          # consumer time inside train steps
    n_hyperbatches: int
    n_minibatches: int
    losses: list[float]
    prepare_reports: list[PrepareReport]
    # io_queue_depth after each hyperbatch when the adaptive scheduler
    # hook is on (empty otherwise); scalar per hyperbatch without a
    # storage topology, ``{array: depth}`` with one (per-array control)
    queue_depths: list = dataclasses.field(default_factory=list)
    # per-store migration summaries from the engine's epoch-boundary
    # online re-placement pass (None when online_placement is off)
    migration: dict | None = None

    @property
    def exposed_prepare_s(self) -> float:
        """Prepare time the consumer actually waited on (not hidden)."""
        return max(self.epoch_wall_s - self.train_wall_s, 0.0)

    @property
    def hidden_fraction(self) -> float:
        """Fraction of prepare wall time overlapped with training.

        1.0 = fully hidden (epoch wall == train wall); 0.0 = serial.
        """
        if self.prepare_wall_s <= 0.0:
            return 0.0
        hidden = self.prepare_wall_s - self.exposed_prepare_s
        return min(max(hidden / self.prepare_wall_s, 0.0), 1.0)

    @property
    def serial_estimate_s(self) -> float:
        return self.prepare_wall_s + self.train_wall_s

    def io_summary(self) -> dict:
        """Aggregate I/O schedule quality across the epoch's hyperbatches.

        Surfaces the coalescing scheduler's effect (``repro.core.io_sched``):
        block-granular reads vs merged device requests, sequential fraction,
        and modeled device time.
        """
        reads = requests = seq = bytes_ = 0
        modeled = 0.0
        for r in self.prepare_reports:
            for io in (r.sample_io, r.gather_io):
                reads += io.get("n_reads", 0)
                requests += io.get("n_requests", 0)
                seq += io.get("n_sequential", 0)
                bytes_ += io.get("bytes", 0)
                modeled += io.get("modeled_s", 0.0)
        return {
            "n_reads": reads,
            "n_requests": requests,
            "n_sequential_reads": seq,
            "sequential_fraction": round(seq / reads, 4) if reads else 0.0,
            "coalesce_factor": round(reads / requests, 3) if requests else 0.0,
            "bytes_read": bytes_,
            "modeled_io_s": modeled,
            # the adaptive scheduler's control signal: how much prepare
            # time the consumer actually waited on (0 = fully hidden;
            # clamped — epoch wall includes consumer overhead beyond
            # train + prepare)
            "exposed_prepare_fraction": round(min(
                self.exposed_prepare_s / self.prepare_wall_s, 1.0), 4)
            if self.prepare_wall_s > 0 else 0.0,
            "io_queue_depths": list(self.queue_depths),
        }

    def summary(self) -> dict:
        out = {
            "epoch_wall_s": self.epoch_wall_s,
            "prepare_wall_s": self.prepare_wall_s,
            "train_wall_s": self.train_wall_s,
            "exposed_prepare_s": self.exposed_prepare_s,
            "hidden_fraction": self.hidden_fraction,
            "n_hyperbatches": self.n_hyperbatches,
            "n_minibatches": self.n_minibatches,
            "io": self.io_summary(),
        }
        if self.migration is not None:
            out["migration"] = self.migration
        return out


class PipelinedExecutor:
    """Bounded-depth producer/consumer over (engine, trainer).

    ``depth`` hyperbatches of prepared minibatches may be in flight at
    once — enough to keep the trainer fed, small enough to bound host
    memory (a hyperbatch of features is the largest transient object in
    the system).

    ``adaptive_io=True`` turns on the hyperbatch-level scheduler hook:
    after each trained hyperbatch the executor reads that hyperbatch's
    exposed-prepare fraction (the same signal
    :meth:`OverlapReport.io_summary` reports, computed over the
    hyperbatch window rather than the whole epoch) and resizes the
    engine's ``io_queue_depth`` — exposed prepare means the epoch is
    I/O-bound, so the queue deepens (more modeled request overlap,
    bounded by ``io_queue_depth_bounds``); fully hidden prepare lets it
    shrink back.  Only the modeled device time changes — plans, bytes
    and losses are identical.

    With a storage topology attached, each array is driven
    *independently* from its own windowed roofline (its per-array
    ``IOStats`` busy-time delta over the hyperbatch): when prepare is
    exposed, only the roofline-setting array(s) deepen — the ones whose
    busy time actually gates the ``max``-over-arrays cost — while
    arrays with significant slack shrink back toward the lower bound
    (``engine.set_io_queue_depth(qd, array=...)``).

    When the engine's ``online_placement`` is on, the executor also
    drives ``engine.end_epoch()`` after the epoch completes — the
    epoch-boundary hotness roll + budgeted block migration pass — and
    surfaces its per-store summaries on :attr:`OverlapReport.migration`.

    Use as a context manager or call :meth:`close`; a mid-epoch
    exception on either side stops and joins the background thread
    before propagating.
    """

    def __init__(self, engine, trainer, depth: int = 2,
                 adaptive_io: bool = False,
                 io_queue_depth_bounds: tuple[int, int] = (2, 32),
                 check_cache_invariants: bool = False,
                 tenant: str = "training"):
        if depth < 1:
            raise ValueError("depth must be >= 1")
        self.engine = engine
        self.trainer = trainer
        self.depth = depth
        # serving-tier fault isolation: producer errors are tagged with
        # this label, so a fault surfacing from one tenant's pipeline is
        # attributable (and testably scoped) to that tenant
        self.tenant = tenant
        self.adaptive_io = adaptive_io
        self.io_queue_depth_bounds = io_queue_depth_bounds
        # debug/stress knob: assert the feature cache's slot_of/node_at
        # bijection from the consumer thread after every minibatch, while
        # the producer may be mid-admit (FeatureCache.check_invariants
        # takes the cache lock, so this exercises the real interleaving)
        self.check_cache_invariants = check_cache_invariants
        self._stop = threading.Event()
        self._producer: threading.Thread | None = None
        self._queue: queue.Queue | None = None
        self._producer_error: BaseException | None = None
        self._prev_array_busy: list[float] | None = None

    # ---------------------------------------------------------- epoch
    def run_epoch(self, all_targets: np.ndarray, epoch: int = 0,
                  shuffle: bool = True) -> OverlapReport:
        """Train one epoch with prepare/compute overlap; returns stats.

        Trainer state (params/opt) advances in place, exactly as the
        serial ``for prepared in engine.iter_epoch(...)`` loop would.
        """
        if self._producer is not None and self._producer.is_alive():
            raise RuntimeError("an epoch is already running")
        plan = self.engine.plan_epoch(all_targets, epoch=epoch,
                                      shuffle=shuffle)
        topo = getattr(self.engine, "topology", None)
        if topo is not None:
            # window base for the per-array adaptive signal: each
            # hyperbatch's busy-time delta, not cumulative history
            with topo.lock:
                self._prev_array_busy = [st.modeled_io_time
                                         for st in topo.array_stats]
        else:
            self._prev_array_busy = None
        q: queue.Queue = queue.Queue(maxsize=self.depth)
        # fresh per-epoch stop event: a producer from a previous epoch that
        # outlived its join timeout keeps seeing its own (set) event and can
        # never be revived by a later epoch
        stop = threading.Event()
        self._queue = q
        self._stop = stop
        prepare_s = [0.0]

        self._producer_error = None

        def produce():
            try:
                for mbs in plan:
                    if stop.is_set():
                        return
                    t0 = time.perf_counter()
                    prepared = self.engine.prepare(mbs, epoch=epoch)
                    prepare_s[0] += time.perf_counter() - t0
                    if not self._offer(q, stop, ("batch", prepared,
                                                 self.engine.last_report)):
                        return
                self._offer(q, stop, ("done", None, None))
            except BaseException as exc:  # propagate into the consumer
                # also stash it: a stopped consumer never drains the queue,
                # and the sentinel may not even get in (_offer gives up on
                # stop) — _shutdown surfaces it either way.  Tag the
                # error with this executor's tenant so a serving tier
                # can attribute (and scope) the failure.
                try:
                    exc.tenant = self.tenant
                except Exception:
                    pass  # exotic exception types may reject attributes
                self._producer_error = exc
                self._offer(q, stop, ("error", exc, None))

        self._producer = threading.Thread(
            target=produce, daemon=True,
            name=f"agnes-prepare-{self.tenant}")
        losses: list[float] = []
        reports: list[PrepareReport] = []
        queue_depths: list = []  # scalar per hyperbatch, or {array: depth}
        train_s = 0.0
        n_hb = n_mb = 0
        prev_wall = prev_prep = prev_train = 0.0  # adaptive-signal window
        # telemetry (core/telemetry.py): consumer "train" spans reuse the
        # exact perf_counter readings that accumulate train_s, so the
        # trace-derived Fig.2 train bar equals OverlapReport.train_wall_s
        tel = getattr(self.engine, "telemetry", None)
        tr = tel.trace if tel is not None else None
        if tel is not None and getattr(self.trainer, "telemetry", 1) is None:
            self.trainer.telemetry = tel  # transfer/step spans, opt-in field
        t_epoch = time.perf_counter()
        self._producer.start()
        try:
            while True:
                try:
                    kind, payload, report = q.get(timeout=0.5)
                except queue.Empty:
                    if self._producer.is_alive():
                        continue
                    try:
                        # the producer may have enqueued its sentinel and
                        # exited between our timeout and the liveness check
                        kind, payload, report = q.get_nowait()
                    except queue.Empty:
                        raise RuntimeError(
                            "prepare thread died without a sentinel") \
                            from None
                if kind == "done":
                    break
                if kind == "error":
                    self._producer_error = None  # being handled right here
                    raise payload
                n_hb += 1
                if report is not None:
                    reports.append(report)
                t0 = time.perf_counter()
                for p in payload:
                    losses.append(self.trainer.train_minibatch(p))
                    n_mb += 1
                    if self.check_cache_invariants:
                        cache = getattr(self.engine, "feature_cache", None)
                        if cache is not None:
                            cache.check_invariants()
                t1 = time.perf_counter()
                train_s += t1 - t0
                if tr is not None:
                    tr.complete(f"train:hb{n_hb - 1}", "train", "train",
                                t0, t1, args={"n_minibatches": len(payload)})
                if self.adaptive_io and hasattr(self.engine,
                                                "set_io_queue_depth"):
                    # windowed signal: this hyperbatch's deltas only — the
                    # cumulative epoch fraction never decays below the
                    # grow threshold after the pipeline-fill warmup, so a
                    # compute-bound epoch could never shrink the queue
                    wall, prep = time.perf_counter() - t_epoch, prepare_s[0]
                    window = OverlapReport(
                        wall - prev_wall, prep - prev_prep,
                        train_s - prev_train, 1, 0, [], [])
                    prev_wall, prev_prep, prev_train = wall, prep, train_s
                    queue_depths.append(self._resize_queue_depth(
                        window.io_summary()["exposed_prepare_fraction"]))
        except BaseException as exc:
            leaked = self._shutdown()
            if leaked is not None and leaked is not exc:
                raise exc from leaked  # keep the prepare-side error visible
            raise
        else:
            leaked = self._shutdown()
            if leaked is not None:
                raise leaked  # a swallowed producer error is a real failure
        migration = None
        if getattr(getattr(self.engine, "config", None),
                   "online_placement", False) \
                and hasattr(self.engine, "end_epoch"):
            # epoch boundary: hotness roll + budgeted re-placement, so
            # the next epoch's plans split against the migrated layout
            migration = self.engine.end_epoch()
        wall = time.perf_counter() - t_epoch
        report = OverlapReport(wall, prepare_s[0], train_s, n_hb, n_mb,
                               losses, reports, queue_depths, migration)
        if tr is not None:
            tr.complete(f"epoch:{epoch}", "pipeline", "pipeline",
                        t_epoch, t_epoch + wall,
                        args={"n_hyperbatches": n_hb, "n_minibatches": n_mb,
                              "hidden_fraction": round(
                                  report.hidden_fraction, 4)})
        if tel is not None:
            tel.metrics.counter("pipeline.hyperbatches").inc(n_hb)
            tel.metrics.counter("pipeline.minibatches").inc(n_mb)
            tel.metrics.gauge("pipeline.hidden_fraction").set(
                round(report.hidden_fraction, 4))
        return report

    # ------------------------------------------------------- lifecycle
    def close(self) -> None:
        """Stop and join any in-flight prepare thread (idempotent).

        Re-raises a prepare-side error the consumer never observed —
        silently dropping it would report a failed epoch as clean.
        """
        leaked = self._shutdown()
        if leaked is not None:
            raise leaked

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is not None:
            self._shutdown()  # don't mask the in-flight exception
        else:
            self.close()

    # ------------------------------------------------------- internals
    @staticmethod
    def _offer(q: queue.Queue, stop: threading.Event, item) -> bool:
        """Backpressure-aware put that stays responsive to its stop event."""
        while not stop.is_set():
            try:
                q.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def _resize_queue_depth(self, exposed_frac: float):
        """Hyperbatch-level scheduler integration: exposed prepare means
        the epoch is I/O-bound — deepen the queue so the coalesced plans
        overlap more requests; fully hidden prepare shrinks it back.

        With a storage topology each array is resized independently
        from its own windowed roofline: exposed prepare deepens only
        the array(s) whose busy time sets the ``max``-over-arrays cost,
        while arrays with >= 2x slack (or a fully hidden epoch) shrink
        back.  Returns the scalar depth, or ``{array: depth}``.
        """
        lo, hi = self.io_queue_depth_bounds
        topo = getattr(self.engine, "topology", None)
        if topo is None or not hasattr(self.engine, "io_queue_depths"):
            qd = self.engine.config.io_queue_depth
            if exposed_frac > 0.2:
                qd = min(max(qd * 2, lo), hi)
            elif exposed_frac < 0.02:
                qd = min(max(qd // 2, lo), hi)
            return self.engine.set_io_queue_depth(qd)
        with topo.lock:
            busys = [st.modeled_io_time for st in topo.array_stats]
        prev = self._prev_array_busy or [0.0] * len(busys)
        deltas = [b - p for b, p in zip(busys, prev)]
        self._prev_array_busy = busys
        mx = max(deltas) if deltas else 0.0
        depths = dict(self.engine.io_queue_depths())
        for a, delta in enumerate(deltas):
            qd = depths.get(a, self.engine.config.io_queue_depth)
            if exposed_frac > 0.2 and mx > 0 and delta >= 0.9 * mx:
                qd = min(max(qd * 2, lo), hi)   # this array gates the max
            elif exposed_frac < 0.02 or (mx > 0 and delta <= 0.5 * mx):
                qd = min(max(qd // 2, lo), hi)  # idle or 2x slack
            depths[a] = self.engine.set_io_queue_depth(qd, array=a)
        return depths

    def _shutdown(self) -> BaseException | None:
        """Stop, drain and join; returns a producer exception that would
        otherwise be swallowed.

        Draining with ``get_nowait`` can discard the producer's terminal
        ``("error", exc, None)`` sentinel — and a producer that errored
        after the stop event never gets to enqueue it at all (``_offer``
        gives up) — so error sentinels are captured from the drain and,
        after the join, from the producer's stash.  *Every* distinct
        drained error survives a multi-fault drain: the first is
        returned (and raised by the caller) with the rest chained behind
        it via ``__context__``, so a storage fault cascade shows all its
        casualties in the traceback instead of just the first.
        """
        self._stop.set()
        errors: list[BaseException] = []
        if self._queue is not None:
            try:  # unblock a producer stuck on a full queue
                while True:
                    kind, payload, _ = self._queue.get_nowait()
                    if kind == "error" and payload is not None:
                        errors.append(payload)
            except queue.Empty:
                pass
        if self._producer is not None:
            self._producer.join(timeout=10.0)
            if self._producer.is_alive():
                # keep the handle: the next run_epoch must refuse to start
                # while a wedged prepare call is still mutating the engine
                return _chain_errors(errors)
            self._producer = None
        self._queue = None
        if self._producer_error is not None:
            errors.append(self._producer_error)
        self._producer_error = None
        return _chain_errors(errors)
