"""Fig 7: storage-based AGNES vs distributed DistDGL (modeled).

The paper quotes DistDGL's published numbers (16× m5.24xlarge, 100 Gbps)
rather than re-running it; we do the analogous comparison with a
communication model: DistDGL-style training moves each minibatch's
remote-partition features + gradients over the network, while AGNES
moves block-wise storage I/O over NVMe.  Both sides use the same sampled
workload measured on the real sampler.
"""
from __future__ import annotations

import numpy as np

from .common import emit, get_dataset, make_agnes, targets_for

NET_BW = 100e9 / 8          # 100 Gbps in bytes/s
NET_LAT = 50e-6             # per-message
N_MACHINES = (1, 2, 4, 8)


def run():
    ds = get_dataset("pa-mini")
    targets = targets_for(ds, n_mb=4, mb_size=512)
    agnes = make_agnes(ds, setting_bytes=64 << 20)
    prepared = agnes.prepare(targets, epoch=0)
    t_agnes = agnes.last_report.modeled_io_s
    emit("fig7/agnes_single_machine", t_agnes * 1e6, "storage I/O only")

    # DistDGL model: graph range-partitioned across machines; a sampled
    # node's features are remote with prob (1 - 1/M); remote fetches are
    # batched per (machine, minibatch).
    n_feat = sum(len(p.mfg.input_nodes) for p in prepared)
    feat_bytes = n_feat * ds.dim * 4
    for m in N_MACHINES:
        remote = feat_bytes * (1 - 1 / m)
        msgs = len(prepared) * max(m - 1, 1) * 3  # per hop
        t = remote / (NET_BW * m) + msgs * NET_LAT
        # each machine also aggregates gradients (all-reduce, 2x model)
        t += 2 * (ds.dim * 128 * 4) / NET_BW
        emit(f"fig7/distdgl_{m}_machines", t * 1e6,
             f"remote_bytes={remote/1e6:.1f}MB msgs={msgs}")


if __name__ == "__main__":
    run()
