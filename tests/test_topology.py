"""Storage topology: placement mappings, per-array accounting, persistence."""
import dataclasses
import threading
import time

import numpy as np
import pytest

from repro.core import (AgnesConfig, AgnesEngine, BlockPlacement,
                        CoalescedReader, ContiguousPlacement,
                        FeatureBlockStore, HotnessAwarePlacement, NVMeModel,
                        PlanStream, Run, StorageTopology, StripePlacement,
                        coalesce, make_policy, plan_cost, topology_plan_cost)


def make_engine(ds, *, n_arrays=1, placement="stripe", topology=None,
                async_io=False, cache_rows=0, io_queue_depth=8):
    g, f = ds.reopen_stores()
    cfg = AgnesConfig(block_size=16384, minibatch_size=64,
                      hyperbatch_size=8, fanouts=(5, 5),
                      graph_buffer_bytes=1 << 20,
                      feature_buffer_bytes=1 << 20,
                      feature_cache_rows=cache_rows,
                      async_io=async_io, io_queue_depth=io_queue_depth,
                      n_arrays=n_arrays, placement=placement)
    return AgnesEngine(g, f, cfg, topology=topology)


def _totals(eng):
    g, f = eng.graph_store.stats, eng.feature_store.stats
    return {"bytes": g.bytes_read + f.bytes_read,
            "reads": g.n_reads + f.n_reads,
            "time": g.modeled_read_time + f.modeled_read_time}


# ------------------------------------------------------------------ mappings
@pytest.mark.parametrize("policy", ["contiguous", "stripe", "hotness"])
def test_placement_is_a_bijection(policy):
    topo = StorageTopology.uniform(4)
    hot = np.arange(101, dtype=np.float64)[::-1] ** 2  # skewed
    pl = make_policy(policy, 2).place(101, topo, hotness=hot)
    assert pl.n_blocks == 101
    # every array's local ids are exactly 0..count-1 (dense, no holes)
    for a in range(topo.n_arrays):
        mine = pl.local_of[pl.array_of == a]
        assert sorted(mine.tolist()) == list(range(len(mine)))
    assert pl.blocks_per_array(np.arange(101)).sum() == 101


def test_stripe_mapping_shape():
    topo = StorageTopology.uniform(4)
    pl = StripePlacement(2).place(16, topo)
    # stripes of 2: blocks 0,1 -> array 0; 2,3 -> array 1; ...
    assert pl.array_of[:8].tolist() == [0, 0, 1, 1, 2, 2, 3, 3]
    # next stripe on the same array is locally adjacent (RAID0)
    assert pl.local_of[8] == pl.local_of[0] + 2


def test_shard_run_splits_at_stripe_boundaries():
    topo = StorageTopology.uniform(2)
    pl = StripePlacement(2).place(12, topo)
    segs = pl.shard_run(Run(0, 8))
    assert [(a, s.start, s.count) for a, s in segs] == [
        (0, 0, 2), (1, 2, 2), (0, 4, 2), (1, 6, 2)]
    # accounting view re-merges the stripes: one sequential run per array
    placed = dict(pl.split_runs([Run(0, 8)], 1024, 1 << 20))
    assert [(r.start, r.count) for r in placed[0]] == [(0, 4)]
    assert [(r.start, r.count) for r in placed[1]] == [(0, 4)]


def test_split_runs_honors_per_block_convention():
    """max_coalesce_bytes=0 means one request per block everywhere —
    split_runs must not re-merge the per-block path on a placed store."""
    topo = StorageTopology.uniform(4)
    pl = StripePlacement(1).place(64, topo)
    singles = coalesce(list(range(64)), 1024, 0)  # 64 one-block runs
    placed = pl.split_runs(singles, 1024, 0)
    assert sum(len(rs) for _, rs in placed) == 64  # still 64 requests
    merged = pl.split_runs(singles, 1024, 1 << 20)
    assert sum(len(rs) for _, rs in merged) == 4   # one seq run per array


def test_hotness_pins_hot_run_on_fastest_array():
    fast = dataclasses.replace(NVMeModel(), bandwidth=2 * 6.7e9)
    topo = StorageTopology([fast, NVMeModel()])
    hot = np.ones(40)
    hot[10:14] = 100.0  # one hot run
    pl = HotnessAwarePlacement(1, hot_mass=0.5).place(40, topo, hotness=hot)
    # hot_mass=0.5 pins the first 3 hub blocks (they cover ~69% of mass);
    # the pinned run lands whole on the fast array
    assert set(pl.array_of[10:13].tolist()) == {0}, "hot run split or mislaid"
    # flat hotness: the skew gate keeps the plain stripe
    flat = HotnessAwarePlacement(1).place(40, topo, hotness=np.ones(40))
    assert np.array_equal(flat.array_of,
                          StripePlacement(1).place(40, topo).array_of)


def test_topology_plan_cost_max_over_arrays():
    topo = StorageTopology.uniform(4)
    runs = coalesce(list(range(64)), 4096, 1 << 20)
    single, *_ = (None,)
    _, _, _, t1 = plan_cost(runs, 4096, NVMeModel(), queue_depth=8)
    pl = StripePlacement(1).place(64, topo)
    placed = pl.split_runs(runs, 4096, 1 << 20)
    _, _, _, t4 = topology_plan_cost(placed, 4096, topo, 8)
    assert t4 < t1  # arrays serve their shares in parallel
    # per-array queue-depth mapping is honored
    _, _, _, t_deep = topology_plan_cost(placed, 4096, topo,
                                         {a: 32 for a in range(4)})
    assert t_deep <= t4


# ------------------------------------------------------------------ engine
def test_multi_array_parity_and_speedup(tiny_ds, rng):
    """4-array striping: byte-identical MFGs/features, less modeled time."""
    targets = [rng.choice(tiny_ds.n_nodes, 150, replace=False)
               for _ in range(6)]
    base = make_engine(tiny_ds)
    p0 = base.prepare(targets, epoch=3)
    ref = _totals(base)
    base.close()
    for policy in ("stripe", "contiguous", "hotness"):
        eng = make_engine(tiny_ds, n_arrays=4, placement=policy)
        p1 = eng.prepare(targets, epoch=3)
        for a, b in zip(p1, p0):
            for x, y in zip(a.mfg.nodes, b.mfg.nodes):
                assert np.array_equal(x, y)
            for lx, ly in zip(a.mfg.layers, b.mfg.layers):
                assert np.array_equal(lx.nbr_idx, ly.nbr_idx)
            assert np.allclose(a.features, b.features)
        got = _totals(eng)
        assert got["bytes"] == ref["bytes"], policy
        assert got["reads"] == ref["reads"], policy
        assert got["time"] < ref["time"], policy
        arrays = eng.io_stats()["arrays"]
        assert arrays["n_arrays"] == 4
        assert sum(a["bytes"] for a in arrays["arrays"]) == got["bytes"]
        eng.close()


def test_multi_array_async_parity(tiny_ds, rng):
    targets = [rng.choice(tiny_ds.n_nodes, 150, replace=False)
               for _ in range(4)]
    base = make_engine(tiny_ds)
    p0 = base.prepare(targets, epoch=1)
    eng = make_engine(tiny_ds, n_arrays=4, async_io=True)
    p1 = eng.prepare(targets, epoch=1)
    for a, b in zip(p1, p0):
        assert np.allclose(a.features, b.features)
    assert _totals(eng)["bytes"] == _totals(base)["bytes"]
    eng.close()
    base.close()


def test_session_plans_carry_array_breakdown(tiny_ds, rng):
    eng = make_engine(tiny_ds, n_arrays=2)
    targets = [rng.choice(tiny_ds.n_nodes, 100, replace=False)]
    eng.prepare(targets, epoch=0)
    plans = [p for p in eng.last_session.plans if p.n_blocks]
    assert plans, "session emitted no non-empty plans"
    for p in plans:
        assert p.blocks_per_array is not None
        assert p.blocks_per_array.sum() == p.n_blocks
    # hop-plan level introspection agrees with the placement mapping
    frontiers = [np.unique(np.asarray(t, dtype=np.int64)) for t in targets]
    hp = eng.sampler.plan_hop(frontiers, 0)
    split = hp.blocks_per_array(eng.graph_store.placement)
    assert split.sum() == len(hp.row_blocks)
    assert len(split) == 2
    eng.close()


def test_placement_persistence_roundtrip(tiny_ds):
    g, f = tiny_ds.reopen_stores()
    topo = StorageTopology.uniform(3)
    pl = StripePlacement(2).place(g.n_blocks, topo)
    g.attach_topology(topo, pl)  # persists <path>.topo.json
    g2, _ = tiny_ds.reopen_stores()
    loaded = g2.load_placement(topo)
    assert np.array_equal(loaded.array_of, pl.array_of)
    assert np.array_equal(loaded.local_of, pl.local_of)
    assert loaded.policy == pl.policy and loaded.n_arrays == pl.n_arrays
    roundtrip = BlockPlacement.load(g.path)
    assert np.array_equal(roundtrip.array_of, pl.array_of)


def test_read_block_charges_owning_array(tiny_ds):
    g, _ = tiny_ds.reopen_stores()
    n = min(g.n_blocks, 4)
    topo = StorageTopology.uniform(2)
    g.attach_topology(topo, StripePlacement(1).place(g.n_blocks, topo),
                      persist=False)
    for b in range(n):
        g.read_block(b)
    per_array = [st.n_reads for st in topo.array_stats]
    assert sum(per_array) == n
    if n == 4:
        assert per_array == [2, 2]
        # blocks 0,2 -> array 0 locals 0,1: the second is sequential
        assert topo.array_stats[0].n_sequential_reads == 1


# ------------------------------------------------------------------ streams
def test_planstream_charges_max_over_two_devices():
    """The per-array accounting seam: two distinct device objects fuse as
    max-of-rooflines, not a merged sum."""
    d1, d2 = NVMeModel(), NVMeModel()
    stream = PlanStream(d1)
    runs = coalesce(list(range(0, 64, 2)), 4096, 0)  # 32 random requests
    _, _, _, alone = plan_cost(runs, 4096, d1, queue_depth=8)
    _, _, _, t1 = stream.charge(runs, 4096, 8, device=d1)
    assert t1 == pytest.approx(alone)
    # same submission on a second, independent device: the stream's
    # roofline is the max over devices, so the increment is zero
    _, _, _, t2 = stream.charge(runs, 4096, 8, device=d2)
    assert t2 == pytest.approx(0.0)
    # more work on d1 raises the max again
    _, _, _, t3 = stream.charge(runs, 4096, 8, device=d1)
    assert t3 > 0
    stream.drain()
    _, _, _, t4 = stream.charge(runs, 4096, 8, device=d2)
    assert t4 == pytest.approx(alone)


def test_planstream_charge_split_atomic():
    d1, d2 = NVMeModel(), NVMeModel()
    stream = PlanStream(d1)
    r1 = coalesce(list(range(8)), 4096, 1 << 20)
    r2 = coalesce(list(range(100, 116)), 4096, 1 << 20)
    total, blocks, seq, t = stream.charge_split(
        [(d1, r1, 8), (d2, r2, 8)], 4096)
    assert blocks == 24 and total == 24 * 4096
    _, _, _, bigger = plan_cost(r2, 4096, d2, queue_depth=8)
    assert t == pytest.approx(bigger)  # max over the two, in one delta


def test_default_single_array_unchanged(tiny_ds, rng):
    """n_arrays=1 must stay byte- and time-identical to the pre-topology
    path (no placement attached at all)."""
    eng = make_engine(tiny_ds)
    assert eng.topology is None
    assert eng.graph_store.placement is None
    assert "arrays" not in eng.io_stats()
    eng.close()


# ------------------------------------------------------------------ reader
class _SlowStore:
    """Store stub: tiny blocks, controllable read latency."""

    def __init__(self, n_blocks=64, delay=0.0):
        self.block_size = 1024
        self.n_blocks = n_blocks
        self.device = NVMeModel()
        from repro.core import IOStats
        self.stats = IOStats()
        self.delay = delay
        self._io_lock = threading.Lock()
        self._last_block_read = -2
        self.placement = None
        self.topology = None

    def account_runs(self, runs, queue_depth, stream=None,
                     max_coalesce_bytes=0):
        pass

    def read_run(self, start, count):
        if self.delay:
            time.sleep(self.delay)
        return [f"blk{b}" for b in range(start, start + count)]


def test_set_queue_depth_while_runs_in_flight():
    """Resizing the in-flight budget mid-plan must not deadlock or drop
    blocks — workers re-read the depth on every wakeup."""
    store = _SlowStore(n_blocks=64, delay=0.005)
    with CoalescedReader(store, max_coalesce_bytes=2048,  # 2-block runs
                         queue_depth=1, workers=2) as rd:
        rd.submit(np.arange(48))
        got = [rd.fetch(b, timeout=10.0) for b in range(4)]
        assert got == [f"blk{b}" for b in range(4)]
        rd.set_queue_depth(8)           # widen while 20 runs still queued
        got = [rd.fetch(b, timeout=10.0) for b in range(4, 24)]
        assert got == [f"blk{b}" for b in range(4, 24)]
        rd.set_queue_depth(1)           # shrink below in-flight count
        got = [rd.fetch(b, timeout=10.0) for b in range(24, 48)]
        assert got == [f"blk{b}" for b in range(24, 48)]
        assert not rd._remaining and sum(rd._ready_runs.values()) == 0


def test_per_array_queues_and_depths(tiny_ds):
    """With a placement the reader keeps one queue per array with an
    independently resizable depth."""
    g, _ = tiny_ds.reopen_stores()
    topo = StorageTopology.uniform(2)
    g.attach_topology(topo, StripePlacement(1).place(g.n_blocks, topo),
                      persist=False)
    n = min(g.n_blocks, 6)
    with CoalescedReader(g, max_coalesce_bytes=8 << 20, queue_depth=2,
                         workers=1) as rd:
        rd.set_queue_depth(5, array=1)
        assert rd.queue_depths() == {0: 2, 1: 5}
        rd.submit(np.arange(n))
        # per-array pending queues exist for both arrays
        assert set(rd._pending) == {0, 1}
        for b in range(n):
            blk = rd.fetch(b, timeout=10.0)
            assert blk is not None and blk.block_id == b
        rd.set_queue_depth(3)  # uniform reset clears the override
        assert rd.queue_depths() == {0: 3, 1: 3}


# ------------------------------------------------------------------ writes
def test_record_write_histogram_and_batch_time():
    from repro.core import IOStats
    st = IOStats()
    st.record_write(8192, 1e-3, request_sizes=[4096, 4096])
    assert st.n_writes == 2 and st.n_requests == 2
    assert st.size_histogram[4] == 2  # two 4 KiB requests
    st.record_write(4096, 1e-4)      # default: one request of nbytes
    assert st.n_writes == 3
    assert st.size_histogram[4] == 3


def test_write_rows_node_granular_queue_depth_overlap(tiny_ds):
    _, f1 = tiny_ds.reopen_stores()
    _, f2 = tiny_ds.reopen_stores()
    nodes = np.arange(64)
    f1.write_rows_node_granular(nodes, queue_depth=1)
    f2.write_rows_node_granular(nodes, queue_depth=32)
    assert f1.stats.bytes_written == f2.stats.bytes_written
    assert f1.stats.n_writes == f2.stats.n_writes == 64
    # queue-depth overlap matches the read path's batch_time semantics
    assert f2.stats.modeled_write_time < f1.stats.modeled_write_time
    assert len(f1.stats.size_histogram) > 0


def test_write_rows_split_across_arrays(tiny_ds):
    _, f = tiny_ds.reopen_stores()
    topo = StorageTopology.uniform(2)
    f.attach_topology(topo, StripePlacement(1).place(f.n_blocks, topo),
                      persist=False)
    rpb = f.rows_per_block
    nodes = np.arange(min(4 * rpb, f.n_nodes))  # spans >= 2 arrays
    f.write_rows_node_granular(nodes)
    per_array_writes = [st.n_writes for st in topo.array_stats]
    assert sum(per_array_writes) == len(nodes)
    assert all(w > 0 for w in per_array_writes)
    # the max-over-arrays charge is cheaper than one merged device batch
    merged = f.device.batch_time(
        f.stats.bytes_written, n_random=len(nodes))
    assert f.stats.modeled_write_time <= merged
