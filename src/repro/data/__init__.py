from .synth import powerlaw_graph, rmat_graph, make_features
from .datasets import DATASETS, GraphDataset, build_dataset

__all__ = ["powerlaw_graph", "rmat_graph", "make_features",
           "DATASETS", "GraphDataset", "build_dataset"]
