"""Operation layer: hyperbatch sampler, bucket matrix, equivalence."""
import numpy as np
import pytest

from repro.core import (AgnesConfig, AgnesEngine, BlockBuffer, build_bucket,
                        sample_indices)


def make_engine(ds, hb=True, buffer_bytes=1 << 20, block_size=16384,
                fanouts=(5, 5), async_io=False, cache_rows=0):
    g, f = ds.reopen_stores()
    cfg = AgnesConfig(block_size=block_size, minibatch_size=64,
                      hyperbatch_size=8, fanouts=fanouts,
                      graph_buffer_bytes=buffer_bytes,
                      feature_buffer_bytes=buffer_bytes,
                      feature_cache_rows=cache_rows,
                      hyperbatch_enabled=hb, async_io=async_io)
    return AgnesEngine(g, f, cfg)


def test_hyperbatch_equals_per_minibatch(tiny_ds, rng):
    """The paper's Fig-12 claim: identical samples, fewer I/Os."""
    targets = [rng.choice(tiny_ds.n_nodes, 64, replace=False)
               for _ in range(6)]
    e1 = make_engine(tiny_ds, hb=True)
    e2 = make_engine(tiny_ds, hb=False)
    p1 = e1.prepare(targets, epoch=3)
    p2 = e2.prepare(targets, epoch=3)
    for a, b in zip(p1, p2):
        assert len(a.mfg.nodes) == len(b.mfg.nodes)
        for x, y in zip(a.mfg.nodes, b.mfg.nodes):
            assert np.array_equal(x, y)
        for lx, ly in zip(a.mfg.layers, b.mfg.layers):
            assert np.array_equal(lx.nbr_idx, ly.nbr_idx)
            assert np.array_equal(lx.self_idx, ly.self_idx)
        assert np.allclose(a.features, b.features)


def test_hyperbatch_fewer_ios_under_pressure(tiny_ds, rng):
    """With a tight buffer, block-major order does strictly fewer reads."""
    targets = [rng.choice(tiny_ds.n_nodes, 200, replace=False)
               for _ in range(8)]
    # buffer of only 2 blocks -> per-minibatch order must thrash
    e_hb = make_engine(tiny_ds, hb=True, buffer_bytes=2 * 16384)
    e_no = make_engine(tiny_ds, hb=False, buffer_bytes=2 * 16384)
    e_hb.prepare(targets, epoch=0)
    e_no.prepare(targets, epoch=0)
    hb_reads = e_hb.graph_store.stats.n_reads \
        + e_hb.feature_store.stats.n_reads
    no_reads = e_no.graph_store.stats.n_reads \
        + e_no.feature_store.stats.n_reads
    assert hb_reads < no_reads, (hb_reads, no_reads)


def test_sampling_deterministic_and_order_free(rng):
    nodes = rng.integers(0, 1000, 50)
    deg = rng.integers(1, 40, 50)
    a = sample_indices(nodes, deg, 10, seed=1, epoch=2, hop=1)
    b = sample_indices(nodes[::-1].copy(), deg[::-1].copy(), 10,
                       seed=1, epoch=2, hop=1)
    assert np.array_equal(a, b[::-1])
    c = sample_indices(nodes, deg, 10, seed=1, epoch=3, hop=1)
    assert not np.array_equal(a, c), "different epoch must resample"
    # positions are valid
    assert (a < deg[:, None]).all()
    small = deg <= 10
    assert ((a[small] >= 0).sum(1) == deg[small]).all()


def test_bucket_groups_complete_and_sorted(rng):
    nodes = [rng.integers(0, 100, 30) for _ in range(4)]
    blocks = [n // 10 for n in nodes]
    bck = build_bucket(nodes, blocks)
    assert np.all(np.diff(bck.row_blocks) > 0)
    # every (node, mb) pair appears exactly once in its block row
    seen = set()
    for r in range(bck.n_rows):
        for mb, ns in bck.row(r):
            for v in ns.tolist():
                assert v // 10 == bck.row_blocks[r]
                seen.add((mb, v))
    want = {(j, int(v)) for j, ns in enumerate(nodes) for v in ns}
    assert seen == want


def test_lru_buffer_pinning():
    stats_loads = []
    buf = BlockBuffer(2, name="t")
    load = lambda b: stats_loads.append(b) or b * 10  # noqa: E731
    buf.get(1, load, pin=True)
    buf.get(2, load)
    buf.get(3, load)          # evicts 2 (1 is pinned)
    assert 1 in buf and 3 in buf and 2 not in buf
    buf.unpin(1)
    buf.get(4, load)          # now 1 is evictable
    assert 1 not in buf
    assert buf.stats.buffer_misses == 4


def test_async_prefetch_equivalent_io(tiny_ds, rng):
    targets = [rng.choice(tiny_ds.n_nodes, 64, replace=False)
               for _ in range(4)]
    e_sync = make_engine(tiny_ds, async_io=False)
    e_async = make_engine(tiny_ds, async_io=True)
    p1 = e_sync.prepare(targets, epoch=1)
    p2 = e_async.prepare(targets, epoch=1)
    for a, b in zip(p1, p2):
        for x, y in zip(a.mfg.nodes, b.mfg.nodes):
            assert np.array_equal(x, y)
        assert np.allclose(a.features, b.features)
    e_async.close()


def test_feature_cache_reduces_second_epoch_io(tiny_ds, rng):
    targets = [rng.choice(tiny_ds.n_nodes, 200, replace=False)
               for _ in range(4)]
    eng = make_engine(tiny_ds, cache_rows=2000)
    eng.prepare(targets, epoch=0)
    first = eng.feature_store.stats.n_reads
    eng.prepare(targets, epoch=1)   # same working set -> cache hits
    second = eng.feature_store.stats.n_reads - first
    assert second <= first
    assert eng.feature_cache.stats.cache_hits > 0
