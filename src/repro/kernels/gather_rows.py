"""Pallas TPU kernel: block-wise feature-row gather.

The TPU analogue of AGNES's gathering stage (paper G-1/G-2): rows are
pulled HBM→VMEM in *blocks* chosen by a scalar-prefetched index vector —
the BlockSpec index_map plays the role of the object index table
``T_obj``: it maps each grid step to the (block-sized) region of the
feature table that must be resident in VMEM.

Tiling: the row dimension of the table is pre-blocked at ``rows_per_blk``
(the "feature block"); the gather processes ``idx_per_step`` output rows
per grid step with the *whole row width* resident (feature dims are
128-aligned by the caller: MXU/VPU lane width).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(idx_ref, table_ref, out_ref):
    """One output row per grid step, row block selected by idx prefetch."""
    out_ref[...] = table_ref[...]


def _gather_masked_kernel(idx_ref, valid_ref, table_ref, out_ref):
    """Masked row gather: invalid rows come out exactly zero.

    ``valid`` rides the scalar-prefetch channel next to ``idx`` — the
    DMA address (index map) only consumes ``idx``; the mask is applied
    in-kernel so a clamped placeholder address never leaks data into a
    row the caller marked invalid.
    """
    i = pl.program_id(0)
    out_ref[...] = table_ref[...] * valid_ref[i].astype(out_ref.dtype)


def gather_rows_kernel(table: jnp.ndarray, idx: jnp.ndarray, *,
                       interpret: bool = False) -> jnp.ndarray:
    """out[i] = table[idx[i]].

    table: (M, D) with D a multiple of 128 ideally; idx: (N,) int32.
    Grid is (N,); each step DMA's exactly the needed (1, D) row block —
    the index map consumes the scalar-prefetched ``idx`` so the DMA
    address is known before the step runs (double-buffered by Mosaic).
    """
    n = idx.shape[0]
    m, d = table.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, idx_ref: (idx_ref[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, idx_ref: (i, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, d), table.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), table)


def gather_rows_masked_kernel(table: jnp.ndarray, idx: jnp.ndarray,
                              valid: jnp.ndarray, *,
                              interpret: bool = False) -> jnp.ndarray:
    """out[i] = table[idx[i]] if valid[i] else 0.

    The device-resident gather primitive (GIDS-style): ``table`` is the
    HBM-pinned feature-cache mirror, ``idx`` the per-output cache slot
    (callers clamp invalid slots to a legal placeholder address), and
    ``valid`` marks which outputs are genuine cache hits — the rest are
    zeroed here and scattered in from host memory by the wrapper.
    """
    n = idx.shape[0]
    m, d = table.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, idx_ref, valid_ref:
                         (idx_ref[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, idx_ref, valid_ref:
                               (i, 0)),
    )
    return pl.pallas_call(
        _gather_masked_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, d), table.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), valid.astype(jnp.int32), table)
