"""Serving tier: concurrent prepare sessions with QoS-aware I/O admission.

The prepare stack below this module optimizes *one* bulk training job.
The production shape (ROADMAP north star; "Reducing Memory Contention
and I/O Congestion for Disk-based GNN Training" shows why it cannot be
left uncoordinated) is N concurrent tenants over one storage topology:

* ``inference`` — latency-sensitive ego-net prepares (sample a user's
  k-hop neighborhood, gather through the oracle cache, run the jitted
  forward) that must jump ahead of queued bulk I/O;
* ``training``  — the throughput tenant, the existing hyperbatch path;
* ``migration`` — the background re-placement engine, now a real tenant
  competing in the same queues, which is what makes **mid-epoch
  migration** possible at all.

Architecture (the saxml servable pattern, one level down the stack)::

    tenant session ──▶ AdmissionController.acquire(tenant, array, bytes)
                          │   priority class + token-bucket byte credit
                          │   + aging (skip bound / wall bound) so bulk
                          ▼   tenants are delayed, never starved
    per-array run issue (CoalescedReader) ──▶ per-tenant IOStats roofline

Every tenant runs its own :class:`~repro.core.agnes.AgnesEngine` over
*reopened* store handles sharing one :class:`StorageTopology` and one
:class:`~repro.core.topology.BlockPlacement` object (``move_block``
mutates in place, so a migration pass is visible to every tenant
atomically).  Per-tenant engines keep byte parity trivially exact —
admission reorders *when* a run is issued, never what is read — and
give each tenant its own fault domain: a ``PermanentIOError`` stashed
in one tenant's reader (``_error_of``) cannot poison another tenant's
fetch path, because the stash lives per reader and readers are never
shared across tenants.

Latency model: physical reads are real (memmap) but timing is modeled
(``device_model``), so a prepare's *served* latency is its own modeled
I/O plus the modeled queueing delay sampled at arrival —
:meth:`AdmissionController.queueing_delay_s` charges the in-flight runs
of every tenant plus the queued backlog admission would let ahead of
this tenant (priority policy: higher-priority + own backlog; the
``fifo`` contrast policy: everyone's backlog, which is exactly the
uncoordinated system the bench compares against).
"""
from __future__ import annotations

import dataclasses
import threading
import time

import numpy as np

from .agnes import AgnesEngine
from .block_store import FeatureBlockStore, GraphBlockStore
from .device_model import IOStats

# pseudo-array key for bulk grants (migration copy passes) that occupy
# every array's queue at once rather than one array's
ALL_ARRAYS = -1


@dataclasses.dataclass(frozen=True)
class QoSClass:
    """One tenant's admission contract.

    ``priority`` orders eligibility (lower = more urgent).  ``share`` is
    the token-bucket refill rate: every byte granted to *another* tenant
    credits this one ``share`` bytes (capped at ``burst_bytes``), so a
    backlogged low-priority tenant accumulates the right to issue its
    next run even under sustained high-priority load — the minimum-share
    guarantee.  ``aging_grants`` / ``aging_wait_s`` bound starvation
    outright: after that many foreign grants (or that much wall time)
    with demand posted, the next request is force-granted regardless of
    priority.  ``fetch_timeout_s`` is the tenant's per-fetch deadline,
    installed on its readers at enrollment (satellite: the old hardcoded
    ``fetch(timeout=30.0)`` becomes a QoS-derived knob).
    """

    name: str
    priority: int
    share: float = 0.3
    burst_bytes: int = 16 << 20
    fetch_timeout_s: float = 30.0
    aging_grants: int = 32
    aging_wait_s: float = 0.5


DEFAULT_QOS = {
    "inference": QoSClass("inference", priority=0, share=0.25,
                          burst_bytes=4 << 20, fetch_timeout_s=5.0,
                          aging_grants=16, aging_wait_s=0.25),
    "training": QoSClass("training", priority=1, share=0.65,
                         burst_bytes=32 << 20, fetch_timeout_s=30.0,
                         aging_grants=32, aging_wait_s=0.5),
    "migration": QoSClass("migration", priority=2, share=0.10,
                          burst_bytes=8 << 20, fetch_timeout_s=30.0,
                          aging_grants=64, aging_wait_s=1.0),
}


class _TenantState:
    """Controller-internal per-tenant accounting."""

    def __init__(self, qos: QoSClass):
        self.qos = qos
        self.credit = float(qos.burst_bytes)   # start with a full bucket
        self.skips = 0            # foreign grants since our last grant
        self.grants = 0
        self.forced_grants = 0    # aging overrides of the priority order
        self.granted_bytes = 0
        self.granted_runs = 0
        self.wait_s = 0.0         # wall time spent blocked in acquire
        self.stall_s = 0.0        # modeled service granted ahead of us
        self.pending: dict[int, list] = {}    # array -> [runs, bytes]
        self.inflight: dict[int, list] = {}   # array -> [runs, bytes]
        self.waiting: dict[int, int] = {}     # array -> blocked acquires

    def _demand_on(self, array: int) -> bool:
        for a in (array, ALL_ARRAYS) if array != ALL_ARRAYS else \
                list(self.pending) + list(self.waiting):
            p = self.pending.get(a)
            if p is not None and p[0] > 0:
                return True
            if self.waiting.get(a, 0) > 0:
                return True
        return False

    def summary(self) -> dict:
        return {
            "priority": self.qos.priority,
            "grants": self.grants,
            "forced_grants": self.forced_grants,
            "granted_runs": self.granted_runs,
            "granted_bytes": self.granted_bytes,
            "wait_s": round(self.wait_s, 6),
            "stall_s": round(self.stall_s, 6),
            "credit_bytes": int(self.credit),
            "pending_runs": sum(p[0] for p in self.pending.values()),
            "inflight_runs": sum(f[0] for f in self.inflight.values()),
        }


class AdmissionController:
    """Priority + token-bucket admission over shared per-array queues.

    One controller per :class:`ServingTier`; every tenant reader routes
    each run issue through :meth:`acquire` (see
    ``CoalescedReader.bind_admission``).  ``policy="priority"`` is the
    QoS path; ``policy="fifo"`` grants everything immediately and models
    queueing delay behind the *full* backlog — the uncoordinated
    baseline the bench contrasts against.
    """

    def __init__(self, devices, policy: str = "priority"):
        if policy not in ("priority", "fifo"):
            raise ValueError(f"unknown admission policy {policy!r}")
        self.policy = policy
        self._devices = list(devices)
        self._cv = threading.Condition()
        self._tenants: dict[str, _TenantState] = {}
        self._exclusive_holder: str | None = None
        self._n_submitting = 0
        self._granted_service_s = 0.0   # modeled service of all grants
        # unified telemetry (core/telemetry.py): wait histograms +
        # blocked-acquire spans; shared with the tier's primary engine
        self.telemetry = None
        self._excl_t0 = 0.0

    # ------------------------------------------------------------ enrollment
    def register(self, tenant: str, qos: QoSClass) -> _TenantState:
        with self._cv:
            st = self._tenants.get(tenant)
            if st is None:
                st = self._tenants[tenant] = _TenantState(qos)
            return st

    # ------------------------------------------------------------ demand
    def note_submit(self, tenant: str, per_array: dict) -> None:
        """Register a submitted plan's per-array backlog *before* its
        runs start issuing: ``{array: (n_runs, n_bytes)}``.  Eligibility
        of lower-priority tenants and the queueing-delay model both read
        this backlog."""
        with self._cv:
            st = self._tenants[tenant]
            for a, (runs, nbytes) in per_array.items():
                p = st.pending.setdefault(int(a), [0, 0])
                p[0] += int(runs)
                p[1] += int(nbytes)
            self._cv.notify_all()

    def cancel_pending(self, tenant: str) -> None:
        """Drop a tenant's queued (not yet granted) backlog — the
        reader's ``reset()`` hook.  Granted in-flight runs complete
        normally through :meth:`complete`."""
        with self._cv:
            st = self._tenants.get(tenant)
            if st is not None:
                st.pending.clear()
            self._cv.notify_all()

    # ------------------------------------------------------------ grants
    def acquire(self, tenant: str, array: int | None, nbytes: int) -> float:
        """Block until ``tenant`` may issue one run of ``nbytes`` on
        ``array`` (``None`` = a bulk grant on every array).  Returns the
        wall time spent waiting.  Never blocks forever: the QoS class's
        aging bounds (grant count and wall clock) force a grant past
        sustained higher-priority load — except while another tenant
        holds the exclusive gate, which is itself bounded (a migration
        pass runs synchronously and releases it)."""
        a = ALL_ARRAYS if array is None else int(array)
        tel = self.telemetry
        t_tr = time.perf_counter() if tel is not None else 0.0
        with self._cv:
            st = self._tenants[tenant]
            st.waiting[a] = st.waiting.get(a, 0) + 1
            t0 = time.monotonic()
            svc0 = self._granted_service_s
            forced = False
            try:
                while not self._eligible_locked(tenant, st, a, nbytes):
                    if self._exclusive_holder is None and (
                            st.skips >= st.qos.aging_grants
                            or time.monotonic() - t0 >= st.qos.aging_wait_s):
                        forced = True
                        break
                    self._cv.wait(timeout=max(
                        st.qos.aging_wait_s - (time.monotonic() - t0),
                        1e-3))
            finally:
                st.waiting[a] -= 1
            waited = time.monotonic() - t0
            st.wait_s += waited
            st.stall_s += self._granted_service_s - svc0
            if forced:
                st.forced_grants += 1
            self._grant_locked(st, a, nbytes)
            self._cv.notify_all()
            if tel is not None:
                tel.metrics.histogram(f"admission.{tenant}.wait_s").observe(
                    waited)
                if forced:
                    tel.metrics.counter(
                        f"admission.{tenant}.forced_grants").inc()
                tr = tel.trace
                if tr is not None and (forced or waited > 1e-4):
                    # only blocked acquires make the timeline — unblocked
                    # grants would bury the trace in zero-width spans
                    tr.complete("wait", "admission",
                                f"admission:{tenant}", t_tr,
                                t_tr + waited,
                                args={"array": ("all" if a == ALL_ARRAYS
                                                else a),
                                      "bytes": int(nbytes),
                                      "forced": forced})
            return waited

    def try_acquire(self, tenant: str, array: int | None,
                    nbytes: int) -> bool:
        """Non-blocking :meth:`acquire` (deterministic unit testing):
        grant iff eligible right now (or the skip-count aging bound has
        been reached)."""
        a = ALL_ARRAYS if array is None else int(array)
        with self._cv:
            st = self._tenants[tenant]
            aged = (self._exclusive_holder is None
                    and st.skips >= st.qos.aging_grants)
            if not self._eligible_locked(tenant, st, a, nbytes) and not aged:
                return False
            if aged and not self._eligible_locked(tenant, st, a, nbytes):
                st.forced_grants += 1
            self._grant_locked(st, a, nbytes)
            self._cv.notify_all()
            return True

    def complete(self, tenant: str, array: int | None, nbytes: int) -> None:
        a = ALL_ARRAYS if array is None else int(array)
        with self._cv:
            st = self._tenants.get(tenant)
            if st is not None:
                fl = st.inflight.get(a)
                if fl is not None:
                    fl[0] = max(fl[0] - 1, 0)
                    fl[1] = max(fl[1] - int(nbytes), 0)
            self._cv.notify_all()

    def _eligible_locked(self, tenant: str, st: _TenantState, array: int,
                         nbytes: int) -> bool:
        if self._exclusive_holder is not None:
            return tenant == self._exclusive_holder
        if self.policy == "fifo":
            return True
        higher = any(
            u.qos.priority < st.qos.priority and u._demand_on(array)
            for name, u in self._tenants.items() if name != tenant)
        if not higher:
            return True               # work-conserving: nobody urgent waits
        return st.credit >= nbytes    # minimum-share token bucket

    def _grant_locked(self, st: _TenantState, array: int,
                      nbytes: int) -> None:
        nbytes = int(nbytes)
        st.grants += 1
        st.granted_runs += 1
        st.granted_bytes += nbytes
        st.skips = 0
        st.credit = max(st.credit - nbytes, -float(st.qos.burst_bytes))
        self._granted_service_s += self._service_s(array, 1, nbytes)
        for u in self._tenants.values():
            if u is st or not any(
                    p[0] > 0 for p in u.pending.values()) \
                    and not any(w > 0 for w in u.waiting.values()):
                continue
            u.credit = min(u.credit + u.qos.share * nbytes,
                           float(u.qos.burst_bytes))
            u.skips += 1
        # pending -> inflight
        p = st.pending.get(array)
        if p is not None and p[0] > 0:
            p[0] -= 1
            p[1] = max(p[1] - nbytes, 0)
        fl = st.inflight.setdefault(array, [0, 0])
        fl[0] += 1
        fl[1] += nbytes

    # ------------------------------------------------------------ delay model
    def _service_s(self, array: int, runs: int, nbytes: int) -> float:
        if runs <= 0 and nbytes <= 0:
            return 0.0
        dev = self._devices[0] if array == ALL_ARRAYS else \
            self._devices[min(array, len(self._devices) - 1)]
        return dev.batch_time(nbytes, n_random=runs)

    def queueing_delay_s(self, tenant: str) -> float:
        """Modeled delay a request arriving *now* waits before its own
        first run issues: the max over arrays of the service of (a)
        every tenant's in-flight runs plus (b) the queued backlog this
        policy would grant ahead of ``tenant`` — higher-priority + its
        own backlog under ``priority``, everyone's under ``fifo``."""
        with self._cv:
            st = self._tenants[tenant]
            delay = 0.0
            for a in range(len(self._devices)):
                runs = nbytes = 0
                for name, u in self._tenants.items():
                    for key in (a, ALL_ARRAYS):
                        fl = u.inflight.get(key)
                        if fl is not None:
                            runs += fl[0]
                            nbytes += fl[1]
                    ahead = (self.policy == "fifo" or name == tenant
                             or u.qos.priority < st.qos.priority)
                    if ahead:
                        for key in (a, ALL_ARRAYS):
                            p = u.pending.get(key)
                            if p is not None:
                                runs += p[0]
                                nbytes += p[1]
                delay = max(delay, self._service_s(a, runs, nbytes))
            return delay

    # ------------------------------------------------------------ exclusive
    def submit_begin(self, tenant: str) -> None:
        """Plan-submission gate: blocks while the exclusive (placement
        swap) gate is held by someone else, so no plan is split against
        a mapping that is mid-swap."""
        with self._cv:
            while (self._exclusive_holder is not None
                   and tenant != self._exclusive_holder):
                self._cv.wait(timeout=0.05)
            self._n_submitting += 1

    def submit_end(self, tenant: str) -> None:
        with self._cv:
            self._n_submitting = max(self._n_submitting - 1, 0)
            self._cv.notify_all()

    def queue_slack(self) -> bool:
        """True when no tenant has queued, in-flight or mid-submit work."""
        with self._cv:
            return self._slack_locked()

    def _slack_locked(self) -> bool:
        if self._n_submitting:
            return False
        for u in self._tenants.values():
            if any(p[0] > 0 for p in u.pending.values()):
                return False
            if any(f[0] > 0 for f in u.inflight.values()):
                return False
        return True

    def try_exclusive(self, holder: str) -> bool:
        """Claim the exclusive gate iff the queues have slack *right
        now* — the mid-epoch migration precondition.  Non-blocking by
        design: migration must only run in slack, never create it."""
        with self._cv:
            if self._exclusive_holder is not None or not self._slack_locked():
                return False
            self._exclusive_holder = holder
            self._excl_t0 = time.perf_counter()
            return True

    def end_exclusive(self) -> None:
        with self._cv:
            holder, self._exclusive_holder = self._exclusive_holder, None
            self._cv.notify_all()
            tel = self.telemetry
            if tel is not None and holder is not None:
                tr = tel.trace
                if tr is not None:
                    tr.complete("exclusive", "serving", "migration",
                                self._excl_t0, args={"holder": holder})

    def summary(self) -> dict:
        with self._cv:
            return {
                "policy": self.policy,
                "tenants": {name: st.summary()
                            for name, st in self._tenants.items()},
            }


@dataclasses.dataclass
class ServedPrepare:
    """One tenant prepare, with its served-latency decomposition."""

    prepared: list                # PreparedMinibatch list
    latency_s: float              # queue_delay_s + io_s
    queue_delay_s: float          # modeled admission delay at arrival
    io_s: float                   # the session's own modeled I/O delta


class ServingTier:
    """N tenants over one engine's storage topology.

    The constructor enrolls ``engine`` as the ``training`` tenant (its
    readers route through the shared :class:`AdmissionController`);
    :meth:`open_tenant` reopens the on-disk stores against the *same*
    topology + placement objects and enrolls a new engine per tenant.
    :meth:`prepare` serves one session and records its modeled latency
    in the tenant's reservoir (p50/p99 via :meth:`latency_summary`).

    With the engine's ``online_placement`` on, the migration engines are
    re-registered as the lowest-priority tenant and
    :meth:`maybe_migrate` runs a **mid-epoch** pass whenever the queues
    have slack — followed by a mid-epoch oracle refresh
    (``AgnesEngine.refresh_cache_oracle``) on every enrolled engine.
    """

    def __init__(self, engine: AgnesEngine, qos: dict | None = None,
                 policy: str = "priority", tenant: str = "training"):
        self.engine = engine
        self.qos = dict(DEFAULT_QOS)
        if qos:
            self.qos.update(qos)
        if engine.topology is not None:
            devices = list(engine.topology.devices)
        else:
            devices = [engine.graph_store.device]
        self.controller = AdmissionController(devices, policy=policy)
        # one Telemetry bundle for the whole tier: tenant engines share
        # the primary engine's, so admission waits, per-tenant prepare
        # spans and every tenant's I/O land in one trace
        self.controller.telemetry = getattr(engine, "telemetry", None)
        self._handles: dict[str, dict] = {}
        self._lat_lock = threading.Lock()
        self.migration_attempts = 0
        self.migrations_blocked = 0
        self.migrations_run = 0
        self._enroll(tenant, engine, own=False)
        if engine._migrations:
            self.register_migration()

    # ------------------------------------------------------------ tenants
    def _qos_of(self, name: str) -> QoSClass:
        q = self.qos.get(name)
        if q is None:
            q = dataclasses.replace(self.qos["training"], name=name)
            self.qos[name] = q
        return q

    def _enroll(self, name: str, eng: AgnesEngine, own: bool) -> None:
        q = self._qos_of(name)
        self.controller.register(name, q)
        for rd in (eng._g_prefetch, eng._f_prefetch):
            if rd is not None and hasattr(rd, "bind_admission"):
                rd.bind_admission(self.controller, name,
                                  fetch_timeout_s=q.fetch_timeout_s)
        if hasattr(eng, "set_telemetry") and \
                getattr(self.engine, "telemetry", None) is not None:
            # after bind_admission, so the readers' telemetry tenant
            # label matches their admission tenant
            eng.set_telemetry(self.engine.telemetry, tenant=name)
        self._handles[name] = {"engine": eng, "own": own, "latencies": []}

    def open_tenant(self, name: str, qos: QoSClass | None = None,
                    **config_overrides) -> AgnesEngine:
        """Enroll a new tenant: reopen the stores over the shared
        topology/placement and build it an engine.

        ``config_overrides`` patch the primary engine's
        :class:`AgnesConfig` (e.g. ``fanouts=(8, 8)`` for a 2-hop
        ego-net path).  Tenants never drive placement themselves
        (``online_placement`` off) and default to a clean fault domain
        (``fault_schedule=None``) — pass either explicitly to override.
        """
        if name in self._handles:
            return self._handles[name]["engine"]
        if qos is not None:
            self.qos[name] = qos
        base = self.engine
        # trace=False: the tenant engine's own recorder would be dead
        # weight — _enroll immediately shares the primary's bundle
        safe = {"online_placement": False, "fault_schedule": None,
                "record_feature_trace": False, "trace": False}
        safe.update(config_overrides)
        cfg = dataclasses.replace(base.config, **safe)
        g = GraphBlockStore.open(base.graph_store.path,
                                 base.graph_store.device)
        f = FeatureBlockStore.open(base.feature_store.path,
                                   base.feature_store.device)
        if base.topology is not None:
            # the placement *objects* are shared: move_block mutates the
            # arrays in place, so a migration pass lands on every tenant
            g.attach_topology(base.topology, base.graph_store.placement,
                              persist=False)
            f.attach_topology(base.topology, base.feature_store.placement,
                              persist=False)
        eng = AgnesEngine(g, f, cfg, topology=base.topology)
        self._enroll(name, eng, own=True)
        return eng

    def engine_of(self, name: str) -> AgnesEngine:
        return self._handles[name]["engine"]

    @property
    def tenants(self) -> list[str]:
        return list(self._handles)

    # ------------------------------------------------------------ serve
    def prepare(self, tenant: str, targets_per_mb: list,
                epoch: int = 0) -> ServedPrepare:
        """Serve one prepare session for ``tenant``.

        Latency = the modeled queueing delay sampled at arrival (the
        backlog admission puts ahead of this tenant) + the session's own
        modeled I/O delta.  Bytes are unaffected by admission — only
        issue *order* changes — so per-tenant byte parity against a solo
        run holds exactly (``tests/test_serving.py``).
        """
        h = self._handles[tenant]
        eng = h["engine"]
        tel = getattr(self.engine, "telemetry", None)
        t0 = time.perf_counter() if tel is not None else 0.0
        queue_delay = self.controller.queueing_delay_s(tenant)
        io0 = _modeled_io_s(eng)
        prepared = eng.open_session(targets_per_mb, epoch=epoch,
                                    tenant=tenant).run()
        io_s = _modeled_io_s(eng) - io0
        served = ServedPrepare(prepared, queue_delay + io_s,
                               queue_delay, io_s)
        with self._lat_lock:
            h["latencies"].append(served.latency_s)
        if tel is not None:
            tel.metrics.histogram(f"serving.{tenant}.latency_s").observe(
                served.latency_s)
            tel.metrics.counter(f"serving.{tenant}.requests").inc()
            tr = tel.trace
            if tr is not None:
                tr.complete(f"serve:{tenant}", "serving",
                            f"serving:{tenant}", t0,
                            args={"latency_s": round(served.latency_s, 9),
                                  "queue_delay_s": round(queue_delay, 9),
                                  "io_s": round(io_s, 9),
                                  "epoch": epoch})
        return served

    def latency_summary(self, tenant: str, since: int = 0) -> dict:
        """Quantiles over the tenant's served latencies; ``since`` slices
        off already-reported requests (per-epoch windows)."""
        with self._lat_lock:
            lat = np.asarray(self._handles[tenant]["latencies"][since:],
                             dtype=np.float64)
        if lat.size == 0:
            return {"n": 0, "p50_s": 0.0, "p99_s": 0.0, "mean_s": 0.0}
        return {
            "n": int(lat.size),
            "p50_s": float(np.quantile(lat, 0.5)),
            "p99_s": float(np.quantile(lat, 0.99)),
            "mean_s": float(lat.mean()),
        }

    def tenant_roofline(self, tenant: str) -> dict:
        """Per-tenant roofline: the tenant engine's merged
        :class:`IOStats` with the admission counters folded in."""
        eng = self._handles[tenant]["engine"]
        merged = IOStats().merge(eng.graph_store.stats) \
                          .merge(eng.feature_store.stats)
        adm = self.controller.summary()["tenants"].get(tenant, {})
        merged.note_admission_wait(adm.get("stall_s", 0.0),
                                   forced=0)
        merged.admission_forced_grants = adm.get("forced_grants", 0)
        return {"io": merged.summary(), "admission": adm,
                "latency": self.latency_summary(tenant)}

    def summary(self) -> dict:
        return {
            "policy": self.controller.policy,
            "tenants": {name: self.tenant_roofline(name)
                        for name in self._handles},
            "migration": {"attempts": self.migration_attempts,
                          "blocked": self.migrations_blocked,
                          "run": self.migrations_run},
        }

    def update_metrics(self):
        """Fold the tier's summary dicts (per-tenant latency quantiles,
        admission state) into the shared metrics registry as
        ``serving.*`` / ``admission.*`` gauges.  Returns the registry,
        or ``None`` when the primary engine carries no telemetry."""
        tel = getattr(self.engine, "telemetry", None)
        if tel is None:
            return None
        m = tel.metrics
        for name in self._handles:
            m.set_gauges(f"serving.{name}", self.latency_summary(name))
        # "admission.state." prefix: the per-tenant summary dict reuses
        # key names (wait_s) that live as histograms under "admission."
        m.set_gauges("admission.state", self.controller.summary()["tenants"])
        return m

    def diagnose(self, thresholds=None):
        """Storage doctor over the whole tier.

        Refreshes the tier gauges (:meth:`update_metrics`), then runs
        :func:`repro.core.diagnosis.diagnose` on the primary engine's
        snapshot + shared trace with every tenant's roofline attached —
        so a tenant starving behind the admission queues surfaces as an
        ``admission-throttled`` finding naming that tenant, ranked
        against the device-level causes.
        """
        from .diagnosis import diagnose
        self.update_metrics()
        eng = self.engine
        snap = eng.metrics_snapshot(refresh=True)
        tel = eng.telemetry
        tr = tel.trace if tel is not None else None
        dev = eng.graph_store.device
        return diagnose(
            snap, events=tr.events() if tr is not None else None,
            tenant_rooflines={n: self.tenant_roofline(n)
                              for n in self._handles},
            thresholds=thresholds,
            default_device={"bandwidth": dev.array_bandwidth,
                            "latency": dev.latency,
                            "queue_depth": dev.queue_depth})

    # ------------------------------------------------------------ migration
    def register_migration(self) -> None:
        """Re-register the primary engine's migration engines as the
        lowest-priority tenant: their copy grants flow through the same
        admission queues (bulk ``ALL_ARRAYS`` grants), so migration
        competes rather than preempts."""
        self.controller.register("migration", self._qos_of("migration"))
        for _name, mig, _tracker in self.engine._migrations:
            mig.bind_admission(self.controller, "migration")

    def maybe_migrate(self) -> dict | None:
        """Mid-epoch migration: run one budgeted re-placement pass *iff*
        the queues have slack right now, then refresh every tenant's
        oracle schedule from the remaining trace.

        Returns the per-store migration summaries, or ``None`` when the
        pass was skipped (no slack, a session open, or no migration
        engines configured).  The slack check is the whole point — the
        acceptance drill asserts migration proceeds *only* in queue
        slack, never under a tenant's open I/O plan.
        """
        eng = self.engine
        if not eng._migrations:
            return None
        self.migration_attempts += 1
        if not self.controller.try_exclusive("migration"):
            self.migrations_blocked += 1
            return None
        try:
            for h in self._handles.values():
                e = h["engine"]
                if e._in_session or not all(
                        getattr(rd, "idle", True)
                        for rd in (e._g_prefetch, e._f_prefetch)
                        if rd is not None):
                    self.migrations_blocked += 1
                    return None
            reports = {}
            for name, mig, tracker in eng._migrations:
                mig.queue_depth = eng.io_queue_depths()
                reports[name] = mig.run(tracker.hotness()).summary()
        finally:
            self.controller.end_exclusive()
        self.migrations_run += 1
        # mid-epoch oracle refresh (ROADMAP PR-6 follow-on): rebuild each
        # installed Belady schedule from the steps not yet consumed
        refreshed = {}
        for name, h in self._handles.items():
            sched = h["engine"].refresh_cache_oracle()
            if sched is not None:
                refreshed[name] = sched.n_steps
        if refreshed:
            reports["oracle_refresh_steps"] = refreshed
        return reports

    # ------------------------------------------------------------ lifecycle
    def close(self) -> None:
        """Close every tenant engine this tier opened (the primary
        engine stays the caller's to close)."""
        for h in self._handles.values():
            if h["own"]:
                h["engine"].close()


class InferenceServer:
    """Low-latency embedding facade over a :class:`ServingTier`.

    ``embed(node_ids)`` = one ego-net prepare through the ``inference``
    tenant (k-hop sample + oracle-cache gather) followed by the jitted
    GNN forward — the path a production embedding service runs per user
    request.  Model parameters come from a
    :class:`~repro.gnn.training.GNNTrainer` (the co-trained model) or
    explicit ``params``/``arch``/``backend``.

    The tenant's engine is opened with ``fanouts`` matching the model's
    layer count (an L-layer GNN consumes an L-hop MFG).
    """

    def __init__(self, tier: ServingTier, trainer=None, *, params=None,
                 arch: str = "gcn", backend: str = "jnp", labels=None,
                 fanouts=None, tenant: str = "inference",
                 **tenant_overrides):
        if trainer is not None:
            params = trainer.params
            arch = trainer.arch
            backend = trainer.backend
            if labels is None:
                labels = getattr(trainer, "labels", None)
            if fanouts is None:
                fanouts = tuple([8] * trainer.n_layers)
        if params is None:
            raise ValueError("need a trainer or explicit params")
        self.tier = tier
        self.tenant = tenant
        self.params = params
        self.arch = arch
        self.backend = backend
        if fanouts is None:
            fanouts = tier.engine.config.fanouts
        self.engine = tier.open_tenant(tenant, fanouts=tuple(fanouts),
                                       **tenant_overrides)
        n_nodes = self.engine.graph_store.n_nodes
        self._labels = (np.asarray(labels) if labels is not None
                        else np.zeros(n_nodes, dtype=np.int32))
        self._fwd = None   # jitted forward, built on first embed
        self._n_requests = 0

    def embed(self, node_ids, epoch: int | None = None) -> np.ndarray:
        """Embeddings (model outputs) for ``node_ids``, row-aligned with
        the input order.  ``epoch`` seeds the neighbor sampler — fix it
        for reproducible sampling, or leave ``None`` for a fresh
        per-request seed."""
        import jax

        from ..gnn.models import gnn_apply, pad_mfg

        if self._fwd is None:
            self._fwd = jax.jit(gnn_apply,
                                static_argnames=("arch", "backend"))
        nodes = np.asarray(node_ids, dtype=np.int64).ravel()
        if epoch is None:
            epoch = 1_000_000 + self._n_requests
        self._n_requests += 1
        served = self.tier.prepare(self.tenant, [nodes], epoch=epoch)
        p = served.prepared[0]
        mfg = pad_mfg(p.mfg, p.features, self._labels)
        out = np.asarray(self._fwd(self.params, mfg, self.arch,
                                   self.backend))
        # session frontiers are sorted-unique; map back to input order
        uniq = p.targets
        return out[:len(uniq)][np.searchsorted(uniq, nodes)]

    def latency_summary(self, since: int = 0) -> dict:
        return self.tier.latency_summary(self.tenant, since=since)


def _modeled_io_s(eng: AgnesEngine) -> float:
    return (eng.graph_store.stats.modeled_io_time
            + eng.feature_store.stats.modeled_io_time)
