"""Unified telemetry (core/telemetry.py): trace ring, metrics, Fig.2.

Covers:

* span nesting + event ordering in the ring, instants, wraparound with
  an *exact* dropped-event count, and a multi-writer hammer (plus a
  concurrent ``events()`` reader) asserting the emit counter is exact;
* the metrics registry — atomic snapshot, counter/histogram-aware
  delta, Prometheus text rendering, kind-conflict errors, nested
  summary folding via ``set_gauges``, and exact concurrent increments;
* satellite: ``IOStats.merge``/``summary`` completeness, field-driven —
  adding a counter to the dataclass without carrying it through both is
  a test failure, not a silently dropped stat;
* Chrome trace-event export: the *file* written by ``export_chrome``
  round-trips through ``validate_chrome_trace`` cleanly and carries the
  per-array / per-tenant tracks;
* Fig.2 fidelity: on a traced pipelined epoch the trace-derived
  prepare/train bars agree with ``OverlapReport`` wall times;
* the nullability contract (``trace=False`` ⇒ ``telemetry.trace is
  None`` while metrics stay live) and concurrent serving tenants
  tracing onto separate tracks.
"""
import dataclasses
import json
import threading
from collections import Counter

import numpy as np
import pytest

from repro.core import (AgnesConfig, AgnesEngine, IOStats, MetricsRegistry,
                        ServingTier, TraceRecorder, fig2_breakdown,
                        validate_chrome_trace)
from repro.core.device_model import SUMMARY_FIELD_MAP
from repro.gnn import GNNTrainer, PipelinedExecutor

CFG = dict(block_size=16384, minibatch_size=64, hyperbatch_size=2,
           fanouts=(4, 4), graph_buffer_bytes=1 << 20,
           feature_buffer_bytes=1 << 20, async_io=False)


def _engine(tiny_ds, **over):
    g, f = tiny_ds.reopen_stores()
    return AgnesEngine(g, f, AgnesConfig(**dict(CFG, **over)))


# ------------------------------------------------------------------ recorder
def test_span_nesting_and_order():
    rec = TraceRecorder(capacity=64)
    with rec.span("outer", "cat", "t0"):
        with rec.span("inner", "cat", "t0", args={"k": 1}):
            pass
        rec.instant("mark", "cat", "t0")
    evs = rec.events()
    assert [e[1] for e in evs] == ["inner", "mark", "outer"]  # close order
    inner, mark, outer = evs
    assert inner[0] == "X" and outer[0] == "X" and mark[0] == "i"
    # proper nesting on the shared timeline: inner ⊆ outer
    assert outer[4] <= inner[4]
    assert inner[4] + inner[5] <= outer[4] + outer[5] + 1e-9
    assert inner[6] == {"k": 1}
    assert rec.n_emitted == 3 and rec.n_dropped == 0


def test_ring_wraparound_exact_drop_count():
    rec = TraceRecorder(capacity=16)
    for i in range(50):
        rec.instant(f"e{i}", "c", "t")
    assert rec.n_emitted == 50
    assert rec.n_dropped == 34            # exactly 50 - 16, oldest first
    assert rec.n_retained == 16
    assert [e[1] for e in rec.events()] == [f"e{i}" for i in range(34, 50)]
    assert rec.to_chrome()["otherData"]["dropped_events"] == 34
    rec.clear()
    assert rec.n_emitted == 0 and rec.events() == []


def test_trace_thread_safety_hammer():
    rec = TraceRecorder(capacity=8192)
    stop = threading.Event()
    reader_sane = []

    def writer(tag):
        for i in range(500):
            rec.instant(f"{tag}:{i}", "hammer", f"track:{tag}")

    def reader():
        while not stop.is_set():
            evs = rec.events()          # consistent copy mid-write
            reader_sane.append(len(evs) <= 8192
                               and all(e is not None for e in evs))

    rt = threading.Thread(target=reader)
    ws = [threading.Thread(target=writer, args=(t,)) for t in range(8)]
    rt.start()
    for w in ws:
        w.start()
    for w in ws:
        w.join()
    stop.set()
    rt.join()
    assert rec.n_emitted == 4000 and rec.n_dropped == 0
    assert reader_sane and all(reader_sane)
    # small ring under the same load: the drop count stays exact
    rec2 = TraceRecorder(capacity=64)
    ws = [threading.Thread(
        target=lambda t=t: [rec2.instant(f"{t}:{i}", "h", "t")
                            for i in range(500)]) for t in range(8)]
    for w in ws:
        w.start()
    for w in ws:
        w.join()
    assert rec2.n_emitted == 4000
    assert rec2.n_dropped == 4000 - 64
    assert len(rec2.events()) == 64


# ------------------------------------------------------------------- metrics
def test_metrics_snapshot_delta_and_prometheus():
    reg = MetricsRegistry()
    c = reg.counter("io.reads", help="total reads")
    g = reg.gauge("queue.depth")
    h = reg.histogram("latency_s", buckets=(0.001, 0.01, 0.1))
    c.inc(3)
    g.set(7)
    h.observe(0.005)
    h.observe(5.0)                        # overflow bucket
    s0 = reg.snapshot()
    assert s0["io.reads"] == 3 and s0["queue.depth"] == 7
    assert s0["latency_s"] == {"count": 2, "sum": 5.005,
                               "buckets": [0, 1, 0, 1]}
    c.inc(2)
    g.set(9)
    h.observe(0.0001)
    d = reg.delta(s0)
    assert d["io.reads"] == 2             # counters difference
    assert d["queue.depth"] == 9          # gauges pass through
    assert d["latency_s"]["count"] == 1
    assert d["latency_s"]["buckets"] == [1, 0, 0, 0]
    text = reg.render_prometheus()
    assert "# HELP io_reads total reads" in text
    assert "# TYPE io_reads counter" in text
    assert "io_reads 5" in text
    assert '# TYPE latency_s histogram' in text
    assert 'latency_s_bucket{le="+Inf"} 3' in text
    assert "latency_s_count 3" in text
    with pytest.raises(TypeError):
        reg.gauge("io.reads")             # kind conflict fails loudly


def test_prometheus_full_exposition():
    """The full text-format contract: HELP before TYPE for *every*
    family (help-less included), escaping, name sanitization, and the
    complete cumulative histogram series."""
    reg = MetricsRegistry()
    reg.counter("io.reads", help="line1\nline2\\tail").inc(5)
    reg.counter("9starts.with-digit")
    reg.gauge("no.help.gauge").set(2.5)
    h = reg.histogram("lat_s", buckets=(0.001, 0.01, 0.1))
    for v in (0.0005, 0.005, 0.005, 0.05, 5.0):
        h.observe(v)
    text = reg.render_prometheus()
    assert text.endswith("\n")
    lines = text.splitlines()
    for fam, kind in (("io_reads", "counter"),
                      ("_9starts_with_digit", "counter"),
                      ("no_help_gauge", "gauge"),
                      ("lat_s", "histogram")):
        ti = lines.index(f"# TYPE {fam} {kind}")
        assert lines[ti - 1].startswith(f"# HELP {fam}")
    # newline and backslash escaped per the exposition format; an empty
    # help string renders as the bare header, no trailing space
    assert "# HELP io_reads line1\\nline2\\\\tail" in lines
    assert "# HELP no_help_gauge" in lines
    # the histogram series is cumulative, ends at +Inf == _count
    bi = lines.index('lat_s_bucket{le="0.001"} 1')
    assert lines[bi:bi + 5] == [
        'lat_s_bucket{le="0.001"} 1',
        'lat_s_bucket{le="0.01"} 3',
        'lat_s_bucket{le="0.1"} 4',
        'lat_s_bucket{le="+Inf"} 5',
        'lat_s_sum 5.0605',
    ]
    assert "lat_s_count 5" in lines
    assert "io_reads 5" in lines and "no_help_gauge 2.5" in lines
    # families come out sorted by registry name
    types = [ln.split()[2] for ln in lines if ln.startswith("# TYPE")]
    assert types == sorted(types, key=lambda f: f.lstrip("_"))


def test_metrics_concurrent_increments_exact():
    reg = MetricsRegistry()
    c = reg.counter("hits")
    h = reg.histogram("obs_s")

    def work():
        for _ in range(1000):
            c.inc()
            h.observe(0.5)

    ts = [threading.Thread(target=work) for _ in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    snap = reg.snapshot()
    assert snap["hits"] == 4000
    assert snap["obs_s"]["count"] == 4000


def test_set_gauges_folds_nested_summaries():
    reg = MetricsRegistry()
    reg.set_gauges("io", {"graph": {"bytes": 10, "ok": True},
                          "arr": [1.5, 2.5], "skip": "a-string"})
    s = reg.snapshot()
    assert s["io.graph.bytes"] == 10
    assert s["io.graph.ok"] == 1
    assert s["io.arr.0"] == 1.5 and s["io.arr.1"] == 2.5
    assert "io.skip" not in s


# ---------------------------------------------------- IOStats completeness
def test_iostats_merge_and_summary_cover_every_field():
    """Field-driven: a counter added to IOStats but dropped by merge()
    or summary() fails here, instead of silently zeroing stats."""
    a, b = IOStats(), IOStats()
    for i, f in enumerate(dataclasses.fields(IOStats), start=1):
        if isinstance(getattr(a, f.name), Counter):
            getattr(a, f.name).update({i: i})
            getattr(b, f.name).update({i: 2 * i})
        else:
            setattr(a, f.name, i)
            setattr(b, f.name, 2 * i)
    a.merge(b)
    for i, f in enumerate(dataclasses.fields(IOStats), start=1):
        v = getattr(a, f.name)
        if isinstance(v, Counter):
            assert v == Counter({i: 3 * i}), f"merge() dropped {f.name}"
        else:
            assert v == 3 * i, f"merge() dropped {f.name}"
    summ = a.summary()
    for f in dataclasses.fields(IOStats):
        key = SUMMARY_FIELD_MAP.get(f.name, f.name)
        assert key in summ, f"summary() missing {f.name} (as {key})"


# ------------------------------------------------------------ chrome export
def test_chrome_validator_catches_violations():
    assert validate_chrome_trace({}) == ["traceEvents missing or not a list"]
    bad = {"traceEvents": [
        {"name": "x", "ph": "X", "pid": 1, "tid": 1, "ts": -1.0, "dur": 1.0},
        {"name": "y", "ph": "i", "pid": 1, "tid": 1, "ts": 0.0},
    ]}
    errs = validate_chrome_trace(bad)
    assert any("bad ts" in e for e in errs)
    assert any("instant scope must be t/p/g" in e for e in errs)
    assert any("thread_name" in e for e in errs)


def test_chrome_validator_rejects_handbuilt_bad_payload():
    """One violation per event, hand-built: every check the validator
    documents fires on a payload crafted to trip exactly it."""
    meta = [{"name": "thread_name", "ph": "M", "pid": 1, "tid": 1,
             "args": {"name": "t0"}}]
    bad = {"displayTimeUnit": "s", "traceEvents": meta + [
        {"name": "a", "ph": "i", "pid": 1, "tid": 1, "ts": 1.0, "s": "x"},
        {"name": "b", "ph": "i", "pid": 1, "tid": 1, "ts": 1.0, "s": "t",
         "dur": 2.0},
        {"name": "c", "ph": "X", "pid": 1, "tid": 1, "ts": 1.0, "dur": -1.0},
        {"name": "d", "ph": "X", "pid": 1, "tid": 1, "ts": 1.0, "dur": 1.0,
         "args": [1, 2]},
        {"name": "e", "ph": "Q", "pid": 1, "tid": 1, "ts": 1.0},
        {"name": "f", "ph": "X", "pid": "one", "tid": 1, "ts": 1.0,
         "dur": 0},
        {"ph": "X", "pid": 1, "tid": 1, "ts": 1.0, "dur": 0},
    ]}
    errs = validate_chrome_trace(bad)
    assert any("displayTimeUnit" in e for e in errs)
    assert any("instant scope must be t/p/g, got 'x'" in e for e in errs)
    assert any("instant must not carry dur" in e for e in errs)
    assert any("bad dur -1.0" in e for e in errs)
    assert any("args must be an object" in e for e in errs)
    assert any("bad ph 'Q'" in e for e in errs)
    assert any("pid/tid must be ints" in e for e in errs)
    assert any("name missing" in e for e in errs)
    # the recorder's own export of an instant stays clean (scope "t",
    # no dur, named tid)
    rec = TraceRecorder(capacity=8)
    rec.instant("ok", "c", "t")
    assert validate_chrome_trace(rec.to_chrome()) == []


def test_chrome_export_file_is_schema_valid(tiny_ds, tmp_path):
    eng = _engine(tiny_ds, trace=True)
    eng.prepare([np.arange(64), np.arange(64, 128)], epoch=0)
    path = eng.telemetry.trace.export_chrome(str(tmp_path / "trace.json"))
    with open(path) as f:
        payload = json.load(f)
    assert validate_chrome_trace(payload) == []
    evs = payload["traceEvents"]
    tracks = {e["args"]["name"] for e in evs
              if e["ph"] == "M" and e["name"] == "thread_name"}
    assert any(t.startswith("prepare:") for t in tracks)
    assert any(t.startswith("array:") for t in tracks)
    cats = {e.get("cat") for e in evs if e["ph"] != "M"}
    assert {"prepare", "prepare.stage", "io.submit", "io.run"} <= cats
    eng.close()


# ------------------------------------------------------------ integration
def test_fig2_breakdown_agrees_with_overlap_report(tiny_ds):
    eng = _engine(tiny_ds, trace=True)
    tr = GNNTrainer(arch="gcn", in_dim=32, hidden=32, n_classes=16,
                    n_layers=2, seed=7)
    tr.labels = tiny_ds.labels
    with PipelinedExecutor(eng, tr, depth=2) as ex:
        report = ex.run_epoch(np.arange(256), epoch=0)
    rec = eng.telemetry.trace
    fb = fig2_breakdown(rec)
    assert fb["dropped_events"] == 0
    # the spans reuse the report's own perf_counter readings
    assert fb["train_s"] == pytest.approx(report.train_wall_s, rel=1e-9)
    assert fb["prepare_s"] == pytest.approx(report.prepare_wall_s, rel=0.02)
    assert fb["prepare_fraction"] + fb["train_fraction"] == \
        pytest.approx(1.0)
    # nested sub-bars stay inside their parents
    assert fb["transfer_s"] + fb["train_step_s"] <= fb["train_s"] + 1e-9
    assert sum(fb["stages_s"].values()) <= fb["prepare_s"] * 1.02
    n = fb["spans_per_category"]
    assert n["prepare"] == report.n_hyperbatches
    assert n["train"] == report.n_hyperbatches
    assert n["train.step"] == report.n_minibatches
    assert validate_chrome_trace(rec.to_chrome()) == []
    eng.close()


def test_fig2_breakdown_edge_cases():
    # empty recorder: zeroed bars, well-defined fractions, no drops
    fb = fig2_breakdown(TraceRecorder(capacity=8))
    assert fb["prepare_s"] == 0.0 and fb["train_s"] == 0.0
    assert fb["prepare_fraction"] == 0.0 and fb["train_fraction"] == 0.0
    assert fb["dropped_events"] == 0 and fb["stages_s"] == {}

    # flooded tiny ring: the prepare span got overwritten by instants —
    # the bars zero out, but dropped_events says why
    rec = TraceRecorder(capacity=4)
    with rec.span("hb0", "prepare", "pipeline"):
        pass
    for i in range(16):
        rec.instant(f"e{i}", "diag.alert", "doctor")
    fb = fig2_breakdown(rec)
    assert fb["prepare_s"] == 0.0
    assert fb["dropped_events"] == 13

    # a plain event list of only instants: category counted at zero, no
    # dropped_events key (there is no recorder to ask), nothing raises
    fb = fig2_breakdown([("i", "alert:stall-spike", "diag.alert", "doctor",
                          1.0, 0.0, {"kind": "stall-spike"})])
    assert fb["prepare_s"] == 0.0
    assert fb["spans_per_category"] == {"diag.alert": 0}
    assert "dropped_events" not in fb


def test_disabled_trace_keeps_metrics_live(tiny_ds):
    eng = _engine(tiny_ds)                # trace defaults to False
    assert eng.telemetry.trace is None
    eng.prepare([np.arange(64)], epoch=0)
    snap = eng.metrics_snapshot()
    assert snap["io.graph.runs"] > 0      # counters flow without a trace
    assert snap["agnes.graph.bytes_read"] > 0  # summary gauges folded in
    eng.close()


def test_serving_tenants_trace_onto_separate_tracks(tiny_ds):
    eng = _engine(tiny_ds, trace=True, fanouts=(), feature_cache_rows=1,
                  n_arrays=2, placement="stripe",
                  max_coalesce_bytes=64 << 10, io_queue_depth=4)
    tier = ServingTier(eng)
    tier.open_tenant("inference")
    errs: list = []

    def work(tenant, seed):
        rng = np.random.default_rng(seed)
        try:
            for i in range(3):
                tier.prepare(
                    tenant,
                    [rng.choice(tiny_ds.n_nodes, 32, replace=False)],
                    epoch=i)
        except Exception as e:            # surfaced after join
            errs.append((tenant, e))

    ts = [threading.Thread(target=work, args=("training", 0)),
          threading.Thread(target=work, args=("inference", 1))]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errs, errs
    rec = eng.telemetry.trace
    assert validate_chrome_trace(rec.to_chrome()) == []
    tracks = {e[3] for e in rec.events()}
    assert {"serving:training", "serving:inference"} <= tracks
    assert "prepare:inference" in tracks  # tenant-labeled session stages
    snap = eng.telemetry.metrics.snapshot()
    assert snap["serving.training.requests"] == 3
    assert snap["serving.inference.requests"] == 3
    assert snap["serving.training.latency_s"]["count"] == 3
    tier.close()
    eng.close()
