"""Config system: model / layer / shape / mesh configs + registry.

Every assigned architecture is one file in this package defining a
``ModelConfig`` with the exact published dimensions, a per-layer spec list
(mixer × ffn per layer — this is what lets one model implementation cover
dense, SWA-patterned, MoE, Mamba-hybrid, xLSTM and enc-dec families), and
a ``smoke()`` reduction used by the CPU tests.

Shapes (assignment): train_4k, prefill_32k, decode_32k, long_500k — each
cell (arch × shape) must lower + compile on the production meshes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

# ----------------------------------------------------------------- layers
# mixer kinds: "attn" (full), "swa" (sliding window), "mamba", "mlstm",
#              "slstm", "none"
# ffn kinds:   "mlp", "moe", "none"


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str = "attn"
    ffn: str = "mlp"
    window: int = 0          # >0 => sliding window for this layer's attn


@dataclasses.dataclass
class MoEConfig:
    n_experts: int = 0
    top_k: int = 2
    d_expert: int = 0            # per-expert hidden size
    n_shared: int = 0            # shared (always-on) experts
    capacity_factor: float = 1.25
    group_tokens: int = 4096     # GShard-style dispatch group size
                                 # (bounds the (T, E, C) bucket tensors)


@dataclasses.dataclass
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    chunk: int = 256             # time-chunk for the scan


@dataclasses.dataclass
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 => d_model // n_heads
    layers: tuple[LayerSpec, ...] = ()
    moe: MoEConfig = dataclasses.field(default_factory=MoEConfig)
    ssm: SSMConfig = dataclasses.field(default_factory=SSMConfig)
    rope_theta: float = 10_000.0
    mrope: bool = False          # qwen2-vl multimodal RoPE (3-section)
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    # encoder-decoder (seamless): n_enc encoder layers; decoder gets
    # cross-attention. 0 => decoder-only.
    n_enc_layers: int = 0
    enc_seq: int = 1024          # encoder memory length for decode shapes
    frontend: str = "none"       # none | vision_stub | audio_stub
    attn_logit_softcap: float = 0.0
    # numerics / execution
    dtype: str = "bfloat16"
    ce_chunk: int = 1024         # chunked cross-entropy token block
    attn_chunk: int = 1024       # online-softmax KV chunk
    remat: bool = True
    scan_layers: bool = True
    sequence_parallel: bool = False  # Megatron SP on layer boundaries
    dp_over_model: bool = False      # EP+full-DP mode (batch over model too)
    # notes for DESIGN/EXPERIMENTS
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            self.head_dim = self.d_model // self.n_heads
        if not self.layers:
            self.layers = tuple(LayerSpec() for _ in range(self.n_layers))
        assert len(self.layers) == self.n_layers, \
            f"{self.name}: layer specs {len(self.layers)} != n_layers {self.n_layers}"

    # ------------------------------------------------------------ helpers
    @property
    def repeat_unit(self) -> tuple[LayerSpec, ...]:
        """Repeating unit of the stack plan (see :meth:`stack_plan`)."""
        o, p, _, _ = self.stack_plan()
        return self.layers[o:o + p]

    def stack_plan(self) -> tuple[int, int, int, int]:
        """(head, unit_len, reps, tail): layers = head ++ unit*reps ++ tail.

        Finds the periodic core of the per-layer spec list so the scanned
        stack covers as many layers as possible (small HLO, bounded
        compile time) while aperiodic head layers (e.g. the dense first
        layer of deepseek-moe/moonlight) and tail remainders (gemma3's
        62 = 10x6 + 2) stay unrolled.
        """
        n = len(self.layers)
        best = (0, n, 1, 0)     # fallback: whole stack is one "unit"
        best_cost = n
        for o in range(0, min(3, n)):
            for t in range(0, min(8, n - o)):
                m = n - o - t
                if m <= 0:
                    continue
                for p in range(1, m + 1):
                    if m % p:
                        continue
                    if self.layers[o:o + m] == self.layers[o:o + p] * (m // p):
                        cost = o + t + p   # unrolled layers in the HLO
                        if cost < best_cost:
                            best, best_cost = (o, p, m // p, t), cost
                        break  # smallest p for this (o, t)
        return best

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once if tied)."""
        d, dh = self.d_model, self.head_dim
        total = self.vocab * d  # embed (tied head)
        if not self.tie_embeddings:
            total += self.vocab * d
        def attn_params():
            return d * (self.n_heads * dh) * 2 + d * (self.n_kv_heads * dh) * 2
        def mlp_params(dff):
            return 3 * d * dff
        for spec in self.layers:
            if spec.mixer in ("attn", "swa"):
                total += attn_params()
            elif spec.mixer == "mamba":
                di = self.ssm.expand * d
                total += 2 * d * di + di * (2 * self.ssm.d_state + 2) \
                    + di * self.ssm.d_conv + di * d
            elif spec.mixer in ("mlstm", "slstm"):
                total += 4 * d * d + 2 * d * d
            if spec.ffn == "mlp":
                total += mlp_params(self.d_ff)
            elif spec.ffn == "moe":
                m = self.moe
                total += d * m.n_experts  # router
                total += (m.n_experts + m.n_shared) * 3 * d * m.d_expert
            total += 2 * d  # norms
        for _ in range(self.n_enc_layers):
            total += attn_params() + mlp_params(self.d_ff) + 2 * d
            total += attn_params()  # decoder cross-attn (charged here)
        return total

    def active_param_count(self) -> int:
        """Per-token active params (MoE: top_k + shared experts only)."""
        if not any(s.ffn == "moe" for s in self.layers):
            return self.param_count()
        d = self.d_model
        m = self.moe
        total = self.param_count()
        n_moe = sum(1 for s in self.layers if s.ffn == "moe")
        inactive = n_moe * (m.n_experts - m.top_k) * 3 * d * m.d_expert
        return total - inactive


# ----------------------------------------------------------------- shapes
@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


# --------------------------------------------------------------- registry
_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        from . import _load_all
        _load_all()
    return _REGISTRY[name]()


def list_configs() -> list[str]:
    from . import _load_all
    _load_all()
    return sorted(_REGISTRY)


def smoke_reduce(cfg: ModelConfig, *, d_model: int = 64, n_layers: int | None = None,
                 vocab: int = 512, d_ff: int = 128) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests.

    Keeps the layer-pattern *structure* (one full repeat unit at least)
    while shrinking widths, expert counts and vocab.
    """
    unit = cfg.repeat_unit
    if n_layers is None:
        n_layers = len(unit) if len(unit) > 1 else min(2, cfg.n_layers)
    reps = max(1, -(-n_layers // len(unit)))
    layers = (unit * reps)[:max(n_layers, len(unit))]
    n_layers = len(layers)
    n_heads = max(2, min(cfg.n_heads, 4))
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    while n_heads % n_kv:
        n_kv -= 1
    moe = dataclasses.replace(
        cfg.moe,
        n_experts=min(cfg.moe.n_experts, 8) if cfg.moe.n_experts else 0,
        top_k=min(cfg.moe.top_k, 2),
        d_expert=min(cfg.moe.d_expert, 64) if cfg.moe.d_expert else 0,
        n_shared=min(cfg.moe.n_shared, 1),
        group_tokens=32)
    ssm = dataclasses.replace(cfg.ssm, d_state=8, chunk=16)
    return dataclasses.replace(
        cfg, name=cfg.name + "-smoke", n_layers=n_layers, layers=tuple(layers),
        d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv,
        head_dim=d_model // n_heads, d_ff=d_ff, vocab=vocab, moe=moe, ssm=ssm,
        n_enc_layers=min(cfg.n_enc_layers, 2), enc_seq=32,
        ce_chunk=64, attn_chunk=32, scan_layers=False)
