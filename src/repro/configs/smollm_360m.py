"""smollm-360m [dense]: 32L, d=960, 15H (GQA kv=5), d_ff=2560, vocab=49152.
Llama-architecture small model. [hf:HuggingFaceTB/SmolLM-360M; hf]
"""
from .base import ModelConfig, register


@register("smollm-360m")
def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m", family="dense",
        n_layers=32, d_model=960, n_heads=15, n_kv_heads=5,
        d_ff=2560, vocab=49152, head_dim=64,
        source="hf:HuggingFaceTB/SmolLM-360M")
