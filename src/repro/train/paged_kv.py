"""Paged KV-cache manager — the AGNES block discipline applied to serving.

Serving many variable-length requests fragments KV memory exactly the way
per-node reads fragment NVMe bandwidth: the fix is the same as the
paper's — fixed-size *blocks* (pages), an object-index-table analogue
mapping request → page list, and hyperbatch-style grouping of requests so
every resident page serves all requests in the step.

This manager owns the host-side bookkeeping (page tables, free lists,
admission); the device-side cache the model consumes is the dense ring
described in ``attention.py`` — on TPU the paged layout is materialized
per decode step by a gather over the page table (the same
``gather_rows`` Pallas kernel used for feature blocks).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class PagedKVConfig:
    page_tokens: int = 128        # tokens per page (block)
    n_pages: int = 4096           # device pool size
    max_requests: int = 256


class PagedKVManager:
    """Page tables + free list + hyperbatch grouping for decode."""

    def __init__(self, cfg: PagedKVConfig):
        self.cfg = cfg
        self.free = list(range(cfg.n_pages - 1, -1, -1))
        self.tables: dict[int, list[int]] = {}     # request -> page ids
        self.lengths: dict[int, int] = {}
        self.evictions = 0

    # ------------------------------------------------------------ admit
    def admit(self, request_id: int, prompt_len: int) -> bool:
        need = -(-prompt_len // self.cfg.page_tokens)
        if len(self.free) < need or len(self.tables) >= self.cfg.max_requests:
            return False
        self.tables[request_id] = [self.free.pop() for _ in range(need)]
        self.lengths[request_id] = prompt_len
        return True

    def extend(self, request_id: int, n_tokens: int = 1) -> bool:
        """Grow a request; allocates a new page on block boundary."""
        length = self.lengths[request_id]
        new_len = length + n_tokens
        have = len(self.tables[request_id]) * self.cfg.page_tokens
        while new_len > have:
            if not self.free:
                return False
            self.tables[request_id].append(self.free.pop())
            have += self.cfg.page_tokens
        self.lengths[request_id] = new_len
        return True

    def release(self, request_id: int) -> None:
        self.free.extend(reversed(self.tables.pop(request_id)))
        self.lengths.pop(request_id)

    # -------------------------------------------------------- hyperbatch
    def decode_batch(self) -> dict:
        """Group all active requests into one decode step (hyperbatch).

        Returns the page-table matrix (R, max_pages) the device gather
        uses, plus lengths — every resident page serves every request
        that maps to it in a single step.
        """
        if not self.tables:
            return {"request_ids": np.zeros(0, np.int64),
                    "page_table": np.zeros((0, 0), np.int32),
                    "lengths": np.zeros(0, np.int32)}
        rids = sorted(self.tables)
        max_pages = max(len(self.tables[r]) for r in rids)
        table = np.full((len(rids), max_pages), -1, dtype=np.int32)
        for i, r in enumerate(rids):
            pages = self.tables[r]
            table[i, :len(pages)] = pages
        return {"request_ids": np.asarray(rids),
                "page_table": table,
                "lengths": np.asarray([self.lengths[r] for r in rids],
                                      dtype=np.int32)}

    @property
    def utilization(self) -> float:
        used = self.cfg.n_pages - len(self.free)
        return used / self.cfg.n_pages

    def fragmentation(self) -> float:
        """Wasted tail slots / allocated slots (bounded by page size)."""
        alloc = sum(len(t) for t in self.tables.values()) \
            * self.cfg.page_tokens
        if alloc == 0:
            return 0.0
        live = sum(self.lengths.values())
        return 1.0 - live / alloc
