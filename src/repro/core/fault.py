"""Storage fault domain: error taxonomy + seeded, scriptable fault injection.

The paper's premise is saturating NVMe arrays with a large number of
in-flight block I/Os — exactly the regime where real devices throw
transient read errors, tail-latency spikes, torn writes and whole-array
dropouts.  This module gives the storage subsystem a vocabulary for
those failures and a deterministic way to inject them:

* an **error taxonomy** (:class:`TransientIOError`,
  :class:`PermanentIOError`, :class:`TornWriteError`,
  :class:`ArrayOfflineError`) plus :func:`classify_error`, which maps
  arbitrary exceptions — injected or real ``OSError``\\ s — onto the
  retry/propagate/degrade decision the I/O scheduler takes
  (``core/io_sched.py``);
* a :class:`FaultInjector` that wraps the read path's charge points and
  real file reads (``CoalescedReader`` consults it per physical read
  attempt) and the migration journal's write path
  (``block_store.migrate_blocks``) with a seeded, scriptable schedule.

Fault kinds and what the stack does about them:

=========  ==================================  ===========================
kind       injected as                         handled by
=========  ==================================  ===========================
transient  :class:`TransientIOError` per read  bounded retry + exponential
           attempt (probability or op index)   backoff/jitter in the reader
latency    service-time multiplier on one run  hedged duplicate read past
                                               the p99-derived deadline
torn       journal file truncated mid-record   journal *replay* rolls the
           + :class:`TornWriteError` (a         interrupted migration back
           simulated crash window)             (``recover_store_metadata``)
dropout    :class:`ArrayOfflineError` sticky   degraded mode: topology
           for one array from op ``at`` on     marks the array offline,
                                               reads reroute to survivors,
                                               ``MigrationEngine`` drains
                                               the stranded blocks
=========  ==================================  ===========================

Schedules are strings so they travel through configs and CLI flags
(``AgnesConfig.fault_schedule``, ``--inject-faults``)::

    "transient:p=0.01;latency:p=0.005,factor=30;dropout:array=3,at=400"

Every firing decision is drawn from one seeded ``np.random.default_rng``
under a lock, so a schedule replays identically at a fixed seed and
deterministic consumer order (``async_io=False``).

This is the *storage-level* fault domain; host-level failures
(heartbeats, stragglers, elastic meshes) live in
``repro.distributed.fault``.
"""
from __future__ import annotations

import dataclasses
import errno
import os
import threading

import numpy as np

#: ``OSError`` errnos worth retrying: the kernel-level analogues of a
#: media retry / aborted command / queue-full push-back.
TRANSIENT_ERRNOS = frozenset({
    errno.EAGAIN, errno.EINTR, errno.EBUSY, errno.ETIMEDOUT, errno.EIO})

_FAULT_KINDS = ("transient", "latency", "dropout", "torn")


class IOFaultError(OSError):
    """Base class of storage-fault errors (injected or classified)."""


class TransientIOError(IOFaultError):
    """Retryable read failure — succeeds on a bounded re-issue."""


class PermanentIOError(IOFaultError):
    """Unrecoverable failure: propagate through the error-sentinel path."""


class TornWriteError(PermanentIOError):
    """A journal write tore mid-record (simulated crash window).

    Raised *after* the tear is applied to the on-disk journal, so the
    file state matches a real kill: recovery at the next store open
    detects the torn tail and rolls the migration back.
    """


class ArrayOfflineError(PermanentIOError):
    """A whole array dropped out; carries the failed array's index."""

    def __init__(self, array: int, message: str | None = None):
        self.array = int(array)
        super().__init__(errno.EIO,
                         message or f"storage array {array} offline")


def classify_error(exc: BaseException) -> str:
    """Map an exception to its fault class: the retry/propagate decision.

    Returns ``"transient"`` (bounded retry is worthwhile), ``"offline"``
    (whole-array dropout — flip to degraded mode), or ``"permanent"``
    (re-raise through the sentinel path).  Injected faults carry their
    class; real ``OSError`` s are split on :data:`TRANSIENT_ERRNOS`;
    everything else — index errors, decode bugs — is permanent: retrying
    a deterministic failure only hides it.
    """
    if isinstance(exc, ArrayOfflineError):
        return "offline"
    if isinstance(exc, TransientIOError):
        return "transient"
    if isinstance(exc, PermanentIOError):
        return "permanent"
    if isinstance(exc, OSError) and exc.errno in TRANSIENT_ERRNOS:
        return "transient"
    return "permanent"


@dataclasses.dataclass
class FaultRule:
    """One scheduled fault: ``kind`` + trigger (probability or op index).

    ``p`` fires on an independent seeded draw per read op (per journal
    write for ``torn``); ``at`` fires deterministically at that op index
    (``>= at`` and sticky for ``dropout``, ``== at`` otherwise).
    ``array`` filters to one array (required for ``dropout``); ``count``
    caps total firings; ``factor`` is the latency-spike service-time
    multiplier.
    """

    kind: str
    p: float = 0.0
    at: int | None = None
    array: int | None = None
    factor: float = 20.0
    count: int | None = None
    fired: int = 0

    def __post_init__(self):
        if self.kind not in _FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(expected one of {_FAULT_KINDS})")
        if self.kind == "dropout" and self.array is None:
            raise ValueError("dropout fault needs array=<index>")

    def _exhausted(self) -> bool:
        return self.count is not None and self.fired >= self.count


class FaultInjector:
    """Seeded, scriptable fault schedule over a store's physical I/O.

    Attach to a store via ``store.attach_fault(injector)``; the
    coalesced reader then consults :meth:`on_read` once per physical
    read attempt (so a retry re-rolls the dice) and
    ``migrate_blocks`` consults :meth:`on_journal_write` once per
    journal write.  One injector may be shared by several stores — the
    op counter then spans all of them, which keeps ``at=`` schedules
    meaningful for a whole engine.
    """

    def __init__(self, rules: list[FaultRule], seed: int = 0):
        self.rules = list(rules)
        self.seed = int(seed)
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()
        self._op = 0          # read attempts observed
        self._jop = 0         # journal writes observed
        self.counters = {k: 0 for k in _FAULT_KINDS}

    # ------------------------------------------------------------ parsing
    @classmethod
    def parse(cls, spec: "str | FaultInjector",
              seed: int = 0) -> "FaultInjector":
        """Build an injector from a schedule string.

        Grammar: ``kind:key=val,key=val;kind:...`` with keys ``p``
        (float), ``at`` (int), ``array`` (int), ``factor`` (float),
        ``count`` (int) — e.g.
        ``"transient:p=0.02;dropout:array=1,at=500"``.
        """
        if isinstance(spec, FaultInjector):
            return spec
        rules: list[FaultRule] = []
        for part in str(spec).split(";"):
            part = part.strip()
            if not part:
                continue
            kind, _, argstr = part.partition(":")
            kw: dict = {}
            for item in argstr.split(","):
                item = item.strip()
                if not item:
                    continue
                key, _, val = item.partition("=")
                key, val = key.strip(), val.strip()
                if key == "p":
                    kw["p"] = float(val)
                elif key == "at":
                    kw["at"] = int(val)
                elif key == "array":
                    kw["array"] = int(val)
                elif key == "factor":
                    kw["factor"] = float(val)
                elif key == "count":
                    kw["count"] = int(val)
                else:
                    raise ValueError(
                        f"unknown fault parameter {key!r} in {part!r}")
            rules.append(FaultRule(kind=kind.strip(), **kw))
        if not rules:
            raise ValueError(f"empty fault schedule {spec!r}")
        inj = cls(rules, seed=seed)
        inj.spec = str(spec)
        return inj

    # ------------------------------------------------------------ hooks
    def on_read(self, array: int, start: int = 0, count: int = 1) -> float:
        """One physical read attempt against ``array``.

        Raises :class:`TransientIOError` / :class:`ArrayOfflineError`
        per the schedule, or returns the service-time multiplier
        (``1.0`` = no spike) the caller charges the run at.
        """
        a = int(array)
        with self._lock:
            op = self._op
            self._op += 1
            mult = 1.0
            for r in self.rules:
                if r.kind == "dropout":
                    if a != r.array:
                        continue
                    if not r.fired and (
                            (r.at is not None and op >= r.at)
                            or (r.p > 0 and self._rng.random() < r.p)):
                        r.fired += 1          # sticky from here on
                        self.counters["dropout"] += 1
                    if r.fired:
                        raise ArrayOfflineError(
                            r.array, f"injected dropout of array {r.array} "
                                     f"(op {op})")
                    continue
                if r.array is not None and a != r.array:
                    continue
                if r._exhausted():
                    continue
                hit = ((r.at is not None and op == r.at)
                       or (r.p > 0 and self._rng.random() < r.p))
                if not hit:
                    continue
                if r.kind == "transient":
                    r.fired += 1
                    self.counters["transient"] += 1
                    raise TransientIOError(
                        errno.EIO, f"injected transient read error "
                                   f"(op {op}, array {a}, "
                                   f"run {start}+{count})")
                if r.kind == "latency":
                    r.fired += 1
                    self.counters["latency"] += 1
                    mult = max(mult, float(r.factor))
            return mult

    def on_journal_write(self, path: str) -> None:
        """One durable journal write.  A scheduled torn-write truncates
        the just-written file mid-record and raises
        :class:`TornWriteError` — the moral equivalent of losing power
        with the tail of the journal still in the drive's write cache.
        """
        with self._lock:
            jop = self._jop
            self._jop += 1
            for r in self.rules:
                if r.kind != "torn" or r._exhausted():
                    continue
                if not ((r.at is not None and jop == r.at)
                        or (r.p > 0 and self._rng.random() < r.p)):
                    continue
                r.fired += 1
                self.counters["torn"] += 1
                size = os.path.getsize(path)
                keep = max(int(size * (0.25 + 0.5 * self._rng.random())) - 1,
                           1) if size > 1 else 0
                with open(path, "r+b") as fh:
                    fh.truncate(keep)
                    fh.flush()
                    os.fsync(fh.fileno())
                raise TornWriteError(
                    errno.EIO, f"injected torn journal write "
                               f"(write {jop}, kept {keep}/{size} bytes): "
                               f"{path}")

    # ------------------------------------------------------------ reporting
    def summary(self) -> dict:
        with self._lock:
            return {
                "schedule": getattr(self, "spec", None),
                "seed": self.seed,
                "read_ops": self._op,
                "journal_writes": self._jop,
                "fired": dict(self.counters),
            }
