"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [fig2 fig4 fig6 fig7 fig8 fig9 fig10 fig11 fig12 pipeline]

Prints ``name,us_per_call,derived`` CSV (benchmarks/common.emit).
"""
import sys
import time

from . import (bench_fig2_breakdown, bench_fig4_io_unit, bench_fig6_eq1,
               bench_fig7_distdgl, bench_fig8_hyperbatch, bench_fig9_sweep,
               bench_fig10_sensitivity, bench_fig11_bw, bench_fig12_accuracy,
               bench_pipeline_overlap)

ALL = {
    "fig2": bench_fig2_breakdown.run,
    "fig4": bench_fig4_io_unit.run,
    "fig6": bench_fig6_eq1.run,
    "fig7": bench_fig7_distdgl.run,
    "fig8": bench_fig8_hyperbatch.run,
    "fig9": bench_fig9_sweep.run,
    "fig10": bench_fig10_sensitivity.run,
    "fig11": bench_fig11_bw.run,
    "fig12": bench_fig12_accuracy.run,
    "pipeline": bench_pipeline_overlap.run,
}


def main() -> None:
    which = sys.argv[1:] or list(ALL)
    print("name,us_per_call,derived")
    for name in which:
        t0 = time.time()
        ALL[name]()
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)


if __name__ == '__main__':
    main()
