"""Distributed substrate: checkpoint restore, fault tolerance drill,
gradient compression, paged KV, sharding rules on a debug mesh."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.compression import (compressed_bytes, dequantize_int8,
                                           ef_compress_tree, init_residuals,
                                           quantize_int8)
from repro.distributed.fault import (ElasticTrainer, FaultMonitor,
                                     plan_elastic_mesh)
from repro.train.paged_kv import PagedKVConfig, PagedKVManager


# ------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
            "step": jnp.asarray(3)}
    for step in (10, 20, 30):
        mgr.save(step, tree, blocking=True)
    assert mgr.list_steps() == [20, 30]  # keep=2 garbage collection
    out = mgr.restore(jax.tree.map(lambda x: x, tree))
    assert np.allclose(out["w"], tree["w"])
    assert out["nested"]["b"].dtype == jnp.bfloat16
    assert int(out["step"]) == 3


def test_checkpoint_restore_survives_partial_write(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.ones((3,))}
    mgr.save(1, tree, blocking=True)
    # simulate a torn write of a newer checkpoint (no COMMIT marker)
    os.makedirs(str(tmp_path / "step_0000000002"))
    assert mgr.latest_step() == 1
    out = mgr.restore(tree)
    assert np.allclose(out["w"], 1.0)


# ------------------------------------------------------------------ fault
def test_fault_monitor_detects_death_and_stragglers():
    t = [0.0]
    mon = FaultMonitor(4, timeout_s=10, straggler_factor=2.0,
                       straggler_patience=2, clock=lambda: t[0])
    flagged = set()
    for step in range(5):
        t[0] += 1.0
        for h in range(4):
            if h == 3 and step >= 2:
                continue  # host 3 goes silent
            mon.heartbeat(h, 1.0 if h != 2 else 5.0)  # host 2 straggles
        flagged |= set(mon.check()["stragglers"])  # strikes per check
    assert flagged == {2}
    t[0] += 20.0
    rep = mon.check()
    assert 3 in rep["dead"]


def test_elastic_remesh_preserves_tp_groups():
    # 16 hosts, 4 per TP group; hosts 5 and 11 die -> groups 1 and 2 lost
    alive = [h for h in range(16) if h not in (5, 11)]
    plan = plan_elastic_mesh(alive, hosts_per_tp_group=4, model_axis=16)
    assert plan["data_axis"] == 2
    assert plan["tp_groups"] == [0, 3]
    assert 4 in plan["dropped_hosts"] and 6 in plan["dropped_hosts"]


def test_elastic_trainer_recovery_plan(tmp_path):
    t = [0.0]
    mon = FaultMonitor(8, timeout_s=5, clock=lambda: t[0])
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(40, {"w": jnp.ones(2)}, blocking=True)
    trainer = ElasticTrainer(mon, mgr, hosts_per_tp_group=2, model_axis=8,
                             global_batch=256)
    for h in range(8):
        mon.heartbeat(h, 1.0)
    assert trainer.recovery_plan() is None
    t[0] += 10.0
    for h in range(6):   # hosts 6,7 never report again
        mon.heartbeat(h, 1.0)
    plan = trainer.recovery_plan()
    assert plan is not None
    assert plan["restore_step"] == 40
    assert plan["data_axis"] == 3  # groups {0,1,2} survive


# ------------------------------------------------------------ compression
def test_int8_quantization_error_bounded():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(1000,)) * 3)
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s, x.shape, x.dtype)
    err = np.abs(np.asarray(back - x))
    block_max = np.abs(np.asarray(x)).reshape(-1, 250).max()  # loose bound
    assert err.max() <= block_max / 127 + 1e-6


def test_error_feedback_accumulates_to_truth():
    """Sum of EF-compressed grads converges to sum of true grads."""
    rng = np.random.default_rng(1)
    grads = [{"w": jnp.asarray(rng.normal(size=(64,)) * 0.01)}
             for _ in range(30)]
    res = init_residuals(grads[0])
    total_c = jnp.zeros(64)
    total_t = jnp.zeros(64)
    for g in grads:
        dec, res = ef_compress_tree(g, res)
        total_c += dec["w"]
        total_t += g["w"]
    # residual carries the outstanding error; totals match within it
    gap = np.abs(np.asarray(total_c + res["w"] - total_t))
    assert gap.max() < 1e-5
    assert compressed_bytes(grads[0]) < 64 * 4  # beats f32 wire format


# --------------------------------------------------------------- paged KV
def test_paged_kv_alloc_release_fragmentation():
    kv = PagedKVManager(PagedKVConfig(page_tokens=16, n_pages=32,
                                      max_requests=8))
    assert kv.admit(1, 20)   # 2 pages
    assert kv.admit(2, 16)   # 1 page
    assert kv.utilization == pytest.approx(3 / 32)
    for _ in range(13):      # grow request 1 by 13 tokens -> 33 total
        assert kv.extend(1)
    assert len(kv.tables[1]) == 3
    batch = kv.decode_batch()
    assert batch["page_table"].shape == (2, 3)
    assert (batch["lengths"] == [33, 16]).all()
    kv.release(1)
    assert kv.utilization == pytest.approx(1 / 32)
    assert 0.0 <= kv.fragmentation() < 1.0


def test_paged_kv_admission_control():
    kv = PagedKVManager(PagedKVConfig(page_tokens=16, n_pages=4,
                                      max_requests=8))
    assert kv.admit(1, 64)       # takes all 4 pages
    assert not kv.admit(2, 16)   # pool exhausted
    kv.release(1)
    assert kv.admit(2, 16)


# ----------------------------------------------------------- sharding
def test_sharded_train_step_debug_mesh():
    """End-to-end sharded train step on a small host-device mesh."""
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    from repro.configs import get_config, smoke_reduce
    from repro.distributed.sharding import (batch_sharding,
                                            opt_state_shardings,
                                            param_shardings)
    from repro.compat import set_mesh
    from repro.launch.mesh import make_debug_mesh
    from repro.models import build_model
    from repro.train.loop import make_train_step
    from repro.train.optimizer import adamw_init

    cfg = smoke_reduce(get_config("smollm-360m"))
    model = build_model(cfg)
    mesh = make_debug_mesh()
    with set_mesh(mesh):
        pshard = param_shardings(model.param_specs(), mesh)
        params = jax.jit(model.init, out_shardings=pshard)(
            jax.random.PRNGKey(0))
        oshard = opt_state_shardings(jax.eval_shape(adamw_init, params),
                                     mesh)
        opt = jax.jit(adamw_init, out_shardings=oshard)(params)
        step = jax.jit(make_train_step(model, n_microbatches=2, lr=1e-3))
        toks = jnp.ones((2, 2, 16), jnp.int32)
        batch = {"tokens": jax.device_put(
            toks, batch_sharding(mesh, ndim=3, batch_axis=1))}
        params, opt, metrics = step(params, opt, batch)
        assert jnp.isfinite(metrics["loss"])
