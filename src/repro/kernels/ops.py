"""Jit'd public wrappers for the Pallas kernels.

Each op dispatches: Pallas TPU kernel on TPU backends, Pallas interpret
mode when ``interpret=True`` (CPU validation), and the jnp oracle
otherwise — so the same call sites run everywhere.  The oracle *is* the
semantics (``ref.py``); tests sweep shapes/dtypes asserting the kernels
match it.

The gather ops additionally carry:

* **shape shims** — real MFG tensors have arbitrary feature widths
  (e.g. 32) while the TPU lane width is 128; the wrappers zero-pad the
  feature dim up to the lane multiple before the kernel and slice it
  back after, and clamp indices so -1 padding / out-of-range rows can
  never steer a DMA out of bounds.  Under jit the pad/slice fuse.
* **custom VJPs** — ``pl.pallas_call`` has no autodiff rule, but the
  GNN train step differentiates through aggregation.  The backward of a
  gather is a scatter-add over the same index table; it runs as a plain
  XLA scatter (a Pallas backward kernel is a further optimisation, not
  a semantic need — TPU grads flow through the same masked math).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention_kernel
from .gather_rows import gather_rows_kernel, gather_rows_masked_kernel
from .segment_agg import gather_aggregate_kernel

_LANE = 128  # TPU vector lane width: last-dim tile multiple


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_lanes(table: jnp.ndarray) -> tuple[jnp.ndarray, int]:
    """Zero-pad the feature dim up to the lane multiple; return orig width."""
    d = table.shape[1]
    pad = (-d) % _LANE
    if pad:
        table = jnp.pad(table, ((0, 0), (0, pad)))
    return table, d


# ------------------------------------------------------- gather_rows
@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _gather_rows_impl(table, idx, interpret):
    return gather_rows_kernel(table, idx, interpret=interpret)


def _gather_rows_fwd(table, idx, interpret):
    return _gather_rows_impl(table, idx, interpret), (idx, table.shape[0])


def _gather_rows_bwd(interpret, res, g):
    idx, m = res
    d_table = jnp.zeros((m, g.shape[1]), g.dtype).at[idx].add(g)
    return d_table, None


_gather_rows_impl.defvjp(_gather_rows_fwd, _gather_rows_bwd)


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def gather_rows(table: jnp.ndarray, idx: jnp.ndarray, *,
                use_kernel: bool | None = None,
                interpret: bool = False) -> jnp.ndarray:
    """out[i] = table[idx[i]] (block feature gather)."""
    use = _on_tpu() if use_kernel is None else use_kernel
    if not (use or interpret):
        return ref.gather_rows_ref(table, idx)
    idx = jnp.clip(idx.astype(jnp.int32), 0, table.shape[0] - 1)
    padded, d = _pad_lanes(table)
    out = _gather_rows_impl(padded, idx, interpret or not _on_tpu())
    return out[:, :d] if padded.shape[1] != d else out


# --------------------------------------------- gather_resident_rows
@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def gather_resident_rows(table: jnp.ndarray, slots: jnp.ndarray,
                         miss_pos: jnp.ndarray, miss_rows: jnp.ndarray, *,
                         use_kernel: bool | None = None,
                         interpret: bool = False) -> jnp.ndarray:
    """Assemble a feature block from the HBM-resident cache mirror.

    ``out[i] = table[slots[i]]`` for ``slots[i] >= 0`` (cache hits: an
    HBM->HBM row gather, no host traffic), 0 otherwise; then
    ``out[miss_pos] = miss_rows`` scatters in the host-side rows (cache
    misses + slots demoted by a concurrent admit).  ``table`` may be
    pre-padded to the lane width; the output takes ``miss_rows``'s
    feature width, so callers pass ``miss_rows`` with the true dim even
    when it has zero rows.
    """
    d = miss_rows.shape[1]
    if slots.shape[0] == 0:
        return jnp.zeros((0, d), table.dtype)
    use = _on_tpu() if use_kernel is None else use_kernel
    if not (use or interpret):
        return ref.gather_resident_rows_ref(table, slots, miss_pos,
                                            miss_rows)
    valid = slots >= 0
    idx = jnp.clip(slots.astype(jnp.int32), 0, table.shape[0] - 1)
    padded, _ = _pad_lanes(table)
    out = gather_rows_masked_kernel(padded, idx, valid,
                                    interpret=interpret or not _on_tpu())
    out = out[:, :d]
    if miss_pos.shape[0]:
        out = out.at[miss_pos].set(miss_rows.astype(out.dtype))
    return out


# -------------------------------------------------- gather_aggregate
@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _gather_agg_impl(table, nbr_idx, mean, interpret):
    return gather_aggregate_kernel(table, nbr_idx, mean=mean,
                                   interpret=interpret)


def _gather_agg_fwd(table, nbr_idx, mean, interpret):
    return (_gather_agg_impl(table, nbr_idx, mean, interpret),
            (nbr_idx, table.shape[0]))


def _gather_agg_bwd(mean, interpret, res, g):
    nbr_idx, m = res
    w = (nbr_idx >= 0).astype(g.dtype)            # (n_dst, fanout)
    if mean:
        w = w / jnp.maximum(jnp.sum(w, axis=1, keepdims=True), 1.0)
    contrib = g[:, None, :] * w[..., None]        # masked rows contribute 0
    d_table = jnp.zeros((m, g.shape[-1]), g.dtype).at[
        jnp.clip(nbr_idx, 0)].add(contrib)
    return d_table, None


_gather_agg_impl.defvjp(_gather_agg_fwd, _gather_agg_bwd)


@functools.partial(jax.jit,
                   static_argnames=("mean", "use_kernel", "interpret"))
def gather_aggregate(table: jnp.ndarray, nbr_idx: jnp.ndarray, *,
                     mean: bool = True, use_kernel: bool | None = None,
                     interpret: bool = False) -> jnp.ndarray:
    """Fused GNN neighbor gather + masked sum/mean."""
    use = _on_tpu() if use_kernel is None else use_kernel
    if not (use or interpret):
        return ref.gather_aggregate_ref(table, nbr_idx, mean=mean)
    # clamp the upper bound but preserve -1 (the padding/mask sentinel)
    nbr_idx = jnp.clip(nbr_idx.astype(jnp.int32), -1, table.shape[0] - 1)
    padded, d = _pad_lanes(table)
    out = _gather_agg_impl(padded, nbr_idx, mean,
                           interpret or not _on_tpu())
    return out[:, :d] if padded.shape[1] != d else out


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "use_kernel", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    use_kernel: bool | None = None,
                    interpret: bool = False) -> jnp.ndarray:
    """Tiled online-softmax attention with GQA + sliding window."""
    use = _on_tpu() if use_kernel is None else use_kernel
    if use or interpret:
        return flash_attention_kernel(
            q, k, v, causal=causal, window=window, scale=scale,
            block_q=block_q, block_k=block_k,
            interpret=interpret or not _on_tpu())
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   scale=scale)
