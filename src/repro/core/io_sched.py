"""Coalesced multi-block I/O scheduler (paper §1's thesis, taken seriously).

Once the bucket matrix is built, the ascending block visit order of a
whole hop is known in advance (``async_io`` docstring).  The per-block
path wastes that knowledge: it issues one ``block_size`` request per
block, serialized behind the store lock, charged at per-request latency.
This module turns the plan into *coalesced* requests:

* :func:`coalesce` merges runs of adjacent block ids into single large
  sequential reads, bounded by ``max_coalesce_bytes`` per request;
* :class:`CoalescedReader` submits the independent runs through a small
  reader pool at a configurable queue depth and charges device time once
  per submitted plan via :meth:`NVMeModel.batch_time` (queue-depth
  overlap) instead of summed per-request ``request_time``.

Accounting semantics (see :meth:`IOStats.record_run_batch`): ``n_reads``
stays block-granular so it is directly comparable with the per-block
path; ``n_requests`` counts merged device requests; within a request
every block after the head streams sequentially, while request *heads*
are charged random — concurrent queue-depth submission gives no ordering
guarantee between requests at the device.  Bytes are identical to the
per-block path by construction (a run of ``k`` blocks reads exactly
``k * block_size`` bytes).

``CoalescedReader`` implements the same consumer protocol as
:class:`repro.core.async_io.BlockPrefetcher` (``submit``/``plan`` /
``fetch`` / ``reset`` / ``close``) so the sampler and gatherer are
agnostic to which one the engine wired in.  With ``workers == 0`` the
plan is executed lazily on the consumer thread (deterministic
synchronous mode, still coalesced); with ``workers >= 1`` a pool reads
ahead, bounded to ``queue_depth`` undelivered runs.

Multiple submissions may be in flight at once (cross-hop plan fusion —
``repro.core.session``): :meth:`CoalescedReader.submit` drops ids
already planned, :meth:`CoalescedReader.fetch` steals still-queued runs
rather than deadlocking behind a queue_depth of undrained tail runs, and
back-to-back submissions are charged through a shared
:class:`PlanStream` (max-of-summed-rooflines instead of per-plan
batches).

**Storage topology** (``repro.core.topology``): when the store carries a
:class:`~repro.core.topology.BlockPlacement`, submitted runs are split
at stripe boundaries into per-array segments and queued on *per-array
run queues*, each with an independent queue depth
(:meth:`CoalescedReader.set_queue_depth` takes an optional ``array``);
:class:`PlanStream` accumulates one open batch per *device object* and
charges fused submissions the ``max`` over per-array rooflines, so N
independent arrays genuinely overlap instead of summing.

**Fault domain** (``repro.core.fault``): every physical read attempt
runs through :meth:`CoalescedReader._guarded_read`, which classifies
failures with :func:`~repro.core.fault.classify_error` instead of a
blanket fallback — *transient* faults get bounded retry with
exponential backoff + jitter (each re-issue charged like any other
request), latency-spike stragglers past a p99-derived deadline get a
*hedged* duplicate read on the least-busy sibling array, an array
*dropout* flips the topology to degraded mode (the run re-reads through
the survivors' recovery path), and *permanent* errors are stashed per
block and re-raised from :meth:`CoalescedReader.fetch` so they
propagate through the producer's error-sentinel seam rather than being
silently swallowed.
"""
from __future__ import annotations

import dataclasses
import errno
import threading
import time
from collections import deque

import numpy as np

from .fault import PermanentIOError, classify_error


@dataclasses.dataclass(frozen=True)
class Run:
    """One coalesced device request: ``count`` adjacent blocks from ``start``.

    Within a request every block after the head streams sequentially;
    request *heads* are always charged random — concurrent queue-depth
    submission gives no ordering guarantee between requests at the device
    (this holds for chunks split off a longer run by ``max_coalesce_bytes``
    too: they land on different pool workers).
    """

    start: int
    count: int

    @property
    def stop(self) -> int:
        return self.start + self.count


def coalesce(block_ids, block_size: int,
             max_coalesce_bytes: int) -> list[Run]:
    """Merge an ascending unique block list into coalesced runs.

    ``max_coalesce_bytes <= block_size`` (or 0) yields one single-block
    run per id — batched submission without merging.
    """
    ids = np.asarray(block_ids, dtype=np.int64)
    if ids.size == 0:
        return []
    if np.any(np.diff(ids) <= 0):
        ids = np.unique(ids)
    cap = max(int(max_coalesce_bytes // block_size), 1) if max_coalesce_bytes > 0 else 1
    gaps = np.nonzero(np.diff(ids) != 1)[0] + 1
    starts = np.concatenate([[0], gaps])
    ends = np.concatenate([gaps, [ids.size]])
    runs: list[Run] = []
    for s, e in zip(starts.tolist(), ends.tolist()):
        off = s
        while off < e:
            c = min(e - off, cap)
            runs.append(Run(int(ids[off]), c))
            off += c
    return runs


def plan_cost(runs: list[Run], block_size: int, device,
              queue_depth: int) -> tuple[int, int, int, float]:
    """(total_bytes, n_blocks, n_sequential_blocks, modeled_time) of a plan."""
    n_blocks = sum(r.count for r in runs)
    n_random = len(runs)
    n_seq = n_blocks - n_random
    total = n_blocks * block_size
    t = device.batch_time(total, n_random=n_random, n_sequential=n_seq,
                          queue_depth=queue_depth)
    return total, n_blocks, n_seq, t


class PlanStream:
    """Fused-stream device accounting for back-to-back plan submissions.

    :meth:`NVMeModel.batch_time` is the roofline of a *single* submission
    batch: ``max(bytes / bw, n_random * latency / qd)``.  With a barrier
    between plans (the per-hop ``reset()`` of the pre-session prepare
    path) the device queue drains at every hop boundary, so each plan is
    charged independently and a k-hop prepare pays
    ``sum_h max(bw_h, iops_h)``.  When plans are submitted back to back
    into an *open* stream — cross-hop fusion — the queue never drains:
    the whole stream is one batch and pays ``max(sum_h bw_h, sum_h
    iops_h)``, letting the latency-bound sampling hops overlap the
    bandwidth-bound feature gather inside the device queue.

    The stream accumulates one open batch **per device object**, so a
    multi-array :class:`~repro.core.topology.StorageTopology` fuses too:
    each array accumulates its own share and the stream's total time is
    the ``max`` over per-array rooflines (independent arrays run in
    parallel — they never sum).  :meth:`charge` takes an optional
    ``device`` to route a submission at a specific array;
    :meth:`charge_split` routes one split submission at several arrays
    atomically (one incremental delta).

    :meth:`charge` returns each submission's incremental cost against the
    open stream (a single submission into a drained stream costs exactly
    :func:`plan_cost` — the barriered numbers are the degenerate case);
    :meth:`drain` closes the stream (an explicit barrier, or session
    end).  One stream per *topology*: readers over stores sharing the
    same arrays share the stream, so graph and feature plans fuse too.
    """

    def __init__(self, device):
        self.device = device          # default device for unrouted charges
        self._lock = threading.Lock()
        # id(device) -> [device, bytes, n_random, n_seq, queue_depth]
        self._acc: dict[int, list] = {}
        self._charged = 0.0

    def charge(self, runs: list[Run], block_size: int,
               queue_depth: int, device=None) -> tuple[int, int, int, float]:
        """(bytes, n_blocks, n_seq, incremental_time) of one submission.

        ``device`` routes the submission at a specific array's open
        batch; ``None`` uses the stream's default device (the
        single-array degenerate case).
        """
        dev = device if device is not None else self.device
        return self.charge_split([(dev, runs, queue_depth)], block_size)

    def charge_split(self, placed, block_size: int
                     ) -> tuple[int, int, int, float]:
        """Charge one submission already split across arrays.

        ``placed`` is ``[(device, runs, queue_depth), ...]``; all parts
        enter their per-device open batches under one lock and the
        caller is charged a single incremental delta of the stream's
        ``max``-over-devices roofline.
        """
        total = blocks = seq = 0
        with self._lock:
            for dev, runs, qd in placed:
                slot = self._acc.setdefault(id(dev), [dev, 0, 0, 0, qd])
                nb = sum(r.count for r in runs)
                nr = len(runs)
                slot[1] += nb * block_size
                slot[2] += nr
                slot[3] += nb - nr
                slot[4] = qd          # latest depth governs the open batch
                total += nb * block_size
                blocks += nb
                seq += nb - nr
            t = 0.0
            for dev, b, r, s, qd in self._acc.values():
                t = max(t, dev.batch_time(b, n_random=r, n_sequential=s,
                                          queue_depth=qd))
            delta = max(t - self._charged, 0.0)
            self._charged += delta
        return total, blocks, seq, delta

    def drain(self) -> None:
        """Barrier: the queue empties; later plans start a fresh stream."""
        with self._lock:
            self._acc.clear()
            self._charged = 0.0


class CoalescedReader:
    """Plan-driven coalesced reader over one block store.

    The store must provide ``block_size``, ``stats``, ``device``,
    ``read_run(start, count)`` (one memmap slice + vectorized decode, no
    accounting) and ``account_runs(runs, queue_depth)``.  When the store
    carries a :class:`~repro.core.topology.BlockPlacement`, each array
    gets its own run queue with an independent queue depth; without one,
    everything lives on the single implicit array 0 (behavior identical
    to the pre-topology reader).
    """

    supports_fusion = True  # submit() accepts cross-hop plans, no barrier

    def __init__(self, store, max_coalesce_bytes: int,
                 queue_depth: int = 8, workers: int = 2,
                 stream: PlanStream | None = None, retries: int = 2,
                 retry_backoff_s: float = 1e-3,
                 hedge_deadline_frac: float = 1.5, seed: int = 0,
                 fetch_timeout_s: float = 30.0):
        self.store = store
        self.max_coalesce_bytes = int(max_coalesce_bytes)
        self.queue_depth = max(int(queue_depth), 1)
        self.workers = max(int(workers), 0)
        self.stream = stream
        # per-fetch deadline (AgnesConfig.io_fetch_timeout_s; a serving
        # tenant's QoS class overrides it via bind_admission)
        self.fetch_timeout_s = float(fetch_timeout_s)
        # serving tier (core/serving.py): when bound, every run issue
        # routes through the shared AdmissionController first.  The
        # reader itself stays single-tenant — per-tenant engines own
        # per-tenant readers, which is also what scopes the permanent-
        # error stash (_error_of) per tenant.
        self.admission = None
        self.tenant = "default"
        # unified telemetry (core/telemetry.py): bound by the owning
        # engine via bind_telemetry; None = one branch per hot path
        self.telemetry = None
        self._tel_store = "store"
        self._tel_tenant = "default"
        self._m_runs = self._m_bytes = self._m_submits = None
        self._m_fault: dict = {}
        # fault-domain policy (core/fault.py): bounded retry for
        # transient faults, p99-deadline hedging for stragglers
        self.retries = max(int(retries), 0)
        self.retry_backoff_s = float(retry_backoff_s)
        self.hedge_deadline_frac = float(hedge_deadline_frac)
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        # runs are keyed by a unique token, not their start block: a fused
        # resubmission may legitimately reuse the start of a still-open
        # earlier run (e.g. a delivered-then-evicted head block), and the
        # two must not share slot accounting
        self._pending: dict[int, deque] = {}      # array -> (tok, Run) queue
        self._ready: dict[int, object] = {}       # block_id -> decoded block
        self._run_of: dict[int, int] = {}         # block_id -> run token
        self._remaining: dict[int, int] = {}      # run token -> unfetched blocks
        self._tok_array: dict[int, int] = {}      # run token -> array
        self._qd: dict[int, int] = {}             # per-array depth overrides
        self._ready_runs: dict[int, int] = {}     # array -> reserved runs
        self._error_of: dict[int, BaseException] = {}  # block -> stashed error
        self._svc_times: dict[int, deque] = {}    # array -> nominal run times
        self._run_seq = 0
        self._rr = 0                              # worker round-robin cursor
        self._gen = 0
        self._stop = False
        self._threads = [
            threading.Thread(target=self._worker, daemon=True,
                             name=f"io-sched-{i}")
            for i in range(self.workers)]
        for t in self._threads:
            t.start()

    # ------------------------------------------------------------ admission
    def bind_admission(self, controller, tenant: str,
                       fetch_timeout_s: float | None = None) -> None:
        """Enroll this reader as ``tenant`` of a serving-tier
        :class:`~repro.core.serving.AdmissionController`: submissions
        register their per-array backlog and every run issue blocks in
        ``controller.acquire`` until admitted.  ``fetch_timeout_s``
        installs the tenant's QoS-derived per-fetch deadline."""
        self.admission = controller
        self.tenant = tenant
        self._tel_tenant = tenant
        if fetch_timeout_s is not None:
            self.fetch_timeout_s = float(fetch_timeout_s)

    # ------------------------------------------------------------ telemetry
    def bind_telemetry(self, telemetry, store: str = "store",
                       tenant: str | None = None) -> None:
        """Bind a :class:`~repro.core.telemetry.Telemetry` bundle:
        per-run I/O spans land on ``array:<a>`` tracks, submissions on
        the tenant's prepare track, fault instants on the faulting
        array's track.  Counters are pre-resolved here so the per-run
        cost with tracing off is one locked increment, no registry
        lookup.  ``telemetry=None`` unbinds."""
        self.telemetry = telemetry
        self._tel_store = store
        self._tel_tenant = tenant or self.tenant
        if telemetry is None:
            self._m_runs = self._m_bytes = self._m_submits = None
            self._m_fault = {}
            return
        m = telemetry.metrics
        self._m_runs = m.counter(f"io.{store}.runs",
                                 "coalesced run reads issued")
        self._m_bytes = m.counter(f"io.{store}.bytes_read",
                                  "bytes moved by coalesced run reads")
        self._m_submits = m.counter(f"io.{store}.submitted_runs",
                                    "run segments staged by submit()")
        self._m_fault = {k: m.counter(f"io.{store}.fault.{k}")
                         for k in ("error", "retry", "hedge", "stall",
                                   "degraded")}

    def _issue_read(self, array: int, run: Run):
        """One admitted run read — called *outside* ``_cv``.  Without a
        bound controller this is exactly the (telemetry-timed) guarded
        read."""
        adm = self.admission
        if adm is None:
            return self._timed_read(array, run)
        nbytes = run.count * self.store.block_size
        adm.acquire(self.tenant, array, nbytes)
        try:
            return self._timed_read(array, run)
        finally:
            adm.complete(self.tenant, array, nbytes)

    def _timed_read(self, array: int, run: Run):
        """``_guarded_read`` plus one ``io.run`` span / counter pair
        when telemetry is bound (one branch when it is not)."""
        tel = self.telemetry
        if tel is None:
            return self._guarded_read(array, run)
        t0 = time.perf_counter()
        blocks = self._guarded_read(array, run)
        nbytes = run.count * self.store.block_size
        self._m_runs.inc()
        self._m_bytes.inc(nbytes)
        tr = tel.trace
        if tr is not None:
            tr.complete(f"{self._tel_store}.run", "io.run",
                        f"array:{array}", t0,
                        args={"start": run.start, "count": run.count,
                              "bytes": nbytes,
                              "tenant": self._tel_tenant})
        return blocks

    def _issue_outside_lock(self, array: int, run: Run):
        """Drop ``_cv``, issue one run (admission + guarded read),
        re-take ``_cv``.  Returns ``(blocks, failure)``; the caller must
        re-validate generation/plan state after the re-acquire — a
        concurrent ``reset()`` may have raced the read."""
        self._cv.release()
        blocks, failure = None, None
        try:
            try:
                blocks = self._issue_read(array, run)
            except Exception as exc:
                failure = exc
        finally:
            self._cv.acquire()
        return blocks, failure

    # ------------------------------------------------------------ topology
    def _placement(self):
        return getattr(self.store, "placement", None)

    def _array_of(self, block_id: int) -> int:
        pl = self._placement()
        return int(pl.array_of[block_id]) if pl is not None else 0

    def _qd_of(self, array: int) -> int:
        return self._qd.get(array, self.queue_depth)

    def queue_depths(self):
        """Scalar depth, or per-array ``{array: depth}`` with a placement."""
        pl = self._placement()
        if pl is None:
            return self.queue_depth
        return {a: self._qd_of(a) for a in range(pl.n_arrays)}

    # ------------------------------------------------------------ plan
    def submit(self, block_ids) -> None:
        """Submit one IOPlan stage's block list (ascending, buffer-absent).

        Ids already in the open plan — an earlier fused submission not yet
        consumed — are dropped here, so overlapping cross-hop submissions
        stay read-exactly-once.  Coalesces, charges the submission (via
        the fused :class:`PlanStream` when one is attached, as its own
        batch at queue-depth overlap otherwise), splits runs at array
        boundaries when the store has a placement, and queues the
        per-array segments for the reader pool (or lazy execution).
        """
        ids = np.asarray(list(block_ids) if not isinstance(block_ids, np.ndarray)
                         else block_ids, dtype=np.int64)
        if ids.size == 0:
            return
        adm = self.admission
        tel = self.telemetry
        t_sub = time.perf_counter() if tel is not None else 0.0
        if adm is not None:
            # placement-swap gate: no plan may be split against a
            # mapping that a migration tenant is mid-swap on
            adm.submit_begin(self.tenant)
        try:
            with self._cv:
                if self._run_of:
                    keep = np.fromiter((int(b) not in self._run_of
                                        for b in ids),
                                       dtype=bool, count=ids.size)
                    ids = ids[keep]
            if ids.size == 0:
                return
            runs = coalesce(ids, self.store.block_size,
                            self.max_coalesce_bytes)
            self.store.account_runs(runs, self.queue_depths(),
                                    stream=self.stream,
                                    max_coalesce_bytes=self.max_coalesce_bytes)
            pl = self._placement()
            staged: list[tuple[int, Run]] = []
            per_array: dict[int, list] = {}
            for r in runs:
                segments = pl.shard_run(r) if pl is not None else [(0, r)]
                for a, seg in segments:
                    staged.append((a, seg))
                    pa = per_array.setdefault(a, [0, 0])
                    pa[0] += 1
                    pa[1] += seg.count * self.store.block_size
            if adm is not None:
                # backlog must register *before* any entry is poppable,
                # or a worker could be granted a run the controller has
                # not yet seen as pending
                adm.note_submit(self.tenant,
                                {a: (p[0], p[1])
                                 for a, p in per_array.items()})
            with self._cv:
                for a, seg in staged:
                    tok = self._run_seq
                    self._run_seq += 1
                    self._pending.setdefault(a, deque()).append((tok, seg))
                    self._remaining[tok] = seg.count
                    self._tok_array[tok] = a
                    for b in range(seg.start, seg.stop):
                        self._run_of[b] = tok
                self._cv.notify_all()
            if tel is not None and staged:
                self._m_submits.inc(len(staged))
                tr = tel.trace
                if tr is not None:
                    tr.complete(f"{self._tel_store}.submit", "io.submit",
                                f"prepare:{self._tel_tenant}", t_sub,
                                args={"n_runs": len(staged),
                                      "bytes": int(sum(
                                          p[1] for p in per_array.values()))})
        finally:
            if adm is not None:
                adm.submit_end(self.tenant)

    # protocol alias shared with BlockPrefetcher (one submission per hop)
    plan = submit

    # ------------------------------------------------------------ consume
    def fetch(self, block_id: int, timeout: float | None = None):
        """Return the decoded block if it is part of the current plan.

        Blocks until its run is read (planned blocks are never re-read
        elsewhere, so waiting — not falling back — keeps bytes identical
        to the per-block path).  Returns ``None`` for unplanned ids; the
        caller falls back to a direct ``read_block``.  A run that failed
        with a classified *permanent* error (transient faults were
        already retried in ``_guarded_read``) re-raises that error here,
        so it propagates through the producer's error-sentinel seam
        instead of silently degrading to per-block reads.

        ``timeout=None`` uses the reader's configured deadline
        (``fetch_timeout_s`` — the ``AgnesConfig.io_fetch_timeout_s``
        knob, or the tenant's QoS class under a serving tier).
        """
        b = int(block_id)
        if timeout is None:
            timeout = self.fetch_timeout_s
        deadline = time.monotonic() + timeout
        with self._cv:
            tok = self._run_of.get(b)
            if tok is None:
                exc = self._error_of.pop(b, None)
                if exc is not None:
                    raise exc
                return None
            arr = self._tok_array.get(tok, 0)
            if self.workers == 0:
                while b not in self._ready and b in self._run_of:
                    q = self._pending.get(arr)
                    if not q:
                        break
                    etok, erun = q.popleft()
                    gen = self._gen
                    blocks, failure = self._issue_outside_lock(arr, erun)
                    if gen != self._gen:
                        break  # reset() raced the read: plan state is gone
                    if failure is not None:
                        self._fail_run_locked(etok, erun, failure)
                    elif blocks is not None:
                        for i, blk in enumerate(blocks):
                            self._ready[erun.start + i] = blk
            else:
                while (b not in self._ready and not self._stop
                       and b in self._run_of):
                    if self._ready_runs.get(arr, 0) >= self._qd_of(arr):
                        # With fused cross-hop plans this array's pool can
                        # hold a full queue_depth of undrained tail runs
                        # while b's run is still queued behind them;
                        # waiting would deadlock the consumer against its
                        # own slots.  Steal the queued run and execute it
                        # inline, dropping the lock for the read (an
                        # admission-bound acquire may block, and holding
                        # ``_cv`` across it would wedge the pool).
                        q = self._pending.get(arr, ())
                        entry = next((e for e in q if e[0] == tok), None)
                        if entry is not None:
                            self._pending[arr].remove(entry)
                            self._ready_runs[arr] = \
                                self._ready_runs.get(arr, 0) + 1  # balanced below
                            gen = self._gen
                            blocks, failure = self._issue_outside_lock(
                                arr, entry[1])
                            if gen != self._gen:
                                break  # reset() raced: don't publish
                            if failure is not None:
                                # same fail-fast contract as a worker
                                # read: _guarded_read already retried
                                # transients, so anything surfacing here
                                # is permanent — stash it so this (and
                                # later) fetches re-raise it
                                self._fail_run_locked(tok, entry[1], failure)
                            elif blocks is not None:
                                for i, blk in enumerate(blocks):
                                    self._ready[entry[1].start + i] = blk
                            continue
                    # a failed worker read unplans the run, so also wake
                    # on b leaving the plan (fail fast) and on the pool
                    # saturating while b's run is still queued (steal)
                    if not self._cv.wait_for(
                            lambda: b in self._ready or self._stop
                            or b not in self._run_of
                            or (self._ready_runs.get(arr, 0) >= self._qd_of(arr)
                                and any(e[0] == tok
                                        for e in self._pending.get(arr, ()))),
                            timeout=max(deadline - time.monotonic(), 0.0)):
                        break  # timed out
            blk = self._ready.pop(b, None)
            self._run_of.pop(b, None)
            failure = self._error_of.pop(b, None) if blk is None else None
            # release b's share of the run's queue-depth slot whether or
            # not the block was delivered (timeout/close must not leak
            # slots and wedge the reader pool until the next reset)
            if tok in self._remaining:
                left = self._remaining[tok] - 1
                if left <= 0:
                    self._remaining.pop(tok, None)
                    a = self._tok_array.pop(tok, arr)
                    self._ready_runs[a] = max(self._ready_runs.get(a, 0) - 1, 0)
                else:
                    self._remaining[tok] = left
            self._cv.notify_all()
            if failure is not None:
                raise failure  # classified permanent error, sentinel seam
            return blk  # None -> caller falls back to a direct read

    # alias kept for symmetry with BlockPrefetcher's non-blocking API
    take = fetch

    @property
    def idle(self) -> bool:
        """True when no submitted plan remains undelivered.

        The online re-placement path (``core/migration.py``) swaps the
        store's :class:`~repro.core.topology.BlockPlacement` between
        epochs; an idle reader guarantees no in-flight run was split
        against the outgoing mapping.  ``reset()`` forces idleness.
        """
        with self._cv:
            return not self._run_of and not any(self._pending.values())

    def reset(self) -> None:
        """Drop any undelivered plan state and close the fused stream.

        This is the explicit barrier: hop boundaries on the unfused
        compat path, session end on the fused path.
        """
        with self._cv:
            self._gen += 1
            self._pending.clear()
            self._ready.clear()
            self._run_of.clear()
            self._remaining.clear()
            self._tok_array.clear()
            self._ready_runs.clear()
            self._error_of.clear()
            self._cv.notify_all()
        if self.admission is not None:
            # queued-but-never-granted backlog leaves the admission
            # books; granted in-flight runs complete normally
            self.admission.cancel_pending(self.tenant)
        if self.stream is not None:
            self.stream.drain()

    def set_queue_depth(self, queue_depth: int, array: int | None = None) -> None:
        """Adaptive scheduler hook: resize the in-flight run budget.

        ``array=None`` sets the uniform depth (clearing any per-array
        overrides); an explicit ``array`` resizes that array's queue
        independently — the per-array knob the striping sweep exercises.
        Safe while runs are in flight: workers and stealing consumers
        re-read the depth on every wakeup.
        """
        with self._cv:
            qd = max(int(queue_depth), 1)
            if array is None:
                self.queue_depth = qd
                self._qd.clear()
            else:
                self._qd[int(array)] = qd
            self._cv.notify_all()

    def close(self) -> None:
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        for t in self._threads:
            t.join(timeout=2.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------ internals
    def _fail_run_locked(self, tok: int, run: Run,
                         exc: BaseException | None) -> None:
        """Stash a run's classified-permanent error for every block it
        still owns, then release its slot.  Waiting consumers wake, find
        the block unplanned, and re-raise the stashed error from
        ``fetch`` — the sentinel seam — instead of silently falling back
        to direct reads."""
        if exc is not None:
            for b in range(run.start, run.stop):
                if self._run_of.get(b) == tok:
                    self._error_of[b] = exc
        self._unplan_locked(tok, run)

    def _unplan_locked(self, tok: int, run: Run) -> None:
        """Release a failed run's slot and drop the blocks it still owns."""
        a = self._tok_array.pop(tok, 0)
        self._ready_runs[a] = max(self._ready_runs.get(a, 0) - 1, 0)
        self._remaining.pop(tok, None)
        for b in range(run.start, run.stop):
            if self._run_of.get(b) == tok:  # a resubmission may own b now
                self._run_of.pop(b, None)
                self._ready.pop(b, None)

    # ------------------------------------------------------------ fault domain
    def _device_of(self, array: int):
        topo = getattr(self.store, "topology", None)
        if topo is not None and self._placement() is not None:
            return topo.devices[array]
        return self.store.device

    def _nominal_run_time(self, array: int, run: Run) -> float:
        return self._device_of(array).request_time(
            run.count * self.store.block_size)

    def _account_fault(self, array: int, run: Run, t: float,
                       kind: str) -> None:
        acct = getattr(self.store, "account_fault_io", None)
        if acct is not None:  # duck-typed test stores may not account
            acct(array, run.count * self.store.block_size, run.count,
                 t, kind)
        tel = self.telemetry
        if tel is not None:
            m = self._m_fault.get(kind)
            if m is not None:
                m.inc()
            tr = tel.trace
            if tr is not None:
                tr.instant(f"{self._tel_store}.{kind}", "io.fault",
                           f"array:{array}",
                           args={"start": run.start, "count": run.count,
                                 "modeled_s": round(t, 9),
                                 "tenant": self._tel_tenant})

    def _guarded_read(self, array: int, run: Run):
        """Execute one run's real read under the classified fault policy.

        * injected or real *transient* errors retry up to ``retries``
          times with exponential backoff + jitter, each re-issue charged
          like any other request plus the modeled backoff stall;
        * a latency-spike straggler past the p99-derived hedge deadline
          duplicates the read on the least-busy sibling array
          (``_note_service_time``);
        * an array *dropout* marks the array offline in the topology and
          re-reads through the survivors' recovery path
          (``_read_degraded``) — training continues degraded;
        * *permanent* errors (index/decode bugs, exhausted retries)
          propagate to the caller, which stashes them for ``fetch``.
        """
        store = self.store
        topo = getattr(store, "topology", None)
        has_arrays = topo is not None and self._placement() is not None
        if has_arrays and not topo.is_online(array):
            return self._read_degraded(array, run)
        fault = getattr(store, "fault", None)
        attempt = 0
        while True:
            try:
                mult = (fault.on_read(array, run.start, run.count)
                        if fault is not None else 1.0)
                blocks = store.read_run(run.start, run.count)
            except Exception as exc:
                kind = classify_error(exc)
                self._account_fault(array, run, 0.0, "error")
                if kind == "offline" and has_arrays:
                    topo.mark_offline(getattr(exc, "array", array))
                    return self._read_degraded(array, run)
                if kind == "transient" and attempt < self.retries:
                    attempt += 1
                    self._charge_retry(array, run, attempt)
                    continue
                if kind == "transient":
                    raise PermanentIOError(
                        errno.EIO,
                        f"transient fault persisted past {self.retries} "
                        f"retries on run {run.start}+{run.count}: "
                        f"{exc}") from exc
                raise
            self._note_service_time(array, run, mult)
            return blocks

    def _charge_retry(self, array: int, run: Run, attempt: int) -> None:
        """Charge one re-issue: full run bytes again, plus the modeled
        exponential backoff (jittered to 0.5-1.5x) as stall time."""
        backoff = self.retry_backoff_s * (2 ** (attempt - 1))
        backoff *= 0.5 + float(self._rng.random())
        t = self._nominal_run_time(array, run) + backoff
        self._account_fault(array, run, t, "retry")

    def _note_service_time(self, array: int, run: Run, mult: float) -> None:
        """Track per-array nominal run times for the p99 hedge deadline
        and settle a latency-spiked run: hedge past the deadline, expose
        the stall otherwise."""
        nominal = self._nominal_run_time(array, run)
        dq = self._svc_times.setdefault(array, deque(maxlen=128))
        deadline = None
        if len(dq) >= 16 and self.hedge_deadline_frac > 0:
            deadline = float(np.quantile(np.fromiter(dq, dtype=np.float64),
                                         0.99)) * self.hedge_deadline_frac
        dq.append(nominal)
        if mult <= 1.0:
            return
        spiked = nominal * mult
        if deadline is not None and spiked > deadline + nominal:
            # hedge: at the deadline, duplicate the read to the
            # least-busy sibling array (or the same device's direct path
            # when there is no sibling); completion is whichever copy
            # finishes first, so the effective extra time over nominal
            # is min(straggler, deadline + duplicate) - nominal, charged
            # with the duplicate's bytes on the hedge target
            target = self._hedge_target(array)
            effective = min(spiked,
                            deadline + self._nominal_run_time(target, run))
            self._account_fault(target, run,
                                max(effective - nominal, 0.0), "hedge")
        else:
            # below the deadline (or no history yet): the spike is fully
            # exposed as stall time on the straggling array
            self._account_fault(array, run, max(spiked - nominal, 0.0),
                                "stall")

    def _hedge_target(self, array: int) -> int:
        topo = getattr(self.store, "topology", None)
        if topo is not None and self._placement() is not None:
            cands = [a for a in range(topo.n_arrays)
                     if a != array and topo.is_online(a)]
            if cands:
                with topo.lock:
                    return min(cands, key=lambda a:
                               topo.array_stats[a].modeled_io_time)
        return array  # single array: direct-path duplicate

    def _read_degraded(self, array: int, run: Run):
        """Serve a run whose array is offline.  The bytes come through
        the survivors' recovery path (parity/replica reconstruction in a
        real array; here the shared memmap, which is why byte parity
        holds).  The modeled *time* was charged at submission —
        ``account_runs`` reroutes offline-array runs onto the surviving
        arrays' batched rooflines — so the read itself adds no time;
        here we only tick the degraded counters against the survivor
        that fronts the recovery path, counting reads actually *served*
        degraded (a run can be submitted healthy and land after the
        dropout, or vice versa)."""
        topo = getattr(self.store, "topology", None)
        target = topo.degraded_target() if topo is not None else array
        self._account_fault(target, run, 0.0, "degraded")
        return self.store.read_run(run.start, run.count)

    def _pop_eligible_locked(self):
        """Next (tok, run) from any array with pending work and a free
        slot, round-robin across arrays for fairness.  None if no array
        is eligible."""
        arrays = [a for a, q in self._pending.items()
                  if q and self._ready_runs.get(a, 0) < self._qd_of(a)]
        if not arrays:
            return None
        arrays.sort()
        a = arrays[self._rr % len(arrays)]
        self._rr += 1
        tok, run = self._pending[a].popleft()
        self._ready_runs[a] = self._ready_runs.get(a, 0) + 1  # reserve slot
        return tok, run

    def _worker(self) -> None:
        while True:
            with self._cv:
                entry = None
                while entry is None:
                    self._cv.wait_for(
                        lambda: self._stop
                        or any(q and self._ready_runs.get(a, 0) < self._qd_of(a)
                               for a, q in self._pending.items()))
                    if self._stop:
                        return
                    entry = self._pop_eligible_locked()
                gen = self._gen
                tok, run = entry
                arr = self._tok_array.get(tok, 0)
            blocks, failure = None, None
            try:
                blocks = self._issue_read(arr, run)
            except Exception as exc:
                # transient faults were already retried (with backoff)
                # inside _guarded_read; what reaches here is classified
                # permanent — the worker survives, the error does too
                failure = exc
            with self._cv:
                if gen != self._gen or self._stop:
                    continue  # stale: reset() already zeroed the counters
                if blocks is None:
                    # failed read: stash the error per block, release the
                    # slot and unplan the run so waiting consumers fail
                    # fast by re-raising it from fetch()
                    self._fail_run_locked(tok, run, failure)
                else:
                    for i, blk in enumerate(blocks):
                        self._ready[run.start + i] = blk
                self._cv.notify_all()
