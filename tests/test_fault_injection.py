"""Storage fault domain (core/fault.py + the reader/journal/degraded paths).

Covers:

* the schedule grammar + error taxonomy (parse/validate/classify);
* injector mechanics — deterministic ``at=`` firings, ``count`` caps,
  sticky dropout, latency multipliers, torn journal writes;
* the reader's classified handling: bounded retry with byte-exact
  accounting, retry exhaustion propagating as ``PermanentIOError``
  through the sentinel seam, p99-deadline hedging;
* degraded-array mode end to end: byte parity while an array is dark,
  epoch-boundary evacuation, no residual degraded traffic afterwards;
* a seeded schedule battery (always on; hypothesis widens it when the
  package is installed): engine vs fault-free twin, per-minibatch
  feature/MFG parity — no dropped or duplicated rows under arbitrary
  seeded fault schedules.  ``REPRO_SLOW=1`` raises the battery width.
"""
import os

import numpy as np
import pytest

from repro.core import (AgnesConfig, AgnesEngine, ArrayOfflineError,
                        CoalescedReader, FaultInjector, FaultRule,
                        PermanentIOError, StorageTopology, StripePlacement,
                        TornWriteError, TransientIOError, classify_error,
                        recover_store_metadata)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

SLOW = os.environ.get("REPRO_SLOW", "0") == "1"
N_SEEDS = 12 if SLOW else 6          # seeded battery width
HYP_EXAMPLES = 25 if SLOW else 10    # hypothesis example budget


# ---------------------------------------------------------------- harness
def striped_store(ds, topo, persist=False):
    _, f = ds.reopen_stores()
    f.attach_topology(topo, StripePlacement(1).place(f.n_blocks, topo),
                      persist=persist)
    return f


def engine_for(ds, topo, **over):
    g, f = ds.reopen_stores()
    cfg = AgnesConfig(block_size=16384, minibatch_size=64,
                      hyperbatch_size=4, fanouts=(), feature_cache_rows=1,
                      graph_buffer_bytes=1 << 20,
                      feature_buffer_bytes=1 << 20, async_io=False,
                      placement="stripe", **over)
    return AgnesEngine(g, f, cfg, topology=topo)


def assert_parity(faulty, clean):
    """Per-minibatch byte parity: no dropped, duplicated or torn rows."""
    assert len(faulty) == len(clean)
    for a, b in zip(faulty, clean):
        assert np.array_equal(a.features, b.features)
        for x, y in zip(a.mfg.nodes, b.mfg.nodes):
            assert np.array_equal(x, y)


# ---------------------------------------------------------------- grammar
def test_parse_full_schedule():
    inj = FaultInjector.parse(
        "transient:p=0.01;latency:p=0.005,factor=30;"
        "dropout:array=3,at=400;torn:at=0,count=1", seed=7)
    kinds = [r.kind for r in inj.rules]
    assert kinds == ["transient", "latency", "dropout", "torn"]
    assert inj.rules[1].factor == 30.0
    assert inj.rules[2].array == 3 and inj.rules[2].at == 400
    assert inj.rules[3].count == 1
    assert inj.spec.startswith("transient:")
    # idempotent: an injector passes through parse unchanged
    assert FaultInjector.parse(inj) is inj


@pytest.mark.parametrize("bad", [
    "meteor:p=0.5",                 # unknown kind
    "transient:q=0.5",              # unknown parameter
    "dropout:at=3",                 # dropout needs array=
    "",                             # empty schedule
    ";;",                           # empty after splitting
])
def test_parse_rejects_malformed(bad):
    with pytest.raises(ValueError):
        FaultInjector.parse(bad)


def test_classify_error_taxonomy():
    import errno
    assert classify_error(TransientIOError(errno.EIO, "x")) == "transient"
    assert classify_error(ArrayOfflineError(2)) == "offline"
    assert classify_error(PermanentIOError(errno.EIO, "x")) == "permanent"
    assert classify_error(TornWriteError(errno.EIO, "x")) == "permanent"
    assert classify_error(OSError(errno.EAGAIN, "again")) == "transient"
    assert classify_error(OSError(errno.EACCES, "denied")) == "permanent"
    assert classify_error(IndexError("bug")) == "permanent"
    assert ArrayOfflineError(5).array == 5
    with pytest.raises(ValueError):
        FaultRule(kind="dropout")   # no array


# ---------------------------------------------------------------- injector
def test_transient_fires_at_op_index():
    inj = FaultInjector.parse("transient:at=2")
    assert inj.on_read(0) == 1.0          # op 0
    assert inj.on_read(0) == 1.0          # op 1
    with pytest.raises(TransientIOError):
        inj.on_read(0)                    # op 2
    assert inj.on_read(0) == 1.0          # op 3: one-shot
    assert inj.counters["transient"] == 1


def test_transient_count_caps_firings():
    inj = FaultInjector.parse("transient:p=1,count=2")
    for _ in range(2):
        with pytest.raises(TransientIOError):
            inj.on_read(0)
    for _ in range(8):                    # exhausted: clean forever after
        assert inj.on_read(0) == 1.0
    assert inj.counters["transient"] == 2


def test_dropout_is_sticky_and_array_scoped():
    inj = FaultInjector.parse("dropout:array=1,at=3")
    for _ in range(3):                    # ops 0-2: below the trigger
        assert inj.on_read(1) == 1.0
    with pytest.raises(ArrayOfflineError) as ei:
        inj.on_read(1)                    # op 3: the array drops
    assert ei.value.array == 1
    with pytest.raises(ArrayOfflineError):
        inj.on_read(1)                    # sticky from here on
    assert inj.on_read(0) == 1.0          # other arrays unaffected
    assert inj.counters["dropout"] == 1   # one dropout event, not per-op


def test_latency_multiplier_and_summary():
    inj = FaultInjector.parse("latency:at=0,factor=30", seed=1)
    assert inj.on_read(0) == 30.0
    assert inj.on_read(0) == 1.0
    s = inj.summary()
    assert s["read_ops"] == 2 and s["fired"]["latency"] == 1
    assert s["seed"] == 1 and s["schedule"].startswith("latency:")


# ---------------------------------------------------------------- reader
def test_reader_retries_transient_with_exact_accounting(tiny_ds):
    _, f = tiny_ds.reopen_stores()
    f.attach_fault(FaultInjector.parse("transient:at=0", seed=3))
    with CoalescedReader(f, max_coalesce_bytes=8 << 20, queue_depth=2,
                         workers=0, retries=2) as rd:
        rd.plan([0, 1])                   # one 2-block run; first try fails
        blk = rd.fetch(0, timeout=5.0)
        assert blk is not None            # retried to success
        assert np.array_equal(blk, f.read_block(0))
        assert rd.fetch(1, timeout=5.0) is not None
    assert f.stats.io_errors == 1
    assert f.stats.io_retries == 1
    # the re-issue is charged byte-exact: the full run read a second time
    assert f.stats.bytes_retried == 2 * f.block_size
    assert f.stats.modeled_read_time > 0


def test_reader_retry_exhaustion_is_permanent(tiny_ds):
    _, f = tiny_ds.reopen_stores()
    f.attach_fault(FaultInjector.parse("transient:p=1"))
    with CoalescedReader(f, max_coalesce_bytes=8 << 20, queue_depth=2,
                         workers=0, retries=2) as rd:
        rd.plan([0])
        with pytest.raises(PermanentIOError, match="persisted past 2"):
            rd.fetch(0, timeout=5.0)
    assert f.stats.io_errors == 3         # initial attempt + 2 retries
    assert f.stats.io_retries == 2


def test_reader_hedges_stragglers_past_p99_deadline(tiny_ds):
    """With hedging on, a latency spike costs ~the deadline plus a
    duplicate read; with it off the spike is fully exposed as stall."""
    def run(frac):
        topo = StorageTopology.uniform(2)
        f = striped_store(tiny_ds, topo)
        f.attach_fault(FaultInjector.parse("latency:p=0.5,factor=200",
                                           seed=5))
        with CoalescedReader(f, max_coalesce_bytes=0, queue_depth=4,
                             workers=0, hedge_deadline_frac=frac) as rd:
            for _ in range(3):            # enough history for the p99
                for b in range(f.n_blocks):
                    rd.plan([b])
                    assert rd.fetch(b, timeout=5.0) is not None
        return f

    hedged = run(1.5)
    exposed = run(0.0)                    # hedging disabled
    assert hedged.stats.io_hedges > 0
    # single-block runs: every hedge duplicates exactly one block
    assert hedged.stats.bytes_hedged == \
        hedged.stats.io_hedges * hedged.block_size
    assert exposed.stats.io_hedges == 0
    # identical seeded spikes, so the comparison isolates the hedge:
    # capping stragglers at the deadline must beat eating them whole
    assert hedged.stats.modeled_read_time < exposed.stats.modeled_read_time


# ---------------------------------------------------------------- journal
def test_injected_torn_write_rolls_back(tiny_ds):
    topo = StorageTopology.uniform(2)
    f = striped_store(tiny_ds, topo, persist=True)
    before = np.array(f.placement.array_of)
    snapshot = [f.read_block_bytes(b) for b in range(f.n_blocks)]
    f.attach_fault(FaultInjector.parse("torn:at=0", seed=11))
    victim = int(np.nonzero(before == 1)[0][0])
    with pytest.raises(TornWriteError):
        f.migrate_blocks([(victim, 0)])
    journal = f.path + ".migrate.log"
    assert os.path.exists(journal)        # the torn tail survived the kill
    removed = recover_store_metadata(f.path)
    assert removed[".migrate.log"] == "rolled_back"
    assert not os.path.exists(journal)
    # in-memory and reloaded placement both still the old mapping
    assert np.array_equal(f.placement.array_of, before)
    _, f2 = tiny_ds.reopen_stores()
    assert np.array_equal(f2.load_placement(topo).array_of, before)
    for b in range(f2.n_blocks):
        assert f2.read_block_bytes(b) == snapshot[b]


# ---------------------------------------------------------------- engine
def test_engine_transient_latency_parity_and_counters(tiny_ds, rng):
    topo_c, topo_f = StorageTopology.uniform(2), StorageTopology.uniform(2)
    clean = engine_for(tiny_ds, topo_c)
    faulty = engine_for(
        tiny_ds, topo_f, io_retries=8,
        fault_schedule="transient:p=0.2;latency:p=0.2,factor=25")
    targets = [rng.choice(256, 64, replace=False) for _ in range(4)]
    assert_parity(faulty.prepare(targets, epoch=0),
                  clean.prepare(targets, epoch=0))
    faults = faulty.io_stats()["faults"]
    assert faults["injected"]["fired"]["transient"] > 0
    assert faults["io_errors"] > 0 and faults["io_retries"] > 0
    assert faults["bytes_retried"] > 0
    assert faults["injected"]["read_ops"] > 0
    assert "faults" not in clean.io_stats()
    clean.close()
    faulty.close()


def test_engine_dropout_degraded_then_evacuates(tiny_ds, rng):
    topo_c, topo_f = StorageTopology.uniform(2), StorageTopology.uniform(2)
    clean = engine_for(tiny_ds, topo_c)
    faulty = engine_for(tiny_ds, topo_f,
                        fault_schedule="dropout:array=1,at=0",
                        migrate_budget_bytes=64 << 20)
    targets = [rng.choice(256, 64, replace=False) for _ in range(4)]
    # the array goes dark on its first read; training continues at byte
    # parity through the survivors' recovery path
    assert_parity(faulty.prepare(targets, epoch=0),
                  clean.prepare(targets, epoch=0))
    faults = faulty.io_stats()["faults"]
    assert faults["offline_arrays"] == [1]
    assert faults["io_degraded"] > 0 and faults["bytes_degraded"] > 0
    # epoch boundary: evacuation drains every stranded block
    rep = faulty.end_epoch()
    assert rep is not None and "recovery" in rep
    assert rep["recovery"]["feature"]["n_moved"] > 0
    for store in (faulty.graph_store, faulty.feature_store):
        assert not np.any(store.placement.array_of == 1), \
            "blocks still stranded on the offline array after evacuation"
    # steady degraded state: nothing lives on the dead array any more,
    # so a second epoch adds no degraded read traffic — and stays exact
    clean.end_epoch()
    d0 = faulty.io_stats()["faults"]["io_degraded"]
    t2 = [rng.choice(256, 64, replace=False) for _ in range(2)]
    assert_parity(faulty.prepare(t2, epoch=1), clean.prepare(t2, epoch=1))
    assert faulty.io_stats()["faults"]["io_degraded"] == d0
    clean.close()
    faulty.close()


# ------------------------------------------------------- property battery
def _random_schedule(rng):
    parts = [f"transient:p={rng.uniform(0.02, 0.2):.3f}",
             f"latency:p={rng.uniform(0.02, 0.3):.3f},"
             f"factor={int(rng.integers(5, 60))}"]
    if rng.random() < 0.5:
        parts.append(f"dropout:array={int(rng.integers(0, 2))},"
                     f"at={int(rng.integers(0, 40))}")
    return ";".join(parts)


def _assert_schedule_parity(tiny_ds, spec, seed, rng):
    """Engine under an adversarial schedule vs its fault-free twin:
    byte parity every minibatch, through recovery, across epochs."""
    clean = engine_for(tiny_ds, StorageTopology.uniform(2))
    faulty = engine_for(tiny_ds, StorageTopology.uniform(2),
                        fault_schedule=spec, io_retries=10, seed=seed,
                        migrate_budget_bytes=64 << 20)
    try:
        for epoch in range(2):
            targets = [rng.choice(256, 64, replace=False)
                       for _ in range(3)]
            assert_parity(faulty.prepare(targets, epoch=epoch),
                          clean.prepare(targets, epoch=epoch))
            faulty.end_epoch()            # evacuates after any dropout
            clean.end_epoch()
        assert faulty.io_stats()["faults"]["injected"]["read_ops"] > 0
    finally:
        clean.close()
        faulty.close()


def test_fault_schedule_battery_seeded(tiny_ds):
    """Always-on randomized battery (hypothesis-free fallback)."""
    for seed in range(N_SEEDS):
        rng = np.random.default_rng(4000 + seed)
        _assert_schedule_parity(tiny_ds, _random_schedule(rng), seed, rng)


if HAVE_HYPOTHESIS:

    @given(st.integers(min_value=0, max_value=2**31 - 1))
    @settings(max_examples=HYP_EXAMPLES, deadline=None)
    def test_fault_schedule_parity_hypothesis(tiny_ds, seed):
        rng = np.random.default_rng(seed)
        _assert_schedule_parity(tiny_ds, _random_schedule(rng),
                                seed % 1000, rng)
