import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) on the
production meshes, proving the distribution config is coherent without
real hardware.

Per cell:
  * build the model + input ShapeDtypeStructs (no allocation),
  * jit with in/out shardings from ``repro.distributed.sharding``,
  * ``.lower().compile()`` on the (16,16) single-pod mesh and (with
    ``--multi-pod``) the (2,16,16) 512-chip mesh,
  * record ``memory_analysis()`` (fits-per-device proof) and
    ``cost_analysis()`` + parsed collective bytes (roofline inputs)
    into ``results/dryrun_<mesh>.json`` for EXPERIMENTS.md.

Skips (recorded, per assignment):
  * ``long_500k`` for pure full-attention archs (no sub-quadratic
    structure): smollm, minitron, qwen2-vl, moonshot, deepseek, seamless.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-27b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import SHAPES, get_config, list_configs
from ..distributed.sharding import (batch_sharding, cache_shardings,
                                    opt_state_shardings, param_shardings)
from ..compat import set_mesh
from ..models import build_model
from ..train.loop import make_serve_step, make_train_step
from ..train.optimizer import adamw_init
from .mesh import make_production_mesh

# archs whose every layer is full (non-windowed, non-recurrent) attention:
# a 524k-token KV has no sub-quadratic structure to exploit -> skip, per
# the assignment, with the reason recorded in the results table.
FULL_ATTENTION_ONLY = {
    "smollm-360m", "minitron-4b", "qwen2-vl-2b", "moonshot-v1-16b-a3b",
    "deepseek-moe-16b", "seamless-m4t-large-v2",
}

N_MICRO = {"train": 8}          # grad-accumulation microbatches
VLM_PREFIX = 256                # stubbed vision patches (qwen2-vl)


def input_specs(arch: str, shape_name: str, *, batch_override=None,
                n_micro: int | None = None) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of one cell.

    Train batches carry a leading microbatch axis (n_micro, micro_b, S)
    so gradient accumulation is a plain scan over axis 0 while axis 1
    stays data-sharded.
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    if batch_override:
        B = batch_override
    sd = jax.ShapeDtypeStruct

    def _batched(leaves: dict) -> dict:
        if shape.kind != "train":
            return leaves
        nm = n_micro if n_micro is not None else N_MICRO["train"]
        while B % nm:
            nm -= 1
        return {k: sd((nm, B // nm) + v.shape[1:], v.dtype)
                for k, v in leaves.items()}

    if shape.kind in ("train", "prefill"):
        specs = {"tokens": sd((B, S), jnp.int32)}
        if cfg.n_enc_layers:
            specs["src_embeds"] = sd((B, min(S, cfg.enc_seq), cfg.d_model),
                                     jnp.bfloat16)
        if cfg.frontend == "vision_stub":
            specs = {"tokens": sd((B, S - VLM_PREFIX), jnp.int32),
                     "prefix_embeds": sd((B, VLM_PREFIX, cfg.d_model),
                                         jnp.bfloat16)}
        return _batched(specs)
    # decode: one new token against a seq_len KV cache
    return {"tokens": sd((B,), jnp.int32),
            "pos": sd((), jnp.int32)}


def should_skip(arch: str, shape_name: str) -> str | None:
    if shape_name == "long_500k" and arch in FULL_ATTENTION_ONLY:
        return ("skip: pure full-attention stack — 524k KV decode has no "
                "sub-quadratic structure (DESIGN.md §4)")
    return None


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of collective ops in post-SPMD optimized HLO."""
    import re
    sizes = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
             "all-to-all": 0, "collective-permute": 0}
    counts = dict.fromkeys(sizes, 0)
    dt_bytes = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8,
                "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1}
    pat = re.compile(
        r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^\s]*)\s+"
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
        r"(?:-start|-done)?\(")
    for m in pat.finditer(hlo_text):
        op = m.group(4)
        shapes = []
        if m.group(1) is not None:   # tuple result
            for part in m.group(1).split(","):
                part = part.strip()
                mm = re.match(r"(\w+)\[([\d,]*)\]", part)
                if mm:
                    shapes.append(mm.groups())
        else:
            shapes.append((m.group(2), m.group(3)))
        for dt, dims in shapes:
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            sizes[op] += n * dt_bytes.get(dt, 4)
        counts[op] += 1
    sizes = {k: v for k, v in sizes.items()}
    return {"bytes": sizes, "counts": counts,
            "total_bytes": sum(sizes.values())}


def lower_cell(arch: str, shape_name: str, mesh, *,
               with_opt: bool = True, unroll_inner: bool = False,
               n_layers_override: int | None = None,
               scan_layers: bool | None = None,
               n_micro: int | None = None,
               cfg_overrides: dict | None = None,
               enc_layers_override: int | None = None,
               attn_impl: str | None = None,
               fsdp_threshold: int | None = None,
               batch_override: int | None = None,
               compile_: bool = True) -> dict:
    """Lower (and compile) one (arch × shape × mesh) cell; return record."""
    import dataclasses
    cfg = get_config(arch)
    if n_layers_override is not None:
        o, p, k, t = cfg.stack_plan()
        n_new = n_layers_override
        layers = cfg.layers[:o] + cfg.layers[o:o + p] * ((n_new - o - t) // p) \
            + cfg.layers[len(cfg.layers) - t:]
        cfg = dataclasses.replace(cfg, n_layers=len(layers),
                                  layers=tuple(layers))
    if enc_layers_override is not None and cfg.n_enc_layers:
        cfg = dataclasses.replace(cfg, n_enc_layers=enc_layers_override)
    if scan_layers is not None:
        cfg = dataclasses.replace(cfg, scan_layers=scan_layers)
    if cfg_overrides:
        cfg = dataclasses.replace(
            cfg, **{k2: v for k2, v in cfg_overrides.items()
                    if k2 not in ("scan_layers",)})
    model = build_model(cfg)
    shape = SHAPES[shape_name]
    specs = input_specs(arch, shape_name, n_micro=n_micro,
                        batch_override=batch_override)
    pspecs = model.param_specs()
    pkw = {"ep_only": cfg.dp_over_model}
    if fsdp_threshold is not None:
        pkw["fsdp_threshold"] = fsdp_threshold
    pshard = param_shardings(pspecs, mesh, **pkw)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "x".join(str(s) for s in mesh.devices.shape),
           "n_layers": cfg.n_layers}
    t0 = time.time()
    with set_mesh(mesh):
        if shape.kind == "train":
            nm = next(iter(specs.values())).shape[0]
            # 100B+ models: bf16 moments (memory budget at 16 GB/chip;
            # production would add stochastic rounding)
            mdt = jnp.bfloat16 if cfg.param_count() > 1e11 else jnp.float32
            ostate = jax.eval_shape(lambda p: adamw_init(p, dtype=mdt),
                                    pspecs)
            oshard = opt_state_shardings(ostate, mesh)
            oshard = type(ostate)(
                step=jax.tree.map(
                    lambda _: jax.sharding.NamedSharding(
                        mesh, jax.sharding.PartitionSpec()), ostate.step),
                mu=oshard.mu, nu=oshard.nu)
            bshard = jax.tree.map(
                lambda s: batch_sharding(mesh, ndim=len(s.shape),
                                         batch_axis=1,
                                         dp_over_model=cfg.dp_over_model),
                specs)
            step = make_train_step(model, n_microbatches=nm,
                                   unroll_inner=unroll_inner,
                                   unroll_microbatches=unroll_inner,
                                   attn_impl=attn_impl)
            jitted = jax.jit(step,
                             in_shardings=(pshard, oshard, bshard),
                             out_shardings=(pshard, oshard, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(pspecs, ostate, specs)
        elif shape.kind == "prefill":
            bshard = jax.tree.map(
                lambda s: batch_sharding(mesh, ndim=len(s.shape)), specs)

            def prefill(params, batch):
                if cfg.n_enc_layers:
                    return model.loss(params, batch,
                                      unroll_inner=unroll_inner,
                                      attn_impl=attn_impl)
                h, _ = model.hidden_states(
                    params, batch["tokens"], batch.get("prefix_embeds"),
                    unroll_inner=unroll_inner, attn_impl=attn_impl)
                return h
            jitted = jax.jit(prefill, in_shardings=(pshard, bshard),
                             out_shardings=None)
            lowered = jitted.lower(pspecs, specs)
        else:  # decode
            B = shape.global_batch
            if cfg.n_enc_layers:
                cspecs = jax.eval_shape(
                    lambda: model.init_cache(B, shape.seq_len))
            else:
                cspecs = model.cache_specs(B, shape.seq_len)
            cshard = cache_shardings(cspecs, mesh, B)
            tshard = batch_sharding(mesh, ndim=1) if B > 1 else \
                jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
            serve = make_serve_step(model)
            jitted = jax.jit(
                serve,
                in_shardings=(pshard, cshard, tshard, None),
                out_shardings=(tshard, None, cshard),
                donate_argnums=(1,))
            lowered = jitted.lower(
                pspecs, cspecs,
                jax.ShapeDtypeStruct((B,), jnp.int32),
                jax.ShapeDtypeStruct((), jnp.int32))
        rec["lower_s"] = round(time.time() - t0, 2)
        if not compile_:
            rec["lowered"] = lowered
            return rec
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0 - rec["lower_s"], 2)
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "args_GiB_per_dev": round(ma.argument_size_in_bytes / 2**30, 3),
            "temp_GiB_per_dev": round(ma.temp_size_in_bytes / 2**30, 3),
            "out_GiB_per_dev": round(ma.output_size_in_bytes / 2**30, 3),
            "alias_GiB_per_dev": round(ma.alias_size_in_bytes / 2**30, 3),
        }
        ca = compiled.cost_analysis() or {}
        rec["cost"] = {"flops": float(ca.get("flops", 0.0)),
                       "bytes": float(ca.get("bytes accessed", 0.0))}
        rec["collectives"] = collective_bytes(compiled.as_text())
        return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="results")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    mesh_tag = "2x16x16" if args.multi_pod else "16x16"
    archs = [args.arch] if args.arch else list_configs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    os.makedirs(args.out, exist_ok=True)
    out_path = os.path.join(args.out, f"dryrun_{mesh_tag}.json")
    results = []
    if os.path.exists(out_path):
        results = json.load(open(out_path))
    done = {(r["arch"], r["shape"]) for r in results}

    for arch in archs:
        for shape_name in shapes:
            if (arch, shape_name) in done and not args.arch:
                continue
            skip = should_skip(arch, shape_name)
            if skip:
                rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                       "status": skip}
                print(f"[dryrun] {arch} x {shape_name}: {skip}")
            else:
                print(f"[dryrun] {arch} x {shape_name} on {mesh_tag} ...",
                      flush=True)
                try:
                    rec = lower_cell(arch, shape_name, mesh)
                    rec["status"] = "ok"
                    print(f"  ok: lower {rec['lower_s']}s "
                          f"compile {rec['compile_s']}s "
                          f"temp/dev {rec['memory']['temp_GiB_per_dev']} GiB "
                          f"flops {rec['cost']['flops']:.3e} "
                          f"coll {rec['collectives']['total_bytes']:.3e}B",
                          flush=True)
                except Exception as e:  # noqa: BLE001 — record and continue
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_tag, "status": "FAIL",
                           "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                    print(f"  FAIL: {type(e).__name__}: {str(e)[:300]}",
                          flush=True)
            results = [r for r in results
                       if not (r["arch"] == arch and r["shape"] == shape_name)]
            results.append(rec)
            with open(out_path, "w") as f:
                json.dump(results, f, indent=1, default=str)
    n_ok = sum(r.get("status") == "ok" for r in results)
    n_skip = sum(str(r.get("status", "")).startswith("skip") for r in results)
    n_fail = sum(r.get("status") == "FAIL" for r in results)
    print(f"[dryrun] {mesh_tag}: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
