"""GNN training on AGNES-prepared minibatches (the paper's computation stage).

The trainer consumes :class:`PreparedMinibatch` objects from any engine
(AGNES or a baseline), pads them to jit-stable shapes, and runs the jitted
train step.  Stage timing is recorded so benchmarks can reproduce the
paper's Fig-2 breakdown (data preparation vs computation).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core.agnes import PreparedMinibatch
from ..train.optimizer import adamw_init, adamw_update, clip_by_global_norm
from .models import PaddedMFG, gnn_apply, init_gnn, pad_mfg


def gnn_loss(params: dict, mfg: PaddedMFG, arch: str,
             backend: str = "jnp") -> jnp.ndarray:
    logits = gnn_apply(params, mfg, arch, backend)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, mfg.labels[:, None], axis=-1)[:, 0]
    # only real target rows contribute
    idx = jnp.arange(nll.shape[0])
    w = (idx < mfg.n_targets).astype(nll.dtype)
    return jnp.sum(nll * w) / jnp.maximum(mfg.n_targets, 1)


@dataclasses.dataclass
class GNNTrainer:
    arch: str
    in_dim: int
    hidden: int = 128
    n_classes: int = 16
    n_layers: int = 3
    lr: float = 1e-3
    seed: int = 0
    backend: str = "jnp"   # aggregation primitives: "jnp" | "pallas"
    # None = features stay host numpy until pad_mfg; "jnp" | "pallas" =
    # run PreparedMinibatch.to_device first (the GIDS-style placement
    # hook; "pallas" routes rows through the gather_rows kernel path)
    feature_placement: str | None = None
    # DeviceFeatureTable (engine.device_feature_table()): cache hits are
    # gathered from the HBM-resident mirror, only misses cross the host
    # boundary; requires feature_placement to be set
    feature_table: object | None = None
    labels: np.ndarray | None = None
    # core.telemetry.Telemetry: when set, train_minibatch emits
    # "transfer" (host->device + padding) and "train.step" spans
    telemetry: object | None = None

    def __post_init__(self):
        key = jax.random.PRNGKey(self.seed)
        self.params = init_gnn(key, self.arch, self.in_dim, self.hidden,
                               self.n_classes, self.n_layers)
        self.opt_state = adamw_init(self.params)
        self.compute_time = 0.0
        self.steps = 0
        self._step_fn = jax.jit(self._train_step,
                                static_argnames=("arch", "backend"))
        self._eval_fn = jax.jit(self._eval_step,
                                static_argnames=("arch", "backend"))

    # ------------------------------------------------------------ jitted
    @staticmethod
    def _train_step(params, opt_state, mfg: PaddedMFG, arch: str, lr,
                    backend: str = "jnp"):
        loss, grads = jax.value_and_grad(gnn_loss)(params, mfg, arch, backend)
        grads, gn = clip_by_global_norm(grads, 1.0)
        params, opt_state = adamw_update(params, grads, opt_state, lr=lr)
        return params, opt_state, loss, gn

    @staticmethod
    def _eval_step(params, mfg: PaddedMFG, arch: str, backend: str = "jnp"):
        logits = gnn_apply(params, mfg, arch, backend)
        pred = jnp.argmax(logits, axis=-1)
        idx = jnp.arange(pred.shape[0])
        ok = (pred == mfg.labels) & (idx < mfg.n_targets)
        return jnp.sum(ok), mfg.n_targets

    # ------------------------------------------------------------ api
    def train_minibatch(self, prepared: PreparedMinibatch) -> float:
        assert self.labels is not None, "set trainer.labels first"
        tel = self.telemetry
        tr = tel.trace if tel is not None else None
        t_in = time.perf_counter() if tr is not None else 0.0
        if self.feature_placement is not None and isinstance(
                prepared.features, np.ndarray):
            prepared = prepared.to_device(backend=self.feature_placement,
                                          table=self.feature_table)
        mfg = pad_mfg(prepared.mfg, prepared.features, self.labels)
        t0 = time.perf_counter()
        if tr is not None:
            # transfer = device placement + jit-stable padding; nested
            # inside the pipeline's "train" span on the same track
            tr.complete("transfer", "transfer", "train", t_in, t0,
                        args={"n_targets": int(prepared.mfg.nodes[-1].size)})
        self.params, self.opt_state, loss, _ = self._step_fn(
            self.params, self.opt_state, mfg, self.arch, self.lr,
            self.backend)
        loss = float(loss)  # block for honest timing
        t1 = time.perf_counter()
        self.compute_time += t1 - t0
        self.steps += 1
        if tr is not None:
            tr.complete(f"step:{self.steps - 1}", "train.step", "train",
                        t0, t1, args={"loss": round(float(loss), 5)})
        return loss

    def evaluate(self, prepared_list: list[PreparedMinibatch]) -> float:
        correct = total = 0
        for p in prepared_list:
            mfg = pad_mfg(p.mfg, p.features, self.labels)
            c, t = self._eval_fn(self.params, mfg, self.arch, self.backend)
            correct += int(c)
            total += int(t)
        return correct / max(total, 1)
