"""Telemetry overhead + Fig.2 breakdown fidelity gate (``obs``).

Two floor-guarded measurements over the ``bench_io`` sparse-touch
workload (``benchmarks/check_regression.py`` re-asserts both from
``BENCH_obs.json``):

* **overhead** — the same hyperbatch prepare, wall-clocked with tracing
  off vs tracing on, fresh engine per repeat (warm buffers would skip
  the I/O and flatter the instrumented path).  Wall clocks on a shared
  1-core container carry ±30% run-to-run noise — far above the ~0.5%
  the instrumentation actually costs — so the gated ratio is the max of
  the best-of-N wall ratio and a *deterministic decomposed estimate*:
  ``off / (off + n_events × per_event_cost)`` with the per-event
  recording cost measured in a tight loop on the same recorder class.
  Either a per-event cost blow-up (expensive formatting on the hot
  path) or an event-count explosion on this fixed workload trips it.
* **breakdown** — a traced pipelined epoch; the Fig.2 decomposition
  reconstructed from the trace (``fig2_breakdown``) must agree with the
  :class:`~repro.gnn.pipeline.OverlapReport` wall times the executor
  measured directly.  The spans reuse the report's own ``perf_counter``
  readings, so agreement is structural, not a lucky race.  The exported
  Chrome object is schema-validated in the same pass.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import TraceRecorder, fig2_breakdown, validate_chrome_trace
from repro.gnn import GNNTrainer, PipelinedExecutor

from .common import (emit, get_dataset, make_agnes, maybe_export_trace,
                     quick_val, targets_for)

# wall-clock floor: prepare with tracing on may cost at most ~5% over
# tracing off (disabled telemetry is one branch and is covered for free)
MIN_OFF_ON_RATIO = 0.952
# trace-derived Fig.2 bars vs OverlapReport wall times (min of the
# prepare and train agreements, each min/max of the two readings)
MIN_BREAKDOWN_AGREEMENT = 0.98


def _prepare_wall(ds, targets, kw, *, trace: bool):
    eng = make_agnes(ds, trace=trace, **kw)
    t0 = time.perf_counter()
    eng.prepare(targets, epoch=0)
    dt = time.perf_counter() - t0
    n_ev = eng.telemetry.trace.n_emitted if trace else 0
    eng.close()
    return dt, n_ev


def _agreement(a: float, b: float) -> float:
    return min(a, b) / max(max(a, b), 1e-12)


def _event_cost_s(n: int = 20_000) -> float:
    """Measured cost of recording one span (ring write + tuple build)."""
    rec = TraceRecorder(capacity=1024)
    ta = rec.now()
    t0 = time.perf_counter()
    for _ in range(n):
        rec.complete("x", "io.run", "array:0", ta, ta, args={"n": 1})
    return (time.perf_counter() - t0) / n


def run() -> dict:
    # bench_io geometry: many more blocks than a hyperbatch touches, so
    # the prepare is I/O-plan heavy — the worst case for per-run spans
    n_nodes = quick_val(120_000, 6_000)
    block = quick_val(16384, 2048)
    mb = quick_val(48, 24)
    ds = get_dataset("iosparse", dim=32, block_size=block,
                     n_nodes=n_nodes, avg_degree=8)
    targets = targets_for(ds, n_mb=2, mb_size=mb)
    kw = dict(block_size=block, fanouts=(3, 3), minibatch=mb,
              hyperbatch_size=2, setting_bytes=32 << 20)

    # ---------------------------------------------------------- overhead
    reps = quick_val(7, 5)
    for arm in (False, True):            # warmup: page cache, imports
        _prepare_wall(ds, targets, kw, trace=arm)
    off = on = float("inf")
    n_events = 0
    for _ in range(reps):                # interleaved arms, best-of-N
        dt, _ = _prepare_wall(ds, targets, kw, trace=False)
        off = min(off, dt)
        dt, n_ev = _prepare_wall(ds, targets, kw, trace=True)
        on = min(on, dt)
        n_events = max(n_events, n_ev)
    wall_ratio = off / max(on, 1e-12)
    ev_cost = _event_cost_s()
    est_ratio = off / (off + n_events * ev_cost)
    ratio = max(wall_ratio, est_ratio)   # wall when quiet, bound when noisy
    emit("obs/untraced_ms", off * 1e3)
    emit("obs/traced_ms", on * 1e3, f"events={n_events}")
    emit("obs/event_cost_us", ev_cost * 1e6)
    emit("obs/off_on_ratio", ratio,
         f"wall={wall_ratio:.3f} est={est_ratio:.3f}")
    assert ratio >= MIN_OFF_ON_RATIO, \
        f"tracing overhead regression: off/on {ratio:.3f} < " \
        f"{MIN_OFF_ON_RATIO} (tracing costs more than ~5%: " \
        f"{n_events} events at {ev_cost * 1e6:.2f}us on a " \
        f"{off * 1e3:.1f}ms prepare)"

    # --------------------------------------------------------- breakdown
    eng = make_agnes(ds, trace=True, **kw)
    trainer = GNNTrainer(arch="gcn", in_dim=32, hidden=32, n_classes=16,
                         n_layers=2, seed=7)
    trainer.labels = ds.labels
    with PipelinedExecutor(eng, trainer, depth=2) as ex:
        report = ex.run_epoch(np.concatenate(targets), epoch=0)
    rec = eng.telemetry.trace
    errs = validate_chrome_trace(rec.to_chrome())
    assert not errs, f"exported trace fails schema: {errs[:3]}"
    fb = fig2_breakdown(rec)
    agreement = min(_agreement(fb["prepare_s"], report.prepare_wall_s),
                    _agreement(fb["train_s"], report.train_wall_s))
    emit("obs/fig2_prepare_ms", fb["prepare_s"] * 1e3,
         f"report={report.prepare_wall_s * 1e3:.3f}ms")
    emit("obs/fig2_train_ms", fb["train_s"] * 1e3,
         f"report={report.train_wall_s * 1e3:.3f}ms")
    emit("obs/fig2_agreement", agreement,
         f"dropped={fb['dropped_events']}")
    assert agreement >= MIN_BREAKDOWN_AGREEMENT, \
        f"fig2 breakdown drifted from OverlapReport: {agreement:.4f} < " \
        f"{MIN_BREAKDOWN_AGREEMENT}"
    maybe_export_trace(eng, "obs_breakdown")
    eng.close()

    return {
        "workload": {"n_nodes": ds.n_nodes, "block_size": block,
                     "minibatch": mb, "reps": reps},
        "overhead": {"untraced_wall_s": round(off, 6),
                     "traced_wall_s": round(on, 6),
                     "off_on_ratio": round(ratio, 4),
                     "wall_ratio": round(wall_ratio, 4),
                     "estimated_ratio": round(est_ratio, 4),
                     "event_cost_us": round(ev_cost * 1e6, 3),
                     "trace_events": int(n_events)},
        "breakdown": {"agreement": round(agreement, 4),
                      "trace_prepare_s": round(fb["prepare_s"], 6),
                      "report_prepare_s": round(report.prepare_wall_s, 6),
                      "trace_train_s": round(fb["train_s"], 6),
                      "report_train_s": round(report.train_wall_s, 6),
                      "transfer_s": round(fb["transfer_s"], 6),
                      "dropped_events": int(fb["dropped_events"]),
                      "chrome_schema_errors": 0},
    }


if __name__ == "__main__":
    print(run())
