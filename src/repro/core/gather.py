"""Feature gathering (paper §3.4(2), Algorithm 1 lines 13-17).

Collects the feature vectors of each minibatch's sampled input nodes into
*contiguous* per-minibatch arrays ready for device transfer (G-1..G-3).
Like sampling, gathering runs in block-major (hyperbatch) order: the
misses of *all* minibatches are bucketed by feature block and every
needed block is read exactly once per hyperbatch.  The feature cache
(access-count admission) absorbs hot rows across hyperbatches.

Gathering is exposed as explicit stages for the staged prepare path
(:class:`repro.core.session.PrepareSession`):

* :meth:`FeatureGatherer.plan_gather`    — cache pass + bucket of misses;
  the feature block visit order is known here, so the gather I/O plan
  can be submitted as soon as the final sampling frontier exists;
* :meth:`FeatureGatherer.consume_gather` — the block-major fill.

Also implements the node-granular path used by the baseline engines
(one small I/O per missed row — the pattern the paper identifies as the
bottleneck).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .block_store import FeatureBlockStore
from .bucket import Bucket, build_bucket
from .buffer import BlockBuffer
from .feature_cache import FeatureCache


@dataclasses.dataclass
class ResidentSplit:
    """Cache-hit rows of one minibatch, recorded at cache-pass time.

    The device-resident gather re-validates ``(slots, nodes)`` against
    the live cache at transfer time — a slot re-used by a later admit
    demotes that row to the host path (its bytes are already in the
    minibatch's host ``features``), so staleness can never corrupt a
    feature, only shrink the HBM-served fraction.
    """

    pos: np.ndarray     # positions in the minibatch output (hits)
    slots: np.ndarray   # their cache slots at cache-pass time
    nodes: np.ndarray   # their node ids (revalidation key)


@dataclasses.dataclass
class GatherPlan:
    """Planned gather state: cache-filled outputs + bucketed misses."""

    outs: list[np.ndarray]            # per-mb contiguous outputs (G-3)
    miss_lists: list                  # per-mb (miss_nodes, miss_positions)
    bck: Bucket                       # misses bucketed by feature block
    resident: list | None = None      # per-mb ResidentSplit (cache on)

    @property
    def row_blocks(self) -> np.ndarray:
        """Ascending feature-block visit order for the misses."""
        return self.bck.row_blocks

    @property
    def n_miss(self) -> int:
        return sum(len(m) for m, _ in self.miss_lists)


class FeatureGatherer:
    """Gathers features for sampled nodes through cache + block buffer."""

    def __init__(self, store: FeatureBlockStore, buffer: BlockBuffer,
                 cache: FeatureCache | None = None, prefetcher=None):
        self.store = store
        self.buffer = buffer
        self.cache = cache
        self.prefetcher = prefetcher
        # when set (a list), plan_gather appends each gather cycle's node
        # list — the feature-access trace the cache oracle replays
        # (AgnesEngine.record_feature_trace)
        self.trace_sink: list | None = None

    # ------------------------------------------------------------ stages
    def plan_gather(self, nodes_per_mb: list[np.ndarray]) -> GatherPlan:
        """Cache pass + block bucket of the misses (the *plan* stage)."""
        if self.cache is not None:
            if self.trace_sink is not None:
                self.trace_sink.append(np.concatenate(
                    [np.unique(np.asarray(m, dtype=np.int64))
                     for m in nodes_per_mb]) if nodes_per_mb
                    else np.zeros(0, dtype=np.int64))
            # one oracle step per gather cycle (= one batched admit),
            # entered before the cycle's lookups; no-op off-policy
            self.cache.oracle_advance()
        outs, miss_lists, resident = self._cache_pass(nodes_per_mb)
        miss_nodes = [m for m, _ in miss_lists]
        blocks = [self.store.block_of(m) for m in miss_nodes]
        return GatherPlan(outs, miss_lists, build_bucket(miss_nodes, blocks),
                          resident)

    def consume_gather(self, gp: GatherPlan) -> list[np.ndarray]:
        """Block-major fill of the planned misses; one read per block.

        The per-group scatter is vectorized: block reads only *collect*
        (node, value) pairs per minibatch; at the end one concatenate +
        one ``searchsorted`` + one fancy-index scatter per minibatch moves
        everything into the contiguous outputs (G-2), and the cache sees
        a single batched admit.
        """
        bck = gp.bck
        rpb = self.store.rows_per_block
        n_mb = len(gp.miss_lists)
        per_mb_nodes: list[list[np.ndarray]] = [[] for _ in range(n_mb)]
        per_mb_vals: list[list[np.ndarray]] = [[] for _ in range(n_mb)]
        all_nodes: list[np.ndarray] = []
        all_vals: list[np.ndarray] = []
        for r in range(bck.n_rows):
            b = int(bck.row_blocks[r])
            rows = self._load_block(b)
            g0, g1 = int(bck.row_ptr[r]), int(bck.row_ptr[r + 1])
            p0, p1 = int(bck.group_ptr[g0]), int(bck.group_ptr[g1])
            blk_nodes = bck.nodes[p0:p1]      # all mbs' nodes in block b
            vals = rows[blk_nodes - b * rpb]  # one gather per block
            bounds = (bck.group_ptr[g0 + 1:g1] - p0)
            for off, (gn, gv) in enumerate(zip(np.split(blk_nodes, bounds),
                                               np.split(vals, bounds))):
                j = int(bck.mb_ids[g0 + off])
                per_mb_nodes[j].append(gn)
                per_mb_vals[j].append(gv)
            if self.cache is not None:
                all_nodes.append(blk_nodes)
                all_vals.append(vals)
        for j, (mnodes, mpos) in enumerate(gp.miss_lists):
            if not per_mb_nodes[j]:
                continue
            g_nodes = np.concatenate(per_mb_nodes[j])
            g_vals = np.concatenate(per_mb_vals[j])
            # mnodes sorted unique (inputs are unique per mb)
            where = np.searchsorted(mnodes, g_nodes)
            gp.outs[j][mpos[where]] = g_vals
        if self.cache is not None and all_nodes:
            self.cache.admit(np.concatenate(all_nodes),
                             np.concatenate(all_vals))
        return gp.outs

    # ------------------------------------------------------------ block-major
    def gather_hyperbatch(self, nodes_per_mb: list[np.ndarray]) -> list[np.ndarray]:
        """Block-major gathering for a hyperbatch; one read per needed block.

        Compatibility wrapper over the staged API with the pre-session
        schedule (plan, prefetch, consume, reset barrier).
        """
        gp = self.plan_gather(nodes_per_mb)
        if gp.n_miss == 0:
            return gp.outs
        try:
            if self.prefetcher is not None:
                self.prefetcher.plan(self.buffer.absent(gp.row_blocks))
            self.consume_gather(gp)
        finally:
            if self.prefetcher is not None:
                self.prefetcher.reset()
        return gp.outs

    # ------------------------------------------------------------ target-major
    def gather_per_minibatch(self, nodes_per_mb: list[np.ndarray]) -> list[np.ndarray]:
        """Target-major gathering: each minibatch fetched independently."""
        return [self.gather_hyperbatch([nodes])[0] for nodes in nodes_per_mb]

    def gather_node_granular(self, nodes_per_mb: list[np.ndarray],
                             io_unit: int = 4096) -> list[np.ndarray]:
        """Baseline path: per-row small I/Os for every cache miss."""
        outs, miss_lists, _ = self._cache_pass(nodes_per_mb)
        for j, (miss_nodes, miss_pos) in enumerate(miss_lists):
            if len(miss_nodes) == 0:
                continue
            rows = self.store.read_rows_node_granular(miss_nodes, io_unit)
            outs[j][miss_pos] = rows
            if self.cache is not None:
                self.cache.admit(miss_nodes, rows)
        return outs

    # ------------------------------------------------------------ internals
    def _cache_pass(self, nodes_per_mb):
        """Fill from feature cache; return per-mb outputs, miss lists and
        :class:`ResidentSplit` records (``None`` without a cache)."""
        outs, miss_lists = [], []
        resident = [] if self.cache is not None else None
        for nodes in nodes_per_mb:
            nodes = np.asarray(nodes, dtype=np.int64)
            out = np.empty((len(nodes), self.store.dim), dtype=self.store.dtype)
            if self.cache is not None:
                self.cache.note_access(nodes)
                mask, rows = self.cache.lookup(nodes)
                out[mask] = rows
                miss = ~mask
                miss_lists.append((nodes[miss], np.nonzero(miss)[0]))
                hit_pos = np.nonzero(mask)[0]
                resident.append(ResidentSplit(
                    hit_pos, self.cache.lookup_slots(nodes[hit_pos]),
                    nodes[hit_pos]))
            else:
                miss_lists.append((nodes, np.arange(len(nodes))))
            outs.append(out)
        return outs, miss_lists, resident

    def _load_block(self, b: int) -> np.ndarray:
        if b not in self.buffer and self.prefetcher is not None:
            rows = self.prefetcher.fetch(b)
            if rows is not None:
                self.buffer.stats.buffer_misses += 1
                self.buffer.put(b, rows)
                return rows
        return self.buffer.get(b, self.store.read_block)


class DeviceFeatureTable:
    """HBM-resident mirror of the feature cache (the GIDS-style table).

    Pins the cache's ``rows`` array on device (lane-padded once, so the
    per-minibatch gather never re-pads the whole table) and keeps it
    fresh *incrementally*: each sync uploads only the slots admits have
    rewritten since the last one (``FeatureCache.drain_dirty``).  With
    this table, ``PreparedMinibatch.to_device`` ships only miss rows
    host→device — cache hits are served HBM→HBM through the Pallas
    masked-gather kernel (``kernels.ops.gather_resident_rows``).

    Correctness under the producer/consumer interleaving: a recorded
    ``(slot, node)`` pair is only *used* if ``node_at[slot] == node``
    still holds at sync time, checked under the cache lock in the same
    critical section as the dirty-slot upload — so the device mirror the
    gather reads (an immutable jnp snapshot) is guaranteed to hold
    exactly that node's row for every validated slot.  Invalidated hits
    demote to the host path; their bytes are already in the minibatch's
    host ``features`` array.
    """

    def __init__(self, cache: FeatureCache, lane_multiple: int = 128):
        import jax.numpy as jnp

        self.cache = cache
        self._d_pad = -(-cache.dim // lane_multiple) * lane_multiple
        self.array = jnp.zeros((max(cache.capacity, 1), self._d_pad),
                               dtype=cache.dtype)
        self.hit_rows_served = 0    # rows gathered HBM->HBM
        self.host_rows_shipped = 0  # miss + demoted rows host->device
        self.demoted_rows = 0       # stale hits re-routed to host
        self.sync_rows = 0          # dirty slots uploaded
        with cache.lock:
            self._sync_locked()

    @property
    def host_bytes_shipped(self) -> int:
        return self.host_rows_shipped * self.cache.row_bytes

    def _sync_locked(self) -> None:
        """Upload dirty slots (caller holds ``cache.lock``)."""
        import jax.numpy as jnp

        dirty = self.cache.drain_dirty()
        if dirty.size:
            rows = np.zeros((len(dirty), self._d_pad),
                            dtype=self.cache.rows.dtype)
            rows[:, :self.cache.dim] = self.cache.rows[dirty]
            self.array = self.array.at[jnp.asarray(dirty)].set(
                jnp.asarray(rows))
            self.sync_rows += int(dirty.size)

    def resolve(self, split: ResidentSplit | None, n: int,
                padded_n: int) -> tuple[np.ndarray, np.ndarray]:
        """Sync the mirror and validate a minibatch's recorded hits.

        Returns ``(slots, host_pos)``: per-output-row device slots (-1 =
        not resident; rows past ``n`` are jit padding and stay -1) and
        the positions whose bytes must travel from host ``features``.
        """
        slots = np.full(padded_n, -1, dtype=np.int64)
        with self.cache.lock:
            self._sync_locked()
            if split is not None and len(split.pos):
                ok = self.cache.node_at[split.slots] == split.nodes
                slots[split.pos[ok]] = split.slots[ok]
                self.demoted_rows += int((~ok).sum())
        host_pos = np.nonzero(slots[:n] < 0)[0]
        self.hit_rows_served += int(n - len(host_pos))
        self.host_rows_shipped += int(len(host_pos))
        return slots, host_pos

    def stats(self) -> dict:
        return {
            "hit_rows_served": self.hit_rows_served,
            "host_rows_shipped": self.host_rows_shipped,
            "host_bytes_shipped": self.host_bytes_shipped,
            "demoted_rows": self.demoted_rows,
            "sync_rows": self.sync_rows,
        }
