"""Quickstart: storage-based GNN training with AGNES in ~40 lines.

Builds a power-law graph on disk in AGNES's block layout, prepares
hyperbatches through the 3-layer engine, and trains GraphSAGE on them.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import AgnesConfig, AgnesEngine
from repro.data import build_dataset
from repro.gnn import GNNTrainer


def main():
    # 1. build the on-disk block layout (graph blocks + feature blocks)
    ds = build_dataset("ig-mini", "/tmp/agnes_quickstart", dim=64)
    print(f"graph: {ds.n_nodes} nodes, {ds.n_edges} edges, "
          f"{ds.graph_store.n_blocks} graph blocks, "
          f"{ds.feature_store.n_blocks} feature blocks")

    # 2. the AGNES engine: block-wise I/O + hyperbatch-based processing
    engine = AgnesEngine(ds.graph_store, ds.feature_store, AgnesConfig(
        minibatch_size=512, hyperbatch_size=8, fanouts=(10, 10),
        graph_buffer_bytes=16 << 20, feature_buffer_bytes=16 << 20))

    # 3. train GraphSAGE on prepared minibatches
    trainer = GNNTrainer(arch="sage", in_dim=64, hidden=128, n_classes=16,
                         n_layers=2)
    trainer.labels = ds.labels
    for epoch in range(2):
        losses = []
        for prepared in engine.iter_epoch(np.arange(8192), epoch=epoch):
            for p in prepared:
                losses.append(trainer.train_minibatch(p))
        print(f"epoch {epoch}: loss {np.mean(losses):.4f}")

    acc = trainer.evaluate(engine.prepare(
        [np.arange(8192, 8192 + 1024)], epoch=99))
    print(f"holdout accuracy: {acc:.3f}")
    print("I/O stats:", engine.io_stats()["total"])
    engine.close()


if __name__ == "__main__":
    main()
