"""Fig 11: achieved I/O bandwidth utilization (AGNES ~saturates a RAID0
array; node-granular engines stay IOPS-bound).

Rows per dataset/array: the per-block AGNES path (scheduler disabled),
the coalesced + batched default path (``repro.core.io_sched``), and the
Ginex-like node-granular baseline.  The coalesced rows also report the
sequential fraction of the block reads — the scheduler's merged requests
are where the remaining bandwidth lives.
"""
from __future__ import annotations

from .common import (ALL_BASELINES, emit, get_dataset, make_agnes,
                     make_baseline, targets_for)


def _bw(g_stats, f_stats) -> float:
    return (g_stats.bytes_read + f_stats.bytes_read) / max(
        g_stats.modeled_read_time + f_stats.modeled_read_time, 1e-12)


def run():
    for ds_name in ("ig-mini", "pa-mini"):
        ds = get_dataset(ds_name)
        targets = targets_for(ds, n_mb=4, mb_size=512)
        for n_ssd in (1, 4):
            peak = 6.7e9 * n_ssd
            base = make_agnes(ds, n_ssd=n_ssd, max_coalesce_bytes=0)
            base.prepare(targets, epoch=0)
            bw_pb = _bw(base.graph_store.stats, base.feature_store.stats)
            a = make_agnes(ds, n_ssd=n_ssd)
            a.prepare(targets, epoch=0)
            bw_a = _bw(a.graph_store.stats, a.feature_store.stats)
            reads = a.graph_store.stats.n_reads + a.feature_store.stats.n_reads
            seq = (a.graph_store.stats.n_sequential_reads
                   + a.feature_store.stats.n_sequential_reads)
            g = make_baseline(ALL_BASELINES["ginex"], ds, n_ssd=n_ssd)
            g.prepare(targets, epoch=0)
            bw_g = _bw(g.csr.stats, g.features.stats)
            emit(f"fig11/{ds_name}/ssd{n_ssd}/agnes_per_block_GBps",
                 bw_pb / 1e9, f"util={bw_pb/peak*100:.0f}%")
            emit(f"fig11/{ds_name}/ssd{n_ssd}/agnes_coalesced_GBps",
                 bw_a / 1e9,
                 f"util={bw_a/peak*100:.0f}% seq={seq/max(reads,1)*100:.0f}%")
            emit(f"fig11/{ds_name}/ssd{n_ssd}/ginex_GBps", bw_g / 1e9,
                 f"util={bw_g/peak*100:.0f}%")
            a.close()
            base.close()


if __name__ == "__main__":
    run()
