"""Drifting-hotspot duel: online re-placement vs static placement.

The telemetry -> re-placement -> migration loop (``core/hotness.py`` +
``core/migration.py``) exists to beat exactly one failure mode of PR 4's
static placement: the *attach-time* hotness proxy (degree mass) cannot
see where runtime traffic actually lands, and the landing spot drifts.
This benchmark builds that workload deliberately:

* a **locality-structured ring graph** (every node's neighbors are its
  ±k ring neighbors — the shape the BFS locality relabel produces on
  real graphs), so a hyperbatch's k-hop frontier and gather set stay
  *inside* the hot region instead of spraying over the whole store;
* a **rotating hot window**: all training targets of an epoch are drawn
  from one contiguous window of the node space, and the window jumps
  every ``ROTATE_EVERY`` epochs — degree is uniform, so the static
  degree proxy is blind to it (its skew gate correctly degenerates to
  plain striping);
* a **heterogeneous 2-array topology** (one Gen5-class array at 3x
  bandwidth / one-third latency beside a standard Gen4 array): striping
  splits the hot window 50/50 and the slow array sets the roofline,
  while measured-hotness placement rebalances the window
  bandwidth-proportionally across the arrays.

The online engine observes per-block touches, re-places at every epoch
boundary (``AgnesEngine.end_epoch``), and migrates through the real
crash-consistent write path — with every copy read/write charged to the
owning arrays' rooflines, so the reported speedup already *pays* for
migration.  Acceptance gates (tracked in ``BENCH_migrate.json``,
guarded by ``benchmarks.check_regression``):

* online >= ``MIN_SPEEDUP`` (1.15x) over the static engine on total
  modeled prepare I/O time (reads + migration writes);
* MFGs and gathered features byte-identical to the no-migration path
  every hyperbatch (placement moves bytes, never changes them);
* the per-store migration byte budget is respected every epoch.

Fixed geometry in both tiers: a deterministic policy A/B at container
scale, not a scaling measurement.
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

from .common import WORKDIR, emit

from repro.core import (AgnesConfig, AgnesEngine, FeatureBlockStore,
                        GraphBlockStore, NVMeModel, StorageTopology)

MIN_SPEEDUP = 1.15      # online vs static, migration cost charged

N_NODES = 6_144
RING_K = 8              # ring neighbors per side (degree 16, uniform)
G_BLOCK = 2048          # graph block bytes (~26 objects/block)
F_DIM = 512             # 2 KiB rows -> one row per feature block
F_BLOCK = 2048
WINDOW = 1_536          # hot-window width (1/4 of the node space)
N_EPOCHS = 8
ROTATE_EVERY = 4        # hot window jumps every k epochs
PASSES_PER_EPOCH = 3    # full window re-reads per epoch (tiny buffers)
HB_PER_WINDOW = 4       # hyperbatches tiling one window pass
MB, N_MB = 64, 6        # minibatch geometry (4 x 6 x 64 = WINDOW)
BUDGET = 4 << 20        # migrate_budget_bytes per store per epoch


def _build_workload() -> tuple[str, str]:
    gpath = os.path.join(WORKDIR, "migrate_ring.graph")
    fpath = os.path.join(WORKDIR, "migrate_ring.feat")
    if not os.path.exists(gpath + ".meta.json"):
        offs = np.concatenate([np.arange(-RING_K, 0),
                               np.arange(1, RING_K + 1)])
        indices = ((np.arange(N_NODES)[:, None] + offs[None, :])
                   % N_NODES).astype(np.int64).ravel()
        indptr = (np.arange(N_NODES + 1, dtype=np.int64) * (2 * RING_K))
        GraphBlockStore.build(gpath, indptr, indices, block_size=G_BLOCK)
    if not os.path.exists(fpath + ".meta.json"):
        rng = np.random.default_rng(7)
        feats = rng.normal(0, 1, (N_NODES, F_DIM)).astype(np.float32)
        FeatureBlockStore.build(fpath, feats, block_size=F_BLOCK)
    return gpath, fpath


def _engine(gpath: str, fpath: str, online: bool) -> AgnesEngine:
    # heterogeneous pair: a 4-drive RAID0 array beside a single drive —
    # striping splits the hot window 50/50 and the single drive gates it
    fast = dataclasses.replace(NVMeModel(), n_ssd=4)
    topo = StorageTopology([fast, NVMeModel()])
    g = GraphBlockStore.open(gpath, NVMeModel())
    f = FeatureBlockStore.open(fpath, NVMeModel())
    cfg = AgnesConfig(block_size=G_BLOCK, minibatch_size=MB,
                      hyperbatch_size=N_MB, fanouts=(RING_K,),
                      graph_buffer_bytes=64 << 10,
                      feature_buffer_bytes=128 << 10,
                      feature_cache_rows=1, async_io=False,
                      io_queue_depth=16, placement="hotness",
                      online_placement=online,
                      migrate_budget_bytes=BUDGET, hotness_decay=0.3)
    return AgnesEngine(g, f, cfg, topology=topo)


def _window_targets(epoch: int, hb: int) -> list[np.ndarray]:
    """Hyperbatch ``hb``'s targets: one contiguous quarter of the current
    hot window (the BFS-relabel regime: training labels cluster in the
    locality order).  Every ``HB_PER_WINDOW`` hyperbatches tile the
    window exactly, so the measured hot set is the *whole* window —
    dense and stable — while each hyperbatch's gather is a handful of
    long sequential runs; the buffers are far smaller than the window,
    so each of the epoch's ``PASSES_PER_EPOCH`` passes re-reads it.
    """
    w = (epoch // ROTATE_EVERY) % (N_NODES // WINDOW)
    lo = w * WINDOW + (hb % HB_PER_WINDOW) * N_MB * MB
    return [lo + np.arange(j * MB, (j + 1) * MB) for j in range(N_MB)]


def _io_time(eng: AgnesEngine) -> float:
    g, f = eng.graph_store.stats, eng.feature_store.stats
    return (g.modeled_read_time + g.modeled_write_time
            + f.modeled_read_time + f.modeled_write_time)


def _assert_parity(p1, p0, tag):
    for a, b in zip(p1, p0):
        for x, y in zip(a.mfg.nodes, b.mfg.nodes):
            assert np.array_equal(x, y), f"{tag}: migration changed MFGs"
        for lx, ly in zip(a.mfg.layers, b.mfg.layers):
            assert np.array_equal(lx.nbr_idx, ly.nbr_idx)
            assert np.array_equal(lx.self_idx, ly.self_idx)
        assert np.array_equal(a.features, b.features), \
            f"{tag}: migration changed gathered features"


def run() -> dict:
    gpath, fpath = _build_workload()
    static = _engine(gpath, fpath, online=False)
    online = _engine(gpath, fpath, online=True)
    per_epoch: list[dict] = []
    moved_total = 0
    for epoch in range(N_EPOCHS):
        s0, o0 = _io_time(static), _io_time(online)
        for hb in range(PASSES_PER_EPOCH * HB_PER_WINDOW):
            targets = _window_targets(epoch, hb)
            p0 = static.prepare(targets, epoch=epoch)
            p1 = online.prepare(targets, epoch=epoch)
            _assert_parity(p1, p0, f"epoch{epoch}/hb{hb}")
        static.end_epoch()              # telemetry roll only (no topology
        reports = online.end_epoch()    # diff) vs roll + budgeted moves
        epoch_moved = 0
        for name, rep in (reports or {}).items():
            # acceptance gate: the migration budget holds every epoch
            assert rep["bytes_moved"] <= BUDGET, \
                (f"epoch {epoch}: {name} moved {rep['bytes_moved']} bytes "
                 f"> budget {BUDGET}")
            epoch_moved += rep["n_moved"]
        moved_total += epoch_moved
        per_epoch.append({
            "epoch": epoch,
            "window": (epoch // ROTATE_EVERY) % (N_NODES // WINDOW),
            "static_io_s": round(_io_time(static) - s0, 6),
            "online_io_s": round(_io_time(online) - o0, 6),
            "blocks_migrated": epoch_moved,
            "feature_top_share":
                online.feature_hotness.skew_summary()["top_share"],
        })
    assert moved_total > 0, "online engine never migrated"
    static_t, online_t = _io_time(static), _io_time(online)
    speedup = static_t / max(online_t, 1e-12)
    # acceptance gate: online re-placement beats static placement with
    # the migration copy traffic fully charged
    assert speedup >= MIN_SPEEDUP, \
        (f"online re-placement regression: {speedup:.3f}x < "
         f"{MIN_SPEEDUP}x vs static placement on the drifting hotspot")
    mig = online.io_stats().get("migration", {})
    steady = [e for e in per_epoch if e["epoch"] % ROTATE_EVERY != 0]
    steady_speedup = (sum(e["static_io_s"] for e in steady)
                      / max(sum(e["online_io_s"] for e in steady), 1e-12))
    emit("migrate/speedup", speedup,
         f"{static_t*1e3:.2f}ms -> {online_t*1e3:.2f}ms over {N_EPOCHS} "
         f"epochs, {moved_total} blocks migrated")
    emit("migrate/steady_state_speedup", steady_speedup,
         "epochs after the window's first (placement converged)")
    out = {
        "workload": {"n_nodes": N_NODES, "window": WINDOW,
                     "rotate_every": ROTATE_EVERY, "n_epochs": N_EPOCHS,
                     "graph_blocks": online.graph_store.n_blocks,
                     "feature_blocks": online.feature_store.n_blocks,
                     "budget_bytes": BUDGET},
        "static_io_s": round(static_t, 6),
        "online_io_s": round(online_t, 6),
        "speedup": round(speedup, 3),
        "steady_state_speedup": round(steady_speedup, 3),
        "blocks_migrated": moved_total,
        "bytes_migrated": int(mig.get("bytes_migrated", 0)),
        "per_epoch": per_epoch,
        "arrays": online.io_stats()["arrays"],
    }
    static.close()
    online.close()
    return out


if __name__ == "__main__":
    print(run())
