"""CausalLM / EncDecLM: embed → layer stack → norm → (chunked) CE loss,
plus the one-token ``decode_step`` used by serving and the decode shapes.

Layer-stack execution has two modes sharing one code path:

* ``scan_layers=True``  — parameters of each repeat-unit position are
  stacked ``(n_reps, ...)`` and the stack runs under ``lax.scan`` with
  remat: small HLO, bounded activation memory (the real training config;
  what the dry-run compiles).
* ``scan_layers=False`` — unrolled Python loop (smoke tests, and the
  roofline lowering where per-layer HLO cost must be visible; DESIGN.md
  §8).

The LM head is tied to the embedding; cross-entropy is computed in token
chunks so the (tokens × vocab) logits never materialize (262k vocabs at
4k×256 tokens would be 4.3 TB in f32).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import LayerSpec, ModelConfig
from .attention import attention_apply, attn_init, cross_attention_decode
from .blocks import layer_apply, layer_cache_init, layer_decode, layer_init, \
    mlp_apply, mlp_init
from .common import dense_init, make_mrope_positions, rms_norm


# ------------------------------------------------------------------ model
class CausalLM:
    """Decoder-only LM over a per-layer spec list (all 10 families)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.n_head, p, self.n_reps, self.n_tail = cfg.stack_plan()
        self.unit = cfg.layers[self.n_head:self.n_head + p]

    # ------------------------------------------------------------ params
    def init(self, key) -> dict:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        k_embed, k_head, k_layers, k_tail = jax.random.split(key, 4)
        params: dict[str, Any] = {
            "embed": dense_init(k_embed, (cfg.vocab, cfg.d_model),
                                scale=cfg.d_model ** -0.5, dtype=dt),  # tied head: keeps logit std O(1)
            "norm_f": jnp.zeros((cfg.d_model,), jnp.float32),
        }
        if self.n_head:
            keys = jax.random.split(k_head, self.n_head)
            params["head_layers"] = [
                layer_init(keys[i], cfg, cfg.layers[i])
                for i in range(self.n_head)]
        if cfg.scan_layers and self.n_reps > 1:
            keys = jax.random.split(k_layers, self.n_reps)
            stacked = [
                jax.tree.map(lambda *xs: jnp.stack(xs),
                             *[layer_init(jax.random.fold_in(keys[r], j),
                                          cfg, spec)
                               for r in range(self.n_reps)])
                for j, spec in enumerate(self.unit)]
            params["units"] = stacked
        else:
            keys = jax.random.split(k_layers, cfg.n_layers)
            params["layers"] = [
                layer_init(keys[i], cfg, cfg.layers[self.n_head + i])
                for i in range(self.n_reps * len(self.unit))]
        if self.n_tail:
            keys = jax.random.split(k_tail, self.n_tail)
            params["tail"] = [
                layer_init(keys[i], cfg,
                           cfg.layers[self.n_head
                                      + self.n_reps * len(self.unit) + i])
                for i in range(self.n_tail)]
        return params

    def param_specs(self) -> Any:
        """Abstract params (no allocation) for dry-run lowering."""
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    # ----------------------------------------------------------- forward
    def hidden_states(self, params: dict, tokens: jnp.ndarray,
                      prefix_embeds: jnp.ndarray | None = None,
                      *, unroll_inner: bool = False,
                      attn_impl: str | None = None) -> tuple[jnp.ndarray, jnp.ndarray]:
        """tokens: (B, S_t) → (B, S, D) final hidden states + moe aux."""
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0)
        if prefix_embeds is not None:  # vlm/audio stub frontends
            x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
        B, S, _ = x.shape
        if cfg.mrope:
            positions = make_mrope_positions(B, S)
        else:
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                         (B, S))
        impl = attn_impl or ("full" if S <= 512 else "chunked")
        aux_total = jnp.zeros((), jnp.float32)

        def one_layer(p, x, spec):
            def fn(p, x, positions):
                return layer_apply(p, x, positions, cfg, spec,
                                   impl=impl, unroll=unroll_inner)
            fn = jax.checkpoint(fn) if cfg.remat else fn
            return fn(p, x, positions)

        def apply_unit(x, unit_params):
            aux_u = jnp.zeros((), jnp.float32)
            for j, spec in enumerate(self.unit):
                x, aux = layer_apply(unit_params[j], x, positions, cfg, spec,
                                     impl=impl, unroll=unroll_inner)
                aux_u += aux
            return x, aux_u

        for i, p in enumerate(params.get("head_layers", [])):
            x, aux = one_layer(p, x, cfg.layers[i])
            aux_total += aux
        if "units" in params:
            def body(carry, unit_params):
                x, aux_acc = carry
                fn = jax.checkpoint(apply_unit) if cfg.remat else apply_unit
                x, aux = fn(x, unit_params)
                return (x, aux_acc + aux), None
            (x, aux_total), _ = jax.lax.scan(
                body, (x, aux_total), params["units"])
        else:
            for i, p in enumerate(params.get("layers", [])):
                x, aux = one_layer(p, x, cfg.layers[self.n_head + i])
                aux_total += aux
        for i, p in enumerate(params.get("tail", [])):
            spec = cfg.layers[self.n_head + self.n_reps * len(self.unit) + i]
            x, aux = one_layer(p, x, spec)
            aux_total += aux
        x = rms_norm(x, params["norm_f"], cfg.norm_eps)
        return x, aux_total

    def loss(self, params: dict, batch: dict, *,
             unroll_inner: bool = False,
             attn_impl: str | None = None) -> jnp.ndarray:
        """Next-token CE (chunked over tokens) + MoE aux."""
        cfg = self.cfg
        tokens = batch["tokens"]
        h, aux = self.hidden_states(params, tokens,
                                    batch.get("prefix_embeds"),
                                    unroll_inner=unroll_inner,
                                    attn_impl=attn_impl)
        P = h.shape[1] - tokens.shape[1]  # prefix length (vlm/audio stubs)
        h = h[:, P:]
        targets = jnp.concatenate(
            [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1)
        mask = jnp.concatenate(
            [jnp.ones_like(tokens[:, 1:]), jnp.zeros_like(tokens[:, :1])],
            axis=1).astype(jnp.float32)
        ce = chunked_cross_entropy(h, params["embed"], targets, mask,
                                   chunk=cfg.ce_chunk,
                                   unroll=unroll_inner)
        return ce + 0.01 * aux

    # ------------------------------------------------------------ decode
    def init_cache(self, batch: int, max_len: int) -> list:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        return [layer_cache_init(cfg, spec, batch, max_len, dt)
                for spec in cfg.layers]

    def cache_specs(self, batch: int, max_len: int) -> list:
        return jax.eval_shape(lambda: self.init_cache(batch, max_len))

    def decode_step(self, params: dict, caches: list, tokens: jnp.ndarray,
                    pos: jnp.ndarray) -> tuple[jnp.ndarray, list]:
        """One decode step. tokens: (B,) int32; pos: scalar position."""
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0)       # (B, D)
        new_caches = []

        def get_layer_params(i):
            if i < self.n_head:
                return params["head_layers"][i]
            j = i - self.n_head
            core = self.n_reps * len(self.unit)
            if j >= core:
                return params["tail"][j - core]
            if "units" in params:
                r, u = divmod(j, len(self.unit))
                return jax.tree.map(lambda t: t[r], params["units"][u])
            return params["layers"][j]
        for i, spec in enumerate(cfg.layers):
            p = get_layer_params(i)
            x, c = layer_decode(p, x, pos, caches[i], cfg, spec)
            new_caches.append(c)
        x = rms_norm(x, params["norm_f"], cfg.norm_eps)
        logits = (x @ params["embed"].T).astype(jnp.float32)
        return logits, new_caches


def chunked_cross_entropy(h: jnp.ndarray, embed: jnp.ndarray,
                          targets: jnp.ndarray, mask: jnp.ndarray,
                          chunk: int = 1024,
                          unroll: bool = False) -> jnp.ndarray:
    """Token-chunked CE: logits (chunk, vocab) never exceed one chunk."""
    B, S, D = h.shape
    hf = h.reshape(B * S, D)
    tf = targets.reshape(B * S)
    mf = mask.reshape(B * S)
    T = B * S
    chunk = min(chunk, T)
    while T % chunk:
        chunk //= 2
    n = T // chunk
    # strided chunking keeps every chunk data-sharded (see moe_apply)
    hc = jnp.swapaxes(hf.reshape(chunk, n, D), 0, 1)
    tc = jnp.swapaxes(tf.reshape(chunk, n), 0, 1)
    mc = jnp.swapaxes(mf.reshape(chunk, n), 0, 1)

    def one(args):
        hx, tx, mx = args
        logits = (hx @ embed.T).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tx[:, None], axis=-1)[:, 0]
        return jnp.sum((lse - gold) * mx)

    if unroll:
        tot = jnp.zeros((), jnp.float32)
        for i in range(n):
            tot += one((hc[i], tc[i], mc[i]))
    else:
        def body(acc, args):
            return acc + one(args), None
        # remat per token chunk: (chunk, vocab) logits never persist
        tot, _ = jax.lax.scan(jax.checkpoint(body),
                              jnp.zeros((), jnp.float32), (hc, tc, mc))
    return tot / jnp.maximum(mf.sum(), 1.0)


# ------------------------------------------------------------- enc-dec LM
class EncDecLM:
    """Encoder-decoder (seamless-m4t): stubbed modality frontend feeds the
    encoder precomputed frame embeddings; text decoder has cross-attention.
    """

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def _one_enc(self, key, spec):
        return layer_init(key, self.cfg, spec)

    def _one_dec(self, key, spec):
        p = layer_init(key, self.cfg, spec)
        p["xattn"] = attn_init(jax.random.fold_in(key, 7), self.cfg)
        p["norm_xattn"] = jnp.zeros((self.cfg.d_model,), jnp.float32)
        return p

    def init(self, key) -> dict:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        ks = jax.random.split(key, 4 + cfg.n_enc_layers + cfg.n_layers)
        params = {
            "embed": dense_init(ks[0], (cfg.vocab, cfg.d_model),
                                scale=cfg.d_model ** -0.5, dtype=dt),  # tied head: keeps logit std O(1)
            "norm_enc": jnp.zeros((cfg.d_model,), jnp.float32),
            "norm_f": jnp.zeros((cfg.d_model,), jnp.float32),
        }
        spec = LayerSpec(mixer="attn", ffn="mlp")
        enc = [self._one_enc(ks[2 + i], spec)
               for i in range(cfg.n_enc_layers)]
        dec = [self._one_dec(ks[2 + cfg.n_enc_layers + i], spec)
               for i in range(cfg.n_layers)]
        if cfg.scan_layers and cfg.n_enc_layers > 1:
            params["enc_units"] = jax.tree.map(lambda *xs: jnp.stack(xs),
                                               *enc)
            params["dec_units"] = jax.tree.map(lambda *xs: jnp.stack(xs),
                                               *dec)
        else:
            params["enc"] = enc
            params["dec"] = dec
        return params

    def param_specs(self):
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    def _dec_layers(self, params) -> list:
        if "dec" in params:
            return params["dec"]
        n = self.cfg.n_layers
        return [jax.tree.map(lambda t: t[i], params["dec_units"])
                for i in range(n)]

    def encode(self, params, src_embeds: jnp.ndarray,
               unroll_inner: bool = False) -> jnp.ndarray:
        cfg = self.cfg
        x = src_embeds.astype(jnp.dtype(cfg.dtype))
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        spec = LayerSpec(mixer="attn", ffn="mlp")

        def enc_layer(x, p):
            h = rms_norm(x, p["norm_mixer"], cfg.norm_eps)
            h = attention_apply(p["attn"], h, positions, cfg, spec,
                                impl="full" if S <= 512 else "chunked",
                                unroll=unroll_inner, bidirectional=True)
            x = x + h
            h = rms_norm(x, p["norm_ffn"], cfg.norm_eps)
            return x + mlp_apply(p["mlp"], h)

        if "enc_units" in params:
            def body(x, p):
                fn = jax.checkpoint(enc_layer) if cfg.remat else enc_layer
                return fn(x, p), None
            x, _ = jax.lax.scan(body, x, params["enc_units"])
        else:
            for p in params["enc"]:
                x = enc_layer(x, p)
        return rms_norm(x, params["norm_enc"], cfg.norm_eps)

    def loss(self, params, batch, *, unroll_inner: bool = False,
             attn_impl: str | None = None) -> jnp.ndarray:
        cfg = self.cfg
        tokens = batch["tokens"]
        memory = self.encode(params, batch["src_embeds"], unroll_inner)
        x = jnp.take(params["embed"], tokens, axis=0)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        spec = LayerSpec(mixer="attn", ffn="mlp")
        impl = attn_impl or ("full" if S <= 512 else "chunked")

        def dec_layer(x, p):
            h = rms_norm(x, p["norm_mixer"], cfg.norm_eps)
            h = attention_apply(p["attn"], h, positions, cfg, spec,
                                impl=impl, unroll=unroll_inner)
            x = x + h
            h = rms_norm(x, p["norm_xattn"], cfg.norm_eps)
            h = attention_apply(p["xattn"], h, positions, cfg, spec,
                                impl=impl, unroll=unroll_inner,
                                kv_override=memory)
            x = x + h
            h = rms_norm(x, p["norm_ffn"], cfg.norm_eps)
            return x + mlp_apply(p["mlp"], h)

        if "dec_units" in params:
            def body(x, p):
                fn = jax.checkpoint(dec_layer) if cfg.remat else dec_layer
                return fn(x, p), None
            x, _ = jax.lax.scan(body, x, params["dec_units"])
        else:
            for p in params["dec"]:
                x = dec_layer(x, p)
        x = rms_norm(x, params["norm_f"], cfg.norm_eps)
        targets = jnp.concatenate(
            [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1)
        mask = jnp.concatenate(
            [jnp.ones_like(tokens[:, 1:]), jnp.zeros_like(tokens[:, :1])],
            axis=1).astype(jnp.float32)
        return chunked_cross_entropy(x, params["embed"], targets, mask,
                                     chunk=cfg.ce_chunk, unroll=unroll_inner)

    # ------------------------------------------------------------ decode
    def init_cache(self, batch: int, max_len: int) -> dict:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        spec = LayerSpec(mixer="attn", ffn="mlp")
        self_caches = [layer_cache_init(cfg, spec, batch, max_len, dt)
                       for _ in range(cfg.n_layers)]
        # precomputed encoder memory K/V per decoder layer
        mem = [(jnp.zeros((batch, cfg.n_kv_heads, cfg.enc_seq, cfg.head_dim), dt),
                jnp.zeros((batch, cfg.n_kv_heads, cfg.enc_seq, cfg.head_dim), dt))
               for _ in range(cfg.n_layers)]
        return {"self": self_caches, "memory": mem}

    def decode_step(self, params, caches, tokens: jnp.ndarray,
                    pos: jnp.ndarray):
        cfg = self.cfg
        spec = LayerSpec(mixer="attn", ffn="mlp")
        x = jnp.take(params["embed"], tokens, axis=0)
        new_self = []
        for i, p in enumerate(self._dec_layers(params)):
            h = rms_norm(x, p["norm_mixer"], cfg.norm_eps)
            from .attention import attention_decode
            h, c = attention_decode(p["attn"], h, pos, caches["self"][i],
                                    cfg, spec)
            new_self.append(c)
            x = x + h
            h = rms_norm(x, p["norm_xattn"], cfg.norm_eps)
            x = x + cross_attention_decode(p["xattn"], h,
                                           caches["memory"][i], cfg)
            h = rms_norm(x, p["norm_ffn"], cfg.norm_eps)
            x = x + mlp_apply(p["mlp"], h)
        x = rms_norm(x, params["norm_f"], cfg.norm_eps)
        logits = (x @ params["embed"].T).astype(jnp.float32)
        return logits, {"self": new_self, "memory": caches["memory"]}


def build_model(cfg: ModelConfig):
    return EncDecLM(cfg) if cfg.n_enc_layers > 0 else CausalLM(cfg)
