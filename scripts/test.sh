#!/usr/bin/env bash
# Tier-1 verify — runs the suite exactly as ROADMAP.md specifies.
# RUN_BENCH=1 additionally runs the --quick benchmark smoke tier, which
# writes BENCH_io.json (I/O scheduler before/after numbers),
# BENCH_fusion.json (fused vs barriered staged prepare),
# BENCH_stripe.json (multi-SSD striping sweep) and BENCH_migrate.json
# (online re-placement vs static, drifting hotspot) at repo root, then
# runs the regression guard: every freshly written BENCH_*.json speedup
# is compared against its benchmark's asserted floor and any regression
# fails the build loudly (benchmarks/check_regression.py).
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
if [[ "${RUN_BENCH:-0}" == "1" ]]; then
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.run --quick
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.check_regression
fi
