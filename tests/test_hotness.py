"""Hotness telemetry: per-block touch counts from the real prepare path."""
import numpy as np
import pytest

from repro.core import (AgnesConfig, AgnesEngine, FeatureCache,
                        HotnessTracker, Run)


def make_engine(ds, **over):
    g, f = ds.reopen_stores()
    cfg = AgnesConfig(block_size=16384, minibatch_size=64,
                      hyperbatch_size=4, fanouts=(4, 4),
                      graph_buffer_bytes=1 << 20,
                      feature_buffer_bytes=1 << 20,
                      feature_cache_rows=0, async_io=False, **over)
    return AgnesEngine(g, f, cfg)


# ---------------------------------------------------------------- tracker
def test_touch_roll_and_decay():
    t = HotnessTracker(8, decay=0.5)
    t.touch([1, 1, 3])
    t.touch([3], weight=2.0)
    assert t.hotness()[1] == 2.0 and t.hotness()[3] == 3.0
    w = t.roll()
    assert w[3] == 3.0 and t.window_touches == 0.0
    t.touch([0])
    # the just-rolled epoch enters at full weight + the open window
    assert np.allclose(t.hotness(), [1, 2, 0, 3, 0, 0, 0, 0])
    t.roll()
    # decay applies to history at the *next* roll
    assert np.allclose(t.hot, [1, 1, 0, 1.5, 0, 0, 0, 0])
    assert t.n_rolls == 2


def test_touch_runs_counts_every_block():
    t = HotnessTracker(16)
    t.touch_runs([Run(2, 3), Run(10, 2)])
    h = t.hotness()
    assert h[2] == h[3] == h[4] == h[10] == h[11] == 1.0
    assert h.sum() == 5.0 and t.total_touches == 5.0


def test_decay_bounds():
    with pytest.raises(ValueError):
        HotnessTracker(4, decay=1.0)


def test_skew_summary_flat_vs_concentrated():
    flat, hot = HotnessTracker(100), HotnessTracker(100)
    flat.touch(np.arange(100))
    hot.touch(np.repeat(np.arange(5), 20))
    assert flat.skew_summary()["top_share"] == pytest.approx(0.1)
    assert hot.skew_summary()["top_share"] > 0.9
    assert HotnessTracker(10).skew_summary()["top_share"] == 0.0


# ---------------------------------------------------------------- stores
def test_coalesced_reads_feed_tracker(tiny_ds):
    g, _ = tiny_ds.reopen_stores()
    t = HotnessTracker(g.n_blocks)
    g.attach_hotness(t)
    n = min(g.n_blocks, 4)
    g.read_blocks(np.arange(n), max_coalesce_bytes=8 << 20)
    assert np.array_equal(t.hotness()[:n], np.ones(n))
    g.read_block(0)  # per-block path records too
    assert t.hotness()[0] == 2.0


def test_tracker_size_mismatch_rejected(tiny_ds):
    g, _ = tiny_ds.reopen_stores()
    with pytest.raises(ValueError):
        g.attach_hotness(HotnessTracker(g.n_blocks + 1))


def test_node_granular_rows_feed_tracker(tiny_ds):
    _, f = tiny_ds.reopen_stores()
    t = HotnessTracker(f.n_blocks)
    f.attach_hotness(t)
    rpb = f.rows_per_block
    f.read_rows_node_granular(np.array([0, 1, rpb]))
    assert t.hotness()[0] == 2.0 and t.hotness()[1] == 1.0


def test_cache_hits_attributed_at_discount():
    cache = FeatureCache(8, n_nodes=32, dim=4, admit_threshold=1)
    t = HotnessTracker(8)  # 4 rows per block
    cache.attach_hotness(t, rows_per_block=4, hit_weight=0.25)
    nodes = np.array([0, 1, 4])
    cache.note_access(nodes)
    cache.admit(nodes, np.zeros((3, 4), dtype=np.float32))
    mask, _ = cache.lookup(np.array([0, 1, 4, 9]))
    assert mask.tolist() == [True, True, True, False]
    # hits only: blocks 0 (x2) and 1 (x1) at weight 0.25; the miss (9)
    # is left for the store's read path so rows are never double counted
    assert t.hotness()[0] == pytest.approx(0.5)
    assert t.hotness()[1] == pytest.approx(0.25)
    assert t.hotness()[2] == 0.0


# ---------------------------------------------------------------- engine
def test_engine_wires_trackers_and_reports_skew(tiny_ds, rng):
    eng = make_engine(tiny_ds)
    assert eng.graph_store.hotness is eng.graph_hotness
    assert eng.feature_store.hotness is eng.feature_hotness
    targets = [rng.choice(tiny_ds.n_nodes, 100, replace=False)
               for _ in range(4)]
    eng.prepare(targets, epoch=0)
    assert eng.graph_hotness.window_touches > 0
    assert eng.feature_hotness.window_touches > 0
    # storage touches match block-granular read counts exactly (cache off)
    assert eng.graph_hotness.total_touches == eng.graph_store.stats.n_reads
    hot = eng.io_stats()["hotness"]
    assert hot["feature"]["total_touches"] > 0
    assert 0 < hot["feature"]["touched_fraction"] <= 1.0
    eng.close()


def test_end_epoch_rolls_without_topology(tiny_ds, rng):
    eng = make_engine(tiny_ds)
    targets = [rng.choice(tiny_ds.n_nodes, 80, replace=False)]
    eng.prepare(targets, epoch=0)
    assert eng.end_epoch() is None  # no topology: telemetry roll only
    assert eng.graph_hotness.n_rolls == 1
    assert eng.graph_hotness.window_touches == 0.0
    eng.close()
