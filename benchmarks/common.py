"""Shared benchmark harness utilities.

Every benchmark measures the *real* code paths (block stores on disk,
actual sampling/gathering) on container-scale power-law stand-ins, with
device time supplied by the NVMe model (DESIGN.md §6).  Output is CSV
rows ``name,us_per_call,derived`` via :func:`emit`.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (AgnesConfig, AgnesEngine, BaselineConfig, GinexLike,
                        GNNDriveLike, MariusLike, NVMeModel, OutreLike)
from repro.data import build_dataset

WORKDIR = os.environ.get("REPRO_BENCH_DIR", "/tmp/repro_bench")
ROWS: list[tuple[str, float, str]] = []

# --quick smoke tier (benchmarks/run.py --quick): every benchmark runs on
# tiny synthetic graphs so the whole suite finishes in CI time and the
# perf trajectory (BENCH_io.json) is tracked per PR.
QUICK = os.environ.get("REPRO_BENCH_QUICK", "0") == "1"
QUICK_MAX_NODES = 6_000
QUICK_MAX_DIM = 32
QUICK_MAX_BLOCK = 65_536

# --trace-dir (benchmarks/run.py): when set, traced benchmarks export
# their Chrome traces here so every regression report ships an
# inspectable timeline next to its BENCH_*.json
TRACE_DIR = os.environ.get("REPRO_BENCH_TRACE_DIR") or None


def maybe_export_trace(engine_or_recorder, name: str) -> str | None:
    """Export a benchmark's Chrome trace into ``TRACE_DIR``.

    Accepts an engine (uses ``engine.telemetry.trace``) or a bare
    :class:`~repro.core.TraceRecorder`; a no-op returning ``None`` when
    ``--trace-dir`` was not given or the engine records no trace.
    """
    if TRACE_DIR is None:
        return None
    rec = engine_or_recorder
    tel = getattr(engine_or_recorder, "telemetry", None)
    if tel is not None:
        rec = tel.trace
    if rec is None or not hasattr(rec, "export_chrome"):
        return None
    os.makedirs(TRACE_DIR, exist_ok=True)
    path = os.path.join(TRACE_DIR, f"{name}.trace.json")
    rec.export_chrome(path)
    print(f"# trace: {name} -> {path}", flush=True)
    return path


def quick_val(normal, quick):
    """Pick a parameter by tier (reads the QUICK flag at call time)."""
    return quick if QUICK else normal


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)


def flush_rows() -> list:
    out = list(ROWS)
    ROWS.clear()
    return out


def get_dataset(name: str = "ig-mini", dim: int = 128,
                block_size: int = 1 << 20, **kw):
    os.makedirs(WORKDIR, exist_ok=True)
    if QUICK:
        from repro.data.datasets import DATASETS
        n_reg = DATASETS.get(name, (10_000,))[0]
        kw["n_nodes"] = min(kw.get("n_nodes") or n_reg, QUICK_MAX_NODES)
        dim = min(dim, QUICK_MAX_DIM)
        block_size = min(block_size, QUICK_MAX_BLOCK)
    return build_dataset(name, WORKDIR, dim=dim, block_size=block_size, **kw)


def make_agnes(ds, *, setting_bytes: int = 64 << 20, block_size: int = 1 << 20,
               hyperbatch: bool = True, n_ssd: int = 1,
               fanouts=(10, 10, 10), minibatch=512, hyperbatch_size=8,
               cache_rows: int = 0, async_io: bool = False,
               max_coalesce_bytes: int | None = None,
               io_queue_depth: int | None = None,
               io_workers: int | None = None,
               n_arrays: int | None = None,
               placement: str | None = None,
               trace: bool = False,
               topology=None) -> AgnesEngine:
    dev = NVMeModel(n_ssd=n_ssd)
    g, f = ds.reopen_stores(device=dev)
    extra = {}
    if max_coalesce_bytes is not None:
        extra["max_coalesce_bytes"] = max_coalesce_bytes
    if io_queue_depth is not None:
        extra["io_queue_depth"] = io_queue_depth
    if io_workers is not None:
        extra["io_workers"] = io_workers
    if n_arrays is not None:
        extra["n_arrays"] = n_arrays
    if placement is not None:
        extra["placement"] = placement
    cfg = AgnesConfig(block_size=block_size, minibatch_size=minibatch,
                      hyperbatch_size=hyperbatch_size, fanouts=fanouts,
                      graph_buffer_bytes=setting_bytes // 2,
                      feature_buffer_bytes=setting_bytes // 2,
                      feature_cache_rows=cache_rows,
                      hyperbatch_enabled=hyperbatch, async_io=async_io,
                      trace=trace, **extra)
    return AgnesEngine(g, f, cfg, topology=topology)


def make_baseline(cls, ds, *, setting_bytes: int = 64 << 20, n_ssd: int = 1,
                  fanouts=(10, 10, 10), cache_rows: int | None = None):
    dev = NVMeModel(n_ssd=n_ssd)
    _, f = ds.reopen_stores(device=dev)
    csr = ds.csr_storage(setting_bytes // 2, device=dev)
    if cache_rows is None:
        cache_rows = (setting_bytes // 2) // (ds.dim * 4)
    cfg = BaselineConfig(fanouts=fanouts, feature_cache_rows=cache_rows,
                         page_buffer_bytes=setting_bytes // 2)
    return cls(csr, f, cfg)


def targets_for(ds, n_mb: int, mb_size: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [rng.choice(ds.n_nodes, mb_size, replace=False)
            for _ in range(n_mb)]


ALL_BASELINES = {"ginex": GinexLike, "gnndrive": GNNDriveLike,
                 "marius": MariusLike, "outre": OutreLike}

# --- device-time metrics -------------------------------------------------
# This container has 1 CPU core; the paper's host has 16 cores + an A40.
# Benchmarks therefore report the *modeled device time* of the real I/O
# schedule (NVMe model) and a modeled A40 compute time, both labeled.
A40_FLOPS = 150e12      # bf16 dense peak
A40_MFU = 0.35


def prep_time(report) -> float:
    """Modeled data-preparation device time of the measured I/O schedule."""
    return report.modeled_io_s


def gnn_compute_time(prepared, dims=(128, 128, 128, 16)) -> float:
    """Modeled A40 time for the GNN compute over prepared minibatches."""
    flops = 0.0
    for p in prepared:
        d_in = p.features.shape[1]
        widths = (d_in,) + dims[1:]
        for l, layer in enumerate(p.mfg.layers):
            n_dst, fan = layer.nbr_idx.shape
            flops += 2 * 3 * n_dst * (fan + 1) * widths[l] * widths[l + 1]
    return flops / (A40_FLOPS * A40_MFU)
