"""LM serving example: continuous batching + AGNES-style paged KV.

  PYTHONPATH=src python examples/serve_lm.py --arch qwen2-vl-2b
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main

if __name__ == "__main__":
    argv = sys.argv[1:]
    if "--arch" not in argv:
        argv = ["--arch", "smollm-360m"] + argv
    if "--smoke" not in argv:
        argv.append("--smoke")
    raise SystemExit(main(argv))
