"""Hyperbatch-based sampling (paper §3.3, Algorithm 1 lines 3-12).

The loop-order inversion that is the paper's key idea: instead of walking
*target nodes* and fetching whatever blocks they need (reloading blocks
that fall out of the bounded buffer — Fig 5(a)), AGNES walks *blocks* in
ascending ID order and, for each loaded block, serves every minibatch of
the hyperbatch that needs anything in it (Fig 5(b)).  One block-wise I/O
per needed block per hop, and the ascending visit order makes those I/Os
largely sequential.

Both processing modes share all mechanics and the deterministic sampler,
so they produce *identical* MFGs:

* :meth:`HyperbatchSampler.sample_hyperbatch`  — block-major (AGNES-HB)
* :meth:`HyperbatchSampler.sample_per_minibatch` — target-major (AGNES-No)
"""
from __future__ import annotations

import numpy as np

from .block_store import GraphBlock, GraphBlockStore
from .bucket import build_bucket
from .buffer import BlockBuffer
from .sampling import MFG, assemble_layer, sample_indices


class HyperbatchSampler:
    """k-hop neighbor sampler over a :class:`GraphBlockStore`."""

    def __init__(self, store: GraphBlockStore, buffer: BlockBuffer,
                 fanouts: tuple[int, ...], seed: int = 0,
                 prefetcher=None):
        self.store = store
        self.buffer = buffer
        self.fanouts = tuple(fanouts)
        self.seed = seed
        self.prefetcher = prefetcher

    # ------------------------------------------------------------ public
    def sample_hyperbatch(self, targets_per_mb: list[np.ndarray],
                          epoch: int = 0) -> list[MFG]:
        """Block-major sampling for a full hyperbatch (Algorithm 1)."""
        n_mb = len(targets_per_mb)
        frontiers = [np.unique(np.asarray(t, dtype=np.int64)) for t in targets_per_mb]
        mfgs = [MFG(nodes=[f], layers=[]) for f in frontiers]
        for hop, fanout in enumerate(self.fanouts):
            # Bck_{i,j} <- N_in^j in B_g(i)    (Algorithm 1 line 6)
            primary = [self._primary_block(f) for f in frontiers]
            bck = build_bucket(frontiers, primary)
            sampled = [np.full((len(f), fanout), -1, dtype=np.int64)
                       for f in frontiers]
            try:
                if self.prefetcher is not None:
                    # the hop's full visit order is known now; plan only
                    # blocks not already buffer-resident so every planned
                    # block is consumed exactly once (no slot leak)
                    self.prefetcher.plan(self.buffer.absent(bck.row_blocks))
                for r in range(bck.n_rows):  # ascending blocks (line 7)
                    self._process_row(bck, r, frontiers, sampled,
                                      fanout, epoch, hop)
            finally:
                if self.prefetcher is not None:
                    self.prefetcher.reset()  # hop boundary: drop stale plan
            frontiers = self._advance(mfgs, frontiers, sampled)
        return mfgs

    def sample_per_minibatch(self, targets_per_mb: list[np.ndarray],
                             epoch: int = 0) -> list[MFG]:
        """Target-major sampling (no hyperbatch): one minibatch at a time.

        Identical sampling decisions; only the block visit order differs,
        so the bounded buffer may thrash across minibatches (Fig 5(a)).
        """
        out = []
        for t in targets_per_mb:
            out.extend(self._sample_one([np.unique(np.asarray(t, np.int64))],
                                        epoch))
        return out

    def _sample_one(self, frontiers: list[np.ndarray], epoch: int) -> list[MFG]:
        mfgs = [MFG(nodes=[f], layers=[]) for f in frontiers]
        for hop, fanout in enumerate(self.fanouts):
            primary = [self._primary_block(f) for f in frontiers]
            bck = build_bucket(frontiers, primary)
            sampled = [np.full((len(f), fanout), -1, dtype=np.int64)
                       for f in frontiers]
            for r in range(bck.n_rows):
                self._process_row(bck, r, frontiers, sampled,
                                  fanout, epoch, hop)
            frontiers = self._advance(mfgs, frontiers, sampled)
        return mfgs

    # ------------------------------------------------------------ internals
    def _primary_block(self, nodes: np.ndarray) -> np.ndarray:
        """First block containing each node (vectorized T_obj search)."""
        if len(nodes) == 0:
            return np.zeros(0, dtype=np.int64)
        lasts = self.store.t_obj[:, 1]
        lo = np.searchsorted(lasts, nodes, side="left")
        return np.clip(lo, 0, self.store.n_blocks - 1)

    def _load(self, block_id: int, pin: bool) -> GraphBlock:
        if block_id not in self.buffer and self.prefetcher is not None:
            blk = self.prefetcher.fetch(block_id)
            if blk is not None:
                # the I/O already happened on the prefetch thread: count a miss
                self.buffer.stats.buffer_misses += 1
                self.buffer.put(block_id, blk)
                if pin:
                    self.buffer.pin(block_id)
                return blk
        return self.buffer.get(block_id, self.store.read_block, pin=pin)

    def _process_row(self, bck, r: int, frontiers, sampled,
                     fanout: int, epoch: int, hop: int) -> None:
        """Process row ``Bck[i, :]`` — one block serves all minibatches."""
        b = int(bck.row_blocks[r])
        blk = self._load(b, pin=True)
        pinned = [b]
        try:
            row_nodes = np.unique(bck.row_nodes(r))
            nbrs, ok = self._sample_nodes_in_block(
                blk, row_nodes, fanout, epoch, hop, pinned)
            row_nodes = row_nodes[ok]
            nbrs = nbrs[ok]
            # fan the shared sample out to every minibatch in the row
            for g in range(bck.row_ptr[r], bck.row_ptr[r + 1]):
                j = int(bck.mb_ids[g])
                g_nodes = bck.nodes[bck.group_ptr[g]:bck.group_ptr[g + 1]]
                sel = np.searchsorted(row_nodes, g_nodes)
                sel_ok = (sel < len(row_nodes))
                sel_c = np.clip(sel, 0, max(len(row_nodes) - 1, 0))
                sel_ok &= row_nodes[sel_c] == g_nodes if len(row_nodes) else False
                dst_pos = np.searchsorted(frontiers[j], g_nodes)
                sampled[j][dst_pos[sel_ok]] = nbrs[sel_c[sel_ok]]
        finally:
            for p in pinned:
                self.buffer.unpin(p)

    def _sample_nodes_in_block(self, blk: GraphBlock, nodes: np.ndarray,
                               fanout: int, epoch: int, hop: int,
                               pinned: list[int]) -> tuple[np.ndarray, np.ndarray]:
        """Sample ``fanout`` neighbors for each node whose object starts in
        ``blk``.  Returns ((n, fanout) neighbor ids with -1 pad, ok mask)."""
        entry, present = blk.find_entries(nodes)
        nbrs = np.full((len(nodes), fanout), -1, dtype=np.int64)
        if not present.any():
            return nbrs, present
        e = entry[present]
        deg = blk.total_degree[e]
        pos = sample_indices(nodes[present], deg, fanout, self.seed, epoch, hop)
        counts = blk.indptr[e + 1] - blk.indptr[e]
        whole = counts == deg  # object fully inside this block
        # vectorized path: positions index directly into the block payload
        w = np.nonzero(whole)[0]
        if w.size and len(blk.indices):
            base = blk.indptr[e[w]][:, None]
            p = pos[w]
            sel = np.where(p >= 0, base + p, 0)
            vals = blk.indices[sel]
            nbrs_present = np.where(p >= 0, vals, -1)
            out_idx = np.nonzero(present)[0][w]
            nbrs[out_idx] = nbrs_present
        # split objects (hub nodes): stitch continuation blocks
        s = np.nonzero(~whole)[0]
        for i in s.tolist():
            node = int(nodes[present][i])
            adj = self._stitch_split(blk, int(e[i]), node, int(deg[i]), pinned)
            p = pos[i]
            row = np.where(p >= 0, adj[np.clip(p, 0, len(adj) - 1)], -1)
            nbrs[np.nonzero(present)[0][i]] = row
        return nbrs, present

    def _stitch_split(self, blk: GraphBlock, entry: int, node: int,
                      total_deg: int, pinned: list[int]) -> np.ndarray:
        """Assemble the full adjacency of an object split across blocks."""
        parts = [blk.adjacency(entry)]
        got = len(parts[0])
        bid = blk.block_id
        while got < total_deg:
            bid += 1
            nxt = self._load(bid, pin=True)
            pinned.append(bid)
            ent, ok = nxt.find_entries(np.array([node]))
            if not ok[0]:
                raise RuntimeError(
                    f"split object {node} not found in continuation block {bid}")
            part = nxt.adjacency(int(ent[0]))
            parts.append(part)
            got += len(part)
        return np.concatenate(parts)

    @staticmethod
    def _advance(mfgs: list[MFG], frontiers: list[np.ndarray],
                 sampled: list[np.ndarray]) -> list[np.ndarray]:
        nxt_frontiers = []
        for j, mfg in enumerate(mfgs):
            nxt, layer = assemble_layer(frontiers[j], sampled[j])
            mfg.nodes.append(nxt)
            mfg.layers.append(layer)
            nxt_frontiers.append(nxt)
        return nxt_frontiers
