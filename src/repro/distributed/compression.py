"""Gradient compression with error feedback (inter-pod DP axis).

At 2+ pods the gradient all-reduce crosses the DCN (≈25 GB/s vs 50+ GB/s
ICI), so the pod-axis reduction is the step-time tail.  int8 block-scaled
quantization cuts those bytes 2× vs bf16 (4× vs f32); error feedback
(residual carry) keeps SGD convergence unbiased in expectation — the
standard EF-SGD recipe.

Usage: pass ``make_ef_int8_transform(state)`` as ``grad_transform`` to
``make_train_step`` — quantize→dequantize models the wire format while
the residual state threads through the optimizer step; on real multi-pod
deployments the quantized payload is what crosses the DCN.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def quantize_int8(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Block-scaled symmetric int8. Returns (q, scales)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-len(flat)) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray,
                    shape: tuple, dtype) -> jnp.ndarray:
    out = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return out[:n].reshape(shape).astype(dtype)


def ef_compress_tree(grads, residuals):
    """Error-feedback int8 round trip over a gradient pytree.

    Returns (decompressed grads as would arrive post-all-reduce,
    new residuals).
    """
    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q, s = quantize_int8(corrected)
        deq = dequantize_int8(q, s, g.shape, jnp.float32)
        new_r = corrected - deq
        return deq.astype(g.dtype), new_r

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_bytes(params) -> int:
    """Wire bytes per step for the int8 scheme (payload + scales)."""
    total = 0
    for p in jax.tree.leaves(params):
        n = p.size
        total += n + (n // BLOCK + 1) * 4
    return total
