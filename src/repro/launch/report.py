"""Generate EXPERIMENTS.md tables from results/*.json artifacts."""
from __future__ import annotations

import json
import os


def dryrun_table(path: str) -> str:
    if not os.path.exists(path):
        return f"_missing: {path}_\n"
    rows = json.load(open(path))
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = ["| arch | shape | status | lower s | compile s | args GiB/dev | "
           "temp GiB/dev | HLO flops | collective B |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        st = r.get("status", "?")
        if st == "ok":
            out.append(
                f"| {r['arch']} | {r['shape']} | ok | {r['lower_s']} | "
                f"{r['compile_s']} | {r['memory']['args_GiB_per_dev']} | "
                f"{r['memory']['temp_GiB_per_dev']} | "
                f"{r['cost']['flops']:.3e} | "
                f"{r['collectives']['total_bytes']:.3e} |")
        elif st == "FAIL":
            out.append(f"| {r['arch']} | {r['shape']} | **FAIL** "
                       f"{r.get('error','')[:60]} | | | | | | |")
        else:
            out.append(f"| {r['arch']} | {r['shape']} | "
                       f"{st.split(chr(10))[0][:70]} | | | | | | |")
    return "\n".join(out) + "\n"


def roofline_table(path: str) -> str:
    if not os.path.exists(path):
        return f"_missing: {path}_\n"
    rows = json.load(open(path))
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = ["| arch | shape | compute s | memory s | collective s | dominant "
           "| MODEL/HLO flops | bound MFU |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        st = r.get("status", "?")
        if st == "ok":
            t = r["terms_s"]
            out.append(
                f"| {r['arch']} | {r['shape']} | {t['compute_s']:.4f} | "
                f"{t['memory_s']:.4f} | {t['collective_s']:.4f} | "
                f"{r['dominant'].replace('_s','')} | "
                f"{r['useful_ratio']} | {r['bound_mfu']} |")
        elif st == "FAIL":
            out.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | | |")
        else:
            out.append(f"| {r['arch']} | {r['shape']} | skip (see DESIGN §4)"
                       f" | | | | | |")
    return "\n".join(out) + "\n"


if __name__ == "__main__":
    import sys
    kind = sys.argv[1] if len(sys.argv) > 1 else "dryrun"
    path = sys.argv[2]
    print(dryrun_table(path) if kind == "dryrun" else roofline_table(path))
