"""Feature cache ``C_f`` + cache index table ``T_ch^f`` (paper §3.4(2)).

AGNES counts accesses to each feature vector and keeps only rows whose
access count exceeds a threshold resident in the in-memory feature cache;
infrequently accessed rows are written back / dropped at minibatch
boundaries and re-read from storage when needed again.

Implementation is fully vectorized (this container has one CPU core):

* ``T_ch`` (cache index table)  → ``slot_of[node] ∈ {-1, slot}``
* ``C_f``  (feature cache)      → ``rows[slot, :]``
* access counters               → ``counts[node]``
* eviction                      → clock (second-chance-free FIFO ring),
  which approximates the paper's LRU within the admitted set.
"""
from __future__ import annotations

import numpy as np

from .device_model import IOStats


class FeatureCache:
    """Access-count-thresholded, vectorized feature-row cache."""

    def __init__(self, capacity_rows: int, n_nodes: int, dim: int,
                 admit_threshold: int = 2,
                 dtype: np.dtype = np.float32,
                 stats: IOStats | None = None):
        self.capacity = max(int(capacity_rows), 0)
        self.n_nodes = n_nodes
        self.dim = dim
        self.admit_threshold = admit_threshold
        self.dtype = np.dtype(dtype)
        self.stats = stats if stats is not None else IOStats()
        cap = max(self.capacity, 1)
        self.slot_of = np.full(n_nodes, -1, dtype=np.int64)   # T_ch
        self.node_at = np.full(cap, -1, dtype=np.int64)
        self.rows = np.zeros((cap, dim), dtype=self.dtype)    # C_f
        self.counts = np.zeros(n_nodes, dtype=np.int64)
        self._clock = 0
        self._n_resident = 0
        # hotness telemetry (core/hotness.py): cache hits attributed to
        # their feature blocks at a discount — a hit is storage traffic
        # the cache absorbed *this* epoch but may not absorb the next
        self._hotness = None
        self._hot_rows_per_block = 1
        self._hot_hit_weight = 0.0

    def attach_hotness(self, tracker, rows_per_block: int,
                       hit_weight: float = 0.25) -> None:
        """Report per-block hit traffic into a :class:`HotnessTracker`.

        Misses are *not* recorded here — the store's accounting layer
        records them when the missed blocks are actually read, so a row
        is never double counted.
        """
        self._hotness = tracker
        self._hot_rows_per_block = max(int(rows_per_block), 1)
        self._hot_hit_weight = float(hit_weight)

    def __len__(self) -> int:
        return self._n_resident

    # ------------------------------------------------------------ reads
    def lookup(self, nodes: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Split ``nodes`` into (hit_mask, rows-for-hits, in nodes' order)."""
        nodes = np.asarray(nodes)
        slots = self.slot_of[nodes]
        mask = slots >= 0
        self.stats.cache_hits += int(mask.sum())
        self.stats.cache_misses += int((~mask).sum())
        if self._hotness is not None and self._hot_hit_weight > 0 \
                and mask.any():
            self._hotness.touch(nodes[mask] // self._hot_rows_per_block,
                                weight=self._hot_hit_weight)
        return mask, self.rows[slots[mask]]

    def note_access(self, nodes: np.ndarray) -> None:
        np.add.at(self.counts, np.asarray(nodes), 1)

    # ------------------------------------------------------------ admit
    def admit(self, nodes: np.ndarray, rows: np.ndarray) -> int:
        """Offer freshly-read rows; admit those above the access threshold.

        Rows below the threshold are *not* kept (the paper writes them back
        to storage each minibatch).  Returns the number admitted.
        """
        if self.capacity == 0 or len(nodes) == 0:
            return 0
        nodes = np.asarray(nodes)
        cand = (self.counts[nodes] >= self.admit_threshold) & (self.slot_of[nodes] < 0)
        cand_idx = np.nonzero(cand)[0]
        if cand_idx.size == 0:
            return 0
        # dedupe within the batch, keep first occurrence; a single batch
        # can admit at most `capacity` rows (slots must stay distinct)
        uniq_nodes, first = np.unique(nodes[cand_idx], return_index=True)
        cand_idx = cand_idx[first][:self.capacity]
        k = len(cand_idx)
        # allocate k slots from the clock ring, evicting current occupants
        slots = (self._clock + np.arange(k)) % max(self.capacity, 1)
        self._clock = int((self._clock + k) % max(self.capacity, 1))
        evicted = self.node_at[slots]
        live = evicted >= 0
        self.slot_of[evicted[live]] = -1
        self._n_resident -= int(live.sum())
        self.node_at[slots] = nodes[cand_idx]
        self.slot_of[nodes[cand_idx]] = slots
        self.rows[slots] = rows[cand_idx]
        self._n_resident += k
        return k

    def resident_nodes(self) -> np.ndarray:
        return self.node_at[self.node_at >= 0]

    def clear(self) -> None:
        self.slot_of.fill(-1)
        self.node_at.fill(-1)
        self.counts.fill(0)
        self._clock = 0
        self._n_resident = 0
