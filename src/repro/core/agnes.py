"""AGNES engine: the paper's 3-layer architecture, assembled (§3.2, Alg 1).

* storage layer   — :class:`GraphBlockStore` / :class:`FeatureBlockStore`
* in-memory layer — graph/feature :class:`BlockBuffer` (T_buf), pinned
  object index table (inside the stores), :class:`FeatureCache` (C_f/T_ch)
* operation layer — :class:`HyperbatchSampler` + :class:`FeatureGatherer`

``prepare(targets)`` runs data preparation for one hyperbatch: k-hop
sampling (S-1..S-3) then gathering (G-1..G-3), returning per-minibatch
(MFG, contiguous feature array) pairs ready for device transfer.  The
engine reports exact I/O statistics and modeled device time per stage,
which the benchmark harness turns into the paper's figures.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from .async_io import BlockPrefetcher
from .block_store import DEFAULT_BLOCK_SIZE, FeatureBlockStore, GraphBlockStore
from .io_sched import CoalescedReader, PlanStream
from .buffer import BlockBuffer
from .device_model import IOStats, NVMeModel
from .fault import FaultInjector
from .feature_cache import FeatureCache
from .gather import FeatureGatherer
from .hotness import HotnessTracker
from .hyperbatch import HyperbatchSampler
from .migration import MigrationEngine
from .sampling import MFG
from .session import PrepareSession
from .telemetry import Telemetry
from .topology import (HotnessAwarePlacement, StorageTopology,
                       feature_block_hotness, graph_block_hotness,
                       make_policy)


@dataclasses.dataclass
class AgnesConfig:
    """Paper defaults: 1 MiB blocks, minibatch 1000, hyperbatch 1024."""

    block_size: int = DEFAULT_BLOCK_SIZE
    minibatch_size: int = 1000
    hyperbatch_size: int = 1024          # minibatches per hyperbatch
    fanouts: tuple[int, ...] = (10, 10, 10)
    graph_buffer_bytes: int = 16 << 30   # Setting 1
    feature_buffer_bytes: int = 16 << 30
    feature_cache_rows: int = 0          # 0 = auto (half the feature buffer)
    cache_admit_threshold: int = 2
    # --- feature-cache policy (core/feature_cache.py + cache_oracle.py) ---
    # eviction policy: "clock" | "lru" | "oracle" (Belady MIN from a
    # precomputed trace — install via engine.install_cache_oracle)
    cache_policy: str = "clock"
    # explicit row budget; overrides feature_cache_rows when > 0 (the
    # load-bearing capacity knob: small budgets force real eviction)
    cache_capacity_rows: int = 0
    # charge evictions as row-granular writeback I/O on the feature
    # store's device (the paper's minibatch-boundary writeback)
    cache_writeback: bool = False
    # append each gather cycle's node list to engine.feature_trace —
    # the recorded access trace a later install_cache_oracle() replays
    # (Ginex's offline pass; exact when the same plan is replayed)
    record_feature_trace: bool = False
    hyperbatch_enabled: bool = True      # False = AGNES-No ablation
    async_io: bool = True
    prefetch_depth: int = 8
    # --- coalesced I/O scheduler (io_sched.py) ---
    # max bytes per merged sequential request; 0 disables the scheduler
    # entirely (legacy per-block path); block_size = batched submission
    # without merging
    max_coalesce_bytes: int = 8 << 20
    io_queue_depth: int = 8              # in-flight coalesced requests
    io_workers: int = 2                  # reader pool size (async_io only)
    # cross-hop plan fusion (core/session.py): submit hop k+1's plan while
    # hop k's tail is still being consumed, no per-hop reset barrier, one
    # fused PlanStream per device.  False = pre-session schedule (one plan
    # per hop, barrier at every hop boundary) — bytes/MFGs identical.
    plan_fusion: bool = True
    # --- storage topology (core/topology.py) ---
    # number of independent NVMe arrays; 1 = single opaque device (the
    # pre-topology path, byte- and time-identical to earlier releases)
    n_arrays: int = 1
    # block placement policy across arrays: "contiguous" | "stripe" |
    # "hotness" (degree-aware, Ginex-style pinning)
    placement: str = "stripe"
    # RAID0 chunk in blocks; the block is already the I/O unit, so
    # one-block chunks interleave finest and balance short runs best
    stripe_width_blocks: int = 1
    # --- online re-placement (core/hotness.py + core/migration.py) ---
    # at epoch boundaries, re-score placement from *measured* per-block
    # touch counts (Ginex-style) and migrate up to migrate_budget_bytes
    # of blocks per store per epoch through the crash-consistent write
    # path, charging the copy I/O to the owning arrays
    online_placement: bool = False
    migrate_budget_bytes: int = 64 << 20
    # exponential decay of the hotness accumulator at each epoch roll
    # (0 = only the last epoch counts)
    hotness_decay: float = 0.5
    # weight of a feature-cache *hit* in the hotness signal (hits are
    # absorbed storage traffic — forward-looking, not current cost)
    hotness_cache_hit_weight: float = 0.25
    # --- storage fault domain (core/fault.py) ---
    # scriptable injected-fault schedule, e.g.
    # "transient:p=0.01;latency:p=0.005,factor=30;dropout:array=1,at=500"
    # (None/"" = no injection; real OSErrors are classified regardless)
    fault_schedule: str | None = None
    # bounded retry budget for transient read faults (re-issues beyond
    # the first attempt; an exhausted budget escalates to permanent)
    io_retries: int = 2
    # base of the exponential retry backoff, jittered to 0.5-1.5x and
    # charged as modeled stall time on the retrying array
    io_retry_backoff_s: float = 1e-3
    # hedge a run whose service time exceeds this multiple of the
    # array's p99 run time (duplicate-to-sibling read); <= 0 disables
    hedge_deadline_frac: float = 1.5
    # --- serving tier (core/serving.py) ---
    # per-fetch deadline of the coalesced readers (previously a
    # hardcoded 30 s in CoalescedReader.fetch); a serving tenant's QoS
    # class overrides it per reader at enrollment
    io_fetch_timeout_s: float = 30.0
    # --- telemetry (core/telemetry.py) ---
    # record structured trace spans (prepare stages, per-array I/O runs,
    # faults, admission waits, migration windows, cache churn) into a
    # ring buffer exportable as Chrome trace JSON; off = the metrics
    # registry stays live but span recording costs one branch
    trace: bool = False
    trace_buffer_events: int = 65536
    seed: int = 0

    def buffer_blocks(self, nbytes: int) -> int:
        return max(int(nbytes // self.block_size), 2)


@dataclasses.dataclass
class PreparedMinibatch:
    mfg: MFG
    features: np.ndarray  # (len(mfg.input_nodes), dim) contiguous
    # cache-hit split recorded at gather time (core/gather.py) — fuels
    # the device-resident transfer; None on cache-less/baseline paths
    resident: object | None = None

    @property
    def targets(self) -> np.ndarray:
        return self.mfg.nodes[0]

    def to_device(self, device=None, backend: str = "jnp",
                  pad_multiple: int = 128,
                  table=None) -> "PreparedMinibatch":
        """Placement hook: land the gathered features as a jax device array.

        ``backend="pallas"`` builds the jit-stable *padded* feature block
        on device through the Pallas ``gather_rows`` kernel path (HBM→VMEM
        block DMA on TPU, interpret mode elsewhere) — the GIDS-style
        device-resident landing, with ``pad_mfg`` recognizing the already-
        padded block and skipping its host round-trip; ``"jnp"`` is a
        plain host→device transfer.  The MFG index arrays stay numpy
        (``pad_mfg`` converts them at jit boundaries).

        With a :class:`~repro.core.gather.DeviceFeatureTable`, cache-hit
        rows are gathered HBM→HBM from the pinned cache mirror and only
        miss (or demoted) rows travel host→device, through the masked
        Pallas kernel (``backend="pallas"``) or its jnp oracle.
        """
        import jax
        import jax.numpy as jnp

        n = self.features.shape[0]
        if table is not None and n:
            from ..kernels.ops import gather_resident_rows
            padded_n = -(-n // pad_multiple) * pad_multiple
            slots, host_pos = table.resolve(self.resident, n, padded_n)
            miss_rows = np.ascontiguousarray(self.features[host_pos])
            feats = gather_resident_rows(
                table.array, jnp.asarray(slots, dtype=jnp.int32),
                jnp.asarray(host_pos, dtype=jnp.int32),
                jnp.asarray(miss_rows),
                use_kernel=None if backend == "pallas" else False)
            if device is not None:
                feats = jax.device_put(feats, device)
            return PreparedMinibatch(self.mfg, feats, self.resident)
        feats = jnp.asarray(self.features)
        if backend == "pallas" and n:
            from ..kernels.ops import gather_rows
            padded_n = -(-n // pad_multiple) * pad_multiple
            idx = jnp.arange(padded_n, dtype=jnp.int32)
            rows = gather_rows(feats, jnp.minimum(idx, n - 1))
            feats = jnp.where((idx < n)[:, None], rows, 0)
        if device is not None:
            feats = jax.device_put(feats, device)
        return PreparedMinibatch(self.mfg, feats, self.resident)


@dataclasses.dataclass
class PrepareReport:
    sample_wall_s: float
    gather_wall_s: float
    sample_io: dict
    gather_io: dict
    modeled_io_s: float
    modeled_prepare_s: float  # max(cpu, io) if async else cpu + io

    @property
    def wall_s(self) -> float:
        return self.sample_wall_s + self.gather_wall_s


class AgnesEngine:
    """Storage-based GNN data-preparation engine (the paper's framework)."""

    def __init__(self, graph_store: GraphBlockStore,
                 feature_store: FeatureBlockStore,
                 config: AgnesConfig | None = None,
                 topology: StorageTopology | None = None,
                 migration_policy=None):
        self.config = config or AgnesConfig()
        cfg = self.config
        self.graph_store = graph_store
        self.feature_store = feature_store
        # storage topology: explicit multi-array placement (topology.py).
        # An explicit ``topology`` wins (heterogeneous arrays, sweeps);
        # otherwise cfg.n_arrays > 1 builds a uniform one from the store
        # device.  Placement only reshapes requests/queues/accounting —
        # bytes, MFGs and features stay identical to the single-array path.
        if topology is None and cfg.n_arrays > 1:
            topology = StorageTopology.uniform(cfg.n_arrays,
                                               like=graph_store.device)
        if topology is None:
            topology = graph_store.topology  # stores pre-attached by caller
        self.topology = topology
        if topology is not None:
            # stores with a placement already attached (custom policies,
            # reloaded on-disk layouts) are respected, not re-placed.
            # persist=False: config-derived placements are deterministic,
            # so engine construction must not rewrite <store>.topo.json
            # as a side effect — persistence is the store API's job
            # (attach_topology(persist=True) / load_placement).
            policy = make_policy(cfg.placement, cfg.stripe_width_blocks)
            if graph_store.placement is None:
                graph_store.attach_topology(topology, policy.place(
                    graph_store.n_blocks, topology,
                    hotness=graph_block_hotness(graph_store)),
                    persist=False)
            if feature_store.placement is None:
                feature_store.attach_topology(topology, policy.place(
                    feature_store.n_blocks, topology,
                    hotness=feature_block_hotness(
                        feature_store, graph_store.approx_degrees())),
                    persist=False)
        # storage fault domain (core/fault.py): one injector shared by
        # both stores (engine-wide op counter), consulted by the
        # coalesced readers per physical read attempt and by
        # migrate_blocks per journal write
        self.fault_injector: FaultInjector | None = None
        if cfg.fault_schedule:
            self.fault_injector = FaultInjector.parse(cfg.fault_schedule,
                                                      seed=cfg.seed)
            graph_store.attach_fault(self.fault_injector)
            feature_store.attach_fault(self.fault_injector)
        self.graph_buffer = BlockBuffer(
            cfg.buffer_blocks(cfg.graph_buffer_bytes), name="graph")
        self.feature_buffer = BlockBuffer(
            cfg.buffer_blocks(cfg.feature_buffer_bytes), name="feature")
        cache_rows = cfg.cache_capacity_rows or cfg.feature_cache_rows
        if cache_rows == 0:
            cache_rows = (cfg.feature_buffer_bytes // 2) // max(
                feature_store.row_bytes, 1)
        cache_rows = min(cache_rows, feature_store.n_nodes)
        self.feature_cache = FeatureCache(
            cache_rows, feature_store.n_nodes, feature_store.dim,
            admit_threshold=cfg.cache_admit_threshold,
            dtype=feature_store.dtype, policy=cfg.cache_policy)
        if cfg.cache_writeback:
            # evictions become row-granular writes on the feature store's
            # device — the capacity budget now costs modeled I/O time
            self.feature_cache.attach_writeback(
                feature_store.device, feature_store.stats,
                queue_depth=cfg.io_queue_depth)
        # recorded feature-access trace (one entry per gather cycle);
        # install_cache_oracle() replays it as a Belady MIN schedule.
        # _oracle_trace keeps the installed schedule's source trace so
        # refresh_cache_oracle() can rebuild from the remaining steps.
        self.feature_trace: list[np.ndarray] = []
        self._oracle_trace: list[np.ndarray] | None = None
        # hotness telemetry (core/hotness.py): every storage touch from
        # the prepare path lands in per-store trackers; the feature
        # cache reports its hits at a discount.  Always on — the
        # counters are cheap and io_stats() surfaces the measured skew.
        self.graph_hotness = HotnessTracker(graph_store.n_blocks,
                                            decay=cfg.hotness_decay)
        self.feature_hotness = HotnessTracker(feature_store.n_blocks,
                                              decay=cfg.hotness_decay)
        graph_store.attach_hotness(self.graph_hotness)
        feature_store.attach_hotness(self.feature_hotness)
        self.feature_cache.attach_hotness(
            self.feature_hotness, feature_store.rows_per_block,
            hit_weight=cfg.hotness_cache_hit_weight)
        # online re-placement (core/migration.py): at epoch boundaries
        # the measured hotness replaces the static degree proxy as the
        # PlacementPolicy input and a budgeted migration pass moves the
        # hottest misplaced blocks through the durable write path
        self._migrations: list[tuple[str, MigrationEngine, HotnessTracker]] = []
        if cfg.online_placement and self.topology is not None:
            if migration_policy is None:
                # hot_mass=1.0: pin *everything measured hot* — a mass
                # cut on near-uniform measured hotness selects a random
                # subset that reshuffles every epoch (churn), while the
                # budget + hottest-first ordering already bound the
                # write traffic.  hot_gate=1.2 (vs the attach-time
                # default of 2.0): measured traffic needs far less skew
                # evidence than a noisy proxy, but flat traffic — a hot
                # set no denser than its block share — must still
                # degenerate to plain striping rather than pin a
                # contiguous slab of the store onto one array.
                migration_policy = HotnessAwarePlacement(
                    cfg.stripe_width_blocks, hot_mass=1.0,
                    max_hot_fraction=0.6, hot_gate=1.2)
            self._migrations = [
                ("graph", MigrationEngine(
                    graph_store, migration_policy,
                    cfg.migrate_budget_bytes, name="graph",
                    queue_depth=cfg.io_queue_depth), self.graph_hotness),
                ("feature", MigrationEngine(
                    feature_store, migration_policy,
                    cfg.migrate_budget_bytes, name="feature",
                    queue_depth=cfg.io_queue_depth), self.feature_hotness),
            ]
        self.last_migration: dict | None = None
        self._in_session = False
        self._array_qd: dict[int, int] = {}
        # lazy plan_epoch trigger bookkeeping: tracker roll counts seen
        # at the last plan_epoch (see the hook in plan_epoch)
        self._rolls_at_last_plan: tuple[int, int] = (0, 0)
        self._g_prefetch = None
        self._f_prefetch = None
        if cfg.max_coalesce_bytes > 0:
            # coalesced plan-driven scheduler (default).  With async_io off
            # the plan executes lazily on the consumer thread — still
            # coalesced and batch-charged, but fully deterministic.
            # Readers over stores sharing one NVMe array share a PlanStream
            # so back-to-back graph and feature plans fuse in the device
            # queue (a single submission costs exactly the per-plan batch).
            workers = cfg.io_workers if cfg.async_io else 0
            g_stream = PlanStream(graph_store.device)
            f_stream = (g_stream
                        if (self.topology is not None
                            or feature_store.device is graph_store.device)
                        else PlanStream(feature_store.device))
            self._g_prefetch = CoalescedReader(
                graph_store, max_coalesce_bytes=cfg.max_coalesce_bytes,
                queue_depth=cfg.io_queue_depth, workers=workers,
                stream=g_stream, retries=cfg.io_retries,
                retry_backoff_s=cfg.io_retry_backoff_s,
                hedge_deadline_frac=cfg.hedge_deadline_frac,
                seed=cfg.seed, fetch_timeout_s=cfg.io_fetch_timeout_s)
            self._f_prefetch = CoalescedReader(
                feature_store, max_coalesce_bytes=cfg.max_coalesce_bytes,
                queue_depth=cfg.io_queue_depth, workers=workers,
                stream=f_stream, retries=cfg.io_retries,
                retry_backoff_s=cfg.io_retry_backoff_s,
                hedge_deadline_frac=cfg.hedge_deadline_frac,
                seed=cfg.seed + 1,
                fetch_timeout_s=cfg.io_fetch_timeout_s)
        elif cfg.async_io:
            # legacy per-block read-ahead thread
            self._g_prefetch = BlockPrefetcher(
                graph_store.read_block, depth=cfg.prefetch_depth,
                should_skip=lambda b: b in self.graph_buffer)
            self._f_prefetch = BlockPrefetcher(
                feature_store.read_block, depth=cfg.prefetch_depth,
                should_skip=lambda b: b in self.feature_buffer)
        self.sampler = HyperbatchSampler(
            graph_store, self.graph_buffer, cfg.fanouts, seed=cfg.seed,
            prefetcher=self._g_prefetch)
        self.gatherer = FeatureGatherer(
            feature_store, self.feature_buffer, self.feature_cache,
            prefetcher=self._f_prefetch)
        if cfg.record_feature_trace:
            self.gatherer.trace_sink = self.feature_trace
        self.last_report: PrepareReport | None = None
        self.last_session: PrepareSession | None = None
        # unified telemetry (core/telemetry.py): metrics registry always
        # live, trace recorder only when cfg.trace.  set_telemetry binds
        # the bundle into the readers / cache / migration engines; a
        # serving tier re-calls it with the primary engine's bundle so
        # every tenant records into one trace.
        self._tel_label = "train"
        self.telemetry = Telemetry(trace=cfg.trace,
                                   capacity=cfg.trace_buffer_events)
        self.set_telemetry(self.telemetry)

    # ------------------------------------------------------------ API
    def prepare(self, targets_per_mb: list[np.ndarray],
                epoch: int = 0) -> list[PreparedMinibatch]:
        """Data preparation for one hyperbatch (Algorithm 1).

        Thin compatibility wrapper: drives a staged
        :class:`~repro.core.session.PrepareSession` to completion (the
        hyperbatch path); the session object is kept on
        :attr:`last_session` for stage-level inspection.  The AGNES-No
        ablation (``hyperbatch_enabled=False``) keeps the target-major
        imperative path — there is no hyperbatch-wide plan to stage.
        """
        cfg = self.config
        for p in (self._g_prefetch, self._f_prefetch):
            if p is not None:
                p.reset()  # defensive: drop any stale plan from an aborted run
        io_before = self._io_snapshot()
        t0 = time.perf_counter()
        if cfg.hyperbatch_enabled:
            session = PrepareSession(self, targets_per_mb, epoch)
            out = session.run()
            self.last_session = session
            t2 = time.perf_counter()
            t1 = min(t0 + session.sample_wall_s, t2)
        else:
            mfgs = self.sampler.sample_per_minibatch(targets_per_mb, epoch)
            t1 = time.perf_counter()
            feats = self.gatherer.gather_per_minibatch(
                [m.input_nodes for m in mfgs])
            out = [PreparedMinibatch(m, f) for m, f in zip(mfgs, feats)]
            t2 = time.perf_counter()
        io_after = self._io_snapshot()
        self.last_report = self._report(t0, t1, t2, io_before, io_after)
        tr = self.telemetry.trace
        if tr is not None:
            # reuse this method's own t0/t2 readings so the trace-derived
            # Fig.2 prepare bar agrees with wall-clock accumulators that
            # bracket this call (OverlapReport.prepare_wall_s) to within
            # function-call overhead
            tr.complete("prepare:hb", "prepare",
                        f"prepare:{self._tel_label}", t0, t2,
                        args={"epoch": epoch,
                              "n_minibatches": len(targets_per_mb),
                              "modeled_io_s": round(
                                  self.last_report.modeled_io_s, 6)})
        return out

    def set_telemetry(self, telemetry: Telemetry,
                      tenant: str | None = None) -> Telemetry:
        """Install (or share) a :class:`Telemetry` bundle.

        Rebinds the coalesced readers, feature cache, and migration
        engines so their spans/counters land in ``telemetry``.  A
        serving tier calls this on every tenant engine with the primary
        engine's bundle (and the tenant name) so all tenants record
        into one trace with per-tenant tracks.
        """
        self.telemetry = telemetry
        if tenant:
            self._tel_label = tenant
        for rd, label in ((self._g_prefetch, "graph"),
                          (self._f_prefetch, "feature")):
            if rd is not None and hasattr(rd, "bind_telemetry"):
                rd.bind_telemetry(telemetry, store=label,
                                  tenant=self._tel_label)
        self.feature_cache.attach_telemetry(telemetry)
        for _name, mig, _tracker in self._migrations:
            mig.telemetry = telemetry
        return telemetry

    def metrics_snapshot(self, refresh: bool = True) -> dict:
        """Atomic snapshot of the unified metrics namespace.

        ``refresh=True`` first folds the engine's scattered summary
        dicts (:meth:`io_stats`) into gauges under ``agnes.*`` so the
        snapshot is the one queryable place holding live counters
        (``io.*``, ``cache.*``, ``migration.*``, ``admission.*``) *and*
        the derived summaries — the roofline substrate the ROADMAP's
        model-based controller consumes.
        """
        if refresh:
            self.telemetry.metrics.set_gauges("agnes", self.io_stats())
        return self.telemetry.metrics.snapshot()

    def diagnose(self, thresholds=None):
        """Run the storage doctor over everything this engine has done.

        Folds the current :meth:`io_stats` into the metrics namespace,
        hands the snapshot (plus the trace, when recording) to
        :func:`repro.core.diagnosis.diagnose`, and returns the
        :class:`~repro.core.diagnosis.DoctorReport` — per-array
        roofline states, the exposed-prepare decomposition, and ranked
        findings with a suggested knob each.  Counters are cumulative,
        so the report covers the window since engine construction (or
        the last stats reset); for per-epoch windows, drive an
        :class:`~repro.core.diagnosis.AnomalyWatchdog` alongside.
        """
        from .diagnosis import diagnose
        snap = self.metrics_snapshot(refresh=True)
        tr = self.telemetry.trace
        dev = self.graph_store.device
        return diagnose(
            snap, events=tr.events() if tr is not None else None,
            thresholds=thresholds,
            default_device={"bandwidth": dev.array_bandwidth,
                            "latency": dev.latency,
                            "queue_depth": dev.queue_depth})

    def open_session(self, targets_per_mb: list[np.ndarray],
                     epoch: int = 0,
                     tenant: str | None = None) -> PrepareSession:
        """Open (but do not run) a staged prepare session.

        The serving tier (``core/serving.py``) drives one engine per
        tenant through this: the session carries the tenant label, and
        the caller decides when ``run()`` happens relative to other
        tenants' sessions.  Requires the hyperbatch path — a staged
        session *is* the hyperbatch-wide plan.
        """
        if not self.config.hyperbatch_enabled:
            raise RuntimeError("open_session requires hyperbatch_enabled")
        for p in (self._g_prefetch, self._f_prefetch):
            if p is not None:
                p.reset()  # defensive: drop any stale plan from an aborted run
        return PrepareSession(self, targets_per_mb, epoch, tenant=tenant)

    def plan_epoch(self, all_targets: np.ndarray, epoch: int = 0,
                   shuffle: bool = True) -> list[list[np.ndarray]]:
        """Deterministic hyperbatch plan: list of per-hyperbatch minibatch
        target lists covering ``all_targets`` once.

        Shared by :meth:`iter_epoch` and the pipelined executor
        (``repro.gnn.pipeline``) so the serial and overlapped paths see
        byte-identical work in identical order — which, together with the
        counter-hash sampler, makes pipelined losses equal serial losses.
        """
        cfg = self.config
        if cfg.online_placement and not self._in_session:
            # lazy epoch-boundary hook for flows that never call
            # end_epoch() themselves (plain iter_epoch loops): fold the
            # traffic observed since the last roll and re-place before
            # the new epoch's first plan splits against the old layout.
            # Defers to any *explicit* roller — if end_epoch ran since
            # the previous plan_epoch (the pipelined executor does this
            # every epoch), stray touches in the window (e.g. a holdout
            # evaluation between epochs) must not drive a second
            # migration pass per epoch.
            rolls = (self.graph_hotness.n_rolls,
                     self.feature_hotness.n_rolls)
            if (self._rolls_at_last_plan == rolls
                    and (self.graph_hotness.window_touches > 0
                         or self.feature_hotness.window_touches > 0)):
                self.end_epoch()
            self._rolls_at_last_plan = (self.graph_hotness.n_rolls,
                                        self.feature_hotness.n_rolls)
        targets = np.asarray(all_targets, dtype=np.int64)
        if shuffle:
            rng = np.random.default_rng(cfg.seed + epoch)
            targets = rng.permutation(targets)
        mb = cfg.minibatch_size
        per_hb = mb * cfg.hyperbatch_size
        plan = []
        for start in range(0, len(targets), per_hb):
            chunk = targets[start:start + per_hb]
            plan.append([chunk[i:i + mb] for i in range(0, len(chunk), mb)])
        return plan

    def iter_epoch(self, all_targets: np.ndarray, epoch: int = 0,
                   shuffle: bool = True):
        """Yield prepared hyperbatches covering ``all_targets`` once."""
        for mbs in self.plan_epoch(all_targets, epoch=epoch, shuffle=shuffle):
            yield self.prepare(mbs, epoch)

    def end_epoch(self) -> dict | None:
        """Epoch boundary: roll the hotness windows and, with
        ``online_placement`` on, run one budgeted migration pass per
        store (measured hotness replaces the static degree proxy as the
        placement-policy input).

        Safe to call every epoch — with no placement diff (or no
        topology) it only rolls the telemetry.  Also triggered lazily by
        :meth:`plan_epoch` when un-rolled traffic exists, so the
        pipelined executor and ``iter_epoch`` migrate without explicit
        calls; calling both is idempotent (the second sees an empty
        window).  Returns per-store migration summaries or ``None``.
        """
        if self._in_session:
            raise RuntimeError("end_epoch must not run inside a "
                               "PrepareSession (placement swap would race "
                               "the open I/O plan)")
        # quiesce the readers: no in-flight run may straddle the swap
        for p in (self._g_prefetch, self._f_prefetch):
            if p is not None:
                p.reset()
                assert getattr(p, "idle", True), \
                    "reader still holds an in-flight plan after reset"
        self.graph_hotness.roll()
        self.feature_hotness.roll()
        reports = {}
        for name, mig, tracker in self._migrations:
            # charge the copy I/O at the depths currently in force (the
            # adaptive controller may have resized since construction)
            mig.queue_depth = self.io_queue_depths()
            reports[name] = mig.run(tracker.hotness()).summary()
        # degraded-array recovery runs regardless of online_placement —
        # evacuation is correctness-driven, not a placement optimization
        recovery = self._evacuate_offline()
        if recovery:
            reports["recovery"] = recovery
        if not reports:
            return None
        self.last_migration = reports
        return reports

    def _evacuate_offline(self) -> dict | None:
        """Drain blocks stranded on offline arrays onto the survivors
        (``MigrationEngine.evacuate``), restoring the survivors'
        roofline: every future touch of an evacuated block pays a normal
        placed read instead of the degraded recovery path."""
        topo = self.topology
        if topo is None or not any(not topo.is_online(a)
                                   for a in range(topo.n_arrays)):
            return None
        out = {}
        engines = {name: mig for name, mig, _ in self._migrations}
        for name, store, tracker in (
                ("graph", self.graph_store, self.graph_hotness),
                ("feature", self.feature_store, self.feature_hotness)):
            if store.placement is None:
                continue
            mig = engines.get(name)
            if mig is None:
                # no online-placement engine configured: evacuation still
                # needs the budgeted durable write path (the policy is
                # irrelevant — evacuate() plans its own moves)
                mig = MigrationEngine(
                    store, make_policy("stripe",
                                       self.config.stripe_width_blocks),
                    self.config.migrate_budget_bytes, name=name,
                    queue_depth=self.io_queue_depths())
            else:
                mig.queue_depth = self.io_queue_depths()
            rep = mig.evacuate(tracker.hotness())
            if rep is not None:
                out[name] = rep.summary()
        return out or None

    def set_io_queue_depth(self, queue_depth: int,
                           array: int | None = None) -> int:
        """Adaptive scheduler hook: resize the coalesced readers' in-flight
        run budget between hyperbatches (``PipelinedExecutor`` drives this
        from the measured exposed-prepare fraction).  With a storage
        topology, an explicit ``array`` resizes that array's queue
        independently; ``None`` sets a uniform depth on every array."""
        qd = max(int(queue_depth), 1)
        if array is None:
            self.config.io_queue_depth = qd
            self._array_qd.clear()
        else:
            self._array_qd[int(array)] = qd
        for p in (self._g_prefetch, self._f_prefetch):
            if p is not None and hasattr(p, "set_queue_depth"):
                p.set_queue_depth(qd, array=array)
        return qd

    def io_queue_depths(self):
        """Current depth per array (``{array: depth}`` with a topology,
        scalar otherwise) — the per-array adaptive controller's view."""
        if self.topology is None:
            return self.config.io_queue_depth
        return {a: self._array_qd.get(a, self.config.io_queue_depth)
                for a in range(self.topology.n_arrays)}

    def install_cache_oracle(self, trace: list | None = None,
                             clear: bool = True):
        """Arm the oracle feature cache with a Belady MIN schedule.

        ``trace`` is a per-gather-cycle node-list sequence; ``None``
        replays :attr:`feature_trace` as recorded by a
        ``record_feature_trace=True`` epoch (Ginex's offline pass).  For
        0-hop workloads build it directly from the epoch plan with
        :func:`repro.core.cache_oracle.trace_from_plan` — no recording
        epoch needed.  ``clear`` resets cache contents so the scheduled
        trace starts from the same cold state it was computed for.
        Requires ``cache_policy="oracle"``.
        """
        from .cache_oracle import OracleSchedule

        if trace is None:
            trace = self.feature_trace
        schedule = OracleSchedule.from_trace(
            trace, self.feature_store.n_nodes)
        self.feature_cache.set_oracle(schedule)
        # stash the normalized trace so a mid-epoch migration can
        # rebuild the schedule from the steps not yet consumed
        self._oracle_trace = [np.asarray(t, dtype=np.int64).ravel()
                              for t in trace]
        if clear:
            self.feature_cache.clear()
        else:
            schedule.reset()
        return schedule

    def refresh_cache_oracle(self):
        """Mid-epoch oracle refresh (the serving tier's post-migration
        hook): rebuild the installed Belady schedule from the *remaining*
        trace — the gather cycles the current schedule has not yet
        consumed — and re-install it without clearing the cache.

        The fresh schedule's ``next_use`` table is primed with the
        remaining trace's first-use times, so currently-resident rows
        keep their true priorities instead of all reading NEVER until
        their step comes around.  Returns the new schedule, or ``None``
        when no oracle schedule is installed.
        """
        from .cache_oracle import OracleSchedule, first_use_table

        trace = getattr(self, "_oracle_trace", None)
        sched = getattr(self.feature_cache, "oracle", None)
        if trace is None or sched is None:
            return None
        done = min(sched.step + 1, len(trace))
        remaining = trace[done:]
        fresh = OracleSchedule.from_trace(remaining,
                                          self.feature_store.n_nodes)
        fresh.next_use[:] = first_use_table(remaining,
                                            self.feature_store.n_nodes)
        self.feature_cache.set_oracle(fresh)
        self._oracle_trace = remaining
        return fresh

    def device_feature_table(self, lane_multiple: int = 128):
        """Pin the feature cache's rows in an HBM-resident mirror.

        Hand the returned :class:`~repro.core.gather.DeviceFeatureTable`
        to ``PreparedMinibatch.to_device(table=...)`` (or set it as
        ``GNNTrainer.feature_table``) so cache hits are gathered on
        device and only miss rows travel host→device.
        """
        from .gather import DeviceFeatureTable

        return DeviceFeatureTable(self.feature_cache,
                                  lane_multiple=lane_multiple)

    def io_stats(self) -> dict:
        g = self.graph_store.stats
        f = self.feature_store.stats
        total = IOStats().merge(g).merge(f)
        out = {
            "graph": g.summary(), "feature": f.summary(),
            "total": total.summary(),
            "graph_buffer_hit": self.graph_buffer.stats.buffer_hit_ratio,
            "feature_buffer_hit": self.feature_buffer.stats.buffer_hit_ratio,
            "feature_cache_hit": self.feature_cache.stats.cache_hit_ratio,
        }
        if self.topology is not None:
            out["arrays"] = self.topology.utilization_summary()
        # submitter-side queue depth(s): the roofline's qd arm — folded
        # into the snapshot so the storage doctor can tell queue
        # starvation (small submitter depth) from IOPS saturation
        out["io_queue_depth"] = self.io_queue_depths()
        out["hotness"] = {
            "graph": self.graph_hotness.skew_summary(),
            "feature": self.feature_hotness.skew_summary(),
        }
        if total.n_migrated_blocks:
            out["migration"] = {
                "n_migrated_blocks": total.n_migrated_blocks,
                "bytes_migrated": total.bytes_migrated,
                "last": self.last_migration,
            }
        if (self.fault_injector is not None or total.io_errors
                or total.io_degraded):
            out["faults"] = {
                "io_errors": total.io_errors,
                "io_retries": total.io_retries,
                "io_hedges": total.io_hedges,
                "io_degraded": total.io_degraded,
                "bytes_retried": total.bytes_retried,
                "bytes_hedged": total.bytes_hedged,
                "bytes_degraded": total.bytes_degraded,
            }
            if self.topology is not None:
                out["faults"]["offline_arrays"] = [
                    a for a in range(self.topology.n_arrays)
                    if not self.topology.is_online(a)]
            if self.fault_injector is not None:
                out["faults"]["injected"] = self.fault_injector.summary()
        return out

    def close(self) -> None:
        for p in (self._g_prefetch, self._f_prefetch):
            if p is not None:
                p.close()

    # ------------------------------------------------------------ internals
    def _io_snapshot(self):
        g, f = self.graph_store.stats, self.feature_store.stats
        return (g.n_reads, g.bytes_read, g.modeled_read_time,
                g.n_requests, g.n_sequential_reads,
                f.n_reads, f.bytes_read, f.modeled_read_time,
                f.n_requests, f.n_sequential_reads)

    def _report(self, t0, t1, t2, before, after) -> PrepareReport:
        d = [a - b for a, b in zip(after, before)]
        sample_io = {"n_reads": d[0], "bytes": d[1], "modeled_s": d[2],
                     "n_requests": d[3], "n_sequential": d[4]}
        gather_io = {"n_reads": d[5], "bytes": d[6], "modeled_s": d[7],
                     "n_requests": d[8], "n_sequential": d[9]}
        cpu = (t1 - t0) + (t2 - t1)
        io = d[2] + d[7]
        modeled = max(cpu, io) if self.config.async_io else cpu + io
        return PrepareReport(t1 - t0, t2 - t1, sample_io, gather_io,
                             io, modeled)
