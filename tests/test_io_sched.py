"""Coalesced I/O scheduler: parity, accounting, prefetcher lifecycle."""
import threading
import time

import numpy as np
import pytest

from repro.core import (AgnesConfig, AgnesEngine, BlockPrefetcher,
                        CoalescedReader, NVMeModel, coalesce, plan_cost)


def make_engine(ds, *, mcb, async_io=False, hb=True, buffer_bytes=1 << 20,
                block_size=16384, fanouts=(5, 5), io_workers=2,
                io_queue_depth=8, cache_rows=0):
    g, f = ds.reopen_stores()
    cfg = AgnesConfig(block_size=block_size, minibatch_size=64,
                      hyperbatch_size=8, fanouts=fanouts,
                      graph_buffer_bytes=buffer_bytes,
                      feature_buffer_bytes=buffer_bytes,
                      feature_cache_rows=cache_rows,
                      hyperbatch_enabled=hb, async_io=async_io,
                      max_coalesce_bytes=mcb, io_workers=io_workers,
                      io_queue_depth=io_queue_depth)
    return AgnesEngine(g, f, cfg)


def _totals(eng):
    g, f = eng.graph_store.stats, eng.feature_store.stats
    return {
        "bytes": g.bytes_read + f.bytes_read,
        "reads": g.n_reads + f.n_reads,
        "requests": g.n_requests + f.n_requests,
        "seq": g.n_sequential_reads + f.n_sequential_reads,
        "time": g.modeled_read_time + f.modeled_read_time,
    }


# ------------------------------------------------------------------ coalesce
def test_coalesce_runs_and_cap():
    runs = coalesce([1, 2, 3, 7, 8, 20], 1024, 10 * 1024)
    assert [(r.start, r.count) for r in runs] == [(1, 3), (7, 2), (20, 1)]
    capped = coalesce([1, 2, 3, 4, 5], 1024, 2 * 1024)
    assert [(r.start, r.count) for r in capped] == [(1, 2), (3, 2), (5, 1)]
    # disabled -> one request per block
    single = coalesce([1, 2, 3], 1024, 0)
    assert [(r.start, r.count) for r in single] == [(1, 1), (2, 1), (3, 1)]
    assert coalesce([], 1024, 4096) == []
    # blocks covered exactly once regardless of cap
    for cap in (0, 1024, 3 * 1024, 1 << 20):
        rs = coalesce([0, 1, 2, 5, 6, 9], 1024, cap)
        covered = sorted(b for r in rs for b in range(r.start, r.stop))
        assert covered == [0, 1, 2, 5, 6, 9]


def test_plan_cost_queue_depth_overlap():
    dev = NVMeModel()
    singles = coalesce(list(range(0, 64, 2)), 4096, 0)     # 32 random blocks
    merged = coalesce(list(range(32)), 4096, 1 << 20)      # one 128K request
    _, _, _, t_single = plan_cost(singles, 4096, dev, queue_depth=8)
    _, _, _, t_merged = plan_cost(merged, 4096, dev, queue_depth=8)
    assert t_merged < t_single
    # queue depth overlaps request latency
    _, _, _, t_qd1 = plan_cost(singles, 4096, dev, queue_depth=1)
    assert t_single < t_qd1


# ------------------------------------------------------------------ parity
@pytest.mark.parametrize("mcb,async_io", [
    (16384, False),          # batched submission, no merging
    (4 * 16384, False),      # small coalesce cap, lazy execution
    (8 << 20, False),        # default cap, lazy execution
    (8 << 20, True),         # default cap, reader pool
])
def test_coalescing_parity_with_per_block_path(tiny_ds, rng, mcb, async_io):
    """MFGs, features and bytes_read identical to the per-block path."""
    targets = [rng.choice(tiny_ds.n_nodes, 150, replace=False)
               for _ in range(6)]
    base = make_engine(tiny_ds, mcb=0)           # legacy per-block path
    p0 = base.prepare(targets, epoch=3)
    ref = _totals(base)
    eng = make_engine(tiny_ds, mcb=mcb, async_io=async_io)
    p1 = eng.prepare(targets, epoch=3)
    for a, b in zip(p1, p0):
        for x, y in zip(a.mfg.nodes, b.mfg.nodes):
            assert np.array_equal(x, y)
        for lx, ly in zip(a.mfg.layers, b.mfg.layers):
            assert np.array_equal(lx.nbr_idx, ly.nbr_idx)
            assert np.array_equal(lx.self_idx, ly.self_idx)
        assert np.allclose(a.features, b.features)
    got = _totals(eng)
    assert got["bytes"] == ref["bytes"]
    assert got["reads"] == ref["reads"]
    eng.close()
    base.close()


def test_sequential_reads_monotone_with_coalescing(tiny_ds, rng):
    """More merging -> monotonically more sequential block reads."""
    targets = [rng.choice(tiny_ds.n_nodes, 150, replace=False)
               for _ in range(6)]
    seqs, times = [], []
    for mcb in (16384, 2 * 16384, 4 * 16384, 8 << 20):
        eng = make_engine(tiny_ds, mcb=mcb)
        eng.prepare(targets, epoch=3)
        t = _totals(eng)
        seqs.append(t["seq"])
        times.append(t["time"])
        eng.close()
    assert seqs == sorted(seqs), seqs
    assert seqs[-1] > seqs[0], seqs
    assert times[-1] < times[0], times  # merging buys modeled device time


def test_coalesced_faster_than_per_block(tiny_ds, rng):
    """Modeled prepare I/O time improves vs the per-block path (modeled
    time is deterministic, so the assertion is stable)."""
    targets = [rng.choice(tiny_ds.n_nodes, 150, replace=False)
               for _ in range(6)]
    base = make_engine(tiny_ds, mcb=0)
    base.prepare(targets, epoch=0)
    eng = make_engine(tiny_ds, mcb=8 << 20)
    eng.prepare(targets, epoch=0)
    assert _totals(eng)["time"] < _totals(base)["time"]
    assert _totals(eng)["requests"] < _totals(base)["requests"]
    eng.close()
    base.close()


def test_parity_with_feature_cache_and_multi_epoch(tiny_ds, rng):
    targets = [rng.choice(tiny_ds.n_nodes, 150, replace=False)
               for _ in range(4)]
    base = make_engine(tiny_ds, mcb=0, cache_rows=500)
    eng = make_engine(tiny_ds, mcb=8 << 20, async_io=True, cache_rows=500)
    for ep in range(3):
        p0 = base.prepare(targets, epoch=ep)
        p1 = eng.prepare(targets, epoch=ep)
        for a, b in zip(p1, p0):
            assert np.allclose(a.features, b.features)
    assert _totals(eng)["bytes"] == _totals(base)["bytes"]
    eng.close()
    base.close()


# ------------------------------------------------------------------ reader
def test_coalesced_reader_fetch_and_reset(tiny_ds):
    store, _ = tiny_ds.reopen_stores()
    with CoalescedReader(store, max_coalesce_bytes=8 << 20,
                         queue_depth=2, workers=1) as rd:
        rd.plan(np.arange(min(6, store.n_blocks)))
        for b in range(min(6, store.n_blocks)):
            blk = rd.fetch(b, timeout=10.0)
            assert blk is not None and blk.block_id == b
        assert rd.fetch(10 ** 9) is None        # unplanned -> caller reads
        # reset drops an undelivered plan; a fresh plan still works
        rd.plan(np.arange(min(4, store.n_blocks)))
        rd.reset()
        assert rd.fetch(0) is None
        rd.plan([1])
        assert rd.fetch(1, timeout=10.0).block_id == 1


def test_coalesced_reader_lazy_mode_reads_on_demand(tiny_ds):
    store, _ = tiny_ds.reopen_stores()
    with CoalescedReader(store, max_coalesce_bytes=2 * store.block_size,
                         workers=0) as rd:
        rd.plan(np.arange(min(5, store.n_blocks)))
        before = store.stats.bytes_read  # charged at plan time (whole batch)
        blk = rd.fetch(2)
        assert blk is not None and blk.block_id == 2
        assert store.stats.bytes_read == before  # no double charging


def test_coalesced_reader_run_tokens_survive_start_reuse(tiny_ds):
    """A fused resubmission may reuse the start block of a still-open run
    (delivered-then-evicted head); slot accounting must not collide."""
    store, _ = tiny_ds.reopen_stores()
    n = min(3, store.n_blocks)
    with CoalescedReader(store, max_coalesce_bytes=8 << 20,
                         queue_depth=2, workers=0) as rd:
        rd.plan(np.arange(n))                 # one run
        assert rd.fetch(0).block_id == 0      # head consumed
        rd.plan([0])                          # start reuse, run still open
        assert rd.fetch(0, timeout=5.0).block_id == 0
        for b in range(1, n):
            assert rd.fetch(b, timeout=5.0).block_id == b
        assert not rd._remaining and sum(rd._ready_runs.values()) == 0


def test_coalesced_reader_survives_failing_read(tiny_ds):
    """A raising read_run must not kill the worker or wedge the pool.

    ``IndexError`` classifies as *permanent* (not a transient errno), so
    the reader must propagate it through ``fetch`` — no silent ``None``,
    no blind retry — while the worker pool stays alive for later plans.
    """
    store, _ = tiny_ds.reopen_stores()

    class Flaky:
        block_size = store.block_size
        device = store.device
        stats = store.stats
        fail = True

        def account_runs(self, runs, qd, stream=None, max_coalesce_bytes=0):
            store.account_runs(runs, qd, stream=stream,
                               max_coalesce_bytes=max_coalesce_bytes)

        def read_run(self, start, count):
            if self.fail:
                self.fail = False
                raise IndexError("injected")
            return store.read_run(start, count)

    with CoalescedReader(Flaky(), max_coalesce_bytes=8 << 20,
                         queue_depth=1, workers=1) as rd:
        rd.plan([0, 1])                       # one run; first read fails
        t0 = time.time()
        with pytest.raises(IndexError, match="injected"):
            rd.fetch(0, timeout=10.0)         # fail-fast, no 10s stall
        assert time.time() - t0 < 5.0
        # the sibling block of the failed run surfaces the same error
        # (stashed per block), then the pool is clean for the next plan
        with pytest.raises(IndexError, match="injected"):
            rd.fetch(1, timeout=10.0)
        rd.plan([2])                          # pool must still be alive
        blk = rd.fetch(2, timeout=10.0)
        assert blk is not None and blk.block_id == 2


def test_block_buffer_absent_filter():
    from repro.core import BlockBuffer
    buf = BlockBuffer(4, name="t")
    buf.put(1, "a")
    buf.put(3, "b")
    assert buf.absent([0, 1, 2, 3, 4]) == [0, 2, 4]


# ------------------------------------------------------------------ reports
def test_overlap_report_io_summary_aggregates():
    from repro.core import PrepareReport
    from repro.gnn.pipeline import OverlapReport

    def rep(reads, reqs, seq, nbytes, t):
        io = {"n_reads": reads, "n_requests": reqs, "n_sequential": seq,
              "bytes": nbytes, "modeled_s": t}
        return PrepareReport(0.0, 0.0, io, dict(io), 2 * t, 2 * t)

    r = OverlapReport(1.0, 0.5, 0.5, 2, 4, [],
                      [rep(10, 4, 6, 100, 0.1), rep(20, 5, 15, 200, 0.2)])
    io = r.io_summary()
    assert io["n_reads"] == 60 and io["n_requests"] == 18
    assert io["n_sequential_reads"] == 42
    assert io["coalesce_factor"] == round(60 / 18, 3)
    assert abs(io["modeled_io_s"] - 0.6) < 1e-9
    assert io["bytes_read"] == 600
    assert r.summary()["io"] == io


# ------------------------------------------------------------------ prefetcher
def test_prefetcher_reset_frees_slots():
    """Unconsumed read-ahead must not throttle later hops (slot leak)."""
    reads = []
    pf = BlockPrefetcher(lambda b: reads.append(b) or b * 10, depth=2)
    with pf:
        pf.plan([1, 2])               # fill every slot, never take()
        deadline = time.time() + 5.0
        while len(reads) < 2 and time.time() < deadline:
            time.sleep(0.01)
        assert reads == [1, 2]
        pf.reset()                    # hop boundary: drain leaked slots
        pf.plan([3, 4])
        assert pf.wait(3, timeout=5.0) == 30
        assert pf.wait(4, timeout=5.0) == 40
        assert pf.take(1) is None     # stale block was dropped


def test_prefetcher_close_races_backlog_throttle():
    """close() must not hang while the worker waits on a full backlog."""
    pf = BlockPrefetcher(lambda b: b, depth=1)
    pf.plan([1, 2, 3, 4])             # backlog fills after the first read
    time.sleep(0.05)
    t0 = time.time()
    pf.close()
    assert time.time() - t0 < 2.0
    assert not pf._thread.is_alive()


def test_prefetcher_blocking_wait_no_poll():
    """wait() returns promptly once the worker delivers (no 100ms poll)."""
    gate = threading.Event()

    def reader(b):
        gate.wait(5.0)
        return b

    with BlockPrefetcher(reader, depth=4) as pf:
        pf.plan([7])
        gate.set()
        assert pf.wait(7, timeout=5.0) == 7
