"""Offline storage doctor CLI.

    PYTHONPATH=src python -m repro.doctor trace.json --metrics metrics.json

Diagnoses a recorded run from its exported artifacts: ``trace.json`` is
a Chrome trace written by :meth:`TraceRecorder.export_chrome` (e.g. the
example's ``--trace OUT.json``), ``metrics.json`` is a JSON dump of
:meth:`AgnesEngine.metrics_snapshot` (the example's ``--metrics-json``).
Either input alone still diagnoses — metrics-only skips the
exposed-prepare decomposition, trace-only skips the roofline — but the
full findings table needs both.

Renders the ranked findings with a suggested knob per finding plus the
per-array roofline table; ``--json`` emits the structured
:class:`~repro.core.diagnosis.DoctorReport` instead (for dashboards or
the regression harness).
"""
from __future__ import annotations

import argparse
import json
import sys

from .core.diagnosis import diagnose, events_from_chrome


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.doctor",
        description="Diagnose a recorded AGNES run: roofline attribution "
                    "+ ranked findings with suggested knobs.")
    ap.add_argument("trace", nargs="?", default=None,
                    help="Chrome trace JSON (TraceRecorder.export_chrome)")
    ap.add_argument("--metrics", default=None, metavar="JSON",
                    help="metrics snapshot JSON "
                         "(AgnesEngine.metrics_snapshot dump)")
    ap.add_argument("--json", action="store_true",
                    help="emit the structured report as JSON")
    args = ap.parse_args(argv)
    if args.trace is None and args.metrics is None:
        ap.error("nothing to diagnose: pass a trace file and/or --metrics")

    events = None
    if args.trace is not None:
        with open(args.trace) as f:
            events = events_from_chrome(json.load(f))
    metrics: dict = {}
    if args.metrics is not None:
        with open(args.metrics) as f:
            metrics = json.load(f)

    report = diagnose(metrics, events=events)
    if args.json:
        print(json.dumps(report.to_dict(), indent=2))
    else:
        print(report.render())
    return 0


if __name__ == "__main__":
    sys.exit(main())
