"""Online re-placement + background block migration (ROADMAP items:
*empirical hotness*, *rebalancing writes*, *writable stores*).

PR 4's :class:`~repro.core.topology.BlockPlacement` is computed once at
attach time from a static degree proxy.  Real access skew only emerges
at runtime and drifts across epochs (a rotating hot train subset, label
skew, cache dynamics) — Ginex (VLDB'22) shows measured access traces
beat static heuristics for SSD-based GNN training, and Jiang et al.
(arXiv:2406.13984) show unmanaged write traffic congests the same NVMe
queues the read path needs, which is why migration here is *budgeted*
and charged into the same per-array rooflines it competes with.

At each epoch boundary the :class:`MigrationEngine`:

1. **re-scores** — runs the placement policy over the *measured*
   hotness vector (:class:`~repro.core.hotness.HotnessTracker`, decayed
   across epochs) instead of the attach-time degree proxy;
2. **diffs** — blocks whose target array differs from their current one
   become candidate moves, ordered hottest first (the hottest
   misplacements buy the most roofline per byte written);
3. **caps** — the plan is truncated to ``budget_bytes`` of moved blocks
   per epoch, so migration can never starve the prepare path;
4. **executes** — through the store's crash-consistent write path
   (``block_store.migrate_blocks``: journal the block copies + fsync,
   atomically rewrite ``<store>.topo.json`` via temp-file rename, free
   the old slots), charging reads to the source arrays and writes to
   the destinations.

Blocks with zero measured hotness are never moved: with no capacity
model an unread block costs nothing wherever it sits, so moving it is
pure write traffic.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from .hotness import HotnessTracker
from .topology import PlacementPolicy, StorageTopology


@dataclasses.dataclass(frozen=True)
class BlockMove:
    """One planned migration: ``block_id`` from ``src`` to ``dst``."""

    block_id: int
    src: int
    dst: int
    score: float    # measured hotness — the move ordering key


@dataclasses.dataclass
class MigrationReport:
    """What one epoch-boundary migration pass did on one store."""

    store: str
    n_wanted: int           # placement diff size before the budget cap
    n_moved: int
    bytes_moved: int
    budget_bytes: int
    read_s: float           # copy-read time charged to source arrays
    write_s: float          # copy-write time charged to destinations
    blocks_per_array: list[int] | None = None  # post-migration layout

    def summary(self) -> dict:
        return {
            "store": self.store,
            "n_wanted": self.n_wanted,
            "n_moved": self.n_moved,
            "bytes_moved": self.bytes_moved,
            "budget_bytes": self.budget_bytes,
            "budget_utilization": round(
                self.bytes_moved / self.budget_bytes, 4)
            if self.budget_bytes else 0.0,
            "copy_read_s": round(self.read_s, 6),
            "copy_write_s": round(self.write_s, 6),
            "blocks_per_array": self.blocks_per_array,
        }


class MigrationEngine:
    """Budgeted epoch-boundary re-placement for one block store.

    ``store`` must carry an attached topology + placement
    (``attach_topology``); ``policy`` is the scorer run over measured
    hotness — typically :class:`~repro.core.topology.
    HotnessAwarePlacement`, the only shipped policy that consumes a
    hotness vector (stripe/contiguous targets are hotness-independent,
    so their diffs are empty and migration no-ops).
    """

    def __init__(self, store, policy: PlacementPolicy,
                 budget_bytes: int, name: str = "store",
                 queue_depth: int | None = None,
                 min_score_fraction: float = 0.01):
        if store.topology is None or store.placement is None:
            raise ValueError("store needs an attached topology/placement")
        self.store = store
        self.policy = policy
        self.budget_bytes = int(budget_bytes)
        self.name = name
        self.queue_depth = queue_depth
        # churn guard: moves colder than this fraction of the hottest
        # move are noise (stale windows decaying toward zero, boundary
        # wobble) — pure write traffic with negligible roofline value
        self.min_score_fraction = float(min_score_fraction)
        self.last_report: MigrationReport | None = None
        # serving tier (core/serving.py): when bound, every copy pass is
        # admitted as the lowest-priority tenant (bulk all-array grants)
        self.admission = None
        self.tenant = "migration"
        # unified telemetry (core/telemetry.py): migration/evacuation
        # window spans + moved-block counters; set by the owning engine
        self.telemetry = None

    def bind_admission(self, controller, tenant: str = "migration") -> None:
        """Enroll this engine's copy traffic as a serving-tier tenant."""
        self.admission = controller
        self.tenant = tenant

    def _migrate_admitted(self, moves_list, queue_depth) -> int:
        """``store.migrate_blocks`` behind the admission layer: the copy
        pass is one bulk grant across every array (reads from sources,
        writes to destinations), completed when the pass returns."""
        if self.admission is None:
            return self.store.migrate_blocks(moves_list,
                                             queue_depth=queue_depth)
        nbytes = len(moves_list) * self.store.block_size
        self.admission.acquire(self.tenant, None, nbytes)
        try:
            return self.store.migrate_blocks(moves_list,
                                             queue_depth=queue_depth)
        finally:
            self.admission.complete(self.tenant, None, nbytes)

    def _note_telemetry(self, name: str, moved: int, wanted: int,
                        t0: float) -> None:
        """One migration-window span + moved-block counters (no-op
        without a bound Telemetry)."""
        tel = self.telemetry
        if tel is None:
            return
        nbytes = moved * self.store.block_size
        tel.metrics.counter("migration.blocks_moved").inc(moved)
        tel.metrics.counter("migration.bytes_moved").inc(nbytes)
        tr = tel.trace
        if tr is not None:
            tr.complete(name, "migration", "migration", t0,
                        args={"n_moved": moved, "n_wanted": wanted,
                              "bytes": nbytes})

    @property
    def topology(self) -> StorageTopology:
        return self.store.topology

    # ------------------------------------------------------------ plan
    def plan(self, hotness: np.ndarray) -> tuple[list[BlockMove], int]:
        """Diff the measured-hotness placement against the current one.

        Returns ``(moves, n_wanted)``: the hottest-first move list
        truncated to the byte budget, and the untruncated diff size.
        """
        h = np.asarray(hotness, dtype=np.float64)
        cur = self.store.placement
        # noise floor *before* placing: blocks colder than the fraction
        # of the hottest drop out of the policy's hot set entirely —
        # stale windows decaying toward zero neither fragment the live
        # hot runs nor generate move-back churn (they stay pinned where
        # they are, costing nothing without a capacity model)
        floor = self.min_score_fraction * float(h.max()) if h.size else 0.0
        h_eff = np.where(h > floor, h, 0.0) if floor > 0 else h
        target = self.policy.place(self.store.n_blocks, self.topology,
                                   hotness=h_eff)
        diff = np.nonzero((target.array_of != cur.array_of)
                          & (h_eff > 0))[0]
        # degraded mode: the policy does not know about dropouts — never
        # move blocks *onto* an offline array (blocks stranded on one
        # are evacuate()'s job, not the optimizer's)
        offline = [a for a in range(self.topology.n_arrays)
                   if not self.topology.is_online(a)]
        if offline:
            diff = diff[~np.isin(target.array_of[diff], offline)]
        n_wanted = int(diff.size)
        if n_wanted == 0:
            return [], 0
        order = diff[np.argsort(-h[diff], kind="stable")]
        # budget <= block_size means no block fits — migration disabled,
        # not unlimited (the cap is a ceiling, never an opt-out)
        order = order[:max(self.budget_bytes // self.store.block_size, 0)]
        return [BlockMove(int(b), int(cur.array_of[b]),
                          int(target.array_of[b]), float(h[b]))
                for b in order.tolist()], n_wanted

    # ------------------------------------------------------------ execute
    def run(self, tracker_or_hotness) -> MigrationReport:
        """Plan + execute one bounded migration pass.

        Accepts a :class:`HotnessTracker` (its current
        :meth:`~HotnessTracker.hotness` view is used) or a raw hotness
        vector.  Copy I/O deltas are measured off the store's own
        :class:`~repro.core.device_model.IOStats`.
        """
        hot = (tracker_or_hotness.hotness()
               if isinstance(tracker_or_hotness, HotnessTracker)
               else tracker_or_hotness)
        t0 = time.perf_counter()
        moves, n_wanted = self.plan(hot)
        st = self.store.stats
        r0, w0 = st.modeled_read_time, st.modeled_write_time
        moved = 0
        if moves:
            moved = self._migrate_admitted(
                [(m.block_id, m.dst) for m in moves], self.queue_depth)
        self._note_telemetry(f"migrate:{self.name}", moved, n_wanted, t0)
        report = MigrationReport(
            store=self.name,
            n_wanted=n_wanted,
            n_moved=moved,
            bytes_moved=moved * self.store.block_size,
            budget_bytes=self.budget_bytes,
            read_s=st.modeled_read_time - r0,
            write_s=st.modeled_write_time - w0,
            blocks_per_array=np.bincount(
                self.store.placement.array_of,
                minlength=self.topology.n_arrays).tolist(),
        )
        self.last_report = report
        return report

    # ------------------------------------------------------------ recovery
    def evacuate(self, tracker_or_hotness=None) -> MigrationReport | None:
        """Drain every block off this store's offline arrays.

        Degraded-array recovery: unlike :meth:`run`, which optimizes an
        otherwise-valid placement under a per-epoch budget, evacuation
        is correctness-driven — it loops budgeted passes through the
        same durable write path until no block remains stranded, so one
        epoch boundary fully restores the survivors' roofline.  Returns
        ``None`` when nothing was stranded.
        """
        hot = (tracker_or_hotness.hotness()
               if isinstance(tracker_or_hotness, HotnessTracker)
               else tracker_or_hotness)
        st = self.store.stats
        r0, w0 = st.modeled_read_time, st.modeled_write_time
        moved = stranded = 0
        t0 = time.perf_counter()
        while True:
            moves = plan_evacuation(self.store, self.budget_bytes, hot)
            if not moves:
                break
            if moved == 0:
                stranded = int(np.isin(
                    self.store.placement.array_of,
                    [a for a in range(self.topology.n_arrays)
                     if not self.topology.is_online(a)]).sum())
            moved += self._migrate_admitted(
                [(m.block_id, m.dst) for m in moves], self.queue_depth)
        if moved == 0:
            return None
        self._note_telemetry(f"evacuate:{self.name}", moved, stranded, t0)
        report = MigrationReport(
            store=self.name,
            n_wanted=stranded,
            n_moved=moved,
            bytes_moved=moved * self.store.block_size,
            budget_bytes=self.budget_bytes,
            read_s=st.modeled_read_time - r0,
            write_s=st.modeled_write_time - w0,
            blocks_per_array=np.bincount(
                self.store.placement.array_of,
                minlength=self.topology.n_arrays).tolist(),
        )
        self.last_report = report
        return report


def plan_evacuation(store, budget_bytes: int,
                    hotness: np.ndarray | None = None) -> list[BlockMove]:
    """Moves for blocks stranded on offline arrays (degraded mode).

    Hottest-first under the byte budget — but always at least one block
    per pass, so recovery makes progress even under a sub-block budget
    (a stranded block pays the degraded-read penalty on every touch,
    which a too-small budget must not make permanent).  Within the
    pass, destinations come from a *smooth weighted round-robin* over
    the stranded ids in ascending order, weighted by each survivor's
    bandwidth-proportional deficit against current block counts.  Two
    properties matter, and the sweep order delivers both:

    * **balance** — any contiguous block span's stranded share spreads
      proportionally over every survivor, so no single array's roofline
      eats the whole recovered quarter on every later gather (assigning
      whole contiguous chunks per survivor concentrates each span's
      stranded blocks on one array — a permanent per-span hot spot);
    * **locality** — the survivors only have tail slots free, and
      ``migrate_blocks`` allocates them in ascending block order, so
      each survivor's received ids map to ascending consecutive locals;
      within any read span a survivor's stranded ids are a contiguous
      slice of that sequence, and the reader's local-adjacency re-merge
      turns them into one sequential tail run (assigning in *hotness*
      order breaks the id/local monotonicity and shreds run detection).
    """
    pl, topo = store.placement, store.topology
    if pl is None or topo is None:
        return []
    offline = [a for a in range(topo.n_arrays) if not topo.is_online(a)]
    if not offline:
        return []
    online = topo.online_arrays()
    if not online:
        raise RuntimeError("no online array left to evacuate onto")
    ids = np.nonzero(np.isin(pl.array_of, offline))[0]
    if ids.size == 0:
        return []
    h = (np.asarray(hotness, dtype=np.float64) if hotness is not None
         else np.zeros(pl.n_blocks, dtype=np.float64))
    order = ids[np.argsort(-h[ids], kind="stable")]
    chunk = np.sort(order[:max(int(budget_bytes) // store.block_size, 1)])
    bw = np.array([topo.devices[a].array_bandwidth for a in online],
                  dtype=np.float64)
    load = np.bincount(pl.array_of, minlength=topo.n_arrays)[online] \
        .astype(np.float64)
    # bandwidth-proportional deficits over the post-evacuation total,
    # largest-remainder rounding — deterministic and exactly exhaustive
    deficit = np.maximum((load.sum() + chunk.size) * bw / bw.sum() - load,
                         0.0)
    if deficit.sum() <= 0:
        deficit = bw.copy()
    share = deficit / deficit.sum()
    # smooth weighted round-robin: sweep ids ascending, each step grant
    # every survivor its fractional share of credit and send the block
    # to the most-owed one — proportional in every window, deterministic
    credit = np.zeros(len(online))
    moves: list[BlockMove] = []
    for b in chunk.tolist():
        credit += share
        i = int(np.argmax(credit))
        credit[i] -= 1.0
        moves.append(BlockMove(int(b), int(pl.array_of[b]),
                               int(online[i]), float(h[b])))
    return moves
