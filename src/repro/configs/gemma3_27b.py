"""gemma3-27b [dense]: 62L, d=5376, 32H (GQA kv=16), d_ff=21504,
vocab=262144 — 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt family; unverified]
"""
from .base import LayerSpec, ModelConfig, register

LOCAL_WINDOW = 1024  # gemma3 sliding window for local layers


@register("gemma3-27b")
def config() -> ModelConfig:
    # 5 local (sliding-window) : 1 global, repeating; 62 = 10*6 + 2 locals
    unit = [LayerSpec(mixer="swa", ffn="mlp", window=LOCAL_WINDOW)] * 5 \
        + [LayerSpec(mixer="attn", ffn="mlp")]
    layers = (unit * 11)[:62]
    return ModelConfig(
        name="gemma3-27b", family="dense",
        n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16,
        d_ff=21504, vocab=262144, head_dim=128,
        layers=tuple(layers), rope_theta=1_000_000.0,
        source="hf:google/gemma-3-27b (dims per assignment); 5:1 local:global")
