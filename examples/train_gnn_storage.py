"""End-to-end driver: storage-based GNN training, AGNES vs Ginex-like.

Trains the same GCN on the same deterministic samples through both
engines (the paper's EQ1/EQ4 protocol at container scale) and reports
per-epoch accuracy, exact I/O counts, and modeled NVMe time.

  PYTHONPATH=src python examples/train_gnn_storage.py [--epochs 3]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (AgnesConfig, AgnesEngine, BaselineConfig, GinexLike,
                        NVMeModel, fig2_breakdown, format_metrics)
from repro.data import build_dataset
from repro.gnn import GNNTrainer, PipelinedExecutor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--arch", default="gcn", choices=["gcn", "sage", "gat"])
    ap.add_argument("--dataset", default="pa-mini")
    ap.add_argument("--backend", default="jnp", choices=["jnp", "pallas"],
                    help="aggregation primitives (pallas = TPU kernels, "
                         "interpret mode on CPU)")
    ap.add_argument("--pipeline", action="store_true",
                    help="overlap data preparation with training "
                         "(engines with a plan_epoch hook)")
    ap.add_argument("--coalesce-bytes", type=int, default=8 << 20,
                    help="max bytes per merged sequential I/O request "
                         "(0 = legacy per-block path)")
    ap.add_argument("--io-queue-depth", type=int, default=8,
                    help="in-flight coalesced requests")
    ap.add_argument("--io-workers", type=int, default=2,
                    help="reader pool size for the I/O scheduler")
    ap.add_argument("--no-fusion", action="store_true",
                    help="disable cross-hop plan fusion (pre-session "
                         "schedule: one plan per hop, barrier per hop)")
    ap.add_argument("--adaptive-io", action="store_true",
                    help="resize io_queue_depth per hyperbatch from the "
                         "measured exposed-prepare fraction (needs "
                         "--pipeline)")
    ap.add_argument("--place-features", default=None,
                    choices=["jnp", "pallas"],
                    help="land prepared features device-resident via "
                         "PreparedMinibatch.to_device before training")
    ap.add_argument("--n-arrays", type=int, default=1,
                    help="independent NVMe arrays in the storage topology "
                         "(1 = single opaque device)")
    ap.add_argument("--placement", default="stripe",
                    choices=["contiguous", "stripe", "hotness"],
                    help="block placement policy across arrays "
                         "(hotness = degree-aware, Ginex-style pinning)")
    ap.add_argument("--stripe-width", type=int, default=1,
                    help="RAID0 chunk in blocks for striped placements")
    ap.add_argument("--online-placement", action="store_true",
                    help="re-place blocks at epoch boundaries from "
                         "measured per-block hotness and migrate them "
                         "through the crash-consistent write path "
                         "(needs --n-arrays > 1)")
    ap.add_argument("--migrate-budget-mb", type=int, default=64,
                    help="per-store migration byte budget per epoch")
    ap.add_argument("--inject-faults", default=None, metavar="SPEC",
                    help="seeded storage-fault schedule, e.g. "
                         "'transient:p=0.01;latency:p=0.005,factor=30;"
                         "dropout:array=3,at=400' — reads survive via "
                         "retry/hedge/degraded paths, byte-identical")
    ap.add_argument("--io-retries", type=int, default=2,
                    help="bounded retry budget for transient read faults "
                         "(exhaustion escalates to permanent)")
    ap.add_argument("--serve-qps", type=int, default=0,
                    help="serve this many inference embed requests per "
                         "epoch concurrently with training, through the "
                         "QoS-aware serving tier (AGNES engine only); "
                         "prints per-epoch p50/p99 prepare latency")
    ap.add_argument("--inference-priority", default="high",
                    choices=["high", "fifo"],
                    help="admission policy for serve traffic: 'high' = "
                         "inference preempts bulk training I/O at run "
                         "granularity, 'fifo' = uncoordinated (inference "
                         "queues behind the training backlog)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record a timeline of the AGNES run and export "
                         "it as Chrome trace-event JSON (load the file "
                         "in https://ui.perfetto.dev); also prints the "
                         "trace-derived Fig.2 breakdown")
    ap.add_argument("--metrics-dump", action="store_true",
                    help="print the AGNES metrics registry as Prometheus "
                         "text exposition after the run")
    ap.add_argument("--metrics-json", default=None, metavar="OUT.json",
                    help="dump the final metrics snapshot as JSON — "
                         "feed it to `python -m repro.doctor` together "
                         "with the --trace file")
    ap.add_argument("--doctor", action="store_true",
                    help="run the storage doctor after the run and print "
                         "the findings table (roofline attribution + "
                         "suggested knobs)")
    args = ap.parse_args()

    if args.backend == "pallas":
        import jax
        if jax.default_backend() != "tpu":
            print("warning: backend=pallas off-TPU runs interpret mode — "
                  "orders of magnitude slower at this problem size; "
                  "use it for small-scale kernel validation.", flush=True)

    ds = build_dataset(args.dataset, "/tmp/agnes_e2e", dim=128)
    train_nodes = np.arange(16384)
    holdout = [np.arange(16384, 16384 + 2048)]

    def run(name, engine):
        tr = GNNTrainer(arch=args.arch, in_dim=128, hidden=128,
                        n_classes=16, n_layers=3, seed=3,
                        backend=args.backend,
                        feature_placement=args.place_features)
        tr.labels = ds.labels
        io_time = 0.0
        tier = srv = None
        prev_snap: dict = {}
        if args.serve_qps and hasattr(engine, "open_session"):
            from repro.core import InferenceServer, ServingTier
            tier = ServingTier(engine, policy=(
                "priority" if args.inference_priority == "high" else "fifo"))
            srv = InferenceServer(tier, tr)

        def serve_epoch(epoch, errs):
            # an embedding service hitting the same storage mid-training
            rng = np.random.default_rng(100 + epoch)
            try:
                srv.params = tr.params  # serve the freshest model
                for _ in range(args.serve_qps):
                    srv.embed(rng.integers(0, len(train_nodes), size=1))
            except BaseException as e:   # surface, don't swallow
                errs.append(e)
        pipelined = args.pipeline and hasattr(engine, "plan_epoch")
        executor = (PipelinedExecutor(engine, tr,
                                      adaptive_io=args.adaptive_io)
                    if pipelined else None)
        for epoch in range(args.epochs):
            overlap = ""
            serve_thread, serve_errs = None, []
            if srv is not None:
                import threading
                serve_thread = threading.Thread(
                    target=serve_epoch, args=(epoch, serve_errs))
                serve_thread.start()
            if pipelined:
                # shuffle=False so both engines see identical minibatches
                # (the sample-equivalence property then makes accuracy exact)
                rep = executor.run_epoch(train_nodes, epoch=epoch,
                                         shuffle=False)
                losses = rep.losses
                io_time += sum(r.modeled_io_s for r in rep.prepare_reports)
                overlap = f" prep_hidden {rep.hidden_fraction:.0%}"
            else:
                losses = []
                if hasattr(engine, "iter_epoch"):
                    batches = engine.iter_epoch(train_nodes, epoch=epoch,
                                                shuffle=False)
                else:
                    mbs = [train_nodes[i:i + 1000]
                           for i in range(0, len(train_nodes), 1000)]
                    batches = [engine.prepare(mbs, epoch=epoch)]
                for prepared in batches:
                    io_time += engine.last_report.modeled_io_s
                    for p in prepared:
                        losses.append(tr.train_minibatch(p))
            if serve_thread is not None:
                serve_thread.join()
                if serve_errs:
                    raise serve_errs[0]
            if getattr(getattr(engine, "config", None),
                       "online_placement", False) and not pipelined:
                # pipelined epochs already migrated inside run_epoch;
                # the serial path runs its boundary pass here (what it
                # moved shows up as migration.* counters below)
                engine.end_epoch()
            acc = tr.evaluate(engine.prepare(holdout, epoch=900 + epoch))
            # one metrics-delta line replaces the old serve/migrate/fault
            # print blocks: everything the epoch did, from one snapshot
            obs = ""
            tel = getattr(engine, "telemetry", None)
            if tel is not None:
                if tier is not None:
                    tier.update_metrics()
                line = format_metrics(
                    tel.metrics.delta(prev_snap),
                    include=("io.", "cache.", "migration.", "serving.",
                             "admission.", "pipeline."))
                prev_snap = engine.metrics_snapshot()
                if line:
                    obs = f"\n[{name}]   {line}"
            print(f"[{name}] epoch {epoch}: loss {np.mean(losses):.4f} "
                  f"acc {acc:.3f} modeled_io {io_time:.3f}s{overlap}{obs}",
                  flush=True)
        if executor is not None:
            executor.close()
        if tier is not None:
            tier.close()
        return acc, io_time

    agnes = AgnesEngine(*ds.reopen_stores(NVMeModel()), AgnesConfig(
        minibatch_size=1000, hyperbatch_size=8,
        graph_buffer_bytes=32 << 20, feature_buffer_bytes=32 << 20,
        max_coalesce_bytes=args.coalesce_bytes,
        io_queue_depth=args.io_queue_depth, io_workers=args.io_workers,
        plan_fusion=not args.no_fusion,
        n_arrays=args.n_arrays, placement=args.placement,
        stripe_width_blocks=args.stripe_width,
        online_placement=args.online_placement,
        migrate_budget_bytes=args.migrate_budget_mb << 20,
        fault_schedule=args.inject_faults, io_retries=args.io_retries,
        trace=bool(args.trace)))
    acc_a, io_a = run("agnes", agnes)
    if args.metrics_dump:
        print("\n# AGNES metrics (Prometheus text exposition)")
        print(agnes.telemetry.metrics.render_prometheus())
    if args.trace:
        rec = agnes.telemetry.trace
        path = rec.export_chrome(args.trace)
        fb = fig2_breakdown(rec)
        print(f"[agnes] trace: {rec.n_retained} events -> {path} "
              f"(dropped {rec.n_dropped}); load in https://ui.perfetto.dev")
        print(f"[agnes] fig2 breakdown: prepare {fb['prepare_s']:.3f}s "
              f"({fb['prepare_fraction']:.0%}) train {fb['train_s']:.3f}s "
              f"({fb['train_fraction']:.0%}) of which transfer "
              f"{fb['transfer_s']:.3f}s")
    if args.metrics_json:
        import json
        with open(args.metrics_json, "w") as f:
            json.dump(agnes.metrics_snapshot(), f, indent=2)
        print(f"[agnes] metrics snapshot -> {args.metrics_json} "
              f"(diagnose offline: python -m repro.doctor "
              f"{args.trace or 'trace.json'} --metrics {args.metrics_json})")
    if args.doctor:
        print("\n# storage doctor")
        print(agnes.diagnose().render())
    if agnes.topology is not None:
        u = agnes.io_stats()["arrays"]
        print(f"[agnes] storage topology: {u['n_arrays']} arrays "
              f"({args.placement}), busy balance {u['balance']:.2f}")
        for a in u["arrays"]:
            print(f"[agnes]   array {a['array']}: {a['bandwidth_GBps']} GB/s, "
                  f"{a['bytes'] / 1e6:.1f} MB in {a['n_requests']} requests "
                  f"(seq {a['sequential_fraction']:.0%}), "
                  f"busy {a['busy_s'] * 1e3:.2f} ms, share {a['share']:.0%}")
        mig = agnes.io_stats().get("migration")
        if mig:
            print(f"[agnes] online re-placement: "
                  f"{mig['n_migrated_blocks']} blocks / "
                  f"{mig['bytes_migrated'] / 1e6:.1f} MB migrated")
    agnes.close()

    ginex = GinexLike(ds.csr_storage(16 << 20, NVMeModel()),
                      ds.reopen_stores(NVMeModel())[1],
                      BaselineConfig(feature_cache_rows=40000,
                                     page_buffer_bytes=16 << 20))
    acc_g, io_g = run("ginex-like", ginex)

    print(f"\nsame accuracy: {abs(acc_a - acc_g) < 1e-9} "
          f"(AGNES {acc_a:.3f} vs Ginex {acc_g:.3f}); "
          f"modeled NVMe speedup: {io_g / max(io_a, 1e-12):.1f}x")


if __name__ == "__main__":
    main()
