"""Storage doctor ground truth: planted bottlenecks, graded diagnosis.

The doctor (``core/diagnosis.py``) is only trustworthy if it names the
*planted* bottleneck, not a plausible one.  This benchmark constructs
eight labeled scenarios on one ring workload — each engineered so a
single cause dominates by construction — runs the real engine through
each, and grades ``AgnesEngine.diagnose`` / ``ServingTier.diagnose``
against the label:

========== ==================== =====================================
scenario   expected primary     how the bottleneck is planted
========== ==================== =====================================
bw         bw-bound             contiguous tiles, 8 MiB coalesce,
                                qd 32 — few huge sequential requests
iops       iops-bound           scattered ego islands, per-block path
                                (coalesce off), qd 8 — tiny random
                                requests at healthy depth
qd         queue-starved        same scatter, qd clamped to 1 — the
                                submitter starves the device queue
cache      cache-miss-bound     feature cache 64 rows vs a ~2.5k-row
                                working set replayed 3 epochs (graph
                                fully buffered, so feature I/O
                                dominates and the cache thrashes)
dropout    fault-degraded       4 arrays, array 3 drops on its first
                                read — reads served degraded
latency    hedge-stall          seeded latency spikes (p=0.2, 40x)
                                with hedging armed
admission  admission-throttled  a 1%-share tenant behind a saturating
                                bulk tenant on one admission queue
clean      (no causal finding)  tiles, ample cache, no faults — the
                                watchdog and causal detectors must
                                stay silent (zero false positives)
========== ==================== =====================================

Graded as ``n_correct`` out of 8 (the seven planted primaries plus the
alert-free clean run); floors ``MIN_CORRECT`` and
``MIN_CLEAN_ALERT_FREE`` are enforced inline and re-checked from
``BENCH_doctor.json`` by ``benchmarks.check_regression``.  Fixed
geometry in both tiers — a deterministic grading matrix at container
scale, not a scaling measurement.
"""
from __future__ import annotations

import os
import threading

import numpy as np

from .common import WORKDIR, emit, maybe_export_trace

from repro.core import (AgnesConfig, AgnesEngine, AnomalyWatchdog,
                        FeatureBlockStore, GraphBlockStore, NVMeModel,
                        QoSClass, ServingTier, StorageTopology)

MIN_CORRECT = 7           # of N_SCENARIOS labeled scenarios
MIN_CLEAN_ALERT_FREE = 1  # clean run: 1 <=> zero alerts + zero causal

N_SCENARIOS = 8
CAUSAL_KINDS = ("fault-degraded", "admission-throttled",
                "cache-miss-bound", "hedge-stall")

N_NODES = 4_096
RING_K = 8                # ring neighbors per side (degree 16, uniform)
G_BLOCK = 2048
F_DIM = 128               # 512 B rows -> 4 rows per feature block
F_BLOCK = 2048
MB, N_MB = 64, 4          # tile minibatch geometry (256 nodes/hyperbatch)
SMB, SN_MB = 24, 2        # scatter geometry (48 isolated ego islands)

DROPOUT_NOW = "dropout:array=3,at=0"
LATENCY_SPIKES = "latency:p=0.2,factor=40"


def _build_workload() -> tuple[str, str]:
    gpath = os.path.join(WORKDIR, "doctor_ring.graph")
    fpath = os.path.join(WORKDIR, "doctor_ring.feat")
    if not os.path.exists(gpath + ".meta.json"):
        offs = np.concatenate([np.arange(-RING_K, 0),
                               np.arange(1, RING_K + 1)])
        indices = ((np.arange(N_NODES)[:, None] + offs[None, :])
                   % N_NODES).astype(np.int64).ravel()
        indptr = (np.arange(N_NODES + 1, dtype=np.int64) * (2 * RING_K))
        GraphBlockStore.build(gpath, indptr, indices, block_size=G_BLOCK)
    if not os.path.exists(fpath + ".meta.json"):
        rng = np.random.default_rng(7)
        feats = rng.normal(0, 1, (N_NODES, F_DIM)).astype(np.float32)
        FeatureBlockStore.build(fpath, feats, block_size=F_BLOCK)
    return gpath, fpath


def _engine(gpath: str, fpath: str, n_arrays: int = 1,
            **over) -> AgnesEngine:
    g = GraphBlockStore.open(gpath, NVMeModel())
    f = FeatureBlockStore.open(fpath, NVMeModel())
    kw = dict(block_size=G_BLOCK, minibatch_size=MB, hyperbatch_size=N_MB,
              fanouts=(RING_K,), graph_buffer_bytes=64 << 10,
              feature_buffer_bytes=64 << 10,
              # capacity >= every row touched: the cache never evicts,
              # so cold one-pass misses cannot masquerade as a planted
              # cache-miss-bound scenario
              cache_capacity_rows=N_NODES, async_io=False,
              io_queue_depth=8, max_coalesce_bytes=64 << 10,
              placement="stripe", trace=True)
    kw.update(over)
    topo = StorageTopology.uniform(n_arrays) if n_arrays > 1 else None
    return AgnesEngine(g, f, AgnesConfig(**kw), topology=topo)


def _tiles(hb: int) -> list[np.ndarray]:
    """Contiguous tiles marching over the ring: long sequential runs."""
    lo = (hb * N_MB * MB) % N_NODES
    return [(lo + np.arange(j * MB, (j + 1) * MB)) % N_NODES
            for j in range(N_MB)]


def _scatter(hb: int) -> list[np.ndarray]:
    """48 isolated ego islands ~85 nodes apart: each island spans ~2
    graph blocks and ~5 feature blocks, so per-block reads are random
    heads with short sequential tails — the iops arm by construction."""
    seeds = (hb * 409 + np.arange(SN_MB * SMB) * 85) % N_NODES
    return [seeds[j * SMB:(j + 1) * SMB].astype(np.int64)
            for j in range(SN_MB)]


def _grade(report, expected: str) -> dict:
    top = report.findings[0] if report.findings else None
    return {"expected": expected, "primary": report.primary,
            "correct": int(report.primary == expected),
            "severity": top.severity if top else 0.0}


# ---------------------------------------------------------------- scenarios
def _scn_bw(gpath, fpath):
    eng = _engine(gpath, fpath, max_coalesce_bytes=8 << 20,
                  io_queue_depth=32)
    for hb in range(6):
        eng.prepare(_tiles(hb), epoch=0)
    report = eng.diagnose()
    maybe_export_trace(eng, "doctor_bw")
    eng.close()
    return report


def _scn_iops(gpath, fpath):
    eng = _engine(gpath, fpath, minibatch_size=SMB, hyperbatch_size=SN_MB,
                  max_coalesce_bytes=0, io_queue_depth=8)
    for hb in range(6):
        eng.prepare(_scatter(hb), epoch=0)
    report = eng.diagnose()
    maybe_export_trace(eng, "doctor_iops")
    eng.close()
    return report


def _scn_qd(gpath, fpath):
    eng = _engine(gpath, fpath, minibatch_size=SMB, hyperbatch_size=SN_MB,
                  max_coalesce_bytes=0, io_queue_depth=1)
    for hb in range(6):
        eng.prepare(_scatter(hb), epoch=0)
    report = eng.diagnose()
    maybe_export_trace(eng, "doctor_qd")
    eng.close()
    return report


def _scn_cache(gpath, fpath):
    # graph fully buffered after epoch 0; the 64-row cache thrashes
    # against a ~2.5k-row working set replayed every epoch
    eng = _engine(gpath, fpath, minibatch_size=SMB, hyperbatch_size=SN_MB,
                  graph_buffer_bytes=1 << 20, cache_capacity_rows=64,
                  cache_policy="clock")
    plan = [_scatter(hb) for hb in range(4)]
    for epoch in range(3):
        for targets in plan:
            eng.prepare(targets, epoch=epoch)
    report = eng.diagnose()
    maybe_export_trace(eng, "doctor_cache")
    eng.close()
    return report


def _scn_dropout(gpath, fpath):
    eng = _engine(gpath, fpath, n_arrays=4, fault_schedule=DROPOUT_NOW,
                  io_retries=6)
    for hb in range(6):
        eng.prepare(_tiles(hb), epoch=0)
    report = eng.diagnose()
    maybe_export_trace(eng, "doctor_dropout")
    eng.close()
    return report


def _scn_latency(gpath, fpath):
    eng = _engine(gpath, fpath, n_arrays=4, fault_schedule=LATENCY_SPIKES,
                  hedge_deadline_frac=1.5, io_retries=6)
    for hb in range(8):
        eng.prepare(_tiles(hb), epoch=0)
    report = eng.diagnose()
    maybe_export_trace(eng, "doctor_latency")
    eng.close()
    return report


def _scn_admission(gpath, fpath):
    """A 1%-share tenant behind a bulk tenant saturating the same
    queues: its admission stall must dominate its own tiny I/O and
    surface as a per-tenant admission-throttled finding."""
    eng = _engine(gpath, fpath, n_arrays=4, io_queue_depth=4)
    tier = ServingTier(eng)
    tier.open_tenant(
        "starved",
        qos=QoSClass("starved", priority=9, share=0.01, burst_bytes=1024,
                     fetch_timeout_s=30.0, aging_grants=10_000,
                     aging_wait_s=0.05),
        fanouts=(RING_K,))
    errs: list[BaseException] = []
    done = [False]

    def bulk():
        try:
            hb = 0
            while not done[0] and hb < 48:
                tier.prepare("training", _tiles(hb), epoch=0)
                hb += 1
        except BaseException as e:       # surfaced via errs
            errs.append(e)

    t = threading.Thread(target=bulk)
    t.start()
    try:
        for i in range(8):
            seeds = np.array([(i * 97 + j * 911) % N_NODES
                              for j in range(4)], dtype=np.int64)
            tier.prepare("starved", [seeds], epoch=100 + i)
    finally:
        done[0] = True
        t.join(timeout=300)
    assert not errs, errs
    report = tier.diagnose()
    maybe_export_trace(eng, "doctor_admission")
    tier.close()
    eng.close()
    return report


def _scn_clean(gpath, fpath):
    """No planted bottleneck: the causal detectors and every watchdog
    window must stay silent."""
    eng = _engine(gpath, fpath)
    wd = AnomalyWatchdog(eng)
    wd.begin()
    for epoch in range(3):
        for hb in range(4):
            eng.prepare(_tiles(hb), epoch=epoch)
            wd.observe(f"e{epoch}hb{hb}")
    report = eng.diagnose()
    maybe_export_trace(eng, "doctor_clean")
    eng.close()
    return report, list(wd.alerts)


# --------------------------------------------------------------------- run
def run() -> dict:
    gpath, fpath = _build_workload()
    planted = [
        ("bw", "bw-bound", _scn_bw),
        ("iops", "iops-bound", _scn_iops),
        ("qd", "queue-starved", _scn_qd),
        ("cache", "cache-miss-bound", _scn_cache),
        ("dropout", "fault-degraded", _scn_dropout),
        ("latency", "hedge-stall", _scn_latency),
        ("admission", "admission-throttled", _scn_admission),
    ]
    scenarios: dict = {}
    n_correct = 0
    for tag, expected, fn in planted:
        report = fn(gpath, fpath)
        g = _grade(report, expected)
        scenarios[tag] = g
        n_correct += g["correct"]
        emit(f"doctor/{tag}", g["correct"],
             f"expected {expected}, diagnosed {g['primary']} "
             f"(severity {g['severity']:.2f})")

    clean_report, clean_alerts = _scn_clean(gpath, fpath)
    causal = [f.kind for f in clean_report.findings
              if f.kind in CAUSAL_KINDS]
    alert_free = int(not clean_alerts and not causal)
    scenarios["clean"] = {"expected": "no causal finding",
                         "primary": clean_report.primary,
                         "correct": alert_free,
                         "severity": (clean_report.findings[0].severity
                                      if clean_report.findings else 0.0)}
    n_correct += alert_free
    emit("doctor/clean", alert_free,
         f"{len(clean_alerts)} watchdog alerts, causal findings "
         f"{causal or '[]'} (primary {clean_report.primary})")
    emit("doctor/accuracy", n_correct,
         f"{n_correct}/{N_SCENARIOS} planted bottlenecks diagnosed "
         f"correctly")

    assert n_correct >= MIN_CORRECT, \
        (f"doctor accuracy regression: {n_correct}/{N_SCENARIOS} < "
         f"{MIN_CORRECT} — " + ", ".join(
             f"{t}: expected {s['expected']} got {s['primary']}"
             for t, s in scenarios.items() if not s["correct"]))
    assert alert_free >= MIN_CLEAN_ALERT_FREE, \
        (f"clean run false positives: alerts {clean_alerts}, "
         f"causal findings {causal}")

    return {
        "workload": {"n_nodes": N_NODES, "graph_block": G_BLOCK,
                     "feature_block": F_BLOCK, "dim": F_DIM},
        "scenarios": scenarios,
        "n_scenarios": N_SCENARIOS,
        "n_correct": n_correct,
        "clean": {"alerts": len(clean_alerts),
                  "causal_findings": causal,
                  "alert_free": alert_free},
    }


if __name__ == "__main__":
    print(run())
