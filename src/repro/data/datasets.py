"""Dataset registry: container-scale stand-ins for the paper's graphs.

The paper evaluates on IG (10M/120M), TW (41.65M/1.47B), PA (111M/1.62B),
FR (68M/2.29B), YH (1.4B/6.6B).  Those do not fit this container, so each
gets a power-law stand-in with the same *shape* (avg degree, skew) scaled
down; benchmark speedup ratios are measured on the real code paths and the
NVMe device model (DESIGN.md §6).  The builder produces the full AGNES
storage layout on disk: locality-relabeled CSR → graph blocks + feature
blocks (+ the raw CSR file baselines read node-granularly).
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

from ..core.block_store import (DEFAULT_BLOCK_SIZE, FeatureBlockStore,
                                GraphBlockStore)
from ..core.device_model import NVMeModel
from ..core.layout import apply_relabel, bfs_locality_order
from ..core.baselines import CSRStorage
from .synth import make_features, powerlaw_graph, rmat_graph

# name -> (n_nodes, avg_degree, generator)  — shapes echo the paper's Table 2
DATASETS = {
    "ig-mini": (40_000, 12, "rmat"),     # IGB-medium stand-in
    "tw-mini": (80_000, 35, "rmat"),     # twitter-2010 stand-in (hub-heavy)
    "pa-mini": (120_000, 15, "powerlaw"),  # ogbn-papers100M stand-in
    "fr-mini": (100_000, 33, "powerlaw"),  # com-friendster stand-in
    "yh-mini": (200_000, 10, "rmat"),    # yahoo-web stand-in (largest)
    "tiny": (2_000, 8, "rmat"),          # unit-test scale
}


@dataclasses.dataclass
class GraphDataset:
    name: str
    n_nodes: int
    n_edges: int
    dim: int
    indptr: np.ndarray           # locality-relabeled CSR (in memory, for oracles)
    indices: np.ndarray
    labels: np.ndarray
    graph_store: GraphBlockStore
    feature_store: FeatureBlockStore
    csr_path: str                # raw indices file for baseline engines
    workdir: str
    n_classes: int = 16

    def csr_storage(self, page_buffer_bytes: int,
                    device: NVMeModel | None = None) -> CSRStorage:
        return CSRStorage(self.indptr, self.csr_path, len(self.indices),
                          page_buffer_bytes, device)

    def reopen_stores(self, device: NVMeModel | None = None
                      ) -> tuple[GraphBlockStore, FeatureBlockStore]:
        """Fresh store handles with independent I/O stats."""
        g = GraphBlockStore.open(self.graph_store.path, device)
        f = FeatureBlockStore.open(self.feature_store.path, device)
        return g, f


def build_dataset(name: str, workdir: str, *, dim: int = 128,
                  block_size: int = DEFAULT_BLOCK_SIZE,
                  n_nodes: int | None = None, avg_degree: int | None = None,
                  relabel: bool = True, seed: int = 0,
                  device: NVMeModel | None = None) -> GraphDataset:
    """Generate (or reuse cached) storage layout for a registry dataset."""
    n, d, gen = DATASETS.get(name, (n_nodes or 10_000, avg_degree or 10, "rmat"))
    if n_nodes is not None:
        n = n_nodes
    if avg_degree is not None:
        d = avg_degree
    os.makedirs(workdir, exist_ok=True)
    tag = f"{name}_n{n}_d{d}_f{dim}_b{block_size}_r{int(relabel)}_s{seed}"
    gpath = os.path.join(workdir, tag + ".graph.blocks")
    fpath = os.path.join(workdir, tag + ".feat.blocks")
    cpath = os.path.join(workdir, tag + ".indices.bin")
    lpath = os.path.join(workdir, tag + ".labels.npy")
    ipath = os.path.join(workdir, tag + ".indptr.npy")

    if all(os.path.exists(p) for p in
           (gpath, fpath, cpath, lpath, ipath,
            gpath + ".meta.json", fpath + ".meta.json")):
        indptr = np.load(ipath)
        labels = np.load(lpath)
        indices = np.memmap(cpath, dtype=np.int64, mode="r")
        gstore = GraphBlockStore.open(gpath, device)
        fstore = FeatureBlockStore.open(fpath, device)
        return GraphDataset(name, n, len(indices), dim, indptr,
                            np.asarray(indices), labels, gstore, fstore,
                            cpath, workdir)

    if gen == "rmat":
        indptr, indices = rmat_graph(n, n * d, seed=seed)
    else:
        indptr, indices = powerlaw_graph(n, d, seed=seed)
    if relabel:
        order = bfs_locality_order(indptr, indices)
        indptr, indices, _ = apply_relabel(indptr, indices, order)
    feats, labels = make_features(n, dim, seed=seed)

    gstore = GraphBlockStore.build(gpath, indptr, indices, block_size, device)
    fstore = FeatureBlockStore.build(fpath, feats, block_size, device)
    indices.astype(np.int64).tofile(cpath)
    np.save(lpath, labels)
    np.save(ipath, indptr)
    return GraphDataset(name, n, len(indices), dim, indptr, indices, labels,
                        gstore, fstore, cpath, workdir)
