"""Pipelined executor: determinism vs the serial loop, clean shutdown."""
import dataclasses
import threading

import numpy as np
import pytest

from repro.core import AgnesConfig, AgnesEngine, NVMeModel, StorageTopology
from repro.gnn import GNNTrainer, PipelinedExecutor

CFG = dict(block_size=16384, minibatch_size=64, hyperbatch_size=2,
           fanouts=(4, 4), graph_buffer_bytes=1 << 20,
           feature_buffer_bytes=1 << 20, async_io=False)


def _engine(tiny_ds):
    g, f = tiny_ds.reopen_stores()
    return AgnesEngine(g, f, AgnesConfig(**CFG))


def _trainer(tiny_ds):
    tr = GNNTrainer(arch="gcn", in_dim=32, hidden=32, n_classes=16,
                    n_layers=2, seed=7)
    tr.labels = tiny_ds.labels
    return tr


def test_pipelined_matches_serial_losses(tiny_ds):
    """Fixed seed ⇒ the overlapped epoch is loss-for-loss identical."""
    targets = np.arange(256)
    serial_tr = _trainer(tiny_ds)
    eng = _engine(tiny_ds)
    serial = [serial_tr.train_minibatch(p)
              for prepared in eng.iter_epoch(targets, epoch=0)
              for p in prepared]

    pipe_tr = _trainer(tiny_ds)
    with PipelinedExecutor(_engine(tiny_ds), pipe_tr, depth=2) as ex:
        report = ex.run_epoch(targets, epoch=0)

    assert len(serial) == len(report.losses) == report.n_minibatches
    assert serial == report.losses  # exact: same prepare order, same jit fn
    # trainer states advanced identically
    for a, b in zip(np.asarray(serial_tr.params["layers"][0]["w"]).ravel(),
                    np.asarray(pipe_tr.params["layers"][0]["w"]).ravel()):
        assert a == b


def test_multi_epoch_reuse_and_report(tiny_ds):
    with PipelinedExecutor(_engine(tiny_ds), _trainer(tiny_ds)) as ex:
        r0 = ex.run_epoch(np.arange(256), epoch=0)
        r1 = ex.run_epoch(np.arange(256), epoch=1)
    for r in (r0, r1):
        assert r.n_hyperbatches == 2 and r.n_minibatches == 4
        assert 0.0 <= r.hidden_fraction <= 1.0
        assert r.epoch_wall_s > 0 and r.prepare_wall_s > 0
        assert len(r.prepare_reports) == r.n_hyperbatches
    assert r1.losses != r0.losses  # epochs see different shuffles/samples


def test_close_leaves_no_threads(tiny_ds):
    before = threading.active_count()
    ex = PipelinedExecutor(_engine(tiny_ds), _trainer(tiny_ds), depth=1)
    ex.run_epoch(np.arange(128), epoch=0)
    ex.close()
    ex.close()  # idempotent
    assert threading.active_count() == before


def test_producer_exception_propagates_and_joins(tiny_ds):
    class Boom(RuntimeError):
        pass

    class FailingEngine:
        last_report = None

        def plan_epoch(self, targets, epoch=0, shuffle=True):
            return [[targets]]

        def prepare(self, mbs, epoch=0):
            raise Boom("storage went away")

    before = threading.active_count()
    ex = PipelinedExecutor(FailingEngine(), _trainer(tiny_ds))
    with pytest.raises(Boom, match="storage went away"):
        ex.run_epoch(np.arange(64))
    ex.close()
    assert threading.active_count() == before


def test_per_array_adaptive_queue_depth(tiny_ds):
    """With a storage topology each array is driven from its own windowed
    roofline: the slow (roofline-setting) array deepens while the fast
    one with slack shrinks — independent per-array control."""
    fast = dataclasses.replace(NVMeModel(), bandwidth=4 * 6.7e9,
                               latency=20e-6)
    topo = StorageTopology([fast, NVMeModel()])
    g, f = tiny_ds.reopen_stores()
    eng = AgnesEngine(g, f, AgnesConfig(**CFG, io_queue_depth=4,
                                        placement="stripe"), topology=topo)

    class InstantTrainer:  # train time ~0 => prepare is fully exposed
        labels = None

        def train_minibatch(self, prepared):
            return 0.0

    with PipelinedExecutor(eng, InstantTrainer(), adaptive_io=True,
                           io_queue_depth_bounds=(2, 32)) as ex:
        rep = ex.run_epoch(np.arange(512), epoch=0, shuffle=False)
    assert rep.queue_depths, "adaptive hook never fired"
    assert all(isinstance(d, dict) and set(d) == {0, 1}
               for d in rep.queue_depths)
    # while real I/O flowed, the slow array (4x busier) out-deepened the
    # fast one; once the tiny store is fully buffer-resident both decay
    # toward the floor, so assert the divergence, not the final state
    assert any(d[1] > d[0] for d in rep.queue_depths), \
        "the roofline-setting slow array never out-deepened the fast one"
    assert eng.io_queue_depths() == rep.queue_depths[-1]  # engine agrees
    eng.close()


def test_consumer_exception_stops_producer(tiny_ds):
    """A failing train step mid-epoch must not leave the producer alive."""
    class BadTrainer:
        labels = None

        def train_minibatch(self, prepared):
            raise ValueError("nan loss")

    before = threading.active_count()
    ex = PipelinedExecutor(_engine(tiny_ds), BadTrainer(), depth=1)
    with pytest.raises(ValueError, match="nan loss"):
        ex.run_epoch(np.arange(256))
    ex.close()
    assert threading.active_count() == before
