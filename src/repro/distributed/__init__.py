from .sharding import (batch_sharding, cache_shardings, param_shardings,
                       opt_state_shardings)

__all__ = ["batch_sharding", "cache_shardings", "param_shardings",
           "opt_state_shardings"]
