"""Synthetic power-law graph generation (container-scale stand-ins).

Real-world graphs have power-law degree distributions (paper §1, [15]) —
that skew is exactly what creates the many-small-I/O problem AGNES solves,
so the generators here are built to reproduce it:

* :func:`rmat_graph` — Kronecker/R-MAT edges (a,b,c,d), the standard
  web/social-graph generator (Graph500 uses it).
* :func:`powerlaw_graph` — preferential-attachment-flavored generator with
  an explicit Zipf exponent (vectorized; no Python-per-edge loops).

Both return deduplicated, symmetrized-optional CSR.
"""
from __future__ import annotations

import numpy as np


def rmat_graph(n_nodes: int, n_edges: int, *, a: float = 0.57, b: float = 0.19,
               c: float = 0.19, seed: int = 0,
               symmetrize: bool = True) -> tuple[np.ndarray, np.ndarray]:
    """R-MAT graph as CSR (indptr, indices)."""
    rng = np.random.default_rng(seed)
    scale = max(int(np.ceil(np.log2(max(n_nodes, 2)))), 1)
    m = int(n_edges)
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    pa, pb, pc = a, a + b, a + b + c
    for bit in range(scale):
        r = rng.random(m)
        quad_b = (r >= pa) & (r < pb)
        quad_c = (r >= pb) & (r < pc)
        quad_d = r >= pc
        src = (src << 1) | (quad_c | quad_d)
        dst = (dst << 1) | (quad_b | quad_d)
    src %= n_nodes
    dst %= n_nodes
    return _to_csr(n_nodes, src, dst, symmetrize)


def powerlaw_graph(n_nodes: int, avg_degree: int = 15, *, alpha: float = 1.8,
                   seed: int = 0,
                   symmetrize: bool = True) -> tuple[np.ndarray, np.ndarray]:
    """Zipf-skewed multigraph: endpoints drawn from a truncated zipf."""
    rng = np.random.default_rng(seed)
    m = n_nodes * avg_degree // (2 if symmetrize else 1)
    # endpoint popularity ~ zipf(alpha) over a shuffled identity
    ranks = rng.permutation(n_nodes)
    u = rng.random(m)
    v = rng.random(m)
    # inverse-CDF for truncated zipf on [1, n]
    x = _zipf_inv(u, alpha, n_nodes)
    y = (rng.random(m) * n_nodes).astype(np.int64)  # uniform other end
    src = ranks[x]
    dst = ranks[np.minimum(y, n_nodes - 1)]
    keep = src != dst
    return _to_csr(n_nodes, src[keep], dst[keep], symmetrize)


def _zipf_inv(u: np.ndarray, alpha: float, n: int) -> np.ndarray:
    if abs(alpha - 1.0) < 1e-9:
        alpha = 1.0000001
    h = lambda x: (x ** (1 - alpha) - 1) / (1 - alpha)  # noqa: E731
    total = h(n + 1.0)
    x = ((u * total) * (1 - alpha) + 1) ** (1.0 / (1 - alpha))
    return np.clip(x.astype(np.int64), 1, n) - 1


def _to_csr(n: int, src: np.ndarray, dst: np.ndarray,
            symmetrize: bool) -> tuple[np.ndarray, np.ndarray]:
    if symmetrize:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    # dedupe
    key = src * n + dst
    key = np.unique(key)
    src = key // n
    dst = key % n
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, src + 1, 1)
    np.cumsum(indptr, out=indptr)
    return indptr, dst.astype(np.int64)


def make_features(n_nodes: int, dim: int, seed: int = 0,
                  n_classes: int = 16,
                  dtype=np.float32) -> tuple[np.ndarray, np.ndarray]:
    """Class-conditional Gaussian features + labels (classification-able)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, n_nodes)
    centers = rng.normal(0, 1.0, (n_classes, dim))
    feats = centers[labels] + rng.normal(0, 1.0, (n_nodes, dim))
    return feats.astype(dtype), labels.astype(np.int32)
