"""Fine-grained MoE: top-k routing, shared experts, EP-shardable dispatch.

This is where the paper's idea transfers deepest (DESIGN.md §4,
"AGNES-for-MoE"): top-6-of-64 routing produces a power-law stream of
small gathers against a large expert store — the same many-small-I/Os
shape AGNES fixes with bucketing.  The dispatch below is the bucket
matrix made dense: tokens are grouped (GShard groups = hyperbatch), each
group builds a (token → expert, capacity-slot) one-hot ``Bck`` and every
expert processes its whole bucket in one contraction.  Experts shard over
the ``model`` axis (EP); GSPMD lowers the dispatch/combine einsums to
all-to-alls on that axis.

Capacity: C = ceil(tokens_per_group * top_k / n_experts * capacity_factor)
(128-aligned).  Overflowing tokens are dropped (standard GShard behavior);
the router uses f32 and adds the usual load-balancing auxiliary loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .common import dense_init


def moe_init(key, cfg: ModelConfig) -> dict:
    m = cfg.moe
    d = cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 7)
    p = {
        "router": dense_init(ks[0], (d, m.n_experts), dtype=jnp.float32),
        # routed experts: stacked (E, ...)
        "w_gate": dense_init(ks[1], (m.n_experts, d, m.d_expert), dtype=dt),
        "w_up": dense_init(ks[2], (m.n_experts, d, m.d_expert), dtype=dt),
        "w_down": dense_init(ks[3], (m.n_experts, m.d_expert, d), dtype=dt),
    }
    if m.n_shared:
        p["s_gate"] = dense_init(ks[4], (d, m.n_shared * m.d_expert), dtype=dt)
        p["s_up"] = dense_init(ks[5], (d, m.n_shared * m.d_expert), dtype=dt)
        p["s_down"] = dense_init(ks[6], (m.n_shared * m.d_expert, d), dtype=dt)
    return p


def _capacity(tokens_per_group: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = int(tokens_per_group * m.top_k / m.n_experts * m.capacity_factor)
    return max(-(-c // 8) * 8, 8)


def _dispatch_one_group(p, x, cfg: ModelConfig):
    """x: (T, D) one dispatch group. Returns (T, D) output + aux loss."""
    m = cfg.moe
    T, D = x.shape
    C = _capacity(T, cfg)
    logits = (x.astype(jnp.float32) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    # iterative top-k with capacity assignment (GShard generalized to k)
    remaining = probs
    combine = jnp.zeros((T, m.n_experts, C), jnp.float32)
    fill = jnp.zeros((m.n_experts,), jnp.int32)
    for _ in range(m.top_k):
        gate = jnp.max(remaining, axis=-1)                   # (T,)
        eid = jnp.argmax(remaining, axis=-1)                 # (T,)
        onehot = jax.nn.one_hot(eid, m.n_experts, dtype=jnp.int32)
        # position of each token within its expert queue
        pos_in_e = (jnp.cumsum(onehot, axis=0) - 1) * onehot  # (T, E)
        slot = jnp.sum(pos_in_e, axis=-1) + fill[eid]        # (T,)
        keep = slot < C
        combine += (gate * keep)[:, None, None] \
            * jax.nn.one_hot(eid, m.n_experts)[:, :, None] \
            * jax.nn.one_hot(jnp.clip(slot, 0, C - 1), C)[:, None, :]
        fill = fill + jnp.sum(onehot, axis=0)
        remaining = remaining * (1.0 - jax.nn.one_hot(eid, m.n_experts))
    # renormalize combine weights over the selected experts
    denom = jnp.sum(combine, axis=(1, 2), keepdims=True)
    combine = combine / jnp.maximum(denom, 1e-9)
    dispatch = (combine > 0).astype(x.dtype)                 # (T, E, C)
    xe = jnp.einsum("td,tec->ecd", x, dispatch)              # (E, C, D)
    h = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    h = jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype) * u
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])          # (E, C, D)
    y = jnp.einsum("ecd,tec->td", ye, combine.astype(x.dtype))
    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    f = jnp.mean(jnp.sum(dispatch, axis=-1).astype(jnp.float32), axis=0)
    pbar = jnp.mean(probs, axis=0)
    aux = m.n_experts * jnp.sum(f * pbar)
    return y, aux


def moe_apply(p: dict, x: jnp.ndarray, cfg: ModelConfig, *,
              unroll: bool = False) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) → (B, S, D), aux-loss scalar.

    Tokens are split into dispatch groups of ~``moe.group_tokens``
    (bounding the (T, E, C) bucket tensors to a fixed size regardless of
    batch·seq) and processed by a scanned/unrolled loop — the hyperbatch
    loop shape.
    """
    B, S, D = x.shape
    m = cfg.moe
    tokens = x.reshape(B * S, D)
    n_groups = max(1, min((B * S) // max(m.group_tokens, 1), B * S))
    while (B * S) % n_groups:
        n_groups -= 1
    # STRIDED grouping: group i takes tokens {i, i+n, i+2n, ...} so every
    # group spans all data shards (a contiguous reshape would land whole
    # groups on single shards and serialize the scan).
    groups = jnp.swapaxes(
        tokens.reshape((B * S) // n_groups, n_groups, D), 0, 1)

    if unroll:
        outs, auxs = [], []
        for gi in range(n_groups):
            y, a = _dispatch_one_group(p, groups[gi], cfg)
            outs.append(y)
            auxs.append(a)
        out = jnp.stack(outs)
        aux = jnp.stack(auxs).mean()
    else:
        def body(_, g):
            y, a = _dispatch_one_group(p, g, cfg)
            return None, (y, a)
        # remat per dispatch group: the (T, E, C) bucket tensors are
        # recomputed in backward, never stored across groups
        _, (out, aux) = jax.lax.scan(jax.checkpoint(body), None, groups)
        aux = aux.mean()
    # invert the strided grouping: (n_groups, G_len, D) -> (B*S, D)
    y = jnp.swapaxes(out, 0, 1).reshape(B, S, D)
    if m.n_shared:
        h = jax.nn.silu((tokens @ p["s_gate"]).astype(jnp.float32)).astype(x.dtype)
        u = tokens @ p["s_up"]
        y = y + ((h * u) @ p["s_down"]).reshape(B, S, D)
    return y, aux


def moe_decode(p: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Single-token MoE (B, D) through the same EP dispatch einsums.

    (A per-token gather of expert weights would materialize B·k full
    expert matrices — 100+ GB for jamba — whereas the dispatch form keeps
    experts in place and moves only (E, C, D) token buckets over the EP
    axis.)  Decode uses a generous capacity factor since a B-token step
    is far more skewed than a 4k-token training group.
    """
    import dataclasses as _dc
    m = cfg.moe
    decode_cfg = cfg if m.capacity_factor >= 4.0 else _dc.replace(
        cfg, moe=_dc.replace(m, capacity_factor=4.0))
    y, _ = _dispatch_one_group(p, x, decode_cfg)
    if m.n_shared:
        hs = jax.nn.silu((x @ p["s_gate"]).astype(jnp.float32)).astype(x.dtype)
        y = y + (hs * (x @ p["s_up"])) @ p["s_down"]
    return y
