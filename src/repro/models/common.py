"""Shared LM building blocks: norms, RoPE/M-RoPE, init helpers,
activation sharding constraints."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import get_abstract_mesh


def constrain_batch(x: jnp.ndarray, seq_shard: bool = False,
                    dp_model: bool = False) -> jnp.ndarray:
    """Pin layer-boundary activations: batch sharded over (pod, data);
    with ``seq_shard`` also shard the sequence dim over ``model``
    (Megatron sequence parallelism — GSPMD inserts the seq all-gather
    before each mixer and the reduce-scatter after, cutting layer-
    boundary residual memory by the TP width).

    GSPMD propagation through scan bodies with mixed producers (Mamba
    conv / associative scan / MoE dispatch) can silently drop the batch
    sharding — this constraint at every layer boundary keeps activations
    data-parallel.  No-op outside a mesh context (requires
    ``repro.compat.set_mesh``) or when dims aren't divisible.
    """
    mesh = get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return x
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if dp_model and "model" in mesh.axis_names:
        axes = axes + ("model",)
    if not axes:
        return x
    dsize = 1
    for a in axes:
        dsize *= mesh.shape[a]
    if x.ndim == 0 or x.shape[0] % dsize:
        return x
    spec = [axes if len(axes) > 1 else axes[0]] + [None] * (x.ndim - 1)
    if seq_shard and not dp_model and x.ndim >= 3 \
            and "model" in mesh.axis_names \
            and x.shape[1] % mesh.shape["model"] == 0:
        spec[1] = "model"
    return jax.lax.with_sharding_constraint(x, P(*spec))


def dense_init(key, shape, scale: float | None = None, dtype=jnp.bfloat16):
    fan_in = shape[0]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))
    return out.astype(x.dtype)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap <= 0:
        return x
    return jnp.tanh(x / cap) * cap


# ----------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10_000.0) -> jnp.ndarray:
    """x: (B, S, H, Dh); positions: (B, S) int32."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, dh/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray,
                theta: float = 10_000.0,
                sections: tuple[int, int, int] = (1, 1, 2)) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE: 3 position streams (t, h, w).

    x: (B, S, H, Dh); positions3: (3, B, S).  The rotary dimension is
    split into ``sections`` (normalized ratios over Dh/2); each section
    rotates by its own position stream.  Text tokens carry identical
    t/h/w positions, which reduces exactly to standard RoPE.
    """
    dh = x.shape[-1]
    half = dh // 2
    freqs = rope_freqs(dh, theta)                       # (half,)
    total = sum(sections)
    bounds = []
    acc = 0
    for s in sections:
        acc += s
        bounds.append(half * acc // total)
    # section id per freq index
    idx = jnp.arange(half)
    sec = jnp.zeros(half, jnp.int32)
    sec = jnp.where(idx >= bounds[0], 1, sec)
    sec = jnp.where(idx >= bounds[1], 2, sec)
    pos = positions3.astype(jnp.float32)                # (3, B, S)
    pos_sel = jnp.take(pos, sec, axis=0)                # (half, B, S) -> via take on axis0?
    # jnp.take maps sec (half,) over axis 0: result (half, B, S)
    ang = jnp.moveaxis(pos_sel, 0, -1) * freqs          # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def make_mrope_positions(batch: int, seq: int) -> jnp.ndarray:
    """Stub M-RoPE positions for precomputed-patch inputs: text-like ramp.

    The vision frontend (stubbed per assignment) would supply true
    (t, h, w) grids for image patches; text positions are (p, p, p).
    """
    p = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (batch, seq))
    return jnp.stack([p, p, p], axis=0)
