"""Empirical per-block hotness telemetry (ROADMAP item: Ginex-style).

PR 4's placement policies score blocks with *static* proxies computed at
attach time (``graph_block_hotness`` / ``feature_block_hotness`` in
``topology.py``: degree mass from the pinned T_obj).  Real access skew
only emerges at runtime — hub sampling, label skew, cache residency —
and drifts across epochs.  Ginex (VLDB'22) shows placement/caching
driven by *measured* access traces substantially beats static
heuristics for SSD-based GNN training; this module is that measurement.

:class:`HotnessTracker` accumulates per-block touch counts from the
prepare path:

* the store accounting layer (``block_store._BlockReadBatcher``) records
  one touch per block of every submitted coalesced run and every
  block-granular read — exact storage touches, covering the coalesced
  scheduler, the legacy prefetcher, and direct reads alike;
* :class:`~repro.core.feature_cache.FeatureCache` attributes cache
  *hits* to their feature blocks at a configurable discount
  (``hit_weight``): a hit generates no storage I/O today, but the row
  can be evicted and its block re-read tomorrow, so hit traffic is a
  forward-looking placement signal rather than a current cost.

At epoch boundaries :meth:`roll` folds the epoch's window into an
exponentially-decayed hotness vector (``hot = decay * hot + window``),
so the score tracks drift with bounded memory of the past.
:meth:`hotness` (decayed history + the open window) is what the online
re-placement feeds to :class:`~repro.core.topology.PlacementPolicy`
instead of the static degree proxy — see ``core/migration.py``.
"""
from __future__ import annotations

import threading

import numpy as np


class HotnessTracker:
    """Exponentially-decayed per-block touch counter for one block store.

    Thread-safe: the coalesced reader pool, the legacy prefetch thread
    and the consumer all record touches concurrently with the stores'
    per-store ``_io_lock`` *not* held across stores, so the tracker
    carries its own lock.
    """

    def __init__(self, n_blocks: int, decay: float = 0.5):
        if not (0.0 <= decay < 1.0):
            raise ValueError("decay must be in [0, 1)")
        self.n_blocks = int(n_blocks)
        self.decay = float(decay)
        self.hot = np.zeros(self.n_blocks, dtype=np.float64)
        self.window = np.zeros(self.n_blocks, dtype=np.float64)
        self.n_rolls = 0
        self.total_touches = 0.0
        self._lock = threading.Lock()

    # ------------------------------------------------------------ record
    def touch(self, block_ids, weight: float = 1.0) -> None:
        """Record one touch per entry of ``block_ids`` (repeats add up)."""
        ids = np.asarray(block_ids, dtype=np.int64)
        if ids.size == 0:
            return
        with self._lock:
            np.add.at(self.window, ids, weight)
            self.total_touches += weight * ids.size

    def touch_runs(self, runs, weight: float = 1.0) -> None:
        """Record every block of a submitted coalesced-run plan."""
        with self._lock:
            n = 0
            for r in runs:
                self.window[r.start:r.stop] += weight
                n += r.count
            self.total_touches += weight * n

    # ------------------------------------------------------------ epoch
    def roll(self) -> np.ndarray:
        """Epoch boundary: fold the window into the decayed accumulator.

        Returns the epoch's (pre-fold) window so callers can report
        per-epoch traffic.
        """
        with self._lock:
            epoch_window = self.window
            self.hot *= self.decay
            self.hot += epoch_window
            self.window = np.zeros(self.n_blocks, dtype=np.float64)
            self.n_rolls += 1
            return epoch_window

    @property
    def window_touches(self) -> float:
        """Touches recorded since the last :meth:`roll` (un-rolled traffic)."""
        with self._lock:
            return float(self.window.sum())

    def hotness(self) -> np.ndarray:
        """Current per-block hotness: decayed history + the open window.

        This is the drop-in replacement for the static degree proxies as
        the ``hotness=`` input to ``PlacementPolicy.place``.
        """
        with self._lock:
            return self.hot + self.window

    # ------------------------------------------------------------ reporting
    def skew_summary(self, top_fraction: float = 0.1) -> dict:
        """How concentrated the measured traffic is (placement headroom).

        ``top_share`` is the hotness mass held by the hottest
        ``top_fraction`` of blocks — 1.0 means the hot set is tiny and
        pinnable, ``top_fraction`` means traffic is flat and placement
        cannot beat plain striping.
        """
        h = self.hotness()
        total = float(h.sum())
        k = max(int(self.n_blocks * top_fraction), 1)
        if total <= 0 or self.n_blocks == 0:
            return {"n_blocks": self.n_blocks, "total_touches": 0.0,
                    "top_fraction": top_fraction, "top_share": 0.0,
                    "touched_fraction": 0.0, "n_rolls": self.n_rolls}
        top = np.partition(h, self.n_blocks - k)[self.n_blocks - k:]
        return {
            "n_blocks": self.n_blocks,
            "total_touches": round(float(total), 3),
            "top_fraction": top_fraction,
            "top_share": round(float(top.sum()) / total, 4),
            "touched_fraction": round(float((h > 0).mean()), 4),
            "n_rolls": self.n_rolls,
        }
