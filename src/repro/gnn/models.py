"""GNN models (GCN / GraphSAGE / GAT) on padded message-flow graphs.

The sampler emits per-hop padded neighbor tables (``nbr_idx`` with -1
padding) — the dense-gather layout TPU compute wants: aggregation is a
``take`` + masked mean instead of scatter.

Aggregation is **pluggable** via ``backend``:

* ``"jnp"``   — inline jnp gathers (reference semantics; CPU default).
* ``"pallas"`` — the ``gather_rows`` / ``gather_aggregate`` Pallas
  kernels from ``repro.kernels``: compiled on TPU, interpret mode
  elsewhere, verified against the jnp path within fp32 tolerance
  (``tests/test_kernel_parity.py``).

All three models follow Eq. (1) of the paper:
``h_v^{i+1} = psi(phi(h_{v'}^i | v' in N(v), h_v^i))``.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..core.sampling import MFG
from ..kernels import gather_aggregate, gather_rows

GNN_ARCHS = ("gcn", "sage", "gat")
AGG_BACKENDS = ("jnp", "pallas")


# --------------------------------------------------------------------- MFG
@dataclasses.dataclass
class PaddedMFG:
    """Fixed-shape (jit-stable) MFG for one minibatch.

    ``nbr_idx[l]``: (n_pad[l], fanout) int32 into hop l+1 nodes, -1 pad.
    ``self_idx[l]``: (n_pad[l],) int32 into hop l+1 nodes.
    ``node_mask[l]``: (n_pad[l],) bool — real vs padded dst rows.
    """

    nbr_idx: list[jnp.ndarray]
    self_idx: list[jnp.ndarray]
    node_mask: list[jnp.ndarray]
    features: jnp.ndarray        # (n_pad[k], dim)
    labels: jnp.ndarray          # (n_pad[0],) int32
    n_targets: jnp.ndarray       # scalar


jax.tree_util.register_dataclass(
    PaddedMFG,
    data_fields=["nbr_idx", "self_idx", "node_mask", "features", "labels",
                 "n_targets"],
    meta_fields=[])


def _round_up(n: int, mult: int = 128) -> int:
    return max(((n + mult - 1) // mult) * mult, mult)


def pad_mfg(mfg: MFG, features: np.ndarray, labels: np.ndarray,
            pad_multiple: int = 128) -> PaddedMFG:
    """Pad an MFG + gathered features to jit-stable shapes."""
    k = len(mfg.layers)
    sizes = [_round_up(len(nodes), pad_multiple) for nodes in mfg.nodes]
    nbr_idx, self_idx, node_mask = [], [], []
    for l, layer in enumerate(mfg.layers):
        n_dst, fan = layer.nbr_idx.shape
        ni = np.full((sizes[l], fan), -1, dtype=np.int32)
        ni[:n_dst] = layer.nbr_idx
        si = np.zeros(sizes[l], dtype=np.int32)
        si[:n_dst] = layer.self_idx
        m = np.zeros(sizes[l], dtype=bool)
        m[:n_dst] = True
        nbr_idx.append(jnp.asarray(ni))
        self_idx.append(jnp.asarray(si))
        node_mask.append(jnp.asarray(m))
    n_in = len(mfg.nodes[k])
    if isinstance(features, jnp.ndarray):
        # placement hook (PreparedMinibatch.to_device): features are
        # already device-resident — pad on device, no host round-trip;
        # the pallas route delivers the padded block ready-made
        if features.shape[0] == sizes[k]:
            f = features
        else:
            f = jnp.zeros((sizes[k], features.shape[1]), features.dtype)
            f = f.at[:n_in].set(features)
    else:
        f = jnp.asarray(np.pad(features, ((0, sizes[k] - n_in), (0, 0))))
    lab = np.zeros(sizes[0], dtype=np.int32)
    lab[:len(mfg.nodes[0])] = labels[mfg.nodes[0]]
    return PaddedMFG(nbr_idx, self_idx, node_mask, f,
                     jnp.asarray(lab), jnp.asarray(len(mfg.nodes[0])))


# ------------------------------------------------------------------ params
def _dense_init(key, fan_in, fan_out, dtype=jnp.float32):
    scale = (2.0 / fan_in) ** 0.5
    return jax.random.normal(key, (fan_in, fan_out), dtype) * scale


def init_gnn(key: jax.Array, arch: str, in_dim: int, hidden: int,
             n_classes: int, n_layers: int = 3, n_heads: int = 4) -> dict:
    """Initialize parameters for a k-layer GNN."""
    if arch not in GNN_ARCHS:
        raise ValueError(f"unknown arch {arch}")
    keys = jax.random.split(key, n_layers * 4)
    layers = []
    d_in = in_dim
    for l in range(n_layers):
        d_out = n_classes if l == n_layers - 1 else hidden
        ki = keys[l * 4:(l + 1) * 4]
        if arch == "gcn":
            p = {"w": _dense_init(ki[0], d_in, d_out),
                 "b": jnp.zeros((d_out,))}
        elif arch == "sage":
            p = {"w_self": _dense_init(ki[0], d_in, d_out),
                 "w_neigh": _dense_init(ki[1], d_in, d_out),
                 "b": jnp.zeros((d_out,))}
        else:  # gat
            dh = max(d_out // n_heads, 1)
            p = {"w": _dense_init(ki[0], d_in, n_heads * dh),
                 "a_src": _dense_init(ki[1], n_heads, dh) * 0.1,
                 "a_dst": _dense_init(ki[2], n_heads, dh) * 0.1,
                 "b": jnp.zeros((n_heads * dh,))}
            d_out = n_heads * dh
        layers.append(p)
        d_in = d_out
    return {"layers": layers}  # pure-array pytree (grad-able); arch is static


# ------------------------------------------------------------------ compute
def _masked_mean(h_nbr: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """(n, fanout, d) masked mean over fanout."""
    m = mask[..., None].astype(h_nbr.dtype)
    s = jnp.sum(h_nbr * m, axis=1)
    c = jnp.maximum(jnp.sum(m, axis=1), 1.0)
    return s / c


def _gather(h: jnp.ndarray, idx: jnp.ndarray, backend: str) -> jnp.ndarray:
    """Row gather h[idx]; Pallas ``gather_rows`` on the kernel backend."""
    if backend == "pallas":
        return gather_rows(h, idx, use_kernel=True)
    return h[idx]


def _agg(h: jnp.ndarray, nbr_idx: jnp.ndarray, backend: str,
         mean: bool) -> jnp.ndarray:
    """Masked neighbor sum/mean; Pallas fused kernel on the kernel backend."""
    if backend == "pallas":
        return gather_aggregate(h, nbr_idx, mean=mean, use_kernel=True)
    mask = nbr_idx >= 0
    h_nbr = h[jnp.clip(nbr_idx, 0)]                  # dense gather
    if mean:
        return _masked_mean(h_nbr, mask)
    return jnp.sum(h_nbr * mask[..., None].astype(h.dtype), axis=1)


def _gcn_layer(p, h_next, nbr_idx, self_idx, backend):
    h_self = _gather(h_next, self_idx, backend)
    # mean over {v} ∪ N(v)  (paper Eq. 1 with mean aggregator)
    s = _agg(h_next, nbr_idx, backend, mean=False) + h_self
    c = jnp.sum(nbr_idx >= 0, axis=1, keepdims=True).astype(h_next.dtype) + 1.0
    return (s / c) @ p["w"] + p["b"]


def _sage_layer(p, h_next, nbr_idx, self_idx, backend):
    h_self = _gather(h_next, self_idx, backend)
    agg = _agg(h_next, nbr_idx, backend, mean=True)
    return h_self @ p["w_self"] + agg @ p["w_neigh"] + p["b"]


def _gat_layer(p, h_next, nbr_idx, self_idx, backend):
    H, dh = p["a_src"].shape  # static under jit
    n, fan = nbr_idx.shape
    mask = nbr_idx >= 0
    z = h_next @ p["w"]                                # (n_src, H*dh)
    z_dst = _gather(z, self_idx, backend).reshape(n, H, dh)
    z_nbr = _gather(z, jnp.clip(nbr_idx, 0).reshape(-1), backend)
    z_nbr = z_nbr.reshape(n, fan, H, dh)               # (n, fan, H, dh)
    e_dst = jnp.einsum("nhd,hd->nh", z_dst, p["a_dst"])
    e_nbr = jnp.einsum("nfhd,hd->nfh", z_nbr, p["a_src"])
    e = jax.nn.leaky_relu(e_dst[:, None, :] + e_nbr, 0.2)
    e = jnp.where(mask[..., None], e, -1e30)
    # include self edge in the softmax (standard GAT self-loop)
    e_self = jax.nn.leaky_relu(e_dst + jnp.einsum("nhd,hd->nh", z_dst, p["a_src"]))
    all_e = jnp.concatenate([e_self[:, None, :], e], axis=1)
    alpha = jax.nn.softmax(all_e, axis=1)
    vals = jnp.concatenate([z_dst[:, None], z_nbr], axis=1)  # (n, 1+fan, H, dh)
    out = jnp.einsum("nfh,nfhd->nhd", alpha, vals)
    return out.reshape(out.shape[0], H * dh) + p["b"]


_LAYER_FNS = {"gcn": _gcn_layer, "sage": _sage_layer, "gat": _gat_layer}


def gnn_apply(params: dict, mfg: PaddedMFG, arch: str,
              backend: str = "jnp") -> jnp.ndarray:
    """Forward pass: hop-k features → target logits (paper's computation).

    ``backend`` selects the aggregation primitives: ``"jnp"`` (inline
    reference) or ``"pallas"`` (kernels; compiled on TPU, interpret on
    CPU).  Static under jit.
    """
    if backend not in AGG_BACKENDS:
        raise ValueError(f"unknown backend {backend}")
    layer_fn = _LAYER_FNS[arch]
    h = mfg.features
    k = len(params["layers"])
    # params.layers[0] consumes raw features => applies to the deepest hop
    for i, p in enumerate(params["layers"]):
        l = k - 1 - i  # MFG hop index: nodes[l] <- nodes[l+1]
        h = layer_fn(p, h, mfg.nbr_idx[l], mfg.self_idx[l], backend)
        h = jnp.where(mfg.node_mask[l][:, None], h, 0.0)
        if i < k - 1:
            h = jax.nn.relu(h)
    return h  # (n_pad[0], n_classes) logits for targets
