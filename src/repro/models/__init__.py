from .lm import CausalLM, EncDecLM, build_model, chunked_cross_entropy

__all__ = ["CausalLM", "EncDecLM", "build_model", "chunked_cross_entropy"]
