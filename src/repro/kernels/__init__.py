"""Pallas TPU kernels for the framework's compute hot spots.

kernel files (pl.pallas_call + BlockSpec) | ops.py (jit wrappers) | ref.py
(pure-jnp oracles).  Validated in interpret mode on CPU; compiled for TPU
as the deployment target.
"""
from .ops import (flash_attention, gather_aggregate, gather_resident_rows,
                  gather_rows)
from . import ref

__all__ = ["flash_attention", "gather_aggregate", "gather_resident_rows",
           "gather_rows", "ref"]
