"""xLSTM mixers: mLSTM (matrix memory, chunk-parallel) + sLSTM (scalar
memory, exponential gating, sequential).

mLSTM is a gated linear-attention form: per head, memory C_t ∈ R^{dh×dh},
C_t = f_t C_{t-1} + i_t v_t k_tᵀ, output h_t = C_t q_t / max(|n_tᵀq_t|, 1)
with exponential input gates stabilized by a running max m_t.  We run it
chunkwise (intra-chunk quadratic in chunk length, inter-chunk via the
(C, n, m) carry) — same memory-bounding shape as the attention/Mamba
chunking.  sLSTM keeps the recurrent R h_{t-1} term and is therefore a
true sequential ``lax.scan`` over time (block-diagonal per head R).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .common import dense_init


# ================================================================== mLSTM
def mlstm_init(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], (d, d), dtype=dt),
        "wk": dense_init(ks[1], (d, d), dtype=dt),
        "wv": dense_init(ks[2], (d, d), dtype=dt),
        "w_if": dense_init(ks[3], (d, 2 * H), scale=0.01, dtype=jnp.float32),
        "b_if": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]),
        "w_o_gate": dense_init(ks[4], (d, d), dtype=dt),
        "w_out": dense_init(ks[5], (d, d), dtype=dt),
    }


def _mlstm_chunk(q, k, v, logi, logf, carry):
    """One chunk, heads folded into batch.

    q,k,v: (B, T, dh); logi/logf: (B, T); carry = (C, n, m) with the
    convention that C/n are stored at scale exp(m) (stabilized
    exponential gating per the xLSTM paper, eqs. 19-27).  q arrives
    pre-scaled by dh^-0.5.
    """
    B, T, dh = q.shape
    C0, n0, m0 = carry
    F = jnp.cumsum(logf, axis=1)                      # (B, T) log-decay prefix
    # intra-chunk log weight of source s for target t: F_t - F_s + logi_s
    d_mat = F[:, :, None] - F[:, None, :] + logi[:, None, :]
    mask = jnp.tril(jnp.ones((T, T), bool))
    d_mat = jnp.where(mask[None], d_mat, -jnp.inf)
    inter_log = F + m0[:, None]                       # carry contribution
    m_t = jnp.maximum(jnp.max(d_mat, axis=2), inter_log)  # (B, T) stabilizer
    d_exp = jnp.exp(d_mat - m_t[:, :, None])          # (B, T, T)
    w_inter = jnp.exp(inter_log - m_t)                # (B, T)
    s = jnp.einsum("btd,bsd->bts", q, k)
    num = jnp.einsum("bts,bsd->btd", s * d_exp, v) \
        + jnp.einsum("btd,bde->bte", q * w_inter[:, :, None], C0)
    # normalizer accumulates exactly like C with v -> k identity weights
    n_full = jnp.einsum("bts,bsd->btd", d_exp, k) \
        + w_inter[:, :, None] * n0[:, None, :]
    qn = jnp.abs(jnp.einsum("btd,btd->bt", q, n_full))
    h = num / jnp.maximum(qn, jnp.exp(-m_t))[:, :, None]
    # chunk-final carry at scale m_T
    m_T = m_t[:, -1]
    decay_to_T = jnp.exp(F[:, -1:] - F + logi - m_T[:, None])   # (B, T)
    C_new = jnp.exp(F[:, -1] + m0 - m_T)[:, None, None] * C0 \
        + jnp.einsum("bt,btd,bte->bde", decay_to_T, k, v)
    n_new = jnp.exp(F[:, -1] + m0 - m_T)[:, None] * n0 \
        + jnp.einsum("bt,btd->bd", decay_to_T, k)
    return h, (C_new, n_new, m_T)


def mlstm_apply(p: dict, x: jnp.ndarray, cfg: ModelConfig, *,
                unroll: bool = False) -> jnp.ndarray:
    B, S, D = x.shape
    H = cfg.n_heads
    dh = D // H
    q = (x @ p["wq"]).reshape(B, S, H, dh)
    k = (x @ p["wk"]).reshape(B, S, H, dh)
    v = (x @ p["wv"]).reshape(B, S, H, dh)
    gates = x.astype(jnp.float32) @ p["w_if"] + p["b_if"]   # (B, S, 2H)
    logi = jax.nn.log_sigmoid(gates[..., :H])   # stabilized input gate (log)
    logf = jax.nn.log_sigmoid(gates[..., H:])
    # fold heads into batch
    fold = lambda t: jnp.moveaxis(t, 2, 1).reshape(B * H, S, dh)  # noqa: E731
    qf = fold(q).astype(jnp.float32) * (dh ** -0.5)
    kf = fold(k).astype(jnp.float32)
    vf = fold(v).astype(jnp.float32)
    li = jnp.moveaxis(logi, 2, 1).reshape(B * H, S)
    lf = jnp.moveaxis(logf, 2, 1).reshape(B * H, S)

    chunk = min(cfg.ssm.chunk, S)
    while S % chunk:
        chunk //= 2
    n_chunks = S // chunk
    C0 = jnp.zeros((B * H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B * H, dh), jnp.float32)
    m0 = jnp.full((B * H,), -1e30, jnp.float32)

    def body(carry, i):
        sl = lambda t: jax.lax.dynamic_slice_in_dim(t, i * chunk, chunk, 1)  # noqa: E731
        h, carry = _mlstm_chunk(sl(qf), sl(kf), sl(vf), sl(li), sl(lf), carry)
        return carry, h

    if unroll:
        hs = []
        carry = (C0, n0, m0)
        for i in range(n_chunks):
            carry, h = body(carry, i)
            hs.append(h)
        h = jnp.concatenate(hs, axis=1)
    else:
        # remat per chunk: keep only the (C, n, m) carries
        _, h = jax.lax.scan(jax.checkpoint(body), (C0, n0, m0),
                            jnp.arange(n_chunks))
        h = jnp.moveaxis(h, 0, 1).reshape(B * H, S, dh)
    h = h.reshape(B, H, S, dh).swapaxes(1, 2).reshape(B, S, D)
    og = jax.nn.sigmoid((x @ p["w_o_gate"]).astype(jnp.float32))
    return ((h * og).astype(x.dtype)) @ p["w_out"]


@dataclasses.dataclass
class MLSTMCache:
    C: jnp.ndarray   # (B*H, dh, dh)
    n: jnp.ndarray   # (B*H, dh)
    m: jnp.ndarray   # (B*H,)


jax.tree_util.register_dataclass(MLSTMCache, data_fields=["C", "n", "m"],
                                 meta_fields=[])


def mlstm_cache_init(cfg: ModelConfig, batch: int) -> MLSTMCache:
    H = cfg.n_heads
    dh = cfg.d_model // H
    return MLSTMCache(C=jnp.zeros((batch * H, dh, dh), jnp.float32),
                      n=jnp.zeros((batch * H, dh), jnp.float32),
                      m=jnp.full((batch * H,), -1e30, jnp.float32))


def mlstm_decode(p, x, cache: MLSTMCache, cfg: ModelConfig):
    B, D = x.shape
    H = cfg.n_heads
    dh = D // H
    q = (x @ p["wq"]).reshape(B * H, dh).astype(jnp.float32)
    k = (x @ p["wk"]).reshape(B * H, dh).astype(jnp.float32)
    v = (x @ p["wv"]).reshape(B * H, dh).astype(jnp.float32)
    gates = x.astype(jnp.float32) @ p["w_if"] + p["b_if"]
    li = jax.nn.log_sigmoid(gates[..., :H]).reshape(B * H)
    lf = jax.nn.log_sigmoid(gates[..., H:]).reshape(B * H)
    m_new = jnp.maximum(lf + cache.m, li)
    fw = jnp.exp(lf + cache.m - m_new)
    iw = jnp.exp(li - m_new)
    C = fw[:, None, None] * cache.C + iw[:, None, None] * v[:, :, None] \
        * k[:, None, :]
    n = fw[:, None] * cache.n + iw[:, None] * k
    num = jnp.einsum("bde,be->bd", C, q) * (dh ** -0.5)
    qn = jnp.abs(jnp.einsum("bd,bd->b", n, q)) * (dh ** -0.5)
    h = num / jnp.maximum(qn, jnp.exp(-m_new))[:, None]
    h = h.reshape(B, D)
    og = jax.nn.sigmoid((x @ p["w_o_gate"]).astype(jnp.float32))
    out = ((h * og).astype(x.dtype)) @ p["w_out"]
    return out, MLSTMCache(C=C, n=n, m=m_new)


# ================================================================== sLSTM
def slstm_init(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    return {
        "w": dense_init(ks[0], (d, 4 * d), dtype=dt),       # i, f, z, o
        # recurrent block-diagonal per head: (H, dh, 4*dh)
        "r": dense_init(ks[1], (H, dh, 4 * dh), scale=0.3, dtype=jnp.float32),
        "b": jnp.concatenate([jnp.zeros((d,)), 3.0 * jnp.ones((d,)),
                              jnp.zeros((2 * d,))]),
        "w_out": dense_init(ks[2], (d, d), dtype=dt),
    }


def slstm_apply(p: dict, x: jnp.ndarray, cfg: ModelConfig, *,
                unroll: bool = False) -> jnp.ndarray:
    """Sequential scan over time (true recurrence; xLSTM paper §2.1)."""
    B, S, D = x.shape
    H = cfg.n_heads
    dh = D // H
    wx = (x @ p["w"]).astype(jnp.float32)                  # (B, S, 4D)

    def step(carry, wx_t):
        h, c, n, m = carry                                 # (B, D) each
        hr = h.reshape(B, H, dh)
        rec = jnp.einsum("bhd,hde->bhe", hr, p["r"]).reshape(B, 4 * D)
        z = wx_t + rec + p["b"]
        zi, zf, zz, zo = jnp.split(z, 4, axis=-1)
        m_new = jnp.maximum(zf + m, zi)                    # stabilizer
        iw = jnp.exp(zi - m_new)
        fw = jnp.exp(zf + m - m_new)
        c_new = fw * c + iw * jnp.tanh(zz)
        n_new = fw * n + iw
        h_new = jax.nn.sigmoid(zo) * c_new / jnp.maximum(n_new, 1e-6)
        return (h_new, c_new, n_new, m_new), h_new

    zeros = jnp.zeros((B, D), jnp.float32)
    carry = (zeros, zeros, zeros, jnp.full((B, D), -1e30, jnp.float32))
    wx_t = jnp.moveaxis(wx, 1, 0)                          # (S, B, 4D)
    if unroll and S <= 64:
        hs = []
        for t in range(S):
            carry, h = step(carry, wx_t[t])
            hs.append(h)
        h = jnp.stack(hs)
    else:
        _, h = jax.lax.scan(step, carry, wx_t)
    h = jnp.moveaxis(h, 0, 1).astype(x.dtype)              # (B, S, D)
    return h @ p["w_out"]


@dataclasses.dataclass
class SLSTMCache:
    h: jnp.ndarray
    c: jnp.ndarray
    n: jnp.ndarray
    m: jnp.ndarray


jax.tree_util.register_dataclass(SLSTMCache, data_fields=["h", "c", "n", "m"],
                                 meta_fields=[])


def slstm_cache_init(cfg: ModelConfig, batch: int) -> SLSTMCache:
    z = jnp.zeros((batch, cfg.d_model), jnp.float32)
    return SLSTMCache(h=z, c=z, n=z, m=jnp.full_like(z, -1e30))


def slstm_decode(p, x, cache: SLSTMCache, cfg: ModelConfig):
    B, D = x.shape
    H = cfg.n_heads
    dh = D // H
    wx = (x @ p["w"]).astype(jnp.float32)
    hr = cache.h.reshape(B, H, dh)
    rec = jnp.einsum("bhd,hde->bhe", hr, p["r"]).reshape(B, 4 * D)
    z = wx + rec + p["b"]
    zi, zf, zz, zo = jnp.split(z, 4, axis=-1)
    m_new = jnp.maximum(zf + cache.m, zi)
    iw = jnp.exp(zi - m_new)
    fw = jnp.exp(zf + cache.m - m_new)
    c_new = fw * cache.c + iw * jnp.tanh(zz)
    n_new = fw * cache.n + iw
    h_new = jax.nn.sigmoid(zo) * c_new / jnp.maximum(n_new, 1e-6)
    out = (h_new.astype(x.dtype)) @ p["w_out"]
    return out, SLSTMCache(h=h_new, c=c_new, n=n_new, m=m_new)
