import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest

from repro.data import build_dataset


@pytest.fixture(scope="session")
def workdir(tmp_path_factory):
    return str(tmp_path_factory.mktemp("agnes_store"))


@pytest.fixture(scope="session")
def tiny_ds(workdir):
    """Small power-law graph with on-disk block layout (shared)."""
    return build_dataset("tiny", workdir, dim=32, block_size=16384)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
