"""Striping saturation sweep over the storage topology (fig11-style).

The paper evaluates RAID0 arrays of 1-4 NVMe drives; the storage
topology subsystem (``repro.core.topology``) makes that explicit —
placement policies map blocks to independent arrays, coalesced runs
split at stripe boundaries into per-array requests, and fused plans pay
the ``max`` over per-array rooflines.  This benchmark sweeps
``n_arrays x io_queue_depth x max_coalesce_bytes x policy`` on the real
prepare path and locates the *saturation frontier*: the smallest
(queue depth, coalesce cap) at which each array count reaches ~all of
its achievable bandwidth.

Two acceptance gates (tracked in ``BENCH_stripe.json`` by
``run.py --quick``, guarded by ``benchmarks.check_regression``):

* striping a bandwidth-bound prepare across 4 arrays must model
  >= ``MIN_SPEEDUP`` (2x) over the single-array path, with byte-identical
  MFGs, features and bytes_read — placement reshapes requests, never
  what is read;
* on a skewed-degree (hub-heavy) workload over a *heterogeneous*
  topology (one Gen5-class array at 2x bandwidth / half latency beside
  a standard one), the degree-aware hotness policy must beat
  round-robin striping by >= ``MIN_POLICY_GAIN`` — it pins the hot
  feature region on the fastest/least-loaded array (Ginex-style) where
  striping spreads it uniformly and the slow array sets the roofline.
  The duel workload draws training targets proportional to degree
  (hub-heavy train sets, the common case for real labels), gathers
  wide rows (feature traffic dominates), and runs three epochs so the
  hot set is re-read — the regime the paper's §2 analysis puts Ginex
  in.  The duel geometry is fixed at container scale in both tiers:
  it is a policy A/B, not a scaling measurement.
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

from .common import (WORKDIR, emit, get_dataset, make_agnes, quick_val,
                     targets_for)

from repro.core import (AgnesConfig, AgnesEngine, FeatureBlockStore,
                        HotnessAwarePlacement, NVMeModel, StorageTopology,
                        StripePlacement, feature_block_hotness,
                        graph_block_hotness)
from repro.data.synth import make_features

MIN_SPEEDUP = 2.0       # 1 -> 4 arrays, bandwidth-bound workload
MIN_POLICY_GAIN = 1.08  # hotness vs round-robin stripe, skewed workload
SATURATION = 0.9        # fraction of best bandwidth that counts as saturated


def _measure(eng, targets):
    prepared = eng.prepare(targets, epoch=0)
    g, f = eng.graph_store.stats, eng.feature_store.stats
    t = g.modeled_read_time + f.modeled_read_time
    nbytes = g.bytes_read + f.bytes_read
    return prepared, {
        "modeled_prepare_io_s": t,
        "bytes_read": int(nbytes),
        "n_requests": int(g.n_requests + f.n_requests),
        "achieved_bw_GBps": round(nbytes / max(t, 1e-12) / 1e9, 3),
    }


def _assert_parity(p1, p0, tag):
    for a, b in zip(p1, p0):
        for x, y in zip(a.mfg.nodes, b.mfg.nodes):
            assert np.array_equal(x, y), f"{tag}: placement changed the MFGs"
        for lx, ly in zip(a.mfg.layers, b.mfg.layers):
            assert np.array_equal(lx.nbr_idx, ly.nbr_idx)
            assert np.array_equal(lx.self_idx, ly.self_idx)
        assert np.allclose(a.features, b.features), \
            f"{tag}: placement changed gathered features"


def run() -> dict:
    # bandwidth-bound geometry: dense block touch + large coalesce caps,
    # so bytes/bw dominates the roofline and striping's parallel arrays
    # are what is being measured
    n_nodes = quick_val(120_000, 6_000)
    block = quick_val(16384, 2048)
    mb = quick_val(64, 48)
    n_mb = 4
    ds = get_dataset("stripesweep", dim=32, block_size=block,
                     n_nodes=n_nodes, avg_degree=16)
    targets = targets_for(ds, n_mb=n_mb, mb_size=mb)
    kw = dict(block_size=block, fanouts=(4, 4), minibatch=mb,
              hyperbatch_size=n_mb, setting_bytes=32 << 20)

    # ---------------------------------------------------------- sweep
    sweep: list[dict] = []
    for n_arrays in (1, 2, 4):
        for qd in (1, 4, 16):
            for mcb in (block, 8 << 20):
                eng = make_agnes(ds, n_arrays=n_arrays, placement="stripe",
                                 io_queue_depth=qd, max_coalesce_bytes=mcb,
                                 **kw)
                _, m = _measure(eng, targets)
                row = {"n_arrays": n_arrays, "io_queue_depth": qd,
                       "max_coalesce_bytes": mcb, **m}
                if eng.topology is not None:
                    row["balance"] = \
                        eng.topology.utilization_summary()["balance"]
                sweep.append(row)
                eng.close()
    frontier: dict = {}
    for n_arrays in (1, 2, 4):
        rows = [r for r in sweep if r["n_arrays"] == n_arrays]
        best = max(r["achieved_bw_GBps"] for r in rows)
        sat = min((r for r in rows
                   if r["achieved_bw_GBps"] >= SATURATION * best),
                  key=lambda r: (r["io_queue_depth"],
                                 r["max_coalesce_bytes"]))
        frontier[f"arrays{n_arrays}"] = {
            "best_bw_GBps": best,
            "io_queue_depth": sat["io_queue_depth"],
            "max_coalesce_bytes": sat["max_coalesce_bytes"],
        }
        emit(f"stripe/arrays{n_arrays}/best_bw_GBps", best,
             f"saturates at qd={sat['io_queue_depth']} "
             f"mcb={sat['max_coalesce_bytes'] // 1024}K")

    # -------------------------------------------- acceptance: 1 -> 4 arrays
    base = make_agnes(ds, n_arrays=1, **kw)
    p0, before = _measure(base, targets)
    quad = make_agnes(ds, n_arrays=4, placement="stripe", **kw)
    p1, after = _measure(quad, targets)
    _assert_parity(p1, p0, "stripe4")
    assert after["bytes_read"] == before["bytes_read"], \
        (after["bytes_read"], before["bytes_read"])
    speedup = before["modeled_prepare_io_s"] / max(
        after["modeled_prepare_io_s"], 1e-12)
    # acceptance gate (deterministic: modeled device time of fixed plans)
    assert speedup >= MIN_SPEEDUP, \
        f"striping regression: {speedup:.2f}x < {MIN_SPEEDUP}x (1->4 arrays)"
    # staged plans expose how placement splits each submission
    plan_splits = [
        {"stage": p.stage, "blocks": p.n_blocks,
         "per_array": p.blocks_per_array.tolist()}
        for p in quad.last_session.plans
        if p.blocks_per_array is not None]
    emit("stripe/speedup_1_to_4", speedup,
         f"{before['modeled_prepare_io_s']*1e3:.2f}ms -> "
         f"{after['modeled_prepare_io_s']*1e3:.2f}ms "
         f"reqs {before['n_requests']}->{after['n_requests']}")
    base.close()
    quad.close()

    # ------------------------------------- policy duel: skewed workload,
    # heterogeneous 2-array topology (Gen5-class: 2x bandwidth, half
    # latency — beside one standard Gen4 array).  Fixed geometry in both
    # tiers: a deterministic policy A/B, not a scaling measurement.
    duel_nodes, duel_g_block, duel_f_block, duel_dim = 6_000, 16384, 2048, 256
    skew = get_dataset("stripeskew", dim=32, block_size=duel_g_block,
                       n_nodes=duel_nodes, avg_degree=30)  # rmat: hub-heavy
    fat_path = os.path.join(WORKDIR, "stripeskew_fat.feat")
    if not os.path.exists(fat_path + ".meta.json"):
        feats, _ = make_features(duel_nodes, duel_dim, seed=0)
        FeatureBlockStore.build(fat_path, feats, block_size=duel_f_block)
    # hub-heavy train set: target draw proportional to degree
    duel_mb = 48
    deg = np.diff(skew.indptr).astype(np.float64) + 1
    rng = np.random.default_rng(0)
    skew_targets = [rng.choice(duel_nodes, duel_mb, replace=False,
                               p=deg / deg.sum()) for _ in range(n_mb)]

    def duel_engine(policy_cls):
        fast = dataclasses.replace(NVMeModel(), bandwidth=2 * 6.7e9,
                                   latency=40e-6)
        topo = StorageTopology([fast, NVMeModel()])
        g, _ = skew.reopen_stores(NVMeModel())
        f = FeatureBlockStore.open(fat_path, NVMeModel())
        g.attach_topology(topo, policy_cls().place(
            g.n_blocks, topo, hotness=graph_block_hotness(g)))
        f.attach_topology(topo, policy_cls().place(
            f.n_blocks, topo,
            hotness=feature_block_hotness(f, g.approx_degrees())))
        cfg = AgnesConfig(block_size=duel_g_block, minibatch_size=duel_mb,
                          hyperbatch_size=n_mb, fanouts=(4, 4),
                          graph_buffer_bytes=8 << 20,
                          feature_buffer_bytes=2 << 20,
                          feature_cache_rows=1, async_io=False)
        return AgnesEngine(g, f, cfg)

    duel: dict = {}
    prepared_by_policy = {}
    for policy, mk in (
            ("stripe", lambda: StripePlacement(1)),
            # pin a large hot mass: the duel's train set is hub-heavy,
            # so most traffic is pinnable
            ("hotness", lambda: HotnessAwarePlacement(1, hot_mass=0.8))):
        eng = duel_engine(mk)
        for epoch in range(3):  # hot set re-read every epoch
            prepared = eng.prepare(skew_targets, epoch=epoch)
        g, f = eng.graph_store.stats, eng.feature_store.stats
        duel[policy] = {
            "modeled_prepare_io_s": g.modeled_read_time + f.modeled_read_time,
            "bytes_read": int(g.bytes_read + f.bytes_read),
            "n_requests": int(g.n_requests + f.n_requests),
            "balance": eng.topology.utilization_summary()["balance"],
        }
        prepared_by_policy[policy] = prepared
        eng.close()
    _assert_parity(prepared_by_policy["hotness"],
                   prepared_by_policy["stripe"], "policy_duel")
    assert duel["hotness"]["bytes_read"] == duel["stripe"]["bytes_read"]
    policy_speedup = duel["stripe"]["modeled_prepare_io_s"] / max(
        duel["hotness"]["modeled_prepare_io_s"], 1e-12)
    assert policy_speedup >= MIN_POLICY_GAIN, \
        (f"degree-aware placement regression: {policy_speedup:.2f}x < "
         f"{MIN_POLICY_GAIN}x vs round-robin on the skewed workload")
    emit("stripe/policy_duel_speedup", policy_speedup,
         f"hotness {duel['hotness']['modeled_prepare_io_s']*1e3:.2f}ms vs "
         f"stripe {duel['stripe']['modeled_prepare_io_s']*1e3:.2f}ms "
         f"(balance {duel['stripe']['balance']}->"
         f"{duel['hotness']['balance']})")

    return {
        "workload": {"n_nodes": ds.n_nodes, "block_size": block,
                     "graph_blocks": ds.graph_store.n_blocks,
                     "feature_blocks": ds.feature_store.n_blocks},
        "sweep": sweep,
        "frontier": frontier,
        "single_array": before,
        "striped4": after,
        "plan_splits": plan_splits,
        "speedup_1_to_4": round(speedup, 3),
        "policy_duel": {**duel, "speedup": round(policy_speedup, 3)},
    }


if __name__ == "__main__":
    print(run())
