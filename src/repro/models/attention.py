"""Attention: GQA + RoPE/M-RoPE + sliding window; chunked online-softmax
for prefill/train (memory-bounded at 32k), cache-based decode.

Three executable paths with identical semantics:
* ``full``    — materialized S×S (smoke tests / roofline-unrolled lowering)
* ``chunked`` — pure-jnp flash pattern (q-chunk outer, kv-chunk online
  softmax inner) used for real training shapes
* Pallas ``repro.kernels.flash_attention`` — the TPU hot path.

Decode attends over a (optionally ring-buffered, for SWA) KV cache; the
``long_500k`` shape shards the cache on the sequence axis — the softmax
over the sharded axis is expressed with log-sum-exp-safe ops that GSPMD
partitions into (all-reduce max, all-reduce sum), flash-decoding style.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import LayerSpec, ModelConfig
from .common import apply_mrope, apply_rope, dense_init, softcap

NEG_INF = -1e30


def attn_init(key, cfg: ModelConfig) -> dict:
    d, dh = cfg.d_model, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    return {
        "wq": dense_init(k1, (d, cfg.n_heads * dh), dtype=dt),
        "wk": dense_init(k2, (d, cfg.n_kv_heads * dh), dtype=dt),
        "wv": dense_init(k3, (d, cfg.n_kv_heads * dh), dtype=dt),
        "wo": dense_init(k4, (cfg.n_heads * dh, d), dtype=dt),
    }


def cross_attn_init(key, cfg: ModelConfig) -> dict:
    return attn_init(key, cfg)


# ------------------------------------------------------------- core math
def _scores_mask(q_pos, k_pos, causal: bool, window: int):
    m = jnp.ones(jnp.broadcast_shapes(q_pos.shape, k_pos.shape), bool)
    if causal:
        m &= k_pos <= q_pos
    if window > 0:
        m &= k_pos > q_pos - window
    return m


def full_attention(q, k, v, q_pos, k_pos, *, causal: bool, window: int,
                   scale: float, logit_cap: float = 0.0) -> jnp.ndarray:
    """q: (B, Hq, Sq, Dh), k/v: (B, Hkv, Sk, Dh)."""
    B, Hq, Sq, Dh = q.shape
    Hkv = k.shape[1]
    g = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, g, Sq, Dh)
    s = jnp.einsum("bhgsd,bhtd->bhgst", qf, k.astype(jnp.float32)) * scale
    s = softcap(s, logit_cap)
    mask = _scores_mask(q_pos[:, None], k_pos[None, :], causal, window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgst,bhtd->bhgsd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, Sq, Dh).astype(q.dtype)


def chunked_attention(q, k, v, q_pos, k_pos, *, causal: bool, window: int,
                      scale: float, q_chunk: int, kv_chunk: int,
                      logit_cap: float = 0.0,
                      unroll: bool = False) -> jnp.ndarray:
    """Online-softmax attention; memory O(q_chunk * kv_chunk) per head."""
    B, Hq, S, Dh = q.shape
    Hkv = k.shape[1]
    g = Hq // Hkv
    q_chunk = min(q_chunk, S)
    while S % q_chunk:          # chunks must tile the sequence exactly
        q_chunk //= 2
    Sk = k.shape[2]
    kv_chunk = min(kv_chunk, Sk)
    while Sk % kv_chunk:
        kv_chunk //= 2
    nq = S // q_chunk
    nk = Sk // kv_chunk

    qg = q.reshape(B, Hkv, g, S, Dh)   # cast to f32 per chunk, not upfront

    def one_q_chunk(qi):
        qs = qi * q_chunk
        qb = jax.lax.dynamic_slice_in_dim(qg, qs, q_chunk, axis=3)
        qb = qb.astype(jnp.float32)
        qp = jax.lax.dynamic_slice_in_dim(q_pos, qs, q_chunk, axis=0)

        def kv_step(carry, ki):
            m_prev, l_prev, acc = carry
            ks = ki * kv_chunk
            kb = jax.lax.dynamic_slice_in_dim(k, ks, kv_chunk, axis=2)
            vb = jax.lax.dynamic_slice_in_dim(v, ks, kv_chunk, axis=2)
            kp = jax.lax.dynamic_slice_in_dim(k_pos, ks, kv_chunk, axis=0)
            s = jnp.einsum("bhgsd,bhtd->bhgst", qb,
                           kb.astype(jnp.float32)) * scale
            s = softcap(s, logit_cap)
            mask = _scores_mask(qp[:, None], kp[None, :], causal, window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_cur = jnp.max(s, axis=-1, keepdims=True)
            m_new = jnp.maximum(m_prev, m_cur)
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(m_prev - m_new)
            l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
            pv = jnp.einsum("bhgst,bhtd->bhgsd", p, vb.astype(jnp.float32))
            acc = acc * alpha[..., 0][..., None] + pv
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, Hkv, g, q_chunk, 1), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, g, q_chunk, 1), jnp.float32)
        a0 = jnp.zeros((B, Hkv, g, q_chunk, Dh), jnp.float32)
        if unroll:
            carry = (m0, l0, a0)
            for ki in range(nk):
                carry, _ = kv_step(carry, ki)
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                          jnp.arange(nk))
        return (acc / jnp.maximum(l[..., 0][..., None], 1e-30))

    if unroll:
        blocks = [one_q_chunk(qi) for qi in range(nq)]
        out = jnp.concatenate(blocks, axis=3)
    else:
        # remat per q-chunk: backward recomputes the kv online-softmax scan
        # instead of saving per-step probability tiles (flash backward).
        out = jax.lax.map(jax.checkpoint(one_q_chunk), jnp.arange(nq))
        out = jnp.moveaxis(out, 0, 3).reshape(B, Hkv, g, nq * q_chunk, Dh)
    out = out[:, :, :, :S]
    return out.reshape(B, Hq, S, Dh).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, k_pos, q_pos, *, window: int,
                     scale: float, logit_cap: float = 0.0) -> jnp.ndarray:
    """One-token decode. q: (B, Hq, Dh); caches: (B, Hkv, Sc, Dh).

    ``k_pos``: (Sc,) absolute positions stored in each cache slot (ring
    buffers store out-of-order positions); invalid slots hold -1.
    Softmax over the (possibly seq-sharded) cache axis is the flash-
    decoding LSE pattern: max / sum reduce over that axis partition.
    """
    B, Hq, Dh = q.shape
    Hkv = k_cache.shape[1]
    g = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, g, Dh)
    s = jnp.einsum("bhgd,bhtd->bhgt", qf, k_cache.astype(jnp.float32)) * scale
    s = softcap(s, logit_cap)
    valid = (k_pos >= 0) & (k_pos <= q_pos)
    if window > 0:
        valid &= k_pos > q_pos - window
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgt,bhtd->bhgd", p, v_cache.astype(jnp.float32))
    o = o / jnp.maximum(l, 1e-30)
    return o.reshape(B, Hq, Dh).astype(q.dtype)


# --------------------------------------------------------------- module
def attention_apply(p: dict, x: jnp.ndarray, positions: jnp.ndarray,
                    cfg: ModelConfig, spec: LayerSpec, *,
                    impl: str = "chunked", unroll: bool = False,
                    kv_override: jnp.ndarray | None = None,
                    bidirectional: bool = False) -> jnp.ndarray:
    """Train/prefill path. x: (B, S, D); positions (B, S) or (3, B, S).

    ``kv_override`` switches to cross-attention (no RoPE, non-causal);
    ``bidirectional`` drops causality for encoder self-attention.
    """
    B, S, D = x.shape
    dh = cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, dh)
    kv_src = x if kv_override is None else kv_override
    Skv = kv_src.shape[1]
    k = (kv_src @ p["wk"]).reshape(B, Skv, cfg.n_kv_heads, dh)
    v = (kv_src @ p["wv"]).reshape(B, Skv, cfg.n_kv_heads, dh)
    cross = kv_override is not None
    if not cross:
        if cfg.mrope:
            q = apply_mrope(q, positions, cfg.rope_theta)
            k = apply_mrope(k, positions, cfg.rope_theta)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    pos_q = positions[0][0] if cfg.mrope else positions[0]
    q = jnp.swapaxes(q, 1, 2)   # (B, H, S, dh)
    k = jnp.swapaxes(k, 1, 2)
    v = jnp.swapaxes(v, 1, 2)
    scale = dh ** -0.5
    causal = not (cross or bidirectional)
    window = spec.window if not cross else 0
    pos_k = pos_q if not cross else jnp.arange(Skv)
    if impl == "full" or S <= 256:
        o = full_attention(q, k, v, pos_q, pos_k, causal=causal,
                           window=window, scale=scale,
                           logit_cap=cfg.attn_logit_softcap)
    else:
        o = chunked_attention(q, k, v, pos_q, pos_k, causal=causal,
                              window=window, scale=scale,
                              q_chunk=min(cfg.attn_chunk, 512),
                              kv_chunk=cfg.attn_chunk,
                              logit_cap=cfg.attn_logit_softcap,
                              unroll=unroll)
    o = jnp.swapaxes(o, 1, 2).reshape(B, S, cfg.n_heads * dh)
    return o @ p["wo"]


# ----------------------------------------------------------------- cache
@dataclasses.dataclass
class AttnCache:
    k: jnp.ndarray       # (B, Hkv, Sc, Dh)
    v: jnp.ndarray
    slot_pos: jnp.ndarray  # (Sc,) absolute position in each slot, -1 empty


jax.tree_util.register_dataclass(AttnCache,
                                 data_fields=["k", "v", "slot_pos"],
                                 meta_fields=[])


def attn_cache_init(cfg: ModelConfig, spec: LayerSpec, batch: int,
                    max_len: int, dtype) -> AttnCache:
    sc = min(spec.window, max_len) if spec.window > 0 else max_len
    return AttnCache(
        k=jnp.zeros((batch, cfg.n_kv_heads, sc, cfg.head_dim), dtype),
        v=jnp.zeros((batch, cfg.n_kv_heads, sc, cfg.head_dim), dtype),
        slot_pos=jnp.full((sc,), -1, jnp.int32))


def attention_decode(p: dict, x: jnp.ndarray, pos: jnp.ndarray,
                     cache: AttnCache, cfg: ModelConfig,
                     spec: LayerSpec) -> tuple[jnp.ndarray, AttnCache]:
    """One-token decode. x: (B, D); pos: scalar int32 absolute position."""
    B, D = x.shape
    dh = cfg.head_dim
    q = (x @ p["wq"]).reshape(B, 1, cfg.n_heads, dh)
    k = (x @ p["wk"]).reshape(B, 1, cfg.n_kv_heads, dh)
    v = (x @ p["wv"]).reshape(B, 1, cfg.n_kv_heads, dh)
    pos_b = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)
    if cfg.mrope:
        pos3 = jnp.stack([pos_b, pos_b, pos_b], axis=0)
        q = apply_mrope(q, pos3, cfg.rope_theta)
        k = apply_mrope(k, pos3, cfg.rope_theta)
    else:
        q = apply_rope(q, pos_b, cfg.rope_theta)
        k = apply_rope(k, pos_b, cfg.rope_theta)
    sc = cache.k.shape[2]
    slot = pos % sc   # ring slot (== pos while pos < sc for full caches)
    new_k = jax.lax.dynamic_update_slice_in_dim(
        cache.k, jnp.swapaxes(k, 1, 2), slot, axis=2)
    new_v = jax.lax.dynamic_update_slice_in_dim(
        cache.v, jnp.swapaxes(v, 1, 2), slot, axis=2)
    slot_pos = jax.lax.dynamic_update_slice_in_dim(
        cache.slot_pos, jnp.reshape(pos, (1,)).astype(jnp.int32), slot, axis=0)
    o = decode_attention(q.reshape(B, cfg.n_heads, dh),
                         new_k, new_v, slot_pos, pos,
                         window=spec.window, scale=dh ** -0.5,
                         logit_cap=cfg.attn_logit_softcap)
    out = o.reshape(B, cfg.n_heads * dh) @ p["wo"]
    return out, AttnCache(new_k, new_v, slot_pos)


def cross_attention_decode(p: dict, x: jnp.ndarray, memory_kv,
                           cfg: ModelConfig) -> jnp.ndarray:
    """Decoder cross-attention over precomputed encoder memory (k, v)."""
    B, D = x.shape
    dh = cfg.head_dim
    k, v = memory_kv
    q = (x @ p["wq"]).reshape(B, cfg.n_heads, dh)
    pos = jnp.asarray(k.shape[2], jnp.int32)
    slot_pos = jnp.arange(k.shape[2], dtype=jnp.int32)
    o = decode_attention(q, k, v, slot_pos, pos, window=0, scale=dh ** -0.5)
    return o.reshape(B, cfg.n_heads * dh) @ p["wo"]
