"""Storage fault domain under load: parity, hedging, degraded throughput.

The fault subsystem (``core/fault.py`` + the classified retry/hedge/
degrade paths in ``core/io_sched.py`` and the journal replay in
``core/block_store.py``) exists to keep storage-based training *correct*
and *fast enough* when the NVMe arrays misbehave.  This benchmark drives
the real engine through each failure regime and gates on both claims:

* **parity** — an adversarial seeded schedule (transient read errors +
  latency spikes + a mid-epoch whole-array dropout) against a fault-free
  twin: gathered features and MFGs must stay byte-identical every
  minibatch, through retries, hedges, degraded reads and the
  epoch-boundary evacuation.  Faults may cost time, never bytes;
* **hedging** — a latency-spike-only schedule with hedged duplicate
  reads on vs off (identical seeded spikes): capping stragglers at the
  p99-derived deadline plus a duplicate read must beat eating the full
  spike (``MIN_HEDGE_GAIN``);
* **degraded operation** — a 4-array engine that loses one array on its
  first read, keeps training through the survivors' recovery path and
  evacuates the stranded quarter at the epoch boundary, vs a fault-free
  3-array baseline doing the same work: total modeled I/O time within
  ``1/MIN_DEGRADED_THROUGHPUT``x (1.45x) of the baseline *with the
  recovery copy I/O fully charged*;
* **replay drill** — a kill window between the journal seal and the
  metadata commit rolls *forward* at recovery; an injected torn journal
  write rolls *back*; both land byte-identical.

Tracked in ``BENCH_faults.json`` and guarded by
``benchmarks.check_regression`` (degraded throughput floor + hedge
gain).  Fixed geometry in both tiers: a deterministic A/B at container
scale, not a scaling measurement.
"""
from __future__ import annotations

import os

import numpy as np

from .common import WORKDIR, emit, quick_val

from repro.core import (AgnesConfig, AgnesEngine, FaultInjector,
                        FeatureBlockStore, GraphBlockStore, NVMeModel,
                        StorageTopology, StripePlacement, TornWriteError,
                        recover_store_metadata)

MIN_DEGRADED_THROUGHPUT = 1 / 1.45   # 3-of-4 arrays vs fault-free 3-array
MIN_HEDGE_GAIN = 1.0                 # hedging must never lose to stalling

N_NODES = 4_096
RING_K = 8              # ring neighbors per side (degree 16, uniform)
G_BLOCK = 2048
F_DIM = 512             # 2 KiB rows -> one row per feature block
F_BLOCK = 2048
MB, N_MB = 64, 4        # minibatch geometry (256 nodes per hyperbatch)
BUDGET = 4 << 20        # migrate_budget_bytes (evacuation loops past it)

ADVERSARIAL = ("transient:p=0.05;latency:p=0.05,factor=25;"
               "dropout:array=3,at=200")
LATENCY_ONLY = "latency:p=0.3,factor=50"
DROPOUT_NOW = "dropout:array=3,at=0"


def _build_workload() -> tuple[str, str]:
    gpath = os.path.join(WORKDIR, "faults_ring.graph")
    fpath = os.path.join(WORKDIR, "faults_ring.feat")
    if not os.path.exists(gpath + ".meta.json"):
        offs = np.concatenate([np.arange(-RING_K, 0),
                               np.arange(1, RING_K + 1)])
        indices = ((np.arange(N_NODES)[:, None] + offs[None, :])
                   % N_NODES).astype(np.int64).ravel()
        indptr = (np.arange(N_NODES + 1, dtype=np.int64) * (2 * RING_K))
        GraphBlockStore.build(gpath, indptr, indices, block_size=G_BLOCK)
    if not os.path.exists(fpath + ".meta.json"):
        rng = np.random.default_rng(7)
        feats = rng.normal(0, 1, (N_NODES, F_DIM)).astype(np.float32)
        FeatureBlockStore.build(fpath, feats, block_size=F_BLOCK)
    return gpath, fpath


def _engine(gpath: str, fpath: str, n_arrays: int,
            schedule: str | None = None, hedge_frac: float = 1.5,
            retries: int = 6) -> AgnesEngine:
    g = GraphBlockStore.open(gpath, NVMeModel())
    f = FeatureBlockStore.open(fpath, NVMeModel())
    cfg = AgnesConfig(block_size=G_BLOCK, minibatch_size=MB,
                      hyperbatch_size=N_MB, fanouts=(RING_K,),
                      graph_buffer_bytes=64 << 10,
                      feature_buffer_bytes=128 << 10,
                      feature_cache_rows=1, async_io=False,
                      io_queue_depth=16, placement="stripe",
                      fault_schedule=schedule, io_retries=retries,
                      hedge_deadline_frac=hedge_frac,
                      migrate_budget_bytes=BUDGET)
    return AgnesEngine(g, f, cfg, topology=StorageTopology.uniform(n_arrays))


def _targets(hb: int) -> list[np.ndarray]:
    """Contiguous tiles marching over the ring's locality order — long
    sequential runs striped over every array, so each array sees steady
    traffic (the hedge deadline needs per-array service history)."""
    lo = (hb * N_MB * MB) % N_NODES
    return [(lo + np.arange(j * MB, (j + 1) * MB)) % N_NODES
            for j in range(N_MB)]


def _io_time(eng: AgnesEngine) -> float:
    g, f = eng.graph_store.stats, eng.feature_store.stats
    return (g.modeled_read_time + g.modeled_write_time
            + f.modeled_read_time + f.modeled_write_time)


def _assert_parity(p1, p0, tag):
    for a, b in zip(p1, p0):
        for x, y in zip(a.mfg.nodes, b.mfg.nodes):
            assert np.array_equal(x, y), f"{tag}: faults changed MFGs"
        for lx, ly in zip(a.mfg.layers, b.mfg.layers):
            assert np.array_equal(lx.nbr_idx, ly.nbr_idx)
            assert np.array_equal(lx.self_idx, ly.self_idx)
        assert np.array_equal(a.features, b.features), \
            f"{tag}: faults changed gathered features"


# ---------------------------------------------------------------- phases
def _phase_parity(gpath, fpath) -> dict:
    """Adversarial schedule vs fault-free twin: byte parity every
    minibatch, through retries, hedges, dropout and evacuation."""
    n_epochs = quick_val(3, 2)
    hb_per_epoch = quick_val(10, 8)
    clean = _engine(gpath, fpath, 4)
    faulty = _engine(gpath, fpath, 4, schedule=ADVERSARIAL)
    n_minibatches = 0
    for epoch in range(n_epochs):
        for hb in range(hb_per_epoch):
            targets = _targets(epoch * hb_per_epoch + hb)
            p0 = clean.prepare(targets, epoch=epoch)
            p1 = faulty.prepare(targets, epoch=epoch)
            _assert_parity(p1, p0, f"parity epoch{epoch}/hb{hb}")
            n_minibatches += len(targets)
        clean.end_epoch()
        faulty.end_epoch()              # evacuates once the array drops
    faults = faulty.io_stats()["faults"]
    fired = faults["injected"]["fired"]
    assert fired["transient"] > 0 and fired["latency"] > 0, \
        "adversarial schedule never fired — the parity gate tested nothing"
    assert fired["dropout"] == 1 and faults["io_degraded"] > 0
    assert faults["offline_arrays"] == [3]
    for store in (faulty.graph_store, faulty.feature_store):
        assert not np.any(store.placement.array_of == 3), \
            "blocks still stranded on the dropped array after evacuation"
    out = {
        "minibatches": n_minibatches,
        "io_errors": faults["io_errors"],
        "io_retries": faults["io_retries"],
        "io_degraded": faults["io_degraded"],
        "bytes_retried": faults["bytes_retried"],
        "bytes_degraded": faults["bytes_degraded"],
        "injected": faults["injected"],
        "byte_identical": True,
    }
    clean.close()
    faulty.close()
    emit("faults/parity_minibatches", n_minibatches,
         f"{faults['io_errors']} errors, {faults['io_retries']} retries, "
         f"{faults['io_degraded']} degraded reads — all byte-identical")
    return out


def _phase_hedge(gpath, fpath) -> dict:
    """Identical seeded latency spikes, hedging on vs off: the p99
    deadline + duplicate read must beat the fully exposed straggler."""
    n_hb = quick_val(24, 14)

    def run(frac):
        eng = _engine(gpath, fpath, 4, schedule=LATENCY_ONLY,
                      hedge_frac=frac)
        for hb in range(n_hb):
            eng.prepare(_targets(hb), epoch=0)
        t = _io_time(eng)
        faults = eng.io_stats()["faults"]
        eng.close()
        return t, faults

    hedged_t, hedged = run(1.5)
    exposed_t, exposed = run(0.0)       # hedging disabled
    assert hedged["io_hedges"] > 0, \
        "latency schedule produced no hedges — deadline never armed"
    assert exposed["io_hedges"] == 0
    # same seed + deterministic consumer order -> identical spike
    # sequence, so the ratio isolates the hedge policy
    assert hedged["injected"]["fired"] == exposed["injected"]["fired"]
    speedup = exposed_t / max(hedged_t, 1e-12)
    assert speedup >= MIN_HEDGE_GAIN, \
        (f"hedged reads regression: {speedup:.3f}x < {MIN_HEDGE_GAIN}x "
         f"vs exposed stragglers")
    emit("faults/hedge_speedup", speedup,
         f"{exposed_t*1e3:.2f}ms stalled -> {hedged_t*1e3:.2f}ms hedged, "
         f"{hedged['io_hedges']} hedges")
    return {"speedup": round(speedup, 3),
            "hedged_io_s": round(hedged_t, 6),
            "exposed_io_s": round(exposed_t, 6),
            "io_hedges": hedged["io_hedges"],
            "bytes_hedged": hedged["bytes_hedged"]}


def _phase_degraded(gpath, fpath) -> dict:
    """3-of-4 arrays (dropout on first read + evacuation) vs a
    fault-free 3-array baseline on the same work: the survivors'
    roofline, with all recovery I/O charged."""
    n_epochs = quick_val(6, 4)
    hb_per_epoch = quick_val(16, 10)
    base3 = _engine(gpath, fpath, 3)
    deg4 = _engine(gpath, fpath, 4, schedule=DROPOUT_NOW)
    recovery = None
    for epoch in range(n_epochs):
        for hb in range(hb_per_epoch):
            targets = _targets(epoch * hb_per_epoch + hb)
            p0 = base3.prepare(targets, epoch=epoch)
            p1 = deg4.prepare(targets, epoch=epoch)
            _assert_parity(p1, p0, f"degraded epoch{epoch}/hb{hb}")
        base3.end_epoch()
        rep = deg4.end_epoch()
        if rep and "recovery" in rep and recovery is None:
            recovery = rep["recovery"]
    assert recovery is not None, "dropout never triggered evacuation"
    for store in (deg4.graph_store, deg4.feature_store):
        assert not np.any(store.placement.array_of == 3)
    base_t, deg_t = _io_time(base3), _io_time(deg4)
    frac = base_t / max(deg_t, 1e-12)
    # acceptance gate: degraded 3-of-4 within 1/MIN_DEGRADED_THROUGHPUT
    # (1.45x) of the fault-free 3-array roofline, recovery I/O included
    assert frac >= MIN_DEGRADED_THROUGHPUT, \
        (f"degraded throughput regression: {frac:.3f} < "
         f"{MIN_DEGRADED_THROUGHPUT:.3f} of the 3-array baseline "
         f"({base_t*1e3:.2f}ms vs {deg_t*1e3:.2f}ms)")
    evac_bytes = sum(r["bytes_moved"] for r in recovery.values())
    emit("faults/degraded_throughput_frac", frac,
         f"3-of-4 arrays {deg_t*1e3:.2f}ms vs 3-array baseline "
         f"{base_t*1e3:.2f}ms, {evac_bytes >> 10} KiB evacuated")
    out = {"throughput_frac": round(frac, 4),
           "baseline3_io_s": round(base_t, 6),
           "degraded4_io_s": round(deg_t, 6),
           "evacuated_bytes": evac_bytes,
           "recovery": recovery}
    base3.close()
    deg4.close()
    return out


def _phase_replay() -> dict:
    """Kill-window + torn-write recovery drill on a dedicated store."""
    path = os.path.join(WORKDIR, "faults_replay.feat")
    if not os.path.exists(path + ".meta.json"):
        rng = np.random.default_rng(13)
        FeatureBlockStore.build(
            path, rng.normal(0, 1, (256, 64)).astype(np.float32),
            block_size=2048)
    topo = StorageTopology.uniform(2)
    f = FeatureBlockStore.open(path, NVMeModel())
    f.attach_topology(topo, StripePlacement(1).place(f.n_blocks, topo),
                      persist=True)
    before = np.array(f.placement.array_of)
    snapshot = [f.read_block_bytes(b) for b in range(f.n_blocks)]
    victims = np.nonzero(before == 1)[0][:4].tolist()

    def kill(point):                    # between journal seal and commit
        if point == "copied":
            raise RuntimeError("injected kill")

    try:
        f.migrate_blocks([(b, 0) for b in victims], _fault=kill)
        raise AssertionError("kill hook never fired")
    except RuntimeError:
        pass
    actions = recover_store_metadata(path)
    f2 = FeatureBlockStore.open(path, NVMeModel())
    pl = f2.load_placement(topo)
    forward = (actions.get(".migrate.log") == "rolled_forward"
               and all(pl.array_of[b] == 0 for b in victims))
    byte_ok = all(f2.read_block_bytes(b) == snapshot[b]
                  for b in range(f2.n_blocks))
    # torn journal write: the injector truncates the sealed journal on
    # disk mid-record, so recovery must refuse to roll forward
    f2.attach_topology(topo, pl, persist=True)
    f2.attach_fault(FaultInjector.parse("torn:at=0", seed=3))
    before2 = np.array(pl.array_of)
    victim2 = int(np.nonzero(before2 == 1)[0][0])
    torn_raised = False
    try:
        f2.migrate_blocks([(victim2, 0)])
    except TornWriteError:
        torn_raised = True
    actions2 = recover_store_metadata(path)
    f3 = FeatureBlockStore.open(path, NVMeModel())
    back = (torn_raised
            and actions2.get(".migrate.log") == "rolled_back"
            and np.array_equal(f3.load_placement(topo).array_of, before2))
    byte_ok = byte_ok and all(f3.read_block_bytes(b) == snapshot[b]
                              for b in range(f3.n_blocks))
    assert forward, "sealed journal did not roll forward at recovery"
    assert back, "torn journal did not roll back at recovery"
    assert byte_ok, "replay drill tore block bytes"
    emit("faults/replay_drill", 1.0,
         "sealed journal rolled forward, torn journal rolled back, "
         "bytes identical")
    return {"rolled_forward": True, "torn_rolled_back": True,
            "byte_identical": True}


def run() -> dict:
    gpath, fpath = _build_workload()
    parity = _phase_parity(gpath, fpath)
    hedge = _phase_hedge(gpath, fpath)
    degraded = _phase_degraded(gpath, fpath)
    replay = _phase_replay()
    return {
        "workload": {"n_nodes": N_NODES, "graph_block": G_BLOCK,
                     "feature_block": F_BLOCK, "dim": F_DIM},
        "parity": parity,
        "hedge": hedge,
        "degraded": degraded,
        "replay": replay,
    }


if __name__ == "__main__":
    print(run())
