from .models import (AGG_BACKENDS, GNN_ARCHS, init_gnn, gnn_apply, pad_mfg,
                     PaddedMFG)
from .pipeline import OverlapReport, PipelinedExecutor
from .training import GNNTrainer, gnn_loss

__all__ = ["AGG_BACKENDS", "GNN_ARCHS", "init_gnn", "gnn_apply", "pad_mfg",
           "PaddedMFG", "GNNTrainer", "gnn_loss", "OverlapReport",
           "PipelinedExecutor"]
