"""In-memory layer (paper §3.2): block buffers with LRU + pinning.

The graph buffer and feature buffer hold loaded blocks in bounded main
memory.  The buffer index tables ``T_buf^g`` / ``T_buf^f`` (paper Table 1)
are the ``_table`` dicts mapping block-id → buffered block.  Eviction is
LRU with *pinning* (paper §3.4(1)): blocks being processed by the current
hyperbatch iteration are pinned and cannot be evicted until unpinned.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Callable

from .device_model import IOStats


class BlockBuffer:
    """Bounded block buffer: LRU eviction, pin/unpin, hit accounting."""

    def __init__(self, capacity_blocks: int, stats: IOStats | None = None,
                 name: str = "buffer"):
        if capacity_blocks < 1:
            raise ValueError("buffer needs capacity >= 1")
        self.capacity = capacity_blocks
        self.name = name
        self.stats = stats if stats is not None else IOStats()
        self._table: OrderedDict[int, Any] = OrderedDict()  # T_buf
        self._pins: dict[int, int] = {}
        self.evictions = 0

    def __contains__(self, block_id: int) -> bool:
        return block_id in self._table

    def __len__(self) -> int:
        return len(self._table)

    def absent(self, block_ids) -> list[int]:
        """Filter a planned visit order down to non-resident blocks.

        Prefetch/scheduler plans are filtered through this at submit time
        so every planned block is consumed exactly once (no read-ahead
        slot leak, and bytes stay identical to the unplanned path).
        """
        return [int(b) for b in block_ids if int(b) not in self._table]

    def get(self, block_id: int, loader: Callable[[int], Any],
            pin: bool = False) -> Any:
        """Return the block, loading through ``loader`` on a miss."""
        if block_id in self._table:
            self._table.move_to_end(block_id)
            self.stats.buffer_hits += 1
            blk = self._table[block_id]
        else:
            self.stats.buffer_misses += 1
            blk = loader(block_id)
            self._insert(block_id, blk)
        if pin:
            self.pin(block_id)
        return blk

    def peek(self, block_id: int) -> Any:
        return self._table.get(block_id)

    def put(self, block_id: int, blk: Any) -> None:
        """Insert without counting a hit/miss (prefetch path)."""
        if block_id in self._table:
            self._table.move_to_end(block_id)
            self._table[block_id] = blk
        else:
            self._insert(block_id, blk)

    def pin(self, block_id: int) -> None:
        if block_id not in self._table:
            raise KeyError(f"{self.name}: cannot pin absent block {block_id}")
        self._pins[block_id] = self._pins.get(block_id, 0) + 1

    def unpin(self, block_id: int) -> None:
        c = self._pins.get(block_id, 0)
        if c <= 1:
            self._pins.pop(block_id, None)
        else:
            self._pins[block_id] = c - 1

    def unpin_all(self) -> None:
        self._pins.clear()

    def pinned(self, block_id: int) -> bool:
        return self._pins.get(block_id, 0) > 0

    def _insert(self, block_id: int, blk: Any) -> None:
        while len(self._table) >= self.capacity:
            victim = self._evict_one()
            if victim is None:
                break  # everything pinned: allow temporary overflow
        self._table[block_id] = blk

    def _evict_one(self) -> int | None:
        for bid in self._table:  # OrderedDict: LRU-first
            if not self.pinned(bid):
                del self._table[bid]
                self.evictions += 1
                return bid
        return None

    def clear(self) -> None:
        self._table.clear()
        self._pins.clear()
