"""LM training driver: config → mesh → sharded params → train loop with
checkpointing, fault-monitor heartbeats, and the block-I/O token pipeline.

Runs at container scale with ``--smoke`` (reduced config, debug mesh) and
at production scale on a real TPU fleet with the same code path.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
      --steps 20
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..compat import set_mesh
from ..configs import get_config, smoke_reduce
from ..data.tokens import TokenBlockStore, TokenPipeline
from ..distributed.checkpoint import CheckpointManager
from ..distributed.fault import FaultMonitor
from ..distributed.sharding import (batch_sharding, opt_state_shardings,
                                    param_shardings)
from ..models import build_model
from ..train.loop import make_train_step
from ..train.optimizer import adamw_init, cosine_schedule
from .mesh import make_debug_mesh, make_production_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + debug mesh (container scale)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--data", default="/tmp/repro_tokens.bin")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_reduce(cfg)
    model = build_model(cfg)
    mesh = make_debug_mesh() if args.smoke else make_production_mesh()

    key = jax.random.PRNGKey(0)
    with set_mesh(mesh):
        pshard = param_shardings(model.param_specs(), mesh)
        params = jax.jit(model.init, out_shardings=pshard)(key)
        oshard = opt_state_shardings(jax.eval_shape(adamw_init, params), mesh)
        opt_state = jax.jit(adamw_init, out_shardings=oshard)(params)

        ckpt = CheckpointManager(os.path.join(args.ckpt_dir, cfg.name))
        start_step = 0
        if args.resume and ckpt.latest_step() is not None:
            state = ckpt.restore({"params": params, "opt": opt_state},
                                 shardings={"params": pshard, "opt": oshard})
            params, opt_state = state["params"], state["opt"]
            start_step = ckpt.latest_step()
            print(f"[train] resumed from step {start_step}")

        sched = cosine_schedule(args.lr, warmup_steps=20,
                                total_steps=args.steps)
        step_fn = jax.jit(
            make_train_step(model, n_microbatches=args.n_micro, lr=sched),
            donate_argnums=(0, 1))

        store = TokenBlockStore.synthesize(
            args.data, vocab=cfg.vocab,
            n_tokens=max(args.batch * args.seq * 64, 1 << 20),
            block_tokens=1 << 18)
        pipe = TokenPipeline(store, batch=args.batch, seq_len=args.seq,
                             n_micro=args.n_micro)
        monitor = FaultMonitor(n_hosts=jax.process_count())

        t_start = time.time()
        for step in range(start_step, args.steps):
            t0 = time.time()
            batch_np = next(pipe)
            batch = {"tokens": jnp.asarray(batch_np)}
            if cfg.n_enc_layers:
                batch["src_embeds"] = jnp.zeros(
                    (args.n_micro, args.batch // args.n_micro,
                     min(args.seq, cfg.enc_seq), cfg.d_model), jnp.bfloat16)
            if cfg.frontend == "vision_stub":
                batch["prefix_embeds"] = jnp.zeros(
                    (args.n_micro, args.batch // args.n_micro, 8,
                     cfg.d_model), jnp.bfloat16)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            dt = time.time() - t0
            monitor.heartbeat(jax.process_index(), dt)
            if step % 5 == 0 or step == args.steps - 1:
                print(f"[train] step {step} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} {dt:.2f}s", flush=True)
            if (step + 1) % args.ckpt_every == 0 or step == args.steps - 1:
                ckpt.save(step + 1, {"params": params, "opt": opt_state})
        ckpt.wait()
        pipe.close()
        tokens_per_step = args.batch * args.seq
        total = (args.steps - start_step) * tokens_per_step
        print(f"[train] done: {total} tokens in {time.time()-t_start:.1f}s; "
              f"checkpoints at {ckpt.directory}; "
              f"data-pipeline I/O: {store.stats.summary()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
