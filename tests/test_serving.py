"""Serving tier (core/serving.py): QoS admission + concurrent tenants.

Covers:

* controller mechanics, deterministically via ``try_acquire`` — strict
  priority with demand posted, the token-bucket minimum-share refill,
  the aging bound (no starvation under sustained high-priority load),
  and the fifo-vs-priority queueing-delay model;
* the ``io_fetch_timeout_s`` config knob and the QoS-derived per-tenant
  fetch deadline (satellite: the old hardcoded ``fetch(timeout=30.0)``);
* per-tenant byte parity under concurrency — admission reorders issue
  order, never bytes — and a concurrent overlapping-submission hammer
  asserting each tenant's reader dedup stays exact;
* per-tenant fault isolation: a ``PermanentIOError`` on the training
  tenant's runs does not poison the inference tenant's fetch path, and
  pipeline producer errors carry the failing tenant's label;
* the mid-epoch migration drill: blocked while any tenant has queued
  work, runs in slack, and rebuilds the oracle schedule from the
  remaining trace (primed so resident rows keep true priorities);
* ``InferenceServer.embed``: shape, input-order row mapping, and
  fixed-epoch determinism.
"""
import dataclasses
import threading

import numpy as np
import pytest

from repro.core import (DEFAULT_QOS, AdmissionController, AgnesConfig,
                        AgnesEngine, CoalescedReader, InferenceServer,
                        NVMeModel, PermanentIOError, QoSClass, ServingTier,
                        trace_from_plan)


# ---------------------------------------------------------------- harness
def engine_for(ds, **over):
    kw = dict(block_size=16384, minibatch_size=64,
              hyperbatch_size=4, fanouts=(), feature_cache_rows=1,
              graph_buffer_bytes=1 << 20,
              feature_buffer_bytes=1 << 20, async_io=False,
              n_arrays=2, placement="stripe",
              max_coalesce_bytes=64 << 10, io_queue_depth=4)
    kw.update(over)
    return AgnesEngine(*ds.reopen_stores(NVMeModel()), AgnesConfig(**kw))


def controller(policy="priority", **tenants):
    c = AdmissionController([NVMeModel(), NVMeModel()], policy=policy)
    for name, qos in tenants.items():
        c.register(name, qos)
    return c


URGENT = QoSClass("urgent", priority=0, share=0.25, burst_bytes=1 << 20,
                  aging_grants=1000, aging_wait_s=100.0)


# ---------------------------------------------------------------- controller
def test_priority_blocks_bulk_only_under_demand():
    bulk = QoSClass("bulk", priority=5, share=0.0, burst_bytes=1000,
                    aging_grants=1000, aging_wait_s=100.0)
    c = controller(urgent=URGENT, bulk=bulk)
    # work-conserving: no urgent demand -> bulk admitted immediately,
    # even for a request far past its byte budget
    assert c.try_acquire("bulk", 0, 50_000)
    # urgent demand posted -> bulk is credit-gated (share=0 and the
    # bucket already drained 50k past its 1000-byte burst)
    c.note_submit("urgent", {0: (10, 100_000)})
    assert not c.try_acquire("bulk", 0, 50_000)
    # urgent itself is never blocked by lower-priority demand
    assert c.try_acquire("urgent", 0, 10_000)
    # a different array with no urgent backlog is open to bulk... but
    # demand is per-array: urgent only queued on array 0
    assert c.try_acquire("bulk", 1, 50_000)


def test_min_share_credit_refill():
    bulk = QoSClass("bulk", priority=5, share=0.5, burst_bytes=1000,
                    aging_grants=1000, aging_wait_s=100.0)
    c = controller(urgent=URGENT, bulk=bulk)
    c.note_submit("urgent", {0: (100, 1 << 20)})
    c.note_submit("bulk", {0: (10, 6000)})      # bulk has demand too
    assert c.try_acquire("bulk", 0, 600)        # full bucket: 1000 >= 600
    assert not c.try_acquire("bulk", 0, 600)    # drained: 400 < 600
    # every urgent grant refills bulk at share=0.5 -> one 1000-byte
    # urgent grant credits 500, lifting bulk back over its request
    assert c.try_acquire("urgent", 0, 1000)
    assert c.try_acquire("bulk", 0, 600)


def test_aging_bounds_starvation():
    bulk = QoSClass("bulk", priority=5, share=0.0, burst_bytes=0,
                    aging_grants=5, aging_wait_s=100.0)
    c = controller(urgent=URGENT, bulk=bulk)
    c.note_submit("urgent", {0: (10_000, 1 << 30)})
    c.note_submit("bulk", {0: (1, 4096)})
    rng = np.random.default_rng(7)
    max_gap, gap = 0, 0
    for _ in range(200):
        if c.try_acquire("bulk", 0, 4096):
            max_gap, gap = max(max_gap, gap), 0
            c.complete("bulk", 0, 4096)
            c.note_submit("bulk", {0: (1, 4096)})
        else:
            gap += 1
        for _ in range(int(rng.integers(1, 3))):   # sustained urgent load
            assert c.try_acquire("urgent", 0, int(rng.integers(1, 1 << 16)))
    # share=0 means *only* aging admits bulk: the gap between grants is
    # bounded by the aging_grants skip budget, never unbounded
    assert max_gap <= bulk.aging_grants + 1
    st = c.summary()["tenants"]["bulk"]
    assert st["forced_grants"] >= 1


def test_queueing_delay_fifo_vs_priority():
    for policy in ("priority", "fifo"):
        c = controller(policy=policy, urgent=URGENT,
                       bulk=QoSClass("bulk", priority=5))
        assert c.queueing_delay_s("urgent") == 0.0   # empty queues
        c.note_submit("bulk", {0: (64, 64 << 20)})
        d = c.queueing_delay_s("urgent")
        if policy == "priority":
            assert d == 0.0       # bulk backlog never delays urgent
        else:
            assert d > 0.0        # uncoordinated: urgent queues behind it
        # a tenant always queues behind its own backlog
        assert c.queueing_delay_s("bulk") > 0.0


def test_exclusive_gate_requires_slack():
    c = controller(urgent=URGENT)
    assert c.queue_slack()
    assert c.try_exclusive("migration")
    assert not c.try_exclusive("migration")   # held
    c.end_exclusive()
    c.note_submit("urgent", {0: (1, 4096)})
    assert not c.queue_slack()
    assert not c.try_exclusive("migration")   # queued work -> no slack
    c.cancel_pending("urgent")
    assert c.try_exclusive("migration")
    c.end_exclusive()


# ---------------------------------------------------------------- timeouts
def test_fetch_timeout_config_knob_and_qos_override(tiny_ds):
    eng = engine_for(tiny_ds, io_fetch_timeout_s=0.125)
    assert eng._g_prefetch.fetch_timeout_s == 0.125
    assert eng._f_prefetch.fetch_timeout_s == 0.125
    tier = ServingTier(eng)
    # enrollment installs the tenant's QoS-derived deadline
    assert eng._f_prefetch.fetch_timeout_s == \
        DEFAULT_QOS["training"].fetch_timeout_s
    inf = tier.open_tenant("inference")
    assert inf._f_prefetch.fetch_timeout_s == \
        DEFAULT_QOS["inference"].fetch_timeout_s
    tier.close()
    eng.close()


# ---------------------------------------------------------------- parity
def test_per_tenant_byte_parity_vs_solo(tiny_ds):
    train_mbs = [[np.arange(i * 64, i * 64 + 64) for i in range(4)],
                 [np.arange(256 + i * 64, 320 + i * 64) for i in range(4)]]
    infer_mbs = [[np.array([3, 999, 400])], [np.array([7, 7, 1200])],
                 [np.array([1999, 5])]]

    def solo_bytes(mbs_list, **over):
        eng = engine_for(tiny_ds, **over)
        for i, mbs in enumerate(mbs_list):
            eng.prepare(mbs, epoch=i)
        b = (eng.graph_store.stats.bytes_read
             + eng.feature_store.stats.bytes_read)
        eng.close()
        return b

    solo_train = solo_bytes(train_mbs)
    solo_infer = solo_bytes(infer_mbs)

    eng = engine_for(tiny_ds)
    tier = ServingTier(eng)
    tier.open_tenant("inference")
    feats: dict[str, list] = {"training": [], "inference": []}
    errs: list[BaseException] = []

    def drive(tenant, mbs_list):
        try:
            for i, mbs in enumerate(mbs_list):
                served = tier.prepare(tenant, mbs, epoch=i)
                feats[tenant].append([p.features for p in served.prepared])
        except BaseException as e:  # surfaced below
            errs.append(e)

    ts = [threading.Thread(target=drive, args=("training", train_mbs)),
          threading.Thread(target=drive, args=("inference", infer_mbs))]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=60)
    assert not errs, errs
    duel = {}
    for name in ("training", "inference"):
        e = tier.engine_of(name)
        duel[name] = (e.graph_store.stats.bytes_read
                      + e.feature_store.stats.bytes_read)
    # admission reorders *when* runs issue, never what is read
    assert duel["training"] == solo_train
    assert duel["inference"] == solo_infer
    # and the served features are the solo features
    eng2 = engine_for(tiny_ds)
    for i, mbs in enumerate(infer_mbs):
        ref = eng2.prepare(mbs, epoch=i)
        for a, b in zip(feats["inference"][i], ref):
            assert np.array_equal(a, b.features)
    eng2.close()
    tier.close()
    eng.close()


def test_overlapping_submission_dedup_hammer(tiny_ds):
    _, f_ref = tiny_ds.reopen_stores(NVMeModel())
    c = controller(a=dataclasses.replace(URGENT, name="a"),
                   b=QoSClass("b", priority=1, aging_wait_s=0.05))
    ids_a = np.arange(0, 10)
    ids_b = np.arange(5, 16)          # overlaps ids_a on [5, 10)
    union = np.union1d(ids_a, ids_b)
    results, errs = {}, []

    def tenant(name):
        try:
            _, f = tiny_ds.reopen_stores(NVMeModel())
            rd = CoalescedReader(f, max_coalesce_bytes=64 << 10,
                                 queue_depth=2, workers=2)
            rd.bind_admission(c, name)
            rd.submit(ids_a)
            rd.submit(ids_b)          # overlap dropped by the reader
            got = {int(b): rd.fetch(int(b), timeout=30.0) for b in union}
            assert rd.idle
            results[name] = (got, f.stats.n_reads)
            rd.close()
        except BaseException as e:
            errs.append(e)

    ts = [threading.Thread(target=tenant, args=(n,)) for n in ("a", "b")]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=60)
    assert not errs, errs
    for name in ("a", "b"):
        got, n_reads = results[name]
        # in-flight dedup exact per tenant: the overlap region is read
        # once, so block reads == |A ∪ B| despite the double submission
        assert n_reads == len(union)
        for b in union:
            ref = f_ref.read_run(int(b), 1)[0]
            assert np.array_equal(got[int(b)], ref)


# ---------------------------------------------------------------- faults
def test_permanent_fault_stays_in_its_tenant(tiny_ds):
    eng = engine_for(tiny_ds, fault_schedule="transient:p=1.0",
                     io_retries=0)
    tier = ServingTier(eng)
    inf = tier.open_tenant("inference")   # clean fault domain by default
    assert inf.fault_injector is None
    with pytest.raises(PermanentIOError):
        tier.prepare("training", [np.arange(64)], epoch=0)
    # the failed tenant's error stash must not leak into this tenant
    served = tier.prepare("inference", [np.array([1, 5, 9])], epoch=0)
    assert served.prepared[0].features.shape[0] == 3
    # and the training tenant keeps failing independently
    with pytest.raises(PermanentIOError):
        tier.prepare("training", [np.arange(64)], epoch=1)
    tier.close()
    eng.close()


def test_pipeline_error_carries_tenant_label(tiny_ds):
    from repro.gnn.pipeline import PipelinedExecutor

    class Boom:
        def train_minibatch(self, p):
            return 0.0

    eng = engine_for(tiny_ds, fault_schedule="transient:p=1.0",
                     io_retries=0)
    ex = PipelinedExecutor(eng, Boom(), tenant="training")
    with pytest.raises(PermanentIOError) as ei:
        ex.run_epoch(np.arange(256), epoch=0, shuffle=False)
    assert getattr(ei.value, "tenant", None) == "training"
    ex.close()
    eng.close()


# ---------------------------------------------------------------- migration
def test_mid_epoch_migration_slack_gate_and_oracle_refresh(tiny_ds):
    eng = engine_for(tiny_ds, online_placement=True,
                     migrate_budget_bytes=8 << 20,
                     cache_policy="oracle", feature_cache_rows=64)
    tier = ServingTier(eng)
    plan = [[np.arange(i * 64, i * 64 + 64)] for i in range(6)]
    trace = trace_from_plan(plan)          # exact for 0-hop workloads
    eng.install_cache_oracle(trace)
    n_total = eng.feature_cache.oracle.n_steps

    # queued foreign work -> no slack -> migration must refuse to run
    tier.controller.note_submit("training", {0: (4, 8192)})
    assert tier.maybe_migrate() is None
    assert tier.migrations_blocked == 1
    tier.controller.cancel_pending("training")

    consumed = 3
    for i in range(consumed):              # burn part of the schedule
        tier.prepare("training", plan[i], epoch=0)
    rep = tier.maybe_migrate()             # slack now: the pass runs
    assert rep is not None and tier.migrations_run == 1
    fresh = eng.feature_cache.oracle
    assert fresh.n_steps == n_total - consumed
    assert rep["oracle_refresh_steps"]["training"] == n_total - consumed
    # primed next_use: the remaining trace's nodes carry true first-use
    # steps, not NEVER (which would mass-evict residents pre-advance)
    nxt = fresh.next_use_of(np.unique(np.concatenate(trace[consumed:])))
    assert (nxt < np.iinfo(np.int64).max).all()

    # post-refresh prepares stay byte-correct vs an untouched twin
    twin = engine_for(tiny_ds)
    for i in range(consumed, len(plan)):
        a = tier.prepare("training", plan[i], epoch=0).prepared
        b = twin.prepare(plan[i], epoch=0)
        for x, y in zip(a, b):
            assert np.array_equal(x.features, y.features)
    twin.close()
    tier.close()
    eng.close()


# ---------------------------------------------------------------- inference
def test_inference_server_embed_mapping_and_determinism(tiny_ds):
    eng = engine_for(tiny_ds, fanouts=(3, 3))
    tier = ServingTier(eng)
    labels = np.zeros(eng.graph_store.n_nodes, dtype=np.int32)
    from repro.gnn import GNNTrainer
    tr = GNNTrainer(arch="gcn", in_dim=32, hidden=8, n_classes=4,
                    n_layers=2, seed=0, backend="jnp")
    tr.labels = labels
    srv = InferenceServer(tier, tr)
    e1 = srv.embed([11, 3, 400], epoch=5)
    e2 = srv.embed([3, 400, 11], epoch=5)
    assert e1.shape == (3, 4)
    # input-order row mapping: same nodes, permuted request order
    assert np.allclose(e1[0], e2[2])
    assert np.allclose(e1[1], e2[0])
    assert np.allclose(e1[2], e2[1])
    # fixed epoch -> identical sampling -> identical embeddings
    assert np.allclose(e1, srv.embed([11, 3, 400], epoch=5))
    assert srv.latency_summary()["n"] == 3
    tier.close()
    eng.close()
