"""Distributed checkpointing: sharded, async, double-buffered.

Design for 1000+-node fleets (DESIGN.md §5):

* **Sharded**: every host writes only the shards it owns (here: the
  single-process stand-in writes per-shard files keyed by shard index, so
  the on-disk layout is already the multi-host one).
* **Async**: ``save()`` snapshots the device arrays to host memory
  (cheap, device→host DMA) and hands serialization to a background
  thread — the training loop never blocks on the filesystem.
* **Double-buffered**: checkpoints alternate between two directories
  (``step_<N>`` kept, previous kept until the new one commits via an
  atomic ``COMMIT`` marker) — a node failure mid-write never corrupts
  the restore point.
* **Self-describing**: a manifest records the pytree structure, shapes,
  dtypes and PartitionSpecs, so restore works on a *different* mesh
  shape (elastic restart after losing a pod: shards are re-cut on load).
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading

import jax
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "name", getattr(k, "key", getattr(k, "idx", k))))
                      for k in path) for path, _ in flat]
    return names, [leaf for _, leaf in flat], treedef


class CheckpointManager:
    """Async double-buffered checkpoint writer/reader."""

    def __init__(self, directory: str, keep: int = 2):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._q: queue.Queue = queue.Queue()
        self._error: Exception | None = None
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()

    # ------------------------------------------------------------- save
    def save(self, step: int, tree, *, blocking: bool = False) -> None:
        """Snapshot to host memory, then serialize asynchronously."""
        if self._error:
            raise self._error
        names, leaves, _ = _flatten_with_names(tree)
        # device -> host snapshot (this is the only synchronous cost)
        host_leaves = [np.asarray(x) for x in leaves]
        self._q.put((step, names, host_leaves))
        if blocking:
            self.wait()

    def wait(self) -> None:
        self._q.join()
        if self._error:
            raise self._error

    def _run(self) -> None:
        while True:
            step, names, leaves = self._q.get()
            try:
                self._write(step, names, leaves)
            except Exception as e:  # noqa: BLE001
                self._error = e
            finally:
                self._q.task_done()

    def _write(self, step: int, names, leaves) -> None:
        path = os.path.join(self.directory, f"step_{step:010d}")
        tmp = path + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        manifest = []
        for i, (name, leaf) in enumerate(zip(names, leaves)):
            fn = f"shard_{i:05d}.npy"
            on_disk = leaf
            if str(leaf.dtype) == "bfloat16":   # .npy stores bf16 as f32
                on_disk = leaf.astype(np.float32)
            np.save(os.path.join(tmp, fn), on_disk)
            manifest.append({"name": name, "file": fn,
                             "shape": list(leaf.shape),
                             "dtype": str(leaf.dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "leaves": manifest}, f)
        with open(os.path.join(tmp, "COMMIT"), "w") as f:
            f.write("ok")
        if os.path.exists(path):
            shutil.rmtree(path)
        os.rename(tmp, path)
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.list_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore
    def list_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and not d.endswith(".tmp") and \
                    os.path.exists(os.path.join(self.directory, d, "COMMIT")):
                out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like, step: int | None = None,
                shardings=None):
        """Restore into the structure of ``tree_like``.

        ``shardings`` (optional pytree of NamedSharding) re-cuts shards
        for the *current* mesh — the elastic-restart path: a checkpoint
        written on 512 chips restores onto 256 (or vice versa).
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:010d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        by_name = {m["name"]: m for m in manifest["leaves"]}
        names, leaves, treedef = _flatten_with_names(tree_like)
        out = []
        shard_flat = None
        if shardings is not None:
            shard_flat = jax.tree_util.tree_flatten(
                shardings, is_leaf=lambda x: isinstance(
                    x, jax.sharding.Sharding))[0]
        for i, (name, leaf) in enumerate(zip(names, leaves)):
            m = by_name[name]
            arr = np.load(os.path.join(path, m["file"]))
            want = getattr(leaf, "dtype", arr.dtype)
            if str(want) != str(arr.dtype):
                import ml_dtypes  # bf16-on-disk round trip
                arr = arr.astype(np.dtype(want) if str(want) != "bfloat16"
                                 else ml_dtypes.bfloat16)
            if shard_flat is not None:
                out.append(jax.device_put(arr, shard_flat[i]))
            else:
                out.append(jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out)
