"""LM pretraining example: any assigned architecture, smoke scale.

Uses the full production path (sharded params on a debug mesh, block-I/O
token pipeline, async checkpoints, train loop) with a reduced config.

  PYTHONPATH=src python examples/train_lm.py --arch smollm-360m --steps 30
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main

if __name__ == "__main__":
    argv = sys.argv[1:]
    if "--arch" not in argv:
        argv = ["--arch", "smollm-360m"] + argv
    if "--smoke" not in argv:
        argv.append("--smoke")
    raise SystemExit(main(argv))
