"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis is
data-parallel only and rides the inter-pod DCN link — the sharding rules
keep every latency-sensitive collective (TP) on intra-pod ICI.

Defined as functions (never module-level) so importing this module never
touches jax device state; ``dryrun.py`` sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import to fabricate the devices.
"""
from __future__ import annotations

import jax

from ..compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(n_devices: int | None = None):
    """Small mesh over whatever devices exist (tests on 1-8 host devices)."""
    n = n_devices or len(jax.devices())
    model = 1
    for m in (4, 2, 1):
        if n % m == 0:
            model = m
            break
    return make_mesh((n // model, model), ("data", "model"))
