"""Fig 6 (EQ1): AGNES vs four storage-based baselines, two memory settings.

Paper: AGNES up to 3.1x over Ginex in Setting 1 (32 GB) and 4.1x in
Setting 2 (8 GB).  Container settings are scaled 32GB→64MB / 8GB→16MB
against the mini datasets (same buffer:dataset ratios); times are the
modeled NVMe device times of the real I/O schedules.
"""
from __future__ import annotations

from .common import (ALL_BASELINES, emit, get_dataset, make_agnes,
                     make_baseline, targets_for)

SETTINGS = {"setting1_64MB": 64 << 20, "setting2_16MB": 16 << 20}
DATASETS = ("ig-mini", "tw-mini", "pa-mini")


def run(datasets=DATASETS):
    for ds_name in datasets:
        ds = get_dataset(ds_name)
        targets = targets_for(ds, n_mb=4, mb_size=512)
        for setting, nbytes in SETTINGS.items():
            times = {}
            agnes = make_agnes(ds, setting_bytes=nbytes)
            agnes.prepare(targets, epoch=0)
            times["agnes"] = agnes.last_report.modeled_io_s
            for name, cls in ALL_BASELINES.items():
                eng = make_baseline(cls, ds, setting_bytes=nbytes)
                eng.prepare(targets, epoch=0)
                times[name] = eng.last_report.modeled_io_s
            best_rival = min(v for k, v in times.items() if k != "agnes")
            for name, t in sorted(times.items()):
                emit(f"fig6/{ds_name}/{setting}/{name}", t * 1e6,
                     f"epoch-slice modeled seconds={t:.4f}")
            emit(f"fig6/{ds_name}/{setting}/speedup_vs_best", 0.0,
                 f"{best_rival / times['agnes']:.2f}x")


if __name__ == "__main__":
    run()
