"""Feature gathering (paper §3.4(2), Algorithm 1 lines 13-17).

Collects the feature vectors of each minibatch's sampled input nodes into
*contiguous* per-minibatch arrays ready for device transfer (G-1..G-3).
Like sampling, gathering runs in block-major (hyperbatch) order: the
misses of *all* minibatches are bucketed by feature block and every
needed block is read exactly once per hyperbatch.  The feature cache
(access-count admission) absorbs hot rows across hyperbatches.

Gathering is exposed as explicit stages for the staged prepare path
(:class:`repro.core.session.PrepareSession`):

* :meth:`FeatureGatherer.plan_gather`    — cache pass + bucket of misses;
  the feature block visit order is known here, so the gather I/O plan
  can be submitted as soon as the final sampling frontier exists;
* :meth:`FeatureGatherer.consume_gather` — the block-major fill.

Also implements the node-granular path used by the baseline engines
(one small I/O per missed row — the pattern the paper identifies as the
bottleneck).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .block_store import FeatureBlockStore
from .bucket import Bucket, build_bucket
from .buffer import BlockBuffer
from .feature_cache import FeatureCache


@dataclasses.dataclass
class GatherPlan:
    """Planned gather state: cache-filled outputs + bucketed misses."""

    outs: list[np.ndarray]            # per-mb contiguous outputs (G-3)
    miss_lists: list                  # per-mb (miss_nodes, miss_positions)
    bck: Bucket                       # misses bucketed by feature block

    @property
    def row_blocks(self) -> np.ndarray:
        """Ascending feature-block visit order for the misses."""
        return self.bck.row_blocks

    @property
    def n_miss(self) -> int:
        return sum(len(m) for m, _ in self.miss_lists)


class FeatureGatherer:
    """Gathers features for sampled nodes through cache + block buffer."""

    def __init__(self, store: FeatureBlockStore, buffer: BlockBuffer,
                 cache: FeatureCache | None = None, prefetcher=None):
        self.store = store
        self.buffer = buffer
        self.cache = cache
        self.prefetcher = prefetcher

    # ------------------------------------------------------------ stages
    def plan_gather(self, nodes_per_mb: list[np.ndarray]) -> GatherPlan:
        """Cache pass + block bucket of the misses (the *plan* stage)."""
        outs, miss_lists = self._cache_pass(nodes_per_mb)
        miss_nodes = [m for m, _ in miss_lists]
        blocks = [self.store.block_of(m) for m in miss_nodes]
        return GatherPlan(outs, miss_lists, build_bucket(miss_nodes, blocks))

    def consume_gather(self, gp: GatherPlan) -> list[np.ndarray]:
        """Block-major fill of the planned misses; one read per block.

        The per-group scatter is vectorized: block reads only *collect*
        (node, value) pairs per minibatch; at the end one concatenate +
        one ``searchsorted`` + one fancy-index scatter per minibatch moves
        everything into the contiguous outputs (G-2), and the cache sees
        a single batched admit.
        """
        bck = gp.bck
        rpb = self.store.rows_per_block
        n_mb = len(gp.miss_lists)
        per_mb_nodes: list[list[np.ndarray]] = [[] for _ in range(n_mb)]
        per_mb_vals: list[list[np.ndarray]] = [[] for _ in range(n_mb)]
        all_nodes: list[np.ndarray] = []
        all_vals: list[np.ndarray] = []
        for r in range(bck.n_rows):
            b = int(bck.row_blocks[r])
            rows = self._load_block(b)
            g0, g1 = int(bck.row_ptr[r]), int(bck.row_ptr[r + 1])
            p0, p1 = int(bck.group_ptr[g0]), int(bck.group_ptr[g1])
            blk_nodes = bck.nodes[p0:p1]      # all mbs' nodes in block b
            vals = rows[blk_nodes - b * rpb]  # one gather per block
            bounds = (bck.group_ptr[g0 + 1:g1] - p0)
            for off, (gn, gv) in enumerate(zip(np.split(blk_nodes, bounds),
                                               np.split(vals, bounds))):
                j = int(bck.mb_ids[g0 + off])
                per_mb_nodes[j].append(gn)
                per_mb_vals[j].append(gv)
            if self.cache is not None:
                all_nodes.append(blk_nodes)
                all_vals.append(vals)
        for j, (mnodes, mpos) in enumerate(gp.miss_lists):
            if not per_mb_nodes[j]:
                continue
            g_nodes = np.concatenate(per_mb_nodes[j])
            g_vals = np.concatenate(per_mb_vals[j])
            # mnodes sorted unique (inputs are unique per mb)
            where = np.searchsorted(mnodes, g_nodes)
            gp.outs[j][mpos[where]] = g_vals
        if self.cache is not None and all_nodes:
            self.cache.admit(np.concatenate(all_nodes),
                             np.concatenate(all_vals))
        return gp.outs

    # ------------------------------------------------------------ block-major
    def gather_hyperbatch(self, nodes_per_mb: list[np.ndarray]) -> list[np.ndarray]:
        """Block-major gathering for a hyperbatch; one read per needed block.

        Compatibility wrapper over the staged API with the pre-session
        schedule (plan, prefetch, consume, reset barrier).
        """
        gp = self.plan_gather(nodes_per_mb)
        if gp.n_miss == 0:
            return gp.outs
        try:
            if self.prefetcher is not None:
                self.prefetcher.plan(self.buffer.absent(gp.row_blocks))
            self.consume_gather(gp)
        finally:
            if self.prefetcher is not None:
                self.prefetcher.reset()
        return gp.outs

    # ------------------------------------------------------------ target-major
    def gather_per_minibatch(self, nodes_per_mb: list[np.ndarray]) -> list[np.ndarray]:
        """Target-major gathering: each minibatch fetched independently."""
        return [self.gather_hyperbatch([nodes])[0] for nodes in nodes_per_mb]

    def gather_node_granular(self, nodes_per_mb: list[np.ndarray],
                             io_unit: int = 4096) -> list[np.ndarray]:
        """Baseline path: per-row small I/Os for every cache miss."""
        outs, miss_lists = self._cache_pass(nodes_per_mb)
        for j, (miss_nodes, miss_pos) in enumerate(miss_lists):
            if len(miss_nodes) == 0:
                continue
            rows = self.store.read_rows_node_granular(miss_nodes, io_unit)
            outs[j][miss_pos] = rows
            if self.cache is not None:
                self.cache.admit(miss_nodes, rows)
        return outs

    # ------------------------------------------------------------ internals
    def _cache_pass(self, nodes_per_mb):
        """Fill from feature cache; return per-mb outputs + miss lists."""
        outs, miss_lists = [], []
        for nodes in nodes_per_mb:
            nodes = np.asarray(nodes, dtype=np.int64)
            out = np.empty((len(nodes), self.store.dim), dtype=self.store.dtype)
            if self.cache is not None:
                self.cache.note_access(nodes)
                mask, rows = self.cache.lookup(nodes)
                out[mask] = rows
                miss = ~mask
                miss_lists.append((nodes[miss], np.nonzero(miss)[0]))
            else:
                miss_lists.append((nodes, np.arange(len(nodes))))
            outs.append(out)
        return outs, miss_lists

    def _load_block(self, b: int) -> np.ndarray:
        if b not in self.buffer and self.prefetcher is not None:
            rows = self.prefetcher.fetch(b)
            if rows is not None:
                self.buffer.stats.buffer_misses += 1
                self.buffer.put(b, rows)
                return rows
        return self.buffer.get(b, self.store.read_block)
