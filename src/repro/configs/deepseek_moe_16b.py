"""deepseek-moe-16b [moe]: 28L, d=2048, 16H (kv=16), vocab=102400 —
2 shared + 64 routed experts top-6, fine-grained, d_expert=1408; first
layer dense (d_ff=10944). [arXiv:2401.06066; hf]
"""
from .base import LayerSpec, ModelConfig, MoEConfig, register

DENSE_FF = 10944


@register("deepseek-moe-16b")
def config() -> ModelConfig:
    layers = [LayerSpec(mixer="attn", ffn="mlp")] \
        + [LayerSpec(mixer="attn", ffn="moe") for _ in range(27)]
    return ModelConfig(
        name="deepseek-moe-16b", family="moe",
        n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=DENSE_FF, vocab=102400, head_dim=128,
        layers=tuple(layers),
        moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2,
                      group_tokens=4096),
        source="arXiv:2401.06066 (DeepSeekMoE-16B)")
