"""Jit'd public wrappers for the Pallas kernels.

Each op dispatches: Pallas TPU kernel on TPU backends, Pallas interpret
mode when ``interpret=True`` (CPU validation), and the jnp oracle
otherwise — so the same call sites run everywhere.  The oracle *is* the
semantics (``ref.py``); tests sweep shapes/dtypes asserting the kernels
match it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention_kernel
from .gather_rows import gather_rows_kernel
from .segment_agg import gather_aggregate_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def gather_rows(table: jnp.ndarray, idx: jnp.ndarray, *,
                use_kernel: bool | None = None,
                interpret: bool = False) -> jnp.ndarray:
    """out[i] = table[idx[i]] (block feature gather)."""
    use = _on_tpu() if use_kernel is None else use_kernel
    if use or interpret:
        return gather_rows_kernel(table, idx, interpret=interpret or not _on_tpu())
    return ref.gather_rows_ref(table, idx)


@functools.partial(jax.jit,
                   static_argnames=("mean", "use_kernel", "interpret"))
def gather_aggregate(table: jnp.ndarray, nbr_idx: jnp.ndarray, *,
                     mean: bool = True, use_kernel: bool | None = None,
                     interpret: bool = False) -> jnp.ndarray:
    """Fused GNN neighbor gather + masked sum/mean."""
    use = _on_tpu() if use_kernel is None else use_kernel
    if use or interpret:
        return gather_aggregate_kernel(
            table, nbr_idx, mean=mean, interpret=interpret or not _on_tpu())
    return ref.gather_aggregate_ref(table, nbr_idx, mean=mean)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "use_kernel", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    use_kernel: bool | None = None,
                    interpret: bool = False) -> jnp.ndarray:
    """Tiled online-softmax attention with GQA + sliding window."""
    use = _on_tpu() if use_kernel is None else use_kernel
    if use or interpret:
        return flash_attention_kernel(
            q, k, v, causal=causal, window=window, scale=scale,
            block_q=block_q, block_k=block_k,
            interpret=interpret or not _on_tpu())
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   scale=scale)
