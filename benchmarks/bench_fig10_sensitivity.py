"""Fig 10: sensitivity — buffer size, CPU threads, feature dim, sampling
fanout, SSD array size (AGNES vs Ginex-like)."""
from __future__ import annotations

from .common import (ALL_BASELINES, emit, get_dataset, make_agnes,
                     make_baseline, targets_for)


def run():
    ds = get_dataset("ig-mini")
    targets = targets_for(ds, n_mb=4, mb_size=512)

    # (a) buffer size
    for mb in (4, 8, 16, 64):
        a = make_agnes(ds, setting_bytes=mb << 20)
        g = make_baseline(ALL_BASELINES["ginex"], ds, setting_bytes=mb << 20)
        a.prepare(targets, epoch=0)
        g.prepare(targets, epoch=0)
        emit(f"fig10a/buffer_{mb}MB/agnes",
             a.last_report.modeled_io_s * 1e6, "")
        emit(f"fig10a/buffer_{mb}MB/ginex",
             g.last_report.modeled_io_s * 1e6, "")

    # (b) CPU threads — modeled: data-prep CPU work scales 1/t; device
    # time does not (the paper's point: AGNES parallelizes better because
    # its block-major loop has no cross-minibatch dependencies)
    a = make_agnes(ds)
    g = make_baseline(ALL_BASELINES["ginex"], ds)
    a.prepare(targets, epoch=0)
    g.prepare(targets, epoch=0)
    for threads in (1, 2, 4, 8, 16):
        ra, rg = a.last_report, g.last_report
        ta = max(ra.wall_s / threads, ra.modeled_io_s)
        # ginex's superbatch sampling pass serializes on its cache build
        tg = rg.wall_s * (0.4 + 0.6 / threads) + rg.modeled_io_s
        emit(f"fig10b/threads_{threads}/agnes", ta * 1e6, "model: max(cpu/t, io)")
        emit(f"fig10b/threads_{threads}/ginex", tg * 1e6,
             "model: serial fraction 0.4")

    # (c) feature dimension
    for dim in (64, 128, 256, 512):
        ds_d = get_dataset("ig-mini", dim=dim)
        t2 = targets_for(ds_d, n_mb=2, mb_size=512)
        a = make_agnes(ds_d)
        g = make_baseline(ALL_BASELINES["ginex"], ds_d)
        a.prepare(t2, epoch=0)
        g.prepare(t2, epoch=0)
        emit(f"fig10c/dim_{dim}/agnes", a.last_report.modeled_io_s * 1e6, "")
        emit(f"fig10c/dim_{dim}/ginex", g.last_report.modeled_io_s * 1e6, "")

    # (d) sampling fanout
    for fan in (5, 10, 15):
        a = make_agnes(ds, fanouts=(fan,) * 3)
        g = make_baseline(ALL_BASELINES["ginex"], ds, fanouts=(fan,) * 3)
        a.prepare(targets, epoch=0)
        g.prepare(targets, epoch=0)
        emit(f"fig10d/fanout_{fan}/agnes", a.last_report.modeled_io_s * 1e6, "")
        emit(f"fig10d/fanout_{fan}/ginex", g.last_report.modeled_io_s * 1e6, "")

    # (e) SSD array size (RAID0)
    for n_ssd in (1, 2, 4):
        a = make_agnes(ds, n_ssd=n_ssd)
        g = make_baseline(ALL_BASELINES["ginex"], ds)
        g.csr.device.n_ssd = n_ssd
        g.features.device.n_ssd = n_ssd
        a.prepare(targets, epoch=0)
        g.prepare(targets, epoch=0)
        emit(f"fig10e/ssd_{n_ssd}/agnes", a.last_report.modeled_io_s * 1e6, "")
        emit(f"fig10e/ssd_{n_ssd}/ginex", g.last_report.modeled_io_s * 1e6,
             "IOPS-bound: no RAID0 benefit")


if __name__ == "__main__":
    run()
