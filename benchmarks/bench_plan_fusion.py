"""Cross-hop plan fusion: barriered vs fused staged prepare.

The staged ``PrepareSession`` (``repro.core.session``) submits hop k+1's
I/O plan while hop k's tail blocks are still being consumed and the
gather plan as soon as the final frontier exists — no per-hop ``reset()``
barrier — so back-to-back submissions share one device queue
(``PlanStream``): the prepare pays ``max(sum bw, sum iops)`` instead of
the barriered ``sum of per-hop max(bw, iops)``.

The workload constructs the regime mix where that fusion pays: graph
blocks are small (scattered sampling touch → every hop latency-bound)
while feature blocks are large (the paper's Fig-4 I/O-unit tuning →
gather bandwidth-bound), both stores on one NVMe array.  With a barrier
the device alternates between starving its bandwidth (sampling hops) and
starving its queue (gather); fused, the two rooflines overlap.

MFG/feature/bytes parity between the two schedules is asserted (the
speedup must be free), and the fused prepare must stay >= 1.3x — the
acceptance gate tracked in ``BENCH_fusion.json`` by ``run.py --quick``.
"""
from __future__ import annotations

import os

import numpy as np

from .common import WORKDIR, emit, quick_val

from repro.core import AgnesConfig, AgnesEngine, FeatureBlockStore, NVMeModel
from repro.data import build_dataset
from repro.data.synth import make_features

MIN_SPEEDUP = 1.3


def _build(n_nodes, avg_degree, g_block, f_block, dim):
    """Graph store at small blocks + feature store at large blocks."""
    ds = build_dataset(f"fusion{n_nodes}", WORKDIR, dim=16,
                       block_size=g_block, n_nodes=n_nodes,
                       avg_degree=avg_degree)
    fpath = os.path.join(WORKDIR, f"fusion{n_nodes}_{dim}_{f_block}.feat")
    if not os.path.exists(fpath + ".meta.json"):
        feats, _ = make_features(n_nodes, dim, seed=0)
        FeatureBlockStore.build(fpath, feats, block_size=f_block)
    return ds, fpath


def _engine(ds, fpath, *, g_block, fusion, fanouts, mb, n_mb):
    dev = NVMeModel()  # one array: graph + feature plans share the stream
    g, _ = ds.reopen_stores(device=dev)
    f = FeatureBlockStore.open(fpath, device=dev)
    cfg = AgnesConfig(block_size=g_block, minibatch_size=mb,
                      hyperbatch_size=n_mb, fanouts=fanouts,
                      graph_buffer_bytes=16 << 20,
                      feature_buffer_bytes=16 << 20,
                      feature_cache_rows=0, async_io=False,
                      plan_fusion=fusion)
    return AgnesEngine(g, f, cfg)


def _measure(eng, targets):
    prepared = eng.prepare(targets, epoch=0)
    g, f = eng.graph_store.stats, eng.feature_store.stats
    return prepared, {
        "modeled_prepare_io_s": g.modeled_read_time + f.modeled_read_time,
        "sample_io_s": g.modeled_read_time,
        "gather_io_s": f.modeled_read_time,
        "bytes_read": int(g.bytes_read + f.bytes_read),
        "n_requests": int(g.n_requests + f.n_requests),
    }


def run() -> dict:
    n_nodes = quick_val(80_000, 20_000)
    g_block = 4096
    f_block = quick_val(256 << 10, 128 << 10)
    dim = quick_val(96, 64)
    mb = quick_val(48, 24)
    fanouts = (4, 4)
    ds, fpath = _build(n_nodes, 6, g_block, f_block, dim)
    rng = np.random.default_rng(0)
    targets = [rng.choice(n_nodes, mb, replace=False) for _ in range(2)]

    barrier = _engine(ds, fpath, g_block=g_block, fusion=False,
                      fanouts=fanouts, mb=mb, n_mb=2)
    p0, before = _measure(barrier, targets)
    fused = _engine(ds, fpath, g_block=g_block, fusion=True,
                    fanouts=fanouts, mb=mb, n_mb=2)
    p1, after = _measure(fused, targets)

    # the fusion must be free: byte-identical MFGs, features, bytes_read
    for a, b in zip(p1, p0):
        for x, y in zip(a.mfg.nodes, b.mfg.nodes):
            assert np.array_equal(x, y), "fusion changed the MFGs"
        for lx, ly in zip(a.mfg.layers, b.mfg.layers):
            assert np.array_equal(lx.nbr_idx, ly.nbr_idx)
            assert np.array_equal(lx.self_idx, ly.self_idx)
        assert np.allclose(a.features, b.features), \
            "fusion changed gathered features"
    assert after["bytes_read"] == before["bytes_read"], \
        (after["bytes_read"], before["bytes_read"])

    speedup = before["modeled_prepare_io_s"] / max(
        after["modeled_prepare_io_s"], 1e-12)
    # acceptance gate (deterministic: modeled device time of fixed plans)
    assert speedup >= MIN_SPEEDUP, \
        f"plan fusion regression: {speedup:.2f}x < {MIN_SPEEDUP}x"

    n_stages = len(fused.last_session.plans)
    emit("fusion/barriered_ms", before["modeled_prepare_io_s"] * 1e3,
         f"sample={before['sample_io_s']*1e3:.2f}ms "
         f"gather={before['gather_io_s']*1e3:.2f}ms")
    emit("fusion/fused_ms", after["modeled_prepare_io_s"] * 1e3,
         f"{n_stages} staged plans")
    emit("fusion/speedup", speedup,
         f"n_requests={before['n_requests']}->{after['n_requests']}")
    barrier.close()
    fused.close()
    return {
        "workload": {"n_nodes": n_nodes, "graph_block": g_block,
                     "feature_block": f_block, "dim": dim,
                     "fanouts": list(fanouts)},
        "barriered": before, "fused": after,
        "n_staged_plans": n_stages,
        "speedup": round(speedup, 3),
    }


if __name__ == "__main__":
    print(run())
