"""Hyperbatch-based sampling (paper §3.3, Algorithm 1 lines 3-12).

The loop-order inversion that is the paper's key idea: instead of walking
*target nodes* and fetching whatever blocks they need (reloading blocks
that fall out of the bounded buffer — Fig 5(a)), AGNES walks *blocks* in
ascending ID order and, for each loaded block, serves every minibatch of
the hyperbatch that needs anything in it (Fig 5(b)).  One block-wise I/O
per needed block per hop, and the ascending visit order makes those I/Os
largely sequential.

Each hop is exposed as explicit stages so a :class:`repro.core.session.
PrepareSession` can schedule the I/O between them:

* :meth:`HyperbatchSampler.plan_hop`      — bucket matrix + flat scatter
  tables for one hop (the block visit order is known here);
* :meth:`HyperbatchSampler.consume_hop`   — the ascending row scan, with
  a ``tail_cb`` fusion hook fired before the tail rows so the next hop's
  partial plan can be submitted while this hop is still consuming;
* :meth:`HyperbatchSampler.advance_frontiers` / :meth:`assemble_hop` —
  next frontier first (cheap, unblocks the next plan), index maps after.

The per-group Python fanout loop is gone: every bucket node's destination
row in the hop's flat ``sampled`` table is precomputed with one segmented
``searchsorted`` (:meth:`_bucket_positions`), so a row scatter is a single
fancy-index assignment covering all minibatches in the row.

Both processing modes share all mechanics and the deterministic sampler,
so they produce *identical* MFGs:

* :meth:`HyperbatchSampler.sample_hyperbatch`  — block-major (AGNES-HB)
* :meth:`HyperbatchSampler.sample_per_minibatch` — target-major (AGNES-No)
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .block_store import GraphBlock, GraphBlockStore
from .bucket import Bucket, build_bucket
from .buffer import BlockBuffer
from .sampling import (MFG, layer_from_frontier, next_frontier,
                       sample_indices)


@dataclasses.dataclass
class HopPlan:
    """One hop's planned sampling state (output of the *plan* stage).

    ``sampled`` is the hyperbatch-flat neighbor table: minibatch ``j``'s
    rows live at ``[offsets[j], offsets[j+1])``; ``dst_pos[i]`` is the
    flat row of bucket node ``bck.nodes[i]`` — both fixed at plan time,
    so consuming a bucket row is one vectorized scatter.
    """

    hop: int
    fanout: int
    bck: Bucket
    frontiers: list[np.ndarray]
    offsets: np.ndarray     # (n_mb + 1,) frontier row offsets into sampled
    dst_pos: np.ndarray     # (len(bck.nodes),) flat rows into sampled
    sampled: np.ndarray     # (offsets[-1], fanout) int64, -1 padded

    @property
    def row_blocks(self) -> np.ndarray:
        """The hop's ascending block visit order (Algorithm 1 line 7)."""
        return self.bck.row_blocks

    def blocks_per_array(self, placement) -> np.ndarray:
        """Per-array counts of this hop's block visit plan under a
        :class:`~repro.core.topology.BlockPlacement` — how striping
        reshapes the sampling fan-out (the ascending global visit order
        round-robins across arrays, so per-array queues stay busy
        together instead of draining one slab at a time)."""
        return placement.blocks_per_array(self.row_blocks)

    def sampled_for(self, j: int) -> np.ndarray:
        return self.sampled[self.offsets[j]:self.offsets[j + 1]]


class HyperbatchSampler:
    """k-hop neighbor sampler over a :class:`GraphBlockStore`."""

    def __init__(self, store: GraphBlockStore, buffer: BlockBuffer,
                 fanouts: tuple[int, ...], seed: int = 0,
                 prefetcher=None):
        self.store = store
        self.buffer = buffer
        self.fanouts = tuple(fanouts)
        self.seed = seed
        self.prefetcher = prefetcher

    # ------------------------------------------------------------ stages
    def plan_hop(self, frontiers: list[np.ndarray], hop: int) -> HopPlan:
        """Bucket matrix + flat scatter tables for one hop.

        ``Bck_{i,j} <- N_in^j in B_g(i)`` (Algorithm 1 line 6); after this
        the hop's full block visit order (:attr:`HopPlan.row_blocks`) is
        known and can be submitted to the I/O scheduler.
        """
        fanout = self.fanouts[hop]
        primary = [self._primary_block(f) for f in frontiers]
        bck = build_bucket(frontiers, primary)
        offsets = np.zeros(len(frontiers) + 1, dtype=np.int64)
        np.cumsum([len(f) for f in frontiers], out=offsets[1:])
        sampled = np.full((int(offsets[-1]), fanout), -1, dtype=np.int64)
        dst_pos = self._bucket_positions(bck, frontiers)
        return HopPlan(hop, fanout, bck, list(frontiers), offsets,
                       dst_pos, sampled)

    def consume_hop(self, hp: HopPlan, epoch: int,
                    tail_cb=None, tail_at: float = 0.75) -> None:
        """Ascending row scan of the hop's bucket (Algorithm 1 line 7).

        ``tail_cb`` is the cross-hop fusion hook: fired once, just before
        the tail rows, with the candidate next-frontier known so far
        (frontier self-edges + neighbors sampled in the head rows), so
        the caller can submit hop k+1's partial I/O plan while this hop's
        tail blocks are still being consumed.
        """
        n_rows = hp.bck.n_rows
        trigger = int(n_rows * tail_at) if (tail_cb is not None
                                            and n_rows >= 8) else -1
        for r in range(n_rows):
            if r == trigger:
                tail_cb(self._partial_candidates(hp))
            self._process_row(hp, r, epoch)

    def advance_frontiers(self, hp: HopPlan) -> list[np.ndarray]:
        """Next hop's frontiers — available before the layer index maps
        are built, so the next plan can be submitted first."""
        return [next_frontier(hp.frontiers[j], hp.sampled_for(j))
                for j in range(len(hp.frontiers))]

    def assemble_hop(self, hp: HopPlan, nxt: list[np.ndarray],
                     mfgs: list[MFG]) -> None:
        """Build the hop's MFG layers (the CPU-heavy index maps)."""
        for j, mfg in enumerate(mfgs):
            mfg.nodes.append(nxt[j])
            mfg.layers.append(layer_from_frontier(
                hp.frontiers[j], hp.sampled_for(j), nxt[j]))

    # ------------------------------------------------------------ public
    def sample_hyperbatch(self, targets_per_mb: list[np.ndarray],
                          epoch: int = 0) -> list[MFG]:
        """Block-major sampling for a full hyperbatch (Algorithm 1).

        Compatibility wrapper over the staged API with the pre-session
        schedule: one plan per hop, reset barrier at every hop boundary.
        :class:`repro.core.session.PrepareSession` drives the same stages
        without the barriers.
        """
        frontiers = [np.unique(np.asarray(t, dtype=np.int64))
                     for t in targets_per_mb]
        mfgs = [MFG(nodes=[f], layers=[]) for f in frontiers]
        for hop in range(len(self.fanouts)):
            hp = self.plan_hop(frontiers, hop)
            try:
                if self.prefetcher is not None:
                    # plan only blocks not already buffer-resident so every
                    # planned block is consumed exactly once (no slot leak)
                    self.prefetcher.plan(self.buffer.absent(hp.row_blocks))
                self.consume_hop(hp, epoch)
            finally:
                if self.prefetcher is not None:
                    self.prefetcher.reset()  # hop boundary: drop stale plan
            nxt = self.advance_frontiers(hp)
            self.assemble_hop(hp, nxt, mfgs)
            frontiers = nxt
        return mfgs

    def sample_per_minibatch(self, targets_per_mb: list[np.ndarray],
                             epoch: int = 0) -> list[MFG]:
        """Target-major sampling (no hyperbatch): one minibatch at a time.

        Identical sampling decisions; only the block visit order differs,
        so the bounded buffer may thrash across minibatches (Fig 5(a)).
        """
        out = []
        for t in targets_per_mb:
            out.extend(self._sample_one([np.unique(np.asarray(t, np.int64))],
                                        epoch))
        return out

    def _sample_one(self, frontiers: list[np.ndarray], epoch: int) -> list[MFG]:
        mfgs = [MFG(nodes=[f], layers=[]) for f in frontiers]
        for hop in range(len(self.fanouts)):
            hp = self.plan_hop(frontiers, hop)
            self.consume_hop(hp, epoch)
            nxt = self.advance_frontiers(hp)
            self.assemble_hop(hp, nxt, mfgs)
            frontiers = nxt
        return mfgs

    # ------------------------------------------------------------ internals
    @staticmethod
    def _bucket_positions(bck: Bucket, frontiers: list[np.ndarray]) -> np.ndarray:
        """Flat ``sampled`` row of every bucket node — one segmented
        ``searchsorted`` for the whole hop (replaces the per-group loop).

        Keyed trick: with stride ``K > max node id``, the concatenation of
        ``j * K + frontiers[j]`` is globally ascending, so a single binary
        search of ``mb * K + node`` yields ``offsets[mb] + position-in-
        frontier`` directly.
        """
        if len(bck.nodes) == 0:
            return np.zeros(0, dtype=np.int64)
        group_mb = np.repeat(bck.mb_ids, np.diff(bck.group_ptr))
        stride = max(int(f[-1]) for f in frontiers if len(f)) + 1
        keyed = np.concatenate([f + j * stride
                                for j, f in enumerate(frontiers)])
        return np.searchsorted(keyed, bck.nodes + group_mb * stride)

    @staticmethod
    def _partial_candidates(hp: HopPlan) -> np.ndarray:
        """Candidate next-frontier nodes known mid-scan: the frontier
        itself (self edges always survive) + neighbors sampled so far."""
        got = hp.sampled[hp.sampled >= 0]
        front = (np.concatenate(hp.frontiers) if hp.frontiers
                 else np.zeros(0, np.int64))
        return np.unique(np.concatenate([front, got]))

    def _primary_block(self, nodes: np.ndarray) -> np.ndarray:
        """First block containing each node (vectorized T_obj search)."""
        if len(nodes) == 0:
            return np.zeros(0, dtype=np.int64)
        lasts = self.store.t_obj[:, 1]
        lo = np.searchsorted(lasts, nodes, side="left")
        return np.clip(lo, 0, self.store.n_blocks - 1)

    def _load(self, block_id: int, pin: bool) -> GraphBlock:
        if block_id not in self.buffer and self.prefetcher is not None:
            blk = self.prefetcher.fetch(block_id)
            if blk is not None:
                # the I/O already happened on the prefetch thread: count a miss
                self.buffer.stats.buffer_misses += 1
                self.buffer.put(block_id, blk)
                if pin:
                    self.buffer.pin(block_id)
                return blk
        return self.buffer.get(block_id, self.store.read_block, pin=pin)

    def _process_row(self, hp: HopPlan, r: int, epoch: int) -> None:
        """Process row ``Bck[i, :]`` — one block serves all minibatches.

        The fanout to every minibatch in the row is one fancy scatter into
        the hop's flat ``sampled`` table (rows precomputed by
        :meth:`_bucket_positions`): no per-group Python work.
        """
        bck = hp.bck
        b = int(bck.row_blocks[r])
        blk = self._load(b, pin=True)
        pinned = [b]
        try:
            g0, g1 = int(bck.row_ptr[r]), int(bck.row_ptr[r + 1])
            p0, p1 = int(bck.group_ptr[g0]), int(bck.group_ptr[g1])
            all_nodes = bck.nodes[p0:p1]      # every mb's nodes in block b
            row_nodes = np.unique(all_nodes)
            nbrs, ok = self._sample_nodes_in_block(
                blk, row_nodes, hp.fanout, epoch, hp.hop, pinned)
            row_nodes = row_nodes[ok]
            nbrs = nbrs[ok]
            sel = np.searchsorted(row_nodes, all_nodes)
            sel_ok = sel < len(row_nodes)
            sel_c = np.clip(sel, 0, max(len(row_nodes) - 1, 0))
            if len(row_nodes):
                sel_ok &= row_nodes[sel_c] == all_nodes
            else:
                sel_ok &= False
            hp.sampled[hp.dst_pos[p0:p1][sel_ok]] = nbrs[sel_c[sel_ok]]
        finally:
            for p in pinned:
                self.buffer.unpin(p)

    def _sample_nodes_in_block(self, blk: GraphBlock, nodes: np.ndarray,
                               fanout: int, epoch: int, hop: int,
                               pinned: list[int]) -> tuple[np.ndarray, np.ndarray]:
        """Sample ``fanout`` neighbors for each node whose object starts in
        ``blk``.  Returns ((n, fanout) neighbor ids with -1 pad, ok mask)."""
        entry, present = blk.find_entries(nodes)
        nbrs = np.full((len(nodes), fanout), -1, dtype=np.int64)
        if not present.any():
            return nbrs, present
        e = entry[present]
        deg = blk.total_degree[e]
        pos = sample_indices(nodes[present], deg, fanout, self.seed, epoch, hop)
        counts = blk.indptr[e + 1] - blk.indptr[e]
        whole = counts == deg  # object fully inside this block
        # vectorized path: positions index directly into the block payload
        w = np.nonzero(whole)[0]
        if w.size and len(blk.indices):
            base = blk.indptr[e[w]][:, None]
            p = pos[w]
            sel = np.where(p >= 0, base + p, 0)
            vals = blk.indices[sel]
            nbrs_present = np.where(p >= 0, vals, -1)
            out_idx = np.nonzero(present)[0][w]
            nbrs[out_idx] = nbrs_present
        # split objects (hub nodes): stitch continuation blocks
        s = np.nonzero(~whole)[0]
        for i in s.tolist():
            node = int(nodes[present][i])
            adj = self._stitch_split(blk, int(e[i]), node, int(deg[i]), pinned)
            p = pos[i]
            row = np.where(p >= 0, adj[np.clip(p, 0, len(adj) - 1)], -1)
            nbrs[np.nonzero(present)[0][i]] = row
        return nbrs, present

    def _stitch_split(self, blk: GraphBlock, entry: int, node: int,
                      total_deg: int, pinned: list[int]) -> np.ndarray:
        """Assemble the full adjacency of an object split across blocks."""
        parts = [blk.adjacency(entry)]
        got = len(parts[0])
        bid = blk.block_id
        while got < total_deg:
            bid += 1
            nxt = self._load(bid, pin=True)
            pinned.append(bid)
            ent, ok = nxt.find_entries(np.array([node]))
            if not ok[0]:
                raise RuntimeError(
                    f"split object {node} not found in continuation block {bid}")
            part = nxt.adjacency(int(ent[0]))
            parts.append(part)
            got += len(part)
        return np.concatenate(parts)
