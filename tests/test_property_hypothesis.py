"""Property-based tests (hypothesis) on the system's core invariants."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not installed; property tests skipped")
from hypothesis import given, settings, strategies as st

from repro.core import GraphBlockStore, build_bucket, sample_indices
from repro.core.feature_cache import FeatureCache
from repro.data.synth import powerlaw_graph


@st.composite
def csr_graphs(draw):
    n = draw(st.integers(10, 120))
    avg = draw(st.integers(1, 12))
    seed = draw(st.integers(0, 10_000))
    return powerlaw_graph(n, avg, seed=seed)


@given(csr_graphs(), st.sampled_from([512, 1024, 4096]))
@settings(max_examples=15, deadline=None)
def test_block_store_preserves_graph(tmp_path_factory, g, block_size):
    indptr, indices = g
    path = str(tmp_path_factory.mktemp("bs") / "g.blk")
    store = GraphBlockStore.build(path, indptr, indices, block_size)
    # every edge recoverable; T_obj ranges cover all nodes in order
    n = len(indptr) - 1
    per_node = {v: [] for v in range(n)}
    for b in range(store.n_blocks):
        blk = store.read_block(b)
        lo, hi = store.t_obj[b]
        assert (blk.node_ids >= lo).all() and (blk.node_ids <= hi).all()
        assert np.all(np.diff(blk.node_ids) >= 0)
        for e in range(len(blk.node_ids)):
            per_node[int(blk.node_ids[e])].append(blk.adjacency(e))
    for v in range(n):
        ref = np.sort(indices[indptr[v]:indptr[v + 1]])
        got = np.sort(np.concatenate(per_node[v])
                      if per_node[v] else np.zeros(0, np.int64))
        assert np.array_equal(ref, got)


@given(st.integers(0, 2**20), st.integers(0, 50), st.integers(0, 3),
       st.integers(1, 64), st.integers(1, 16))
@settings(max_examples=50, deadline=None)
def test_sample_indices_bounds(seed, epoch, hop, deg, fanout):
    nodes = np.arange(7, dtype=np.int64) * 13
    degs = np.full(7, deg)
    out = sample_indices(nodes, degs, fanout, seed, epoch, hop)
    assert out.shape == (7, fanout)
    valid = out >= 0
    assert (out[valid] < deg).all()
    if deg <= fanout:   # small-degree nodes take the whole neighborhood
        assert (valid.sum(axis=1) == deg).all()
    else:
        assert valid.all()


@given(st.lists(st.lists(st.integers(0, 499), min_size=0, max_size=40),
                min_size=1, max_size=6))
@settings(max_examples=30, deadline=None)
def test_bucket_is_lossless_partition(mb_nodes):
    nodes = [np.asarray(sorted(set(x)), dtype=np.int64) for x in mb_nodes]
    blocks = [n // 7 for n in nodes]
    bck = build_bucket(nodes, blocks)
    rebuilt = {j: [] for j in range(len(nodes))}
    for r in range(bck.n_rows):
        for mb, ns in bck.row(r):
            rebuilt[mb].extend(ns.tolist())
    for j, n in enumerate(nodes):
        assert sorted(rebuilt[j]) == n.tolist()


@given(st.integers(1, 200), st.integers(1, 50), st.integers(1, 5))
@settings(max_examples=25, deadline=None)
def test_feature_cache_never_lies(capacity, n_rows, threshold):
    """Whatever the cache returns must equal what was admitted for it."""
    n_nodes = 300
    dim = 4
    cache = FeatureCache(capacity, n_nodes, dim, admit_threshold=threshold)
    rng = np.random.default_rng(capacity * 1000 + n_rows)
    truth = rng.normal(size=(n_nodes, dim)).astype(np.float32)
    for _ in range(4):
        nodes = rng.integers(0, n_nodes, n_rows)
        nodes = np.unique(nodes)
        cache.note_access(nodes)
        mask, rows = cache.lookup(nodes)
        if mask.any():
            assert np.allclose(rows, truth[nodes[mask]])
        cache.admit(nodes, truth[nodes])
        assert len(cache) <= max(capacity, 1)


@given(st.integers(2, 64), st.integers(0, 3))
@settings(max_examples=20, deadline=None)
def test_mfg_assembly_invariants(n_dst, pad_rows):
    from repro.core import assemble_layer
    rng = np.random.default_rng(n_dst)
    dst = np.unique(rng.integers(0, 500, n_dst))
    nbrs = rng.integers(-1, 500, (len(dst), 5))
    nxt, layer = assemble_layer(dst, nbrs)
    # self nesting: every dst appears in next layer
    assert np.isin(dst, nxt).all()
    assert np.array_equal(nxt[layer.self_idx], dst)
    valid = layer.nbr_idx >= 0
    assert np.array_equal(np.sort(np.unique(nxt[layer.nbr_idx[valid]])),
                          np.sort(np.unique(nbrs[nbrs >= 0])))
