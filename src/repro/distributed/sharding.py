"""Sharding rules: logical roles → NamedSharding over the production mesh.

Parallelism map (DESIGN.md §5):
* ``data`` (×``pod``)  — batch dim of activations/tokens; ZeRO shard of
  optimizer moments.
* ``model``            — Megatron TP: attention heads / FFN columns /
  vocab rows; **EP**: MoE expert dim; Mamba/xLSTM channel dim.

Rules are *divisibility-guarded*: a dim is sharded over an axis only if
divisible by the axis size, otherwise it stays replicated (e.g.
smollm-360m's 15 heads on a 16-way model axis → realistic choice is DP
with replicated weights, which is what the guard produces).

Params are matched by their tree path (param name), so one rule table
covers every family; stacked scan units get their leading layer dim
prepended automatically.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# name -> (spec builder) ; dims listed for the *unstacked* param
_COL = ("wq", "wk", "wv", "w_gate", "w_up", "w_in", "w", "w_if",
        "s_gate", "s_up")          # (d_in, d_out): shard d_out
_ROW = ("wo", "w_down", "w_out", "s_down", "w_bcdt")  # (d_in, d_out): shard d_in
_EXPERT = ("w_gate", "w_up", "w_down")                # under "moe": (E, ..)
_VEC_MODEL = ("conv_b", "dt_bias", "d_skip")          # (d_inner,)
_REPLICATED = ("router", "b", "b_if", "norm_mixer", "norm_ffn",
               "norm_xattn", "norm_f", "norm_enc")


def _div(n: int, mesh: Mesh, axis: str) -> bool:
    return n % mesh.shape[axis] == 0


def _spec_for(path: tuple, shape: tuple, mesh: Mesh,
              ep_only: bool = False) -> P:
    names = [getattr(p, "name", getattr(p, "key", None)) or str(getattr(p, "idx", ""))
             for p in path]
    name = names[-1]
    in_moe = "moe" in names
    stacked = "units" in names   # leading scan-layer dim
    base = shape[1:] if stacked else shape
    spec: list = [None] * len(base)

    def shard(dim: int, axis: str):
        if 0 <= dim < len(base) and _div(base[dim], mesh, axis):
            spec[dim] = axis

    if ep_only and not (in_moe and name in _EXPERT) and name != "embed":
        if stacked:
            spec = [None] + spec
        return P(*spec)  # dense weights replicate (EP+full-DP mode)
    if name == "embed":
        shard(0, "model")                       # vocab rows
    elif in_moe and name in _EXPERT:
        shard(0, "model")                       # expert parallelism
    elif name == "r":                           # sLSTM (H, dh, 4dh)
        shard(0, "model")
    elif name == "log_a":                       # (d_inner, N)
        shard(0, "model")
    elif name == "conv_w":                      # (K, d_inner)
        shard(1, "model")
    elif name in _VEC_MODEL:
        shard(0, "model")
    elif name in _ROW:
        shard(0, "model")
    elif name in _COL:
        shard(len(base) - 1, "model")
    elif name in _REPLICATED or len(base) <= 1:
        pass
    else:  # default: replicate
        pass
    if stacked:
        spec = [None] + spec
    return P(*spec)


FSDP_THRESHOLD = 128 << 20  # per-device bytes above which we also FSDP-shard


def param_shardings(param_specs: Any, mesh: Mesh,
                    fsdp_threshold: int = FSDP_THRESHOLD,
                    ep_only: bool = False) -> Any:
    """NamedShardings for a param pytree of ShapeDtypeStructs/arrays.

    Tensors still larger than ``fsdp_threshold`` per device after TP get
    FSDP/ZeRO-3 treatment: the largest remaining divisible dim shards
    over the data axes; GSPMD inserts the per-layer all-gather at the use
    site (overlapped by the latency-hiding scheduler).  This is what lets
    jamba-1.5-large's 794 GB of bf16 weights fit 256 × 16 GB chips.
    """
    data_axes = [a for a in ("pod", "data") if a in mesh.shape]
    dsize = int(np.prod([mesh.shape[a] for a in data_axes]))
    d_axis = tuple(data_axes) if len(data_axes) > 1 else data_axes[0]

    def one(path, leaf):
        spec = list(_spec_for(path, leaf.shape, mesh, ep_only=ep_only))
        spec += [None] * (len(leaf.shape) - len(spec))
        model_shards = np.prod([mesh.shape["model"]
                                for s in spec if s == "model"]) or 1
        itemsize = jnp.dtype(getattr(leaf, "dtype", jnp.float32)).itemsize
        per_dev = int(np.prod(leaf.shape)) * itemsize / model_shards
        if fsdp_threshold and per_dev > fsdp_threshold and dsize > 1:
            for d in sorted(range(len(leaf.shape)),
                            key=lambda i: -leaf.shape[i]):
                if spec[d] is None and leaf.shape[d] % dsize == 0:
                    spec[d] = d_axis
                    break
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(one, param_specs)


def opt_state_shardings(param_specs: Any, mesh: Mesh) -> Any:
    """ZeRO-1: moments sharded over data (and pod) axes on top of TP —
    f32 moment memory per chip scales with the full chip count."""
    return param_shardings(param_specs, mesh, fsdp_threshold=1)


def batch_sharding(mesh: Mesh, ndim: int = 2, batch_axis: int = 0,
                   dp_over_model: bool = False) -> NamedSharding:
    """Tokens/labels: batch over (pod, data) [+ model in full-DP mode].

    ``dp_over_model`` is the EP+DP configuration for narrow MoE models
    (deepseek-moe/moonshot: d_model 2048 on a 16-wide TP axis leaves
    128-wide matmul shards — collective-bound).  Batch shards over
    (pod, data, model); experts stay sharded over ``model`` so the MoE
    dispatch becomes the canonical all-to-all on the shared axis, and
    dense weights replicate.
    """
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    if dp_over_model:
        axes = axes + ("model",)
    spec = [None] * ndim
    spec[batch_axis] = axes if len(axes) > 1 else axes[0]
    return NamedSharding(mesh, P(*spec))


def cache_shardings(cache_specs: Any, mesh: Mesh, batch: int,
                    seq_shard_threshold: int = 65536) -> Any:
    """KV/SSM cache shardings for decode.

    Batch shards over (pod, data) when divisible; KV-head dim over
    ``model`` when divisible.  For very long caches with unshardable
    batch (long_500k: B=1) the *sequence* axis shards over data instead —
    flash-decoding style; the LSE-safe softmax in ``decode_attention``
    partitions into (max, sum) all-reduces.
    """
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dsize = int(np.prod([mesh.shape[a] for a in data_axes]))
    d_axis = data_axes if len(data_axes) > 1 else data_axes[0]

    def one(path, leaf):
        shape = leaf.shape
        spec: list = [None] * len(shape)
        names = [getattr(p, "name", "") for p in path]
        if len(shape) == 4:          # attention k/v: (B, Hkv, Sc, dh)
            if shape[0] % dsize == 0:
                spec[0] = d_axis
            elif shape[2] >= seq_shard_threshold and shape[2] % dsize == 0:
                spec[2] = d_axis     # sequence-sharded KV (long_500k)
            if shape[1] % mesh.shape["model"] == 0:
                spec[1] = "model"
            elif spec[2] is None and shape[2] % mesh.shape["model"] == 0:
                # KV heads not divisible (e.g. 5 heads on model=16):
                # shard the sequence axis over model instead; the LSE-safe
                # decode softmax partitions into (max, sum) all-reduces.
                spec[2] = "model"
        elif len(shape) == 3:        # mamba h (B, di, N) / conv (B, K-1, di)
            if shape[0] % dsize == 0:
                spec[0] = d_axis
            if shape[1] % mesh.shape["model"] == 0 and "h" in names[-1:]:
                spec[1] = "model"
            elif shape[2] % mesh.shape["model"] == 0:
                spec[2] = "model"
        elif len(shape) == 2:        # (B, D) states
            if shape[0] % dsize == 0:
                spec[0] = d_axis
            if shape[1] % mesh.shape["model"] == 0:
                spec[1] = "model"
        elif len(shape) == 1:        # slot_pos etc.
            pass
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(one, cache_specs)
