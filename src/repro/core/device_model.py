"""Storage-device cost model + I/O accounting.

The container has no NVMe SSD, so the *timing* of storage I/O is modeled
while the I/O itself is real (bytes move through ``np.memmap`` files).
The model is calibrated to the paper's hardware (Dell R750, PCIe Gen4 NVMe,
~6.7 GB/s per SSD, RAID0 arrays of 1-4 drives).  Counts/bytes/hit-ratios
reported by :class:`IOStats` are exact measurements of the algorithms.

Model (per request):
    t(req)  = latency + bytes / bw           (random)
    t(req)  = bytes / bw                      (sequential follow-on)
Aggregate with queue-depth QD in flight and an n-SSD RAID0 array:
    T(batch) = max(sum_bytes / (bw * n_ssd), n_random * latency / QD)
which captures both the bandwidth-bound regime (large block I/O: AGNES)
and the latency/IOPS-bound regime (many 4 KB reads: Ginex-like).

``n_ssd`` models one *merged* RAID0 array (bandwidth scales, the queue
does not).  Multi-array topologies — N independent devices with their
own queues, placement, and per-array accounting — are modeled above
this layer by ``repro.core.topology``; each array there is a
single-SSD :class:`NVMeModel`.
"""
from __future__ import annotations

import dataclasses
from collections import Counter


@dataclasses.dataclass
class NVMeModel:
    """PCIe Gen4 NVMe SSD (paper's hardware)."""

    bandwidth: float = 6.7e9        # bytes/s, per SSD
    latency: float = 80e-6          # s, random 4K read latency
    queue_depth: int = 32           # in-flight requests
    n_ssd: int = 1                  # RAID0 array size (paper: 1..4)
    min_io: int = 4096              # device sector granularity

    @property
    def array_bandwidth(self) -> float:
        return self.bandwidth * self.n_ssd

    def request_time(self, nbytes: int, sequential: bool = False) -> float:
        nbytes = max(int(nbytes), self.min_io)
        t = nbytes / self.array_bandwidth
        if not sequential:
            t += self.latency
        return t

    def batch_time(self, total_bytes: int, n_random: int, n_sequential: int = 0,
                   queue_depth: int | None = None) -> float:
        """Time for a batch of requests issued with queue-depth overlap.

        ``queue_depth`` caps the submitter's in-flight requests; the device
        cannot overlap more than its own ``self.queue_depth``.
        """
        qd = self.queue_depth if queue_depth is None else queue_depth
        qd = max(min(qd, self.queue_depth), 1)
        total_bytes = max(int(total_bytes), self.min_io * max(n_random + n_sequential, 1))
        bw_bound = total_bytes / self.array_bandwidth
        iops_bound = n_random * self.latency / qd
        return max(bw_bound, iops_bound)


# summary() keys that *rename* raw IOStats fields.  The
# field-completeness test walks every dataclass field and requires it to
# appear in summary() either under its own name or under the rename
# listed here — adding a field without surfacing it fails the test.
SUMMARY_FIELD_MAP = {
    "modeled_read_time": "modeled_read_time_s",
    "modeled_write_time": "modeled_write_time_s",
}


@dataclasses.dataclass
class IOStats:
    """Exact I/O accounting + modeled device time."""

    n_reads: int = 0              # block-granular read count (I/O units)
    n_requests: int = 0           # device requests (drops under coalescing)
    n_writes: int = 0
    n_sequential_reads: int = 0
    bytes_read: int = 0
    bytes_written: int = 0
    modeled_read_time: float = 0.0
    modeled_write_time: float = 0.0
    # background migration traffic (core/migration.py): the copy I/O is
    # charged through record_run_batch / record_write like any other
    # request — these counters additionally isolate how much of the
    # above was re-placement overhead rather than prepare traffic
    n_migrated_blocks: int = 0
    bytes_migrated: int = 0
    size_histogram: Counter = dataclasses.field(default_factory=Counter)

    # cache-level accounting (filled by the buffer layers)
    buffer_hits: int = 0
    buffer_misses: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    # feature-cache rows displaced under capacity pressure; with a
    # writeback device attached (FeatureCache.attach_writeback) each
    # eviction is also charged as a row-granular write above
    cache_evictions: int = 0

    # storage fault domain (core/fault.py): failed read attempts, bounded
    # retries, hedged duplicate reads past the p99 deadline, and block
    # reads served through the degraded (offline-array) path.  The
    # retry/hedge/degraded I/O is charged through record_run_batch /
    # record_stall like any other request — these isolate the overhead.
    io_errors: int = 0
    io_retries: int = 0
    io_hedges: int = 0
    io_degraded: int = 0
    bytes_retried: int = 0
    bytes_hedged: int = 0
    bytes_degraded: int = 0

    # serving tier (core/serving.py): modeled service granted ahead of
    # this tenant by the admission layer, and how often the aging bound
    # overrode the priority order to force a grant.
    admission_wait_s: float = 0.0
    admission_forced_grants: int = 0

    def record_read(self, nbytes: int, t: float, sequential: bool = False) -> None:
        self.n_reads += 1
        self.n_requests += 1
        if sequential:
            self.n_sequential_reads += 1
        self.bytes_read += int(nbytes)
        self.modeled_read_time += t
        self.size_histogram[_bucket(nbytes)] += 1

    def record_run_batch(self, nbytes: int, n_block_reads: int,
                         n_sequential: int, request_sizes, t: float) -> None:
        """Account one batch of coalesced multi-block requests.

        ``n_reads`` stays block-granular (parity with the per-block path);
        ``n_requests`` counts the merged device requests; the histogram
        records the *request* sizes, so coalescing visibly shifts it toward
        larger I/Os.
        """
        self.n_reads += int(n_block_reads)
        self.n_requests += len(request_sizes)
        self.n_sequential_reads += int(n_sequential)
        self.bytes_read += int(nbytes)
        self.modeled_read_time += t
        for s in request_sizes:
            self.size_histogram[_bucket(s)] += 1

    def record_write(self, nbytes: int, t: float,
                     request_sizes=None) -> None:
        """Account a write batch; ``request_sizes`` lists the individual
        device requests (one request of ``nbytes`` when omitted) so
        fig4-style size histograms reflect the full I/O mix, reads and
        writes alike."""
        sizes = list(request_sizes) if request_sizes is not None \
            else [int(nbytes)]
        self.n_writes += len(sizes)
        self.n_requests += len(sizes)
        self.bytes_written += int(nbytes)
        self.modeled_write_time += t
        for s in sizes:
            self.size_histogram[_bucket(s)] += 1

    def note_migration(self, n_blocks: int, nbytes: int) -> None:
        """Tag already-charged copy I/O as block-migration traffic."""
        self.n_migrated_blocks += int(n_blocks)
        self.bytes_migrated += int(nbytes)

    def note_error(self) -> None:
        """One failed physical read attempt (injected or real)."""
        self.io_errors += 1

    def note_retry(self, nbytes: int) -> None:
        """Tag already-charged re-issue I/O as transient-fault retries."""
        self.io_retries += 1
        self.bytes_retried += int(nbytes)

    def note_hedge(self, nbytes: int) -> None:
        """Tag already-charged duplicate I/O as a hedged straggler read."""
        self.io_hedges += 1
        self.bytes_hedged += int(nbytes)

    def note_degraded(self, n_reads: int, nbytes: int) -> None:
        """Tag already-charged I/O as served via the degraded path."""
        self.io_degraded += int(n_reads)
        self.bytes_degraded += int(nbytes)

    def note_admission_wait(self, t: float, forced: bool = False) -> None:
        """Account admission-queue delay (modeled service granted ahead
        of this tenant) without moving bytes; ``forced`` marks a grant
        the aging bound pushed past the priority order."""
        self.admission_wait_s += float(t)
        if forced:
            self.admission_forced_grants += 1

    def record_stall(self, t: float) -> None:
        """Charge exposed stall time (unhedged latency spike, modeled
        retry backoff) against the read roofline without moving bytes."""
        self.modeled_read_time += t

    @property
    def n_ios(self) -> int:
        return self.n_reads + self.n_writes

    @property
    def total_bytes(self) -> int:
        return self.bytes_read + self.bytes_written

    @property
    def modeled_io_time(self) -> float:
        return self.modeled_read_time + self.modeled_write_time

    @property
    def buffer_hit_ratio(self) -> float:
        tot = self.buffer_hits + self.buffer_misses
        return self.buffer_hits / tot if tot else 0.0

    @property
    def cache_hit_ratio(self) -> float:
        tot = self.cache_hits + self.cache_misses
        return self.cache_hits / tot if tot else 0.0

    def achieved_bandwidth(self) -> float:
        """Modeled achieved read bandwidth (bytes/s)."""
        if self.modeled_read_time <= 0:
            return 0.0
        return self.bytes_read / self.modeled_read_time

    def merge(self, other: "IOStats") -> "IOStats":
        """Field-complete fold of ``other`` into ``self``.

        Driven by ``dataclasses.fields`` rather than a hand-maintained
        name list, so a counter added to the dataclass can never be
        silently dropped from per-array merges again (PRs 7-8 each grew
        this struct; the completeness test in ``tests/test_telemetry.py``
        locks both merge and summary coverage).
        """
        for f in dataclasses.fields(self):
            mine = getattr(self, f.name)
            theirs = getattr(other, f.name)
            if isinstance(mine, Counter):
                mine.update(theirs)
            else:
                setattr(self, f.name, mine + theirs)
        return self

    def summary(self) -> dict:
        return {
            "n_reads": self.n_reads,
            "n_requests": self.n_requests,
            "n_writes": self.n_writes,
            "n_sequential_reads": self.n_sequential_reads,
            "sequential_fraction": round(
                self.n_sequential_reads / self.n_reads, 4) if self.n_reads else 0.0,
            "bytes_read": self.bytes_read,
            "bytes_written": self.bytes_written,
            "n_migrated_blocks": self.n_migrated_blocks,
            "bytes_migrated": self.bytes_migrated,
            "modeled_io_time_s": round(self.modeled_io_time, 6),
            "modeled_read_time_s": round(self.modeled_read_time, 6),
            "modeled_write_time_s": round(self.modeled_write_time, 6),
            "achieved_bw_GBps": round(self.achieved_bandwidth() / 1e9, 3),
            "buffer_hits": self.buffer_hits,
            "buffer_misses": self.buffer_misses,
            "buffer_hit_ratio": round(self.buffer_hit_ratio, 4),
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
            "cache_hit_ratio": round(self.cache_hit_ratio, 4),
            "cache_evictions": self.cache_evictions,
            "io_errors": self.io_errors,
            "io_retries": self.io_retries,
            "io_hedges": self.io_hedges,
            "io_degraded": self.io_degraded,
            "bytes_retried": self.bytes_retried,
            "bytes_hedged": self.bytes_hedged,
            "bytes_degraded": self.bytes_degraded,
            "admission_wait_s": round(self.admission_wait_s, 6),
            "admission_forced_grants": self.admission_forced_grants,
            "size_histogram": {int(k): int(v) for k, v
                               in sorted(self.size_histogram.items())},
        }


def _bucket(nbytes: int) -> int:
    """Histogram bucket: power-of-two size class in KiB."""
    kib = max(nbytes // 1024, 1)
    b = 1
    while b < kib:
        b <<= 1
    return b
