"""Fig 8 (EQ2): hyperbatch ablation — AGNES-HB vs AGNES-No.

The paper reports up to 622x; the gap grows as the buffer shrinks
relative to the working set (block-reload thrash).
"""
from __future__ import annotations

from .common import emit, get_dataset, make_agnes, targets_for


def run():
    ds = get_dataset("pa-mini", block_size=256 * 1024)
    targets = targets_for(ds, n_mb=8, mb_size=512)
    for setting, nbytes in (("64MB", 64 << 20), ("8MB", 8 << 20),
                            ("4MB", 4 << 20)):
        hb = make_agnes(ds, setting_bytes=nbytes, hyperbatch=True, block_size=256*1024)
        no = make_agnes(ds, setting_bytes=nbytes, hyperbatch=False, block_size=256*1024)
        hb.prepare(targets, epoch=0)
        no.prepare(targets, epoch=0)
        t_hb = hb.last_report.modeled_io_s
        t_no = no.last_report.modeled_io_s
        io_hb = hb.graph_store.stats.n_reads + hb.feature_store.stats.n_reads
        io_no = no.graph_store.stats.n_reads + no.feature_store.stats.n_reads
        emit(f"fig8/{setting}/agnes_hb", t_hb * 1e6, f"n_ios={io_hb}")
        emit(f"fig8/{setting}/agnes_no", t_no * 1e6, f"n_ios={io_no}")
        emit(f"fig8/{setting}/speedup", 0.0,
             f"{t_no / max(t_hb, 1e-12):.1f}x io_ratio={io_no/max(io_hb,1)}")


if __name__ == "__main__":
    run()
