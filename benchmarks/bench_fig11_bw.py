"""Fig 11: achieved I/O bandwidth utilization (AGNES ~saturates a RAID0
array; node-granular engines stay IOPS-bound)."""
from __future__ import annotations

from .common import (ALL_BASELINES, emit, get_dataset, make_agnes,
                     make_baseline, targets_for)


def run():
    for ds_name in ("ig-mini", "pa-mini"):
        ds = get_dataset(ds_name)
        targets = targets_for(ds, n_mb=4, mb_size=512)
        for n_ssd in (1, 4):
            peak = 6.7e9 * n_ssd
            a = make_agnes(ds, n_ssd=n_ssd)
            a.prepare(targets, epoch=0)
            bw_a = (a.graph_store.stats.bytes_read
                    + a.feature_store.stats.bytes_read) / max(
                a.graph_store.stats.modeled_read_time
                + a.feature_store.stats.modeled_read_time, 1e-12)
            g = make_baseline(ALL_BASELINES["ginex"], ds, n_ssd=n_ssd)
            g.prepare(targets, epoch=0)
            bw_g = (g.csr.stats.bytes_read + g.features.stats.bytes_read) \
                / max(g.csr.stats.modeled_read_time
                      + g.features.stats.modeled_read_time, 1e-12)
            emit(f"fig11/{ds_name}/ssd{n_ssd}/agnes_GBps", bw_a / 1e9,
                 f"util={bw_a/peak*100:.0f}%")
            emit(f"fig11/{ds_name}/ssd{n_ssd}/ginex_GBps", bw_g / 1e9,
                 f"util={bw_g/peak*100:.0f}%")


if __name__ == "__main__":
    run()
