"""Pallas TPU kernel: flash attention (tiled online-softmax), GQA + SWA.

The LM-side compute hot spot.  The paper's insight — organize data
movement around the transfer unit the hardware likes, and batch consumers
per loaded block — is literally what flash attention does one level down
the memory hierarchy: KV tiles are the "blocks" (HBM→VMEM DMAs), and all
query rows of the Q tile are the "hyperbatch" consuming each loaded KV
tile before it is evicted.

Layout: q (B*H, S, D) processed on a grid (bh, q_tiles, kv_tiles);
running max ``m``, normalizer ``l`` and the unnormalized accumulator
``acc`` live in VMEM scratch across the kv_tile loop; the output tile is
written on the last kv step.  Causal + sliding-window masks are applied
per tile, and fully-masked tiles short-circuit (no MXU work) — with
causal + ascending kv order that skips ~half the grid.

Block sizes default to (128, 128): MXU-aligned in both matmul dims.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, kv_steps: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), dtype=jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window

    def _tile():
        q = q_ref[0].astype(jnp.float32)                    # (bq, d)
        k = k_ref[0].astype(jnp.float32)                    # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                                  # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        pv = jax.lax.dot_general(p, v_ref[0].astype(jnp.float32),
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv

    if causal or window > 0:
        # skip tiles that are fully masked (the mask above is static per
        # (qi, ki) only in the diagonal sense; compute reachability)
        first_q = qi * block_q
        last_q = first_q + block_q - 1
        first_k = ki * block_k
        last_k = first_k + block_k - 1
        reach = jnp.array(True)
        if causal:
            reach &= first_k <= last_q
        if window > 0:
            reach &= last_k > first_q - window
        pl.when(reach)(_tile)
    else:
        _tile()

    @pl.when(ki == kv_steps - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_kernel(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           causal: bool = True, window: int = 0,
                           scale: float | None = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False) -> jnp.ndarray:
    """q: (B, Hq, S, D); k/v: (B, Hkv, S, D) → (B, Hq, S, D).

    GQA handled by folding the group into the batch*head grid axis and
    pointing the K/V BlockSpecs at head ``h // (Hq // Hkv)``.
    """
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    g = Hq // Hkv
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    q_steps = pl.cdiv(S, block_q)
    kv_steps = pl.cdiv(S, block_k)

    qr = q.reshape(B * Hq, S, D)
    kr = k.reshape(B * Hkv, S, D)
    vr = v.reshape(B * Hkv, S, D)

    kern = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, kv_steps=kv_steps)

    out = pl.pallas_call(
        kern,
        grid=(B * Hq, q_steps, kv_steps),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, D), lambda bh, qi, ki: (bh // g, ki, 0)),
            pl.BlockSpec((1, block_k, D), lambda bh, qi, ki: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # normalizer l
            pltpu.VMEM((block_q, D), jnp.float32),   # accumulator
        ],
        out_shape=jax.ShapeDtypeStruct((B * Hq, S, D), q.dtype),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, Hq, S, D)
