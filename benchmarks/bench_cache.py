"""Cache-policy duel: oracle (Belady MIN) vs clock vs LRU at equal capacity.

Ginex's observation, measured end-to-end: storage-based GNN training
knows its feature-access trace before the first gather I/O (here the
epoch plan *is* the trace — a 0-hop feature-serving workload), so the
feature cache can run Belady's MIN instead of a recency heuristic.  The
workload is built to make the cache the only lever:

* **zipf-skewed targets** over a permuted node space — the hot rows are
  scattered across feature blocks, so block-buffer locality cannot
  absorb the skew (every cache miss is a real block read);
* a **feature buffer far smaller than the hot set** — re-reads hit
  storage, not the buffer;
* an **equal, finite row budget** for all three policies, ~4x smaller
  than the hot set, with ``cache_writeback=True`` — evictions are
  charged as row-granular writes, so churn costs modeled device time,
  not just miss counts.

All three engines run the identical plan; gathered features are asserted
byte-identical every minibatch (a cache policy moves I/O, never bytes).
The oracle engine additionally drives the device-resident gather
(``DeviceFeatureTable`` + masked Pallas path): cache hits are served
HBM→HBM and only miss rows cross the host boundary, with byte parity
asserted against the host features and the host-traffic fraction
reported.

Acceptance gates (tracked in ``BENCH_cache.json``, guarded by
``benchmarks.check_regression``):

* oracle >= ``MIN_SPEEDUP`` (1.3x) over clock on modeled prepare I/O
  time (reads + eviction writebacks) at equal capacity;
* oracle misses <= clock and <= LRU misses on the same trace;
* byte parity across policies and across the device-resident path.

Fixed geometry in both tiers: a deterministic policy A/B at container
scale, not a scaling measurement.
"""
from __future__ import annotations

import os

import numpy as np

from .common import WORKDIR, emit

from repro.core import (AgnesConfig, AgnesEngine, FeatureBlockStore,
                        GraphBlockStore, NVMeModel, trace_from_plan)

MIN_SPEEDUP = 1.3       # oracle vs clock, writeback churn charged

N_NODES = 4_096
RING_K = 2              # minimal graph (0-hop: never sampled)
F_DIM = 128             # 512 B rows
F_BLOCK = 4_096         # 8 rows per feature block -> 512 blocks
G_BLOCK = 2_048
N_TARGETS = 8_192       # zipf-skewed accesses (with repeats)
ZIPF_A = 1.3
MB, HB = 64, 2          # 128 targets per gather cycle -> 64 oracle steps
CAPACITY = 192          # rows, ~4x smaller than the zipf hot set
FEAT_BUF = 2 * F_BLOCK  # buffer ~= 2 blocks: re-reads hit storage


def _build_workload() -> tuple[str, str]:
    os.makedirs(WORKDIR, exist_ok=True)
    gpath = os.path.join(WORKDIR, "cache_duel.graph")
    fpath = os.path.join(WORKDIR, "cache_duel.feat")
    if not os.path.exists(gpath + ".meta.json"):
        offs = np.concatenate([np.arange(-RING_K, 0),
                               np.arange(1, RING_K + 1)])
        indices = ((np.arange(N_NODES)[:, None] + offs[None, :])
                   % N_NODES).astype(np.int64).ravel()
        indptr = np.arange(N_NODES + 1, dtype=np.int64) * (2 * RING_K)
        GraphBlockStore.build(gpath, indptr, indices, block_size=G_BLOCK)
    if not os.path.exists(fpath + ".meta.json"):
        rng = np.random.default_rng(11)
        feats = rng.normal(0, 1, (N_NODES, F_DIM)).astype(np.float32)
        FeatureBlockStore.build(fpath, feats, block_size=F_BLOCK)
    return gpath, fpath


def _targets() -> np.ndarray:
    """Zipf ranks mapped through a permutation: hot rows scatter across
    feature blocks, so the cache — not block locality — absorbs them."""
    rng = np.random.default_rng(5)
    perm = rng.permutation(N_NODES)
    ranks = np.minimum(rng.zipf(ZIPF_A, size=N_TARGETS) - 1, N_NODES - 1)
    return perm[ranks]


def _engine(gpath: str, fpath: str, policy: str) -> AgnesEngine:
    g = GraphBlockStore.open(gpath, NVMeModel())
    f = FeatureBlockStore.open(fpath, NVMeModel())
    cfg = AgnesConfig(block_size=G_BLOCK, minibatch_size=MB,
                      hyperbatch_size=HB, fanouts=(),
                      graph_buffer_bytes=64 << 10,
                      feature_buffer_bytes=FEAT_BUF,
                      cache_policy=policy, cache_capacity_rows=CAPACITY,
                      cache_admit_threshold=1, cache_writeback=True,
                      async_io=False)
    return AgnesEngine(g, f, cfg)


def _feature_io_s(eng: AgnesEngine) -> float:
    st = eng.feature_store.stats
    return st.modeled_read_time + st.modeled_write_time


def run() -> dict:
    gpath, fpath = _build_workload()
    targets = _targets()
    engines = {p: _engine(gpath, fpath, p)
               for p in ("clock", "lru", "oracle")}
    plan = engines["oracle"].plan_epoch(targets, epoch=0, shuffle=False)
    # 0-hop: the epoch plan IS the feature-access trace (no sampling)
    engines["oracle"].install_cache_oracle(trace_from_plan(plan))
    table = engines["oracle"].device_feature_table()
    n_rows_total = 0
    for mbs in plan:
        prepared = {p: eng.prepare(mbs, epoch=0)
                    for p, eng in engines.items()}
        for pc, pl, po in zip(prepared["clock"], prepared["lru"],
                              prepared["oracle"]):
            # a cache policy moves I/O, never bytes
            assert np.array_equal(pc.features, po.features), \
                "clock vs oracle: gathered features diverged"
            assert np.array_equal(pl.features, po.features), \
                "lru vs oracle: gathered features diverged"
            # device-resident landing: HBM hits + host-scattered misses
            n = po.features.shape[0]
            n_rows_total += n
            dv = po.to_device(backend="pallas", table=table)
            got = np.asarray(dv.features)
            assert np.array_equal(got[:n], po.features), \
                "device-resident gather diverged from host features"
            assert (got[n:] == 0).all(), "jit padding rows must be zero"
    stats = {p: eng.feature_cache.stats for p, eng in engines.items()}
    # the oracle never misses more than either heuristic on its trace
    for p in ("clock", "lru"):
        assert stats["oracle"].cache_misses <= stats[p].cache_misses, \
            (f"oracle missed {stats['oracle'].cache_misses} > {p} "
             f"{stats[p].cache_misses} — MIN property violated")
    io_s = {p: _feature_io_s(eng) for p, eng in engines.items()}
    speedup = io_s["clock"] / max(io_s["oracle"], 1e-12)
    speedup_lru = io_s["lru"] / max(io_s["oracle"], 1e-12)
    # acceptance gate: knowing the future is worth >= MIN_SPEEDUP at
    # equal capacity, with the eviction writeback traffic fully charged
    assert speedup >= MIN_SPEEDUP, \
        (f"oracle cache regression: {speedup:.3f}x < {MIN_SPEEDUP}x vs "
         f"clock at capacity {CAPACITY}")
    total_bytes = n_rows_total * engines["oracle"].feature_cache.row_bytes
    hbm_fraction = table.hit_rows_served / max(
        table.hit_rows_served + table.host_rows_shipped, 1)
    emit("cache/speedup", speedup,
         f"{io_s['clock']*1e3:.2f}ms -> {io_s['oracle']*1e3:.2f}ms "
         f"modeled prepare I/O, capacity {CAPACITY} rows")
    emit("cache/speedup_vs_lru", speedup_lru,
         f"lru {io_s['lru']*1e3:.2f}ms at the same capacity")
    emit("cache/hbm_hit_fraction", hbm_fraction,
         f"{table.host_bytes_shipped}/{total_bytes} bytes crossed "
         f"host->device")
    out = {
        "workload": {"n_nodes": N_NODES, "dim": F_DIM,
                     "feature_block": F_BLOCK, "n_targets": N_TARGETS,
                     "zipf_a": ZIPF_A, "capacity_rows": CAPACITY,
                     "minibatch": MB, "hyperbatch": HB},
        "speedup": round(speedup, 3),
        "speedup_vs_lru": round(speedup_lru, 3),
        "io_s": {p: round(v, 6) for p, v in io_s.items()},
        "misses": {p: stats[p].cache_misses for p in engines},
        "evictions": {p: stats[p].cache_evictions for p in engines},
        "hit_ratio": {p: round(stats[p].cache_hit_ratio, 4)
                      for p in engines},
        "device": {**table.stats(),
                   "hbm_hit_fraction": round(hbm_fraction, 4),
                   "total_feature_bytes": total_bytes},
    }
    for eng in engines.values():
        eng.close()
    return out


if __name__ == "__main__":
    print(run())
