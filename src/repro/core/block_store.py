"""Storage layer (paper §3.2): block-organized graph + feature stores.

Graph topology and node features are split into fixed-size *blocks* (the
storage I/O unit, default 1 MiB).  Two block types:

* **Graph block** — multiple *objects* (a node + its adjacency list) packed
  in ascending node-ID order.  An object larger than one block is split
  across consecutive blocks (paper: "the object is split across multiple
  blocks").  On-disk format per block (int32 words), directory-first so
  decode is fully vectorized::

      [n_entries][node_id x n][count x n][total_degree x n][neighbors ...]

  ``count`` is the number of neighbors in *this block's* entry; an object
  split across blocks has several entries whose counts sum to
  ``total_degree``.

* **Feature block** — ``rows_per_block`` consecutive nodes' feature rows,
  row ``v`` living in block ``v // rows_per_block``.

The *object index table* ``T_obj`` keeps only (first_node, last_node) per
graph block (paper: "we only store the first and last object indices for
each block"), is pinned in memory, and locates blocks via binary search.
Both stores do real file I/O through ``np.memmap`` and charge the device
model for every block touched.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import zlib

import numpy as np

from .device_model import IOStats, NVMeModel
from .hotness import HotnessTracker
from .io_sched import Run, coalesce, plan_cost
from .topology import (BlockPlacement, StorageTopology,
                       distribute_offline_runs, fsync_dir,
                       topology_plan_cost)

DEFAULT_BLOCK_SIZE = 1 << 20  # 1 MiB (paper default)
_HDR = 3  # directory words per entry: node_id, count, total_degree
_MIGRATE_LOG = ".migrate.log"   # block-copy journal (crash consistency)
_TOPO_TMP = ".topo.json.tmp"    # atomic-save staging file
_JREC = 5   # int64 header words per journal record:
#             [block_id, src_array, dst_array, nbytes, crc32(raw)]
_JSEAL = -1  # block_id of the seal record marking the copy phase complete


def _parse_migration_journal(journal: str) -> tuple[list, bool]:
    """Parse a ``<store>.migrate.log`` into its records.

    Returns ``(records, sealed)`` with ``records = [(block, src, dst,
    raw_bytes), ...]`` — only records whose header, payload and CRC are
    fully intact — and ``sealed`` true iff the terminal seal record is
    present and its count matches (the copy phase provably completed).
    Any torn tail (truncated header/payload, CRC mismatch, missing
    seal) yields ``sealed=False``: roll-back territory.
    """
    try:
        with open(journal, "rb") as fh:
            data = fh.read()
    except OSError:
        return [], False
    recs: list = []
    off, hdr_bytes = 0, _JREC * 8
    while off + hdr_bytes <= len(data):
        hdr = np.frombuffer(data, dtype=np.int64, count=_JREC, offset=off)
        off += hdr_bytes
        b, src, dst, n, crc = (int(x) for x in hdr)
        if b == _JSEAL:
            return recs, src == len(recs)  # seal carries the record count
        if n < 0 or off + n > len(data):
            return recs, False  # payload torn off
        raw = data[off:off + n]
        off += n
        if zlib.crc32(raw) != crc & 0xFFFFFFFF:
            return recs, False  # payload corrupted mid-record
        recs.append((b, src, dst, raw))
    return recs, False  # ran out of bytes before the seal


def replay_migration_journal(path: str) -> str:
    """Replay a leftover ``<path>.migrate.log`` against the committed
    ``<path>.topo.json``.

    Rolls the interrupted migration *forward* when the copy phase
    provably completed — the journal is sealed, every record's CRC
    holds, the committed mapping still has every block at its journaled
    source, and the journaled bytes match the data file — by re-applying
    the journaled moves in journal order (identical slot assignment to
    the uninterrupted ``migrate_blocks``) and committing the mapping
    atomically.  Rolls *backward* (keeps the committed old mapping)
    otherwise.  Either way the store is byte-identical — the data file
    is never touched by migration — and placement-consistent.

    Returns the action taken: ``"rolled_forward"``, ``"rolled_back"``
    or ``"already_committed"`` (crash landed after the commit rename;
    the new mapping is already durable).  Does not remove the journal.
    """
    recs, sealed = _parse_migration_journal(path + _MIGRATE_LOG)
    if not recs or not sealed or not os.path.exists(path + ".topo.json"):
        return "rolled_back"
    pl = BlockPlacement.load(path)
    if not all(0 <= b < pl.n_blocks and 0 <= dst < pl.n_arrays
               for b, _, dst, _ in recs):
        return "rolled_back"  # journal from a different store shape
    if all(int(pl.array_of[b]) == dst for b, _, dst, _ in recs):
        return "already_committed"
    if not all(int(pl.array_of[b]) == src for b, src, _, _ in recs):
        return "rolled_back"  # mapping matches neither side of the move
    # byte-verify the copy against the data file (uniform block records:
    # block b's bytes start at b * record_length in both store formats)
    lengths = {len(raw) for _, _, _, raw in recs}
    if len(lengths) != 1:
        return "rolled_back"
    blen = lengths.pop()
    with open(path, "rb") as fh:
        for b, _, _, raw in recs:
            fh.seek(b * blen)
            if fh.read(blen) != raw:
                return "rolled_back"
    for b, _, dst, _ in recs:
        pl.move_block(b, dst)
    pl.save(path)  # atomic commit, exactly as migrate_blocks would have
    return "rolled_forward"


def recover_store_metadata(path: str) -> dict:
    """Recover partial migration/placement state left by a crash.

    The migration protocol (``migrate_blocks``) is: journal every moved
    block's bytes (+ source/destination/CRC) to ``<path>.migrate.log``
    and seal it + fsync, then atomically commit the new
    ``<path>.topo.json`` via temp-file + ``os.replace``, then remove the
    journal.  The committed ``topo.json`` is therefore always a complete
    old or complete new mapping, and the data file is never touched.
    Recovery at store open:

    * a leftover ``.topo.json.tmp`` is a save that died mid-write —
      discarded (the committed file is intact by construction);
    * a leftover journal is **replayed** (:func:`replay_migration_
      journal`): rolled forward when the copy provably completed
      (sealed + CRC + byte-verified against the data file), rolled back
      otherwise — then removed.

    Returns ``{suffix: action}`` describing what was found
    (``".topo.json.tmp"`` maps to the discarded temp file's size,
    ``".migrate.log"`` to the replay outcome).
    """
    actions: dict = {}
    tmp = path + _TOPO_TMP
    if os.path.exists(tmp):
        actions[_TOPO_TMP] = os.path.getsize(tmp)
        os.remove(tmp)
    journal = path + _MIGRATE_LOG
    if os.path.exists(journal):
        actions[_MIGRATE_LOG] = replay_migration_journal(path)
        os.remove(journal)
        fsync_dir(journal)
    return actions


@dataclasses.dataclass
class GraphBlock:
    """A decoded graph block: local CSR over the entries it contains."""

    block_id: int
    node_ids: np.ndarray      # (n_entries,) ascending (may repeat across blocks)
    indptr: np.ndarray        # (n_entries + 1,) into indices
    indices: np.ndarray       # concatenated neighbor ids
    total_degree: np.ndarray  # (n_entries,) full degree of each object

    def adjacency(self, entry: int) -> np.ndarray:
        return self.indices[self.indptr[entry]:self.indptr[entry + 1]]

    def find_entries(self, nodes: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Locate (first) entry index for each node; mask=False if absent."""
        pos = np.searchsorted(self.node_ids, nodes, side="left")
        pos_c = np.clip(pos, 0, len(self.node_ids) - 1)
        mask = (pos < len(self.node_ids)) & (self.node_ids[pos_c] == nodes)
        return pos_c, mask


class _BlockReadBatcher:
    """Store-side half of the coalesced I/O protocol (io_sched.py).

    Mixed into both stores: hosts must provide ``block_size``, ``device``,
    ``stats``, ``_io_lock``, ``_last_block_read`` and
    ``read_run(start, count)`` (one memmap slice, vectorized decode, no
    accounting).

    Also the store-side half of the storage-topology protocol
    (``topology.py``): :meth:`attach_topology` binds a
    :class:`StorageTopology` + :class:`BlockPlacement`, after which
    coalesced runs are split at stripe boundaries into per-array runs
    and every read is charged on its *owning array's* device — the
    ``max``-over-arrays roofline instead of one merged device.
    """

    topology: StorageTopology | None = None
    placement: BlockPlacement | None = None
    hotness: HotnessTracker | None = None
    fault = None  # FaultInjector (core/fault.py), None = no injection

    def attach_fault(self, injector) -> None:
        """Bind a :class:`~repro.core.fault.FaultInjector`: the coalesced
        reader consults it on every physical read attempt against this
        store, and ``migrate_blocks`` on every journal write.  One
        injector may be shared across stores (engine-wide op counter)."""
        self.fault = injector

    def account_fault_io(self, array: int, nbytes: int, n_blocks: int,
                         t: float, kind: str) -> None:
        """Charge fault-path I/O like any other request, tagged by kind.

        ``kind``: ``"retry"`` (transient-fault re-issue — full bytes +
        modeled backoff), ``"hedge"`` (duplicate straggler read on a
        sibling array), ``"degraded"`` (offline-array read served by a
        survivor — *counter only*: its modeled time and bytes were
        already charged at submission, where ``account_runs`` reroutes
        offline shares onto the survivor's batched roofline), ``"stall"``
        (exposed latency with no extra bytes), or ``"error"`` (a failed
        attempt — counter only).  Retry/hedge bytes land in
        ``bytes_read`` exactly like prepare traffic, so rooflines and
        parity checks see the overhead.
        """

        def charge(st: IOStats) -> None:
            if kind == "error":
                st.note_error()
            elif kind == "stall":
                st.record_stall(t)
            elif kind == "degraded":
                st.note_degraded(n_blocks, nbytes)
            else:
                st.record_run_batch(nbytes, n_blocks,
                                    max(n_blocks - 1, 0), [nbytes], t)
                if kind == "retry":
                    st.note_retry(nbytes)
                elif kind == "hedge":
                    st.note_hedge(nbytes)
                else:
                    raise ValueError(f"unknown fault I/O kind {kind!r}")

        with self._io_lock:
            charge(self.stats)
        if self.topology is not None and self.placement is not None:
            with self.topology.lock:
                charge(self.topology.array_stats[int(array)])

    def attach_hotness(self, tracker: HotnessTracker) -> None:
        """Bind a :class:`HotnessTracker`: every storage touch charged
        through this store (coalesced submissions, per-block reads,
        node-granular rows) is recorded per block — the empirical
        replacement for the static degree proxies (``core/hotness.py``)."""
        if tracker.n_blocks != self.n_blocks:
            raise ValueError(
                f"tracker covers {tracker.n_blocks} blocks, "
                f"store has {self.n_blocks}")
        self.hotness = tracker

    def attach_topology(self, topology: StorageTopology,
                        placement: BlockPlacement,
                        persist: bool = True) -> None:
        """Bind this store's blocks to a multi-array topology.

        ``persist=True`` writes the ``block_id -> (array, local_block)``
        mapping into the store's on-disk directory
        (``<path>.topo.json``) so a reopened store can
        :meth:`load_placement` the same layout.
        """
        if placement.n_blocks != self.n_blocks:
            raise ValueError(
                f"placement covers {placement.n_blocks} blocks, "
                f"store has {self.n_blocks}")
        if placement.n_arrays > topology.n_arrays:
            raise ValueError("placement references more arrays than the "
                             "topology has")
        self.topology = topology
        self.placement = placement
        # per-array sequential-access detection in *local* coordinates
        self._last_local_read = np.full(topology.n_arrays, -2, dtype=np.int64)
        if persist:
            placement.save(self.path)

    def load_placement(self, topology: StorageTopology) -> BlockPlacement:
        """Re-attach the persisted on-disk placement (``<path>.topo.json``)."""
        placement = BlockPlacement.load(self.path)
        self.attach_topology(topology, placement, persist=False)
        return placement

    def read_blocks(self, block_ids, max_coalesce_bytes: int = 0,
                    queue_depth: int | None = None) -> list:
        """Vectorized batch read: coalesced requests, batch-time charging.

        Returns decoded blocks in ascending-id order.  With
        ``max_coalesce_bytes=0`` every block is its own request (batched
        submission without merging); bytes read are identical to a
        ``read_block`` loop either way.
        """
        runs = coalesce(block_ids, self.block_size, max_coalesce_bytes)
        qd = queue_depth if queue_depth is not None else self.device.queue_depth
        self.account_runs(runs, qd, max_coalesce_bytes=max_coalesce_bytes)
        out: list = []
        for r in runs:
            out.extend(self.read_run(r.start, r.count))
        return out

    def account_runs(self, runs: list[Run], queue_depth, stream=None,
                     max_coalesce_bytes: int = 0) -> None:
        """Charge a submitted plan of coalesced runs.

        With ``stream=None`` the plan is an isolated batch at queue-depth
        overlap (:func:`plan_cost`).  With a :class:`PlanStream` the
        submission fuses into the stream's open batch and is charged only
        its incremental cost (cross-hop plan fusion).

        With a placement attached the runs are first split at stripe
        boundaries into per-array local runs (re-merged where stripes
        are physically adjacent on one array, capped at
        ``max_coalesce_bytes``) and the submission costs the ``max``
        over per-array rooflines; ``queue_depth`` may be a per-array
        mapping.  Bytes are identical either way — splitting reshapes
        requests, never what is read.
        """
        if not runs:
            return
        if self.hotness is not None:
            self.hotness.touch_runs(runs)
        if self.placement is not None:
            placed = self.placement.split_runs(runs, self.block_size,
                                               max_coalesce_bytes)
            # degraded mode: shares placed on an offline array are served
            # (and charged) across *all* survivors — each stranded run is
            # cut into near-equal pieces riding the surviving rooflines
            # in parallel until the epoch-boundary evacuation re-places
            # the blocks for good.  The degraded *counters* tick at read
            # time (``CoalescedReader._read_degraded``), where service
            # through the recovery path actually happens
            served = [(a, own + rec, bool(rec))
                      for a, own, rec in distribute_offline_runs(
                          placed, self.topology) if own or rec]
            entries = [(self.topology.devices[a], rs,
                        self.topology.queue_depth_of(queue_depth, a))
                       for a, rs, _ in served]
            if stream is not None:
                total, n_blocks, n_seq, t = stream.charge_split(
                    entries, self.block_size)
            else:
                total, n_blocks, n_seq, t = topology_plan_cost(
                    [(a, rs) for a, rs, _ in served], self.block_size,
                    self.topology, queue_depth)
            sizes = [r.count * self.block_size for _, rs, _ in served
                     for r in rs]
            # per-array utilization accounting: each array's isolated
            # roofline for its share of this submission
            with self.topology.lock:
                for (a, rs, _), (dev, _, qd) in zip(served, entries):
                    nb = sum(r.count for r in rs)
                    busy = dev.batch_time(nb * self.block_size,
                                          n_random=len(rs),
                                          n_sequential=nb - len(rs),
                                          queue_depth=qd)
                    st = self.topology.array_stats[a]
                    st.record_run_batch(
                        nb * self.block_size, nb, nb - len(rs),
                        [r.count * self.block_size for r in rs], busy)
        else:
            qd = queue_depth if not isinstance(queue_depth, dict) \
                else queue_depth.get(0, self.device.queue_depth)
            if stream is not None:
                total, n_blocks, n_seq, t = stream.charge(
                    runs, self.block_size, qd)
            else:
                total, n_blocks, n_seq, t = plan_cost(runs, self.block_size,
                                                      self.device, qd)
            sizes = [r.count * self.block_size for r in runs]
        with self._io_lock:
            self.stats.record_run_batch(total, n_blocks, n_seq, sizes, t)
            self._last_block_read = runs[-1].stop - 1
            if self.placement is not None:
                # seed per-array sequential detection: a following
                # per-block read locally adjacent to a batch's tail must
                # stream sequential, like _last_block_read does above
                # (offline arrays excluded — their local lattice is moot)
                for a, rs in placed:
                    if rs and self.topology.is_online(a):
                        self._last_local_read[a] = rs[-1].stop - 1

    def _record_block_read_locked(self, block_id: int) -> None:
        """Charge one block-granular read on its owning array (or the
        single device), with sequential detection in that array's local
        block coordinates.  Caller holds ``_io_lock``."""
        if self.hotness is not None:
            self.hotness.touch([block_id])
        if self.placement is not None:
            a = int(self.placement.array_of[block_id])
            if not self.topology.is_online(a):
                # degraded: the block's array is offline — serve and
                # charge the read (random: the survivor has no local
                # adjacency for foreign blocks) on the least-busy one
                eff = self.topology.degraded_target()
                dev = self.topology.devices[eff]
                t = dev.request_time(self.block_size, sequential=False)
                self.stats.record_read(self.block_size, t, sequential=False)
                self.stats.note_degraded(1, self.block_size)
                self._last_block_read = block_id
                with self.topology.lock:
                    st = self.topology.array_stats[eff]
                    st.record_read(self.block_size, t, sequential=False)
                    st.note_degraded(1, self.block_size)
                return
            loc = int(self.placement.local_of[block_id])
            sequential = loc == self._last_local_read[a] + 1
            self._last_local_read[a] = loc
            dev = self.topology.devices[a]
        else:
            sequential = block_id == self._last_block_read + 1
            dev = self.device
        self._last_block_read = block_id
        t = dev.request_time(self.block_size, sequential=sequential)
        self.stats.record_read(self.block_size, t, sequential=sequential)
        if self.placement is not None:
            with self.topology.lock:
                self.topology.array_stats[a].record_read(
                    self.block_size, t, sequential=sequential)

    # ---------------------------------------------------------- migration
    def read_block_bytes(self, block_id: int) -> bytes:
        """Raw on-disk bytes of one block (the migration copy unit)."""
        raise NotImplementedError

    def migrate_blocks(self, moves, queue_depth=None, _fault=None) -> int:
        """Durably move blocks between arrays (``core/migration.py``).

        ``moves`` is ``[(block_id, dst_array), ...]``.  Protocol, in
        order, with a crash at any point leaving the store loadable:

        1. **copy** — every moved block's bytes are read from the data
           file and appended to the journal ``<path>.migrate.log``
           (real file I/O on behalf of the destination array), then
           fsynced.  Reads are charged to the *source* arrays and
           writes to the *destination* arrays — migration competes in
           the same per-array rooflines as the prepare path;
        2. **commit** — the updated ``block_id -> (array, local)``
           mapping is rewritten atomically (``BlockPlacement.save``:
           temp file + ``os.replace``).  This rename is the linearization
           point: before it the old placement is on disk, after it the
           new one — never a torn mix;
        3. **free** — the journal is removed and the freed source slots
           are returned to their arrays' free lists
           (``BlockPlacement.move_block``).

        ``recover_store_metadata`` (run at store open) **replays** a
        leftover journal from a crash between the steps: forward when
        the sealed, CRC'd copy byte-verifies against the data file
        (finishing the interrupted migration), backward otherwise.
        Returns the number of blocks moved.  ``_fault`` is a test hook
        called with ``"copied"`` and ``"committed"`` at the two crash
        windows; an attached :class:`~repro.core.fault.FaultInjector`
        additionally sees every journal write (torn-write faults).
        """
        if self.placement is None or self.topology is None:
            raise RuntimeError("migrate_blocks needs an attached topology")
        pl, topo = self.placement, self.topology
        moves = [(int(b), int(dst)) for b, dst in moves
                 if int(dst) != int(pl.array_of[int(b)])]
        if not moves:
            return 0
        dst_of = dict(moves)
        if len(dst_of) != len(moves):
            raise ValueError("duplicate block in migration plan")
        ids = np.sort(np.fromiter(dst_of, dtype=np.int64, count=len(dst_of)))
        with self._io_lock:
            # -------- copy: journal the moved blocks' bytes (with their
            # source/destination arrays and a CRC), seal, then fsync.
            # The seal record proves the copy phase completed, so
            # recovery can tell a replayable journal from a torn one.
            journal = self.path + _MIGRATE_LOG
            with open(journal, "wb") as jf:
                for b in ids.tolist():
                    raw = self.read_block_bytes(b)
                    np.asarray([b, int(pl.array_of[b]), dst_of[b],
                                len(raw), zlib.crc32(raw)],
                               dtype=np.int64).tofile(jf)
                    jf.write(raw)
                np.asarray([_JSEAL, len(ids), 0, 0, 0],
                           dtype=np.int64).tofile(jf)
                jf.flush()
                os.fsync(jf.fileno())
            fsync_dir(journal)  # the journal's existence must survive too
            if self.fault is not None:
                # injected torn-write: truncates the journal on disk and
                # raises — the simulated crash window recovery tests and
                # bench_faults exercise end to end
                self.fault.on_journal_write(journal)
            # copy reads are charged against the *source* placement, so
            # this must precede the moves
            self._charge_migration_reads(ids, queue_depth)
            if _fault is not None:
                _fault("copied")
            # -------- commit: atomic metadata rewrite (the linearization
            # point — old mapping before the rename, new mapping after)
            for b in ids.tolist():
                pl.move_block(b, dst_of[b])
            # write charges come from the *actual* destination slots the
            # moves landed on (free-list reuse can scatter them)
            self._charge_migration_writes(ids, dst_of, queue_depth)
            pl.save(self.path)
            if _fault is not None:
                _fault("committed")
            # -------- free: drop the journal, reset sequential detection
            os.remove(journal)
            self._last_local_read = np.full(topo.n_arrays, -2,
                                            dtype=np.int64)
            self._last_block_read = -2
        return len(ids)

    def _migration_qd(self, queue_depth, array: int) -> int:
        return self.topology.queue_depth_of(
            queue_depth if queue_depth is not None
            else self.topology.devices[array].queue_depth, array)

    def _charge_migration_reads(self, ids: np.ndarray,
                                queue_depth=None) -> None:
        """Charge the copy's read side on the *source* arrays (call
        before the moves are applied).  Caller holds ``_io_lock``; takes
        the topology lock itself."""
        pl, topo, bs = self.placement, self.topology, self.block_size
        placed = pl.split_runs(coalesce(ids, bs, 8 << 20), bs, 8 << 20)
        # evacuation: copy reads whose source array is offline come
        # through the survivors' recovery path, each stranded run spread
        # across every online array (recovery I/O competes with prepare
        # traffic, so no single survivor should eat the whole copy)
        read_t = 0.0
        read_blocks = read_seq = 0
        degraded_blocks = 0
        read_sizes: list[int] = []
        with topo.lock:
            for a, own, rec in distribute_offline_runs(placed, topo):
                rs = own + rec
                if not rs:
                    continue
                nb = sum(r.count for r in rs)
                t = topo.devices[a].batch_time(
                    nb * bs, n_random=len(rs), n_sequential=nb - len(rs),
                    queue_depth=self._migration_qd(queue_depth, a))
                sizes = [r.count * bs for r in rs]
                st = topo.array_stats[a]
                st.record_run_batch(nb * bs, nb, nb - len(rs), sizes, t)
                st.note_migration(nb, nb * bs)
                rec_nb = sum(r.count for r in rec)
                if rec_nb:
                    st.note_degraded(rec_nb, rec_nb * bs)
                    degraded_blocks += rec_nb
                read_t = max(read_t, t)
                read_blocks += nb
                read_seq += nb - len(rs)
                read_sizes.extend(sizes)
        nbytes = int(len(ids)) * bs
        self.stats.record_run_batch(nbytes, read_blocks, read_seq,
                                    read_sizes, read_t)
        self.stats.note_migration(int(len(ids)), nbytes)
        if degraded_blocks:
            self.stats.note_degraded(degraded_blocks, degraded_blocks * bs)

    def _charge_migration_writes(self, ids: np.ndarray, dst_of: dict,
                                 queue_depth=None) -> None:
        """Charge the copy's write side on the *destination* arrays from
        the local slots the moves actually landed on — fresh tail slots
        stream sequentially, reused free-list slots pay random heads.
        Call after the moves are applied; caller holds ``_io_lock``."""
        pl, topo, bs = self.placement, self.topology, self.block_size
        dst_arrays = np.asarray([dst_of[int(b)] for b in ids],
                                dtype=np.int64)
        write_t = 0.0
        write_sizes: list[int] = []
        with topo.lock:
            for a in np.unique(dst_arrays).tolist():
                loc = np.sort(pl.local_of[ids[dst_arrays == a]])
                k = int(loc.size)
                n_runs = int((np.diff(loc) != 1).sum()) + 1
                t = topo.devices[a].batch_time(
                    k * bs, n_random=n_runs, n_sequential=k - n_runs,
                    queue_depth=self._migration_qd(queue_depth, a))
                cuts = np.nonzero(np.diff(loc) != 1)[0] + 1
                sizes = [len(seg) * bs for seg in np.split(loc, cuts)]
                topo.array_stats[a].record_write(
                    k * bs, t, request_sizes=sizes)
                topo.array_stats[a].note_migration(k, k * bs)
                write_t = max(write_t, t)
                write_sizes.extend(sizes)
        self.stats.record_write(int(len(ids)) * bs, write_t,
                                request_sizes=write_sizes)


class GraphBlockStore(_BlockReadBatcher):
    """Block-organized adjacency storage with pinned object index table."""

    directory_header_words = _HDR  # per-entry directory width (topology.py
    # derives per-block payload/degree estimates from it)

    def __init__(self, path: str, block_size: int, t_obj: np.ndarray,
                 n_nodes: int, n_edges: int,
                 device: NVMeModel | None = None):
        self.path = path
        self.block_size = block_size
        self.words_per_block = block_size // 4
        self.t_obj = t_obj  # (n_blocks, 2): first/last node id. Pinned.
        self.n_blocks = len(t_obj)
        self.n_nodes = n_nodes
        self.n_edges = n_edges
        self.device = device or NVMeModel()
        self.stats = IOStats()
        recover_store_metadata(path)  # GC partial migration state (crash)
        self._mm = np.memmap(path, dtype=np.int32, mode="r")
        self._last_block_read = -2  # sequential-access detection
        self._io_lock = threading.Lock()  # prefetch thread vs consumer

    # ---------------------------------------------------------- build
    @classmethod
    def build(cls, path: str, indptr: np.ndarray, indices: np.ndarray,
              block_size: int = DEFAULT_BLOCK_SIZE,
              device: NVMeModel | None = None) -> "GraphBlockStore":
        n = len(indptr) - 1
        wpb = block_size // 4
        cap = wpb - 1  # payload words per block (1 word for n_entries)
        if cap < _HDR + 1:
            raise ValueError(f"block_size {block_size} too small")
        deg = np.diff(indptr).astype(np.int64)
        words = deg + _HDR
        cum = np.zeros(n + 1, dtype=np.int64)
        np.cumsum(words, out=cum[1:])

        chunks: list[np.ndarray] = []
        t_obj: list[tuple[int, int]] = []
        v, off = 0, 0  # next node; neighbor offset within v (for splits)
        while v < n:
            ids: list[np.ndarray] = []
            cnt: list[np.ndarray] = []
            tot: list[np.ndarray] = []
            pay: list[np.ndarray] = []
            used = 0
            first = v
            if off > 0:  # continue a split object
                take = min(int(deg[v]) - off, cap - _HDR)
                ids.append(np.array([v]))
                cnt.append(np.array([take]))
                tot.append(np.array([deg[v]]))
                pay.append(indices[indptr[v] + off:indptr[v] + off + take])
                used += _HDR + take
                off += take
                if off >= deg[v]:
                    v, off = v + 1, 0
            if v < n and off == 0 and used < cap - _HDR:
                # how many whole objects fit in the remaining capacity
                budget = cap - used
                m = int(np.searchsorted(cum, cum[v] + budget, side="right")) - 1 - v
                if m > 0:
                    ids.append(np.arange(v, v + m))
                    cnt.append(deg[v:v + m])
                    tot.append(deg[v:v + m])
                    pay.append(indices[indptr[v]:indptr[v + m]])
                    used += int(cum[v + m] - cum[v])
                    v += m
                elif used == 0:
                    # single object larger than a block: start a split
                    take = cap - _HDR
                    ids.append(np.array([v]))
                    cnt.append(np.array([take]))
                    tot.append(np.array([deg[v]]))
                    pay.append(indices[indptr[v]:indptr[v] + take])
                    used += _HDR + take
                    off = take
            last = v if off > 0 else v - 1
            e_ids = np.concatenate(ids).astype(np.int32)
            e_cnt = np.concatenate(cnt).astype(np.int32)
            e_tot = np.concatenate(tot).astype(np.int32)
            e_pay = (np.concatenate(pay).astype(np.int32)
                     if pay and sum(len(p) for p in pay) else np.zeros(0, np.int32))
            blk = np.zeros(wpb, dtype=np.int32)
            ne = len(e_ids)
            blk[0] = ne
            blk[1:1 + ne] = e_ids
            blk[1 + ne:1 + 2 * ne] = e_cnt
            blk[1 + 2 * ne:1 + 3 * ne] = e_tot
            blk[1 + 3 * ne:1 + 3 * ne + len(e_pay)] = e_pay
            chunks.append(blk)
            t_obj.append((int(first), int(max(last, first))))

        data = np.concatenate(chunks) if chunks else np.zeros(0, dtype=np.int32)
        data.tofile(path)
        meta = {"block_size": block_size, "n_nodes": int(n),
                "n_edges": int(len(indices)),
                "t_obj": np.asarray(t_obj, dtype=np.int64).tolist()}
        with open(path + ".meta.json", "w") as f:
            json.dump(meta, f)
        return cls(path, block_size, np.asarray(t_obj, dtype=np.int64),
                   n, len(indices), device)

    @classmethod
    def open(cls, path: str, device: NVMeModel | None = None) -> "GraphBlockStore":
        with open(path + ".meta.json") as f:
            meta = json.load(f)
        return cls(path, meta["block_size"],
                   np.asarray(meta["t_obj"], dtype=np.int64),
                   meta["n_nodes"], meta["n_edges"], device)

    # ---------------------------------------------------------- lookup
    def blocks_for_nodes(self, nodes: np.ndarray) -> np.ndarray:
        """All block ids containing any of ``nodes`` (ascending, unique).

        Binary search on the pinned T_obj (Algorithm 1 ``LoadData``
        lines 19-24, vectorized).  Handles split objects by expanding over
        the contiguous run of blocks covering the node.
        """
        if len(nodes) == 0:
            return np.zeros(0, dtype=np.int64)
        nodes = np.asarray(nodes)
        firsts = self.t_obj[:, 0]
        lasts = self.t_obj[:, 1]
        lo = np.searchsorted(lasts, nodes, side="left")
        hi = np.searchsorted(firsts, nodes, side="right") - 1
        lo = np.clip(lo, 0, self.n_blocks - 1)
        hi = np.clip(hi, 0, self.n_blocks - 1)
        if ((hi - lo) == 0).all():
            return np.unique(lo)
        # vectorized run expansion for split objects: block id = run start
        # + offset within the run, no per-node np.arange
        lens = hi - lo + 1
        cum = np.cumsum(lens)
        out = np.repeat(lo, lens) + np.arange(cum[-1]) - np.repeat(cum - lens, lens)
        return np.unique(out)

    def entry_payload_estimate(self) -> np.ndarray:
        """Per-block payload words per directory entry, from the pinned
        T_obj (no I/O): each block's payload is split evenly over the
        objects it holds.  Blocks holding few objects hold hubs — the
        score the hotness-aware placement pins on (``topology.py``)."""
        if self.n_blocks == 0:
            return np.zeros(0, dtype=np.float64)
        n_obj = (self.t_obj[:, 1] - self.t_obj[:, 0] + 1).astype(np.float64)
        payload = np.maximum(
            self.words_per_block - 1 - self.directory_header_words * n_obj,
            1.0)
        return payload / np.maximum(n_obj, 1.0)

    def approx_degrees(self) -> np.ndarray:
        """Per-node degree estimate from the pinned T_obj (no I/O).

        An object split across k blocks accumulates ~k blocks of
        payload, so hubs score near their true degree.  Feeds the
        hotness-aware placement policy (``topology.py``)."""
        deg = np.zeros(self.n_nodes + 1, dtype=np.float64)
        if self.n_blocks == 0 or self.n_nodes == 0:
            return deg[:-1]
        firsts = self.t_obj[:, 0]
        lasts = self.t_obj[:, 1]
        per = self.entry_payload_estimate()
        # add per[b] to every node in [first, last] via prefix sums
        np.add.at(deg, firsts, per)
        np.add.at(deg, np.minimum(lasts + 1, self.n_nodes), -per)
        return np.cumsum(deg)[:-1]

    # ---------------------------------------------------------- I/O
    def read_block(self, block_id: int) -> GraphBlock:
        """Block-wise storage I/O: one device read of ``block_size`` bytes."""
        if not (0 <= block_id < self.n_blocks):
            raise IndexError(block_id)
        with self._io_lock:
            w = self.words_per_block
            raw = np.asarray(self._mm[block_id * w:(block_id + 1) * w])
            self._record_block_read_locked(block_id)
        return self._decode(block_id, raw)

    def read_block_bytes(self, block_id: int) -> bytes:
        """Raw on-disk bytes of one graph block (migration copy unit)."""
        if not (0 <= block_id < self.n_blocks):
            raise IndexError(block_id)
        w = self.words_per_block
        return np.asarray(self._mm[block_id * w:(block_id + 1) * w]).tobytes()

    def read_run(self, start: int, count: int) -> list[GraphBlock]:
        """One memmap slice over ``count`` adjacent blocks, decoded together.

        No device accounting — the caller (scheduler / ``read_blocks``)
        charges whole submissions via :meth:`account_runs`.
        """
        if not (0 <= start and start + count <= self.n_blocks):
            raise IndexError((start, count))
        w = self.words_per_block
        raw = np.asarray(self._mm[start * w:(start + count) * w])
        return self.decode_many(start, raw.reshape(count, w))

    def decode_many(self, start: int, raw: np.ndarray) -> list[GraphBlock]:
        """Decode ``raw`` (count, words_per_block) into GraphBlocks.

        All directories and payloads are extracted with flat fancy
        indexing — no per-block Python work beyond the final ``np.split``.
        """
        k = raw.shape[0]
        ne = raw[:, 0].astype(np.int64)
        tot_e = int(ne.sum())
        if tot_e == 0 or (ne == 0).any():
            # build() never emits empty blocks; if one appears (truncated
            # file), the flat-offset math below is invalid — decode singly
            return [self._decode(start + i, raw[i]) for i in range(k)]
        rows_idx = np.repeat(np.arange(k), ne)          # block of each entry
        cum_ne = np.cumsum(ne)
        ent = np.arange(tot_e) - np.repeat(cum_ne - ne, ne)  # entry-local idx
        node_ids = raw[rows_idx, 1 + ent].astype(np.int64)
        counts = raw[rows_idx, 1 + ne[rows_idx] + ent].astype(np.int64)
        total_deg = raw[rows_idx, 1 + 2 * ne[rows_idx] + ent].astype(np.int64)
        # entry-local payload offsets within each block
        cum_cnt = np.cumsum(counts)
        blk_pay_start = np.concatenate([[0], cum_cnt[cum_ne - 1][:-1]])
        local_off = cum_cnt - counts - blk_pay_start[rows_idx]
        tot_p = int(cum_cnt[-1]) if tot_e else 0
        if tot_p:
            pay_rows = np.repeat(rows_idx, counts)
            pay_base = np.repeat(1 + 3 * ne[rows_idx] + local_off, counts)
            within = np.arange(tot_p) - np.repeat(cum_cnt - counts, counts)
            payload = raw[pay_rows, pay_base + within].astype(np.int64)
        else:
            payload = np.zeros(0, np.int64)
        # split flat arrays back into per-block GraphBlocks
        ent_bounds = cum_ne[:-1]
        pay_bounds = cum_cnt[cum_ne - 1][:-1] if k > 1 else np.zeros(0, np.int64)
        ids_per = np.split(node_ids, ent_bounds)
        cnt_per = np.split(counts, ent_bounds)
        tot_per = np.split(total_deg, ent_bounds)
        pay_per = np.split(payload, pay_bounds)
        out = []
        for i in range(k):
            indptr = np.zeros(len(cnt_per[i]) + 1, dtype=np.int64)
            np.cumsum(cnt_per[i], out=indptr[1:])
            out.append(GraphBlock(start + i, ids_per[i], indptr,
                                  pay_per[i], tot_per[i]))
        return out

    @staticmethod
    def _decode(block_id: int, raw: np.ndarray) -> GraphBlock:
        ne = int(raw[0])
        node_ids = raw[1:1 + ne].astype(np.int64)
        counts = raw[1 + ne:1 + 2 * ne].astype(np.int64)
        total_deg = raw[1 + 2 * ne:1 + 3 * ne].astype(np.int64)
        indptr = np.zeros(ne + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr[1:])
        payload = raw[1 + 3 * ne:1 + 3 * ne + indptr[-1]].astype(np.int64)
        return GraphBlock(block_id, node_ids, indptr, payload, total_deg)


class FeatureBlockStore(_BlockReadBatcher):
    """Block-organized node-feature storage.

    Row ``v`` lives in feature block ``v // rows_per_block`` at local offset
    ``v % rows_per_block`` — the feature analogue of T_obj degenerates to a
    stride, kept explicit for symmetry with the paper.
    """

    def __init__(self, path: str, n_nodes: int, dim: int, dtype: str,
                 block_size: int, device: NVMeModel | None = None):
        self.path = path
        self.n_nodes = n_nodes
        self.dim = dim
        self.dtype = np.dtype(dtype)
        self.block_size = block_size
        self.row_bytes = dim * self.dtype.itemsize
        self.rows_per_block = max(block_size // self.row_bytes, 1)
        self.n_blocks = -(-n_nodes // self.rows_per_block)
        self.device = device or NVMeModel()
        self.stats = IOStats()
        recover_store_metadata(path)  # GC partial migration state (crash)
        self._mm = np.memmap(path, dtype=self.dtype, mode="r",
                             shape=(self.n_blocks * self.rows_per_block, dim))
        self._last_block_read = -2
        self._io_lock = threading.Lock()

    @classmethod
    def build(cls, path: str, features: np.ndarray,
              block_size: int = DEFAULT_BLOCK_SIZE,
              device: NVMeModel | None = None) -> "FeatureBlockStore":
        n, dim = features.shape
        dtype = features.dtype
        row_bytes = dim * dtype.itemsize
        rows_per_block = max(block_size // row_bytes, 1)
        n_blocks = -(-n // rows_per_block)
        # stream to disk chunk-by-chunk: rows are contiguous across blocks,
        # so only the final block needs zero padding — no fully padded
        # (n_blocks * rows_per_block, dim) copy (2x peak RAM) is ever built
        chunk_rows = max((64 << 20) // max(row_bytes, 1), 1)
        with open(path, "wb") as fh:
            for s in range(0, n, chunk_rows):
                np.ascontiguousarray(features[s:s + chunk_rows]).tofile(fh)
            pad = n_blocks * rows_per_block - n
            if pad:
                np.zeros((pad, dim), dtype=dtype).tofile(fh)
        meta = {"n_nodes": int(n), "dim": int(dim), "dtype": dtype.name,
                "block_size": int(block_size)}
        with open(path + ".meta.json", "w") as f:
            json.dump(meta, f)
        return cls(path, n, dim, dtype.name, block_size, device)

    @classmethod
    def open(cls, path: str, device: NVMeModel | None = None) -> "FeatureBlockStore":
        with open(path + ".meta.json") as f:
            meta = json.load(f)
        return cls(path, meta["n_nodes"], meta["dim"], meta["dtype"],
                   meta["block_size"], device)

    def block_of(self, nodes: np.ndarray) -> np.ndarray:
        return np.asarray(nodes) // self.rows_per_block

    def read_block(self, block_id: int) -> np.ndarray:
        """One block-wise I/O; returns (rows_per_block, dim)."""
        if not (0 <= block_id < self.n_blocks):
            raise IndexError(block_id)
        with self._io_lock:
            r = self.rows_per_block
            rows = np.asarray(self._mm[block_id * r:(block_id + 1) * r])
            self._record_block_read_locked(block_id)
        return rows

    def read_block_bytes(self, block_id: int) -> bytes:
        """Raw on-disk bytes of one feature block (migration copy unit)."""
        if not (0 <= block_id < self.n_blocks):
            raise IndexError(block_id)
        r = self.rows_per_block
        return np.asarray(self._mm[block_id * r:(block_id + 1) * r]).tobytes()

    def read_run(self, start: int, count: int) -> list[np.ndarray]:
        """One memmap slice over ``count`` adjacent blocks; no accounting."""
        if not (0 <= start and start + count <= self.n_blocks):
            raise IndexError((start, count))
        r = self.rows_per_block
        rows = np.asarray(self._mm[start * r:(start + count) * r])
        return [rows[i * r:(i + 1) * r] for i in range(count)]

    def read_rows_node_granular(self, nodes: np.ndarray, io_unit: int = 4096) -> np.ndarray:
        """Baseline path (Ginex-like): one small I/O per requested row.

        Each row read costs ``ceil(row_bytes / io_unit) * io_unit`` device
        bytes at random-read latency — the paper's "large number of small
        storage I/Os".
        """
        nodes = np.asarray(nodes)
        out = np.asarray(self._mm[nodes])
        if self.hotness is not None:
            self.hotness.touch(self.block_of(nodes))
        per_io = -(-self.row_bytes // io_unit) * io_unit
        t = self.device.batch_time(per_io * len(nodes), n_random=len(nodes))
        self.stats.n_reads += len(nodes)
        self.stats.n_requests += len(nodes)
        self.stats.bytes_read += per_io * len(nodes)
        self.stats.modeled_read_time += t
        self.stats.size_histogram[max(per_io // 1024, 1)] += len(nodes)
        return out

    def write_rows_node_granular(self, nodes: np.ndarray, io_unit: int = 4096,
                                 queue_depth: int | None = None) -> None:
        """Account a node-granular write-back (feature-cache eviction path).

        Charged through :meth:`NVMeModel.batch_time` with queue-depth
        overlap — matching the read path — with every write request's
        size recorded in the histogram; with a placement attached the
        writes split across their owning arrays and cost the ``max``
        over per-array rooflines.
        """
        nodes = np.asarray(nodes)
        if len(nodes) == 0:
            return
        per_io = -(-self.row_bytes // io_unit) * io_unit
        if self.placement is not None:
            arrays = self.placement.array_of[self.block_of(nodes)]
            t = 0.0
            with self.topology.lock:
                for a in np.unique(arrays):
                    k = int((arrays == a).sum())
                    dev = self.topology.devices[int(a)]
                    ta = dev.batch_time(per_io * k, n_random=k,
                                        queue_depth=queue_depth)
                    self.topology.array_stats[int(a)].record_write(
                        per_io * k, ta, request_sizes=[per_io] * k)
                    t = max(t, ta)
        else:
            t = self.device.batch_time(per_io * len(nodes),
                                       n_random=len(nodes),
                                       queue_depth=queue_depth)
        self.stats.record_write(per_io * len(nodes), t,
                                request_sizes=[per_io] * len(nodes))
