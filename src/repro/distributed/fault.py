"""Fault tolerance: failure detection, straggler mitigation, elastic
re-mesh, checkpoint/restart orchestration.

On a real fleet each host runs the heartbeat agent; here the monitor is
driven by injected events (tests simulate host loss / stragglers), but
the *decision logic* — what the controller does when a host dies or lags
— is the production logic:

* **Heartbeats**: hosts report per-step completion times; a host silent
  for ``timeout_s`` is declared dead.
* **Stragglers**: a host whose step time exceeds ``straggler_factor`` ×
  the fleet median for ``straggler_patience`` consecutive steps is
  flagged; the controller first reroutes its input shard (skip-and-
  requeue), then treats a persistent straggler as failed (the standard
  MTTR-vs-throughput tradeoff at 1000+ nodes).
* **Elastic re-mesh**: on failure the controller computes the largest
  (data', model) mesh that fits the surviving hosts — the model axis is
  preserved (TP groups must stay intact: a TP group that lost a member
  is lost entirely); the data axis shrinks.  Training resumes from the
  last committed checkpoint via ``CheckpointManager.restore`` with the
  new mesh's shardings; global batch is preserved by raising gradient-
  accumulation microbatches.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict, deque


@dataclasses.dataclass
class HostState:
    last_seen: float
    step_times: deque
    straggler_strikes: int = 0
    alive: bool = True


class FaultMonitor:
    """Controller-side failure/straggler detector."""

    def __init__(self, n_hosts: int, *, timeout_s: float = 60.0,
                 straggler_factor: float = 2.0,
                 straggler_patience: int = 3,
                 clock=time.monotonic):
        self.n_hosts = n_hosts
        self.timeout_s = timeout_s
        self.straggler_factor = straggler_factor
        self.straggler_patience = straggler_patience
        self.clock = clock
        now = clock()
        self.hosts = {h: HostState(now, deque(maxlen=16))
                      for h in range(n_hosts)}
        self.events: list[tuple[str, int]] = []

    # ------------------------------------------------------------ inputs
    def heartbeat(self, host: int, step_time_s: float | None = None) -> None:
        st = self.hosts[host]
        st.last_seen = self.clock()
        if step_time_s is not None:
            st.step_times.append(step_time_s)

    # ----------------------------------------------------------- queries
    def _median_step(self) -> float | None:
        times = [t for h in self.hosts.values() if h.alive
                 for t in h.step_times]
        if not times:
            return None
        times.sort()
        return times[len(times) // 2]

    def check(self) -> dict:
        """Run detection; returns {dead: [...], stragglers: [...]}"""
        now = self.clock()
        dead, stragglers = [], []
        med = self._median_step()
        for hid, st in self.hosts.items():
            if not st.alive:
                continue
            if now - st.last_seen > self.timeout_s:
                st.alive = False
                dead.append(hid)
                self.events.append(("dead", hid))
                continue
            if med and st.step_times and \
                    st.step_times[-1] > self.straggler_factor * med:
                st.straggler_strikes += 1
                if st.straggler_strikes >= self.straggler_patience:
                    stragglers.append(hid)
                    self.events.append(("straggler", hid))
            else:
                st.straggler_strikes = 0
        return {"dead": dead, "stragglers": stragglers}

    def mark_failed(self, host: int) -> None:
        self.hosts[host].alive = False
        self.events.append(("evicted", host))

    @property
    def alive_hosts(self) -> list[int]:
        return [h for h, st in self.hosts.items() if st.alive]


def plan_elastic_mesh(alive_hosts: list[int], *, hosts_per_tp_group: int,
                      model_axis: int) -> dict:
    """Largest coherent (data', model) mesh from the survivors.

    Hosts are grouped into TP groups of ``hosts_per_tp_group``; a group
    missing any member cannot serve the model axis and is dropped whole.
    Returns the re-mesh plan consumed by the trainer.
    """
    groups = defaultdict(list)
    for h in alive_hosts:
        groups[h // hosts_per_tp_group].append(h)
    complete = [g for g, members in groups.items()
                if len(members) == hosts_per_tp_group]
    if not complete:
        raise RuntimeError("no complete TP group survives — cannot re-mesh")
    return {
        "data_axis": len(complete),
        "model_axis": model_axis,
        "tp_groups": sorted(complete),
        "dropped_hosts": sorted(set(alive_hosts)
                                - {h for g in complete
                                   for h in range(g * hosts_per_tp_group,
                                                  (g + 1) * hosts_per_tp_group)}),
    }


@dataclasses.dataclass
class ElasticTrainer:
    """Checkpoint/restart orchestration glue (see tests for the drill).

    Wire-up: every ``ckpt_every`` steps → async checkpoint; every step →
    heartbeats; on ``check()`` reporting a death → ``plan_elastic_mesh``
    over survivors → rebuild mesh/shardings → ``restore`` → adjust
    microbatch count to preserve global batch → continue.
    """

    monitor: FaultMonitor
    ckpt_manager: object
    hosts_per_tp_group: int
    model_axis: int
    global_batch: int

    def recovery_plan(self) -> dict | None:
        report = self.monitor.check()
        if not report["dead"] and not report["stragglers"]:
            return None
        for h in report["stragglers"]:
            self.monitor.mark_failed(h)  # requeue-then-evict policy
        plan = plan_elastic_mesh(self.monitor.alive_hosts,
                                 hosts_per_tp_group=self.hosts_per_tp_group,
                                 model_axis=self.model_axis)
        step = self.ckpt_manager.latest_step()
        plan["restore_step"] = step
        # preserve global batch: data-parallel width shrank, so raise
        # per-replica accumulation
        plan["n_microbatches"] = max(
            1, self.global_batch // max(plan["data_axis"], 1) // 1)
        return plan
