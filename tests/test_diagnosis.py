"""Storage doctor (core/diagnosis.py): roofline states, decomposition,
watchdog, engine/tier entry points, offline CLI.

Covers:

* the six-way per-array roofline classifier on synthetic rows — each
  :data:`ARRAY_STATES` member is reachable and the bw/iops arms and
  utilizations are the NVMe model's algebra;
* ``decompose_prepare`` — exact interval arithmetic for the exposed
  fraction (overlap merged, not double counted) and the component
  split over the recorded span categories;
* ``events_from_chrome`` — an exported Chrome object re-imports to
  event tuples whose decomposition matches the recorder's own;
* ``diagnose`` findings from synthetic snapshots: every causal
  detector (fault-degraded, admission-throttled per tenant,
  hedge-stall, cache-miss-bound), ranked above the shape finding, and
  "healthy" on an empty snapshot;
* :class:`AnomalyWatchdog` detectors over hand-driven counter windows
  (stall spike, starvation, cache collapse, trace drops), silence on
  clean windows, and the ``diag.alert`` instants emitted back into the
  trace;
* ``AgnesEngine.diagnose`` / ``ServingTier.diagnose`` smoke on the
  shared tiny dataset, and the ``python -m repro.doctor`` CLI over
  exported trace + metrics files (rendered and ``--json``).
"""
import json
import types

import numpy as np
import pytest

from repro.core import (AgnesConfig, AgnesEngine, AnomalyWatchdog,
                        ARRAY_STATES, DoctorThresholds, MetricsRegistry,
                        ServingTier, SUGGESTED_KNOBS, TraceRecorder,
                        decompose_prepare, diagnose, events_from_chrome)
from repro.core.diagnosis import _classify_array
from repro.doctor import main as doctor_main

CFG = dict(block_size=16384, minibatch_size=64, hyperbatch_size=2,
           fanouts=(4, 4), graph_buffer_bytes=1 << 20,
           feature_buffer_bytes=1 << 20, async_io=False)

TH = DoctorThresholds()


def _engine(tiny_ds, **over):
    g, f = tiny_ds.reopen_stores()
    return AgnesEngine(g, f, AgnesConfig(**dict(CFG, **over)))


def _row(**over):
    row = dict(array=0, online=True, bytes=2 << 20, n_requests=64,
               sequential_fraction=0.0, busy_s=0.01, bandwidth=6.7e9,
               latency=80e-6, device_queue_depth=32, queue_depth=8)
    row.update(over)
    return row


# ------------------------------------------------------------- classifier
def test_classifier_reaches_every_state():
    got = {
        "idle": _classify_array(_row(bytes=0, busy_s=0.0), 0.0, 0.0, TH),
        "bw-bound": _classify_array(
            _row(bytes=512 << 20, n_requests=8, sequential_fraction=1.0,
                 busy_s=0.08, queue_depth=32), 0.0, 0.0, TH),
        "iops-bound": _classify_array(
            _row(n_requests=4096, queue_depth=8), 0.0, 0.0, TH),
        "queue-starved": _classify_array(
            _row(n_requests=4096, queue_depth=1), 0.0, 0.0, TH),
        "admission-throttled": _classify_array(_row(), 0.5, 0.0, TH),
        "fault-degraded": _classify_array(_row(online=False), 0.0, 0.0, TH),
    }
    for state, diag in got.items():
        assert diag.state == state, f"{state}: got {diag.state}"
    assert set(got) == set(ARRAY_STATES)
    # degraded reads flip the state even with the array online
    assert _classify_array(_row(), 0.0, 0.5, TH).state == "fault-degraded"


def test_classifier_arms_are_the_nvme_model():
    d = _classify_array(
        _row(bytes=67 << 20, n_requests=1000, sequential_fraction=0.25,
             busy_s=0.02, queue_depth=8), 0.0, 0.0, TH)
    assert d.bw_term_s == pytest.approx((67 << 20) / 6.7e9, rel=1e-3)
    assert d.iops_term_s == pytest.approx(750 * 80e-6 / 8, rel=1e-3)
    assert 0.0 < d.bw_utilization <= 1.0
    assert d.avg_request_bytes == pytest.approx((67 << 20) / 1000)
    # the submitter's depth is clamped to the device's
    d2 = _classify_array(_row(queue_depth=128), 0.0, 0.0, TH)
    assert d2.queue_depth == 128 and d2.device_queue_depth == 32


# ---------------------------------------------------------- decomposition
def _ev(ph, name, cat, ts, dur, args=None):
    return (ph, name, cat, "t0", ts, dur, args)


def test_decompose_prepare_interval_arithmetic():
    events = [
        _ev("X", "hb0", "prepare", 0.0, 10.0),
        _ev("X", "hb0", "train", 5.0, 10.0),       # overlaps [5, 10]
        _ev("X", "plan:graph", "prepare.stage", 0.0, 2.0),
        _ev("X", "assemble:feat", "prepare.stage", 2.0, 1.0),
        _ev("X", "consume:io", "prepare.stage", 3.0, 1.0),  # not sampling
        _ev("X", "graph.run", "io.run", 3.0, 2.0),
        _ev("X", "feature.run", "io.run", 5.0, 1.0),
        _ev("X", "wait", "admission", 6.0, 0.5),
        _ev("i", "graph.retry", "io.fault", 7.0, 0.0, {"modeled_s": 0.25}),
        _ev("i", "graph.error", "io.fault", 7.1, 0.0, {"modeled_s": 9.0}),
    ]
    d = decompose_prepare(events)
    assert d["prepare_s"] == pytest.approx(10.0)
    assert d["train_s"] == pytest.approx(10.0)
    assert d["hidden_prepare_s"] == pytest.approx(5.0)
    assert d["exposed_prepare_s"] == pytest.approx(5.0)
    assert d["exposed_prepare_fraction"] == pytest.approx(0.5)
    c = d["components_s"]
    assert c["sampling_cpu"] == pytest.approx(3.0)   # plan + assemble only
    assert c["io"] == pytest.approx(2.0)             # graph store reads
    assert c["cache_miss"] == pytest.approx(1.0)     # feature store reads
    assert c["admission_wait"] == pytest.approx(0.5)
    assert c["fault_stall"] == pytest.approx(0.25)   # error is not a stall
    assert c["other"] == pytest.approx(10.0 - 6.75)
    assert sum(d["component_fractions"].values()) == pytest.approx(1.0)
    assert sum(d["exposed_components_s"].values()) == \
        pytest.approx(d["exposed_prepare_s"], rel=1e-3)


def test_decompose_merges_overlapping_spans():
    # two overlapping prepare spans must not double count the overlap
    # against a train span covering both
    d = decompose_prepare([
        _ev("X", "a", "prepare", 0.0, 4.0),
        _ev("X", "b", "prepare", 2.0, 4.0),
        _ev("X", "t", "train", 0.0, 6.0),
    ])
    assert d["prepare_s"] == pytest.approx(8.0)      # wall sum, per span
    assert d["hidden_prepare_s"] == pytest.approx(6.0)  # merged overlap
    assert d["exposed_prepare_s"] == pytest.approx(2.0)


def test_decompose_empty_trace_is_zeroed():
    d = decompose_prepare([])
    assert d["prepare_s"] == 0.0
    assert d["exposed_prepare_fraction"] == 0.0
    assert all(v == 0.0 for v in d["component_fractions"].values())


# ----------------------------------------------------------- chrome import
def test_events_from_chrome_round_trip():
    rec = TraceRecorder(capacity=256)
    with rec.span("hb0", "prepare", "pipeline"):
        with rec.span("plan:graph", "prepare.stage", "prepare:training"):
            pass
        rec.instant("graph.retry", "io.fault", "array:0",
                    args={"modeled_s": 0.5})
    with rec.span("hb0", "train", "pipeline"):
        pass
    back = events_from_chrome(rec.to_chrome())
    assert len(back) == len(rec.events())
    assert {e[3] for e in back} == \
        {"pipeline", "prepare:training", "array:0"}
    d0 = decompose_prepare(rec.events())
    d1 = decompose_prepare(back)
    assert d1["prepare_s"] == pytest.approx(d0["prepare_s"], rel=1e-3,
                                            abs=1e-8)
    assert d1["components_s"]["fault_stall"] == pytest.approx(0.5)
    # malformed payloads degrade to empty, never raise
    assert events_from_chrome({}) == []
    assert events_from_chrome({"traceEvents": "nope"}) == []


# ------------------------------------------------------------- findings
def test_diagnose_empty_snapshot_is_healthy():
    report = diagnose({})
    assert report.primary == "healthy"
    assert report.findings == [] and report.arrays == []
    assert json.loads(json.dumps(report.to_dict()))["primary"] == "healthy"
    assert "healthy" in report.render()


def _base_metrics(**over):
    m = {"agnes.total.modeled_io_time_s": 0.01,
         "agnes.total.n_requests": 100, "agnes.total.n_reads": 400,
         "agnes.total.n_sequential_reads": 100,
         "agnes.total.bytes_read": 4 << 20, "agnes.io_queue_depth": 8}
    m.update(over)
    return m


def test_diagnose_fault_degraded_outranks_shape():
    report = diagnose(_base_metrics(**{
        "agnes.faults.offline_arrays.0": 3,
        "agnes.total.io_degraded": 4}))
    assert report.primary == "fault-degraded"
    assert report.findings[0].evidence["offline_arrays"] == [3]
    assert report.findings[0].knob == SUGGESTED_KNOBS["fault-degraded"]
    # the shape finding is still attributed, ranked below
    assert any(f.kind in ("bw-bound", "iops-bound", "queue-starved")
               for f in report.findings[1:])


def test_diagnose_admission_engine_and_tenant():
    report = diagnose(_base_metrics(**{
        "agnes.total.admission_wait_s": 0.04}))
    assert report.primary == "admission-throttled"
    tenants = {"bulk": {"io": {"admission_wait_s": 0.0,
                               "modeled_io_time_s": 0.01}},
               "starved": {"io": {"admission_wait_s": 0.09,
                                  "modeled_io_time_s": 0.001},
                           "admission": {"forced_grants": 2}}}
    report = diagnose(_base_metrics(), tenant_rooflines=tenants)
    assert report.primary == "admission-throttled"
    top = report.findings[0]
    assert top.evidence["tenant"] == "starved"
    assert top.evidence["forced_grants"] == 2


def test_diagnose_hedge_stall_and_cache_detectors():
    report = diagnose(_base_metrics(**{
        "agnes.total.io_retries": 5, "agnes.total.io_hedges": 3,
        "io.graph.fault.stall": 4}))
    assert report.primary == "hedge-stall"
    assert report.findings[0].evidence["fault_events"] == 12

    report = diagnose(_base_metrics(**{
        "agnes.feature_cache_hit": 0.05,
        "cache.rows_admitted": 900, "cache.rows_evicted": 800,
        "agnes.feature.modeled_io_time_s": 0.009}))
    assert report.primary == "cache-miss-bound"
    # eviction-gated: the same snapshot minus evictions is cold
    # streaming, not an undersized cache
    report = diagnose(_base_metrics(**{
        "agnes.feature_cache_hit": 0.05,
        "cache.rows_admitted": 900, "cache.rows_evicted": 0,
        "agnes.feature.modeled_io_time_s": 0.009}))
    assert all(f.kind != "cache-miss-bound" for f in report.findings)


def test_diagnose_multi_array_rows_and_report_render():
    m = _base_metrics(**{
        "agnes.arrays.arrays.0.online": 1,
        "agnes.arrays.arrays.0.bytes": 64 << 20,
        "agnes.arrays.arrays.0.n_requests": 16,
        "agnes.arrays.arrays.0.sequential_fraction": 1.0,
        "agnes.arrays.arrays.0.busy_s": 0.01,
        "agnes.arrays.arrays.0.bandwidth_GBps": 6.7,
        "agnes.arrays.arrays.0.latency_us": 80.0,
        "agnes.arrays.arrays.0.device_queue_depth": 32,
        "agnes.arrays.arrays.1.online": 1,
        "agnes.arrays.arrays.1.bytes": 0,
        "agnes.arrays.arrays.1.n_requests": 0,
        "agnes.arrays.arrays.1.busy_s": 0.0,
        "agnes.io_queue_depth.0": 32, "agnes.io_queue_depth.1": 32})
    report = diagnose(m)
    states = {a.array: a.state for a in report.arrays}
    assert states == {0: "bw-bound", 1: "idle"}
    text = report.render()
    assert "storage doctor" in text and "per-array roofline" in text
    assert "bw-bound" in text


# ------------------------------------------------------------- watchdog
def _tel(trace_capacity=256):
    return types.SimpleNamespace(metrics=MetricsRegistry(),
                                 trace=TraceRecorder(trace_capacity))


def test_watchdog_stall_spike_and_silence():
    tel = _tel()
    runs = tel.metrics.counter("io.graph.runs")
    retries = tel.metrics.counter("io.graph.fault.retry")
    wd = AnomalyWatchdog(telemetry=tel)
    wd.begin()
    runs.inc(100)
    assert wd.observe("clean") == []       # healthy window: silence
    runs.inc(100)
    retries.inc(10)                        # 10% >> w_stall_rate
    alerts = wd.observe("spike")
    assert [a["kind"] for a in alerts] == ["stall-spike"]
    assert alerts[0]["window"] == "spike"
    # the alert landed in the trace as a diag.alert instant
    instants = [e for e in tel.trace.events() if e[2] == "diag.alert"]
    assert len(instants) == 1 and instants[0][1] == "alert:stall-spike"
    assert wd.alerts == alerts


def test_watchdog_starvation_and_gauge_passthrough():
    tel = _tel()
    forced = tel.metrics.counter("admission.starved.forced_grants")
    # admission.state.* gauges reuse counter-ish names; they must not
    # trip the windowed detector
    tel.metrics.gauge("admission.state.starved.forced_grants").set(99)
    wd = AnomalyWatchdog(telemetry=tel)
    wd.begin()
    assert wd.observe() == []
    forced.inc()
    alerts = wd.observe()
    assert [a["kind"] for a in alerts] == ["starvation"]


def test_watchdog_cache_collapse_needs_healthy_baseline():
    tel = _tel()
    hit = tel.metrics.gauge("agnes.feature_cache_hit")
    wd = AnomalyWatchdog(telemetry=tel)
    wd.begin()
    hit.set(0.9)
    assert wd.observe() == []              # building the baseline
    hit.set(0.2)
    alerts = wd.observe()
    assert [a["kind"] for a in alerts] == ["cache-collapse"]
    # a low-from-the-start ratio is cold, not a collapse
    tel2 = _tel()
    hit2 = tel2.metrics.gauge("agnes.feature_cache_hit")
    wd2 = AnomalyWatchdog(telemetry=tel2)
    wd2.begin()
    hit2.set(0.1)
    assert wd2.observe() == []
    hit2.set(0.0)
    assert wd2.observe() == []


def test_watchdog_trace_drops():
    tel = _tel(trace_capacity=8)
    wd = AnomalyWatchdog(telemetry=tel)
    wd.begin()
    for i in range(50):
        tel.trace.instant(f"e{i}", "c", "t")
    alerts = wd.observe()
    assert [a["kind"] for a in alerts] == ["trace-drops"]
    assert wd.observe() == []              # no new drops: no re-alert


# ------------------------------------------------------------ entry points
def test_engine_diagnose_smoke(tiny_ds):
    eng = _engine(tiny_ds, trace=True)
    eng.prepare([np.arange(64), np.arange(64, 128)], epoch=0)
    report = eng.diagnose()
    assert report.primary in SUGGESTED_KNOBS
    assert report.arrays and report.arrays[0].busy_s > 0
    assert report.decomposition["prepare_s"] > 0
    json.dumps(report.to_dict())           # wire-serializable
    eng.close()


def test_tier_diagnose_smoke(tiny_ds):
    eng = _engine(tiny_ds, trace=True, fanouts=(), feature_cache_rows=1,
                  n_arrays=2, placement="stripe",
                  max_coalesce_bytes=64 << 10, io_queue_depth=4)
    tier = ServingTier(eng)
    tier.prepare("training", [np.arange(32)], epoch=0)
    report = tier.diagnose()
    assert len(report.arrays) == 2
    assert isinstance(report.primary, str)
    tier.close()
    eng.close()


# ------------------------------------------------------------------- CLI
def test_doctor_cli_renders_and_json(tiny_ds, tmp_path, capsys):
    eng = _engine(tiny_ds, trace=True)
    eng.prepare([np.arange(64)], epoch=0)
    trace_path = eng.telemetry.trace.export_chrome(
        str(tmp_path / "trace.json"))
    metrics_path = str(tmp_path / "metrics.json")
    with open(metrics_path, "w") as f:
        json.dump(eng.metrics_snapshot(refresh=True), f)
    eng.close()

    assert doctor_main([trace_path, "--metrics", metrics_path]) == 0
    out = capsys.readouterr().out
    assert "storage doctor — primary bottleneck:" in out
    assert "per-array roofline" in out

    assert doctor_main([trace_path, "--metrics", metrics_path,
                        "--json"]) == 0
    payload = json.loads(capsys.readouterr().out)
    assert {"primary", "findings", "arrays", "decomposition"} <= \
        set(payload)

    # trace-only still diagnoses (roofline degrades, decomposition live)
    assert doctor_main([trace_path]) == 0
    assert "storage doctor" in capsys.readouterr().out
    with pytest.raises(SystemExit):
        doctor_main([])                    # nothing to diagnose
