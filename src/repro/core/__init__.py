"""AGNES core: storage-based GNN training (KDD'26) in JAX-friendly form.

Layers (paper §3.2):
  storage   — block_store (+ device_model timing, layout for locality)
  in-memory — buffer (T_buf), feature_cache (C_f/T_ch)
  operation — hyperbatch sampler + gather (Algorithm 1), async_io
Plus the baseline engines the paper evaluates against.
"""
from .agnes import AgnesConfig, AgnesEngine, PreparedMinibatch, PrepareReport
from .async_io import BlockPrefetcher
from .baselines import (BaselineConfig, CSRStorage, GinexLike, GNNDriveLike,
                        MariusLike, OutreLike)
from .block_store import (DEFAULT_BLOCK_SIZE, FeatureBlockStore, GraphBlock,
                          GraphBlockStore, recover_store_metadata,
                          replay_migration_journal)
from .bucket import Bucket, build_bucket
from .buffer import BlockBuffer
from .cache_oracle import (NEVER, OracleSchedule, belady_min_misses,
                           first_use_table, trace_from_plan)
from .device_model import IOStats, NVMeModel
from .diagnosis import (ARRAY_STATES, SUGGESTED_KNOBS, AnomalyWatchdog,
                        ArrayDiagnosis, DoctorReport, DoctorThresholds,
                        Finding, decompose_prepare, diagnose,
                        events_from_chrome)
from .fault import (ArrayOfflineError, FaultInjector, FaultRule, IOFaultError,
                    PermanentIOError, TornWriteError, TransientIOError,
                    classify_error)
from .feature_cache import CACHE_POLICIES, FeatureCache
from .gather import (DeviceFeatureTable, FeatureGatherer, GatherPlan,
                     ResidentSplit)
from .hotness import HotnessTracker
from .hyperbatch import HopPlan, HyperbatchSampler
from .io_sched import CoalescedReader, PlanStream, Run, coalesce, plan_cost
from .migration import (BlockMove, MigrationEngine, MigrationReport,
                        plan_evacuation)
from .layout import apply_relabel, bfs_locality_order, degree_order
from .sampling import (MFG, MFGLayer, assemble_layer, layer_from_frontier,
                       next_frontier, sample_indices)
from .serving import (ALL_ARRAYS, DEFAULT_QOS, AdmissionController,
                      InferenceServer, QoSClass, ServedPrepare, ServingTier)
from .session import IOPlan, PrepareSession
from .telemetry import (MetricsRegistry, Telemetry, TraceRecorder,
                        fig2_breakdown, format_metrics, maybe_span,
                        validate_chrome_trace)
from .topology import (BlockPlacement, ContiguousPlacement,
                       HotnessAwarePlacement, PlacementPolicy,
                       StorageTopology, StripePlacement,
                       feature_block_hotness, graph_block_hotness,
                       make_policy, topology_plan_cost)

__all__ = [
    "AgnesConfig", "AgnesEngine", "PreparedMinibatch", "PrepareReport",
    "BlockPrefetcher", "BaselineConfig", "CSRStorage", "GinexLike",
    "GNNDriveLike", "MariusLike", "OutreLike", "DEFAULT_BLOCK_SIZE",
    "FeatureBlockStore", "GraphBlock", "GraphBlockStore", "Bucket",
    "build_bucket", "BlockBuffer", "IOStats", "NVMeModel", "FeatureCache",
    "CACHE_POLICIES", "NEVER", "OracleSchedule", "belady_min_misses",
    "trace_from_plan", "DeviceFeatureTable", "ResidentSplit",
    "CoalescedReader", "PlanStream", "Run", "coalesce", "plan_cost",
    "FeatureGatherer", "GatherPlan", "HopPlan", "HyperbatchSampler",
    "IOPlan", "PrepareSession", "apply_relabel",
    "bfs_locality_order", "degree_order", "MFG", "MFGLayer",
    "assemble_layer", "layer_from_frontier", "next_frontier",
    "sample_indices", "BlockPlacement", "ContiguousPlacement",
    "HotnessAwarePlacement", "PlacementPolicy", "StorageTopology",
    "StripePlacement", "feature_block_hotness", "graph_block_hotness",
    "make_policy", "topology_plan_cost", "HotnessTracker",
    "BlockMove", "MigrationEngine", "MigrationReport",
    "recover_store_metadata", "replay_migration_journal", "plan_evacuation",
    "FaultInjector", "FaultRule", "IOFaultError", "TransientIOError",
    "PermanentIOError", "TornWriteError", "ArrayOfflineError",
    "classify_error", "first_use_table",
    "ALL_ARRAYS", "DEFAULT_QOS", "AdmissionController", "InferenceServer",
    "QoSClass", "ServedPrepare", "ServingTier",
    "MetricsRegistry", "Telemetry", "TraceRecorder", "fig2_breakdown",
    "format_metrics", "maybe_span", "validate_chrome_trace",
    "ARRAY_STATES", "SUGGESTED_KNOBS", "AnomalyWatchdog", "ArrayDiagnosis",
    "DoctorReport", "DoctorThresholds", "Finding", "decompose_prepare",
    "diagnose", "events_from_chrome",
]
