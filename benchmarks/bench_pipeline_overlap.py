"""Measure prepare/train overlap of the pipelined executor.

Runs the same epochs on a tiny synthetic dataset twice — once with the
serial ``iter_epoch`` loop, once through :class:`PipelinedExecutor` —
and reports wall times plus the measured prepare-hidden fraction (the
share of ``AgnesEngine.prepare`` wall time overlapped with the jitted
train steps).  Losses are asserted identical: overlap must not change
the training trajectory.

  PYTHONPATH=src python -m benchmarks.bench_pipeline_overlap [--arch gat]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from .common import emit, get_dataset, make_agnes

from repro.gnn import GNNTrainer, PipelinedExecutor


def run(arch: str = "gcn", backend: str = "jnp", epochs: int | None = None,
        depth: int = 2):
    from .common import quick_val
    if epochs is None:
        epochs = quick_val(2, 1)
    import jax
    if backend == "pallas" and jax.default_backend() != "tpu":
        print("# warning: backend=pallas runs the kernels in interpret "
              "mode off-TPU — orders of magnitude slower; meant for "
              "small-scale validation (tests/test_kernel_parity.py), "
              "not this benchmark's problem size.", flush=True)
    ds = get_dataset("ig-mini", dim=128, block_size=1 << 20)
    targets = np.arange(min(8192, ds.n_nodes))
    mk = dict(block_size=1 << 20, fanouts=(10, 10), minibatch=512,
              hyperbatch_size=2, setting_bytes=64 << 20)

    def trainer():
        tr = GNNTrainer(arch=arch, in_dim=ds.dim, hidden=128, n_classes=16,
                        n_layers=2, seed=11, backend=backend)
        tr.labels = ds.labels
        return tr

    # warm the jit cache with a throwaway trainer over the exact epoch
    # plan: every padded-MFG shape bucket compiles once here, so neither
    # timed phase pays XLA compiles (the step fn cache is shared across
    # instances: same staticmethod, same static args)
    weng = make_agnes(ds, **mk)
    wtr = trainer()
    for epoch in range(epochs):
        for prepared in weng.iter_epoch(targets, epoch=epoch, shuffle=False):
            for p in prepared:
                wtr.train_minibatch(p)
    weng.close()

    # serial reference
    eng = make_agnes(ds, **mk)
    tr = trainer()
    serial_losses, prep_s = [], 0.0
    t0 = time.perf_counter()
    for epoch in range(epochs):
        for prepared in eng.iter_epoch(targets, epoch=epoch, shuffle=False):
            prep_s += eng.last_report.wall_s
            serial_losses += [tr.train_minibatch(p) for p in prepared]
    serial_wall = time.perf_counter() - t0
    eng.close()

    # pipelined
    eng = make_agnes(ds, **mk)
    pipe_losses, reports = [], []
    t0 = time.perf_counter()
    with PipelinedExecutor(eng, trainer(), depth=depth) as ex:
        for epoch in range(epochs):
            rep = ex.run_epoch(targets, epoch=epoch, shuffle=False)
            reports.append(rep)
            pipe_losses += rep.losses
    pipe_wall = time.perf_counter() - t0
    eng.close()

    assert serial_losses == pipe_losses, \
        "pipelining changed the training trajectory"

    prepare_s = sum(r.prepare_wall_s for r in reports)
    train_s = sum(r.train_wall_s for r in reports)
    hidden = float(np.mean([r.hidden_fraction for r in reports]))
    n_mb = sum(r.n_minibatches for r in reports)

    emit("pipeline/serial_epoch", serial_wall / epochs * 1e6,
         f"prepare_s={prep_s:.3f}")
    emit("pipeline/pipelined_epoch", pipe_wall / epochs * 1e6,
         f"prepare_s={prepare_s:.3f};train_s={train_s:.3f}")
    emit("pipeline/hidden_fraction", hidden * 1e6,
         ";".join(f"{r.hidden_fraction:.2f}" for r in reports))
    emit("pipeline/speedup", serial_wall / max(pipe_wall, 1e-9) * 1e6,
         f"n_minibatches={n_mb};losses_identical=True")
    print(f"# prepare-hidden fraction: {hidden:.1%} "
          f"(serial {serial_wall:.2f}s -> pipelined {pipe_wall:.2f}s, "
          f"{serial_wall / max(pipe_wall, 1e-9):.2f}x)", flush=True)
    print("# note: with no discrete accelerator, XLA's CPU backend shares "
          "the host cores with prepare, so the wall-clock gain here "
          "understates a TPU deployment; hidden_fraction is the "
          "device-independent overlap metric.", flush=True)
    if hidden <= 0:
        # timing-dependent: don't abort the whole benchmarks.run sweep
        print("# warning: no overlap measured (host too loaded or too few "
              "cores); hidden_fraction should be > 0 on an idle 2+-core "
              "host.", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gcn", choices=["gcn", "sage", "gat"])
    ap.add_argument("--backend", default="jnp", choices=["jnp", "pallas"])
    ap.add_argument("--epochs", type=int, default=2)
    ap.add_argument("--depth", type=int, default=2)
    run(**vars(ap.parse_args()))


if __name__ == "__main__":
    main()
