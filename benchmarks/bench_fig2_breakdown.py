"""Fig 2: (a) data-prep share of step time, (b) I/O size distribution,
(c) implied compute-utilization — for node-granular baselines vs AGNES."""
from __future__ import annotations

import numpy as np

from .common import (ALL_BASELINES, emit, get_dataset, gnn_compute_time,
                     make_agnes, make_baseline, prep_time, targets_for)


def run():
    ds = get_dataset("ig-mini")
    targets = targets_for(ds, n_mb=4, mb_size=512)

    def one(name, eng):
        prepared = eng.prepare(targets, epoch=0)
        rep = eng.last_report
        prep = prep_time(rep)
        comp = gnn_compute_time(prepared)
        share = prep / (prep + comp)
        emit(f"fig2a/{name}/prep_share_pct", share * 100,
             f"prep={prep*1e3:.2f}ms compute(A40-model)={comp*1e3:.2f}ms")
        stats = (eng.graph_store.stats if hasattr(eng, "graph_store")
                 else eng.csr.stats)
        fstats = (eng.feature_store.stats if hasattr(eng, "feature_store")
                  else eng.features.stats)
        hist = dict(stats.size_histogram)
        for k, v in fstats.size_histogram.items():
            hist[k] = hist.get(k, 0) + v
        total = sum(hist.values()) or 1
        small = sum(v for k, v in hist.items() if k <= 4) / total
        emit(f"fig2b/{name}/small_io_pct", small * 100,
             f"n_ios={total} hist_KiB={sorted(hist.items())[:6]}")
        emit(f"fig2c/{name}/gpu_util_proxy_pct", comp / (prep + comp) * 100,
             "computed as compute/(prep+compute)")

    one("agnes", make_agnes(ds))
    for name in ("ginex", "gnndrive"):
        one(name, make_baseline(ALL_BASELINES[name], ds))


if __name__ == "__main__":
    run()
