"""Training step factory: microbatched grad accumulation + ZeRO AdamW.

``make_train_step(model, n_microbatches)`` returns a pure function
    (params, opt_state, batch) -> (params, opt_state, metrics)
that scans over microbatches accumulating gradients (remat happens inside
the model's layer stack), clips by global norm, and applies AdamW whose
moments the caller shards over the data axes (ZeRO-1) via
``opt_state_shardings``.  Under a multi-pod mesh the gradient reduction
over the ``pod`` axis is a single bf16 all-reduce per step, overlapped by
XLA's latency-hiding scheduler with the backward pass; optional int8
error-feedback compression for that axis lives in
``repro.distributed.compression``.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from .optimizer import AdamWState, adamw_update, clip_by_global_norm


def make_train_step(model, *, n_microbatches: int = 1,
                    lr: float | Callable = 1e-4, weight_decay: float = 0.01,
                    clip_norm: float = 1.0,
                    unroll_inner: bool = False,
                    unroll_microbatches: bool = False,
                    attn_impl: str | None = None,
                    grad_transform: Callable | None = None):
    """Build the jittable train step for a CausalLM/EncDecLM."""

    def loss_fn(params, micro):
        return model.loss(params, micro, unroll_inner=unroll_inner,
                          attn_impl=attn_impl)

    def train_step(params, opt_state: AdamWState, batch: dict):
        """``batch`` leaves carry a leading (n_microbatches, ...) axis so
        microbatch selection is a plain scan slice — the per-microbatch
        data sharding (axis 1 = data) is preserved with no gather."""
        if n_microbatches == 1:
            squeezed = jax.tree.map(lambda x: x[0], batch)
            loss, grads = jax.value_and_grad(loss_fn)(params, squeezed)
        elif unroll_microbatches:
            loss, grads = jax.value_and_grad(loss_fn)(
                params, jax.tree.map(lambda x: x[0], batch))
            for i in range(1, n_microbatches):
                l2, g2 = jax.value_and_grad(loss_fn)(
                    params, jax.tree.map(lambda x: x[i], batch))
                loss = loss + l2
                grads = jax.tree.map(jnp.add, grads, g2)
            loss = loss / n_microbatches
            grads = jax.tree.map(lambda g: g / n_microbatches, grads)
        else:
            def body(carry, micro):
                loss_acc, g_acc = carry
                l2, g2 = jax.value_and_grad(loss_fn)(params, micro)
                return (loss_acc + l2,
                        jax.tree.map(jnp.add, g_acc, g2)), None
            g0 = jax.tree.map(jnp.zeros_like, params)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), g0), batch)
            loss = loss / n_microbatches
            grads = jax.tree.map(lambda g: g / n_microbatches, grads)

        if grad_transform is not None:   # e.g. int8 inter-pod compression
            grads = grad_transform(grads)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        step_lr = lr(opt_state.step) if callable(lr) else lr
        params, opt_state = adamw_update(params, grads, opt_state,
                                         lr=step_lr,
                                         weight_decay=weight_decay)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "lr": jnp.asarray(step_lr, jnp.float32)}
        return params, opt_state, metrics

    return train_step


def make_serve_step(model):
    """One-token decode step: (params, caches, tokens, pos) -> logits/caches."""

    def serve_step(params, caches, tokens, pos):
        logits, new_caches = model.decode_step(params, caches, tokens, pos)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tokens, logits, new_caches

    return serve_step
