"""moonshot-v1-16b-a3b [moe]: 48L, d=2048, 16H (kv=16), vocab=163840,
MoE 64 routed experts top-6 + 2 shared, d_expert=1408 — kimi/moonlight
(deepseek-moe lineage: first layer dense).
[hf:moonshotai/Moonlight-16B-A3B; hf]
"""
from .base import LayerSpec, ModelConfig, MoEConfig, register

DENSE_FF = 11264  # dense first-layer FFN (8x expert hidden, ds-moe style)


@register("moonshot-v1-16b-a3b")
def config() -> ModelConfig:
    layers = [LayerSpec(mixer="attn", ffn="mlp")] \
        + [LayerSpec(mixer="attn", ffn="moe") for _ in range(47)]
    return ModelConfig(
        name="moonshot-v1-16b-a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=DENSE_FF, vocab=163840, head_dim=128,
        layers=tuple(layers),
        moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2,
                      group_tokens=4096),
        source="hf:moonshotai/Moonlight-16B-A3B")
