"""Staged PrepareSession: Algorithm 1 as a schedulable dataflow.

``AgnesEngine.prepare()`` used to be a monolithic sample-then-gather call
with a full prefetcher ``reset()`` barrier between hops — the coalesced
scheduler went idle exactly when the next hop's plan was already
computable.  :class:`PrepareSession` re-expresses one hyperbatch's data
preparation as explicit stages that flow through the I/O scheduler::

    plan    — bucket matrix / cache pass: the block visit order is known
    submit  — the IOPlan enters the CoalescedReader (device time charged)
    consume — the ascending row scan fetches and processes the blocks
    assemble— frontiers, MFG layers, contiguous feature outputs

The seam between *plan* and *consume* is what enables **cross-hop plan
fusion**: hop k+1's plan is submitted while hop k's tail blocks are
still being consumed — a partial plan from the mid-scan ``tail_cb`` hook
plus the remainder as soon as the frontier exists, with no ``reset()``
barrier in between — and the gather plan is submitted as soon as the
final frontier exists, before the MFG layer index maps are built.  All
back-to-back submissions are charged through one
:class:`repro.core.io_sched.PlanStream` per device, so the latency-bound
sampling hops and the bandwidth-bound feature gather share the device
queue (``max`` of the summed rooflines instead of the summed per-hop
``max`` — see ``PlanStream``).

Bytes, MFGs and features are *identical* to the barriered path: plans
are filtered against buffer residency and the reader's open plan at
submit time, so every block is still read exactly once
(``tests/test_session.py`` asserts parity).  ``plan_fusion=False``
reproduces the pre-session schedule — one plan per hop, barrier at every
hop boundary — which is what ``benchmarks/bench_plan_fusion.py`` compares
against.
"""
from __future__ import annotations

import dataclasses
import time
from contextlib import nullcontext

import numpy as np

from .sampling import MFG


@dataclasses.dataclass
class IOPlan:
    """One staged I/O submission: the blocks a stage needs from one store.

    ``state`` walks ``planned -> submitted -> consumed``; sessions keep
    every emitted plan in :attr:`PrepareSession.plans` for inspection.
    """

    stage: str               # "sample:hop0[:early]" | "gather"
    store: str               # "graph" | "feature"
    block_ids: np.ndarray    # ascending, buffer-absent at plan time
    block_size: int
    state: str = "planned"
    # per-array block counts when the store has a storage topology
    # attached (topology.py) — how placement splits this submission
    blocks_per_array: np.ndarray | None = None

    @property
    def n_blocks(self) -> int:
        return int(len(self.block_ids))

    @property
    def nbytes(self) -> int:
        return self.n_blocks * self.block_size


class PrepareSession:
    """Drives one hyperbatch's data preparation through explicit stages.

    Create via ``AgnesEngine.prepare()`` (which is now a thin wrapper) or
    directly for stage-level control; :meth:`run` drives every stage to
    completion and returns the prepared minibatches.
    """

    def __init__(self, engine, targets_per_mb: list[np.ndarray],
                 epoch: int = 0, tenant: str | None = None):
        self.engine = engine
        self.epoch = epoch
        # serving-tier label (core/serving.py): which tenant this
        # session's I/O is admitted as; None outside a serving tier
        self.tenant = tenant
        self.frontiers = [np.unique(np.asarray(t, dtype=np.int64))
                          for t in targets_per_mb]
        self.mfgs = [MFG(nodes=[f], layers=[]) for f in self.frontiers]
        self.plans: list[IOPlan] = []
        cfg = engine.config
        self.fused = bool(
            cfg.plan_fusion
            and getattr(engine._g_prefetch, "supports_fusion", False)
            and getattr(engine._f_prefetch, "supports_fusion", False))
        self.sample_wall_s = 0.0
        self.gather_wall_s = 0.0
        self._done = False

    # ------------------------------------------------------------ stages
    def _emit(self, stage: str, store: str, block_ids,
              block_size: int) -> IOPlan:
        plan = IOPlan(stage, store, np.asarray(block_ids, dtype=np.int64),
                      block_size)
        st = (self.engine.graph_store if store == "graph"
              else self.engine.feature_store)
        if st.placement is not None and plan.n_blocks:
            plan.blocks_per_array = st.placement.blocks_per_array(
                plan.block_ids)
        self.plans.append(plan)
        return plan

    @staticmethod
    def _submit(plan: IOPlan, reader) -> None:
        if plan.state != "planned":
            return
        if reader is not None and plan.n_blocks:
            # CoalescedReader.submit drops ids already in its open plan
            # (fused overlap) and charges the submission's device time
            reader.submit(plan.block_ids)
        plan.state = "submitted"

    # ------------------------------------------------------------ drive
    def run(self):
        """Drive plan→submit→consume→assemble to completion."""
        from .agnes import PreparedMinibatch  # cycle: agnes drives sessions

        if self._done:
            raise RuntimeError("a PrepareSession is single-use")
        eng = self.engine
        # the online re-placement path (engine.end_epoch) swaps store
        # placements and must only run between sessions — mark the
        # engine busy so a mid-session migration fails loudly instead
        # of racing the open plan's array split
        eng._in_session = True
        sampler, gatherer = eng.sampler, eng.gatherer
        g_reader, f_reader = eng._g_prefetch, eng._f_prefetch
        g_bs = eng.graph_store.block_size
        f_bs = eng.feature_store.block_size
        n_hops = len(sampler.fanouts)
        # stage spans (core/telemetry.py): cat "prepare.stage" nests
        # under the engine-level "prepare" span on the tenant's track
        # and never double counts into the Fig.2 prepare bar
        tel = getattr(eng, "telemetry", None)
        tr = tel.trace if tel is not None else None
        track = f"prepare:{self.tenant or getattr(eng, '_tel_label', 'train')}"

        def _stage(name):
            if tr is None:
                return nullcontext()
            return tr.span(name, "prepare.stage", track)

        t0 = time.perf_counter()
        try:
            frontiers = self.frontiers
            gp = fplan = None
            with _stage("plan:hop0"):
                hp = sampler.plan_hop(frontiers, 0) if n_hops else None
                if hp is not None:
                    plan = self._emit(
                        "sample:hop0", "graph",
                        eng.graph_buffer.absent(hp.row_blocks), g_bs)
                    self._submit(plan, g_reader)
            for hop in range(n_hops):
                tail_cb = None
                if self.fused and hop + 1 < n_hops:
                    def tail_cb(cand, _h=hop):
                        # cross-hop fusion: partial plan for hop k+1 while
                        # hop k's tail blocks are still being consumed
                        blocks = np.unique(sampler._primary_block(cand))
                        early = self._emit(
                            f"sample:hop{_h + 1}:early", "graph",
                            eng.graph_buffer.absent(blocks), g_bs)
                        self._submit(early, g_reader)
                with _stage(f"consume:hop{hop}"):
                    sampler.consume_hop(hp, self.epoch, tail_cb=tail_cb)
                for p in self.plans:  # the hop's main + early plans
                    if p.store == "graph" and p.state == "submitted" \
                            and p.stage.split(":")[1] == f"hop{hop}":
                        p.state = "consumed"
                if not self.fused and g_reader is not None:
                    g_reader.reset()  # pre-session hop barrier
                nxt = sampler.advance_frontiers(hp)
                nxt_hp = None
                if hop + 1 < n_hops:
                    with _stage(f"plan:hop{hop + 1}"):
                        nxt_hp = sampler.plan_hop(nxt, hop + 1)
                        plan = self._emit(
                            f"sample:hop{hop + 1}", "graph",
                            eng.graph_buffer.absent(nxt_hp.row_blocks), g_bs)
                        self._submit(plan, g_reader)
                else:
                    # gather plan as soon as the final frontier exists —
                    # before the MFG layer index maps are built
                    self.sample_wall_s = time.perf_counter() - t0
                    with _stage("plan:gather"):
                        gp = gatherer.plan_gather(nxt)
                        fplan = self._emit(
                            "gather", "feature",
                            eng.feature_buffer.absent(gp.row_blocks)
                            if gp.n_miss else [], f_bs)
                        self._submit(fplan, f_reader)
                # layer index assembly overlaps the submitted I/O
                with _stage(f"assemble:hop{hop}"):
                    sampler.assemble_hop(hp, nxt, self.mfgs)
                frontiers, hp = nxt, nxt_hp
            if gp is None:  # 0-hop degenerate case: gather the targets
                gp = gatherer.plan_gather(frontiers)
                fplan = self._emit(
                    "gather", "feature",
                    eng.feature_buffer.absent(gp.row_blocks)
                    if gp.n_miss else [], f_bs)
                self._submit(fplan, f_reader)
            t1 = time.perf_counter()
            with _stage("consume:gather"):
                feats = gatherer.consume_gather(gp) if gp.n_miss else gp.outs
            fplan.state = "consumed"
            if not self.fused and f_reader is not None:
                f_reader.reset()
            self.gather_wall_s = time.perf_counter() - t1
            self._done = True
            resident = gp.resident or [None] * len(self.mfgs)
            return [PreparedMinibatch(m, f, r)
                    for m, f, r in zip(self.mfgs, feats, resident)]
        finally:
            # session end: the stream's barrier + drop any stale state
            # (early-planned blocks that turned out buffer-resident);
            # no-op on the barriered path, cleanup after an exception
            eng._in_session = False
            for rd in (g_reader, f_reader):
                if rd is not None:
                    rd.reset()
