"""Fig 12 (EQ4): accuracy per unit time — AGNES reaches the *same*
per-epoch accuracy as the Ginex-like engine (bit-identical samples via
the deterministic sampler) in less modeled wall time."""
from __future__ import annotations

import numpy as np

from .common import (ALL_BASELINES, emit, get_dataset, make_agnes,
                     make_baseline, quick_val, targets_for)
from repro.gnn import GNNTrainer


def run(arch: str = "sage", epochs: int | None = None):
    if epochs is None:
        epochs = quick_val(3, 1)
    ds = get_dataset("ig-mini")
    train_nodes = np.arange(min(4096, int(ds.n_nodes * 0.6)))
    eval_targets = targets_for(ds, n_mb=2, mb_size=512, seed=99)

    results = {}
    for name, make in (("agnes", lambda: make_agnes(ds)),
                       ("ginex", lambda: make_baseline(
                           ALL_BASELINES["ginex"], ds))):
        eng = make()
        tr = GNNTrainer(arch=arch, in_dim=ds.dim, hidden=128, n_classes=16,
                        n_layers=3, seed=7)
        tr.labels = ds.labels
        elapsed = 0.0
        accs = []
        for ep in range(epochs):
            mb = 512
            mbs = [train_nodes[i:i + mb]
                   for i in range(0, len(train_nodes), mb)]
            prepared = eng.prepare(mbs, epoch=ep)
            elapsed += eng.last_report.modeled_io_s
            for p in prepared:
                tr.train_minibatch(p)
            elapsed += tr.compute_time
            tr.compute_time = 0.0
            acc = tr.evaluate(eng.prepare(
                [t for t in eval_targets], epoch=100 + ep))
            accs.append(acc)
            emit(f"fig12/{name}/epoch{ep}", elapsed * 1e6,
                 f"acc={acc:.4f}")
        results[name] = accs
    # identical sampling -> identical accuracy trajectory
    same = np.allclose(results["agnes"], results["ginex"], atol=1e-6)
    emit("fig12/accuracy_identical", 0.0, str(same))


if __name__ == "__main__":
    run()
