"""Pallas TPU kernel: fused neighbor gather + aggregate (GNN hot spot).

GNN aggregation ``h_v = mean_{u in N(v)} x_u`` on GPU is a scatter-add
(cuSPARSE SpMM); on TPU the efficient form is the inverse — a *gather*
driven by the padded neighbor table the AGNES sampler emits, accumulated
in VMEM.  This is the hardware adaptation DESIGN.md §3 describes: the
random access moves into the BlockSpec index_map (sequential, prefetched
DMA schedule) instead of a scattered write stream.

Grid: (n_dst, fanout).  For each dst row we walk its fanout neighbor
rows; the neighbor feature block is selected by the scalar-prefetched
``nbr_idx``; a VMEM f32 accumulator carries the partial sum; on the last
fanout step the (optionally mean-normalized) row is written out.
Padding (-1) contributes zero via a mask multiply; the index map clamps
-1 to row 0 so the DMA stays in bounds.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _agg_kernel(idx_ref, cnt_ref, table_ref, out_ref, acc_ref, *,
                fanout: int, mean: bool):
    v = pl.program_id(0)
    f = pl.program_id(1)

    @pl.when(f == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    valid = idx_ref[v * fanout + f] >= 0
    w = jnp.where(valid, 1.0, 0.0).astype(jnp.float32)
    acc_ref[...] += table_ref[...].astype(jnp.float32) * w

    @pl.when(f == fanout - 1)
    def _finalize():
        acc = acc_ref[...]
        if mean:
            c = jnp.maximum(cnt_ref[v].astype(jnp.float32), 1.0)
            acc = acc / c
        out_ref[...] = acc.astype(out_ref.dtype)


def gather_aggregate_kernel(table: jnp.ndarray, nbr_idx: jnp.ndarray, *,
                            mean: bool = True,
                            interpret: bool = False) -> jnp.ndarray:
    """out[v] = sum/mean_f table[nbr_idx[v, f]] with -1 padding masked."""
    n_dst, fanout = nbr_idx.shape
    m, d = table.shape
    flat_idx = nbr_idx.reshape(-1).astype(jnp.int32)
    counts = jnp.sum(nbr_idx >= 0, axis=1).astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # flat_idx, counts
        grid=(n_dst, fanout),
        in_specs=[
            pl.BlockSpec(
                (1, d),
                lambda v, f, idx_ref, cnt_ref: (
                    jnp.maximum(idx_ref[v * fanout + f], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, d),
                               lambda v, f, idx_ref, cnt_ref: (v, 0)),
        scratch_shapes=[pltpu.VMEM((1, d), jnp.float32)],
    )
    kern = functools.partial(_agg_kernel, fanout=fanout, mean=mean)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_dst, d), table.dtype),
        interpret=interpret,
    )(flat_idx, counts, table)
