"""h2o-danube-3-4b [dense]: 24L, d=3840, 32H (GQA kv=8), d_ff=10240,
vocab=32000 — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; unverified]
"""
from .base import LayerSpec, ModelConfig, register

WINDOW = 4096  # mistral-style SWA


@register("h2o-danube-3-4b")
def config() -> ModelConfig:
    layers = tuple(LayerSpec(mixer="swa", ffn="mlp", window=WINDOW)
                   for _ in range(24))
    return ModelConfig(
        name="h2o-danube-3-4b", family="dense",
        n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
        d_ff=10240, vocab=32000, head_dim=120,
        layers=layers,
        source="arXiv:2401.16818 (danube family, SWA)")
