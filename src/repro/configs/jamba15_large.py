"""jamba-1.5-large-398b [hybrid]: 72L, d=8192, 64H (GQA kv=8), d_ff=24576,
vocab=65536, MoE 16e top-2 — Mamba+attention 1:7 interleave, MoE every
other layer. [arXiv:2403.19887; hf]
"""
from .base import LayerSpec, ModelConfig, MoEConfig, SSMConfig, register


@register("jamba-1.5-large-398b")
def config() -> ModelConfig:
    # Jamba block = 8 layers: attention at index 4, Mamba elsewhere;
    # MoE replaces the MLP on every other layer (odd indices).
    unit = []
    for i in range(8):
        mixer = "attn" if i == 4 else "mamba"
        ffn = "moe" if i % 2 == 1 else "mlp"
        unit.append(LayerSpec(mixer=mixer, ffn=ffn))
    layers = tuple(unit * 9)  # 72 layers
    return ModelConfig(
        name="jamba-1.5-large-398b", family="hybrid",
        n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=24576, vocab=65536, head_dim=128,
        layers=layers,
        moe=MoEConfig(n_experts=16, top_k=2, d_expert=24576, n_shared=0,
                      group_tokens=4096),
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, chunk=64),
        sequence_parallel=True,   # 398B on 16 GB chips needs SP residuals
        source="arXiv:2403.19887 (Jamba-1.5-Large)")
