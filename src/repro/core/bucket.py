"""Bucket matrix ``Bck`` for node identification (paper §3.4(3)).

``Bck`` is logically a (n_blocks × hyperbatch_size) matrix whose cell
``Bck[i, j]`` holds the nodes of minibatch *j* that live in block *i*.
Real-world buckets are extremely sparse, so we materialize it as a sorted
COO structure grouped by (block, minibatch): scanning "row ``Bck[i, :]``"
is a contiguous slice.  Construction is a single vectorized
sort-by-(block, minibatch) — no Python-per-node work.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Bucket:
    """Sparse (block × minibatch) bucket matrix."""

    block_ids: np.ndarray     # (n_groups,) ascending unique-per-(block,mb)
    mb_ids: np.ndarray        # (n_groups,)
    group_ptr: np.ndarray     # (n_groups + 1,) into nodes
    nodes: np.ndarray         # concatenated node ids, grouped
    row_ptr: np.ndarray       # (n_rows + 1,) into groups, one row per block
    row_blocks: np.ndarray    # (n_rows,) distinct block ids, ascending

    @property
    def n_rows(self) -> int:
        return len(self.row_blocks)

    def row(self, r: int):
        """Iterate ``Bck[i, :]`` for row r: yields (mb_id, nodes)."""
        for g in range(self.row_ptr[r], self.row_ptr[r + 1]):
            yield int(self.mb_ids[g]), self.nodes[self.group_ptr[g]:self.group_ptr[g + 1]]

    def row_nodes(self, r: int) -> np.ndarray:
        """All nodes of row r across minibatches (with duplicates)."""
        g0, g1 = self.row_ptr[r], self.row_ptr[r + 1]
        return self.nodes[self.group_ptr[g0]:self.group_ptr[g1]]


def build_bucket(nodes_per_mb: list[np.ndarray],
                 blocks_of_nodes: list[np.ndarray]) -> Bucket:
    """Build ``Bck`` from per-minibatch frontiers.

    ``blocks_of_nodes[j][t]`` is the block id of ``nodes_per_mb[j][t]``
    (a node split across several blocks may appear once per block; callers
    pass the *primary* block and the sampler pulls continuation blocks).
    """
    if not nodes_per_mb:
        return _empty()
    nodes = np.concatenate(nodes_per_mb) if nodes_per_mb else np.zeros(0, np.int64)
    blocks = np.concatenate(blocks_of_nodes) if blocks_of_nodes else np.zeros(0, np.int64)
    mbs = np.repeat(np.arange(len(nodes_per_mb), dtype=np.int64),
                    [len(x) for x in nodes_per_mb])
    if len(nodes) == 0:
        return _empty()
    # sort by (block, mb, node) — one vectorized argsort
    n_mb = len(nodes_per_mb)
    key = (blocks * n_mb + mbs)
    order = np.argsort(key * (nodes.max() + 1) + nodes
                       if nodes.max() < 2**30 else key, kind="stable")
    nodes, blocks, mbs, key = nodes[order], blocks[order], mbs[order], key[order]
    # group boundaries by (block, mb)
    is_new = np.empty(len(key), dtype=bool)
    is_new[0] = True
    np.not_equal(key[1:], key[:-1], out=is_new[1:])
    g_start = np.nonzero(is_new)[0]
    group_ptr = np.append(g_start, len(nodes))
    g_block = blocks[g_start]
    g_mb = mbs[g_start]
    # rows: distinct blocks
    row_new = np.empty(len(g_block), dtype=bool)
    row_new[0] = True
    np.not_equal(g_block[1:], g_block[:-1], out=row_new[1:])
    r_start = np.nonzero(row_new)[0]
    row_ptr = np.append(r_start, len(g_block))
    row_blocks = g_block[r_start]
    return Bucket(g_block, g_mb, group_ptr.astype(np.int64), nodes,
                  row_ptr.astype(np.int64), row_blocks)


def _empty() -> Bucket:
    z = np.zeros(0, dtype=np.int64)
    return Bucket(z, z, np.zeros(1, dtype=np.int64), z,
                  np.zeros(1, dtype=np.int64), z)
