"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention, gather_aggregate, gather_rows
from repro.kernels import ref

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("m,d", [(16, 128), (64, 128), (33, 256)])
@pytest.mark.parametrize("n", [1, 8, 57])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather_rows_sweep(m, d, n, dtype):
    table = jax.random.normal(KEY, (m, d), dtype)
    idx = jax.random.randint(jax.random.fold_in(KEY, n), (n,), 0, m)
    out = gather_rows(table, idx, use_kernel=True, interpret=True)
    expect = ref.gather_rows_ref(table, idx)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32))


@pytest.mark.parametrize("n_dst,fanout", [(4, 3), (16, 10), (33, 7)])
@pytest.mark.parametrize("mean", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather_aggregate_sweep(n_dst, fanout, mean, dtype):
    m, d = 48, 128
    table = jax.random.normal(KEY, (m, d), dtype)
    nbr = jax.random.randint(jax.random.fold_in(KEY, n_dst),
                             (n_dst, fanout), -1, m)
    out = gather_aggregate(table, nbr, mean=mean, use_kernel=True,
                           interpret=True)
    expect = ref.gather_aggregate_ref(table, nbr, mean=mean)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=2e-2 if dtype == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("s,bq,bk", [(128, 64, 64), (256, 128, 128),
                                     (192, 64, 64)])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 64)])
def test_flash_attention_sweep(s, bq, bk, causal, window):
    B, Hq, Hkv, D = 1, 4, 2, 64
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (B, Hq, s, D))
    k = jax.random.normal(jax.random.fold_in(KEY, 2), (B, Hkv, s, D))
    v = jax.random.normal(jax.random.fold_in(KEY, 3), (B, Hkv, s, D))
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=bq, block_k=bk,
                          use_kernel=True, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(out, expect, rtol=3e-4, atol=3e-4)


def test_flash_attention_bf16():
    B, Hq, Hkv, S, D = 2, 2, 1, 128, 64
    q = jax.random.normal(KEY, (B, Hq, S, D), jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(KEY, 9), (B, Hkv, S, D),
                          jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(KEY, 8), (B, Hkv, S, D),
                          jnp.bfloat16)
    out = flash_attention(q, k, v, use_kernel=True, interpret=True,
                          block_q=64, block_k=64)
    expect = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_chunked_attention_matches_ref():
    """The pure-jnp chunked path (model hot path on CPU) vs oracle."""
    from repro.models.attention import chunked_attention
    B, Hq, Hkv, S, D = 1, 4, 2, 256, 32
    q = jax.random.normal(KEY, (B, Hq, S, D))
    k = jax.random.normal(jax.random.fold_in(KEY, 5), (B, Hkv, S, D))
    v = jax.random.normal(jax.random.fold_in(KEY, 6), (B, Hkv, S, D))
    pos = jnp.arange(S)
    for window in (0, 64):
        out = chunked_attention(q, k, v, pos, pos, causal=True,
                                window=window, scale=D ** -0.5,
                                q_chunk=64, kv_chunk=64)
        expect = ref.flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(out, expect, rtol=2e-4, atol=2e-4)


def test_decode_attention_matches_ref():
    from repro.models.attention import decode_attention
    B, Hq, Hkv, Sc, D = 3, 4, 2, 64, 32
    q = jax.random.normal(KEY, (B, Hq, D))
    kc = jax.random.normal(jax.random.fold_in(KEY, 2), (B, Hkv, Sc, D))
    vc = jax.random.normal(jax.random.fold_in(KEY, 3), (B, Hkv, Sc, D))
    lengths = jnp.full((B,), 40)
    expect = ref.decode_attention_ref(q, kc, vc, lengths)
    slot_pos = jnp.where(jnp.arange(Sc) < 40, jnp.arange(Sc), -1)
    out = decode_attention(q, kc, vc, slot_pos, jnp.asarray(39),
                           window=0, scale=D ** -0.5)
    np.testing.assert_allclose(out, expect, rtol=2e-5, atol=2e-5)
