"""Storage topology subsystem: multi-SSD block placement (ROADMAP item).

The paper evaluates RAID0 arrays of 1-4 NVMe drives; until this module
the reproduction only modeled that *aggregate* bandwidth inside
``NVMeModel.n_ssd`` — everything above the device model treated storage
as one opaque device, so striping could not change request shapes, queue
depths, or placement.  This module makes the topology explicit:

* :class:`StorageTopology` — N *independent* NVMe arrays, each its own
  :class:`~repro.core.device_model.NVMeModel` (possibly heterogeneous)
  with its own per-array :class:`~repro.core.device_model.IOStats`;
* :class:`PlacementPolicy` implementations mapping every store block to
  ``(array, local_block)``:

  - :class:`ContiguousPlacement` — bandwidth-proportional contiguous
    ranges (one array owns one slab of the id space);
  - :class:`StripePlacement` — round-robin stripes of a configurable
    width in blocks (RAID0: consecutive stripes on one array are
    *physically adjacent*, so a long global run becomes N parallel
    sequential reads);
  - :class:`HotnessAwarePlacement` — Ginex-style: high-degree graph
    blocks and hot feature blocks are pinned greedily on the
    fastest/least-loaded array (load balanced relative to bandwidth);

* :class:`BlockPlacement` — the concrete ``block_id -> (array, local)``
  mapping, persisted in the store's on-disk directory
  (``<store path>.topo.json``) and reloadable via :meth:`BlockPlacement.
  load`;
* :func:`topology_plan_cost` — per-array roofline accounting: arrays
  serve their shares *in parallel*, so a split submission costs
  ``max`` over the per-array ``batch_time`` rooflines instead of one
  merged-device roofline (the seam that makes striping actually reduce
  modeled prepare time instead of inflating a constant).

Stores attach a topology via ``attach_topology`` (``block_store.py``),
which splits coalesced runs at stripe boundaries into per-array runs;
``CoalescedReader`` (``io_sched.py``) then grows per-array worker queues
with independent ``io_queue_depth``, and ``PlanStream`` charges fused
plans as the ``max`` over per-array accumulated rooflines.
"""
from __future__ import annotations

import bisect
import dataclasses
import json
import os
import threading

import numpy as np


def fsync_dir(path: str) -> None:
    """fsync the directory holding ``path`` so a just-renamed or
    just-created entry survives power loss (the rename itself is atomic
    but not durable until its directory is flushed)."""
    fd = os.open(os.path.dirname(os.path.abspath(path)) or ".",
                 os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)

from .device_model import IOStats, NVMeModel
from .io_sched import Run, coalesce


class StorageTopology:
    """N independent NVMe arrays with per-array I/O accounting.

    Unlike ``NVMeModel(n_ssd=N)`` — one merged device with N-fold
    bandwidth — each array here has its own queue, its own latency
    budget, and its own :class:`IOStats`, so placement and request
    splitting are observable per array (``utilization_summary``).
    """

    def __init__(self, devices):
        if not devices:
            raise ValueError("a topology needs at least one array")
        self.devices: list[NVMeModel] = list(devices)
        self.array_stats: list[IOStats] = [IOStats() for _ in self.devices]
        # several stores (and their reader/prefetch threads) share one
        # topology; their per-store _io_locks do not protect these
        # shared IOStats — every array_stats mutation takes this lock
        self.lock = threading.Lock()
        # degraded mode (core/fault.py dropout faults): an offline array
        # stops serving I/O; reads of its blocks reroute to survivors
        # and MigrationEngine.evacuate drains its blocks at the next
        # epoch boundary
        self._offline = [False] * len(self.devices)

    @property
    def n_arrays(self) -> int:
        return len(self.devices)

    # ------------------------------------------------------------ fault domain
    def mark_offline(self, array: int) -> None:
        """Take one array out of service (dropout fault / maintenance)."""
        self._offline[int(array)] = True

    def mark_online(self, array: int) -> None:
        """Return a repaired/replaced array to service."""
        self._offline[int(array)] = False

    def is_online(self, array: int) -> bool:
        return not self._offline[int(array)]

    def online_arrays(self) -> list[int]:
        return [a for a in range(self.n_arrays) if not self._offline[a]]

    def degraded_target(self) -> int:
        """Least-busy online array to serve I/O for an offline one.

        Takes ``self.lock`` — callers must not already hold it.
        """
        cands = self.online_arrays()
        if not cands:
            from .fault import ArrayOfflineError
            raise ArrayOfflineError(-1, "every storage array is offline")
        with self.lock:
            return min(cands,
                       key=lambda a: self.array_stats[a].modeled_io_time)

    @classmethod
    def uniform(cls, n_arrays: int, like: NVMeModel | None = None,
                **kw) -> "StorageTopology":
        """N identical single-SSD arrays (the paper's RAID0 sweep shape)."""
        base = like if like is not None else NVMeModel()
        return cls([dataclasses.replace(base, n_ssd=1, **kw)
                    for _ in range(n_arrays)])

    def queue_depth_of(self, queue_depth, array: int) -> int:
        """Resolve a scalar-or-per-array queue depth for one array."""
        if isinstance(queue_depth, dict):
            return queue_depth.get(array, self.devices[array].queue_depth)
        return queue_depth

    def utilization_summary(self) -> dict:
        """Per-array byte/request/busy-time balance of everything charged.

        ``busy_s`` is each array's own isolated roofline (the time it
        would take serving its share alone); ``balance`` is min/max busy
        across arrays — 1.0 means perfectly even placement.
        """
        with self.lock:
            return self._summary_locked()

    def _summary_locked(self) -> dict:
        busys = [st.modeled_io_time for st in self.array_stats]
        total_bytes = sum(st.total_bytes for st in self.array_stats)
        arrays = []
        for a, (dev, st) in enumerate(zip(self.devices, self.array_stats)):
            arrays.append({
                "array": a,
                "online": not self._offline[a],
                "bandwidth_GBps": round(dev.array_bandwidth / 1e9, 3),
                "latency_us": round(dev.latency * 1e6, 3),
                "device_queue_depth": dev.queue_depth,
                "bytes": st.total_bytes,
                "n_requests": st.n_requests,
                "sequential_fraction": round(
                    st.n_sequential_reads / st.n_reads, 4) if st.n_reads else 0.0,
                "busy_s": round(st.modeled_io_time, 6),
                "share": round(st.total_bytes / total_bytes, 4)
                if total_bytes else 0.0,
            })
        mx = max(busys) if busys else 0.0
        return {
            "n_arrays": self.n_arrays,
            "offline": [a for a in range(self.n_arrays) if self._offline[a]],
            "balance": round(min(busys) / mx, 4) if mx > 0 else 1.0,
            "arrays": arrays,
        }


class BlockPlacement:
    """Concrete ``block_id -> (array, local_block)`` mapping for one store.

    ``local_of`` numbers each array's blocks in ascending *global* order,
    so globally-adjacent blocks that land on the same array stay locally
    adjacent (device-level sequential) — the property the per-array run
    splitting and sequential accounting rely on.
    """

    def __init__(self, array_of, local_of, policy: str = "custom",
                 n_arrays: int | None = None):
        self.array_of = np.asarray(array_of, dtype=np.int64)
        self.local_of = np.asarray(local_of, dtype=np.int64)
        if self.array_of.shape != self.local_of.shape:
            raise ValueError("array_of and local_of must align")
        self.policy = policy
        self.n_arrays = int(n_arrays if n_arrays is not None
                            else (self.array_of.max() + 1
                                  if len(self.array_of) else 1))
        # per-array slot bookkeeping for online migration (lazy: only
        # built once move_block is first called)
        self._next_local: dict[int, int] | None = None
        self._free: dict[int, list[int]] | None = None

    @property
    def n_blocks(self) -> int:
        return int(len(self.array_of))

    # ------------------------------------------------------------ splitting
    def shard_run(self, run: Run) -> list[tuple[int, Run]]:
        """Split one globally-contiguous run at array boundaries.

        Under striping these are the stripe boundaries; each returned
        segment is still globally contiguous (one memmap slice) and
        lives wholly on one array — the unit the per-array execution
        queues operate on.
        """
        arr = self.array_of[run.start:run.stop]
        cuts = np.nonzero(np.diff(arr) != 0)[0] + 1
        bounds = np.concatenate([[0], cuts, [run.count]]).astype(np.int64)
        return [(int(arr[s]), Run(run.start + int(s), int(e - s)))
                for s, e in zip(bounds[:-1], bounds[1:])]

    def split_runs(self, runs: list[Run], block_size: int,
                   max_coalesce_bytes: int = 0
                   ) -> list[tuple[int, list[Run]]]:
        """Per-array *device-request* view of one submission.

        Maps every block to its local id and re-coalesces per array:
        consecutive stripes on one array are physically adjacent (RAID0),
        so segments that were split only by stripe boundaries merge back
        into long per-array sequential requests, capped at
        ``max_coalesce_bytes`` per request with :func:`coalesce`'s
        convention (``0`` = one request per block — the per-block path
        stays per-block on a placed store).  Returned runs are in
        *local* block coordinates — accounting only, never dereferenced
        against the global memmap.
        """
        ids = np.concatenate([np.arange(r.start, r.stop) for r in runs])
        arr = self.array_of[ids]
        loc = self.local_of[ids]
        out: list[tuple[int, list[Run]]] = []
        for a in np.unique(arr):
            mine = np.sort(loc[arr == a])
            out.append((int(a), coalesce(mine, block_size,
                                         max_coalesce_bytes)))
        return out

    def blocks_per_array(self, block_ids) -> np.ndarray:
        """Per-array block counts of a plan (introspection/benchmarks)."""
        ids = np.asarray(block_ids, dtype=np.int64)
        if ids.size == 0:
            return np.zeros(self.n_arrays, dtype=np.int64)
        return np.bincount(self.array_of[ids], minlength=self.n_arrays)

    # ------------------------------------------------------------ migration
    def _ensure_slots(self) -> None:
        """Build the per-array free/next-slot maps from the current mapping."""
        if self._next_local is not None:
            return
        self._next_local = {}
        self._free = {}
        for a in range(self.n_arrays):
            mine = self.local_of[self.array_of == a]
            if len(mine) == 0:
                self._next_local[a] = 0
                self._free[a] = []
                continue
            nxt = int(mine.max()) + 1
            present = np.zeros(nxt, dtype=bool)
            present[mine] = True
            self._next_local[a] = nxt
            # ascending: reuse the lowest freed slot first
            self._free[a] = np.nonzero(~present)[0].tolist()

    def move_block(self, block_id: int, dst_array: int) -> None:
        """Reassign one block to ``dst_array``, freeing its old local slot.

        The destination slot comes from the array's free list (lowest
        first) or, when none is free, a fresh slot past the end of its
        local space — the same tail "hot partition" convention as
        :class:`HotnessAwarePlacement`, so the destination's natural
        stripe lattice is never perturbed.  This only rewrites the
        mapping; the durable write path (block copy + fsync + atomic
        metadata commit) lives in ``block_store.migrate_blocks``.
        """
        b = int(block_id)
        dst = int(dst_array)
        if not (0 <= dst < self.n_arrays):
            raise ValueError(f"array {dst} outside topology of {self.n_arrays}")
        src = int(self.array_of[b])
        if src == dst:
            return
        self._ensure_slots()
        bisect.insort(self._free[src], int(self.local_of[b]))
        if self._free[dst]:
            slot = self._free[dst].pop(0)
        else:
            slot = self._next_local[dst]
            self._next_local[dst] = slot + 1
        self.array_of[b] = dst
        self.local_of[b] = slot

    # ------------------------------------------------------------ persistence
    def save(self, store_path: str) -> str:
        """Persist next to the store's data file (``<path>.topo.json``).

        Atomic: the payload is written to ``<path>.topo.json.tmp`` and
        fsynced, then moved into place with :func:`os.replace` — a crash
        mid-save can leave a stale temp file behind but never a torn
        ``topo.json`` (the committed file is always the complete old or
        the complete new mapping).  Stale temp files are discarded by
        ``block_store.recover_store_metadata`` when the store reopens.
        """
        out = store_path + ".topo.json"
        tmp = out + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"policy": self.policy, "n_arrays": self.n_arrays,
                       "array_of": self.array_of.tolist(),
                       "local_of": self.local_of.tolist()}, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, out)
        fsync_dir(out)  # make the rename itself durable
        return out

    @classmethod
    def load(cls, store_path: str) -> "BlockPlacement":
        with open(store_path + ".topo.json") as f:
            meta = json.load(f)
        return cls(np.asarray(meta["array_of"], dtype=np.int64),
                   np.asarray(meta["local_of"], dtype=np.int64),
                   policy=meta["policy"], n_arrays=meta["n_arrays"])


# ---------------------------------------------------------------- policies
class PlacementPolicy:
    """Maps a store's block id space onto a topology's arrays."""

    name = "base"

    def place(self, n_blocks: int, topology: StorageTopology,
              hotness: np.ndarray | None = None) -> BlockPlacement:
        raise NotImplementedError


class ContiguousPlacement(PlacementPolicy):
    """Bandwidth-proportional contiguous ranges (one slab per array)."""

    name = "contiguous"

    def place(self, n_blocks, topology, hotness=None):
        bw = np.array([d.array_bandwidth for d in topology.devices],
                      dtype=np.float64)
        ends = np.floor(np.cumsum(bw) / bw.sum() * n_blocks).astype(np.int64)
        ends[-1] = n_blocks
        starts = np.concatenate([[0], ends[:-1]])
        array_of = np.repeat(np.arange(topology.n_arrays),
                             np.maximum(ends - starts, 0))
        local_of = np.arange(n_blocks, dtype=np.int64) - starts[array_of]
        return BlockPlacement(array_of, local_of, self.name,
                              topology.n_arrays)


class StripePlacement(PlacementPolicy):
    """Round-robin RAID0 stripes of ``stripe_width_blocks`` blocks."""

    name = "stripe"

    def __init__(self, stripe_width_blocks: int = 1):
        self.width = max(int(stripe_width_blocks), 1)

    def place(self, n_blocks, topology, hotness=None):
        n, w = topology.n_arrays, self.width
        ids = np.arange(n_blocks, dtype=np.int64)
        stripe = ids // w
        array_of = stripe % n
        local_of = (stripe // n) * w + ids % w
        return BlockPlacement(array_of, local_of, self.name, n)


class HotnessAwarePlacement(PlacementPolicy):
    """Degree/hotness-aware placement (Ginex-style pinning).

    Two mechanisms on top of plain striping, both keyed to where the
    modeled time actually goes:

    * **Hot-run pinning** — the blocks covering ``hot_mass`` of the
      total hotness (capped at ``max_hot_fraction`` of all blocks) are
      pinned, *whole consecutive runs at a time*, on the
      fastest/least-loaded array: greedy on accumulated hotness load
      relative to bandwidth, seeded with each array's cold load so the
      pinning balances *total* traffic, not just the hot set.  Runs,
      not blocks: consecutive hot blocks are one object's chain (a hub
      split across blocks) or one hot region (high-degree rows packed
      together by the locality relabel), read with locally-sequential
      I/O — scattering them across arrays turns every link into a
      full-latency random head, costing more than the balance wins.
    * **Skew gate** — pinning only happens when the capped hot set
      concentrates >= ``hot_gate`` times its block-count share of the
      mass.  A flat distribution has no hot set worth perturbing the
      stripe for, so cold-path stores degenerate to plain striping.

    Cold blocks keep their *natural* stripe slot (``(id // width) %
    n_arrays`` computed on global ids, not renumbered around the hot
    set): round-robin striping keeps any access stride that divides
    ``n_arrays`` device-level sequential (the reason real RAID0 arrays
    come in powers of two), and renumbering would shift every slot
    after a pinned block and break those harmonics.  Pinned blocks land
    in a dedicated *hot partition* at the end of each array's local
    space — splicing them between an array's natural members would
    punch holes in its stripe adjacency and turn the array's own
    sequential runs into random heads.
    """

    name = "hotness"

    def __init__(self, stripe_width_blocks: int = 1, hot_mass: float = 0.5,
                 max_hot_fraction: float = 0.25, hot_gate: float = 2.0):
        self.width = max(int(stripe_width_blocks), 1)
        self.hot_mass = float(hot_mass)
        self.max_hot_fraction = float(max_hot_fraction)
        self.hot_gate = float(hot_gate)

    def place(self, n_blocks, topology, hotness=None):
        n = topology.n_arrays
        if hotness is None or n_blocks == 0:
            return StripePlacement(self.width).place(n_blocks, topology)
        h = np.asarray(hotness, dtype=np.float64)
        if len(h) != n_blocks:
            raise ValueError("hotness must have one score per block")
        ids = np.arange(n_blocks, dtype=np.int64)
        natural = (ids // self.width) % n
        order = np.argsort(-h, kind="stable")
        cum = np.cumsum(h[order])
        total = float(cum[-1])
        k = int(np.searchsorted(cum, self.hot_mass * total) + 1) \
            if total > 0 else 0
        k = min(k, max(int(n_blocks * self.max_hot_fraction), 1))
        # skew gate: pin only if the hot set genuinely concentrates mass
        mass_frac = float(cum[k - 1]) / total if (k and total > 0) else 0.0
        if k == 0 or mass_frac < self.hot_gate * (k / n_blocks):
            k = 0
        array_of = natural.copy()
        pinned = np.zeros(n_blocks, dtype=bool)
        if k:
            bw = np.array([d.array_bandwidth for d in topology.devices],
                          dtype=np.float64)
            hot = np.sort(order[:k])
            pinned[hot] = True
            load = np.zeros(n, dtype=np.float64)
            np.add.at(load, natural[~pinned], h[~pinned])  # cold seed
            cuts = np.nonzero(np.diff(hot) != 1)[0] + 1
            segments = np.split(hot, cuts)
            for seg in sorted(segments, key=lambda s: -float(h[s].sum())):
                a = int(np.argmin(load / bw))  # fastest/least-loaded
                array_of[seg] = a
                load[a] += float(h[seg].sum())
        local_of = np.empty(n_blocks, dtype=np.int64)
        for a in range(n):
            mine = np.nonzero(array_of == a)[0]
            # natural members first (stripe lattice intact), then the
            # array's hot partition
            mine = np.concatenate([mine[~pinned[mine]], mine[pinned[mine]]])
            local_of[mine] = np.arange(len(mine), dtype=np.int64)
        return BlockPlacement(array_of, local_of, self.name, n)


def make_policy(name: str, stripe_width_blocks: int = 1) -> PlacementPolicy:
    """Policy factory for the ``AgnesConfig.placement`` knob."""
    if name == "contiguous":
        return ContiguousPlacement()
    if name == "stripe":
        return StripePlacement(stripe_width_blocks)
    if name == "hotness":
        return HotnessAwarePlacement(stripe_width_blocks)
    raise ValueError(f"unknown placement policy {name!r}")


# ---------------------------------------------------------------- accounting
def distribute_offline_runs(placed, topology: StorageTopology):
    """Reroute offline arrays' run shares onto the survivors.

    ``placed`` is a ``[(array, runs)]`` split; the result is
    ``[(array, own_runs, recovered_runs)]`` over online arrays only.
    Each stranded run is cut into near-equal contiguous pieces, one per
    survivor: a submission costs the *max* over per-array rooflines, so
    handing one victim an offline array's whole share doubles that
    array's batch while its siblings idle — spreading the pieces serves
    the recovery traffic at the survivors' aggregate bandwidth for one
    extra request head each.
    """
    out = {a: (list(rs), []) for a, rs in placed if topology.is_online(a)}
    stranded = [rs for a, rs in placed if not topology.is_online(a)]
    if not stranded:
        return [(a, own, rec) for a, (own, rec) in sorted(out.items())]
    online = topology.online_arrays()
    if not online:
        from .fault import ArrayOfflineError
        raise ArrayOfflineError(-1, "every storage array is offline")
    for a in online:
        out.setdefault(a, ([], []))
    for rs in stranded:
        for r in rs:
            k = min(len(online), r.count)
            for i in range(k):
                lo = r.start + (r.count * i) // k
                hi = r.start + (r.count * (i + 1)) // k
                if hi > lo:
                    out[online[i]][1].append(type(r)(lo, hi - lo))
    return [(a, own, rec) for a, (own, rec) in sorted(out.items())]


def topology_plan_cost(placed, block_size: int, topology: StorageTopology,
                       queue_depth) -> tuple[int, int, int, float]:
    """(bytes, n_blocks, n_seq, time) of one split submission.

    Independent arrays serve their shares in parallel, so the submission
    costs the ``max`` over per-array :meth:`NVMeModel.batch_time`
    rooflines — not one merged-device roofline.  ``queue_depth`` may be
    a scalar or a per-array ``{array: depth}`` mapping (independent
    per-array queues).
    """
    total = blocks = seq = 0
    t = 0.0
    for a, runs in placed:
        nb = sum(r.count for r in runs)
        nr = len(runs)
        qd = topology.queue_depth_of(queue_depth, a)
        t = max(t, topology.devices[a].batch_time(
            nb * block_size, n_random=nr, n_sequential=nb - nr,
            queue_depth=qd))
        total += nb * block_size
        blocks += nb
        seq += nb - nr
    return total, blocks, seq, t


# ---------------------------------------------------------------- hotness
def graph_block_hotness(store) -> np.ndarray:
    """Per-graph-block hotness from the pinned T_obj: average object degree.

    A block holding few objects holds hubs (one huge adjacency fills it),
    and hubs are touched by nearly every frontier under power-law
    sampling — the blocks Ginex would pin.
    """
    return store.entry_payload_estimate()


def feature_block_hotness(store, degrees: np.ndarray) -> np.ndarray:
    """Per-feature-block expected *touch* frequency under neighbor sampling.

    High-degree nodes' rows are sampled most often, but a block is read
    once per hyperbatch no matter how many of its rows (or minibatches)
    hit it — traffic saturates.  So the proxy is the touch probability
    ``1 - exp(-mass / mean_mass)`` of the block's degree mass, the
    static stand-in for Ginex's empirical access counts: hub blocks
    saturate near 1, leaf blocks fall off proportionally, and the
    hot-set pinning moves blocks in proportion to the heads they will
    actually cost."""
    deg = np.asarray(degrees, dtype=np.float64)[:store.n_nodes]
    blocks = np.arange(store.n_nodes, dtype=np.int64) // store.rows_per_block
    mass = np.bincount(blocks, weights=deg, minlength=store.n_blocks)
    scale = float(mass[mass > 0].mean()) if (mass > 0).any() else 1.0
    return 1.0 - np.exp(-mass / scale)
