"""Fig 9: block-size and hyperbatch-size sweeps (execution time + #I/Os).

Paper: best block size 1024 KiB; performance saturates for hyperbatch
size >= 1024.  Swept on the largest stand-in (yh-mini).
"""
from __future__ import annotations

from .common import emit, get_dataset, make_agnes, targets_for


def run():
    for blk_kb in (64, 256, 1024, 4096):
        ds = get_dataset("yh-mini", block_size=blk_kb * 1024)
        targets = targets_for(ds, n_mb=4, mb_size=512)
        eng = make_agnes(ds, block_size=blk_kb * 1024,
                         setting_bytes=32 << 20)
        eng.prepare(targets, epoch=0)
        n_io = eng.graph_store.stats.n_reads + eng.feature_store.stats.n_reads
        emit(f"fig9a/block_{blk_kb}KiB",
             eng.last_report.modeled_io_s * 1e6, f"n_ios={n_io}")

    ds = get_dataset("yh-mini")
    for hb_size in (1, 2, 4, 8, 16):
        targets = targets_for(ds, n_mb=16, mb_size=256)
        eng = make_agnes(ds, hyperbatch_size=hb_size,
                         setting_bytes=32 << 20)
        total_t, total_io = 0.0, 0
        for s in range(0, 16, hb_size):
            eng.prepare(targets[s:s + hb_size], epoch=0)
            total_t += eng.last_report.modeled_io_s
        total_io = eng.graph_store.stats.n_reads \
            + eng.feature_store.stats.n_reads
        emit(f"fig9b/hyperbatch_{hb_size}", total_t * 1e6,
             f"n_ios={total_io}")


if __name__ == "__main__":
    run()
