"""Online re-placement + background block migration (core/migration.py).

Covers the migration plan (diff, hottest-first order, budget cap), the
crash-consistent write path (journal -> atomic metadata commit -> free),
slot bookkeeping on live placements, interrupted-save recovery, and the
engine-level epoch-boundary loop (byte parity with the static path).
"""
import dataclasses
import os

import numpy as np
import pytest

from repro.core import (AgnesConfig, AgnesEngine, BlockPlacement,
                        HotnessAwarePlacement, MigrationEngine, NVMeModel,
                        StorageTopology, StripePlacement,
                        recover_store_metadata)


def hetero_topo(speedup=3.0):
    fast = dataclasses.replace(NVMeModel(), bandwidth=speedup * 6.7e9,
                               latency=80e-6 / speedup)
    return StorageTopology([fast, NVMeModel()])


def striped_feature_store(ds, topo, persist=True):
    _, f = ds.reopen_stores()
    f.attach_topology(topo, StripePlacement(1).place(f.n_blocks, topo),
                      persist=persist)
    return f


ONLINE_POLICY = HotnessAwarePlacement(1, hot_mass=0.9, max_hot_fraction=0.5)


# ---------------------------------------------------------------- placement
def test_move_block_keeps_bijection_and_reuses_slots():
    topo = StorageTopology.uniform(2)
    pl = StripePlacement(1).place(10, topo)
    pl.move_block(1, 0)   # array 1 -> 0: fresh tail slot on 0
    assert pl.array_of[1] == 0 and pl.local_of[1] == 5
    pl.move_block(3, 0)
    assert pl.local_of[3] == 6
    pl.move_block(1, 1)   # back: reuses 1's freed slot (lowest first)
    assert pl.array_of[1] == 1 and pl.local_of[1] == 0
    pl.move_block(1, 0)   # forth again: reuses the freed tail slot on 0
    assert pl.local_of[1] == 5
    # every array's local ids stay dense-injective (no collisions)
    for a in range(2):
        mine = pl.local_of[pl.array_of == a]
        assert len(set(mine.tolist())) == len(mine)
    pl.move_block(0, 0)   # no-op: same array
    with pytest.raises(ValueError):
        pl.move_block(0, 5)


def test_save_is_atomic_and_recovery_discards_tmp(tmp_path):
    topo = StorageTopology.uniform(2)
    pl = StripePlacement(1).place(8, topo)
    base = str(tmp_path / "store.bin")
    out = pl.save(base)
    assert not os.path.exists(out + ".tmp")
    # interrupted save: a torn temp file must never shadow the committed
    # mapping, and store-open recovery garbage-collects it
    with open(out + ".tmp", "w") as f:
        f.write('{"policy": "torn garb')
    loaded = BlockPlacement.load(base)
    assert np.array_equal(loaded.array_of, pl.array_of)
    removed = recover_store_metadata(base)
    assert ".topo.json.tmp" in removed
    assert not os.path.exists(out + ".tmp")
    pl.save(base)  # saving over the recovered state still works
    assert np.array_equal(BlockPlacement.load(base).local_of, pl.local_of)


# ---------------------------------------------------------------- planning
def test_plan_diff_order_and_budget(tiny_ds):
    topo = hetero_topo()
    f = striped_feature_store(tiny_ds, topo, persist=False)
    hot = np.zeros(f.n_blocks)
    hot[1], hot[3], hot[5] = 10.0, 30.0, 20.0  # all on slow array 1
    mig = MigrationEngine(f, ONLINE_POLICY,
                          budget_bytes=2 * f.block_size, name="feature")
    moves, wanted = mig.plan(hot)
    # the greedy balances hot load relative to bandwidth: 3 and 1 pin to
    # the fast array, 5 stays put on the slow one — 2 moves wanted
    assert wanted == 2
    assert [m.block_id for m in moves] == [3, 1]  # hottest-delta first
    assert all(m.src == 1 and m.dst == 0 for m in moves)
    # a 1-block budget truncates to the hottest move only
    mig_tight = MigrationEngine(f, ONLINE_POLICY,
                                budget_bytes=f.block_size)
    tight, _ = mig_tight.plan(hot)
    assert [m.block_id for m in tight] == [3]
    # zero-hotness blocks never move (pure write traffic, no benefit)
    assert all(hot[m.block_id] > 0 for m in moves)


def test_zero_budget_disables_migration(tiny_ds):
    """budget <= block_size is a hard off switch, never 'unlimited'."""
    topo = hetero_topo()
    f = striped_feature_store(tiny_ds, topo, persist=False)
    hot = np.zeros(f.n_blocks)
    hot[1:5] = 5.0
    moves, wanted = MigrationEngine(f, ONLINE_POLICY,
                                    budget_bytes=0).plan(hot)
    assert wanted > 0 and moves == []
    rep = MigrationEngine(f, ONLINE_POLICY, budget_bytes=0).run(hot)
    assert rep.n_moved == 0 and f.stats.bytes_written == 0


def test_flat_traffic_degenerates_to_no_migration(tiny_ds, rng):
    """Uniform measured hotness must not pin a contiguous slab onto one
    array: the online policy's skew gate falls back to striping, so a
    striped store sees an empty diff."""
    eng = engine_for(tiny_ds, hetero_topo(), online_placement=True,
                     migrate_budget_bytes=64 << 20)
    # every feature block touched equally: full sequential passes
    eng.feature_hotness.touch(np.arange(eng.feature_store.n_blocks))
    eng.graph_hotness.touch(np.arange(eng.graph_store.n_blocks))
    rep = eng.end_epoch()
    assert rep["feature"]["n_moved"] == 0
    assert rep["graph"]["n_moved"] == 0
    eng.close()


def test_untouched_store_never_migrates(tiny_ds):
    topo = hetero_topo()
    f = striped_feature_store(tiny_ds, topo, persist=False)
    mig = MigrationEngine(f, ONLINE_POLICY, budget_bytes=1 << 20)
    rep = mig.run(np.zeros(f.n_blocks))
    assert rep.n_wanted == rep.n_moved == 0
    assert f.stats.bytes_written == 0


# ---------------------------------------------------------------- write path
def test_migrate_blocks_charges_arrays_and_persists(tiny_ds):
    topo = hetero_topo()
    f = striped_feature_store(tiny_ds, topo)
    hot = np.zeros(f.n_blocks)
    hot[1:5] = 5.0  # one contiguous hot run: pinned whole on the fast
    # array, so its array-1 members (blocks 1 and 3) migrate
    snapshot = [f.read_block_bytes(b) for b in range(f.n_blocks)]
    mig = MigrationEngine(f, ONLINE_POLICY, budget_bytes=4 * f.block_size,
                          name="feature")
    rep = mig.run(hot)
    assert rep.n_moved == 2 and rep.bytes_moved == 2 * f.block_size
    assert rep.bytes_moved <= rep.budget_bytes
    assert rep.read_s > 0 and rep.write_s > 0
    # writes landed on the destination (fast) array, reads on the source
    assert topo.array_stats[0].bytes_written == 2 * f.block_size
    assert topo.array_stats[1].bytes_migrated == 2 * f.block_size
    assert f.stats.n_migrated_blocks == 2
    assert f.stats.bytes_migrated == 2 * f.block_size
    # durable: journal gone, metadata committed, reload agrees
    assert not os.path.exists(f.path + ".migrate.log")
    _, f2 = tiny_ds.reopen_stores()
    reloaded = f2.load_placement(topo)
    assert np.array_equal(reloaded.array_of, f.placement.array_of)
    assert np.array_equal(reloaded.local_of, f.placement.local_of)
    # the data file is untouched: every block byte-identical
    for b in range(f.n_blocks):
        assert f.read_block_bytes(b) == snapshot[b]


@pytest.mark.parametrize("crash_at", ["copied", "committed"])
def test_crash_consistency_between_copy_and_commit(tiny_ds, crash_at):
    """A kill at either crash window reloads to a valid, byte-identical
    state — and, since the journal replays, to the *new* placement in
    both windows: a sealed journal proves the copy phase completed, so
    recovery rolls the placement commit forward instead of discarding
    finished work."""
    topo = hetero_topo()
    f = striped_feature_store(tiny_ds, topo)
    before = np.array(f.placement.array_of)
    snapshot = [f.read_block_bytes(b) for b in range(f.n_blocks)]
    hot = np.zeros(f.n_blocks)
    hot[1:5] = 5.0
    mig = MigrationEngine(f, ONLINE_POLICY, budget_bytes=4 * f.block_size)
    moves, _ = mig.plan(hot)

    def fault(point):
        if point == crash_at:
            raise RuntimeError("simulated kill")

    with pytest.raises(RuntimeError, match="simulated kill"):
        f.migrate_blocks([(m.block_id, m.dst) for m in moves], _fault=fault)
    # the journal survives the "kill" ...
    assert os.path.exists(f.path + ".migrate.log")
    # ... and a reopened store replays it (forward: the seal proves the
    # copies landed), garbage-collects it, and loads the new mapping
    _, f2 = tiny_ds.reopen_stores()
    assert not os.path.exists(f2.path + ".migrate.log")
    reloaded = f2.load_placement(topo)
    moved = np.array([m.block_id for m in moves])
    assert np.array_equal(reloaded.array_of[moved],
                          [m.dst for m in moves])
    unmoved = np.setdiff1d(np.arange(f2.n_blocks), moved)
    assert np.array_equal(reloaded.array_of[unmoved], before[unmoved])
    for a in range(topo.n_arrays):  # either way the mapping is injective
        mine = reloaded.local_of[reloaded.array_of == a]
        assert len(set(mine.tolist())) == len(mine)
    for b in range(f2.n_blocks):  # and the data never tore
        assert f2.read_block_bytes(b) == snapshot[b]


@pytest.mark.parametrize("journal_state", ["sealed", "torn", "missing"])
def test_torn_tmp_with_journal_states(tiny_ds, journal_state):
    """A torn ``.topo.json.tmp`` combined with every journal state:

    * ``sealed``  — the copy phase completed before the kill: recovery
      discards the tmp and rolls the journal *forward*;
    * ``torn``    — the journal itself tore (no seal): recovery discards
      both and keeps the old committed placement;
    * ``missing`` — only the tmp is stale: discard it, nothing replays.

    In every combination the store reloads byte-identical and the
    placement stays injective."""
    topo = hetero_topo()
    f = striped_feature_store(tiny_ds, topo)
    before = np.array(f.placement.array_of)
    snapshot = [f.read_block_bytes(b) for b in range(f.n_blocks)]
    hot = np.zeros(f.n_blocks)
    hot[1:5] = 5.0
    moves, _ = MigrationEngine(f, ONLINE_POLICY,
                               budget_bytes=4 * f.block_size).plan(hot)
    journal = f.path + ".migrate.log"
    if journal_state != "missing":
        def fault(point):   # kill between seal and metadata commit
            if point == "copied":
                raise RuntimeError("simulated kill")
        with pytest.raises(RuntimeError, match="simulated kill"):
            f.migrate_blocks([(m.block_id, m.dst) for m in moves],
                             _fault=fault)
        assert os.path.exists(journal)
        if journal_state == "torn":
            # tear inside the seal record: the copy no longer provably
            # completed, so replay must refuse to roll forward
            size = os.path.getsize(journal)
            with open(journal, "r+b") as jf:
                jf.truncate(size - 8)
    with open(f.path + ".topo.json.tmp", "w") as tmp:
        tmp.write('{"policy": "torn garb')   # interrupted save, any state
    removed = recover_store_metadata(f.path)
    assert ".topo.json.tmp" in removed
    if journal_state == "missing":
        assert ".migrate.log" not in removed
    else:
        assert removed[".migrate.log"] == (
            "rolled_forward" if journal_state == "sealed" else "rolled_back")
    assert not os.path.exists(journal)
    assert not os.path.exists(f.path + ".topo.json.tmp")
    _, f2 = tiny_ds.reopen_stores()
    reloaded = f2.load_placement(topo)
    moved = np.array([m.block_id for m in moves])
    if journal_state == "sealed":
        assert np.array_equal(reloaded.array_of[moved],
                              [m.dst for m in moves])
        unmoved = np.setdiff1d(np.arange(f2.n_blocks), moved)
        assert np.array_equal(reloaded.array_of[unmoved], before[unmoved])
    else:
        assert np.array_equal(reloaded.array_of, before)
    for a in range(topo.n_arrays):
        mine = reloaded.local_of[reloaded.array_of == a]
        assert len(set(mine.tolist())) == len(mine)
    for b in range(f2.n_blocks):
        assert f2.read_block_bytes(b) == snapshot[b]


def test_migrate_requires_topology(tiny_ds):
    _, f = tiny_ds.reopen_stores()
    with pytest.raises(RuntimeError):
        f.migrate_blocks([(0, 1)])


# ---------------------------------------------------------------- engine
def engine_for(ds, topo, **over):
    g, f = ds.reopen_stores()
    cfg = AgnesConfig(block_size=16384, minibatch_size=64,
                      hyperbatch_size=4, fanouts=(), feature_cache_rows=1,
                      graph_buffer_bytes=1 << 20,
                      feature_buffer_bytes=1 << 20, async_io=False,
                      placement="stripe", **over)
    return AgnesEngine(g, f, cfg, topology=topo)


def test_engine_online_replacement_parity_and_budget(tiny_ds, rng):
    """Two epochs of concentrated traffic: the online engine migrates the
    hot feature blocks to the fast array, stays byte-identical to the
    static engine, and respects the per-epoch budget."""
    targets = [[rng.choice(256, 64, replace=False) for _ in range(4)]
               for _ in range(2)]  # hot: feature blocks 0-1 only
    static = engine_for(tiny_ds, hetero_topo())
    online = engine_for(tiny_ds, hetero_topo(), online_placement=True,
                        migrate_budget_bytes=4 * 16384)
    for epoch in range(2):
        p0 = static.prepare(targets[epoch], epoch=epoch)
        p1 = online.prepare(targets[epoch], epoch=epoch)
        for a, b in zip(p1, p0):
            assert np.allclose(a.features, b.features)
            for x, y in zip(a.mfg.nodes, b.mfg.nodes):
                assert np.array_equal(x, y)
        rep = online.end_epoch()
        assert rep["feature"]["bytes_moved"] <= 4 * 16384
    # the concentrated hot set ended up pinned on the fast array
    hot_blocks = online.feature_store.placement.array_of[:2]
    assert set(hot_blocks.tolist()) == {0}
    assert online.io_stats()["migration"]["n_migrated_blocks"] > 0
    # and the online epochs now cost less modeled read time per epoch
    static.close()
    online.close()


def test_plan_epoch_triggers_migration_and_is_idempotent(tiny_ds, rng):
    eng = engine_for(tiny_ds, hetero_topo(), online_placement=True,
                     migrate_budget_bytes=4 * 16384)
    targets = [rng.choice(256, 64, replace=False) for _ in range(4)]
    eng.prepare(targets, epoch=0)
    assert eng.feature_hotness.window_touches > 0
    eng.plan_epoch(np.arange(256), epoch=1)  # epoch boundary: migrates
    # the boundary pass quiesced the readers before swapping placement
    for rd in (eng._g_prefetch, eng._f_prefetch):
        if rd is not None and hasattr(rd, "idle"):
            assert rd.idle
    assert eng.last_migration is not None
    moved = eng.last_migration["feature"]["n_moved"]
    assert moved > 0
    # idempotent: the window is already rolled, a second boundary does
    # not roll or migrate again
    first = eng.last_migration
    rolls = eng.feature_hotness.n_rolls
    eng.plan_epoch(np.arange(256), epoch=1)
    assert eng.last_migration is first
    assert eng.feature_hotness.n_rolls == rolls
    # the lazy hook defers to explicit rollers: an end_epoch (as the
    # pipelined executor runs every epoch) followed by stray holdout
    # traffic must not drive a second migration pass at the next plan
    eng.prepare([rng.choice(256, 64, replace=False)], epoch=0)
    eng.end_epoch()
    eng.prepare([rng.choice(256, 16, replace=False)], epoch=900)  # eval
    rolls = eng.feature_hotness.n_rolls
    eng.plan_epoch(np.arange(256), epoch=2)
    assert eng.feature_hotness.n_rolls == rolls, \
        "eval traffic after an explicit roll re-triggered the boundary"
    # end_epoch refuses to run mid-session (placement swap would race)
    eng._in_session = True
    with pytest.raises(RuntimeError, match="PrepareSession"):
        eng.end_epoch()
    eng._in_session = False
    eng.close()


def test_online_default_off_keeps_static_behavior(tiny_ds, rng):
    eng = engine_for(tiny_ds, hetero_topo())
    targets = [rng.choice(256, 64, replace=False)]
    eng.prepare(targets, epoch=0)
    before = np.array(eng.feature_store.placement.array_of)
    eng.plan_epoch(np.arange(256), epoch=1)
    assert np.array_equal(eng.feature_store.placement.array_of, before)
    assert eng.last_migration is None
    eng.close()
