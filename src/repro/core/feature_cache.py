"""Feature cache ``C_f`` + cache index table ``T_ch^f`` (paper §3.4(2)).

AGNES counts accesses to each feature vector and keeps hot rows resident
in the in-memory feature cache; infrequently accessed rows are written
back / dropped at minibatch boundaries and re-read from storage when
needed again.  Implementation is fully vectorized (this container has
one CPU core):

* ``T_ch`` (cache index table)  → ``slot_of[node] ∈ {-1, slot}``
* ``C_f``  (feature cache)      → ``rows[slot, :]``
* access counters               → ``counts[node]``

Eviction is pluggable (``policy=``):

* ``"clock"``  — second-chance-free FIFO ring (the original default;
  approximates the paper's LRU within the admitted set);
* ``"lru"``    — true least-recently-used over per-slot access stamps
  (hits refresh the stamp, eviction takes the stalest slots);
* ``"oracle"`` — Belady MIN driven by a precomputed
  :class:`repro.core.cache_oracle.OracleSchedule`: of residents and the
  step's miss candidates, keep the ``capacity`` rows with the nearest
  next use.  Provably optimal on the scheduled trace (Ginex's insight:
  storage-based GNN training knows its access future); the access-count
  admission threshold is ignored — the oracle's future knowledge
  supersedes the frequency heuristic.

Capacity is load-bearing: evictions are counted
(``IOStats.cache_evictions``) and, with a writeback device attached
(:meth:`attach_writeback`), charged as row-granular write I/O — the
paper's minibatch-boundary writeback of cooled rows — so a finite
``capacity_rows`` budget shows up in the modeled I/O time instead of
being free.

The cache also backs the GIDS-style device-resident gather
(``core/gather.py``): :attr:`lock` makes admit atomic against a
concurrent device-table sync, and per-slot dirty tracking
(:meth:`drain_dirty`) lets the HBM mirror upload only the slots an
admit actually rewrote.
"""
from __future__ import annotations

import threading

import numpy as np

from .device_model import IOStats

CACHE_POLICIES = ("clock", "lru", "oracle")


class FeatureCache:
    """Access-count-thresholded, vectorized feature-row cache."""

    def __init__(self, capacity_rows: int, n_nodes: int, dim: int,
                 admit_threshold: int = 2,
                 dtype: np.dtype = np.float32,
                 stats: IOStats | None = None,
                 policy: str = "clock"):
        if policy not in CACHE_POLICIES:
            raise ValueError(f"unknown cache policy {policy!r}; "
                             f"choose from {CACHE_POLICIES}")
        self.capacity = max(int(capacity_rows), 0)
        self.n_nodes = n_nodes
        self.dim = dim
        self.admit_threshold = admit_threshold
        self.dtype = np.dtype(dtype)
        self.stats = stats if stats is not None else IOStats()
        self.policy = policy
        cap = max(self.capacity, 1)
        self.slot_of = np.full(n_nodes, -1, dtype=np.int64)   # T_ch
        self.node_at = np.full(cap, -1, dtype=np.int64)
        self.rows = np.zeros((cap, dim), dtype=self.dtype)    # C_f
        self.counts = np.zeros(n_nodes, dtype=np.int64)
        self._clock = 0
        self._n_resident = 0
        # LRU bookkeeping: per-slot last-access stamp (0 = never)
        self._last_used = np.zeros(cap, dtype=np.int64)
        self._tick = 0
        # oracle schedule (core/cache_oracle.py), policy="oracle" only
        self.oracle = None
        # admit/device-sync exclusion + per-slot dirty tracking for the
        # HBM-resident mirror (core/gather.py DeviceFeatureTable)
        self.lock = threading.Lock()
        self._dirty = np.zeros(cap, dtype=bool)
        # modeled eviction writeback (attach_writeback)
        self._wb_device = None
        self._wb_stats = None
        self._wb_queue_depth = 8
        # hotness telemetry (core/hotness.py): cache hits attributed to
        # their feature blocks at a discount — a hit is storage traffic
        # the cache absorbed *this* epoch but may not absorb the next
        self._hotness = None
        self._hot_rows_per_block = 1
        self._hot_hit_weight = 0.0
        # unified telemetry (core/telemetry.py): admit/evict instants +
        # churn counters; bound by the owning engine (attach_telemetry)
        self.telemetry = None
        self._m_admitted = self._m_evicted = self._m_wb_bytes = None

    def attach_hotness(self, tracker, rows_per_block: int,
                       hit_weight: float = 0.25) -> None:
        """Report per-block hit traffic into a :class:`HotnessTracker`.

        Misses are *not* recorded here — the store's accounting layer
        records them when the missed blocks are actually read, so a row
        is never double counted.
        """
        self._hotness = tracker
        self._hot_rows_per_block = max(int(rows_per_block), 1)
        self._hot_hit_weight = float(hit_weight)

    def attach_writeback(self, device, stats: IOStats | None = None,
                         queue_depth: int = 8) -> None:
        """Charge evictions as row-granular writeback I/O on ``device``.

        The paper writes cooled rows back to storage at minibatch
        boundaries; charging that traffic makes the capacity budget
        load-bearing — a too-small cache pays for its churn in modeled
        device time, not just in miss counts.
        """
        self._wb_device = device
        self._wb_stats = stats if stats is not None else self.stats
        self._wb_queue_depth = max(int(queue_depth), 1)

    def attach_telemetry(self, telemetry) -> None:
        """Bind a :class:`~repro.core.telemetry.Telemetry` bundle:
        admit/evict instants on the ``cache`` track plus churn counters
        (pre-resolved so the admit path pays one locked inc each).
        ``telemetry=None`` unbinds."""
        self.telemetry = telemetry
        if telemetry is None:
            self._m_admitted = self._m_evicted = self._m_wb_bytes = None
            return
        m = telemetry.metrics
        self._m_admitted = m.counter("cache.rows_admitted",
                                     "feature rows installed in the cache")
        self._m_evicted = m.counter("cache.rows_evicted",
                                    "feature rows displaced under pressure")
        self._m_wb_bytes = m.counter("cache.writeback_bytes",
                                     "modeled eviction writeback traffic")

    def set_oracle(self, schedule) -> None:
        """Install a precomputed MIN schedule (switches admit to it)."""
        if self.policy != "oracle":
            raise ValueError("set_oracle requires policy='oracle', "
                             f"cache has policy={self.policy!r}")
        self.oracle = schedule

    def oracle_advance(self) -> None:
        """Enter the next trace step (no-op for non-oracle policies).

        Called once per gather cycle by ``FeatureGatherer.plan_gather``
        — i.e. once per hyperbatch in the engine — and once per step by
        the bare trace driver, *before* the step's lookups.
        """
        if self.oracle is not None:
            self.oracle.advance()

    def __len__(self) -> int:
        return self._n_resident

    @property
    def row_bytes(self) -> int:
        return self.dim * self.dtype.itemsize

    # ------------------------------------------------------------ reads
    def lookup(self, nodes: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Split ``nodes`` into (hit_mask, rows-for-hits, in nodes' order)."""
        nodes = np.asarray(nodes)
        slots = self.slot_of[nodes]
        mask = slots >= 0
        self.stats.cache_hits += int(mask.sum())
        self.stats.cache_misses += int((~mask).sum())
        if self.policy == "lru" and mask.any():
            self._tick += 1
            self._last_used[slots[mask]] = self._tick
        if self._hotness is not None and self._hot_hit_weight > 0 \
                and mask.any():
            self._hotness.touch(nodes[mask] // self._hot_rows_per_block,
                                weight=self._hot_hit_weight)
        return mask, self.rows[slots[mask]]

    def lookup_slots(self, nodes: np.ndarray) -> np.ndarray:
        """Current slot of each node (-1 = not resident); no accounting.

        The device-resident gather records these at cache-pass time and
        re-validates them against ``node_at`` at gather time (a slot
        re-used by a later admit demotes that row to the host path).
        """
        return self.slot_of[np.asarray(nodes, dtype=np.int64)]

    def note_access(self, nodes: np.ndarray) -> None:
        np.add.at(self.counts, np.asarray(nodes), 1)

    # ------------------------------------------------------------ admit
    def admit(self, nodes: np.ndarray, rows: np.ndarray) -> int:
        """Offer freshly-read rows; admit per the eviction policy.

        clock/lru: rows at/above the access-count threshold are admitted
        (the paper's frequency heuristic), evicting per the policy; a
        batch with more candidates than ``capacity`` keeps the
        highest-``counts`` candidates (not an arbitrary prefix).
        oracle: the installed MIN schedule picks the keep-set by nearest
        next use.  Returns the number admitted.
        """
        if self.capacity == 0 or len(nodes) == 0:
            return 0
        nodes = np.asarray(nodes)
        with self.lock:
            if self.policy == "oracle" and self.oracle is not None:
                return self._admit_oracle(nodes, rows)
            return self._admit_counted(nodes, rows)

    def _admit_counted(self, nodes: np.ndarray, rows: np.ndarray) -> int:
        """clock/lru admission: threshold-gated, frequency-capped."""
        cand = (self.counts[nodes] >= self.admit_threshold) \
            & (self.slot_of[nodes] < 0)
        cand_idx = np.nonzero(cand)[0]
        if cand_idx.size == 0:
            return 0
        # dedupe within the batch, keep first occurrence (slots must
        # stay distinct)
        _, first = np.unique(nodes[cand_idx], return_index=True)
        cand_idx = cand_idx[first]
        if len(cand_idx) > self.capacity:
            # over-capacity batch: keep the hottest candidates by access
            # count, not whichever happened to sort first
            cnt = self.counts[nodes[cand_idx]]
            top = np.argpartition(-cnt, self.capacity - 1)[:self.capacity]
            cand_idx = cand_idx[np.sort(top)]
        k = len(cand_idx)
        if self.policy == "lru":
            slots = self._take_lru_slots(k)
        else:
            slots = (self._clock + np.arange(k)) % max(self.capacity, 1)
            self._clock = int((self._clock + k) % max(self.capacity, 1))
        self._install(slots, nodes[cand_idx], rows[cand_idx])
        return k

    def _admit_oracle(self, nodes: np.ndarray, rows: np.ndarray) -> int:
        """Belady MIN keep-set: residents + candidates ranked by next use."""
        from .cache_oracle import NEVER

        cand = self.slot_of[nodes] < 0
        cand_idx = np.nonzero(cand)[0]
        if cand_idx.size == 0:
            return 0
        _, first = np.unique(nodes[cand_idx], return_index=True)
        cand_idx = cand_idx[first]
        cand_nodes = nodes[cand_idx]
        nu_cand = self.oracle.next_use_of(cand_nodes)
        # rows never used again can't earn their slot — drop them first
        live = nu_cand < NEVER
        cand_idx, cand_nodes, nu_cand = \
            cand_idx[live], cand_nodes[live], nu_cand[live]
        if cand_idx.size == 0:
            return 0
        res_slots = np.nonzero(self.node_at >= 0)[0]
        res_nodes = self.node_at[res_slots]
        nu_res = self.oracle.next_use_of(res_nodes)
        free = self.capacity - len(res_slots)
        if len(cand_idx) <= free:
            keep_c = np.arange(len(cand_idx))
            evict_slots = np.zeros(0, dtype=np.int64)
        else:
            # rank the pool by next use; residents win ties (an exchange
            # at equal distance buys nothing and costs a writeback).
            # Dead residents (next use NEVER) rank last so they fund the
            # admission first, but are never evicted *without* an
            # incoming row — an idle eviction is a free writeback.
            n_c, n_r = len(cand_idx), len(res_slots)
            pool_nu = np.concatenate([nu_res, nu_cand])
            is_cand = np.concatenate([np.zeros(n_r, np.int8),
                                      np.ones(n_c, np.int8)])
            order = np.lexsort((is_cand, pool_nu))
            keep = np.zeros(n_r + n_c, dtype=bool)
            keep[order[:self.capacity]] = True
            keep_c = np.nonzero(keep[n_r:])[0]
            evict_slots = res_slots[~keep[:n_r]]
        k = len(keep_c)
        if k == 0:
            return 0
        free_slots = np.nonzero(self.node_at < 0)[0]
        # exactly enough by construction: free + evicted == kept candidates
        slots = np.concatenate([free_slots, evict_slots])[:k]
        self._install(np.asarray(slots, dtype=np.int64),
                      cand_nodes[keep_c], rows[cand_idx[keep_c]])
        return k

    # ------------------------------------------------------ slot helpers
    def _take_lru_slots(self, k: int) -> np.ndarray:
        """k slots: free ones first, then least-recently-used stamps."""
        free = np.nonzero(self.node_at < 0)[0]
        if len(free) >= k:
            return free[:k]
        need = k - len(free)
        occupied = np.nonzero(self.node_at >= 0)[0]
        stale = np.argpartition(self._last_used[occupied], need - 1)[:need]
        return np.concatenate([free, occupied[stale]])

    def _install(self, slots: np.ndarray, nodes: np.ndarray,
                 rows: np.ndarray) -> None:
        """Place ``nodes``' rows into ``slots``, evicting occupants."""
        evicted = self.node_at[slots]
        live = evicted >= 0
        if live.any():
            self._evict_arrays(slots[live], evicted[live])
        self.node_at[slots] = nodes
        self.slot_of[nodes] = slots
        self.rows[slots] = rows
        self._dirty[slots] = True
        self._tick += 1
        self._last_used[slots] = self._tick
        self._n_resident += len(slots)
        tel = self.telemetry
        if tel is not None:
            self._m_admitted.inc(int(len(slots)))
            tr = tel.trace
            if tr is not None:
                tr.instant("admit", "cache", "cache",
                           args={"rows": int(len(slots))})

    def _evict_arrays(self, slots: np.ndarray, nodes: np.ndarray) -> None:
        """Common eviction bookkeeping + modeled writeback charge."""
        self.slot_of[nodes] = -1
        self._n_resident -= len(slots)
        k = int(len(slots))
        self.stats.cache_evictions += k
        wb_bytes = 0
        if self._wb_device is not None and k:
            wb_bytes = k * self.row_bytes
            t = self._wb_device.batch_time(
                wb_bytes, n_random=k, queue_depth=self._wb_queue_depth)
            self._wb_stats.record_write(
                wb_bytes, t, request_sizes=[self.row_bytes] * k)
        tel = self.telemetry
        if tel is not None and k:
            self._m_evicted.inc(k)
            if wb_bytes:
                self._m_wb_bytes.inc(wb_bytes)
            tr = tel.trace
            if tr is not None:
                tr.instant("evict", "cache", "cache",
                           args={"rows": k, "writeback_bytes": wb_bytes})

    # ------------------------------------------------------------ device
    def drain_dirty(self) -> np.ndarray:
        """Slots rewritten since the last drain (caller holds the lock)."""
        dirty = np.nonzero(self._dirty)[0]
        self._dirty[dirty] = False
        return dirty

    # ------------------------------------------------------------ debug
    def check_invariants(self) -> None:
        """Assert the slot_of/node_at bijection and resident accounting.

        Cheap enough to run every minibatch in stress tests; takes the
        admit lock so it can run from a consumer thread while a producer
        is admitting (the pipelined-executor interleaving).
        """
        with self.lock:
            res_slots = np.nonzero(self.node_at >= 0)[0]
            assert len(res_slots) == self._n_resident, \
                (f"_n_resident={self._n_resident} but "
                 f"{len(res_slots)} occupied slots")
            res_nodes = self.node_at[res_slots]
            assert len(np.unique(res_nodes)) == len(res_nodes), \
                "a node occupies two slots"
            assert np.array_equal(self.slot_of[res_nodes], res_slots), \
                "slot_of does not invert node_at on residents"
            fwd = np.nonzero(self.slot_of >= 0)[0]
            assert len(fwd) == self._n_resident, \
                (f"{len(fwd)} nodes map to slots but "
                 f"{self._n_resident} residents")
            assert np.array_equal(self.node_at[self.slot_of[fwd]], fwd), \
                "node_at does not invert slot_of"
            if self.capacity:
                assert 0 <= self._clock < self.capacity
                assert (self.slot_of < self.capacity).all()

    def resident_nodes(self) -> np.ndarray:
        return self.node_at[self.node_at >= 0]

    def clear(self) -> None:
        with self.lock:
            self.slot_of.fill(-1)
            self.node_at.fill(-1)
            self.counts.fill(0)
            self._clock = 0
            self._n_resident = 0
            self._last_used.fill(0)
            self._tick = 0
            self._dirty.fill(True)  # a mirror must resync everything
            if self.oracle is not None:
                self.oracle.reset()
