"""Oracle (Belady MIN) eviction schedule for the feature cache.

Ginex's observation, transplanted: storage-based GNN training knows its
feature-access trace *ahead of time* — the epoch plan fixes the targets,
the counter-hash sampler is deterministic, and ``PrepareSession`` holds
every minibatch's input-node list before a single gather I/O is issued.
Belady's MIN ("evict the row whose next use is farthest in the future")
is therefore not a thought experiment here but an implementable policy:
this module turns a trace into a precomputed eviction schedule that
:class:`repro.core.feature_cache.FeatureCache` consults at admit time
(``policy="oracle"``).

The cache's access model is *batched*: each step runs all of its lookups
first, then one batched admit of the step's misses (one step = one
hyperbatch in the engine, one minibatch in the bare driver).  MIN
generalizes unchanged: at each step boundary, of the residents and the
step's miss candidates, keep the ``capacity`` rows with the *nearest
next use* — the classic exchange argument applies per decision point, so
no policy (LRU, clock, anything) can miss less on the same trace.
``tests/test_cache_oracle.py`` verifies this against an independent
brute-force reference (:func:`belady_min_misses`) and against LRU/clock
on randomized traces.

Where the trace comes from:

* :func:`trace_from_plan` — 0-hop workloads (pure feature serving, the
  ``bench_cache`` shape): the epoch plan *is* the trace, no sampling
  needed;
* ``AgnesEngine.record_feature_trace`` — k-hop workloads: the session
  appends each hyperbatch's gather node list as soon as the final
  sampling frontier exists (Ginex's "offline sampling pass", amortized
  into a recording epoch); replaying the same plan (same targets, same
  epoch seed) makes the recorded trace exact for the replay.

A schedule driven past its trace (or against a different plan) stays
*correct* — features are read from storage on every miss regardless —
it merely stops being optimal; overruns are counted, never raised.
"""
from __future__ import annotations

import numpy as np

# "never used again": any step comparison must see this as farthest
NEVER = np.iinfo(np.int64).max


class OracleSchedule:
    """Precomputed per-step next-use table over a fixed access trace.

    ``advance()`` moves the cursor to the next step and updates
    ``next_use[node]`` for every node accessed at that step to the step
    of its *next* access (``NEVER`` if none) — so after ``advance()``,
    ``next_use`` is exact for every node accessed so far, and the admit
    decision for the current step reads it directly.
    """

    def __init__(self, n_nodes: int, step_nodes: np.ndarray,
                 step_next: np.ndarray, step_ptr: np.ndarray):
        self.n_nodes = int(n_nodes)
        self._step_nodes = step_nodes    # unique nodes, grouped by step
        self._step_next = step_next      # their next-use step (or NEVER)
        self._step_ptr = step_ptr        # (n_steps + 1,) group offsets
        self.next_use = np.full(n_nodes, NEVER, dtype=np.int64)
        self.step = -1                   # advance() enters step 0
        self.overruns = 0                # advances past the trace end

    @property
    def n_steps(self) -> int:
        return len(self._step_ptr) - 1

    @classmethod
    def from_trace(cls, trace: list[np.ndarray],
                   n_nodes: int) -> "OracleSchedule":
        """Build the schedule from per-step access lists.

        One vectorized pass: dedupe (node, step) pairs, then each pair's
        next-use is simply the following pair of the same node in
        (node, step) order.
        """
        n_steps = len(trace)
        steps = [np.asarray(s, dtype=np.int64).ravel() for s in trace]
        lens = np.array([len(s) for s in steps], dtype=np.int64)
        if lens.sum() == 0:
            ptr = np.zeros(n_steps + 1, dtype=np.int64)
            z = np.zeros(0, dtype=np.int64)
            return cls(n_nodes, z, z, ptr)
        flat = np.concatenate(steps)
        step_of = np.repeat(np.arange(n_steps, dtype=np.int64), lens)
        order = np.lexsort((step_of, flat))       # by node, then step
        fn, fs = flat[order], step_of[order]
        keep = np.ones(len(fn), dtype=bool)       # dedupe same-step repeats
        keep[1:] = (fn[1:] != fn[:-1]) | (fs[1:] != fs[:-1])
        un, us = fn[keep], fs[keep]
        nxt = np.full(len(un), NEVER, dtype=np.int64)
        same = un[1:] == un[:-1]                  # next pair, same node
        nxt[:-1][same] = us[1:][same]
        by_step = np.argsort(us, kind="stable")   # regroup by step
        step_nodes, step_next = un[by_step], nxt[by_step]
        step_ptr = np.searchsorted(us[by_step], np.arange(n_steps + 1))
        return cls(n_nodes, step_nodes, step_next,
                   step_ptr.astype(np.int64))

    def advance(self) -> int:
        """Enter the next step; refresh next-use for its accessed nodes."""
        self.step += 1
        if self.step >= self.n_steps:
            # driven past the trace: freeze (correctness is unaffected —
            # the cache just stops admitting optimally) and count it
            self.overruns += 1
            return self.step
        lo, hi = int(self._step_ptr[self.step]), \
            int(self._step_ptr[self.step + 1])
        self.next_use[self._step_nodes[lo:hi]] = self._step_next[lo:hi]
        return self.step

    def next_use_of(self, nodes: np.ndarray) -> np.ndarray:
        return self.next_use[np.asarray(nodes, dtype=np.int64)]

    def reset(self) -> None:
        self.next_use.fill(NEVER)
        self.step = -1
        self.overruns = 0


# ------------------------------------------------------------ traces
def trace_from_plan(plan: list[list[np.ndarray]]) -> list[np.ndarray]:
    """Epoch plan -> feature-access trace, one step per hyperbatch.

    Exact for 0-hop workloads (``fanouts=()``): the gathered nodes *are*
    the (deduplicated, sorted) minibatch targets — which is precisely
    what ``PrepareSession`` hands the gatherer.  k-hop workloads need
    the recorded trace instead (``AgnesEngine.record_feature_trace``).
    """
    return [np.concatenate([np.unique(np.asarray(t, dtype=np.int64))
                            for t in mbs])
            if mbs else np.zeros(0, dtype=np.int64)
            for mbs in plan]


def first_use_table(trace: list[np.ndarray], n_nodes: int) -> np.ndarray:
    """Per-node step index of each node's *first* appearance in ``trace``
    (``NEVER`` for absent nodes).

    This primes a freshly rebuilt schedule's ``next_use`` table for the
    mid-epoch oracle refresh (``AgnesEngine.refresh_cache_oracle``): a
    schedule installed mid-epoch starts at ``step=-1`` with an all-NEVER
    table, which would mark every currently-resident row as
    never-needed and let arbitrary traffic evict the lot before the
    first ``advance``.  Seeding true first-use times keeps resident-row
    priorities exact from the first post-refresh access on.
    """
    table = np.full(n_nodes, NEVER, dtype=np.int64)
    for t in range(len(trace) - 1, -1, -1):   # reverse: earliest use wins
        table[np.asarray(trace[t], dtype=np.int64)] = t
    return table


# ------------------------------------------------- brute-force reference
def belady_min_misses(trace: list[np.ndarray], capacity: int) -> int:
    """Independent O(T^2) Belady MIN reference for small traces.

    Same batched access model as :class:`FeatureCache` (per-step lookups,
    then one batched keep-set decision), but next-use distances are
    recomputed by scanning the remaining trace forward at every step —
    no shared code with :class:`OracleSchedule`, so the property test
    cross-checks two implementations.

    Exact agreement with the cache is guaranteed for traces whose steps
    contain no duplicate nodes (the engine's per-hyperbatch gathers are
    deduplicated, so real traces qualify).  With intra-step duplicates
    the *multiplicity-weighted* miss count depends on how ties at equal
    next-use are broken, and the two implementations may differ by a few
    misses in either direction; the dominance property (oracle <= LRU,
    clock) is unaffected.
    """
    capacity = int(capacity)
    raw = [np.asarray(s, dtype=np.int64).ravel() for s in trace]
    steps = [set(int(v) for v in s) for s in raw]
    resident: set[int] = set()
    misses = 0
    for t, acc in enumerate(steps):
        # multiplicity-aware, matching FeatureCache.lookup accounting:
        # every occurrence of a non-resident node is one miss
        misses += sum(1 for v in raw[t] if int(v) not in resident)
        if capacity <= 0:
            continue
        pool = resident | acc
        # forward scan: next step > t that touches each pool node
        nxt = {}
        for v in pool:
            nxt[v] = NEVER
            for u in range(t + 1, len(steps)):
                if v in steps[u]:
                    nxt[v] = u
                    break
        # keep the `capacity` nearest next uses (residents win ties so
        # the schedule never churns for free); rows never used again
        # need not occupy a slot — dropping them cannot add misses
        ranked = sorted(pool, key=lambda v: (nxt[v], v not in resident, v))
        resident = {v for v in ranked[:capacity] if nxt[v] != NEVER}
    return misses
