"""Layer dispatch: (mixer × ffn) per LayerSpec, with decode variants.

A decoder layer is pre-norm residual:
    x = x + Mixer(RMSNorm(x))
    x = x + FFN(RMSNorm(x))          (skipped when ffn == "none")
Mixers: attn / swa / mamba / mlstm / slstm.  FFNs: mlp (gated SiLU) / moe.
One implementation covers all 10 assigned families via the per-layer spec
list each config generates.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import LayerSpec, ModelConfig
from .attention import (AttnCache, attention_apply, attention_decode,
                        attn_cache_init, attn_init)
from .common import constrain_batch, dense_init, rms_norm
from .moe import moe_apply, moe_decode, moe_init
from .ssm import MambaCache, mamba_apply, mamba_cache_init, mamba_decode, mamba_init
from .xlstm import (MLSTMCache, SLSTMCache, mlstm_apply, mlstm_cache_init,
                    mlstm_decode, mlstm_init, slstm_apply, slstm_cache_init,
                    slstm_decode, slstm_init)


# ------------------------------------------------------------------- MLP
def mlp_init(key, cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_gate": dense_init(k1, (d, f), dtype=dt),
            "w_up": dense_init(k2, (d, f), dtype=dt),
            "w_down": dense_init(k3, (f, d), dtype=dt)}


def mlp_apply(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu((x @ p["w_gate"]).astype(jnp.float32)).astype(x.dtype)
    return (h * (x @ p["w_up"])) @ p["w_down"]


# ----------------------------------------------------------------- layer
def layer_init(key, cfg: ModelConfig, spec: LayerSpec) -> dict:
    km, kf, kn = jax.random.split(key, 3)
    p: dict[str, Any] = {"norm_mixer": jnp.zeros((cfg.d_model,), jnp.float32)}
    if spec.mixer in ("attn", "swa"):
        p["attn"] = attn_init(km, cfg)
    elif spec.mixer == "mamba":
        p["mamba"] = mamba_init(km, cfg)
    elif spec.mixer == "mlstm":
        p["mlstm"] = mlstm_init(km, cfg)
    elif spec.mixer == "slstm":
        p["slstm"] = slstm_init(km, cfg)
    else:
        raise ValueError(spec.mixer)
    if spec.ffn != "none":
        p["norm_ffn"] = jnp.zeros((cfg.d_model,), jnp.float32)
        if spec.ffn == "mlp":
            p["mlp"] = mlp_init(kf, cfg)
        elif spec.ffn == "moe":
            p["moe"] = moe_init(kf, cfg)
        else:
            raise ValueError(spec.ffn)
    return p


def layer_apply(p: dict, x: jnp.ndarray, positions, cfg: ModelConfig,
                spec: LayerSpec, *, impl: str = "chunked",
                unroll: bool = False) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (x, moe_aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    x = constrain_batch(x, seq_shard=cfg.sequence_parallel, dp_model=cfg.dp_over_model)
    h = rms_norm(x, p["norm_mixer"], cfg.norm_eps)
    if spec.mixer in ("attn", "swa"):
        h = attention_apply(p["attn"], h, positions, cfg, spec,
                            impl=impl, unroll=unroll)
    elif spec.mixer == "mamba":
        h = mamba_apply(p["mamba"], h, cfg, unroll=unroll)
    elif spec.mixer == "mlstm":
        h = mlstm_apply(p["mlstm"], h, cfg, unroll=unroll)
    elif spec.mixer == "slstm":
        h = slstm_apply(p["slstm"], h, cfg, unroll=unroll)
    x = constrain_batch(x + h, seq_shard=cfg.sequence_parallel, dp_model=cfg.dp_over_model)
    if spec.ffn != "none":
        h = rms_norm(x, p["norm_ffn"], cfg.norm_eps)
        if spec.ffn == "mlp":
            h = mlp_apply(p["mlp"], h)
        else:
            h, aux = moe_apply(p["moe"], h, cfg, unroll=unroll)
        x = constrain_batch(x + h, seq_shard=cfg.sequence_parallel, dp_model=cfg.dp_over_model)
    return x, aux


# ----------------------------------------------------------------- decode
def layer_cache_init(cfg: ModelConfig, spec: LayerSpec, batch: int,
                     max_len: int, dtype) -> Any:
    if spec.mixer in ("attn", "swa"):
        return attn_cache_init(cfg, spec, batch, max_len, dtype)
    if spec.mixer == "mamba":
        return mamba_cache_init(cfg, batch, dtype)
    if spec.mixer == "mlstm":
        return mlstm_cache_init(cfg, batch)
    if spec.mixer == "slstm":
        return slstm_cache_init(cfg, batch)
    raise ValueError(spec.mixer)


def layer_decode(p: dict, x: jnp.ndarray, pos, cache, cfg: ModelConfig,
                 spec: LayerSpec) -> tuple[jnp.ndarray, Any]:
    """One-token decode. x: (B, D)."""
    h = rms_norm(x, p["norm_mixer"], cfg.norm_eps)
    if spec.mixer in ("attn", "swa"):
        h, cache = attention_decode(p["attn"], h, pos, cache, cfg, spec)
    elif spec.mixer == "mamba":
        h, cache = mamba_decode(p["mamba"], h, cache, cfg)
    elif spec.mixer == "mlstm":
        h, cache = mlstm_decode(p["mlstm"], h, cache, cfg)
    elif spec.mixer == "slstm":
        h, cache = slstm_decode(p["slstm"], h, cache, cfg)
    x = x + h
    if spec.ffn != "none":
        h = rms_norm(x, p["norm_ffn"], cfg.norm_eps)
        if spec.ffn == "mlp":
            h = mlp_apply(p["mlp"], h)
        else:
            h = moe_decode(p["moe"], h, cfg)
        x = x + h
    return x, cache
