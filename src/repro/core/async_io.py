"""Asynchronous storage I/O (paper §3.4(4)).

After the bucket matrix is built, the ascending block visit order for the
whole hop is *known in advance* — a perfect prefetch plan, which is itself
a benefit of block-major scheduling.  The prefetcher runs a background
thread that reads ahead of the consumer up to ``depth`` blocks, so the
processing thread "does not wait for the completion of the I/O in an idle
state".

Device-time accounting under overlap: the engine reports both
``sync_time = cpu + io`` and ``async_time = max(cpu, io) + ramp`` — on
this 1-core container the wall-clock benefit is limited, but the I/O
schedule and counts are identical to a multi-core host.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable


class BlockPrefetcher:
    """Read-ahead worker over a planned block visit order."""

    def __init__(self, reader: Callable[[int], Any], depth: int = 4,
                 should_skip: Callable[[int], bool] | None = None):
        self.reader = reader
        self.depth = depth
        self.should_skip = should_skip
        self._plan: queue.Queue = queue.Queue()
        self._done: dict[int, Any] = {}
        self._lock = threading.Lock()
        self._ready = threading.Condition(self._lock)
        self._stop = False
        self._inflight = 0
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def plan(self, block_ids) -> None:
        """Queue the hop's ascending block visit order."""
        for b in list(block_ids):
            self._plan.put(int(b))

    def take(self, block_id: int) -> Any | None:
        """Non-blocking: return the prefetched block if ready, else None."""
        with self._lock:
            return self._done.pop(block_id, None)

    def wait(self, block_id: int, timeout: float = 30.0) -> Any | None:
        """Blocking variant used when the consumer catches up to the plan."""
        with self._ready:
            if block_id in self._done:
                return self._done.pop(block_id)
            self._ready.wait_for(lambda: block_id in self._done or self._stop,
                                 timeout=timeout)
            return self._done.pop(block_id, None)

    def _run(self) -> None:
        while not self._stop:
            try:
                b = self._plan.get(timeout=0.1)
            except queue.Empty:
                continue
            with self._lock:
                backlog = len(self._done)
            if backlog >= self.depth:
                # consumer is behind; throttle via condition rather than spin
                with self._ready:
                    self._ready.wait_for(
                        lambda: len(self._done) < self.depth or self._stop,
                        timeout=1.0)
            if self._stop:
                break
            if self.should_skip is not None and self.should_skip(b):
                continue  # already resident in the consumer's buffer
            blk = self.reader(b)
            with self._ready:
                self._done[b] = blk
                self._ready.notify_all()

    def close(self) -> None:
        self._stop = True
        with self._ready:
            self._ready.notify_all()
        self._thread.join(timeout=2.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
