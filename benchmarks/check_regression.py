"""Benchmark regression guard (``RUN_BENCH=1 scripts/test.sh``).

Compares the freshly written ``BENCH_*.json`` trajectory files at the
repo root against each benchmark's asserted speedup floor — the floors
are imported from the benchmark modules themselves, so the guard can
never drift from what the benchmarks enforce inline.  Fails loudly
(non-zero exit, one line per violation) on any regression; a missing
trajectory file is skipped with a note (subset runs must not fail the
guard), but a file that exists with a missing or sub-floor speedup is
an error.

  PYTHONPATH=src python -m benchmarks.check_regression [repo_root]
"""
from __future__ import annotations

import json
import os
import sys

from . import (bench_cache, bench_doctor, bench_faults, bench_io_sched,
               bench_migration, bench_obs, bench_plan_fusion, bench_serving,
               bench_striping)

# file -> [(dotted path into the json payload, floor, description)]
GUARDS = {
    "BENCH_io.json": [
        ("io.ssd1.speedup", bench_io_sched.MIN_SPEEDUP,
         "coalesced vs per-block prepare I/O (1 SSD)"),
        ("io.ssd4.speedup", bench_io_sched.MIN_SPEEDUP,
         "coalesced vs per-block prepare I/O (RAID0 x4)"),
    ],
    "BENCH_fusion.json": [
        ("fusion.speedup", bench_plan_fusion.MIN_SPEEDUP,
         "fused vs barriered staged prepare"),
    ],
    "BENCH_stripe.json": [
        ("stripe.speedup_1_to_4", bench_striping.MIN_SPEEDUP,
         "striped 4-array vs single-array prepare I/O"),
        ("stripe.policy_duel.speedup", bench_striping.MIN_POLICY_GAIN,
         "degree-aware placement vs round-robin stripe"),
    ],
    "BENCH_migrate.json": [
        ("migrate.speedup", bench_migration.MIN_SPEEDUP,
         "online re-placement vs static placement, drifting hotspot "
         "(migration write cost charged)"),
    ],
    "BENCH_cache.json": [
        ("cache.speedup", bench_cache.MIN_SPEEDUP,
         "oracle (Belady MIN) vs clock cache on modeled prepare I/O "
         "at equal capacity (eviction writebacks charged)"),
    ],
    "BENCH_faults.json": [
        ("faults.degraded.throughput_frac",
         bench_faults.MIN_DEGRADED_THROUGHPUT,
         "degraded 3-of-4-array training vs fault-free 3-array baseline "
         "(dropout + evacuation, recovery I/O charged)"),
        ("faults.hedge.speedup", bench_faults.MIN_HEDGE_GAIN,
         "hedged duplicate reads vs fully exposed latency stragglers"),
    ],
    "BENCH_serving.json": [
        ("serving.duel.inference.p99_headroom",
         bench_serving.MIN_P99_HEADROOM,
         "inference prepare p99 under concurrent bulk training within "
         "3x of the idle-system p99 (QoS admission)"),
        ("serving.duel.training.throughput_frac",
         bench_serving.MIN_TRAIN_THROUGHPUT,
         "bulk training modeled I/O rate vs solo with admission stalls "
         "charged, inference tenant live"),
    ],
    "BENCH_obs.json": [
        ("obs.overhead.off_on_ratio", bench_obs.MIN_OFF_ON_RATIO,
         "prepare wall with tracing off vs on — tracing overhead must "
         "stay within ~5%"),
        ("obs.breakdown.agreement", bench_obs.MIN_BREAKDOWN_AGREEMENT,
         "trace-derived Fig.2 prepare/train bars vs OverlapReport wall "
         "times on a traced pipelined epoch"),
    ],
    "BENCH_doctor.json": [
        ("doctor.n_correct", bench_doctor.MIN_CORRECT,
         "storage doctor ground truth: planted primary bottleneck "
         "diagnosed correctly in >= 7 of 8 labeled scenarios"),
        ("doctor.clean.alert_free", bench_doctor.MIN_CLEAN_ALERT_FREE,
         "clean run false positives: zero watchdog alerts and zero "
         "causal findings on an unperturbed workload"),
    ],
}


def _lookup(payload: dict, dotted: str):
    node = payload
    for key in dotted.split("."):
        if not isinstance(node, dict) or key not in node:
            return None
        node = node[key]
    return node


def check(root: str) -> list[str]:
    failures: list[str] = []
    for fname, guards in GUARDS.items():
        path = os.path.join(root, fname)
        if not os.path.exists(path):
            print(f"# {fname}: not present, skipping "
                  f"(subset run writes only what it measured)")
            continue
        with open(path) as f:
            payload = json.load(f)
        for dotted, floor, what in guards:
            value = _lookup(payload, dotted)
            if not isinstance(value, (int, float)):
                failures.append(
                    f"{fname}: {dotted} missing — {what} was not measured "
                    f"by the run that wrote this file")
                continue
            if value < floor:
                failures.append(
                    f"{fname}: {dotted} = {value:.3f} < floor {floor} "
                    f"({what})")
            else:
                print(f"# {fname}: {dotted} = {value:.3f} >= {floor} ok")
    return failures


def main() -> None:
    root = sys.argv[1] if len(sys.argv) > 1 else \
        os.path.join(os.path.dirname(__file__), "..")
    failures = check(os.path.abspath(root))
    if failures:
        print("BENCHMARK REGRESSION:", file=sys.stderr)
        for line in failures:
            print(f"  {line}", file=sys.stderr)
        sys.exit(1)
    print("# benchmark floors all green")


if __name__ == "__main__":
    main()
