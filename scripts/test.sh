#!/usr/bin/env bash
# Tier-1 verify — runs the suite exactly as ROADMAP.md specifies.
# RUN_SLOW=1 additionally re-runs the cache-oracle property battery at
# its widened budget (REPRO_SLOW=1: ~5x the seeded traces, and larger
# hypothesis example budgets where hypothesis is installed).
# RUN_BENCH=1 additionally runs the --quick benchmark smoke tier, which
# writes BENCH_io.json (I/O scheduler before/after numbers),
# BENCH_fusion.json (fused vs barriered staged prepare),
# BENCH_stripe.json (multi-SSD striping sweep), BENCH_migrate.json
# (online re-placement vs static, drifting hotspot), BENCH_cache.json
# (oracle vs clock/LRU cache policy duel + HBM hit fraction) and
# BENCH_faults.json (fault-domain parity/hedge/degraded/replay drill)
# at repo root, then runs the regression guard: every freshly written
# BENCH_*.json speedup is compared against its benchmark's asserted
# floor and any regression fails the build loudly
# (benchmarks/check_regression.py).
# RUN_FAULTS=1 runs just the fault-domain tier: the fault-injection and
# migration/journal-replay test files, the --quick faults benchmark
# (writes BENCH_faults.json) and the regression guard over its floors
# (degraded 3-of-4 throughput, hedge gain).
# RUN_SERVING=1 runs just the serving tier: the QoS admission /
# multi-tenant test file, the --quick serving benchmark (writes
# BENCH_serving.json) and the regression guard over its floors
# (inference p99 headroom under concurrent training, bulk training
# throughput fraction with admission stalls charged).
# RUN_OBS=1 runs just the observability tier: the telemetry test file,
# the --quick obs benchmark (writes BENCH_obs.json) and the regression
# guard over its floors (tracing overhead <= ~5%, Fig.2 breakdown
# agreement with OverlapReport).
# RUN_DOCTOR=1 runs just the storage-doctor tier: the diagnosis test
# file, the --quick doctor benchmark (writes BENCH_doctor.json: eight
# labeled bottleneck scenarios graded against the doctor's primary
# finding) and the regression guard over its floors (>= 7/8 correct,
# zero false positives on the clean run).
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
if [[ "${RUN_SLOW:-0}" == "1" ]]; then
  REPRO_SLOW=1 PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -x -q tests/test_cache_oracle.py
fi
if [[ "${RUN_BENCH:-0}" == "1" ]]; then
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.run --quick
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.check_regression
fi
if [[ "${RUN_FAULTS:-0}" == "1" ]]; then
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -x -q tests/test_fault_injection.py tests/test_migration.py
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.run --quick faults
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.check_regression
fi
if [[ "${RUN_SERVING:-0}" == "1" ]]; then
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -x -q tests/test_serving.py
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.run --quick serving
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.check_regression
fi
if [[ "${RUN_OBS:-0}" == "1" ]]; then
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -x -q tests/test_telemetry.py
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.run --quick obs
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.check_regression
fi
if [[ "${RUN_DOCTOR:-0}" == "1" ]]; then
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -x -q tests/test_diagnosis.py
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.run --quick doctor
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.check_regression
fi
