import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Roofline analysis from compiled dry-run artifacts (deliverable g).

Method (DESIGN.md §8).  ``cost_analysis()`` counts a ``scan``/``while``
body ONCE (verified empirically), so depth-scanned lowering undercounts.
Every cell is therefore lowered twice in *loop-free* form:

  * layer stack **unrolled** at depths L1 = head+tail+2·unit and
    L2 = head+tail+4·unit (repeat-unit reps 2 and 4),
  * all inner chunk loops removed by config overrides — attention
    ``full``, one CE chunk, one Mamba/mLSTM time chunk, one MoE dispatch
    group, a single grad-accumulation microbatch over the full global
    batch.  These transforms are flop-preserving (chunking never changes
    the math); buffers get huge but nothing is allocated (compile only).

HLO cost is exactly affine in the rep count: cost(reps) = a + b·reps.
We solve (a, b) from (L1, L2) and report cost(full reps).  The only
remaining loop is sLSTM's true time recurrence — corrected by a separate
mini-unroll (S=8 vs 16) slope, scaled to the full sequence.

Terms per (arch × shape), single-pod mesh (256 chips), TPU v5e:
  compute_s    = flops_per_chip / 197e12
  memory_s     = hbm_bytes_per_chip / 819e9
  collective_s = collective_bytes_per_chip / 50e9   (ICI link)
``cost_analysis()`` of the post-SPMD module is per-chip; collective
operand sizes parsed from the compiled HLO are per-chip shard sizes.

Bound MFU = MODEL_FLOPS / (chips · peak · max(terms)) — the score §Perf
hillclimbs.
"""
import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp

from ..configs import SHAPES, get_config
from ..configs.base import ModelConfig
from .dryrun import collective_bytes, input_specs, lower_cell, should_skip
from .mesh import make_production_mesh

PEAK_FLOPS = 197e12      # bf16 / chip (v5e)
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link
CHIPS = 256


def _loopfree_overrides(cfg: ModelConfig) -> dict:
    big = 1 << 30
    # NOTE: MoE dispatch keeps the REAL group size — one-hot dispatch
    # flops scale linearly in group size, so a giant merged group is NOT
    # flop-preserving (verified: 70x inflation).  The group loop unrolls
    # via unroll_inner; MoE cells additionally extrapolate over a reduced
    # batch (see roofline_cell) to bound the unrolled group count.
    return {
        "ce_chunk": big,
        "attn_chunk": big,
        "ssm": dataclasses.replace(cfg.ssm, chunk=big),
        "scan_layers": False,
    }


def _lower_costs(arch: str, shape_name: str, mesh, n_layers: int,
                 enc_override: int | None = None,
                 extra_overrides: dict | None = None,
                 fsdp_threshold: int | None = None,
                 batch_override: int | None = None) -> dict:
    """Loop-free lowering at a given depth; returns flops/bytes/coll."""
    cfg = get_config(arch)
    overrides = _loopfree_overrides(cfg)
    if extra_overrides:
        overrides.update(extra_overrides)

    rec = lower_cell(
        arch, shape_name, mesh,
        unroll_inner=False,   # remaining loops (MoE groups, sLSTM time)
        n_layers_override=n_layers,   # are scan-once + corrected
        scan_layers=False,
        n_micro=1,
        cfg_overrides=overrides,
        enc_layers_override=enc_override,
        attn_impl="full",
        fsdp_threshold=fsdp_threshold,
        batch_override=batch_override,
    )
    return {"flops": rec["cost"]["flops"], "bytes": rec["cost"]["bytes"],
            "coll": rec["collectives"]["total_bytes"],
            "coll_by_op": rec["collectives"]["bytes"],
            "memory": rec["memory"]}


def _slstm_correction(cfg: ModelConfig, shape, kind: str) -> dict:
    """Per-step recurrent cost of sLSTM layers × (S-1) (see module doc)."""
    n_slstm = sum(1 for s in cfg.layers if s.mixer == "slstm")
    if n_slstm == 0 or kind == "decode":
        return {"flops": 0.0, "bytes": 0.0}
    from ..models.xlstm import slstm_apply, slstm_init
    B = shape.global_batch
    key = jax.random.PRNGKey(0)
    sc = dataclasses.replace(cfg)
    p = jax.eval_shape(lambda: slstm_init(key, sc))

    def run(S):
        x = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
        if kind == "train":
            fn = jax.grad(lambda pp, xx: slstm_apply(pp, xx, sc).sum()
                          .astype(jnp.float32))
            lowered = jax.jit(fn).lower(p, x)
        else:
            lowered = jax.jit(lambda pp, xx: slstm_apply(pp, xx, sc)).lower(p, x)
        c = lowered.compile().cost_analysis()
        return float(c.get("flops", 0)), float(c.get("bytes accessed", 0))

    f8, b8 = run(8)
    f16, b16 = run(16)
    per_step_f = (f16 - f8) / 8.0
    per_step_b = (b16 - b8) / 8.0
    extra_steps = shape.seq_len - 1  # scan body was counted once
    return {"flops": n_slstm * per_step_f * extra_steps / CHIPS * 1.0,
            "bytes": n_slstm * per_step_b * extra_steps / CHIPS * 1.0}


def _moe_correction(cfg: ModelConfig, shape, kind: str) -> dict:
    """(n_groups - 1) × per-group dispatch/expert cost per MoE layer.

    The MoE group loop stays a ``lax.scan`` in the roofline lowering
    (unrolling 256 groups would explode the HLO; merging groups is not
    flop-preserving), so the body is counted once — this adds the
    remaining groups from a standalone lowering of one dispatch group.
    """
    n_moe = sum(1 for s in cfg.layers if s.ffn == "moe")
    if n_moe == 0 or kind == "decode":
        return {"flops": 0.0, "bytes": 0.0}
    from ..models.moe import _dispatch_one_group, moe_init
    tokens_total = shape.global_batch * shape.seq_len
    n_groups = max(1, tokens_total // cfg.moe.group_tokens)
    if n_groups <= 1:
        return {"flops": 0.0, "bytes": 0.0}
    p = jax.eval_shape(lambda: moe_init(jax.random.PRNGKey(0), cfg))
    xg = jax.ShapeDtypeStruct((cfg.moe.group_tokens, cfg.d_model),
                              jnp.bfloat16)
    if kind == "train":
        fn = jax.grad(lambda pp, xx: _dispatch_one_group(pp, xx, cfg)[0]
                      .astype(jnp.float32).sum(), argnums=(0,))
    else:
        fn = lambda pp, xx: _dispatch_one_group(pp, xx, cfg)[0]  # noqa: E731
    c = jax.jit(fn).lower(p, xg).compile().cost_analysis()
    per_group_f = float(c.get("flops", 0))
    per_group_b = float(c.get("bytes accessed", 0))
    return {"flops": n_moe * (n_groups - 1) * per_group_f / CHIPS,
            "bytes": n_moe * (n_groups - 1) * per_group_b / CHIPS}


def roofline_cell(arch: str, shape_name: str, mesh,
                  extra_overrides: dict | None = None,
                  fsdp_threshold: int | None = None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    o, u, k, t = cfg.stack_plan()
    r1, r2 = (1, 2) if k < 4 else (2, 4)
    L1, L2 = o + t + r1 * u, o + t + r2 * u
    enc1 = enc2 = None
    if cfg.n_enc_layers:
        enc1, enc2 = r1, r2   # scale encoder depth with the same reps

    c1 = _lower_costs(arch, shape_name, mesh, L1, enc1, extra_overrides,
                      fsdp_threshold)
    c2 = _lower_costs(arch, shape_name, mesh, L2, enc2, extra_overrides,
                      fsdp_threshold)

    def extrap(key):
        slope = (c2[key] - c1[key]) / (r2 - r1)
        intercept = c1[key] - slope * r1
        return intercept + slope * k

    flops = extrap("flops")
    bytes_ = extrap("bytes")
    coll = extrap("coll")
    # all-to-all bytes come only from the MoE dispatch, whose group scan
    # body is counted once -> scale by the group count
    n_groups = 1
    if any(s.ffn == "moe" for s in cfg.layers) and shape.kind != "decode":
        tokens_total = shape.global_batch * shape.seq_len
        n_groups = max(1, tokens_total // cfg.moe.group_tokens)
        a2a_1 = c1["coll_by_op"].get("all-to-all", 0)
        a2a_slope = (c2["coll_by_op"].get("all-to-all", 0) - a2a_1) / (r2 - r1)
        a2a_full = (a2a_1 - a2a_slope * r1) + a2a_slope * k
        coll += a2a_full * (n_groups - 1)
    corr = _slstm_correction(cfg, shape, shape.kind)
    corr_moe = _moe_correction(cfg, shape, shape.kind)
    flops += corr["flops"] + corr_moe["flops"]
    bytes_ += corr["bytes"] + corr_moe["bytes"]

    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_ / HBM_BW
    coll_s = coll / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dominant = max(terms, key=terms.get)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    n_active = cfg.active_param_count()
    model_flops = (6 if shape.kind == "train" else 2) * n_active * tokens
    hlo_flops_global = flops * CHIPS
    step_lb = max(terms.values())
    bound_mfu = model_flops / (CHIPS * PEAK_FLOPS * step_lb) if step_lb else 0
    return {
        "arch": arch, "shape": shape_name, "status": "ok",
        "L_extrapolation": {"L1": L1, "L2": L2, "reps": [r1, r2],
                            "full_reps": k},
        "per_chip": {"flops": flops, "hbm_bytes": bytes_,
                     "collective_bytes": coll},
        "terms_s": {k2: round(v, 6) for k2, v in terms.items()},
        "dominant": dominant,
        "model_flops": model_flops,
        "hlo_flops_global": hlo_flops_global,
        "useful_ratio": round(model_flops / hlo_flops_global, 4)
        if hlo_flops_global else None,
        "bound_mfu": round(bound_mfu, 4),
        "collectives_by_op": c2["coll_by_op"],
        "memory_at_L2": c2["memory"],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--out", default="results/roofline_16x16.json")
    args = ap.parse_args()
    from ..configs import list_configs
    mesh = make_production_mesh()
    archs = [args.arch] if args.arch else list_configs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    results = []
    if os.path.exists(args.out):
        results = json.load(open(args.out))
    done = {(r["arch"], r["shape"]) for r in results}
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    for arch in archs:
        for shape_name in shapes:
            if (arch, shape_name) in done and not args.arch:
                continue
            skip = should_skip(arch, shape_name)
            if skip:
                rec = {"arch": arch, "shape": shape_name, "status": skip}
            else:
                print(f"[roofline] {arch} x {shape_name} ...", flush=True)
                try:
                    rec = roofline_cell(arch, shape_name, mesh)
                    print(f"  {rec['terms_s']} dom={rec['dominant']} "
                          f"bound_mfu={rec['bound_mfu']}", flush=True)
                except Exception as e:  # noqa: BLE001
                    import traceback
                    rec = {"arch": arch, "shape": shape_name,
                           "status": "FAIL",
                           "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-1500:]}
                    print(f"  FAIL {e}", flush=True)
            results = [r for r in results
                       if not (r["arch"] == arch and r["shape"] == shape_name)]
            results.append(rec)
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1, default=str)


if __name__ == "__main__":
    main()
