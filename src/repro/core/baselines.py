"""Baseline storage-based engines the paper compares against (§4, Fig 6).

Each baseline reproduces the *I/O pattern* of the corresponding system at
the granularity the paper analyses (node-granular small reads vs. AGNES's
block-wise reads), while sharing the deterministic sampler so that sampled
MFGs are identical where the system semantics allow:

* :class:`GinexLike`    — superbatch two-pass (sample → build per-superbatch
  optimal-ish feature cache → gather); node-granular 4 KiB feature I/O;
  page-granular topology I/O through an OS-page-cache-like buffer.
  [Ginex, VLDB'22]
* :class:`GNNDriveLike` — no feature cache; asynchronous node-granular
  feature extraction with deep queues; small memory footprint.
  [GNNDrive, ICPP'24]
* :class:`MariusLike`   — partition-buffer training: large sequential
  partition swaps, sampling restricted to in-buffer partitions (the
  system's documented sampling bias). [MariusGNN, EuroSys'23]
* :class:`OutreLike`    — partition-grouped batch construction +
  historical-embedding reuse that skips I/O for stale-but-cached nodes.
  [OUTRE, VLDB'24]

These are simulators of each system's data path, not re-implementations
of their full codebases; DESIGN.md §6 records the fidelity envelope.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from .agnes import PreparedMinibatch, PrepareReport
from .block_store import FeatureBlockStore
from .buffer import BlockBuffer
from .device_model import IOStats, NVMeModel
from .sampling import MFG, assemble_layer, sample_indices

PAGE = 4096


class CSRStorage:
    """Node-granular topology storage (indptr pinned, indices on 'disk').

    Models what Ginex/GNNDrive do: adjacency reads hit the indices file at
    OS-page (4 KiB) granularity through a bounded page buffer.
    """

    def __init__(self, indptr: np.ndarray, indices_path: str, n_edges: int,
                 page_buffer_bytes: int, device: NVMeModel | None = None):
        self.indptr = indptr
        self._mm = np.memmap(indices_path, dtype=np.int64, mode="r",
                             shape=(n_edges,))
        self.device = device or NVMeModel()
        self.stats = IOStats()
        self.page_buffer = BlockBuffer(max(page_buffer_bytes // PAGE, 2),
                                       name="pages")
        self.items_per_page = PAGE // 8

    @classmethod
    def build(cls, indices_path: str, indptr: np.ndarray, indices: np.ndarray,
              page_buffer_bytes: int, device: NVMeModel | None = None):
        indices.astype(np.int64).tofile(indices_path)
        return cls(indptr, indices_path, len(indices), page_buffer_bytes, device)

    def read_adjacencies(self, nodes: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Fetch adjacency lists; charge a small I/O per missed page."""
        nodes = np.asarray(nodes, dtype=np.int64)
        starts, ends = self.indptr[nodes], self.indptr[nodes + 1]
        # page-level accounting (most adjacencies span <= 2 pages; hub
        # nodes spanning more are expanded explicitly)
        if len(nodes):
            p0 = starts // self.items_per_page
            p1 = np.maximum(ends - 1, starts) // self.items_per_page
            wide = np.nonzero(p1 - p0 > 1)[0]
            mids = [np.arange(p0[i] + 1, p1[i]) for i in wide.tolist()]
            pages = np.unique(np.concatenate([p0, p1] + mids))
            n_missed = 0
            for p in pages.tolist():
                if p not in self.page_buffer:
                    n_missed += 1
                self.page_buffer.get(int(p), lambda q: True)
            if n_missed:
                t = self.device.batch_time(PAGE * n_missed,
                                           n_random=n_missed)
                self.stats.n_reads += n_missed
                self.stats.bytes_read += PAGE * n_missed
                self.stats.modeled_read_time += t
                self.stats.size_histogram[PAGE // 1024] += n_missed
        counts = ends - starts
        total = int(counts.sum())
        out = np.empty(total, dtype=np.int64)
        offs = np.zeros(len(nodes) + 1, dtype=np.int64)
        np.cumsum(counts, out=offs[1:])
        if total:
            idx = np.repeat(starts - offs[:-1], counts) + np.arange(total)
            np.take(self._mm, idx, out=out)
        return offs, out


def _sample_frontier(csr: CSRStorage, frontier: np.ndarray, fanout: int,
                     seed: int, epoch: int, hop: int) -> np.ndarray:
    """Shared deterministic sampling over node-granular topology reads."""
    offs, adj = csr.read_adjacencies(frontier)
    deg = np.diff(offs)
    pos = sample_indices(frontier, deg, fanout, seed, epoch, hop)
    base = offs[:-1][:, None]
    sel = np.where(pos >= 0, base + np.clip(pos, 0, None), 0)
    sel = np.clip(sel, 0, max(len(adj) - 1, 0))
    vals = adj[sel] if len(adj) else np.zeros_like(sel)
    return np.where(pos >= 0, vals, -1)


def _sample_minibatch(csr: CSRStorage, targets: np.ndarray,
                      fanouts, seed: int, epoch: int) -> MFG:
    frontier = np.unique(np.asarray(targets, dtype=np.int64))
    mfg = MFG(nodes=[frontier], layers=[])
    for hop, fanout in enumerate(fanouts):
        nbrs = _sample_frontier(csr, frontier, fanout, seed, epoch, hop)
        frontier, layer = assemble_layer(frontier, nbrs)
        mfg.nodes.append(frontier)
        mfg.layers.append(layer)
    return mfg


@dataclasses.dataclass
class BaselineConfig:
    fanouts: tuple[int, ...] = (10, 10, 10)
    feature_cache_rows: int = 0       # Ginex/OUTRE row budget
    page_buffer_bytes: int = 4 << 30  # topology page cache
    io_unit: int = PAGE
    seed: int = 0
    # Marius/OUTRE partitioning
    n_partitions: int = 16
    buffer_partitions: int = 4


class _BaseEngine:
    name = "base"

    def __init__(self, csr: CSRStorage, feature_store: FeatureBlockStore,
                 config: BaselineConfig):
        self.csr = csr
        self.features = feature_store
        self.cfg = config
        self.last_report: PrepareReport | None = None

    def _io_snapshot(self):
        c, f = self.csr.stats, self.features.stats
        return (c.n_reads, c.bytes_read, c.modeled_read_time,
                f.n_reads, f.bytes_read, f.modeled_read_time)

    def _mk_report(self, t0, t1, t2, before, after, async_io=False):
        d = [a - b for a, b in zip(self._io_snapshot(), before)]
        cpu = t2 - t0
        io = d[2] + d[5]
        return PrepareReport(
            t1 - t0, t2 - t1,
            {"n_reads": d[0], "bytes": d[1], "modeled_s": d[2]},
            {"n_reads": d[3], "bytes": d[4], "modeled_s": d[5]},
            io, max(cpu, io) if async_io else cpu + io)

    def io_stats(self) -> dict:
        total = IOStats().merge(self.csr.stats).merge(self.features.stats)
        return {"topology": self.csr.stats.summary(),
                "feature": self.features.stats.summary(),
                "total": total.summary()}


class GinexLike(_BaseEngine):
    """Superbatch two-pass with per-superbatch near-optimal feature cache."""

    name = "ginex"

    def prepare(self, targets_per_mb, epoch: int = 0):
        cfg = self.cfg
        before = self._io_snapshot()
        t0 = time.perf_counter()
        mfgs = [_sample_minibatch(self.csr, t, cfg.fanouts, cfg.seed, epoch)
                for t in targets_per_mb]
        t1 = time.perf_counter()
        # changeset precomputation: per-superbatch access counts -> cache set
        inputs = [m.input_nodes for m in mfgs]
        all_nodes, counts = np.unique(np.concatenate(inputs),
                                      return_counts=True)
        budget = cfg.feature_cache_rows or len(all_nodes)
        order = np.argsort(-counts, kind="stable")
        preload = np.sort(all_nodes[order[:budget]])
        # cache preload (Ginex pays this up front; ascending = semi-sequential)
        slot = np.full(self.features.n_nodes, -1, dtype=np.int64)
        cache_rows = np.zeros((len(preload), self.features.dim),
                              dtype=self.features.dtype)
        if len(preload):
            cache_rows[:] = self.features.read_rows_node_granular(
                preload, cfg.io_unit)
            slot[preload] = np.arange(len(preload))
        feats = []
        for nodes in inputs:
            out = np.empty((len(nodes), self.features.dim),
                           dtype=self.features.dtype)
            s = slot[nodes]
            hit = s >= 0
            out[hit] = cache_rows[s[hit]]
            misses = nodes[~hit]
            if len(misses):
                out[~hit] = self.features.read_rows_node_granular(
                    misses, cfg.io_unit)
            self.features.stats.cache_hits += int(hit.sum())
            self.features.stats.cache_misses += int((~hit).sum())
            feats.append(out)
        t2 = time.perf_counter()
        self.last_report = self._mk_report(t0, t1, t2, before, None)
        return [PreparedMinibatch(m, f) for m, f in zip(mfgs, feats)]


class GNNDriveLike(_BaseEngine):
    """No feature cache; async node-granular extraction, deep queues."""

    name = "gnndrive"

    def prepare(self, targets_per_mb, epoch: int = 0):
        cfg = self.cfg
        before = self._io_snapshot()
        t0 = time.perf_counter()
        mfgs = [_sample_minibatch(self.csr, t, cfg.fanouts, cfg.seed, epoch)
                for t in targets_per_mb]
        t1 = time.perf_counter()
        feats = []
        for m in mfgs:
            feats.append(self.features.read_rows_node_granular(
                m.input_nodes, cfg.io_unit))
        t2 = time.perf_counter()
        self.last_report = self._mk_report(t0, t1, t2, before, None,
                                           async_io=True)
        return [PreparedMinibatch(m, f) for m, f in zip(mfgs, feats)]


class MariusLike(_BaseEngine):
    """Partition-buffer training: big sequential swaps, in-buffer sampling.

    Nodes are range-partitioned; the buffer holds ``buffer_partitions`` of
    them.  Target nodes outside the buffered partitions are deferred to a
    later buffer state; sampled neighbors outside the buffer are dropped
    (MariusGNN's documented in-buffer sampling restriction).
    """

    name = "marius"

    def prepare(self, targets_per_mb, epoch: int = 0):
        cfg = self.cfg
        n = len(self.csr.indptr) - 1
        psize = -(-n // cfg.n_partitions)
        before = self._io_snapshot()
        t0 = time.perf_counter()
        # schedule buffer states round-robin over partition groups
        rng = np.random.default_rng(cfg.seed + epoch)
        part_order = rng.permutation(cfg.n_partitions)
        groups = [part_order[i:i + cfg.buffer_partitions]
                  for i in range(0, cfg.n_partitions, cfg.buffer_partitions)]
        mfgs_out, feats_out = [], []
        bytes_per_part_topo = self.csr._mm.nbytes // cfg.n_partitions
        bytes_per_part_feat = (self.features.n_nodes
                               * self.features.row_bytes // cfg.n_partitions)
        for g in groups:
            in_buf = np.zeros(n, dtype=bool)
            for p in g.tolist():
                in_buf[p * psize:min((p + 1) * psize, n)] = True
            # partition swap: large sequential reads (topology + features)
            swap_bytes = (bytes_per_part_topo + bytes_per_part_feat) * len(g)
            t = self.csr.device.batch_time(swap_bytes, n_random=len(g),
                                           n_sequential=len(g))
            self.csr.stats.record_read(swap_bytes, t, sequential=True)
            for targets in targets_per_mb:
                targets = np.asarray(targets, dtype=np.int64)
                mine = targets[in_buf[targets]]
                if len(mine) == 0:
                    continue
                mfg = self._sample_in_buffer(mine, in_buf, epoch)
                # features come from the buffered partitions: no extra I/O
                feats = np.asarray(self.features._mm[mfg.input_nodes])
                mfgs_out.append(mfg)
                feats_out.append(feats)
        t2 = time.perf_counter()
        self.last_report = self._mk_report(t0, t2, t2, before, None)
        return [PreparedMinibatch(m, f) for m, f in zip(mfgs_out, feats_out)]

    def _sample_in_buffer(self, targets, in_buf, epoch) -> MFG:
        frontier = np.unique(targets)
        mfg = MFG(nodes=[frontier], layers=[])
        for hop, fanout in enumerate(self.cfg.fanouts):
            nbrs = _sample_frontier(self.csr, frontier, fanout,
                                    self.cfg.seed, epoch, hop)
            # drop out-of-buffer neighbors (sampling bias of the system)
            nbrs = np.where((nbrs >= 0) & in_buf[np.clip(nbrs, 0, None)],
                            nbrs, -1)
            frontier, layer = assemble_layer(frontier, nbrs)
            mfg.nodes.append(frontier)
            mfg.layers.append(layer)
        return mfg


class OutreLike(_BaseEngine):
    """Partition-grouped batches + historical-embedding reuse."""

    name = "outre"

    def __init__(self, csr, feature_store, config):
        super().__init__(csr, feature_store, config)
        cap = config.feature_cache_rows or 1
        self._hist = np.full(feature_store.n_nodes, -1, dtype=np.int64)
        self._hist_rows = np.zeros((max(cap, 1), feature_store.dim),
                                   dtype=feature_store.dtype)
        self._clock = 0
        self._cap = max(cap, 1)
        self._slot_node = np.full(self._cap, -1, dtype=np.int64)

    def prepare(self, targets_per_mb, epoch: int = 0):
        cfg = self.cfg
        before = self._io_snapshot()
        t0 = time.perf_counter()
        # partition-grouped batch construction: sort each minibatch's
        # targets so topology pages are shared within the batch
        mfgs = [_sample_minibatch(self.csr, np.sort(np.asarray(t)),
                                  cfg.fanouts, cfg.seed, epoch)
                for t in targets_per_mb]
        t1 = time.perf_counter()
        feats = []
        for m in mfgs:
            nodes = m.input_nodes
            slots = self._hist[nodes]
            hit = slots >= 0
            out = np.empty((len(nodes), self.features.dim),
                           dtype=self.features.dtype)
            out[hit] = self._hist_rows[slots[hit]]  # historical embeddings
            misses = nodes[~hit]
            if len(misses):
                fresh = self.features.read_rows_node_granular(misses,
                                                              cfg.io_unit)
                out[~hit] = fresh
                self._admit(misses, fresh)
            self.features.stats.cache_hits += int(hit.sum())
            self.features.stats.cache_misses += int((~hit).sum())
            feats.append(out)
        t2 = time.perf_counter()
        self.last_report = self._mk_report(t0, t1, t2, before, None)
        return [PreparedMinibatch(m, f) for m, f in zip(mfgs, feats)]

    def _admit(self, nodes, rows):
        k = len(nodes)
        slots = (self._clock + np.arange(k)) % self._cap
        self._clock = int((self._clock + k) % self._cap)
        old = self._slot_node[slots]
        self._hist[old[old >= 0]] = -1
        self._slot_node[slots] = nodes
        self._hist[nodes] = slots
        self._hist_rows[slots] = rows
