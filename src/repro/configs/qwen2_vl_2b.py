"""qwen2-vl-2b [vlm]: 28L, d=1536, 12H (GQA kv=2), d_ff=8960,
vocab=151936 — M-RoPE, dynamic resolution.  The vision frontend is a STUB
(``input_specs`` supplies precomputed patch embeddings); the backbone is
the text decoder with multimodal RoPE. [arXiv:2409.12191; hf]
"""
from .base import ModelConfig, register


@register("qwen2-vl-2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b", family="vlm",
        n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
        d_ff=8960, vocab=151936, head_dim=128,
        mrope=True, frontend="vision_stub", rope_theta=1_000_000.0,
        source="arXiv:2409.12191 (Qwen2-VL-2B)")
