"""Deterministic neighbor sampling + message-flow-graph assembly.

Neighbor choice uses a *counter-based hash* keyed by (seed, epoch, hop,
node): the sample drawn for a node is a pure function of those values and
never of execution order.  This is what makes hyperbatch (block-order)
processing *provably equivalent* to per-minibatch (target-order)
processing — the property tests assert bit-equality — and underpins the
paper's Fig-12 "same accuracy, less time" claim.

For a node with degree ``d``:
* ``d <= fanout``  → take the whole neighborhood (no randomness);
* ``d  > fanout``  → take ``fanout`` draws-with-replacement
  ``hash(seed, epoch, hop, node, j) mod d`` (GraphSAGE-style; duplicates
  are deduped by the MFG `unique` step).

The MFG (message-flow graph) per minibatch per hop is a padded neighbor
table ``nbr_idx: (n_dst, fanout) int32`` indexing into the next layer's
node array, with ``-1`` padding — the dense layout TPU/JAX GNN compute
wants (gathers, not scatters).
"""
from __future__ import annotations

import dataclasses

import numpy as np

_M1 = np.uint64(0xBF58476D1CE4E5B9)
_M2 = np.uint64(0x94D049BB133111EB)


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15))
    x = (x ^ (x >> np.uint64(30))) * _M1
    x = (x ^ (x >> np.uint64(27))) * _M2
    return x ^ (x >> np.uint64(31))


def sample_indices(nodes: np.ndarray, degrees: np.ndarray, fanout: int,
                   seed: int, epoch: int, hop: int) -> np.ndarray:
    """(n, fanout) positions into each node's adjacency list; -1 pad.

    Pure function of (seed, epoch, hop, node) — order-independent.
    """
    n = len(nodes)
    with np.errstate(over="ignore"):
        base = _splitmix64(
            np.uint64(seed) * np.uint64(0x9E3779B97F4A7C15)
            ^ (np.uint64(epoch) << np.uint64(40))
            ^ (np.uint64(hop) << np.uint64(32))
            ^ nodes.astype(np.uint64))
        draws = _splitmix64(base[:, None]
                            + np.arange(1, fanout + 1, dtype=np.uint64)[None, :])
    deg = degrees.astype(np.int64)
    out = np.empty((n, fanout), dtype=np.int64)
    big = deg > fanout
    # d > fanout: hashed draws mod d
    safe = np.maximum(deg, 1)
    out[:] = (draws % safe.astype(np.uint64)[:, None]).astype(np.int64)
    # d <= fanout: positions 0..d-1 then -1 padding
    ar = np.arange(fanout, dtype=np.int64)[None, :]
    small_take = np.where(ar < deg[:, None], ar, -1)
    out = np.where(big[:, None], out, small_take)
    return out


@dataclasses.dataclass
class MFGLayer:
    """One hop of a sampled message-flow graph."""

    nbr_idx: np.ndarray   # (n_dst, fanout) int32 → index into next layer nodes; -1 pad
    self_idx: np.ndarray  # (n_dst,) int32 → index of dst nodes in next layer nodes
    n_src: int            # size of next layer's node array


@dataclasses.dataclass
class MFG:
    """Sampled k-hop computation graph for one minibatch.

    ``nodes[0]`` are the targets; ``nodes[k]`` is the full receptive field
    (self-inclusive, so features for ``nodes[k]`` suffice for all hops).
    """

    nodes: list[np.ndarray]    # per hop: node ids, hop 0 = targets
    layers: list[MFGLayer]     # len k; layers[h] maps nodes[h] ← nodes[h+1]

    @property
    def input_nodes(self) -> np.ndarray:
        return self.nodes[-1]

    @property
    def all_sampled(self) -> np.ndarray:
        return np.unique(np.concatenate(self.nodes))


def next_frontier(dst_nodes: np.ndarray, sampled_nbrs: np.ndarray) -> np.ndarray:
    """Next layer's node array: dst nodes (self edges) + sampled neighbors.

    Split out of :func:`assemble_layer` so a staged prepare can submit
    the next hop's I/O plan as soon as the frontier exists — before the
    layer's index maps are built (cross-hop plan fusion).
    """
    valid = sampled_nbrs >= 0
    return np.unique(np.concatenate([dst_nodes, sampled_nbrs[valid]]))


def layer_from_frontier(dst_nodes: np.ndarray, sampled_nbrs: np.ndarray,
                        nxt: np.ndarray) -> MFGLayer:
    """Index maps of one MFG layer given its (sorted-unique) next frontier.

    Equivalent to the ``return_inverse`` of :func:`assemble_layer`: for
    ``nxt = unique(cat)``, ``searchsorted(nxt, x)`` is x's inverse index.
    """
    valid = sampled_nbrs >= 0
    self_idx = np.searchsorted(nxt, dst_nodes).astype(np.int32)
    nbr_idx = np.full(sampled_nbrs.shape, -1, dtype=np.int32)
    nbr_idx[valid] = np.searchsorted(nxt, sampled_nbrs[valid]).astype(np.int32)
    return MFGLayer(nbr_idx, self_idx, int(len(nxt)))


def assemble_layer(dst_nodes: np.ndarray, sampled_nbrs: np.ndarray) -> tuple[np.ndarray, MFGLayer]:
    """Build one MFG layer from dst nodes + their sampled neighbors.

    ``sampled_nbrs``: (n_dst, fanout) node ids with -1 padding.
    Returns (next_layer_nodes, MFGLayer); next layer includes dst nodes
    (self edges) so receptive fields nest.
    """
    nxt = next_frontier(dst_nodes, sampled_nbrs)
    return nxt, layer_from_frontier(dst_nodes, sampled_nbrs, nxt)
