"""Config registry: one module per assigned architecture (+ paper GNNs)."""
import importlib

from .base import (LayerSpec, ModelConfig, MoEConfig, SSMConfig, SHAPES,
                   ShapeConfig, get_config, list_configs, register,
                   smoke_reduce)

_ARCH_MODULES = [
    "gemma3_27b", "smollm_360m", "h2o_danube3_4b", "minitron_4b",
    "jamba15_large", "xlstm_1_3b", "qwen2_vl_2b", "moonshot_v1_16b",
    "deepseek_moe_16b", "seamless_m4t_v2",
]

_loaded = False


def _load_all():
    global _loaded
    if _loaded:
        return
    for m in _ARCH_MODULES:
        importlib.import_module(f".{m}", __package__)
    _loaded = True


__all__ = ["LayerSpec", "ModelConfig", "MoEConfig", "SSMConfig", "SHAPES",
           "ShapeConfig", "get_config", "list_configs", "register",
           "smoke_reduce"]
