"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--trace-dir DIR] \
      [fig2 fig4 fig6 fig7 fig8 fig9 fig10 fig11 fig12 pipeline io fusion
       stripe]

Prints ``name,us_per_call,derived`` CSV (benchmarks/common.emit).

``--trace-dir DIR`` makes the traced benchmarks (obs, doctor) export
their Chrome traces into ``DIR`` (``common.maybe_export_trace``), so a
regression report ships an inspectable ``chrome://tracing`` timeline —
and a ``python -m repro.doctor`` input — next to its ``BENCH_*.json``.

``--quick`` is the smoke tier: every selected benchmark runs on a tiny
synthetic graph (common.QUICK clamps dataset sizes) and the results —
including the I/O scheduler before/after numbers from the ``io``
benchmark (modeled prepare time, achieved bandwidth, sequential
fraction) — are written to ``BENCH_io.json`` at the repo root so the
perf trajectory is tracked PR over PR.  Wired into ``scripts/test.sh``
behind ``RUN_BENCH=1``.
"""
import json
import os
import sys
import time

from . import (bench_cache, bench_doctor, bench_faults,
               bench_fig2_breakdown, bench_fig4_io_unit, bench_fig6_eq1,
               bench_fig7_distdgl, bench_fig8_hyperbatch, bench_fig9_sweep,
               bench_fig10_sensitivity, bench_fig11_bw,
               bench_fig12_accuracy, bench_io_sched, bench_migration,
               bench_obs, bench_pipeline_overlap, bench_plan_fusion,
               bench_serving, bench_striping, common)

ALL = {
    "fig2": bench_fig2_breakdown.run,
    "fig4": bench_fig4_io_unit.run,
    "fig6": bench_fig6_eq1.run,
    "fig7": bench_fig7_distdgl.run,
    "fig8": bench_fig8_hyperbatch.run,
    "fig9": bench_fig9_sweep.run,
    "fig10": bench_fig10_sensitivity.run,
    "fig11": bench_fig11_bw.run,
    "fig12": bench_fig12_accuracy.run,
    "pipeline": bench_pipeline_overlap.run,
    "io": bench_io_sched.run,
    "fusion": bench_plan_fusion.run,
    "stripe": bench_striping.run,
    "migrate": bench_migration.run,
    "cache": bench_cache.run,
    "faults": bench_faults.run,
    "serving": bench_serving.run,
    "obs": bench_obs.run,
    "doctor": bench_doctor.run,
}

OUT_PATH = os.environ.get(
    "REPRO_BENCH_OUT",
    os.path.join(os.path.dirname(__file__), "..", "BENCH_io.json"))
FUSION_OUT_PATH = os.environ.get(
    "REPRO_BENCH_FUSION_OUT",
    os.path.join(os.path.dirname(__file__), "..", "BENCH_fusion.json"))
STRIPE_OUT_PATH = os.environ.get(
    "REPRO_BENCH_STRIPE_OUT",
    os.path.join(os.path.dirname(__file__), "..", "BENCH_stripe.json"))
MIGRATE_OUT_PATH = os.environ.get(
    "REPRO_BENCH_MIGRATE_OUT",
    os.path.join(os.path.dirname(__file__), "..", "BENCH_migrate.json"))
CACHE_OUT_PATH = os.environ.get(
    "REPRO_BENCH_CACHE_OUT",
    os.path.join(os.path.dirname(__file__), "..", "BENCH_cache.json"))
FAULTS_OUT_PATH = os.environ.get(
    "REPRO_BENCH_FAULTS_OUT",
    os.path.join(os.path.dirname(__file__), "..", "BENCH_faults.json"))
SERVING_OUT_PATH = os.environ.get(
    "REPRO_BENCH_SERVING_OUT",
    os.path.join(os.path.dirname(__file__), "..", "BENCH_serving.json"))
OBS_OUT_PATH = os.environ.get(
    "REPRO_BENCH_OBS_OUT",
    os.path.join(os.path.dirname(__file__), "..", "BENCH_obs.json"))
DOCTOR_OUT_PATH = os.environ.get(
    "REPRO_BENCH_DOCTOR_OUT",
    os.path.join(os.path.dirname(__file__), "..", "BENCH_doctor.json"))


def main() -> None:
    argv = sys.argv[1:]
    quick = "--quick" in argv
    if quick:
        argv = [a for a in argv if a != "--quick"]
        common.QUICK = True
        os.environ["REPRO_BENCH_QUICK"] = "1"
    trace_dir = None
    rest = []
    it = iter(argv)
    for a in it:
        if a == "--trace-dir":
            trace_dir = next(it, None)
            if trace_dir is None:
                sys.exit("--trace-dir needs a directory argument")
        elif a.startswith("--trace-dir="):
            trace_dir = a.split("=", 1)[1]
        else:
            rest.append(a)
    argv = rest
    if trace_dir:
        trace_dir = os.path.abspath(trace_dir)
        os.makedirs(trace_dir, exist_ok=True)
        common.TRACE_DIR = trace_dir
        os.environ["REPRO_BENCH_TRACE_DIR"] = trace_dir
    which = argv or list(ALL)
    print("name,us_per_call,derived")
    results: dict = {}
    for name in which:
        t0 = time.time()
        ret = ALL[name]()
        dt = time.time() - t0
        entry: dict = {
            "seconds": round(dt, 2),
            "rows": [{"name": n, "value": v, "derived": d}
                     for n, v, d in common.flush_rows()],
        }
        if isinstance(ret, dict):
            entry["metrics"] = ret
        results[name] = entry
        print(f"# {name} done in {dt:.1f}s", flush=True)
    if quick:
        # per-benchmark trajectory files, tracked PR over PR; only the
        # benchmarks that actually ran overwrite their file — a subset
        # run must not clobber the others with null
        tracked = [("io", OUT_PATH), ("fusion", FUSION_OUT_PATH),
                   ("stripe", STRIPE_OUT_PATH),
                   ("migrate", MIGRATE_OUT_PATH),
                   ("cache", CACHE_OUT_PATH),
                   ("faults", FAULTS_OUT_PATH),
                   ("serving", SERVING_OUT_PATH),
                   ("obs", OBS_OUT_PATH),
                   ("doctor", DOCTOR_OUT_PATH)]
        for name, path in tracked:
            if name not in results:
                continue
            payload = {"quick": True,
                       name: results[name].get("metrics")}
            if name == "io":
                payload["benchmarks"] = results
            out = os.path.abspath(path)
            with open(out, "w") as f:
                json.dump(payload, f, indent=2)
            print(f"# wrote {out}", flush=True)


if __name__ == '__main__':
    main()
