"""Locality-aware data layout (paper §3.2, following RealGraph [9,10]).

AGNES stores objects (a node + its adjacency) in blocks in ascending
node-ID order, so locality is created by *relabeling*: nodes likely to be
accessed together in the same / adjacent iterations of a graph algorithm
get consecutive IDs.  We implement the standard degree-descending-BFS
ordering used by single-machine graph engines: BFS from the highest-degree
unvisited node, visiting neighbors in degree order.  Co-accessed
neighborhoods land in the same or adjacent blocks, which (a) reduces the
number of blocks touched per hyperbatch hop and (b) makes the ascending
block visit order largely *sequential* on the device.
"""
from __future__ import annotations

import numpy as np


def degree_order(indptr: np.ndarray) -> np.ndarray:
    """Relabel by descending degree: perm[new_id] = old_id."""
    deg = np.diff(indptr)
    return np.argsort(-deg, kind="stable").astype(np.int64)


def bfs_locality_order(indptr: np.ndarray, indices: np.ndarray) -> np.ndarray:
    """BFS-from-hubs ordering: perm[new_id] = old_id.

    Repeatedly BFS from the highest-degree unvisited node.  Pure-numpy
    frontier expansion keeps this O(E) and fast on one core.
    """
    n = len(indptr) - 1
    deg = np.diff(indptr)
    visited = np.zeros(n, dtype=bool)
    order = np.empty(n, dtype=np.int64)
    pos = 0
    # seeds in degree-descending order
    seeds = np.argsort(-deg, kind="stable")
    seed_ptr = 0
    while pos < n:
        while seed_ptr < n and visited[seeds[seed_ptr]]:
            seed_ptr += 1
        if seed_ptr >= n:
            break
        root = seeds[seed_ptr]
        visited[root] = True
        order[pos] = root
        pos += 1
        frontier = np.array([root], dtype=np.int64)
        while frontier.size:
            # gather all neighbors of the frontier
            starts = indptr[frontier]
            ends = indptr[frontier + 1]
            counts = ends - starts
            if counts.sum() == 0:
                break
            nbrs = np.concatenate(
                [indices[s:e] for s, e in zip(starts, ends)]) if len(frontier) < 1024 else _gather_ranges(indices, starts, ends)
            nbrs = np.unique(nbrs)
            nbrs = nbrs[~visited[nbrs]]
            if nbrs.size == 0:
                break
            # visit higher-degree neighbors first within the frontier wave
            nbrs = nbrs[np.argsort(-deg[nbrs], kind="stable")]
            visited[nbrs] = True
            order[pos:pos + nbrs.size] = nbrs
            pos += nbrs.size
            frontier = nbrs
    return order


def _gather_ranges(indices: np.ndarray, starts: np.ndarray, ends: np.ndarray) -> np.ndarray:
    """Vectorized concatenation of indices[s:e] ranges."""
    counts = ends - starts
    total = int(counts.sum())
    out = np.empty(total, dtype=indices.dtype)
    # offsets into out
    offs = np.zeros(len(starts) + 1, dtype=np.int64)
    np.cumsum(counts, out=offs[1:])
    idx = np.repeat(starts - offs[:-1], counts) + np.arange(total)
    np.take(indices, idx, out=out)
    return out


def apply_relabel(indptr: np.ndarray, indices: np.ndarray,
                  order: np.ndarray) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Relabel a CSR graph with perm[new_id] = old_id.

    Returns (new_indptr, new_indices, inverse) where inverse[old_id] = new_id.
    Row order and neighbor values are both remapped; neighbor lists are kept
    sorted ascending (helps sequential feature-block access downstream).
    """
    n = len(indptr) - 1
    inverse = np.empty(n, dtype=np.int64)
    inverse[order] = np.arange(n, dtype=np.int64)
    deg = np.diff(indptr)
    new_deg = deg[order]
    new_indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(new_deg, out=new_indptr[1:])
    starts, ends = indptr[order], indptr[order] + new_deg
    new_indices = inverse[_gather_ranges(indices, starts, ends)]
    # sort each adjacency list (vectorized segmented sort)
    seg_ids = np.repeat(np.arange(n, dtype=np.int64), new_deg)
    sort_keys = seg_ids * (n + 1) + new_indices
    new_indices = new_indices[np.argsort(sort_keys, kind="stable")]
    return new_indptr, new_indices.astype(np.int64), inverse
