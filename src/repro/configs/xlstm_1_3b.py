"""xlstm-1.3b [ssm]: 48L, d=2048, 4H, vocab=50304 — sLSTM + mLSTM blocks
at 7:1 (paper's xLSTM[7:1] at 1.3B scale). [arXiv:2405.04517; unverified]
"""
from .base import LayerSpec, ModelConfig, SSMConfig, register


@register("xlstm-1.3b")
def config() -> ModelConfig:
    # xLSTM[7:1]: 7 mLSTM blocks then 1 sLSTM block, repeated (48 = 6*8).
    unit = [LayerSpec(mixer="mlstm", ffn="none")] * 7 \
        + [LayerSpec(mixer="slstm", ffn="none")]
    layers = tuple(unit * 6)
    return ModelConfig(
        name="xlstm-1.3b", family="ssm",
        n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=50304, head_dim=512,
        layers=layers,
        ssm=SSMConfig(chunk=256),
        source="arXiv:2405.04517 (xLSTM[7:1] 1.3B)")
