#!/usr/bin/env bash
# Tier-1 verify — runs the suite exactly as ROADMAP.md specifies.
# RUN_BENCH=1 additionally runs the --quick benchmark smoke tier, which
# writes BENCH_io.json (I/O scheduler before/after numbers) and
# BENCH_fusion.json (fused vs barriered staged prepare, >= 1.3x asserted)
# at repo root.
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
if [[ "${RUN_BENCH:-0}" == "1" ]]; then
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.run --quick
fi
