"""Pallas kernel parity on *real* sampler-emitted padded MFGs.

`test_kernels.py` sweeps synthetic shapes; here the indices come from the
AGNES sampler itself — including the -1 padding the MFG layout uses for
short neighborhoods, fully-padded (degree-0) rows, and feature widths
(32) that are not lane-aligned, exercising the shape shims in
`kernels/ops.py`.  Then the full model backends: ``gnn_apply`` with
``backend="pallas"`` must match ``backend="jnp"`` within fp32 tolerance
on all three archs, for values and gradients.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AgnesConfig, AgnesEngine
from repro.gnn import GNN_ARCHS, GNNTrainer, gnn_loss, init_gnn, gnn_apply
from repro.gnn.models import pad_mfg
from repro.kernels import (gather_aggregate, gather_resident_rows,
                           gather_rows, ref)


@pytest.fixture(scope="module")
def padded_mfgs(tiny_ds):
    """Sampler-emitted MFGs padded to jit shapes (small pad for interpret)."""
    g, f = tiny_ds.reopen_stores()
    eng = AgnesEngine(g, f, AgnesConfig(
        block_size=16384, minibatch_size=48, hyperbatch_size=2,
        fanouts=(4, 4), graph_buffer_bytes=1 << 20,
        feature_buffer_bytes=1 << 20, async_io=False))
    prepared = eng.prepare([np.arange(48), np.arange(48, 96)])
    return [pad_mfg(p.mfg, p.features, tiny_ds.labels, pad_multiple=32)
            for p in prepared]


def test_mfg_exercises_edge_cases(padded_mfgs):
    """The fixture actually contains -1 padding and degree-0 rows."""
    saw_pad = saw_degree0 = False
    for mfg in padded_mfgs:
        for nbr in mfg.nbr_idx:
            nbr = np.asarray(nbr)
            saw_pad |= bool((nbr < 0).any())
            saw_degree0 |= bool((nbr < 0).all(axis=1).any())
    assert saw_pad and saw_degree0


def test_gather_rows_parity_on_mfg(padded_mfgs):
    for mfg in padded_mfgs:
        for self_idx in mfg.self_idx:
            out = gather_rows(mfg.features, self_idx, use_kernel=True,
                              interpret=True)
            expect = ref.gather_rows_ref(mfg.features, self_idx)
            np.testing.assert_allclose(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize("mean", [True, False])
def test_gather_aggregate_parity_on_mfg(padded_mfgs, mean):
    for mfg in padded_mfgs:
        h = mfg.features
        # deepest hop aggregates straight from the gathered features
        nbr = mfg.nbr_idx[-1]
        out = gather_aggregate(h, nbr, mean=mean, use_kernel=True,
                               interpret=True)
        expect = ref.gather_aggregate_ref(h, nbr, mean=mean)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-5, atol=1e-5)
        # degree-0 (all -1) rows must come out exactly zero
        deg0 = np.asarray(nbr < 0).all(axis=1)
        if deg0.any():
            assert np.all(np.asarray(out)[deg0] == 0.0)


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_backend_parity_forward(padded_mfgs, arch):
    params = init_gnn(jax.random.PRNGKey(0), arch, 32, 32, 16, n_layers=2)
    for mfg in padded_mfgs:
        a = gnn_apply(params, mfg, arch, "jnp")
        b = gnn_apply(params, mfg, arch, "pallas")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_backend_parity_grads(padded_mfgs, arch):
    """The custom VJPs give the pallas backend the same gradients."""
    params = init_gnn(jax.random.PRNGKey(1), arch, 32, 32, 16, n_layers=2)
    mfg = padded_mfgs[0]
    ga = jax.grad(gnn_loss)(params, mfg, arch, "jnp")
    gb = jax.grad(gnn_loss)(params, mfg, arch, "pallas")
    for a, b in zip(jax.tree_util.tree_leaves(ga),
                    jax.tree_util.tree_leaves(gb)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


# --------------------------------------- device-resident (HBM) gather
def _resident_case(rng, n, dim, n_slots, kind):
    """One (table, slots, miss_pos, miss_rows) case of the given kind."""
    table = jnp.asarray(rng.normal(0, 1, (n_slots, dim)).astype(np.float32))
    slots = np.full(n, -1, dtype=np.int64)
    if kind == "all_hit":
        slots[:] = rng.integers(0, n_slots, size=n)
    elif kind == "mixed":
        hit = rng.random(n) < 0.6
        slots[hit] = rng.integers(0, n_slots, size=int(hit.sum()))
    miss_pos = np.nonzero(slots < 0)[0]
    miss_rows = rng.normal(0, 1, (len(miss_pos), dim)).astype(np.float32)
    return (table, jnp.asarray(slots, jnp.int32),
            jnp.asarray(miss_pos, jnp.int32), jnp.asarray(miss_rows))


@pytest.mark.parametrize("dim", [32, 128, 200])
@pytest.mark.parametrize("kind", ["all_hit", "all_miss", "mixed"])
def test_gather_resident_rows_parity(rng, dim, kind):
    """Masked Pallas kernel == ref == plain jnp on every hit/miss split,
    including non-lane-aligned widths (32, 200), an empty miss set and
    an all-miss (cold cache) minibatch."""
    table, slots, miss_pos, miss_rows = _resident_case(
        rng, n=37, dim=dim, n_slots=16, kind=kind)
    kern = gather_resident_rows(table, slots, miss_pos, miss_rows,
                                use_kernel=True, interpret=True)
    host = gather_resident_rows(table, slots, miss_pos, miss_rows,
                                use_kernel=False)
    expect = ref.gather_resident_rows_ref(table, slots, miss_pos,
                                          miss_rows)
    np.testing.assert_array_equal(np.asarray(kern), np.asarray(expect))
    np.testing.assert_array_equal(np.asarray(host), np.asarray(expect))
    # spot-check semantics independently of ref
    s = np.asarray(slots)
    out = np.asarray(kern)
    hits = np.nonzero(s >= 0)[0]
    np.testing.assert_array_equal(out[hits],
                                  np.asarray(table)[s[hits], :dim])
    np.testing.assert_array_equal(out[np.asarray(miss_pos)],
                                  np.asarray(miss_rows))


def test_gather_resident_rows_jit_padding_rows_zero(rng):
    """Rows past the true minibatch (slot -1, no miss entry) come out
    exactly zero through the masked kernel — jit padding never leaks
    clamped-DMA garbage."""
    n, true_n, dim = 64, 50, 32
    table = jnp.asarray(rng.normal(0, 1, (8, dim)).astype(np.float32))
    slots = np.full(n, -1, dtype=np.int64)
    slots[:true_n] = rng.integers(0, 8, size=true_n)
    miss_pos = jnp.zeros(0, jnp.int32)
    miss_rows = jnp.zeros((0, dim), jnp.float32)
    for kw in ({"use_kernel": True, "interpret": True},
               {"use_kernel": False}):
        out = np.asarray(gather_resident_rows(
            table, jnp.asarray(slots, jnp.int32), miss_pos, miss_rows,
            **kw))
        assert (out[true_n:] == 0).all()
        np.testing.assert_array_equal(
            out[:true_n], np.asarray(table)[slots[:true_n], :dim])


def test_gather_resident_rows_empty_minibatch():
    table = jnp.zeros((4, 32), jnp.float32)
    out = gather_resident_rows(table, jnp.zeros(0, jnp.int32),
                               jnp.zeros(0, jnp.int32),
                               jnp.zeros((0, 32), jnp.float32))
    assert out.shape == (0, 32)


def test_to_device_table_parity_on_real_minibatches(tiny_ds):
    """End-to-end: ``to_device(table=...)`` through the masked kernel
    path reproduces the host-gathered features byte-for-byte on real
    prepared minibatches, with warm-cache hits actually served from the
    HBM mirror."""
    g, f = tiny_ds.reopen_stores()
    eng = AgnesEngine(g, f, AgnesConfig(
        block_size=16384, minibatch_size=48, hyperbatch_size=2,
        fanouts=(4,), graph_buffer_bytes=1 << 20,
        feature_buffer_bytes=1 << 20, async_io=False,
        cache_capacity_rows=512, cache_admit_threshold=1))
    table = eng.device_feature_table()
    targets = [np.arange(48), np.arange(48, 96)]
    for _ in range(2):                  # second pass hits the warm cache
        for p in eng.prepare(targets):
            n = p.features.shape[0]
            dv = p.to_device(backend="pallas", table=table)
            got = np.asarray(dv.features)
            assert got.shape[0] % 128 == 0      # jit-stable padding
            np.testing.assert_array_equal(got[:n], p.features)
            assert (got[n:] == 0).all()
    assert table.hit_rows_served > 0, "warm pass never hit the mirror"
    assert table.sync_rows > 0
    eng.close()


def test_trainer_pallas_backend_learns(tiny_ds, padded_mfgs):
    """End-to-end: loss decreases when training through the kernels."""
    g, f = tiny_ds.reopen_stores()
    eng = AgnesEngine(g, f, AgnesConfig(
        block_size=16384, minibatch_size=48, hyperbatch_size=2,
        fanouts=(4, 4), graph_buffer_bytes=1 << 20,
        feature_buffer_bytes=1 << 20, async_io=False))
    tr = GNNTrainer(arch="sage", in_dim=32, hidden=32, n_classes=16,
                    n_layers=2, backend="pallas")
    tr.labels = tiny_ds.labels
    prepared = eng.prepare([np.arange(48)] * 2)
    losses = [tr.train_minibatch(p) for _ in range(4) for p in prepared]
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]
