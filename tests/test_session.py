"""Staged PrepareSession: parity, fusion accounting, stage objects,
placement hook, adaptive queue depth, vectorized-sampler oracle."""
import numpy as np
import pytest

from repro.core import (AgnesConfig, AgnesEngine, PlanStream, NVMeModel,
                        coalesce, plan_cost, sample_indices)


def make_engine(ds, *, fusion=True, mcb=8 << 20, async_io=False, hb=True,
                buffer_bytes=1 << 20, block_size=16384, fanouts=(5, 5),
                cache_rows=0, shared_device=True):
    dev = NVMeModel() if shared_device else None
    g, f = ds.reopen_stores(device=dev)
    cfg = AgnesConfig(block_size=block_size, minibatch_size=64,
                      hyperbatch_size=8, fanouts=fanouts,
                      graph_buffer_bytes=buffer_bytes,
                      feature_buffer_bytes=buffer_bytes,
                      feature_cache_rows=cache_rows,
                      hyperbatch_enabled=hb, async_io=async_io,
                      max_coalesce_bytes=mcb, plan_fusion=fusion)
    return AgnesEngine(g, f, cfg)


def _totals(eng):
    g, f = eng.graph_store.stats, eng.feature_store.stats
    return {
        "bytes": g.bytes_read + f.bytes_read,
        "reads": g.n_reads + f.n_reads,
        "time": g.modeled_read_time + f.modeled_read_time,
    }


def _assert_prepared_equal(p1, p0):
    for a, b in zip(p1, p0):
        assert len(a.mfg.nodes) == len(b.mfg.nodes)
        for x, y in zip(a.mfg.nodes, b.mfg.nodes):
            assert np.array_equal(x, y)
        for lx, ly in zip(a.mfg.layers, b.mfg.layers):
            assert np.array_equal(lx.nbr_idx, ly.nbr_idx)
            assert np.array_equal(lx.self_idx, ly.self_idx)
        assert np.allclose(a.features, b.features)


# ------------------------------------------------------------------ parity
@pytest.mark.parametrize("async_io", [False, True])
def test_session_fused_parity_with_barriered_path(tiny_ds, rng, async_io):
    """Fused session vs pre-redesign schedule: byte-identical MFGs,
    features and bytes_read at a fixed seed (the acceptance criterion)."""
    targets = [rng.choice(tiny_ds.n_nodes, 150, replace=False)
               for _ in range(6)]
    barrier = make_engine(tiny_ds, fusion=False, async_io=async_io)
    fused = make_engine(tiny_ds, fusion=True, async_io=async_io)
    for epoch in range(2):
        p0 = barrier.prepare(targets, epoch=epoch)
        p1 = fused.prepare(targets, epoch=epoch)
        _assert_prepared_equal(p1, p0)
    t0, t1 = _totals(barrier), _totals(fused)
    assert t1["bytes"] == t0["bytes"]
    assert t1["reads"] == t0["reads"]
    # fusion can only help the modeled stream (equal when one regime
    # dominates every stage)
    assert t1["time"] <= t0["time"] + 1e-12
    barrier.close()
    fused.close()


def test_session_parity_with_legacy_scheduler_off(tiny_ds, rng):
    """The session must also reproduce the mcb=0 legacy path exactly."""
    targets = [rng.choice(tiny_ds.n_nodes, 150, replace=False)
               for _ in range(4)]
    legacy = make_engine(tiny_ds, mcb=0)             # no readers at all
    fused = make_engine(tiny_ds, fusion=True)
    p0 = legacy.prepare(targets, epoch=1)
    p1 = fused.prepare(targets, epoch=1)
    _assert_prepared_equal(p1, p0)
    assert _totals(fused)["bytes"] == _totals(legacy)["bytes"]
    legacy.close()
    fused.close()


def test_session_parity_hyperbatch_vs_per_minibatch(tiny_ds, rng):
    """The Fig-12 equivalence survives the staged redesign."""
    targets = [rng.choice(tiny_ds.n_nodes, 64, replace=False)
               for _ in range(6)]
    hb = make_engine(tiny_ds, hb=True)
    no = make_engine(tiny_ds, hb=False)
    _assert_prepared_equal(hb.prepare(targets, epoch=3),
                           no.prepare(targets, epoch=3))
    hb.close()
    no.close()


def test_session_parity_with_feature_cache(tiny_ds, rng):
    targets = [rng.choice(tiny_ds.n_nodes, 150, replace=False)
               for _ in range(4)]
    a = make_engine(tiny_ds, fusion=False, cache_rows=500)
    b = make_engine(tiny_ds, fusion=True, async_io=True, cache_rows=500)
    for ep in range(3):
        _assert_prepared_equal(b.prepare(targets, epoch=ep),
                               a.prepare(targets, epoch=ep))
    assert _totals(b)["bytes"] == _totals(a)["bytes"]
    a.close()
    b.close()


# ------------------------------------------------------------------ stages
def test_session_emits_staged_plans(tiny_ds, rng):
    targets = [rng.choice(tiny_ds.n_nodes, 150, replace=False)
               for _ in range(6)]
    eng = make_engine(tiny_ds, fusion=True, fanouts=(5, 5))
    eng.prepare(targets, epoch=0)
    s = eng.last_session
    assert s is not None and s.fused
    stages = [p.stage for p in s.plans]
    assert stages[0] == "sample:hop0"
    assert stages[-1] == "gather"
    assert "sample:hop1" in stages
    for p in s.plans:
        assert p.state == "consumed"
        assert p.store in ("graph", "feature")
        assert p.nbytes == p.n_blocks * p.block_size
    # a session is single-use
    with pytest.raises(RuntimeError, match="single-use"):
        s.run()
    eng.close()


def test_session_unfused_when_fusion_disabled(tiny_ds, rng):
    targets = [rng.choice(tiny_ds.n_nodes, 64, replace=False)
               for _ in range(4)]
    eng = make_engine(tiny_ds, fusion=False)
    eng.prepare(targets, epoch=0)
    assert not eng.last_session.fused
    assert not any(":early" in p.stage for p in eng.last_session.plans)
    eng.close()


def test_plan_stream_fuses_rooflines():
    """A fused stream pays max-of-sums; a barriered pair pays sum-of-max."""
    dev = NVMeModel()
    stream = PlanStream(dev)
    iops_heavy = coalesce(list(range(0, 400, 2)), 4096, 0)   # 200 heads
    bw_heavy = coalesce(list(range(1000, 3000)), 4096, 64 << 20)  # 8 MiB
    *_, t1 = stream.charge(iops_heavy, 4096, 8)
    *_, t2 = stream.charge(bw_heavy, 4096, 8)
    fused = t1 + t2
    *_, b1 = plan_cost(iops_heavy, 4096, dev, 8)
    *_, b2 = plan_cost(bw_heavy, 4096, dev, 8)
    assert fused < b1 + b2
    assert fused == pytest.approx(max(
        dev.batch_time((200 + 2000) * 4096, n_random=200 + len(bw_heavy),
                       n_sequential=2000 - len(bw_heavy), queue_depth=8),
        b1))
    # a drained stream charges a single plan exactly like plan_cost
    stream.drain()
    *_, t3 = stream.charge(iops_heavy, 4096, 8)
    assert t3 == pytest.approx(b1)


# ------------------------------------------------------------------ oracle
def test_vectorized_sampler_matches_independent_oracle(tiny_ds, rng):
    """Seed-for-seed check of the batched fanout scatter against a
    reference built from the in-memory CSR (no block machinery)."""
    targets = [rng.choice(tiny_ds.n_nodes, 80, replace=False)
               for _ in range(4)]
    fanouts, epoch = (5, 4), 7
    eng = make_engine(tiny_ds, fanouts=fanouts)
    prepared = eng.prepare(targets, epoch=epoch)
    indptr, indices = tiny_ds.indptr, tiny_ds.indices
    for t, p in zip(targets, prepared):
        frontier = np.unique(np.asarray(t, np.int64))
        for hop, fanout in enumerate(fanouts):
            deg = (indptr[frontier + 1] - indptr[frontier]).astype(np.int64)
            pos = sample_indices(frontier, deg, fanout, eng.config.seed,
                                 epoch, hop)
            nbrs = np.full((len(frontier), fanout), -1, dtype=np.int64)
            for i, v in enumerate(frontier):         # reference: plain loop
                adj = indices[indptr[v]:indptr[v + 1]]
                for k in range(fanout):
                    if pos[i, k] >= 0:
                        nbrs[i, k] = adj[pos[i, k]]
            expect = np.unique(np.concatenate([frontier, nbrs[nbrs >= 0]]))
            assert np.array_equal(p.mfg.nodes[hop + 1], expect)
            layer = p.mfg.layers[hop]
            got = np.where(layer.nbr_idx >= 0,
                           expect[np.clip(layer.nbr_idx, 0, None)], -1)
            assert np.array_equal(got, nbrs)
            frontier = expect
    eng.close()


# ------------------------------------------------------------------ placement
def test_to_device_placement_hook(tiny_ds, rng):
    import jax

    targets = [rng.choice(tiny_ds.n_nodes, 64, replace=False)]
    eng = make_engine(tiny_ds)
    p = eng.prepare(targets, epoch=0)[0]
    d = p.to_device()
    assert isinstance(d.features, jax.Array)
    assert np.allclose(np.asarray(d.features), p.features)
    # pallas route: the padded jit-stable block built via gather_rows
    dp = p.to_device(backend="pallas")
    n = p.features.shape[0]
    assert dp.features.shape[0] == -(-n // 128) * 128
    assert np.allclose(np.asarray(dp.features)[:n], p.features)
    assert not np.asarray(dp.features)[n:].any()
    assert d.mfg is p.mfg                # index arrays stay host numpy
    eng.close()


def test_trainer_feature_placement_matches_host_path(tiny_ds, rng):
    from repro.gnn import GNNTrainer

    targets = [rng.choice(tiny_ds.n_nodes, 64, replace=False)]
    eng = make_engine(tiny_ds)
    prepared = eng.prepare(targets, epoch=0)

    def losses(placement):
        tr = GNNTrainer(arch="gcn", in_dim=32, hidden=32, n_classes=16,
                        n_layers=2, seed=11, feature_placement=placement)
        tr.labels = tiny_ds.labels
        return [tr.train_minibatch(p) for p in prepared]

    assert losses(None) == losses("jnp")
    eng.close()


# ------------------------------------------------------------------ adaptive
def test_adaptive_io_resizes_queue_depth(tiny_ds):
    from repro.gnn import GNNTrainer, PipelinedExecutor

    eng = make_engine(tiny_ds, fanouts=(4, 4))
    tr = GNNTrainer(arch="gcn", in_dim=32, hidden=32, n_classes=16,
                    n_layers=2, seed=7)
    tr.labels = tiny_ds.labels
    with PipelinedExecutor(eng, tr, depth=1, adaptive_io=True,
                           io_queue_depth_bounds=(2, 32)) as ex:
        rep = ex.run_epoch(np.arange(512), epoch=0)
    assert len(rep.queue_depths) == rep.n_hyperbatches > 0
    assert all(2 <= qd <= 32 for qd in rep.queue_depths)
    assert rep.queue_depths[-1] == eng.config.io_queue_depth
    io = rep.io_summary()
    assert io["io_queue_depths"] == rep.queue_depths
    assert 0.0 <= io["exposed_prepare_fraction"] <= 1.0
    eng.close()


def test_set_io_queue_depth_propagates(tiny_ds):
    eng = make_engine(tiny_ds)
    assert eng.set_io_queue_depth(16) == 16
    assert eng.config.io_queue_depth == 16
    assert eng._g_prefetch.queue_depth == 16
    assert eng._f_prefetch.queue_depth == 16
    eng.close()


# ------------------------------------------------- legacy-path accounting
def test_prepare_report_deltas_with_scheduler_disabled(tiny_ds, rng):
    """max_coalesce_bytes=0 legacy path stays fully accounted."""
    targets = [rng.choice(tiny_ds.n_nodes, 150, replace=False)
               for _ in range(4)]
    eng = make_engine(tiny_ds, mcb=0)
    eng.prepare(targets, epoch=0)
    rep = eng.last_report
    for io in (rep.sample_io, rep.gather_io):
        assert io["n_reads"] == io["n_requests"]  # no merging without sched
        assert io["bytes"] > 0 and io["modeled_s"] > 0
        assert 0 <= io["n_sequential"] <= io["n_reads"]
    stats = _totals(eng)
    assert rep.sample_io["bytes"] + rep.gather_io["bytes"] == stats["bytes"]
    assert rep.modeled_io_s == pytest.approx(stats["time"])
    eng.close()


def test_io_summary_with_scheduler_disabled(tiny_ds):
    from repro.gnn import GNNTrainer, PipelinedExecutor

    eng = make_engine(tiny_ds, mcb=0, fanouts=(4, 4))
    tr = GNNTrainer(arch="gcn", in_dim=32, hidden=32, n_classes=16,
                    n_layers=2, seed=7)
    tr.labels = tiny_ds.labels
    with PipelinedExecutor(eng, tr, depth=1) as ex:
        rep = ex.run_epoch(np.arange(256), epoch=0)
    io = rep.io_summary()
    assert io["coalesce_factor"] == 1.0     # every block its own request
    assert io["n_reads"] == io["n_requests"] > 0
    assert io["bytes_read"] > 0 and io["modeled_io_s"] > 0
    assert io["io_queue_depths"] == []      # adaptive hook off
    assert rep.summary()["io"] == io
    eng.close()


# ------------------------------------------------- executor shutdown race
def test_shutdown_preserves_producer_error(tiny_ds):
    """A prepare-side exception must survive the queue drain even when the
    consumer is failing at the same time (the old get_nowait drain
    silently discarded the ("error", exc, None) sentinel)."""
    from repro.gnn import PipelinedExecutor

    class Boom(RuntimeError):
        pass

    class FlakyEngine:
        last_report = None

        def plan_epoch(self, targets, epoch=0, shuffle=True):
            return [[targets], [targets]]

        def prepare(self, mbs, epoch=0):
            if not hasattr(self, "_once"):
                self._once = True
                from repro.core import MFG
                return [type("P", (), {"mfg": MFG([np.arange(4)], []),
                                       "features": np.zeros((4, 8))})()]
            raise Boom("prepare died mid-epoch")

    class BadTrainer:
        def train_minibatch(self, prepared):
            raise ValueError("nan loss")

    ex = PipelinedExecutor(FlakyEngine(), BadTrainer(), depth=1)
    with pytest.raises(ValueError, match="nan loss") as ei:
        ex.run_epoch(np.arange(8))
    # the swallowed prepare error is chained, not dropped
    assert isinstance(ei.value.__cause__, Boom)
    ex.close()


def test_clean_epoch_and_close_raise_nothing(tiny_ds):
    from repro.gnn import GNNTrainer, PipelinedExecutor

    eng = make_engine(tiny_ds, fanouts=(4, 4))
    tr = GNNTrainer(arch="gcn", in_dim=32, hidden=32, n_classes=16,
                    n_layers=2, seed=7)
    tr.labels = tiny_ds.labels
    ex = PipelinedExecutor(eng, tr, depth=1)
    rep = ex.run_epoch(np.arange(256), epoch=0)
    assert rep.n_minibatches == 4
    ex.close()
    ex.close()
    eng.close()
