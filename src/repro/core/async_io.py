"""Asynchronous storage I/O (paper §3.4(4)).

After the bucket matrix is built, the ascending block visit order for the
whole hop is *known in advance* — a perfect prefetch plan, which is itself
a benefit of block-major scheduling.  The prefetcher runs a background
thread that reads ahead of the consumer up to ``depth`` blocks, so the
processing thread "does not wait for the completion of the I/O in an idle
state".

This is the legacy one-block-at-a-time path; the engine's default is the
coalesced, plan-driven :class:`repro.core.io_sched.CoalescedReader`,
which shares the same consumer protocol (``plan``/``fetch``/``reset``/
``close``).

Device-time accounting under overlap: the engine reports both
``sync_time = cpu + io`` and ``async_time = max(cpu, io) + ramp`` — on
this 1-core container the wall-clock benefit is limited, but the I/O
schedule and counts are identical to a multi-core host.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Any, Callable


class BlockPrefetcher:
    """Read-ahead worker over a planned block visit order.

    The worker blocks on a condition variable (no polling): it wakes when
    a plan arrives, when the consumer drains a backlog slot, on
    :meth:`reset`, or on :meth:`close` — every wait predicate includes
    ``_stop``, so ``close()`` cannot race the backlog throttle.
    """

    # one plan per hop, reset barrier between hops — a PrepareSession
    # falls back to the barriered schedule when this reader is wired in
    supports_fusion = False

    def __init__(self, reader: Callable[[int], Any], depth: int = 4,
                 should_skip: Callable[[int], bool] | None = None):
        self.reader = reader
        self.depth = depth
        self.should_skip = should_skip
        self._plan: deque[int] = deque()
        self._done: dict[int, Any] = {}
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._gen = 0
        self._stop = False
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def plan(self, block_ids) -> None:
        """Queue the hop's ascending block visit order."""
        with self._cv:
            self._plan.extend(int(b) for b in block_ids)
            self._cv.notify_all()

    # staged-session alias (CoalescedReader's primary spelling)
    submit = plan

    def take(self, block_id: int) -> Any | None:
        """Non-blocking: return the prefetched block if ready, else None."""
        with self._cv:
            blk = self._done.pop(block_id, None)
            if blk is not None:
                self._cv.notify_all()  # freed a backlog slot
            return blk

    # the engine-facing protocol shared with CoalescedReader; the legacy
    # prefetcher stays non-blocking (a skipped block would never arrive)
    fetch = take

    def wait(self, block_id: int, timeout: float = 30.0) -> Any | None:
        """Blocking variant used when the consumer catches up to the plan."""
        with self._cv:
            self._cv.wait_for(lambda: block_id in self._done or self._stop,
                              timeout=timeout)
            blk = self._done.pop(block_id, None)
            if blk is not None:
                self._cv.notify_all()
            return blk

    def reset(self) -> None:
        """Drop the remaining plan and any undelivered blocks.

        Called at hop boundaries: blocks read ahead but never taken (the
        consumer found them already buffer-resident) would otherwise sit
        in ``_done`` forever, permanently consuming ``depth`` slots and
        throttling every later hop.
        """
        with self._cv:
            self._gen += 1
            self._plan.clear()
            self._done.clear()
            self._cv.notify_all()

    def _run(self) -> None:
        while True:
            with self._cv:
                # one predicate covers plan arrival, backlog drain, reset
                # and close — no timed polling
                self._cv.wait_for(
                    lambda: self._stop or (self._plan
                                           and len(self._done) < self.depth))
                if self._stop:
                    return
                gen = self._gen
                b = self._plan.popleft()
            if self.should_skip is not None and self.should_skip(b):
                continue  # already resident in the consumer's buffer
            blk = self.reader(b)
            with self._cv:
                if gen != self._gen or self._stop:
                    continue  # reset() raced the read: drop the stale block
                self._done[b] = blk
                self._cv.notify_all()

    def close(self) -> None:
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        self._thread.join(timeout=2.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
